"""Paper Tables V-VIII / figs 7-9: hash-table comparisons.

table5: fixed-slot vs two-level (threshold expansion) — 50% insert/50% find
table6: one-level vs two-level split-order — wall time + the bytes-touched
        locality proxy standing in for the paper's cache-miss counters
table7/8: two-level-bucket vs split-order vs two-level split-order at two
        workload sizes (the paper's three-way final comparison)

Every structure runs behind the unified `repro.store` protocol: a sweep is
(backend name, capacity, init kwargs) and the workload is an `OpPlan`, so
the comparison matrix IS the backend registry — adding a table variant to
the paper comparison means registering a backend, nothing here changes.
"""
from __future__ import annotations

import math

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench, emit, keys64
from repro.store import OP_FIND, OP_INSERT, get_backend, make_plan

LANES = [16, 64, 256]
ROUNDS = 8


def _mixed_plan(ins_k, find_k):
    n_i, n_f = ins_k.shape[0], find_k.shape[0]
    ops = np.concatenate([np.full(n_i, OP_INSERT, np.int32),
                          np.full(n_f, OP_FIND, np.int32)])
    keys = jnp.concatenate([ins_k, find_k])
    return make_plan(ops, keys, keys)


def _sweep(name, backend, capacity, rng, extra="", **init_kw):
    be = get_backend(backend)
    round_ = jax.jit(lambda st, p: be.apply(st, p))
    for lanes in LANES:
        st = be.init(capacity, **init_kw)
        ins_k = keys64(rng, lanes // 2)
        st, _ = be.apply(st, make_plan(np.full(lanes // 2, OP_INSERT,
                                               np.int32), ins_k, ins_k))
        find_k = ins_k[jnp.asarray(rng.integers(0, lanes // 2, lanes // 2))]
        plan = _mixed_plan(ins_k, find_k)

        def steps(st):
            for _ in range(ROUNDS):
                st, r = round_(st, plan)
            return st

        t = bench(steps, st, iters=3)
        per_op = t / (ROUNDS * lanes)
        emit(f"{name}/threads={lanes}", per_op,
             f"ops_per_sec={1.0/per_op:.3e}{extra}")


def run():
    rng = np.random.default_rng(2)
    # --- table 5: fixed vs two-level ---
    _sweep("table5/fixed", "fixed_hash", 16384, rng, bucket=16)
    _sweep("table5/twolevel", "twolevel_hash", 4096, rng, b1=8, m2=64, b2=8)

    # under load: the paper's point — fixed buckets overflow (failed inserts)
    # while threshold expansion absorbs them
    n = 2048
    ks = keys64(rng, n)
    plan = make_plan(np.full(n, OP_INSERT, np.int32), ks, ks)
    bf, bt = get_backend("fixed_hash"), get_backend("twolevel_hash")
    hf = bf.init(1024, bucket=16)                # capacity 1024 < n
    hf, rf = bf.apply(hf, plan)
    ht = bt.init(1024, b1=8, m2=64, b2=8)        # expands per slot
    ht, rt = bt.apply(ht, plan)
    emit("table5/fixed/load=2x", 0.0,
         f"insert_fail_rate={1 - float(rf.ok.mean()):.3f}")
    emit("table5/twolevel/load=2x", 0.0,
         f"insert_fail_rate={1 - float(rt.ok.mean()):.3f};"
         f"l2_tables={int(bt.stats(ht)['l2_tables'])}")

    # --- table 6: split-order locality ---
    n_entries = 4096
    b1l, b2l = get_backend("splitorder"), get_backend("twolevel_splitorder")
    so = b1l.init(8192, seed_slots=64, max_load=16)
    t2 = b2l.init(16384, num_tables=16, seed_slots=8, max_load=16)
    ks = keys64(rng, n_entries)
    for chunk in np.array_split(np.asarray(ks), 8):
        p = make_plan(np.full(len(chunk), OP_INSERT, np.int32),
                      jnp.asarray(chunk), jnp.asarray(chunk))
        so, _ = b1l.apply(so, p)
        t2, _ = b2l.apply(t2, p)
    q = ks[jnp.asarray(rng.integers(0, n_entries, 256))]
    findp = make_plan(np.full(256, OP_FIND, np.int32), q)
    f1 = jax.jit(lambda h, p: b1l.apply(h, p)[1].ok)
    f2 = jax.jit(lambda h, p: b2l.apply(h, p)[1].ok)
    t_1 = bench(lambda: f1(so, findp))
    t_2 = bench(lambda: f2(t2, findp))
    # locality proxy: binary-search touch count x 8B (the cache-miss stand-in)
    touch1 = math.log2(n_entries) * 8
    touch2 = math.log2(n_entries / 16) * 8
    emit("table6/splitorder_1lvl/find256", t_1 / 256,
         f"ops_per_sec={256/t_1:.3e};bytes_touched_per_find={touch1:.0f}")
    emit("table6/splitorder_2lvl/find256", t_2 / 256,
         f"ops_per_sec={256/t_2:.3e};bytes_touched_per_find={touch2:.0f};"
         f"speedup={t_1/t_2:.2f}x")

    # --- tables 7/8: three-way ---
    for tag, total in (("table7(100m-scaled)", 1 << 12), ("table8(1b-scaled)", 1 << 14)):
        rng2 = np.random.default_rng(3)
        _sweep(f"{tag}/BinLists(two-level-bucket)", "twolevel_hash", 4096,
               rng2, b1=8, m2=64, b2=8)
        _sweep(f"{tag}/SPO(split-order)", "splitorder", total * 2, rng2,
               seed_slots=64, max_load=16)
        _sweep(f"{tag}/2lvl-SPO", "twolevel_splitorder", total * 4, rng2,
               num_tables=16, seed_slots=8, max_load=16)
