"""Paper Tables V-VIII / figs 7-9: hash-table comparisons.

table5: fixed-slot vs two-level (threshold expansion) — 50% insert/50% find
table6: one-level vs two-level split-order — wall time + the bytes-touched
        locality proxy standing in for the paper's cache-miss counters
table7/8: two-level-bucket vs split-order vs two-level split-order at two
        workload sizes (the paper's three-way final comparison)
"""
from __future__ import annotations

import math

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench, emit, keys64
from repro.core.hashtable import (fixed_find, fixed_init, fixed_insert,
                                  twolevel_find, twolevel_init, twolevel_insert)
from repro.core.splitorder import (splitorder_find, splitorder_init,
                                   splitorder_insert, twolevel_splitorder_find,
                                   twolevel_splitorder_init,
                                   twolevel_splitorder_insert)

LANES = [16, 64, 256]
ROUNDS = 8


def _mix(insert_fn, find_fn, state, ins_k, find_k):
    def round_(st):
        st, _, _ = insert_fn(st, ins_k, ins_k)
        f, _ = find_fn(st, find_k)
        return st, jnp.sum(f)
    return jax.jit(round_)


def _sweep(name, init_state, insert_fn, find_fn, rng, extra=""):
    for lanes in LANES:
        st = init_state()
        ins_k = keys64(rng, lanes // 2)
        st, _, _ = insert_fn(st, ins_k, ins_k)     # warm content
        find_k = ins_k[jnp.asarray(rng.integers(0, lanes // 2, lanes // 2))]
        round_ = _mix(insert_fn, find_fn, st, ins_k, find_k)

        def steps(st):
            for _ in range(ROUNDS):
                st, f = round_(st)
            return st

        t = bench(steps, st, iters=3)
        per_op = t / (ROUNDS * lanes)
        emit(f"{name}/threads={lanes}", per_op,
             f"ops_per_sec={1.0/per_op:.3e}{extra}")


def run():
    rng = np.random.default_rng(2)
    # --- table 5: fixed vs two-level ---
    _sweep("table5/fixed", lambda: fixed_init(1024, 16),
           fixed_insert, fixed_find, rng)
    _sweep("table5/twolevel", lambda: twolevel_init(256, 8, 64, 8, 256),
           twolevel_insert, twolevel_find, rng)

    # under load: the paper's point — fixed buckets overflow (failed inserts)
    # while threshold expansion absorbs them
    n = 2048
    ks = keys64(rng, n)
    hf = fixed_init(64, 16)                      # capacity 1024 < n
    hf, insf, _ = fixed_insert(hf, ks, ks)
    ht = twolevel_init(64, 8, 64, 8, 128)        # expands per slot
    ht, inst, _ = twolevel_insert(ht, ks, ks)
    emit("table5/fixed/load=2x", 0.0,
         f"insert_fail_rate={1 - float(insf.mean()):.3f}")
    emit("table5/twolevel/load=2x", 0.0,
         f"insert_fail_rate={1 - float(inst.mean()):.3f};"
         f"l2_tables={int((np.asarray(ht.l2_block) >= 0).sum())}")

    # --- table 6: split-order locality ---
    n_entries = 4096
    so = splitorder_init(8192, 64, max_load=16)
    t2 = twolevel_splitorder_init(16, 1024, 8, max_load=16)
    ks = keys64(rng, n_entries)
    for chunk in np.array_split(np.asarray(ks), 8):
        so, _, _ = splitorder_insert(so, jnp.asarray(chunk), jnp.asarray(chunk))
        t2, _, _ = twolevel_splitorder_insert(t2, jnp.asarray(chunk),
                                              jnp.asarray(chunk))
    q = ks[jnp.asarray(rng.integers(0, n_entries, 256))]
    f1 = jax.jit(lambda h, q: splitorder_find(h, q)[0])
    f2 = jax.jit(lambda h, q: twolevel_splitorder_find(h, q)[0])
    t_1 = bench(lambda: f1(so, q))
    t_2 = bench(lambda: f2(t2, q))
    # locality proxy: binary-search touch count x 8B (the cache-miss stand-in)
    touch1 = math.log2(n_entries) * 8
    touch2 = math.log2(n_entries / 16) * 8
    emit("table6/splitorder_1lvl/find256", t_1 / 256,
         f"ops_per_sec={256/t_1:.3e};bytes_touched_per_find={touch1:.0f}")
    emit("table6/splitorder_2lvl/find256", t_2 / 256,
         f"ops_per_sec={256/t_2:.3e};bytes_touched_per_find={touch2:.0f};"
         f"speedup={t_1/t_2:.2f}x")

    # --- tables 7/8: three-way ---
    for tag, total in (("table7(100m-scaled)", 1 << 12), ("table8(1b-scaled)", 1 << 14)):
        rng2 = np.random.default_rng(3)
        _sweep(f"{tag}/BinLists(two-level-bucket)",
               lambda: twolevel_init(256, 8, 64, 8, 512),
               twolevel_insert, twolevel_find, rng2)
        _sweep(f"{tag}/SPO(split-order)",
               lambda: splitorder_init(total * 2, 64, max_load=16),
               splitorder_insert, splitorder_find, rng2)
        _sweep(f"{tag}/2lvl-SPO",
               lambda: twolevel_splitorder_init(16, total // 4, 8, max_load=16),
               twolevel_splitorder_insert, twolevel_splitorder_find, rng2)
