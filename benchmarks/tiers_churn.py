"""Tier-churn benchmark: the §IX tier stacks under a skewed, churning
workload, against the flat skiplist baseline.

The workload models what the tier stack exists for: a working set larger
than the hot tier, with a skewed access pattern (a small hot set absorbs
most FINDs) plus a steady stream of new inserts and deletes that forces
eviction, spill, and promotion every batch. The stack is preloaded past the
warm tier's capacity so all three tiers of `tiered3*` are live, then one
jitted churn `apply` is timed per (backend, exec mode).

Rows land in ``BENCH_tiers.json`` (`benchmarks.common.Recorder`; CI runs
this in smoke mode and uploads the artifact). Derived fields record the
final tier residency and the cumulative eviction/promotion counters, so
the JSON shows WHERE the policies put the data, not just how fast the
batch ran. Since the fused tier find, the tiered rows run BOTH probe
paths: the registered backends (fused — the whole apply prologue is one
`exec.tier_apply` update dispatch and the FIND phase one `exec.tier_find`
probe dispatch) and an unfused `TieredBackend(fused=False)` twin of each
(the original dispatch-per-tier chain), with the measured exec-dispatch
counts per apply in every row, split per half
(``probe_dispatches_per_apply`` / ``update_dispatches_per_apply``, summed
in ``dispatches_per_apply``) — the fused-vs-unfused comparison is the
dispatch reduction AND its wall-time effect on one table, and the CI gate
(`tools/bench_diff.py --assert-within`) fails any row whose
``dispatches_per_apply`` grows against the baseline artifact. On CPU the
`interpret` rows measure Pallas-interpreter overhead (expected to lose to
`jnp`); `pallas` rows appear on TPU. Results are bit-identical across
modes, backends, and probe paths by the store contract, so every
comparison here is purely about performance and residency.

Each row also carries per-op wall-time tails (``p50_us``/``p99_us`` over
the repeat samples — compaction/eviction spikes show in the tail, not the
median) and an ``observed`` flag: one extra ``obs:tiered3/lru`` row
measures the ENABLED metrics-plane cost, while the un-wrapped rows stay
the baseline for the <5% observability-off regression gate
(`tools/bench_diff.py --assert-within`, wired in CI).
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import Recorder, bench_times, finish, percentiles
from repro.store import OP_DELETE, OP_FIND, OP_INSERT, get_backend, make_plan
from repro.store import exec as exec_
from repro.store.tiers import unfused_twin

CAP = 512            # tiered3 warm-tier capacity (hot ~CAP/8, spill CAP)
PRELOAD = 900        # past the warm capacity -> the spill runs are live
WIDTH = 256          # churn-plan lanes
HOT_SET = 64         # the skewed FIND working set
ROUNDS = 4           # preload batches
# capacities matched by TOTAL entry slots (~1.1k) so no backend drops the
# preload: the flat skiplist gets one big array, the 2-tier stack a bigger
# warm tier, the 3-tier stacks overflow into their spill runs by design
BACKENDS = {"det_skiplist": 1088, "hash+skiplist": 1024, "tiered3": CAP,
            "tiered3/lru": CAP, "tiered3/size": CAP, "tiered3/b128": CAP}
# tier stacks also run as unfused twins (same semantics, dispatch per tier)
TIERED = ("hash+skiplist", "tiered3", "tiered3/lru", "tiered3/size",
          "tiered3/b128")


def _streams(rng):
    pool = np.unique(rng.integers(1, 2**62, PRELOAD + PRELOAD // 4,
                                  dtype=np.uint64))[:PRELOAD]
    preload = np.array_split(pool, ROUNDS)
    hot = pool[:HOT_SET]
    # the churn plan: skewed finds + fresh inserts + deletes of cold keys
    ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], WIDTH,
                     p=[0.5, 0.3, 0.2]).astype(np.int32)
    keys = np.where(rng.random(WIDTH) < 0.7, rng.choice(hot, WIDTH),
                    rng.choice(pool, WIDTH))
    keys = np.where(ops == OP_INSERT,
                    rng.integers(2**62, 2**63, WIDTH, dtype=np.uint64),
                    keys).astype(np.uint64)
    return preload, make_plan(ops, keys, keys + 1)


def run(out_dir: str | None = None):
    rec = Recorder("tiers", exec_modes=list(exec_.runnable_modes()))
    rng = np.random.default_rng(23)
    preload, churn = _streams(rng)
    variants = []
    for name in BACKENDS:
        variants.append((name, "", get_backend(name)))
        if name in TIERED:
            variants.append((name, "/unfused", unfused_twin(name)))
    # one observed row: the ENABLED metrics-plane cost on the flagship
    # policy stack (the un-wrapped rows above are the <5%-regression
    # baseline — observability off costs nothing by construction)
    variants.append(("tiered3/lru", "/obs", get_backend("obs:tiered3/lru")))
    for name, tag, be in variants:
        cap = BACKENDS[name]
        for mode in exec_.runnable_modes():
            with exec_.exec_mode(mode):
                st = be.init(cap)
                with exec_.measure_dispatches() as md:
                    step = jax.jit(be.apply)
                    for chunk in preload:
                        st, _ = step(st, make_plan(
                            np.full(len(chunk), OP_INSERT, np.int32), chunk,
                            chunk + 1))
                stats = {k: int(v) for k, v in be.stats(st).items()}
                assert stats["size"] == PRELOAD, (name, stats)
                # dispatches per apply, read off the single preload trace
                # (dispatch structure is plan-shape-independent), split by
                # half: probe (membership/FIND) vs update (insert prologue)
                dispatches = md.n
                d_probe, d_update = md.probe, md.update
                st, _ = step(st, churn)      # settle residency post-churn
                ts = bench_times(lambda: step(st, churn))
                t = float(np.median(ts))
                stats = {k: int(v) for k, v in be.stats(st).items()}
            tails = {k: v / WIDTH for k, v in percentiles(ts).items()}
            rec.record(f"tiers/churn/backend={name}{tag}/mode={mode}",
                       t / WIDTH, ops_per_sec=WIDTH / t, width=WIDTH,
                       preload=PRELOAD, backend=name, mode=mode,
                       fused=("no" if tag == "/unfused" else
                              "yes" if name in TIERED else "flat"),
                       warm_layout=("block" if name.endswith("/b128")
                                    else "level"),
                       observed=("yes" if tag == "/obs" else "no"),
                       dispatches_per_apply=dispatches,
                       probe_dispatches_per_apply=d_probe,
                       update_dispatches_per_apply=d_update,
                       hot_size=stats["hot_size"],
                       cold_size=stats["cold_size"],
                       spill_size=stats["spill_size"],
                       evictions=stats["evictions"],
                       promotions=stats["promotions"],
                       **tails)
    finish(rec, out_dir)
    return rec
