"""Shared benchmark harness: timing, CSV emission, workload scaling.

Paper workloads are 10m-1b ops on a 128-core Milan node; this container is a
1-core CPU running JAX, so workloads scale down (SCALE notes the factor per
table) while preserving every comparison's STRUCTURE (thread count -> batch
width, implementation pairs, workload mixes). Times are wall-clock over
jitted steps after warmup.
"""
from __future__ import annotations

import time

import numpy as np
import jax


def bench(fn, *args, iters: int = 5, warmup: int = 2):
    """Median wall seconds per call of a jitted fn (blocks on outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds_per_call: float, derived: str):
    print(f"{name},{seconds_per_call * 1e6:.1f},{derived}", flush=True)


def keys64(rng, n):
    import jax.numpy as jnp
    return jnp.asarray(rng.integers(1, 2**62, n, dtype=np.uint64))
