"""Shared benchmark harness: timing, CSV + machine-readable JSON emission.

Paper workloads are 10m-1b ops on a 128-core Milan node; this container is a
1-core CPU running JAX, so workloads scale down (SCALE notes the factor per
table) while preserving every comparison's STRUCTURE (thread count -> batch
width, implementation pairs, workload mixes). Times are wall-clock over
jitted steps after warmup.

Tables record through a `Recorder`, which prints the historical
``name,us_per_call,derived`` CSV lines AND collects typed rows; when given
an output directory it writes ``BENCH_<table>.json`` (rows + platform
metadata) — the artifact CI uploads and trend tooling consumes.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax


# shared repeat discipline: every timed number is the median of
# DEFAULT_ITERS calls after DEFAULT_WARMUP discarded warmup calls; the
# Recorder stamps these into the JSON metadata so two bench artifacts are
# comparable (tools/bench_diff.py) without guessing the protocol.
DEFAULT_ITERS = 5
DEFAULT_WARMUP = 2


def bench_times(fn, *args, iters: int = DEFAULT_ITERS,
                warmup: int = DEFAULT_WARMUP) -> list[float]:
    """Per-call wall seconds of a jitted fn (blocks on outputs), after the
    warmup discard — the raw samples behind `bench`'s median, kept so
    tables can surface tail latency (`percentiles`) next to it."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return ts


def bench(fn, *args, iters: int = DEFAULT_ITERS, warmup: int = DEFAULT_WARMUP):
    """Median wall seconds per call of a jitted fn (blocks on outputs)."""
    return float(np.median(bench_times(fn, *args, iters=iters,
                                       warmup=warmup)))


def percentiles(ts: list[float]) -> dict:
    """Per-batch wall-time tail fields for a Recorder row: p50/p99 in µs
    over one `bench_times` sample set. With the default 5-iteration repeat
    p99 ~= max — still worth recording, since compaction/eviction batches
    spike it while the median hides them."""
    return {"p50_us": float(np.percentile(ts, 50) * 1e6),
            "p99_us": float(np.percentile(ts, 99) * 1e6)}


def emit(name: str, seconds_per_call: float, derived: str):
    print(f"{name},{seconds_per_call * 1e6:.1f},{derived}", flush=True)


class Recorder:
    """Collects benchmark rows for one table; CSV to stdout, JSON to disk.

    `meta` lands in the JSON payload next to the platform fields — tables
    use it to record their measurement protocol (exec modes exercised,
    repeat count, warmup discard) so artifacts are self-describing."""

    def __init__(self, table: str, **meta):
        self.table = table
        self.rows: list[dict] = []
        self.meta: dict = {"bench_iters": DEFAULT_ITERS,
                           "warmup_discard": DEFAULT_WARMUP, **meta}

    def record(self, name: str, seconds_per_call: float, **derived):
        """One measurement. `derived` values should be plain numbers/strings
        (they go into the JSON verbatim and into the CSV `derived` column)."""
        emit(name, seconds_per_call,
             ";".join(f"{k}={v}" for k, v in derived.items()))
        self.rows.append({"name": name,
                          "us_per_call": seconds_per_call * 1e6,
                          **derived})

    def write_json(self, out_dir: str) -> str:
        """Write BENCH_<table>.json under `out_dir`; returns the path."""
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"BENCH_{self.table}.json")
        payload = {
            "table": self.table,
            "jax_backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "unix_time": time.time(),
            **self.meta,
            "rows": self.rows,
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {path} ({len(self.rows)} rows)", flush=True)
        return path


def finish(rec: Recorder, out_dir: str | None):
    """Shared tail of every ported table's `run(out_dir=...)`."""
    if out_dir:
        rec.write_json(out_dir)


def keys64(rng, n):
    import jax.numpy as jnp
    return jnp.asarray(rng.integers(1, 2**62, n, dtype=np.uint64))
