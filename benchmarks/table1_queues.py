"""Paper Table I / fig 3: concurrent queue throughput vs thread count.

threads -> batch lanes. Three implementations:
  lkfree    — our LCRQ-adapted block queue with recycling (§III)
  serial    — one-op-at-a-time lax.scan (the coarse-lock/Boost analogue)
  py_deque  — host Python deque (the non-vectorized reference)
Workload: alternating push/pop rounds, ~50/50, total_ops per measurement.

Runs on the shared `benchmarks.common` harness; `run(out_dir=...)` writes
machine-readable BENCH_table1_queues.json.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Recorder, bench, finish
from repro.core.ringqueue import pop_batch, push_batch, queue_init

TOTAL_OPS = 1 << 17        # scaled from the paper's 100m (x~760 down)
LANES = [4, 8, 16, 32, 64, 128]
ROUNDS = 64


def run(out_dir: str | None = None):
    rec = Recorder("table1_queues")
    for lanes in LANES:
        q0 = queue_init(max_blocks=64, block_size=1024)
        vals = jnp.arange(lanes, dtype=jnp.uint64)
        ones = jnp.ones((lanes,), bool)

        @jax.jit
        def round_(q):
            q, _ = push_batch(q, vals, ones)
            q, _, _ = pop_batch(q, lanes)
            return q

        def run_rounds(q):
            for _ in range(ROUNDS):
                q = round_(q)
            return q

        t = bench(run_rounds, q0, iters=3)
        per_op = t / (ROUNDS * 2 * lanes)
        rec.record(f"table1/lkfree/threads={lanes}", per_op,
                   ops_per_sec=1.0 / per_op, total_ops=ROUNDS * 2 * lanes)

    # serialized (one op per device step) — the contended-lock analogue
    q0 = queue_init(max_blocks=64, block_size=1024)

    @jax.jit
    def serial_round(q):
        q, _ = push_batch(q, jnp.ones((1,), jnp.uint64), jnp.ones((1,), bool))
        q, _, _ = pop_batch(q, 1)
        return q

    def run_serial(q):
        for _ in range(ROUNDS):
            q = serial_round(q)
        return q

    t = bench(run_serial, q0, iters=3)
    per_op = t / (ROUNDS * 2)
    rec.record("table1/serial/threads=1", per_op, ops_per_sec=1.0 / per_op,
               total_ops=ROUNDS * 2)

    # host deque reference
    from collections import deque
    import time as _t
    d = deque()
    t0 = _t.perf_counter()
    for i in range(TOTAL_OPS // 2):
        d.append(i)
        d.popleft()
    t = (_t.perf_counter() - t0) / TOTAL_OPS
    rec.record("table1/py_deque/threads=1", t, ops_per_sec=1.0 / t)
    finish(rec, out_dir)
    return rec
