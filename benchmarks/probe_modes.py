"""Execution-layer probe benchmark: jnp reference vs Pallas kernel timings
for the two kernelized probes (deterministic-skiplist search, fixed-hash
bucket probe), across every runnable `repro.store.exec` mode.

On CPU, `interpret` measures the Pallas interpreter (a correctness path, so
it is expected to LOSE to jnp — the number documents the overhead); on TPU
the `pallas` rows are the production hot path. Results are bit-identical in
every mode by contract, so these rows are a pure perf comparison.

`run(out_dir=...)` writes machine-readable BENCH_probe_modes.json.
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import Recorder, bench, finish, keys64
from repro.core import det_skiplist as dsl
from repro.core import hashtable as ht
from repro.store import exec as exec_

CAP = 1 << 13
PRELOAD = CAP // 2
QUERIES = 1024
HASH_SLOTS = 1 << 9
BUCKET = 8


def run(out_dir: str | None = None):
    rec = Recorder("probe_modes")
    rng = np.random.default_rng(7)
    modes = exec_.runnable_modes()

    # deterministic skiplist: preload, then time the batched FIND per mode
    base = keys64(rng, PRELOAD)
    s = dsl.skiplist_init(CAP)
    s, _, _ = dsl.insert_batch(s, base, base)
    queries = keys64(rng, QUERIES // 2)
    queries = jax.numpy.concatenate([base[: QUERIES // 2], queries])
    for mode in modes:
        fn = jax.jit(lambda st, q, m=mode: exec_.skiplist_find(st, q, m)[0])
        t = bench(lambda: fn(s, queries))
        rec.record(f"probe/skiplist_find/mode={mode}", t / QUERIES,
                   ops_per_sec=QUERIES / t, queries=QUERIES,
                   preload=PRELOAD, mode=mode)

    # fixed-slot hash: half the queries hit, half miss
    h = ht.fixed_init(HASH_SLOTS, BUCKET)
    hk = keys64(rng, HASH_SLOTS * BUCKET // 2)
    h, _, _ = ht.fixed_insert(h, hk, hk)
    hq = jax.numpy.concatenate([hk[: QUERIES // 2],
                                keys64(rng, QUERIES // 2)])
    for mode in modes:
        fn = jax.jit(lambda st, q, m=mode: exec_.hash_find(st, q, m)[0])
        t = bench(lambda: fn(h, hq))
        rec.record(f"probe/hash_find/mode={mode}", t / QUERIES,
                   ops_per_sec=QUERIES / t, queries=QUERIES,
                   slots=HASH_SLOTS, bucket=BUCKET, mode=mode)

    finish(rec, out_dir)
    return rec
