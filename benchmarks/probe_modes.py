"""Execution-layer probe benchmark: jnp reference vs Pallas kernel timings
for the kernelized probes (deterministic-skiplist search, fixed-hash
bucket probe), across every runnable `repro.store.exec` mode — plus the
FUSED tier find (`exec.tier_find`, one dispatch across all three §IX
tiers) against the unfused three-dispatch chain on the same preloaded
tier-stack state. Fused/unfused rows carry their measured exec-dispatch
count per plan (`exec.measure_dispatches`) next to the wall time, so the
artifact shows the dispatch reduction the fusion buys, not just the
timing.

On CPU, `interpret` measures the Pallas interpreter (a correctness path, so
it is expected to LOSE to jnp — the number documents the overhead); on TPU
the `pallas` rows are the production hot path. Results are bit-identical in
every mode by contract, so these rows are a pure perf comparison.

`run(out_dir=...)` writes machine-readable BENCH_probe_modes.json (rows +
exec-mode/repeat/warmup metadata; diff two artifacts with
tools/bench_diff.py). Every row carries per-op wall-time tails
(``p50_us``/``p99_us`` over the repeat samples) next to the median.
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import (Recorder, bench_times, finish, keys64,
                               percentiles)
from repro.core import det_skiplist as dsl
from repro.core import hashtable as ht
from repro.core.layout import bskip_num_levels
from repro.store import get_backend, make_plan
from repro.store import exec as exec_
from repro.store.api import OP_INSERT

CAP = 1 << 13
PRELOAD = CAP // 2
QUERIES = 1024
HASH_SLOTS = 1 << 9
BUCKET = 8
TIER_CAP = 512           # tier-stack warm capacity for the fused rows
TIER_PRELOAD = 900       # past warm capacity -> all three tiers live


def _unfused_chain(hot, cold, spill, q, mode):
    """The pre-fusion FIND path: one dispatch per tier."""
    f_hot, v_hot, c_hot = exec_.hash_find_cols(hot, q, mode)
    f_cold, v_cold, _ = exec_.skiplist_find(cold, q, mode)
    f_sp, v_sp = exec_.spill_find(spill, q, mode)
    return f_hot, v_hot, c_hot, f_cold, v_cold, f_sp, v_sp


def run(out_dir: str | None = None):
    modes = exec_.runnable_modes()
    rec = Recorder("probe_modes", exec_modes=list(modes))
    rng = np.random.default_rng(7)

    # deterministic skiplist: preload, then time the batched FIND per mode
    base = keys64(rng, PRELOAD)
    s = dsl.skiplist_init(CAP)
    s, _, _ = dsl.insert_batch(s, base, base)
    queries = keys64(rng, QUERIES // 2)
    queries = jax.numpy.concatenate([base[: QUERIES // 2], queries])
    lvl_steps = int(s.num_levels) + 1
    for mode in modes:
        fn = jax.jit(lambda st, q, m=mode: exec_.skiplist_find(st, q, m)[0])
        ts = bench_times(lambda: fn(s, queries))
        t = float(np.median(ts))
        rec.record(f"probe/skiplist_find/mode={mode}", t / QUERIES,
                   ops_per_sec=QUERIES / t, queries=QUERIES,
                   preload=PRELOAD, mode=mode, warm_layout="level",
                   steps_per_probe=lvl_steps,
                   **{k: v / QUERIES for k, v in percentiles(ts).items()})

    # the block-major B-skiplist walk on the SAME state: one lane-width
    # fat-node compare per level, so the descent is ceil(log128(blocks))+1
    # block steps vs num_levels+1 fan-out-4 cell steps (the row pair shows
    # the measured steps-per-probe reduction, 2 vs 12 at CAP = 8Ki)
    blk_steps = bskip_num_levels(CAP) + 1
    for mode in modes:
        fn = jax.jit(lambda st, q, m=mode: exec_.bskiplist_find(st, q, m)[0])
        ts = bench_times(lambda: fn(s, queries))
        t = float(np.median(ts))
        rec.record(f"probe/bskiplist_find/mode={mode}", t / QUERIES,
                   ops_per_sec=QUERIES / t, queries=QUERIES,
                   preload=PRELOAD, mode=mode, warm_layout="block",
                   steps_per_probe=blk_steps, level_steps_per_probe=lvl_steps,
                   **{k: v / QUERIES for k, v in percentiles(ts).items()})

    # fixed-slot hash: half the queries hit, half miss
    h = ht.fixed_init(HASH_SLOTS, BUCKET)
    hk = keys64(rng, HASH_SLOTS * BUCKET // 2)
    h, _, _ = ht.fixed_insert(h, hk, hk)
    hq = jax.numpy.concatenate([hk[: QUERIES // 2],
                                keys64(rng, QUERIES // 2)])
    for mode in modes:
        fn = jax.jit(lambda st, q, m=mode: exec_.hash_find(st, q, m)[0])
        ts = bench_times(lambda: fn(h, hq))
        t = float(np.median(ts))
        rec.record(f"probe/hash_find/mode={mode}", t / QUERIES,
                   ops_per_sec=QUERIES / t, queries=QUERIES,
                   slots=HASH_SLOTS, bucket=BUCKET, mode=mode,
                   **{k: v / QUERIES for k, v in percentiles(ts).items()})

    # fused tier find vs the unfused three-dispatch chain, on a tiered3
    # state preloaded past the warm tier so all three tiers answer queries
    be = get_backend("tiered3")
    st = be.init(TIER_CAP)
    pool = np.unique(rng.integers(1, 2**62, TIER_PRELOAD + TIER_PRELOAD // 4,
                                  dtype=np.uint64))[:TIER_PRELOAD]
    preload_step = jax.jit(be.apply)
    for chunk in np.array_split(pool, 4):
        st, _ = preload_step(st, make_plan(
            np.full(len(chunk), OP_INSERT, np.int32), chunk, chunk + 1))
    tq = jax.numpy.concatenate([jax.numpy.asarray(pool[:QUERIES // 2]),
                                keys64(rng, QUERIES // 2)])
    hot, cold, spill = st.hot, st.cold, st.spill
    for mode in modes:
        # the jitted probe traces exactly once inside bench's warmup, so
        # the meter reads dispatches per plan directly (1 vs tier depth)
        with exec_.measure_dispatches() as md:
            # return every tier's outputs so XLA cannot dead-code a probe
            fused = jax.jit(lambda h_, c_, s_, q, m=mode:
                            exec_.tier_find(h_, c_, s_, q, m))
            ts_f = bench_times(lambda: fused(hot, cold, spill, tq))
            t_f = float(np.median(ts_f))
        rec.record(f"probe/tier_find/fused/mode={mode}", t_f / QUERIES,
                   ops_per_sec=QUERIES / t_f, queries=QUERIES,
                   preload=TIER_PRELOAD, mode=mode, fused="yes",
                   warm_layout="level", dispatches_per_plan=md.n,
                   **{k: v / QUERIES for k, v in percentiles(ts_f).items()})
        with exec_.measure_dispatches() as md:
            fused_b = jax.jit(lambda h_, c_, s_, q, m=mode:
                              exec_.tier_find(h_, c_, s_, q, m,
                                              warm_layout="block"))
            ts_b = bench_times(lambda: fused_b(hot, cold, spill, tq))
            t_b = float(np.median(ts_b))
        rec.record(f"probe/tier_find/fused/b128/mode={mode}", t_b / QUERIES,
                   ops_per_sec=QUERIES / t_b, queries=QUERIES,
                   preload=TIER_PRELOAD, mode=mode, fused="yes",
                   warm_layout="block", dispatches_per_plan=md.n,
                   warm_steps=bskip_num_levels(TIER_CAP) + 1,
                   warm_level_steps=int(cold.num_levels) + 1,
                   **{k: v / QUERIES for k, v in percentiles(ts_b).items()})
        with exec_.measure_dispatches() as md:
            unf = jax.jit(lambda h_, c_, s_, q, m=mode:
                          _unfused_chain(h_, c_, s_, q, m))
            ts_u = bench_times(lambda: unf(hot, cold, spill, tq))
            t_u = float(np.median(ts_u))
        rec.record(f"probe/tier_find/unfused/mode={mode}", t_u / QUERIES,
                   ops_per_sec=QUERIES / t_u, queries=QUERIES,
                   preload=TIER_PRELOAD, mode=mode, fused="no",
                   dispatches_per_plan=md.n,
                   **{k: v / QUERIES for k, v in percentiles(ts_u).items()})

    finish(rec, out_dir)
    return rec
