"""Framework-level benchmarks (beyond the paper's tables):

serving  — continuous-batching engine tokens/sec on the reduced qwen3 config
           (paged pool + skiplist scheduler + ring queue end to end)
store    — sharded ordered-store ops/sec (single shard degenerate mesh)
train    — reduced-config train-step steps/sec (the e2e substrate check)
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench, emit
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.models import model as M


def run():
    cfg = get_reduced("qwen3-1.7b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    # --- serving ---
    from repro.serving.engine import Engine, Request
    rng = np.random.default_rng(0)
    eng = Engine(cfg, params, max_reqs=8, num_pages=128, page_size=8,
                 max_pages_per_req=16)
    for i in range(8):
        eng.submit(Request(req_id=i, prompt=rng.integers(1, cfg.vocab_size, 8),
                           max_new=16, priority=i % 3))
    t0 = time.perf_counter()
    outs = eng.run(max_steps=64)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in outs.values())
    emit("framework/serving_engine", dt / max(toks, 1),
         f"tokens_per_sec={toks/dt:.1f};requests=8")

    # --- train step ---
    from repro.data.pipeline import synth_batch
    from repro.optim.adamw import adamw_init
    from repro.train.step import make_train_step
    shape = ShapeConfig("bench", seq_len=64, global_batch=8, kind="train")
    step = jax.jit(make_train_step(cfg, microbatches=2))
    opt = {"adam": adamw_init(params)}
    batch = synth_batch(cfg, shape, 0, 0)
    p2 = params

    def one(p, o):
        p, o, m = step(p, o, batch)
        return p, o, m

    t = bench(lambda: one(p2, opt), iters=3)
    tokens = shape.global_batch * shape.seq_len
    emit("framework/train_step_reduced", t,
         f"tokens_per_sec={tokens/t:.1f};microbatches=2")

    # --- skiplist kernel vs pure-jnp find path ---
    from repro.core.det_skiplist import find_batch, insert_batch, skiplist_init
    from repro.kernels.skiplist_search.ops import skiplist_search
    s = skiplist_init(1 << 13)
    ks = jnp.asarray(rng.integers(1, 2**62, 4096, dtype=np.uint64))
    s, _, _ = insert_batch(s, ks, ks)
    q = ks[:512]
    jf = jax.jit(lambda s, q: find_batch(s, q)[0])
    kf = jax.jit(lambda s, q: skiplist_search(s, q, tile=256)[0])
    t_j = bench(lambda: jf(s, q))
    t_k = bench(lambda: kf(s, q))
    emit("framework/skiplist_find_jnp", t_j / 512, f"ops_per_sec={512/t_j:.3e}")
    emit("framework/skiplist_find_kernel(interp)", t_k / 512,
         f"ops_per_sec={512/t_k:.3e};note=interpret-mode-CPU")
