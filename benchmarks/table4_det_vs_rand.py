"""Paper Table IV / fig 6: deterministic 1-2-3-4 skiplist vs randomized
skiplist — the comparison whose verdict the hardware flips.

Paper (CPU, locks): randomized wins (no rebalancing, lock-free).
Here (SIMD lanes): the deterministic fan-out-4 probe is one fixed-shape
gather per level; the randomized variant pads every lane to MAX_GAP probes.
We measure batched find + insert throughput and report the probe-width
ratio as `derived` context.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench, emit, keys64
from repro.core import rand_skiplist as rsl
from repro.core.det_skiplist import find_batch, insert_batch, skiplist_init

CAP = 1 << 14
PRELOAD = CAP // 2
LANES = [8, 32, 128, 512]
ROUNDS = 8


def run():
    rng = np.random.default_rng(1)
    base = keys64(rng, PRELOAD)

    det = skiplist_init(CAP)
    det, _, _ = insert_batch(det, base, base)
    rnd = rsl.rand_skiplist_init(CAP)
    rnd, _, _ = rsl.insert_batch(rnd, base, base)

    for lanes in LANES:
        queries = jnp.asarray(np.asarray(base)[rng.integers(0, PRELOAD, lanes)])

        df = jax.jit(lambda s, q: find_batch(s, q)[0])
        rf = jax.jit(lambda s, q: rsl.find_batch(s, q)[0])

        t_d = bench(lambda: df(det, queries))
        t_r = bench(lambda: rf(rnd, queries))
        emit(f"table4/det_find/threads={lanes}", t_d / lanes,
             f"ops_per_sec={lanes/t_d:.3e};probe_width=4")
        emit(f"table4/rand_find/threads={lanes}", t_r / lanes,
             f"ops_per_sec={lanes/t_r:.3e};probe_width={rsl.MAX_GAP};"
             f"speedup_det={t_r/t_d:.2f}x")

    # bulk insert comparison (rebalance cost vs level re-derivation)
    newk = keys64(rng, 256)
    di = jax.jit(lambda s, k: insert_batch(s, k, k)[0])
    ri = jax.jit(lambda s, k: rsl.insert_batch(s, k, k)[0])
    t_d = bench(lambda: di(det, newk))
    t_r = bench(lambda: ri(rnd, newk))
    emit("table4/det_insert/batch=256", t_d / 256,
         f"ops_per_sec={256/t_d:.3e}")
    emit("table4/rand_insert/batch=256", t_r / 256,
         f"ops_per_sec={256/t_r:.3e};det_speedup={t_r/t_d:.2f}x")
