"""Paper Table IV / fig 6: deterministic 1-2-3-4 skiplist vs randomized
skiplist — the comparison whose verdict the hardware flips.

Paper (CPU, locks): randomized wins (no rebalancing, lock-free).
Here (SIMD lanes): the deterministic fan-out-4 probe is one fixed-shape
gather per level; the randomized variant pads every lane to MAX_GAP probes.
We measure batched find + insert throughput and report the probe-width
ratio as derived context.

Runs on the shared `benchmarks.common` harness; `run(out_dir=...)` writes
machine-readable BENCH_table4_det_vs_rand.json.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Recorder, bench, finish, keys64
from repro.core import rand_skiplist as rsl
from repro.core.det_skiplist import find_batch, insert_batch, skiplist_init

CAP = 1 << 14
PRELOAD = CAP // 2
LANES = [8, 32, 128, 512]


def run(out_dir: str | None = None):
    rec = Recorder("table4_det_vs_rand")
    rng = np.random.default_rng(1)
    base = keys64(rng, PRELOAD)

    det = skiplist_init(CAP)
    det, _, _ = insert_batch(det, base, base)
    rnd = rsl.rand_skiplist_init(CAP)
    rnd, _, _ = rsl.insert_batch(rnd, base, base)

    for lanes in LANES:
        queries = jnp.asarray(np.asarray(base)[rng.integers(0, PRELOAD, lanes)])

        df = jax.jit(lambda s, q: find_batch(s, q)[0])
        rf = jax.jit(lambda s, q: rsl.find_batch(s, q)[0])

        t_d = bench(lambda: df(det, queries))
        t_r = bench(lambda: rf(rnd, queries))
        rec.record(f"table4/det_find/threads={lanes}", t_d / lanes,
                   ops_per_sec=lanes / t_d, probe_width=4)
        rec.record(f"table4/rand_find/threads={lanes}", t_r / lanes,
                   ops_per_sec=lanes / t_r, probe_width=rsl.MAX_GAP,
                   speedup_det=t_r / t_d)

    # bulk insert comparison (rebalance cost vs level re-derivation)
    newk = keys64(rng, 256)
    di = jax.jit(lambda s, k: insert_batch(s, k, k)[0])
    ri = jax.jit(lambda s, k: rsl.insert_batch(s, k, k)[0])
    t_d = bench(lambda: di(det, newk))
    t_r = bench(lambda: ri(rnd, newk))
    rec.record("table4/det_insert/batch=256", t_d / 256,
               ops_per_sec=256 / t_d)
    rec.record("table4/rand_insert/batch=256", t_r / 256,
               ops_per_sec=256 / t_r, det_speedup=t_r / t_d)
    finish(rec, out_dir)
    return rec
