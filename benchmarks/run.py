"""Benchmark harness: one module per paper table (+ framework benches and
the execution-layer probe comparison).

Prints ``name,us_per_call,derived`` CSV; ``--out DIR`` additionally writes
machine-readable ``BENCH_<table>.json`` files for tables ported to the
shared `benchmarks.common.Recorder` harness.
"""
import argparse
import inspect
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    sys.path.insert(0, ROOT)       # `python benchmarks/run.py` from anywhere
    import repro  # noqa: F401
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="directory for BENCH_*.json artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="run only these table modules (by name)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    from benchmarks import (framework_bench, probe_modes, recovery,
                            serve_trace, table1_queues, table2_3_skiplist,
                            table4_det_vs_rand, table5_8_hashes, tiers_churn)
    mods = {m.__name__.rsplit(".", 1)[-1]: m
            for m in (table1_queues, table2_3_skiplist, table4_det_vs_rand,
                      table5_8_hashes, probe_modes, tiers_churn,
                      serve_trace, recovery, framework_bench)}
    unknown = set(args.only or ()) - set(mods)
    if unknown:
        ap.error(f"unknown table(s) {sorted(unknown)}; "
                 f"available: {sorted(mods)}")
    for name, mod in mods.items():
        if args.only and name not in args.only:
            continue
        if "out_dir" in inspect.signature(mod.run).parameters:
            mod.run(out_dir=args.out)
        else:
            mod.run()


if __name__ == '__main__':
    main()
