"""Benchmark harness: one module per paper table (+ framework benches).
Prints ``name,us_per_call,derived`` CSV (see DESIGN.md §7 index).
"""
import sys


def main() -> None:
    sys.path.insert(0, "src")
    import repro  # noqa: F401
    print("name,us_per_call,derived")
    from benchmarks import (framework_bench, table1_queues, table2_3_skiplist,
                            table4_det_vs_rand, table5_8_hashes)
    for mod in (table1_queues, table2_3_skiplist, table4_det_vs_rand,
                table5_8_hashes, framework_bench):
        mod.run()


if __name__ == '__main__':
    main()
