"""Paper Tables II+III / figs 4-5: deterministic skiplist throughput.

Workload1: 10% insert / 90% find; Workload2: + erases (paper: 0.2%, here 2%
so the erase path actually registers at scaled size).
  lkfreefind — batched ops (vectorized lock-free Find + bulk linearized
               updates): the paper's lock-free-find implementation analogue
  RWL        — serialized one-op-at-a-time (reader-writer-lock analogue)
Sweep batch width ("threads").

Workloads run through the unified `repro.store` API as one `OpPlan` per
round, so the structure under test is a config string: set
REPRO_STORE_BACKEND to any registered backend (det_skiplist, rand_skiplist,
hash+skiplist, ...) to re-run the same table against another engine.
"""
from __future__ import annotations

import os

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench, emit, keys64
from repro.store import OP_DELETE, OP_FIND, OP_INSERT, get_backend, make_plan

BACKEND = os.environ.get("REPRO_STORE_BACKEND", "det_skiplist")
CAP = 1 << 14
PRELOAD = CAP // 2
LANES = [4, 8, 16, 32, 64, 128]
ROUNDS = 16


def _preloaded(be, rng):
    s = be.init(CAP)
    ks = keys64(rng, PRELOAD)
    s, _ = be.apply(s, make_plan(np.full(PRELOAD, OP_INSERT, np.int32), ks, ks))
    return s, ks


def _mixed_plan(rng, base, n_ins, n_find, n_del):
    """One linearization unit: inserts + finds (+ deletes) as a single plan."""
    ops = np.concatenate([np.full(n_ins, OP_INSERT, np.int32),
                          np.full(n_find, OP_FIND, np.int32),
                          np.full(n_del, OP_DELETE, np.int32)])
    keys = np.concatenate([
        np.asarray(keys64(rng, n_ins)),
        np.asarray(base)[rng.integers(0, PRELOAD, n_find)],
        np.asarray(base)[rng.integers(0, PRELOAD, n_del)]])
    return make_plan(ops, keys, keys)


def run():
    rng = np.random.default_rng(0)
    be = get_backend(BACKEND)
    round_ = jax.jit(lambda s, p: be.apply(s, p))

    for workload, erase in (("wl1", False), ("wl2", True)):
        for lanes in LANES:
            s, base = _preloaded(be, rng)
            n_ins = max(1, lanes // 10)
            n_del = max(1, lanes // 50) if erase else 0
            plan = _mixed_plan(rng, base, n_ins, lanes - n_ins, n_del)

            def steps(s):
                for _ in range(ROUNDS):
                    s, r = round_(s, plan)
                return s

            t = bench(steps, s, iters=3)
            ops = ROUNDS * plan.width
            per_op = t / ops
            emit(f"table2_3/lkfreefind/{workload}/threads={lanes}", per_op,
                 f"ops_per_sec={1.0/per_op:.3e};backend={BACKEND}")

    # RWL analogue: one op per jit step
    s, base = _preloaded(be, rng)
    plan = _mixed_plan(rng, base, 1, 1, 0)

    def serial(s):
        for _ in range(ROUNDS):
            s, r = round_(s, plan)
        return s

    t = bench(serial, s, iters=3)
    per_op = t / (ROUNDS * 2)
    emit("table2_3/RWL/wl1/threads=1", per_op,
         f"ops_per_sec={1.0/per_op:.3e};backend={BACKEND}")
