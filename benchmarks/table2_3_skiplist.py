"""Paper Tables II+III / figs 4-5: deterministic skiplist throughput.

Workload1: 10% insert / 90% find; Workload2: + erases (paper: 0.2%, here 2%
so the erase path actually registers at scaled size).
  lkfreefind — batched ops (vectorized lock-free Find + bulk linearized
               updates): the paper's lock-free-find implementation analogue
  RWL        — serialized one-op-at-a-time (reader-writer-lock analogue)
Sweep batch width ("threads").
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench, emit, keys64
from repro.core.det_skiplist import (delete_batch, find_batch, insert_batch,
                                     skiplist_init)

CAP = 1 << 14
PRELOAD = CAP // 2
LANES = [4, 8, 16, 32, 64, 128]
ROUNDS = 16


def _preloaded(rng):
    s = skiplist_init(CAP)
    ks = keys64(rng, PRELOAD)
    s, _, _ = insert_batch(s, ks, ks)
    return s, ks


def _mixed_round(cfg_erase: bool):
    def round_(s, ins_k, find_k, del_k):
        s, _, _ = insert_batch(s, ins_k, ins_k)
        f, v, _ = find_batch(s, find_k)
        if cfg_erase:
            s, _ = delete_batch(s, del_k)
        return s, jnp.sum(f)
    return jax.jit(round_)


def run():
    rng = np.random.default_rng(0)
    for workload, erase in (("wl1", False), ("wl2", True)):
        for lanes in LANES:
            s, base = _preloaded(rng)
            n_ins = max(1, lanes // 10)
            n_del = max(1, lanes // 50) if erase else 1
            round_ = _mixed_round(erase)
            ins_k = keys64(rng, n_ins)
            find_k = jnp.asarray(np.asarray(base)[
                rng.integers(0, PRELOAD, lanes - n_ins)])
            del_k = jnp.asarray(np.asarray(base)[
                rng.integers(0, PRELOAD, n_del)])

            def steps(s):
                for _ in range(ROUNDS):
                    s, f = round_(s, ins_k, find_k, del_k)
                return s

            t = bench(steps, s, iters=3)
            ops = ROUNDS * (n_ins + (lanes - n_ins) + (n_del if erase else 0))
            per_op = t / ops
            emit(f"table2_3/lkfreefind/{workload}/threads={lanes}", per_op,
                 f"ops_per_sec={1.0/per_op:.3e}")

    # RWL analogue: one op per jit step
    s, base = _preloaded(rng)
    one = _mixed_round(False)
    k1 = keys64(rng, 1)
    f1 = jnp.asarray(np.asarray(base)[:1])

    def serial(s):
        for _ in range(ROUNDS):
            s, f = one(s, k1, f1, f1)
        return s

    t = bench(serial, s, iters=3)
    per_op = t / (ROUNDS * 2)
    emit("table2_3/RWL/wl1/threads=1", per_op,
         f"ops_per_sec={1.0/per_op:.3e}")
