"""Recovery benchmark: what deterministic fault tolerance costs.

Three question rows, landing in ``BENCH_recovery.json``:

* **replay throughput** — ops/sec of `resilience.restore` (snapshot +
  journal tail through the normal engine step) as the journal tail grows;
  recovery time must scale linearly with journal length, and every replay
  must land on the SAME state digest (asserted, and the digest is recorded
  so two artifacts can be diffed for determinism, like BENCH_serve.json).
* **sync-recovery overhead** — wall time of a faulted run (one shard drop
  mid-stream, recovered synchronously) vs the fault-free twin, per exec
  mode, with the bit-identity of the recovered state asserted.
* **shed rate** — the cost of one `scheduler.cancel_class` RANGE_DELETE
  plan shedding an overload burst, and the fraction of the backlog it
  drops.

Deterministic by construction: the op stream, the fault plan (seeded via
`faults.default_seed`, so the CI chaos lane's ``REPRO_FAULTS`` reseeds it),
and therefore every digest and count are pure functions of the seeds.
CI gates two independent runs with tools/bench_diff.py --assert-within.
"""
from __future__ import annotations

import time
import zlib

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Recorder, finish
from repro.store import engine as engine_mod
from repro.store import exec as exec_
from repro.store import resilience as R
from repro.store.api import OP_DELETE, OP_FIND, OP_INSERT

BACKEND = "obs:det_skiplist"
LANES = 16
CAP = 512
SEED = 17
ITERS = 3
WARMUP = 1


def _fresh_engine(exec_mode=None):
    mesh = jax.make_mesh((1,), ("local",),
                         devices=np.array(jax.devices()[:1]))
    return engine_mod.StoreEngine(mesh, ("local",), LANES, backend=BACKEND,
                                  pool_factor=1, exec_mode=exec_mode)


def _stream(n_steps: int):
    rng = np.random.default_rng(SEED)
    plans = []
    for t in range(n_steps):
        ops = rng.choice([OP_INSERT, OP_FIND, OP_DELETE], size=LANES,
                         p=[0.6, 0.3, 0.1]).astype(np.int32)
        keys = rng.integers(1, 1 << 48, LANES, dtype=np.uint64)
        plans.append((ops, keys, keys + np.uint64(t + 1)))
    return plans


def _journal(plans):
    """Run the stream once, journaling every plan; returns the restore
    inputs plus the live run's final digest."""
    eng = _fresh_engine()
    state = jax.device_put(eng.init(CAP), eng.sharding)
    snap = R.take_snapshot(state, 0)
    j = R.Journal(base_seq=0)
    for s, (ops, keys, vals) in enumerate(plans):
        j.append(s, ops, keys, vals)
        state, _, _, _ = eng.step(state, jnp.asarray(ops), jnp.asarray(keys),
                                  jnp.asarray(vals))
    return snap, j, R.state_digest(state)


def run(out_dir: str | None = None):
    fault_seed = R.default_seed(SEED)
    rec = Recorder("recovery", exec_modes=list(exec_.runnable_modes()),
                   bench_iters=ITERS, warmup_discard=WARMUP,
                   fault_seed=fault_seed)

    # --- replay throughput vs journal length --------------------------
    for n_entries in (8, 32):
        plans = _stream(n_entries)
        snap, j, want = _journal(plans)
        total_ops = sum(e.n_ops for e in j.entries)
        eng = _fresh_engine()     # one traced step reused by every replay
        walls = []
        for it in range(WARMUP + ITERS):
            t0 = time.perf_counter()
            state, replayed = R.restore(eng, snap, j.entries)
            jax.block_until_ready(jax.tree.leaves(state))
            walls.append(time.perf_counter() - t0)
            assert replayed == total_ops
            assert R.state_digest(state) == want, "replay digest drift"
        wall = float(np.median(walls[WARMUP:]))
        rec.record(f"recovery/replay/entries={n_entries}", wall / n_entries,
                   entries=n_entries, replayed_ops=total_ops,
                   ops_per_sec=total_ops / wall,
                   digest=zlib.crc32(want.encode()))

    # --- sync-recovery overhead per exec mode -------------------------
    n_steps = 12
    plans = _stream(n_steps)
    fplan = R.FaultPlan(fault_seed,
                        [R.Fault("shard_drop", n_steps // 2, shard=0)])
    ref_digest = None
    for mode in exec_.runnable_modes():
        def drive(fault_plan):
            eng = _fresh_engine(exec_mode=mode)
            reng = R.ResilientEngine(eng, snapshot_every=4,
                                     fault_plan=fault_plan)
            state = jax.device_put(eng.init(CAP), eng.sharding)
            t0 = time.perf_counter()
            for ops, keys, vals in plans:
                state, _, _, _ = reng.step(state, jnp.asarray(ops),
                                           jnp.asarray(keys),
                                           jnp.asarray(vals))
            jax.block_until_ready(jax.tree.leaves(state))
            return time.perf_counter() - t0, R.state_digest(state), reng

        drive(None)                      # warmup/trace
        base, base_digest, _ = drive(None)
        faulted, fault_digest, reng = drive(fplan)
        assert fault_digest == base_digest, "sync recovery not bit-identical"
        if ref_digest is None:
            ref_digest = base_digest
        assert base_digest == ref_digest, f"exec-mode divergence: {mode}"
        rec.record(f"recovery/sync/mode={mode}", faulted / n_steps,
                   steps=n_steps, overhead_pct=round(
                       100.0 * (faulted - base) / base, 1),
                   replayed_ops=reng.tally["replayed_ops"],
                   recoveries=reng.tally["recoveries"],
                   digest=zlib.crc32(base_digest.encode()), mode=mode)

    # --- shedding one overload burst ----------------------------------
    from repro.serving import scheduler as SCH
    n_bulk, n_urgent = 48, 8
    walls, outcome = [], None
    for it in range(WARMUP + ITERS):
        s = SCH.scheduler_init(max_pending=256)
        prios = np.concatenate([np.full(n_bulk, 2), np.full(n_urgent, 0)])
        for c in range(0, len(prios), LANES):
            chunk = prios[c:c + LANES]
            pad = LANES - len(chunk)
            s, _ = SCH.submit(
                s, jnp.asarray(np.concatenate([chunk, np.zeros(pad)]),
                               jnp.uint32),
                jnp.arange(c, c + LANES, dtype=jnp.int32),
                jnp.asarray([True] * len(chunk) + [False] * pad))
        t0 = time.perf_counter()
        s, cancelled = SCH.cancel_class(s, 2)
        walls.append(time.perf_counter() - t0)
        got = (cancelled, int(SCH.pending(s)))
        assert outcome in (None, got), "shed replay divergence"
        outcome = got
    assert outcome == (n_bulk, n_urgent)
    rec.record("recovery/shed/burst", float(np.median(walls[WARMUP:])),
               backlog=n_bulk + n_urgent, shed=outcome[0],
               shed_rate=round(outcome[0] / (n_bulk + n_urgent), 4),
               survivors=outcome[1])

    finish(rec, out_dir)
    return rec
