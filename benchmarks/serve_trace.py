"""Serving-trace benchmark: the heavy-traffic trace replayed through the
Store-backed admission path, per exec mode.

The workload is `serving.traffic.make_trace` (Zipf-skewed prefixes, bursty
Poisson arrivals, mixed prompt lengths, priority inversion) driven through
a model-free admission simulator: the REAL `obs:pq` scheduler (submit +
bulk-pop-k plans), the REAL `obs:tiered3/lru` prefix cache (OP_FIND /
OP_INSERT plans + ABA handle checks) and the REAL §V block pool — only the
transformer is replaced by a service-time model (pages to prefill + tokens
to decode, in ticks), so the timed loop is exactly the store traffic the
serving engine generates without paying for matmuls. The full-model replay
lives in tests/test_serving.py; this table isolates the data-structure
cost.

Rows land in ``BENCH_serve.json`` (one per exec mode): wall time per tick
with p50/p99 tails, request throughput, admit latency percentiles in ticks
(deterministic), the prefix-cache hit rate and pop counters read off the
`obs` metrics plane, and a digest of the admitted req_id sequence. The
benchmark replays each trace twice per mode and asserts the digest,
admit latencies and metrics counters are identical across replays AND
across exec modes — BENCH_serve.json is a determinism artifact as much as
a performance one (CI diffs two independent runs with
tools/bench_diff.py --assert-within).
"""
from __future__ import annotations

import time
import zlib

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Recorder, finish, percentiles
from repro.core.blockpool import blockpool_init, pool_alloc, pool_free
from repro.serving import prefix_cache as PC
from repro.serving import scheduler as SCH
from repro.serving import traffic
from repro.store import exec as exec_

SEED = 11
N_REQS = 24
PAGE = 8            # tokens per KV page (trace pages are aligned to this)
NUM_PAGES = 96      # pool size; small enough that bursts contend for pages
SLOTS = 4           # concurrent service slots
ADMIT_K = 4         # bulk-pop-k width per admission round
SUB_LANES = 4       # fixed submit-plan width (arrivals chunked/padded to it)
MAX_TICKS = 512
ITERS = 2           # timed replays per mode (after 1 tracing/warmup replay)
WARMUP = 1


def _page_keys(prompt: np.ndarray) -> list[int]:
    """Chained hashes of the prompt's full pages (same scheme as the
    engine's `_page_keys`, hoisted so it runs once per trace)."""
    n_full = len(prompt) // PAGE
    keys, prev = [], jnp.zeros((1,), jnp.uint64)
    for j in range(n_full):
        blk = jnp.asarray(prompt[j * PAGE:(j + 1) * PAGE], jnp.int32)[None]
        prev = PC.block_key(blk, prev)
        keys.append(int(prev[0]))
    return keys


def _drive(trace, pkeys: dict, maxp: int, maxf: int) -> dict:
    """One replay of the trace through scheduler + prefix cache + pool.

    Plan widths are fixed (padded/masked) so every store step after the
    first replay hits the jit cache. Returns the deterministic outcome
    (admission order + latencies + metrics counters) and per-tick walls.
    """
    sched = SCH.scheduler_init(max_pending=256)
    pc = PC.prefix_cache_init(capacity=512)
    pool = blockpool_init(NUM_PAGES)
    reqs = {r.req_id: r for r in trace}
    slots: list = [None] * SLOTS          # (req_id, ticks_left, own_page_ids)
    admitted: list[int] = []
    admit_lat: list[int] = []
    tick_walls: list[float] = []

    def _submit(batch):
        nonlocal sched
        for c in range(0, len(batch), SUB_LANES):
            chunk = batch[c:c + SUB_LANES]
            pad = SUB_LANES - len(chunk)
            prios = jnp.asarray([r.priority for r in chunk] + [0] * pad,
                                jnp.uint32)
            rids = jnp.asarray([r.req_id for r in chunk] + [0] * pad,
                               jnp.int32)
            mask = jnp.asarray([True] * len(chunk) + [False] * pad)
            sched, _ = SCH.submit(sched, prios, rids, mask)

    i, t, done = 0, 0, 0
    while done < len(trace) and t < MAX_TICKS:
        t0 = time.perf_counter()
        due = []
        while i < len(trace) and trace[i].arrival <= t:
            due.append(trace[i])
            i += 1
        _submit(due)
        free = [j for j, s in enumerate(slots) if s is None]
        if free:
            sched, rids, valid = SCH.pop_min(sched, ADMIT_K)
            rids, valid = np.asarray(rids), np.asarray(valid)
            for j in range(ADMIT_K):
                if not valid[j]:
                    continue
                req = reqs[int(rids[j])]
                if not free:                   # popped more than slots free
                    _submit([req])
                    continue
                keys = pkeys[req.req_id]
                n_pages = -(-len(req.prompt) // PAGE)
                pc, _, fresh = PC.lookup(pc, pool,
                                         jnp.asarray(keys, jnp.uint64))
                n_hit = 0
                for f in np.asarray(fresh):
                    if not f:
                        break
                    n_hit += 1
                need = n_pages - n_hit
                want = jnp.arange(maxp) < need
                pool2, ids, handles, got = pool_alloc(pool, want)
                if int(jnp.sum(got)) < need:   # pool exhausted: stay queued
                    pool = pool_free(pool2, ids, got)   # roll back partials
                    _submit([req])
                    continue
                pool = pool2
                own = [int(x) for x in np.asarray(ids)[:need]]
                n_pub = len(keys) - n_hit      # freshly written full pages
                pub_mask = jnp.arange(maxf) < n_pub
                pkey_pad = jnp.asarray(keys[n_hit:] + [0] * (maxf - n_pub),
                                       jnp.uint64)
                hnd_pad = jnp.concatenate(
                    [handles[:maxf],
                     jnp.zeros((max(0, maxf - maxp),), jnp.uint64)])
                pc = PC.insert(pc, pkey_pad, hnd_pad, pub_mask)
                slot = free.pop(0)
                slots[slot] = [req.req_id, n_pages + req.max_new, own]
                admitted.append(req.req_id)
                admit_lat.append(t - req.arrival)
        for j, s in enumerate(slots):          # service-time model
            if s is None:
                continue
            s[1] -= 1
            if s[1] <= 0:
                ids = s[2] + [-1] * (maxp - len(s[2]))
                pool = pool_free(pool, jnp.asarray(ids, jnp.int32),
                                 jnp.asarray([x >= 0 for x in ids]))
                slots[j] = None
                done += 1
        jax.block_until_ready((sched.store, pc.store, pool.gen))
        tick_walls.append(time.perf_counter() - t0)
        t += 1
    assert done == len(trace), f"trace did not drain ({done}/{len(trace)})"

    pcm, scm = PC.metrics(pc), SCH.metrics(sched)
    lookups = int(pcm["find_hits"]) + int(pcm["find_misses"])
    outcome = (tuple(admitted), tuple(admit_lat), int(pcm["find_hits"]),
               lookups, int(scm["pops"]), int(scm["pop_empty"]))
    return {
        "outcome": outcome,
        "digest": zlib.crc32(repr(outcome).encode()),
        "ticks": t,
        "wall": sum(tick_walls),
        "tick_walls": tick_walls,
        "admit_lat": admit_lat,
        "hit_rate": int(pcm["find_hits"]) / lookups if lookups else 0.0,
        "pops": int(scm["pops"]),
        "pop_empty": int(scm["pop_empty"]),
    }


def run(out_dir: str | None = None):
    rec = Recorder("serve", exec_modes=list(exec_.runnable_modes()),
                   bench_iters=ITERS, warmup_discard=WARMUP)
    trace = traffic.make_trace(SEED, n_requests=N_REQS, page_size=PAGE)
    again = traffic.make_trace(SEED, n_requests=N_REQS, page_size=PAGE)
    assert all(a.req_id == b.req_id and a.arrival == b.arrival
               and np.array_equal(a.prompt, b.prompt)
               for a, b in zip(trace, again)), "trace generator not seeded"
    pkeys = {r.req_id: _page_keys(r.prompt) for r in trace}
    maxf = max(len(v) for v in pkeys.values())
    maxp = max(-(-len(r.prompt) // PAGE) for r in trace)

    ref_outcome = None
    for mode in exec_.runnable_modes():
        with exec_.exec_mode(mode):
            runs = [_drive(trace, pkeys, maxp, maxf)
                    for _ in range(WARMUP + ITERS)]
        # determinism gates: seeded replays agree, and so do exec modes
        for r in runs[1:]:
            assert r["outcome"] == runs[0]["outcome"], \
                f"replay divergence in mode={mode}"
        if ref_outcome is None:
            ref_outcome = runs[0]["outcome"]
        assert runs[0]["outcome"] == ref_outcome, \
            f"exec-mode divergence: {mode}"
        timed = runs[WARMUP:]
        best = min(timed, key=lambda r: r["wall"])
        walls = [w for r in timed for w in r["tick_walls"]]
        lat = np.asarray(best["admit_lat"], np.float64)
        rec.record(
            f"serve/trace/mode={mode}",
            best["wall"] / best["ticks"],
            ticks=best["ticks"], requests=N_REQS,
            throughput_rps=N_REQS / best["wall"],
            admit_p50_ticks=float(np.percentile(lat, 50)),
            admit_p99_ticks=float(np.percentile(lat, 99)),
            prefix_hit_rate=round(best["hit_rate"], 4),
            pops=best["pops"], pop_empty=best["pop_empty"],
            digest=best["digest"], mode=mode,
            **percentiles(walls))
    finish(rec, out_dir)
    return rec
