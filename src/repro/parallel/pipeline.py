"""Pipeline parallelism: GPipe-style fill-drain schedule over a mesh axis.

Each device on the `stage` axis holds one stage's parameters; activations
flow stage-to-stage with `collective_permute` (the ICI-neighbor hop), one
microbatch injected per tick — n_micro + n_stages - 1 ticks total, bubble
fraction (S-1)/(T+S-1) as usual. Composes under jit with the other axes on
GSPMD auto (pass `mesh` with extra axes and keep them out of `axis`).

This is the PP primitive (deliverable: DP/TP/PP/EP/SP support); the default
production configs prefer DP×TP(+EP) — PP becomes profitable past the HBM
cliff (see llama3-405b train temp-memory in §Dry-run).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from repro.core.routing import mesh_shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn, mesh: Mesh, axis: str = "stage"):
    """Build a pipelined apply: (stage_params, micro_x) -> micro_y.

    stage_params: pytree with leading dim = n_stages (sharded over `axis`).
    micro_x: [n_micro, ...] microbatch stream (replicated).
    stage_fn(params_slice, x) -> y, same shape as x.
    Returns ys [n_micro, ...] (outputs of the LAST stage, in order).
    """
    n_stages = mesh.shape[axis]

    def body(params, xs):
        params = jax.tree.map(lambda a: a[0], params)   # my stage's params
        sid = jax.lax.axis_index(axis)
        n_micro = xs.shape[0]
        ticks = n_micro + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        buf = jnp.zeros_like(xs[0])
        ys = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, ys = carry
            # stage 0 injects microbatch t (older stages are processing t-sid)
            inj = jax.lax.dynamic_index_in_dim(xs, jnp.minimum(t, n_micro - 1),
                                               axis=0, keepdims=False)
            x_in = jnp.where(sid == 0, inj, buf)
            y = stage_fn(params, x_in)
            # last stage commits its result for microbatch t - (S-1)
            out_idx = t - (n_stages - 1)
            ok = (sid == n_stages - 1) & (out_idx >= 0)
            ys = jax.lax.cond(
                ok,
                lambda ys: jax.lax.dynamic_update_index_in_dim(
                    ys, y, jnp.maximum(out_idx, 0), axis=0),
                lambda ys: ys, ys)
            # shift activations one stage forward
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, ys

        _, ys = jax.lax.fori_loop(0, ticks, tick, (buf, ys))
        # broadcast the last stage's outputs to every stage (so out_specs can
        # be replicated); sum works because other stages contributed zeros
        ys = jax.lax.psum(jnp.where(sid == n_stages - 1, ys, 0), axis)
        return ys

    pspec = jax.tree_util.Partial  # noqa: F841 (doc aid)
    return mesh_shard_map(body, mesh=mesh,
                     in_specs=(P(axis), P()),
                     out_specs=P(),
                     check_vma=False)
