"""Mesh context + sharding rules (DP / FSDP / TP / EP / SP).

The mesh context lets model internals (MoE dispatch, sharded decode
attention) open nested shard_map regions over the model axis while the rest
of the program stays under GSPMD auto-sharding — pjit outside, manual
collectives exactly where the paper's routing lives.

Param sharding rules (2D "fsdp x tp", MaxText-style):
  embed/lm_head [V, D]   -> P(tp, fsdp)
  attn in  [D, H*dh]     -> P(fsdp, tp)
  attn out [H*dh, D]     -> P(tp, fsdp)
  mlp in   [D, F]        -> P(fsdp, tp)   mlp out [F, D] -> P(tp, fsdp)
  experts  [E, D, F]     -> P(ep, fsdp, tp_inner) (EP over the model axis)
  scalars/norms          -> replicated
Dims that do not divide their axis fall back to replication on that dim
(heads that don't divide 16, etc.) — recorded per-arch by the dry-run.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


@contextmanager
def use_mesh(mesh: Mesh, dp_axes=("data",), tp_axis="model", pp_axis=None):
    prev = getattr(_ctx, "cfg", None)
    _ctx.cfg = {"mesh": mesh, "dp_axes": tuple(dp_axes), "tp_axis": tp_axis,
                "pp_axis": pp_axis}
    try:
        yield
    finally:
        _ctx.cfg = prev


def current_mesh():
    cfg = getattr(_ctx, "cfg", None)
    return cfg["mesh"] if cfg else None


def mesh_cfg():
    return getattr(_ctx, "cfg", None)


def _divides(dim: int, axes, mesh: Mesh) -> bool:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return dim % n == 0


def _maybe(axis, dim, mesh):
    """axis if it divides dim else None (replicate)."""
    if axis is None:
        return None
    return axis if _divides(dim, axis, mesh) else None


def param_spec(path: str, shape: tuple, mesh: Mesh, dp_axes=("data",),
               tp_axis="model") -> P:
    """Sharding rule by parameter path suffix + shape."""
    fsdp = tuple(dp_axes)  # ZeRO-3-style: shard the non-TP dim over data
    name = path.split("/")[-1]
    nd = len(shape)
    if nd <= 1:
        return P()
    if name == "embed":
        # vocab-parallel embedding, D replicated (2D-sharded embed gathers
        # trip XLA:CPU SPMD — and Megatron-style vocab-parallel is the
        # production layout anyway)
        return P(_maybe(tp_axis, shape[0], mesh), None)
    if name == "lm_head":
        return P(None, _maybe(tp_axis, shape[-1], mesh))
    if name in ("wo", "wd", "down", "out_proj", "out"):
        # [big_in, D]: first dim tp, second fsdp
        return P(_maybe(tp_axis, shape[0], mesh),
                 fsdp if _divides(shape[1], fsdp, mesh) else None)
    if name in ("wi", "wu", "wq", "wk", "wv", "wx", "wh", "up", "in_proj",
                "x_proj", "wdq", "wuq", "wdkv", "wuk", "wuv", "wkr", "router"):
        if nd == 3:  # experts [E, D, F] — EP over model; ZeRO shard on the
            # LAST dim (F) over data: D-dim sharding trips XLA:CPU SPMD
            # resharding in the scanned backward (llama4 16x16 cell)
            return P(_maybe(tp_axis, shape[0], mesh), None,
                     fsdp if _divides(shape[2], fsdp, mesh) else None)
        return P(fsdp if _divides(shape[0], fsdp, mesh) else None,
                 _maybe(tp_axis, shape[1], mesh))
    if nd == 3:  # stacked experts default
        return P(_maybe(tp_axis, shape[0], mesh), None, None)
    return P(fsdp if _divides(shape[0], fsdp, mesh) else None, None)


def params_shardings(params, mesh: Mesh, dp_axes=("data",), tp_axis="model"):
    """NamedSharding pytree for a param tree. Leading scan-stack dims (added
    by the layer scan) are detected by path containing 'blocks' and skipped."""
    def spec_for(path_elems, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_elems)
        shape = leaf.shape
        stacked = "blocks" in path
        if stacked and len(shape) >= 1:
            inner = shape[1:]
            sp = param_spec(path, inner, mesh, dp_axes, tp_axis)
            return NamedSharding(mesh, P(None, *sp))
        return NamedSharding(mesh, param_spec(path, shape, mesh, dp_axes, tp_axis))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def constrain(x, spec: P):
    """Sharding-constraint hint if a mesh context is active, else no-op."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
