"""Pure-jnp oracle for causal flash attention (GQA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: [B, Sq, H, D]; k/v: [B, Sk, Hkv, D]; returns [B, Sq, H, D] f32."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bqkgs", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        scores = jnp.where(mask[None, :, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d)
