"""jit'd wrapper: [B,S,H,D] GQA layout -> kernel layout, D padded to 128."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: [B, Sq, H, D]; k/v: [B, Sk, Hkv, D] -> [B, Sq, H, D] (q.dtype)."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    scale = 1.0 / (d ** 0.5)
    dp = -(-d // 128) * 128
    pad = dp - d

    def to_bhsd(x, heads):
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad)))
        return x.transpose(0, 2, 1, 3).reshape(b * heads, x.shape[1], dp)

    o = flash_attention_bhsd(to_bhsd(q, h), to_bhsd(k, hkv), to_bhsd(v, hkv),
                             scale=scale, causal=causal, block_q=block_q,
                             block_k=block_k, interpret=interpret)
    o = o.reshape(b, h, sq, dp).transpose(0, 2, 1, 3)
    return o[..., :d]
