"""Causal flash attention — Pallas TPU kernel.

TPU mapping: grid = (B*H, num_q_blocks, num_k_blocks) with the k axis
"arbitrary" (sequential) so the online-softmax accumulators (m, l, acc) live
in VMEM scratch across k steps. Q/K/V stream through VMEM in (block, 128)
tiles — MXU-aligned; the causal upper triangle is skipped entirely via
pl.when (block-level) + in-block iota masking (diagonal blocks).

GQA without materializing kv heads: K/V refs are laid out [B*Hkv, S, D] and
the BlockSpec index_map divides the q-head grid index by the group size —
the kv block is fetched once per group straight from HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
               *, scale: float, block_q: int, block_k: int, causal: bool):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # k block
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    should = (j * block_k <= i * block_q + block_q - 1) if causal else True

    @pl.when(should if causal else j >= 0)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # [bq, d]
        k = k_ref[0].astype(jnp.float32)                  # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qi = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            ki = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qi >= ki, s, NEG_INF)
        m_prev = m_ref[...]                               # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, scale: float, causal: bool = True,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True):
    """q: [BH, Sq, D]; k/v: [BKV, Sk, D] with BH = BKV * group.

    Layout contract: D padded to 128 (MXU lane width) by ops.py.
    """
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    group = bh // bkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    grid = (bh, sq // block_q, sk // block_k)

    kernel = functools.partial(_fa_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),    # acc
            pltpu.VMEM((block_q, 1), jnp.float32),    # m
            pltpu.VMEM((block_q, 1), jnp.float32),    # l
        ],
        interpret=interpret,
    )(q, k, v)
