"""jit'd wrapper: DetSkiplist state -> shared level-major layout
(`repro.core.layout.skiplist_layout`) -> batched Pallas search.

`skiplist_find` is the unjitted entry the `repro.store.exec` dispatch layer
calls from inside already-jitted store steps; `skiplist_search` keeps the
standalone jitted contract of `core.det_skiplist.find_batch`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.bits import KEY_INF
from repro.core.layout import skiplist_layout, split_u64
from repro.kernels.skiplist_search.kernel import skiplist_search_tiles


def stack_levels(s):
    """DetSkiplist -> ([L, C1] hi, lo, child) padded with +inf sentinels.
    (Compatibility veneer over `core.layout.skiplist_layout`.)"""
    lay = skiplist_layout(s)
    return lay.lvl_hi, lay.lvl_lo, lay.lvl_child


def skiplist_find(s, queries, *, tile: int = 256, interpret: bool = True):
    """Batched Find on a DetSkiplist via the Pallas kernel — same contract as
    core.det_skiplist.find_batch: (found bool[T], vals u64[T], idx int32[T]).
    Not jitted: callable from inside jitted/shard_mapped store steps."""
    t = queries.shape[0]
    pad = (-t) % tile
    qp = jnp.pad(queries, (0, pad), constant_values=KEY_INF)
    qh, ql = split_u64(qp)
    lay = skiplist_layout(s)
    # named scope: visible as obs.kernel.skiplist_search in jax.profiler
    # timelines / lowered HLO (span taxonomy in store/obs.py)
    with jax.named_scope("obs.kernel.skiplist_search"):
        found, idx = skiplist_search_tiles(
            qh, ql, lay.lvl_hi, lay.lvl_lo, lay.lvl_child,
            lay.term_hi, lay.term_lo, lay.term_mark,
            tile=tile, interpret=interpret)
    found = found[:t].astype(bool) & (queries != KEY_INF)
    idx = idx[:t]
    vals = jnp.where(found, s.term_vals[jnp.clip(idx, 0, s.capacity - 1)],
                     jnp.uint64(0))
    return found, vals, idx


@partial(jax.jit, static_argnames=("tile", "interpret"))
def skiplist_search(s, queries, *, tile: int = 256, interpret: bool = True):
    """Jitted standalone form of `skiplist_find`."""
    return skiplist_find(s, queries, tile=tile, interpret=interpret)
