"""jit'd wrapper: DetSkiplist state -> kernel layout (u64 -> u32 pairs,
levels stacked + padded) -> batched search."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.bits import KEY_INF
from repro.kernels.skiplist_search.kernel import skiplist_search_tiles


def split_u64(x):
    return ((x >> jnp.uint64(32)).astype(jnp.uint32),
            (x & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32))


def stack_levels(s):
    """DetSkiplist -> ([L, C1] hi, lo, child) padded with +inf sentinels."""
    c1 = s.level_keys[0].shape[0]
    his, los, chs = [], [], []
    for lk, lc in zip(s.level_keys, s.level_child):
        pad = c1 - lk.shape[0]
        lk = jnp.pad(lk, (0, pad), constant_values=KEY_INF)
        lc = jnp.pad(lc, (0, pad))
        h, l = split_u64(lk)
        his.append(h)
        los.append(l)
        chs.append(lc.astype(jnp.int32))
    return jnp.stack(his), jnp.stack(los), jnp.stack(chs)


@partial(jax.jit, static_argnames=("tile", "interpret"))
def skiplist_search(s, queries, *, tile: int = 256, interpret: bool = True):
    """Batched Find on a DetSkiplist via the Pallas kernel.
    Returns (found bool[T], vals u64[T], idx int32[T]) — same contract as
    core.det_skiplist.find_batch (the pure-jnp production path)."""
    t = queries.shape[0]
    pad = (-t) % tile
    qp = jnp.pad(queries, (0, pad), constant_values=KEY_INF)
    qh, ql = split_u64(qp)
    lh, ll, lc = stack_levels(s)
    th, tl = split_u64(s.term_keys)
    tm = s.term_mark.astype(jnp.int8)
    found, idx = skiplist_search_tiles(qh, ql, lh, ll, lc, th, tl, tm,
                                       tile=tile, interpret=interpret)
    found = found[:t].astype(bool) & (queries != KEY_INF)
    idx = idx[:t]
    vals = jnp.where(found, s.term_vals[jnp.clip(idx, 0, s.capacity - 1)],
                     jnp.uint64(0))
    return found, vals, idx
