"""Pure-jnp oracle: batched deterministic-skiplist search over the stacked
level layout the kernel consumes (keys as u32 hi/lo pairs)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.layout import key_leq as _le


def skiplist_search_ref(q_hi, q_lo, lvl_hi, lvl_lo, lvl_child, lvl_count,
                        term_hi, term_lo, term_mark):
    """q_*: [T] u32; lvl_*: [L, C1]; term_*: [C]. Returns (found bool[T],
    idx int32[T]). Levels stacked bottom-up: row L-1 is the top."""
    L, c1 = lvl_hi.shape
    cap = term_hi.shape[0]
    t = q_hi.shape[0]
    # top probe (<= 4 live nodes at the top level)
    topk_h, topk_l = lvl_hi[L - 1, :4], lvl_lo[L - 1, :4]
    ge = _le(q_hi[:, None], q_lo[:, None], topk_h[None, :], topk_l[None, :])
    i = jnp.argmax(ge, axis=1).astype(jnp.int32)
    for r in range(L - 1, -1, -1):
        start = lvl_child[r][jnp.clip(i, 0, c1 - 1)]
        below_h = term_hi if r == 0 else lvl_hi[r - 1]
        below_l = term_lo if r == 0 else lvl_lo[r - 1]
        idx = jnp.clip(start[:, None] + jnp.arange(4, dtype=jnp.int32)[None, :],
                       0, below_h.shape[0] - 1)
        ok = _le(q_hi[:, None], q_lo[:, None], below_h[idx], below_l[idx])
        sel = jnp.argmax(ok, axis=1).astype(jnp.int32)
        i = start + sel
    i = jnp.clip(i, 0, cap - 1)
    found = ((term_hi[i] == q_hi) & (term_lo[i] == q_lo)
             & ~term_mark[i].astype(bool))
    return found, i
