"""Batched deterministic-skiplist search — Pallas TPU kernel.

Why this kernelizes well (and the randomized skiplist would not): the
1-2-3-4 criterion guarantees EXACTLY L descent steps with a fan-out-4 probe
each — a static loop with fixed-shape 4-wide gathers. Determinism = static
shapes = full lane occupancy (DESIGN.md §2's inversion of the paper's CPU
conclusion).

TPU mapping:
  * level-major layout: every level is one contiguous row — the whole index
    stack ([L, C1] u32 x3) is VMEM-resident via whole-array BlockSpecs
    (the skiplist path through HBM pointer-land on CPU becomes L VMEM hops).
  * queries tile [T] per grid step; 64-bit keys travel as (hi, lo) u32 pairs
    compared lexicographically (TPU has no native u64 lanes — this is the
    hardware adaptation of the paper's 128-bit key|next words).
  * the 4-wide child probe is a dynamic gather of int32 lanes (mosaic
    dynamic_gather; validated in interpret mode on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.layout import key_leq as _le


def level_walk(qh, ql, lvl_hi, lvl_lo, lvl_child, term_hi, term_lo,
               term_mark, *, levels: int, fanout: int):
    """The in-kernel level-major descent body: exactly `levels` steps of
    fan-out-`fanout` probes down to the terminal level. Shared with the
    fused tier-find kernel (`kernels/tier_find`), so the warm-tier walk has
    exactly one implementation. Returns (found bool[T], term idx i32[T])."""
    t = qh.shape[0]
    c1 = lvl_hi.shape[1]
    cap = term_hi.shape[0]

    # top probe
    ok = _le(qh[:, None], ql[:, None], lvl_hi[levels - 1, :fanout][None, :],
             lvl_lo[levels - 1, :fanout][None, :])
    i = jnp.argmax(ok, axis=1).astype(jnp.int32)
    for r in range(levels - 1, -1, -1):
        start = jnp.take(lvl_child[r], jnp.clip(i, 0, c1 - 1), axis=0)
        bh = term_hi if r == 0 else lvl_hi[r - 1]
        bl = term_lo if r == 0 else lvl_lo[r - 1]
        hi = bh.shape[0]
        idx = jnp.clip(start[:, None] + jax.lax.broadcasted_iota(
            jnp.int32, (t, fanout), 1), 0, hi - 1)
        ck_h = jnp.take(bh, idx.reshape(-1), axis=0).reshape(t, fanout)
        ck_l = jnp.take(bl, idx.reshape(-1), axis=0).reshape(t, fanout)
        ok = _le(qh[:, None], ql[:, None], ck_h, ck_l)
        sel = jnp.argmax(ok, axis=1).astype(jnp.int32)
        i = start + sel
    i = jnp.clip(i, 0, cap - 1)
    fh = jnp.take(term_hi, i, axis=0)
    fl = jnp.take(term_lo, i, axis=0)
    fm = jnp.take(term_mark, i, axis=0)
    return (fh == qh) & (fl == ql) & (fm == 0), i


def _sk_kernel(qh_ref, ql_ref, lh_ref, ll_ref, lc_ref, th_ref, tl_ref,
               tm_ref, found_ref, idx_ref, *, levels: int, fanout: int):
    found, i = level_walk(qh_ref[...], ql_ref[...], lh_ref[...], ll_ref[...],
                          lc_ref[...], th_ref[...], tl_ref[...], tm_ref[...],
                          levels=levels, fanout=fanout)
    found_ref[...] = found.astype(jnp.int8)
    idx_ref[...] = i


def skiplist_search_tiles(q_hi, q_lo, lvl_hi, lvl_lo, lvl_child,
                          term_hi, term_lo, term_mark, *, tile: int = 256,
                          interpret: bool = True):
    """q_*: [T]; lvl_*: [L, C1]; term_*: [C]. Returns (found i8[T], idx i32[T])."""
    t = q_hi.shape[0]
    L, c1 = lvl_hi.shape
    cap = term_hi.shape[0]
    if t == 0:   # empty batch: same contract as the jnp reference
        return (jnp.zeros((0,), jnp.int8), jnp.zeros((0,), jnp.int32))
    tile = min(tile, t)
    assert t % tile == 0
    grid = (t // tile,)
    whole = lambda a: pl.BlockSpec(a.shape, lambda g: (0,) * a.ndim)

    kernel = functools.partial(_sk_kernel, levels=L, fanout=4)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda g: (g,)),
            pl.BlockSpec((tile,), lambda g: (g,)),
            whole(lvl_hi), whole(lvl_lo), whole(lvl_child),
            whole(term_hi), whole(term_lo), whole(term_mark),
        ],
        out_specs=[pl.BlockSpec((tile,), lambda g: (g,)),
                   pl.BlockSpec((tile,), lambda g: (g,))],
        out_shape=[jax.ShapeDtypeStruct((t,), jnp.int8),
                   jax.ShapeDtypeStruct((t,), jnp.int32)],
        interpret=interpret,
    )(q_hi, q_lo, lvl_hi, lvl_lo, lvl_child, term_hi, term_lo, term_mark)
