"""Pure-jnp oracle for the selective scan (mamba-1 recurrence).

h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t * B_t     (per channel d, state n)
y_t = sum_n h_t[d, n] * C_t[n]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, bmat, cmat, a, h0=None):
    """x: [B,S,D]; dt: [B,S]; bmat/cmat: [B,S,N]; a: [D,N] (negative).
    Returns (y [B,S,D] f32, h_last [B,D,N] f32)."""
    b, s, d = x.shape
    n = bmat.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, d, n), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                      # [B,D],[B],[B,N],[B,N]
        da = jnp.exp(dtt[:, None, None] * a[None])           # [B,D,N]
        h = da * h + (dtt[:, None] * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = (x.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0).astype(jnp.float32),
          bmat.transpose(1, 0, 2).astype(jnp.float32),
          cmat.transpose(1, 0, 2).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h
