"""Fused selective scan — Pallas TPU kernel (the §Perf fix for the SSM
memory wall).

Why: the XLA chunked path materializes the hidden tensor [B, L, D, N] in HBM
(13+ TB/step for hymba@train_4k — measured, EXPERIMENTS.md §Perf). This
kernel keeps the recurrent state h [D_blk, N] in VMEM for the whole sequence:
HBM traffic collapses to the in/out streams (x, dt, B, C, y) — a ~200×
memory-term reduction for the SSM layers.

TPU mapping:
  grid = (B, D_blocks, S_chunks); the S axis is sequential ("arbitrary") so
  the VMEM scratch h persists across chunks. Inside a chunk, a fori_loop
  steps the recurrence; each step is a [D_blk, N] VPU elementwise update +
  an N-contraction — latency-bound but HBM-minimal (the mamba2/SSD matrix
  reformulation is the MXU-friendly successor; out of scope here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ss_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_ref,
               *, chunk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)       # [L, Dblk]
    dt = dt_ref[0].astype(jnp.float32)     # [L]
    bm = b_ref[0].astype(jnp.float32)      # [L, N]
    cm = c_ref[0].astype(jnp.float32)      # [L, N]
    a = a_ref[...].astype(jnp.float32)     # [Dblk, N]

    def step(t, carry):
        h, ys = carry
        da = jnp.exp(dt[t] * a)                            # [Dblk, N]
        h = da * h + (dt[t] * x[t])[:, None] * bm[t][None, :]
        yt = jnp.sum(h * cm[t][None, :], axis=1)           # [Dblk]
        ys = jax.lax.dynamic_update_slice_in_dim(ys, yt[None], t, axis=0)
        return h, ys

    ys0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h_ref[...], ys0))
    h_ref[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


def selective_scan_blocks(x, dt, bmat, cmat, a, *, d_block: int = 512,
                          chunk: int = 256, interpret: bool = True):
    """x: [B,S,D]; dt: [B,S]; bmat/cmat: [B,S,N]; a: [D,N] -> y [B,S,D]."""
    b, s, d = x.shape
    n = bmat.shape[-1]
    d_block = min(d_block, d)
    chunk = min(chunk, s)
    assert d % d_block == 0 and s % chunk == 0
    grid = (b, d // d_block, s // chunk)

    kernel = functools.partial(_ss_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda bb, dd, jj: (bb, jj, dd)),
            pl.BlockSpec((1, chunk), lambda bb, dd, jj: (bb, jj)),
            pl.BlockSpec((1, chunk, n), lambda bb, dd, jj: (bb, jj, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, dd, jj: (bb, jj, 0)),
            pl.BlockSpec((d_block, n), lambda bb, dd, jj: (dd, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block),
                               lambda bb, dd, jj: (bb, jj, dd)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d_block, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, bmat, cmat, a)
