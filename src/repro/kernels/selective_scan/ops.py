"""jit'd wrapper for the fused selective scan."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.selective_scan.kernel import selective_scan_blocks


@partial(jax.jit, static_argnames=("d_block", "chunk", "interpret"))
def selective_scan(x, dt, bmat, cmat, a, *, d_block: int = 512,
                   chunk: int = 256, interpret: bool = True):
    """Fused mamba-1 scan: x [B,S,D], dt [B,S], B/C [B,S,N], A [D,N] -> y."""
    return selective_scan_blocks(x, dt, bmat, cmat, a, d_block=d_block,
                                 chunk=chunk, interpret=interpret)
