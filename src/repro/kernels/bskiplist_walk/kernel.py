"""Batched B-skiplist search — Pallas TPU kernel.

The level-major walk (`kernels/skiplist_search`) touches 4 keys per step —
correct, but it uses 4 of 128 VPU lanes. The B-skiplist walk loads one
lane-width fat node (BSKIP_BLOCK = 128 sorted keys) per step and computes
the searchsorted-left position as ONE vector compare + sum-reduction, so
the descent is `ceil(log_128(..))+1` full-tile steps instead of
`num_levels+1` fan-out-4 steps (e.g. C=8192: 2 blocked vs 12 level-major).

TPU mapping:
  * block-major layout (`core.layout.bskiplist_layout`): index levels are a
    [L, W] rectangle, terminal a flat [NB*128] plane — whole-array
    BlockSpecs keep both VMEM-resident (W <= C/128 u32 cells per row, tiny
    next to the terminal planes the level-major kernel already holds).
  * queries tile [T] per grid step; keys travel as (hi, lo) u32 pairs with
    the shared `key_lt` compare (searchsorted-left needs strict <).
  * each step is a dynamic gather of one 128-wide node row (same mosaic
    dynamic_gather as the 4-wide child probe, just full-tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.layout import BSKIP_BLOCK, key_lt as _lt


def block_walk(qh, ql, blk_hi, blk_lo, term_hi, term_lo, term_mark, *,
               levels: int, block: int = BSKIP_BLOCK):
    """The in-kernel block-major descent body: exactly `levels` + 1
    whole-block compares (index rows top-down, then the terminal block).
    Shared with the fused tier kernels (`kernels/tier_find`,
    `kernels/tier_apply`), so the blocked warm-tier walk has exactly one
    implementation. Returns (found bool[T], term idx i32[T])."""
    t = qh.shape[0]
    B = block
    W = blk_hi.shape[1]
    nb = term_hi.shape[0] // B

    i = jnp.zeros((t,), jnp.int32)              # root: node 0 of row L-1
    lanes = jax.lax.broadcasted_iota(jnp.int32, (t, B), 1)
    for r in range(levels - 1, -1, -1):
        base = jnp.clip(i, 0, W // B - 1) * B
        idx = base[:, None] + lanes
        eh = jnp.take(blk_hi[r], idx.reshape(-1), axis=0).reshape(t, B)
        el = jnp.take(blk_lo[r], idx.reshape(-1), axis=0).reshape(t, B)
        lt = _lt(eh, el, qh[:, None], ql[:, None])
        sel = jnp.sum(lt, axis=1, dtype=jnp.int32)  # searchsorted-left
        i = base + sel                               # child node / block id
    blk = jnp.clip(i, 0, nb - 1)
    idx = blk[:, None] * B + lanes
    eh = jnp.take(term_hi, idx.reshape(-1), axis=0).reshape(t, B)
    el = jnp.take(term_lo, idx.reshape(-1), axis=0).reshape(t, B)
    lt = _lt(eh, el, qh[:, None], ql[:, None])
    sel = jnp.sum(lt, axis=1, dtype=jnp.int32)
    i = jnp.clip(blk * B + sel, 0, term_hi.shape[0] - 1)
    fh = jnp.take(term_hi, i, axis=0)
    fl = jnp.take(term_lo, i, axis=0)
    fm = jnp.take(term_mark, i, axis=0)
    return (fh == qh) & (fl == ql) & (fm == 0), i


def _bw_kernel(qh_ref, ql_ref, bh_ref, bl_ref, th_ref, tl_ref, tm_ref,
               found_ref, idx_ref, *, levels: int, block: int):
    found, i = block_walk(qh_ref[...], ql_ref[...], bh_ref[...], bl_ref[...],
                          th_ref[...], tl_ref[...], tm_ref[...],
                          levels=levels, block=block)
    found_ref[...] = found.astype(jnp.int8)
    idx_ref[...] = i


def bskiplist_walk_tiles(q_hi, q_lo, blk_hi, blk_lo, term_hi, term_lo,
                         term_mark, *, block: int = BSKIP_BLOCK,
                         tile: int = 256, interpret: bool = True):
    """q_*: [T]; blk_*: [L, W]; term_*: [NB*B]. Returns (found i8[T],
    idx i32[T])."""
    t = q_hi.shape[0]
    L = blk_hi.shape[0]
    if t == 0:   # empty batch: same contract as the jnp reference
        return (jnp.zeros((0,), jnp.int8), jnp.zeros((0,), jnp.int32))
    tile = min(tile, t)
    assert t % tile == 0
    grid = (t // tile,)
    whole = lambda a: pl.BlockSpec(a.shape, lambda g: (0,) * a.ndim)

    kernel = functools.partial(_bw_kernel, levels=L, block=block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda g: (g,)),
            pl.BlockSpec((tile,), lambda g: (g,)),
            whole(blk_hi), whole(blk_lo),
            whole(term_hi), whole(term_lo), whole(term_mark),
        ],
        out_specs=[pl.BlockSpec((tile,), lambda g: (g,)),
                   pl.BlockSpec((tile,), lambda g: (g,))],
        out_shape=[jax.ShapeDtypeStruct((t,), jnp.int8),
                   jax.ShapeDtypeStruct((t,), jnp.int32)],
        interpret=interpret,
    )(q_hi, q_lo, blk_hi, blk_lo, term_hi, term_lo, term_mark)
