"""Pure-jnp oracle: batched B-skiplist search over the block-major layout
the kernel consumes (keys as u32 hi/lo pairs, lane-width fat nodes).

One step = one whole-node compare: `sum(key_lt(entry, q))` over the node's
B sorted entries is the searchsorted-left position of q, so the descent
computes exactly the terminal rank the level-major walk computes — found
results are bit-identical by construction.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.layout import BSKIP_BLOCK, key_lt as _lt


def bskiplist_walk_ref(q_hi, q_lo, blk_hi, blk_lo, term_hi, term_lo,
                       term_mark, *, block: int = BSKIP_BLOCK):
    """q_*: [T] u32; blk_*: [L, W]; term_*: [NB * B]. Returns (found
    bool[T], idx int32[T]). Levels stacked bottom-up: row L-1 is the root
    node; node j of a row spans cells [j*B, (j+1)*B)."""
    L, W = blk_hi.shape
    B = block
    nb = term_hi.shape[0] // B
    lanes = jnp.arange(B, dtype=jnp.int32)[None, :]
    i = jnp.zeros(q_hi.shape, jnp.int32)            # root: node 0 of row L-1
    for r in range(L - 1, -1, -1):
        base = jnp.clip(i, 0, W // B - 1) * B
        idx = base[:, None] + lanes
        lt = _lt(blk_hi[r][idx], blk_lo[r][idx], q_hi[:, None], q_lo[:, None])
        sel = jnp.sum(lt, axis=1).astype(jnp.int32)  # searchsorted-left
        i = base + sel                               # child node / block id
    blk = jnp.clip(i, 0, nb - 1)
    idx = blk[:, None] * B + lanes
    lt = _lt(term_hi[idx], term_lo[idx], q_hi[:, None], q_lo[:, None])
    sel = jnp.sum(lt, axis=1).astype(jnp.int32)
    i = jnp.clip(blk * B + sel, 0, term_hi.shape[0] - 1)
    found = ((term_hi[i] == q_hi) & (term_lo[i] == q_lo)
             & ~term_mark[i].astype(bool))
    return found, i
