"""jit'd wrapper: DetSkiplist state -> shared block-major layout
(`repro.core.layout.bskiplist_layout`) -> batched Pallas B-skiplist search.

`bskiplist_find` is the unjitted entry the `repro.store.exec` dispatch
layer calls from inside already-jitted store steps; `bskiplist_search`
keeps the standalone jitted contract of `core.det_skiplist.find_batch`.
Same contract as `kernels.skiplist_search.ops` — the two walks are
interchangeable probe implementations over the same state.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.bits import KEY_INF
from repro.core.layout import BSKIP_BLOCK, bskiplist_layout, split_u64
from repro.kernels.bskiplist_walk.kernel import bskiplist_walk_tiles


def bskiplist_find(s, queries, *, block: int = BSKIP_BLOCK, tile: int = 256,
                   interpret: bool = True):
    """Batched Find on a DetSkiplist via the blocked Pallas kernel — same
    contract as core.det_skiplist.find_batch: (found bool[T], vals u64[T],
    idx int32[T]). Not jitted: callable from inside jitted/shard_mapped
    store steps."""
    t = queries.shape[0]
    pad = (-t) % tile
    qp = jnp.pad(queries, (0, pad), constant_values=KEY_INF)
    qh, ql = split_u64(qp)
    lay = bskiplist_layout(s, block)
    # named scope: visible as obs.kernel.bskiplist_walk in jax.profiler
    # timelines / lowered HLO (span taxonomy in store/obs.py)
    with jax.named_scope("obs.kernel.bskiplist_walk"):
        found, idx = bskiplist_walk_tiles(
            qh, ql, lay.blk_hi, lay.blk_lo,
            lay.term_hi, lay.term_lo, lay.term_mark,
            block=block, tile=tile, interpret=interpret)
    found = found[:t].astype(bool) & (queries != KEY_INF)
    idx = idx[:t]
    vals = jnp.where(found, s.term_vals[jnp.clip(idx, 0, s.capacity - 1)],
                     jnp.uint64(0))
    return found, vals, idx


@partial(jax.jit, static_argnames=("block", "tile", "interpret"))
def bskiplist_search(s, queries, *, block: int = BSKIP_BLOCK,
                     tile: int = 256, interpret: bool = True):
    """Jitted standalone form of `bskiplist_find`."""
    return bskiplist_find(s, queries, block=block, tile=tile,
                          interpret=interpret)
