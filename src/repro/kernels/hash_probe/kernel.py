"""Batched fixed-hash bucket probe — Pallas TPU kernel.

The hot-tier fast path of the §IX tier stack: a fixed-slot table whose
buckets are contiguous [B]-wide rows (`repro.core.layout.BucketLayout`), the
whole table VMEM-resident via whole-array BlockSpecs. One probe = one
dynamic row gather + one vector compare across the bucket — the "constant
cost per key" the paper wants, with the bucket row as the VMEM tile.

TPU mapping:
  * queries tile [T] per grid step; 64-bit keys travel as (hi, lo) u32
    planes compared per-plane (equality, so no lexicographic carry needed).
  * slot ids arrive precomputed as int32 (the splitmix64 scramble runs on
    the u64 host path — TPU lanes have no u64; see `core.layout.hash_slot`).
  * the bucket gather is a dynamic row gather of int32/u32 lanes (mosaic
    dynamic_gather; validated in interpret mode on CPU).
  * outputs are (found i8[T], col i32[T]); the value gather happens outside
    the kernel where u64 lanes exist.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def bucket_probe(qh, ql, slots, key_hi, key_lo):
    """The in-kernel bucket probe body: one dynamic row gather + one vector
    compare. Shared with the fused tier-find kernel (`kernels/tier_find`),
    so the hot-tier compare rule has exactly one implementation. Returns
    (hit bool[T], col i32[T]); col of a miss is the argmax convention
    (first column), callers mask by hit."""
    m = key_hi.shape[0]
    s = jnp.clip(slots, 0, m - 1)
    rows_h = jnp.take(key_hi, s, axis=0)               # [T, B] bucket gather
    rows_l = jnp.take(key_lo, s, axis=0)
    hit = (rows_h == qh[:, None]) & (rows_l == ql[:, None])
    return jnp.any(hit, axis=1), jnp.argmax(hit, axis=1).astype(jnp.int32)


def _hp_kernel(qh_ref, ql_ref, slot_ref, kh_ref, kl_ref, found_ref, col_ref):
    hit, col = bucket_probe(qh_ref[...], ql_ref[...], slot_ref[...],
                            kh_ref[...], kl_ref[...])
    found_ref[...] = hit.astype(jnp.int8)
    col_ref[...] = col


def hash_probe_tiles(q_hi, q_lo, slots, key_hi, key_lo, *, tile: int = 256,
                     interpret: bool = True):
    """q_*: [T] u32; slots: [T] i32; key_*: [M, B] u32 (the bucket layout).
    Returns (found i8[T], col i32[T])."""
    t = q_hi.shape[0]
    if t == 0:   # empty batch: same contract as the jnp reference
        return (jnp.zeros((0,), jnp.int8), jnp.zeros((0,), jnp.int32))
    tile = min(tile, t)
    assert t % tile == 0
    grid = (t // tile,)
    whole = lambda a: pl.BlockSpec(a.shape, lambda g: (0,) * a.ndim)
    qspec = pl.BlockSpec((tile,), lambda g: (g,))
    return pl.pallas_call(
        _hp_kernel,
        grid=grid,
        in_specs=[qspec, qspec, qspec, whole(key_hi), whole(key_lo)],
        out_specs=[pl.BlockSpec((tile,), lambda g: (g,)),
                   pl.BlockSpec((tile,), lambda g: (g,))],
        out_shape=[jax.ShapeDtypeStruct((t,), jnp.int8),
                   jax.ShapeDtypeStruct((t,), jnp.int32)],
        interpret=interpret,
    )(q_hi, q_lo, slots, key_hi, key_lo)
