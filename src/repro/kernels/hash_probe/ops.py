"""Wrapper: FixedHash state -> shared bucket layout
(`repro.core.layout.bucket_layout`) -> batched Pallas probe.

`fixed_hash_find` is the unjitted entry the `repro.store.exec` dispatch
layer calls from inside already-jitted store steps; `hash_probe` keeps a
standalone jitted form with the contract of `core.hashtable.fixed_find`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.bits import EMPTY
from repro.core.layout import bucket_layout, hash_slot, split_u64
from repro.kernels.hash_probe.kernel import hash_probe_tiles


def fixed_hash_find_cols(h, keys, *, tile: int = 256, interpret: bool = True):
    """Batched probe of a FixedHash via the Pallas kernel — same contract as
    core.hashtable.fixed_find_cols: (found bool[K], vals u64[K], col i32[K]).
    The kernel already emits the hit column (argmax over the bucket row, the
    same first-match rule as the jnp reference), so surfacing it for the tier
    stack's eviction-policy metadata refresh costs nothing. Not jitted:
    callable from inside jitted/shard_mapped store steps."""
    t = keys.shape[0]
    pad = (-t) % tile
    kp = jnp.pad(keys, (0, pad), constant_values=EMPTY)
    slots = hash_slot(kp, h.num_slots)
    qh, ql = split_u64(kp)
    lay = bucket_layout(h.keys)
    # named scope: the kernel shows up as obs.kernel.hash_probe in
    # jax.profiler timelines / lowered HLO (span taxonomy in store/obs.py)
    with jax.named_scope("obs.kernel.hash_probe"):
        found, col = hash_probe_tiles(qh, ql, slots, lay.key_hi, lay.key_lo,
                                      tile=tile, interpret=interpret)
    found = found[:t].astype(bool) & (keys != EMPTY)
    col = col[:t]
    vals = jnp.where(found, h.vals[slots[:t], col], jnp.uint64(0))
    return found, vals, col


def fixed_hash_find(h, keys, *, tile: int = 256, interpret: bool = True):
    """(found, vals) form of `fixed_hash_find_cols` — the contract of
    core.hashtable.fixed_find."""
    return fixed_hash_find_cols(h, keys, tile=tile, interpret=interpret)[:2]


@partial(jax.jit, static_argnames=("tile", "interpret"))
def hash_probe(h, keys, *, tile: int = 256, interpret: bool = True):
    """Jitted standalone form of `fixed_hash_find`."""
    return fixed_hash_find(h, keys, tile=tile, interpret=interpret)
