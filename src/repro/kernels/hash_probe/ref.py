"""Pure-jnp oracle: batched bucket probe over the same bucket-major layout
the kernel consumes (keys as u32 hi/lo planes, precomputed slot ids)."""
from __future__ import annotations

import jax.numpy as jnp


def hash_probe_ref(q_hi, q_lo, slots, key_hi, key_lo):
    """q_*: [T] u32; slots: [T] i32; key_*: [M, B] u32. Returns
    (found bool[T], col int32[T])."""
    s = jnp.clip(slots, 0, key_hi.shape[0] - 1)
    rows_h = key_hi[s]
    rows_l = key_lo[s]
    hit = (rows_h == q_hi[:, None]) & (rows_l == q_lo[:, None])
    return jnp.any(hit, axis=1), jnp.argmax(hit, axis=1).astype(jnp.int32)
