"""jit'd wrapper: [B,H,D] q + pool pages -> paged decode attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_grouped


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, block_tables, lengths, *,
                    interpret: bool = True):
    """q: [B, H, D]; pools: [N_pages, page, Hkv, D]; tables [B, P] (-1 pad);
    lengths [B]. Returns [B, H, D]."""
    b, h, d = q.shape
    hkv = k_pool.shape[2]
    g = h // hkv
    dp = -(-d // 128) * 128
    pad = dp - d
    qg = jnp.pad(q, ((0, 0), (0, 0), (0, pad))).reshape(b, hkv, g, dp)
    kp = jnp.pad(k_pool, ((0, 0), (0, 0), (0, 0), (0, pad)))
    vp = jnp.pad(v_pool, ((0, 0), (0, 0), (0, 0), (0, pad)))
    o = paged_attention_grouped(qg, kp, vp, block_tables.astype(jnp.int32),
                                lengths.astype(jnp.int32),
                                scale=1.0 / (d ** 0.5), interpret=interpret)
    return o.reshape(b, h, dp)[..., :d]
