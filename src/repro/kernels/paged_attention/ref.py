"""Pure-jnp oracle for paged decode attention over block-pool KV pages."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pool, v_pool, block_tables, lengths):
    """q: [B, H, D] one decode token per request.
    k_pool/v_pool: [N_pages, page, Hkv, D] (the §V block pool's data arrays).
    block_tables: [B, P] int32 page ids (-1 pad); lengths: [B] int32.
    Returns [B, H, D] f32."""
    b, h, d = q.shape
    n_pages, page, hkv, _ = k_pool.shape
    p = block_tables.shape[1]
    g = h // hkv
    safe = jnp.maximum(block_tables, 0)
    k = k_pool[safe]                              # [B, P, page, Hkv, D]
    v = v_pool[safe]
    k = k.reshape(b, p * page, hkv, d)
    v = v.reshape(b, p * page, hkv, d)
    pos = jnp.arange(p * page)[None, :]
    valid = (pos < lengths[:, None]) & (block_tables >= 0).repeat(page, axis=1)
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    scores = scores / (d ** 0.5)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return o.reshape(b, h, d)
