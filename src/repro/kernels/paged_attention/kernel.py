"""Paged decode attention — Pallas TPU kernel over block-pool KV pages.

This kernel is where the paper's §V memory manager meets the MXU: KV pages
are pool blocks (page = the locality unit = one VMEM tile), the block table
is the per-request page list, and the kernel walks it with SCALAR PREFETCH —
the block-table entry selects which pool page the next grid step DMAs into
VMEM (pl.BlockSpec index_map reads the prefetched table). Online softmax
accumulates across pages in VMEM scratch; page boundaries never touch HBM
twice. Pages whose table entry is -1 (unallocated — the pool's free side)
are skipped entirely via pl.when, so ragged request lengths cost no DMA.

Grid: (B, Hkv, n_pages_per_req)  — arbitrary (sequential) page axis.
q for a kv-head group is [group, D] — small; lives in VMEM whole.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_kernel(tables_ref, lengths_ref,            # scalar-prefetch operands
               q_ref, k_ref, v_ref, o_ref,
               acc_ref, m_ref, l_ref, *, page: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    page_id = tables_ref[b, j]
    live = (page_id >= 0) & (j * page < lengths_ref[b])

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)            # [page, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ki = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(ki < lengths_ref[b], s, NEG_INF)    # ragged tail
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == np_ - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_attention_grouped(q, k_pool, v_pool, block_tables, lengths, *,
                            scale: float | None = None, interpret: bool = True):
    """q: [B, Hkv, G, D]; pools: [N, page, Hkv, D]; tables: [B, P]; -> [B, Hkv, G, D].

    Pass `scale` when D was padded (the true head dim's rsqrt)."""
    b, hkv, g, d = q.shape
    n, page, _, _ = k_pool.shape
    p = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    kernel = functools.partial(_pa_kernel, page=page, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, p),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bb, h, j, T, L: (bb, h, 0, 0)),
            # the §V pool page selected by the prefetched block table:
            pl.BlockSpec((1, page, 1, d),
                         lambda bb, h, j, T, L: (jnp.maximum(T[bb, j], 0), 0, h, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bb, h, j, T, L: (jnp.maximum(T[bb, j], 0), 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bb, h, j, T, L: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, q, k_pool, v_pool)
