"""Wrapper: TwoLevelSplitOrder state -> (hi, lo) planes -> batched Pallas
per-table searchsorted probe.

`twolevel_splitorder_probe` is the unjitted entry the `repro.store.exec`
dispatch layer calls from inside already-jitted store steps — the same
contract as `core.splitorder.twolevel_splitorder_find`: (found bool[K],
vals u64[K]). The bit-reversed-hash sort keys and the table routing both
compute on the u64 host path (TPU lanes have no u64); the kernel sees u32
planes and int32 table ids only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bits import KEY_INF, bitrev64, hash64
from repro.core.layout import split_u64
from repro.kernels.splitorder_probe.kernel import splitorder_probe_tiles


def _table_of(h, keys):
    # mirror of core.splitorder._table_of: route by the TOP hash bits
    t_bits = h.num_tables.bit_length() - 1
    if not t_bits:
        return jnp.zeros(keys.shape, jnp.int32)
    return (hash64(keys) >> jnp.uint64(64 - t_bits)).astype(jnp.int32)


def twolevel_splitorder_probe(h, keys, *, tile: int = 256,
                              interpret: bool = True):
    """Batched probe of a TwoLevelSplitOrder via the Pallas kernel — same
    contract as core.splitorder.twolevel_splitorder_find. Not jitted:
    callable from inside jitted/shard_mapped store steps."""
    t = keys.shape[0]
    pad = (-t) % tile
    kp = jnp.pad(keys, (0, pad), constant_values=KEY_INF)
    rkq = bitrev64(hash64(kp))
    tbl = _table_of(h, kp)
    qrh, qrl = split_u64(rkq)
    qkh, qkl = split_u64(kp)
    rh, rl = split_u64(h.rk)
    kh, kl = split_u64(h.keys)
    # named scope: visible as obs.kernel.splitorder_probe in jax.profiler
    # timelines / lowered HLO (span taxonomy in store/obs.py)
    with jax.named_scope("obs.kernel.splitorder_probe"):
        found, at = splitorder_probe_tiles(qrh, qrl, qkh, qkl, tbl, rh, rl,
                                           kh, kl, tile=tile,
                                           interpret=interpret)
    found = found[:t].astype(bool) & (keys != KEY_INF)
    at = at[:t]
    vals = jnp.where(found, h.vals[tbl[:t], at], jnp.uint64(0))
    return found, vals
