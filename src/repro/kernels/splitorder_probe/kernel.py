"""Two-level split-order probe — Pallas TPU kernel.

The split-order FIND is a searchsorted over keys ordered by bit-reversed
hash (`core.splitorder`). The ONE-level variant binary-searches one global
[C] array — too large for VMEM at production capacity, so it stays a jnp
probe in every exec mode (the same scattered-gather pathology the paper
measured in its one-level table VI). The TWO-level variant routes by the
top hash bits to one of T small tables first (the paper's NUMA
partitioning), so each probe touches ONE [C2] row — the whole [T, C2]
plane stack fits VMEM via whole-array BlockSpecs, and this kernel is the
per-table searchsorted over it.

TPU mapping:
  * queries tile [T] per grid step; the bit-reversed-hash sort key and the
    original key both travel as (hi, lo) u32 planes (`core.layout.
    split_u64`); table ids arrive precomputed as int32 (the splitmix64
    scramble runs on the u64 host path).
  * the binary search is log2(C2) steps of 1D dynamic gathers over the
    flattened planes (flat index = table * C2 + mid), `key_lt` compares —
    `searchsorted(..., side="left")` semantics, bit-identical to the jnp
    reference by construction.
  * the rk-collision window scan (WINDOW entries from the insertion point,
    matching `core.splitorder._window_match`) resolves 64-bit hash
    collisions; outputs are (found i8[T], at i32[T]) and the u64 value
    gather happens outside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.layout import key_lt as _lt

WINDOW = 4  # rk-collision scan width — MUST match core.splitorder._WINDOW


def table_search(qrh, qrl, qkh, qkl, tbl, rk_hi, rk_lo, key_hi, key_lo, *,
                 window: int = WINDOW):
    """The in-kernel per-table searchsorted + window match body. rk_*/key_*
    are [T_tables, C2] planes; returns (found bool[T], at i32[T]) with the
    reference's clipping conventions."""
    t = qrh.shape[0]
    n_tables, c2 = rk_hi.shape
    frh, frl = rk_hi.reshape(-1), rk_lo.reshape(-1)
    fkh, fkl = key_hi.reshape(-1), key_lo.reshape(-1)
    base = jnp.clip(tbl, 0, n_tables - 1) * c2

    lo = jnp.zeros((t,), jnp.int32)
    hi = jnp.full((t,), c2, jnp.int32)
    for _ in range(max(c2.bit_length(), 1)):
        cont = lo < hi
        mid = (lo + hi) // 2
        flat = base + jnp.clip(mid, 0, c2 - 1)
        less = _lt(jnp.take(frh, flat, axis=0), jnp.take(frl, flat, axis=0),
                   qrh, qrl)                     # rk[tbl, mid] < rk_q
        lo = jnp.where(cont & less, mid + 1, lo)
        hi = jnp.where(cont & ~less, mid, hi)
    pos = lo

    found = jnp.zeros((t,), bool)
    off = jnp.zeros((t,), jnp.int32)
    for w in range(window):
        iw = base + jnp.clip(pos + w, 0, c2 - 1)
        hit = (jnp.take(frh, iw, axis=0) == qrh) \
            & (jnp.take(frl, iw, axis=0) == qrl) \
            & (jnp.take(fkh, iw, axis=0) == qkh) \
            & (jnp.take(fkl, iw, axis=0) == qkl)
        off = jnp.where(hit & ~found, w, off)    # first-match, like argmax
        found = found | hit
    return found, jnp.clip(pos + off, 0, c2 - 1)


def _so_kernel(qrh_ref, qrl_ref, qkh_ref, qkl_ref, tbl_ref, rh_ref, rl_ref,
               kh_ref, kl_ref, found_ref, at_ref, *, window: int):
    found, at = table_search(qrh_ref[...], qrl_ref[...], qkh_ref[...],
                             qkl_ref[...], tbl_ref[...], rh_ref[...],
                             rl_ref[...], kh_ref[...], kl_ref[...],
                             window=window)
    found_ref[...] = found.astype(jnp.int8)
    at_ref[...] = at


def splitorder_probe_tiles(q_rk_hi, q_rk_lo, q_key_hi, q_key_lo, tables,
                           rk_hi, rk_lo, key_hi, key_lo, *, tile: int = 256,
                           interpret: bool = True):
    """q_*: [T] u32; tables: [T] i32; rk_*/key_*: [T_tables, C2] u32.
    Returns (found i8[T], at i32[T])."""
    t = q_rk_hi.shape[0]
    if t == 0:   # empty batch: same contract as the jnp reference
        return (jnp.zeros((0,), jnp.int8), jnp.zeros((0,), jnp.int32))
    tile = min(tile, t)
    assert t % tile == 0
    grid = (t // tile,)
    whole = lambda a: pl.BlockSpec(a.shape, lambda g: (0,) * a.ndim)
    qspec = pl.BlockSpec((tile,), lambda g: (g,))
    kernel = functools.partial(_so_kernel, window=WINDOW)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[qspec] * 5 + [whole(a) for a in
                                (rk_hi, rk_lo, key_hi, key_lo)],
        out_specs=[pl.BlockSpec((tile,), lambda g: (g,)),
                   pl.BlockSpec((tile,), lambda g: (g,))],
        out_shape=[jax.ShapeDtypeStruct((t,), jnp.int8),
                   jax.ShapeDtypeStruct((t,), jnp.int32)],
        interpret=interpret,
    )(q_rk_hi, q_rk_lo, q_key_hi, q_key_lo, tables, rk_hi, rk_lo,
      key_hi, key_lo)
