"""Pure-jnp oracle for the per-table searchsorted probe, over the same
(hi, lo) plane layout the kernel consumes. The production jnp reference is
`core.splitorder.twolevel_splitorder_find` (u64 arrays); this oracle
exists so the kernel's plane-level compare/window logic can be tested in
isolation, like `kernels.hash_probe.ref`."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.layout import key_lt


def splitorder_probe_ref(q_rk_hi, q_rk_lo, q_key_hi, q_key_lo, tables,
                         rk_hi, rk_lo, key_hi, key_lo, window: int = 4):
    """Same contract as kernel.splitorder_probe_tiles, bool found."""
    t = q_rk_hi.shape[0]
    n_tables, c2 = rk_hi.shape
    tbl = jnp.clip(tables, 0, n_tables - 1)
    rows_rh, rows_rl = rk_hi[tbl], rk_lo[tbl]            # [T, C2]
    ge = ~key_lt(rows_rh, rows_rl, q_rk_hi[:, None], q_rk_lo[:, None])
    pos = jnp.where(jnp.any(ge, axis=1), jnp.argmax(ge, axis=1), c2)
    pos = pos.astype(jnp.int32)                          # searchsorted left
    idx = jnp.clip(pos[:, None] + jnp.arange(window, dtype=jnp.int32),
                   0, c2 - 1)
    rows = jnp.arange(t)[:, None]
    hit = (rows_rh[rows, idx] == q_rk_hi[:, None]) \
        & (rows_rl[rows, idx] == q_rk_lo[:, None]) \
        & (key_hi[tbl[:, None], idx] == q_key_hi[:, None]) \
        & (key_lo[tbl[:, None], idx] == q_key_lo[:, None])
    found = jnp.any(hit, axis=1)
    at = jnp.clip(pos + jnp.argmax(hit, axis=1).astype(jnp.int32), 0, c2 - 1)
    return found, at
