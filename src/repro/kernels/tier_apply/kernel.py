"""Fused tier-apply — membership probes + the hot-insert prologue in ONE
Pallas dispatch.

PR 5 fused the tier stack's FIND chain; the write half still ran as
separate phases: a membership probe dispatch, then the
`bucket_insert_plan` sort prologue, victim selection, and scatters as jnp
phases. This kernel folds the whole apply prologue into the fused find's
launch: per plan, ONE `pallas_call` probes all three tiers for residency
(hot bucket probe, warm level walk, per-run spill binary search), applies
the miss fall-through, and — for the lanes that should try the hot tier —
runs the insert linearization (in-batch dup rank, pre-batch existence,
within-slot candidate rank, nth-empty placement column) plus the eviction
policy's victim selection over the metadata plane. The u64 scatters and
victim gathers commit in the glue (`ops.py`) where u64 lanes exist.

Shared bodies, not copies: the hot probe is
`kernels.hash_probe.kernel.bucket_probe`, the warm walk is
`kernels.skiplist_search.kernel.level_walk` (or
`kernels.bskiplist_walk.kernel.block_walk` when the stack selected the
block-major warm layout — no child plane in that case) — the same
functions the fused find uses. The lane math mirrors `core.hashtable.bucket_insert_plan` /
`kernels.tier_apply.ref.hot_insert_evict` term by term over (hi, lo) u32
planes, so fused/unfused bit-identity is by construction.

Scalar-prefetched spill probes (`pltpu.PrefetchScalarGridSpec`): the
`run_offsets` boundary plane and the eviction cap arrive as SMEM scalars
BEFORE the grid runs, and the grid iterates over fixed-size CHUNKS of the
spill key/tombstone planes — each step binary-searches every run's
intersection with its chunk and accumulates hits in VMEM scratch (the
sequential TPU grid keeps scratch live across steps). The spill tier
therefore never needs to be VMEM-resident as a whole: chunks stream
through, which is the unlock for HBM/host-resident spill tiers of millions
of keys. All query-plane work (membership compose + insert prologue) is
predicated onto the LAST grid step.

Victim selection without an in-kernel argsort: the reference takes entry
`clip(ev_rank, 0, B-1)` of a stable argsort over the policy score row.
Stable-sort position of column j is `#{k: (score_k, k) <lex (score_j, j)}`
— a counting rank, computed here with a static loop over the B bucket
columns (B is a small static width; positions are distinct, so exactly one
column matches each target rank). Provably equal to the argsort take.

Outputs (all [K], sorted (slot, key) lane order; i8 flags / i32 columns):
in_warm, in_spill, placed, exists, dup, need_ev, col, vcol, ecol.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.layout import BSKIP_BLOCK, key_lt as _lt
from repro.kernels.bskiplist_walk.kernel import block_walk
from repro.kernels.hash_probe.kernel import bucket_probe
from repro.kernels.skiplist_search.kernel import level_walk

# numpy scalar, not a jnp array: pallas_call rejects closure-captured
# jax-array constants, while numpy scalars inline as literals at trace time
_U32MAX = np.uint32(0xFFFFFFFF)


def spill_chunk_probe(qh, ql, sp_hi, sp_lo, sp_dead, off, cbase, *,
                      max_runs: int, chunk: int):
    """One chunk's contribution to the cold-tier membership probe: binary
    search each run's intersection with the chunk window
    [cbase, cbase + chunk) — `sp_*` are the CHUNK blocks, indexed locally.
    Run keys strictly increase, so a query's match position lies in exactly
    one chunk: windows that don't contain it converge to a boundary or a
    different key and stay dead. ORing the per-chunk results over the grid
    reproduces `kernels.tier_find.kernel.spill_run_probe`'s found bit
    exactly. Returns found bool[T] for this chunk."""
    t = qh.shape[0]
    r = max_runs
    cend = cbase + chunk
    lo = jnp.broadcast_to(jnp.clip(off[:r], cbase, cend)[None, :],
                          (t, r)).astype(jnp.int32)
    end = jnp.broadcast_to(jnp.clip(off[1:r + 1], cbase, cend)[None, :],
                           (t, r)).astype(jnp.int32)
    hi = end
    qh2, ql2 = qh[:, None], ql[:, None]
    for _ in range(max(chunk.bit_length(), 1)):
        cont = lo < hi
        mid = (lo + hi) // 2
        lmid = jnp.clip(mid - cbase, 0, chunk - 1)
        mh = jnp.take(sp_hi, lmid.reshape(-1), axis=0).reshape(t, r)
        ml = jnp.take(sp_lo, lmid.reshape(-1), axis=0).reshape(t, r)
        less = _lt(mh, ml, qh2, ql2)            # sp[mid] < q
        lo = jnp.where(cont & less, mid + 1, lo)
        hi = jnp.where(cont & ~less, mid, hi)
    lpos = jnp.clip(lo - cbase, 0, chunk - 1)
    p_hi = jnp.take(sp_hi, lpos.reshape(-1), axis=0).reshape(t, r)
    p_lo = jnp.take(sp_lo, lpos.reshape(-1), axis=0).reshape(t, r)
    p_dead = jnp.take(sp_dead, lpos.reshape(-1), axis=0).reshape(t, r)
    live = (lo < end) & (p_hi == qh2) & (p_lo == ql2) & (p_dead == 0)
    return jnp.any(live, axis=1)


def _ta_kernel(*refs, levels: int, fanout: int, policy: str,
               warm_blocked: bool, block: int, has_spill: bool,
               max_runs: int, chunk: int, n_chunks: int):
    if has_spill:
        off_ref, me_ref = refs[0], refs[1]
        i = 2
    else:
        me_ref = refs[0]
        i = 1
    (skh_ref, skl_ref, ss_ref, sm_ref, krs_ref, srs_ref,
     kh_ref, kl_ref, meta_ref, lh_ref, ll_ref) = refs[i:i + 11]
    i += 11
    if warm_blocked:    # block-major warm planes carry no child plane
        lc_ref = None
    else:
        lc_ref = refs[i]
        i += 1
    th_ref, tl_ref, tm_ref = refs[i:i + 3]
    i += 3
    if has_spill:
        sph_ref, spl_ref, spd_ref = refs[i:i + 3]
        i += 3
        outs = refs[i:i + 9]
        acc_ref = refs[i + 9]
    else:
        outs = refs[i:i + 9]
        acc_ref = None

    skh = skh_ref[...]
    skl = skl_ref[...]
    smb = sm_ref[...] != 0
    k = skh.shape[0]
    # membership queries: masked-off lanes probe with the KEY_INF sentinel,
    # the dispatch layer's `where(mask, keys, KEY_INF)` in u32 planes
    mqh = jnp.where(smb, skh, _U32MAX)
    mql = jnp.where(smb, skl, _U32MAX)

    if has_spill:
        c = pl.program_id(0)

        @pl.when(c == 0)
        def _zero_acc():
            acc_ref[...] = jnp.zeros((k,), jnp.int32)

        off = jnp.stack([off_ref[i] for i in range(max_runs + 1)])
        hit = spill_chunk_probe(mqh, mql, sph_ref[...], spl_ref[...],
                                spd_ref[...], off, c * chunk,
                                max_runs=max_runs, chunk=chunk)
        acc_ref[...] = acc_ref[...] | hit.astype(jnp.int32)

    @pl.when(pl.program_id(0) == n_chunks - 1)
    def _apply_prologue():
        ss = ss_ref[...]
        b = kh_ref.shape[1]
        m = kh_ref.shape[0]

        # membership compose + fall-through (the exec.tier_find contract)
        hot_any, _ = bucket_probe(mqh, mql, ss, kh_ref[...], kl_ref[...])
        f_hot = hot_any & smb
        if warm_blocked:
            warm_found, _ = block_walk(mqh, mql, lh_ref[...], ll_ref[...],
                                       th_ref[...], tl_ref[...],
                                       tm_ref[...], levels=levels,
                                       block=block)
        else:
            warm_found, _ = level_walk(mqh, mql, lh_ref[...], ll_ref[...],
                                       lc_ref[...], th_ref[...],
                                       tl_ref[...], tm_ref[...],
                                       levels=levels, fanout=fanout)
        f_warm = warm_found & smb
        if has_spill:
            f_sp = (acc_ref[...] != 0) & smb
        else:
            f_sp = jnp.zeros((k,), bool)
        in_warm = f_warm & ~f_hot
        in_spill = f_sp & ~f_hot & ~f_warm

        # insert mask after membership: lanes resident below never try hot
        sm_ins = smb & ~in_warm & ~in_spill
        smi = sm_ins.astype(jnp.int32)

        # in-batch duplicate: lane rank within its (slot, key) run — the
        # `core.bits.dup_in_run` formula with host-precomputed run starts
        krs = krs_ref[...]
        c1 = jnp.cumsum(smi)
        before_k = jnp.take(c1, krs, axis=0) - jnp.take(smi, krs, axis=0)
        dup = sm_ins & ((c1 - smi - before_k) > 0)

        # pre-batch bucket rows: one gather serves existence, the empty
        # scan, and the victim metadata below
        ssc = jnp.clip(ss, 0, m - 1)
        rows_h = jnp.take(kh_ref[...], ssc, axis=0)
        rows_l = jnp.take(kl_ref[...], ssc, axis=0)
        hit_e = (rows_h == skh[:, None]) & (rows_l == skl[:, None])
        ecol = jnp.argmax(hit_e, axis=1).astype(jnp.int32)
        exists = sm_ins & jnp.any(hit_e, axis=1) & ~dup
        cand = sm_ins & ~dup & ~exists

        # within-slot candidate rank (`core.hashtable._seg_rank`)
        srs = srs_ref[...]
        ci = cand.astype(jnp.int32)
        c2 = jnp.cumsum(ci)
        before_s = jnp.where(
            srs > 0, jnp.take(c2, jnp.maximum(srs - 1, 0), axis=0), 0)
        rank = c2 - before_s - ci

        # nth-empty placement column (`core.hashtable._nth_empty`)
        empty = (rows_h == _U32MAX) & (rows_l == _U32MAX)
        cum_e = jnp.cumsum(empty.astype(jnp.int32), axis=1)
        hit_n = empty & (cum_e == rank[:, None] + 1)
        fit_e = jnp.any(hit_n, axis=1)
        col_e = jnp.where(fit_e,
                          jnp.argmax(hit_n, axis=1).astype(jnp.int32), b)

        if policy != "none":
            # victim selection: counting rank over the policy score row
            # (see module docstring) — no in-kernel argsort needed
            metar = jnp.take(meta_ref[...], ssc, axis=0)
            n_empty = jnp.sum(empty.astype(jnp.int32), axis=1)
            ev_rank = rank - n_empty
            score = metar if policy == "lru" else -metar
            score = jnp.where(~empty, score, jnp.iinfo(jnp.int32).max)
            tgt = jnp.clip(ev_rank, 0, b - 1)
            iota_b = jax.lax.broadcasted_iota(jnp.int32, (k, b), 1)
            vcol = jnp.zeros((k,), jnp.int32)
            for j in range(b):
                sj = score[:, j:j + 1]
                less_j = (score < sj) | ((score == sj) & (iota_b < j))
                pos_j = jnp.sum(less_j.astype(jnp.int32), axis=1)
                vcol = jnp.where(pos_j == tgt, jnp.int32(j), vcol)
            need_ev = cand & ~fit_e & (ev_rank < b - n_empty)
            need_ev = need_ev & ((jnp.cumsum(need_ev.astype(jnp.int32)) - 1)
                                 < me_ref[0])
        else:
            vcol = jnp.zeros((k,), jnp.int32)
            need_ev = jnp.zeros((k,), bool)

        placed = (cand & fit_e) | need_ev
        col = jnp.where(fit_e, col_e, vcol)

        outs[0][...] = in_warm.astype(jnp.int8)
        outs[1][...] = in_spill.astype(jnp.int8)
        outs[2][...] = placed.astype(jnp.int8)
        outs[3][...] = exists.astype(jnp.int8)
        outs[4][...] = dup.astype(jnp.int8)
        outs[5][...] = need_ev.astype(jnp.int8)
        outs[6][...] = col
        outs[7][...] = vcol
        outs[8][...] = ecol


def tier_apply_tiles(sk_hi, sk_lo, slots, sm, krs, srs, key_hi, key_lo,
                     meta, lvl_hi, lvl_lo, lvl_child, term_hi, term_lo,
                     term_mark, max_evict, sp_hi=None, sp_lo=None,
                     sp_dead=None, run_off=None, *, policy: str,
                     block: int = BSKIP_BLOCK, spill_chunk: int = 512,
                     interpret: bool = True):
    """sk_*: [K] u32 keys in sorted (slot, key) lane order; slots/krs/srs:
    [K] i32 (slot per lane, key-run starts, slot-run starts); sm: [K] i8
    insert mask; key_*/meta: [M, B]; lvl_*: [L, C1]; term_*: [C];
    max_evict: [1] i32 (scalar-prefetched); sp_* [S] + run_off [R+1] i32
    (scalar-prefetched) or None for a 2-tier stack. Returns the 9 outputs
    listed in the module docstring. `lvl_child=None` selects the BLOCKED
    warm walk: lvl_* then carry the `bskiplist_layout` [L, W] fat-node
    rows and term_* its [NB*block] padded terminal planes."""
    k = sk_hi.shape[0]
    L = lvl_hi.shape[0]
    warm_blocked = lvl_child is None
    has_spill = sp_hi is not None
    tensors = [sk_hi, sk_lo, slots, sm, krs, srs, key_hi, key_lo, meta,
               lvl_hi, lvl_lo]
    if not warm_blocked:
        tensors.append(lvl_child)
    tensors += [term_hi, term_lo, term_mark]
    whole = lambda a: pl.BlockSpec(a.shape, lambda g, *_: (0,) * a.ndim)
    in_specs = [whole(a) for a in tensors]
    scalars = [max_evict]
    scratch = []
    max_runs = 0
    if has_spill:
        s = sp_hi.shape[0]
        chunk = min(spill_chunk, s)
        # pad the spill planes to whole chunks; padded cells sit past every
        # run boundary (off <= n <= S), so no window ever reaches them
        pad = (-s) % chunk
        if pad:
            sp_hi = jnp.pad(sp_hi, (0, pad), constant_values=0xFFFFFFFF)
            sp_lo = jnp.pad(sp_lo, (0, pad), constant_values=0xFFFFFFFF)
            sp_dead = jnp.pad(sp_dead, (0, pad), constant_values=1)
        n_chunks = (s + pad) // chunk
        scalars = [run_off, max_evict]
        tensors += [sp_hi, sp_lo, sp_dead]
        cspec = pl.BlockSpec((chunk,), lambda g, *_: (g,))
        in_specs += [cspec, cspec, cspec]
        scratch = [pltpu.VMEM((k,), jnp.int32)]
        max_runs = run_off.shape[0] - 1
    else:
        chunk = 0
        n_chunks = 1

    out_dtypes = [jnp.int8] * 6 + [jnp.int32] * 3
    kernel = functools.partial(_ta_kernel, levels=L, fanout=4,
                               policy=policy, warm_blocked=warm_blocked,
                               block=block, has_spill=has_spill,
                               max_runs=max_runs, chunk=chunk,
                               n_chunks=n_chunks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalars),
        grid=(n_chunks,),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((k,), lambda g, *_: (0,))] * 9,
        scratch_shapes=scratch)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((k,), d) for d in out_dtypes],
        interpret=interpret,
    )(*scalars, *tensors)
