"""Pure-jnp oracle for the fused tier apply.

Two pieces, both shared with the unfused write path so fused/unfused parity
is by construction rather than by test luck:

* `hot_insert_evict` — the policy-driven hot-tier insert (empties first,
  then victims in policy order, eviction capped at the lower tiers' free
  headroom). This IS the unfused path: `store.exec.hot_update` calls it
  directly, and the fused kernel replicates its lane math (same
  `core.hashtable.bucket_insert_plan` linearization, same victim ranking)
  over the (hi, lo) u32 planes.
* `tier_apply_ref` — the whole fused-apply prologue in jnp: lower-tier
  membership via `kernels.tier_find.ref.tier_find_ref` with the SAME miss
  fall-through masking as `store.exec.tier_find`, then the hot insert
  under the policy. What `store.exec.tier_apply` runs in jnp mode.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import hashtable as ht
from repro.core.bits import EMPTY, KEY_INF
from repro.core.layout import val_weight
from repro.kernels.tier_find.ref import tier_find_ref


def hot_insert_evict(hot: ht.FixedHash, meta, clock, keys, vals, mask,
                     policy: str, max_evict):
    """Insert-if-absent into the hot tier, evicting policy victims from
    full buckets instead of refusing placement. Victims come from the
    PRE-batch bucket contents (a key placed this batch is never its own
    batch's victim); empties fill first, then victims in policy order, and
    lanes beyond bucket width fall through (placed=False). At most
    `max_evict` lanes evict: the caller passes the lower tiers' free
    headroom, so a displaced victim ALWAYS has somewhere to land —
    eviction must never turn into key loss. Lanes past the cap fall
    through like any unplaced lane and report their own success honestly.
    Returns (hot', meta', placed[K], existed[K], ev_key[K], ev_val[K],
    ev_mask[K]) where lane i's ev_* carry the victim its placement
    displaced."""
    K = keys.shape[0]
    M, B = hot.num_slots, hot.bucket
    if mask is None:
        mask = jnp.ones((K,), bool)
    p = ht.bucket_insert_plan(hot, keys, vals, mask)  # the SHARED prologue
    vrows = hot.vals[p.ss]
    metar = meta[p.ss]

    # victims: pre-batch entries ordered by the policy's evict-first score
    # (lru: oldest stamp first; size: largest payload first; ties by column)
    nonempty = p.rows != EMPTY
    n_empty = jnp.sum(p.rows == EMPTY, axis=1).astype(jnp.int32)
    ev_rank = p.rank - n_empty
    score = metar if policy == "lru" else -metar
    score = jnp.where(nonempty, score, jnp.iinfo(jnp.int32).max)
    vorder = jnp.argsort(score, axis=1, stable=True)  # [K, B]
    vcol = jnp.take_along_axis(
        vorder, jnp.clip(ev_rank, 0, B - 1)[:, None], axis=1)[:, 0]
    vcol = vcol.astype(jnp.int32)
    need_ev = p.cand & ~p.fit_e & (ev_rank < jnp.sum(nonempty, axis=1))
    need_ev = need_ev & (jnp.cumsum(need_ev.astype(jnp.int32)) - 1
                         < max_evict)
    ev_key = jnp.take_along_axis(p.rows, vcol[:, None], axis=1)[:, 0]
    ev_val = jnp.take_along_axis(vrows, vcol[:, None], axis=1)[:, 0]

    placed = (p.cand & p.fit_e) | need_ev
    col = jnp.where(p.fit_e, p.col_e, vcol)
    flat = jnp.where(placed, p.ss * B + col, M * B)
    nk = hot.keys.reshape(-1).at[flat].set(p.sk, mode="drop").reshape(M, B)
    nv = hot.vals.reshape(-1).at[flat].set(p.sv, mode="drop").reshape(M, B)
    stamp = (jnp.broadcast_to(clock, (K,)).astype(jnp.int32)
             if policy == "lru" else val_weight(p.sv))
    nm = meta.reshape(-1).at[flat].set(stamp, mode="drop").reshape(M, B)
    if policy == "lru":
        # an INSERT that finds its key already hot-resident is a touch too:
        # refresh that cell's stamp so upsert traffic keeps an entry warm
        ecol = jnp.argmax(p.rows == p.sk[:, None], axis=1).astype(jnp.int32)
        eflat = jnp.where(p.exists, p.ss * B + ecol, M * B)
        nm = nm.reshape(-1).at[eflat].set(stamp, mode="drop").reshape(M, B)
    hot2 = ht.FixedHash(keys=nk, vals=nv,
                        count=hot.count
                        + jnp.sum(p.cand & p.fit_e).astype(jnp.int64))
    return (hot2, nm, placed[p.inv], (p.exists | p.dup)[p.inv],
            ev_key[p.inv], ev_val[p.inv], need_ev[p.inv])


def tier_apply_ref(hot, meta, clock, cold, spill, keys, vals, mask,
                   policy: str, max_evict, warm_layout: str = "level"):
    """The fused-apply prologue in jnp: lower-tier membership (with the
    dispatch layer's fall-through masking) + the policy-driven hot insert.
    Returns (hot', meta', in_warm[K], in_spill[K], ins[K], exists[K],
    ev_key[K], ev_val[K], ev_mask[K]) — see `store.exec.tier_apply` for
    the contract; `spill=None` (2-tier stacks) yields all-miss spill
    lanes, `policy == "none"` all-miss eviction lanes. `warm_layout`
    selects the warm membership walk (level-major or blocked B-skiplist —
    same hits either way)."""
    K = keys.shape[0]
    if K == 0:    # degenerate plan: no lanes, state unchanged
        z64 = jnp.zeros((0,), jnp.uint64)
        zb = jnp.zeros((0,), bool)
        return hot, meta, zb, zb, zb, zb, z64, z64, zb
    qk = jnp.where(mask, keys, KEY_INF)
    (f_hot, _, _), (f_warm, _), (f_sp, _) = tier_find_ref(
        hot, cold, spill, qk, warm_layout=warm_layout)
    # the exec.tier_find fall-through contract, verbatim: a warm hit only
    # counts on a hot miss, a spill hit only on a hot+warm miss
    in_warm = f_warm & ~f_hot
    in_spill = f_sp & ~f_hot & ~f_warm
    try_hot = mask & ~in_warm & ~in_spill
    if policy == "none":
        hot2, ins, exists = ht.fixed_insert(hot, keys, vals, try_hot)
        z64 = jnp.zeros((K,), jnp.uint64)
        return (hot2, meta, in_warm, in_spill, ins, exists,
                z64, z64, jnp.zeros((K,), bool))
    (hot2, meta2, ins, exists, ev_k, ev_v, ev_m) = hot_insert_evict(
        hot, meta, clock, keys, vals, try_hot, policy, max_evict)
    return hot2, meta2, in_warm, in_spill, ins, exists, ev_k, ev_v, ev_m
