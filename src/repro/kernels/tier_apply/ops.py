"""Wrapper: tier-stack state -> shared layouts -> ONE fused apply dispatch.

`tier_apply_fused` is the unjitted entry `store.exec.tier_apply` calls
from inside already-jitted store steps. The host side owns everything u64
and everything sort-shaped: the (slot, key) lane sort and its run-start
planes (mask-INDEPENDENT — `core.hashtable._batch_plan` sorts unmasked
keys, which is what lets them be precomputed before the kernel decides the
membership mask), the u64 victim gathers, and the key/value/metadata
scatters. The kernel returns flags and columns only. The scatter formulas
are copied term for term from `kernels.tier_apply.ref.hot_insert_evict` /
`core.hashtable.fixed_insert`, so the fused path's state updates are
bit-identical to the unfused references by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashtable as ht
from repro.core.bits import EMPTY
from repro.core.layout import (bskiplist_layout, bucket_layout, hash_slot,
                               skiplist_layout, spill_layout, split_u64,
                               val_weight)
from repro.kernels.tier_apply.kernel import tier_apply_tiles


def tier_apply_fused(hot, meta, clock, cold, spill, keys, vals, mask,
                     policy: str, max_evict, *, warm_layout: str = "level",
                     spill_chunk: int = 512, interpret: bool = True):
    """One dispatch over the whole apply prologue. `hot` is a FixedHash
    (+ its [M, B] i32 `meta` plane and the batch `clock`), `cold` a
    DetSkiplist, `spill` a SpillTier or None. Returns the same 9-tuple as
    `kernels.tier_apply.ref.tier_apply_ref`. `warm_layout="block"` runs
    the in-kernel warm membership walk over the block-major B-skiplist
    planes — same flags, fewer walk steps."""
    K = keys.shape[0]
    M, B = hot.num_slots, hot.bucket
    if mask is None:
        mask = jnp.ones((K,), bool)
    if K == 0:   # empty batch: same contract as the jnp reference
        z64 = jnp.zeros((0,), jnp.uint64)
        zb = jnp.zeros((0,), bool)
        return hot, meta, zb, zb, zb, zb, z64, z64, zb

    m_eff = mask & (keys != EMPTY)
    slots = hash_slot(keys, M)
    order = ht._lex_sort_slots_keys(slots, keys)
    ss, sk, sv, sm = slots[order], keys[order], vals[order], m_eff[order]
    idx = jnp.arange(K, dtype=jnp.int32)
    inv = jnp.zeros((K,), jnp.int32).at[order].set(idx)
    same = jnp.concatenate([jnp.zeros((1,), bool),
                            (sk[1:] == sk[:-1]) & (ss[1:] == ss[:-1])])
    krs = jax.lax.associative_scan(jnp.maximum, jnp.where(~same, idx, -1))
    srs = jnp.searchsorted(ss, ss, side="left").astype(jnp.int32)

    skh, skl = split_u64(sk)
    blay = bucket_layout(hot.keys)
    if warm_layout == "block":
        wlay = bskiplist_layout(cold)
        warm_planes = (wlay.blk_hi, wlay.blk_lo, None,
                       wlay.term_hi, wlay.term_lo, wlay.term_mark)
    else:
        slay = skiplist_layout(cold)
        warm_planes = (slay.lvl_hi, slay.lvl_lo, slay.lvl_child,
                       slay.term_hi, slay.term_lo, slay.term_mark)
    args = (skh, skl, ss, sm.astype(jnp.int8), krs.astype(jnp.int32), srs,
            blay.key_hi, blay.key_lo, meta) + warm_planes + (
            jnp.asarray(max_evict, jnp.int32).reshape(1),)
    kw = {}
    if spill is not None:
        splay = spill_layout(spill.keys, spill.dead, spill.run_start,
                             spill.n)
        kw = dict(sp_hi=splay.key_hi, sp_lo=splay.key_lo,
                  sp_dead=splay.dead, run_off=splay.run_off)
    # named scope: visible as obs.kernel.tier_apply in jax.profiler
    # timelines / lowered HLO (span taxonomy in store/obs.py)
    with jax.named_scope("obs.kernel.tier_apply"):
        out = tier_apply_tiles(*args, **kw, policy=policy,
                               spill_chunk=spill_chunk,
                               interpret=interpret)
    in_warm = out[0].astype(bool)
    in_spill = out[1].astype(bool)
    placed = out[2].astype(bool)
    exists = out[3].astype(bool)
    dup = out[4].astype(bool)
    need_ev = out[5].astype(bool)
    col, vcol, ecol = out[6], out[7], out[8]

    # u64 victim gathers from the PRE-batch rows (a key placed this batch
    # is never its own batch's victim)
    if policy == "none":
        ev_key = jnp.zeros((K,), jnp.uint64)
        ev_val = jnp.zeros((K,), jnp.uint64)
    else:
        ev_key = hot.keys[ss, vcol]
        ev_val = hot.vals[ss, vcol]

    flat = jnp.where(placed, ss * B + col, M * B)
    nk = hot.keys.reshape(-1).at[flat].set(sk, mode="drop").reshape(M, B)
    nv = hot.vals.reshape(-1).at[flat].set(sv, mode="drop").reshape(M, B)
    nm = meta
    if policy != "none":
        stamp = (jnp.broadcast_to(clock, (K,)).astype(jnp.int32)
                 if policy == "lru" else val_weight(sv))
        nm = meta.reshape(-1).at[flat].set(stamp, mode="drop").reshape(M, B)
        if policy == "lru":
            # upsert traffic refreshes the resident cell's stamp
            eflat = jnp.where(exists, ss * B + ecol, M * B)
            nm = nm.reshape(-1).at[eflat].set(stamp,
                                              mode="drop").reshape(M, B)
    hot2 = ht.FixedHash(
        keys=nk, vals=nv,
        count=hot.count + jnp.sum(placed & ~need_ev).astype(jnp.int64))
    return (hot2, nm, in_warm[inv], in_spill[inv], placed[inv],
            (exists | dup)[inv], ev_key[inv], ev_val[inv], need_ev[inv])
