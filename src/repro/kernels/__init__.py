"""repro.kernels — Pallas TPU kernels for the compute hot spots.

Module map (each kernel is a package of three files — `kernel.py` the
Pallas body + pallas_call wrapper, `ref.py` a pure-jnp oracle over the SAME
layout, `ops.py` the state -> layout -> kernel adapter):

flash_attention   tiled softmax(QK^T)V with online renormalization
paged_attention   decode attention over block-paged KV cache pages
selective_scan    chunked SSM recurrence (Mamba-style selective scan)
skiplist_search   batched deterministic-skiplist FIND: the 1-2-3-4
                  criterion's fixed L-level, fan-out-4 walk over the
                  level-major layout (`core.layout.skiplist_layout`)
bskiplist_walk    batched B-skiplist FIND over the block-major layout
                  (`core.layout.bskiplist_layout`): 128-key lane-width fat
                  nodes, ONE whole-block `key_lt` compare + reduction per
                  descent step — same found/idx contract as
                  skiplist_search in ceil(log128 C) steps instead of the
                  fan-out-4 walk's num_levels
hash_probe        batched fixed-hash bucket probe over the bucket-major
                  layout (`core.layout.bucket_layout`) — the §IX hot-tier
                  fast path
pq_pop            batched priority-queue pop: live-prefix rank-select over
                  the terminal level + the shared skiplist_search
                  `level_walk` descent (the `pq` backend's POPMIN/POPK)
tier_find         fused tier-stack FIND: hot bucket probe + warm level
                  walk + per-run spill binary search in ONE dispatch,
                  bodies shared with hash_probe / skiplist_search
tier_apply        fused tier-stack APPLY prologue: the tier_find probes
                  + the hot-insert linearization and eviction-policy
                  victim selection in ONE dispatch, with the spill tier
                  streamed through VMEM chunks under a scalar-prefetched
                  `run_offsets` plane (`pltpu.PrefetchScalarGridSpec`)
splitorder_probe  two-level split-ordered hash probe (recursive-split
                  bucket directory + sorted-segment search)

The store kernels (skiplist_search, hash_probe) are never called directly
by backends: `repro.store.exec` dispatches between them and their jnp
references by execution mode (jnp | interpret | pallas), with bit-identical
results guaranteed by tests/test_exec_modes.py. All kernels validate in
interpret mode on CPU (tests/test_kernels.py); compiled mode targets TPU.

Add a kernel ONLY for a hot spot the paper itself optimizes; keep the
ref/ops/kernel split so the oracle and the layout adapter stay testable
without TPU hardware. See docs/store_layers.md for the layout/execution/
store layering.
"""
