"""jit'd wrapper: DetSkiplist state -> shared level-major layout
(`repro.core.layout.skiplist_layout`) -> batched Pallas pop rank-select.

`pq_pop_ranks` is the unjitted entry the `repro.store.exec` dispatch layer
calls from inside already-jitted store steps; it matches the contract of
`core.det_skiplist.pop_rank_select` bit for bit (same live-prefix formula,
same found/KEY_INF/idx=0 masking of not-found lanes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bits import KEY_INF
from repro.core.layout import skiplist_layout
from repro.kernels.pq_pop.kernel import pq_pop_tiles


def pq_pop_ranks(s, ranks, mask, *, tile: int = 256, interpret: bool = True):
    """Rank-select the rank-th smallest live key per lane on a DetSkiplist
    via the Pallas kernel — same contract as det_skiplist.pop_rank_select:
    (found bool[K], keys u64[K], idx int32[K]). Not jitted: callable from
    inside jitted/shard_mapped store steps."""
    t = ranks.shape[0]
    pad = (-t) % tile
    rp = jnp.pad(jnp.asarray(ranks, jnp.int32), (0, pad), constant_values=-1)
    mp = jnp.pad(jnp.asarray(mask, bool), (0, pad)).astype(jnp.int8)
    lay = skiplist_layout(s)
    # named scope: visible as obs.kernel.pq_pop in jax.profiler timelines /
    # lowered HLO (span taxonomy in store/obs.py)
    with jax.named_scope("obs.kernel.pq_pop"):
        found, idx = pq_pop_tiles(
            rp, mp, lay.lvl_hi, lay.lvl_lo, lay.lvl_child,
            lay.term_hi, lay.term_lo, lay.term_mark,
            tile=tile, interpret=interpret)
    found = found[:t].astype(bool)
    idx = jnp.where(found, jnp.clip(idx[:t], 0, s.capacity - 1), 0)
    keys = jnp.where(found, s.term_keys[idx], KEY_INF)
    return found, keys, idx
