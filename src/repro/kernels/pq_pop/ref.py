"""Pure-jnp oracle: priority-queue rank-select + descent over the stacked
level layout the kernel consumes (keys as u32 hi/lo pairs)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.skiplist_search.ref import skiplist_search_ref

_INF32 = jnp.uint32(0xFFFFFFFF)


def pq_pop_ref(ranks, mask, lvl_hi, lvl_lo, lvl_child, lvl_count,
               term_hi, term_lo, term_mark):
    """ranks i32[T], mask bool[T]; planes as in the kernel. Returns
    (found bool[T], idx int32[T]) — the layout-level reference the
    kernel is tested against."""
    live = (~term_mark.astype(bool)) & ~((term_hi == _INF32)
                                         & (term_lo == _INF32))
    prefix = jnp.cumsum(live.astype(jnp.int32))
    total = prefix[-1]
    want = ranks.astype(jnp.int32) + 1
    found = mask & (want >= 1) & (want <= total)
    hit = prefix[None, :] >= want[:, None]
    i = jnp.argmax(hit, axis=1).astype(jnp.int32)
    kh = jnp.where(found, term_hi[i], _INF32)
    kl = jnp.where(found, term_lo[i], _INF32)
    walked, idx = skiplist_search_ref(kh, kl, lvl_hi, lvl_lo, lvl_child,
                                      lvl_count, term_hi, term_lo, term_mark)
    found = found & walked
    return found, jnp.where(found, idx, 0)
