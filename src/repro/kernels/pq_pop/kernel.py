"""Batched priority-queue pop (rank-select + descent) — Pallas TPU kernel.

Pop-min on the deterministic skiplist is rank selection over the live
terminal prefix: the j-th pop lane of a plan extracts the j-th smallest
live key. The kernel computes the live-prefix cumsum (the SAME
live = unmarked & non-padding formula as `core.det_skiplist.range_query`),
rank-selects each lane's key with a first-true argmax (the Mosaic-safe
spelling of searchsorted-left over a monotone prefix), then feeds the
selected keys through the shared `skiplist_search.level_walk` descent so
the key -> terminal-index mapping has exactly one implementation across
FIND and POP.

Same layout contract as `kernels/skiplist_search`: level-major index stack
([L, C1] u32 x3) + flat terminal planes ([C] u32 hi/lo + i8 marks), all
VMEM-resident via whole-array BlockSpecs; ranks tile [T] per grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.skiplist_search.kernel import level_walk

# plain int (not a jnp scalar): pallas kernels cannot capture traced
# constants, and the weakly-typed literal folds into the comparisons
_INF32 = 0xFFFFFFFF


def rank_select(ranks, mask, term_hi, term_lo, term_mark):
    """The in-kernel rank-select body: live-prefix cumsum + first-true
    argmax. Returns (found bool[T], key_hi u32[T], key_lo u32[T]) — lanes
    whose rank exceeds the live population come back found=False with
    KEY_INF keys, so the downstream level walk cannot match them against a
    live entry."""
    t = ranks.shape[0]
    live = (term_mark == 0) & ~((term_hi == _INF32) & (term_lo == _INF32))
    prefix = jnp.cumsum(live.astype(jnp.int32))            # [C] inclusive
    total = prefix[-1]
    want = ranks.astype(jnp.int32) + 1
    found = (mask != 0) & (want >= 1) & (want <= total)
    # first index with prefix >= want (== searchsorted-left on a monotone
    # prefix); no true -> 0, which `found` already excludes
    hit = prefix[None, :] >= want[:, None]                  # [T, C]
    i = jnp.argmax(hit, axis=1).astype(jnp.int32)
    kh = jnp.where(found, jnp.take(term_hi, i, axis=0), _INF32)
    kl = jnp.where(found, jnp.take(term_lo, i, axis=0), _INF32)
    return found, kh, kl


def _pq_kernel(rk_ref, mk_ref, lh_ref, ll_ref, lc_ref, th_ref, tl_ref,
               tm_ref, found_ref, idx_ref, *, levels: int, fanout: int):
    th, tl, tm = th_ref[...], tl_ref[...], tm_ref[...]
    sel, kh, kl = rank_select(rk_ref[...], mk_ref[...], th, tl, tm)
    walked, i = level_walk(kh, kl, lh_ref[...], ll_ref[...], lc_ref[...],
                           th, tl, tm, levels=levels, fanout=fanout)
    found_ref[...] = (sel & walked).astype(jnp.int8)
    idx_ref[...] = jnp.where(sel & walked, i, 0)


def pq_pop_tiles(ranks, mask, lvl_hi, lvl_lo, lvl_child, term_hi, term_lo,
                 term_mark, *, tile: int = 256, interpret: bool = True):
    """ranks i32[T], mask i8[T]; lvl_*: [L, C1]; term_*: [C]. Returns
    (found i8[T], term idx i32[T])."""
    t = ranks.shape[0]
    L, _ = lvl_hi.shape
    if t == 0:   # empty batch: same contract as the jnp reference
        return (jnp.zeros((0,), jnp.int8), jnp.zeros((0,), jnp.int32))
    tile = min(tile, t)
    assert t % tile == 0
    grid = (t // tile,)
    whole = lambda a: pl.BlockSpec(a.shape, lambda g: (0,) * a.ndim)

    kernel = functools.partial(_pq_kernel, levels=L, fanout=4)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda g: (g,)),
            pl.BlockSpec((tile,), lambda g: (g,)),
            whole(lvl_hi), whole(lvl_lo), whole(lvl_child),
            whole(term_hi), whole(term_lo), whole(term_mark),
        ],
        out_specs=[pl.BlockSpec((tile,), lambda g: (g,)),
                   pl.BlockSpec((tile,), lambda g: (g,))],
        out_shape=[jax.ShapeDtypeStruct((t,), jnp.int8),
                   jax.ShapeDtypeStruct((t,), jnp.int32)],
        interpret=interpret,
    )(ranks, mask, lvl_hi, lvl_lo, lvl_child, term_hi, term_lo, term_mark)
