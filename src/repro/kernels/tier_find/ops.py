"""Wrapper: tier-stack state -> shared layouts (`core.layout.bucket_layout`
/ `skiplist_layout` / `spill_layout`) -> ONE fused Pallas dispatch.

`tier_find_fused` is the unjitted entry the `repro.store.exec` dispatch
layer calls from inside already-jitted store steps. Like every kernel
wrapper, the u64 value gathers happen out here (TPU lanes have no u64);
the kernel returns per-tier hit flags and gather indices only. Raw per-tier
results — the fall-through masking lives in `store.exec.tier_find`, shared
with the jnp reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bits import KEY_INF
from repro.core.layout import (bskiplist_layout, bucket_layout, hash_slot,
                               skiplist_layout, spill_layout, split_u64)
from repro.kernels.tier_find.kernel import tier_find_tiles


def tier_find_fused(hot, cold, spill, queries, *, warm_layout: str = "level",
                    tile: int = 256, interpret: bool = True):
    """One dispatch over the whole tier stack. `hot` is a FixedHash,
    `cold` a DetSkiplist, `spill` a SpillTier or None (2-tier stacks).
    Returns ((found, vals, col), (found, vals), (found, vals)) — the same
    raw per-tier contract as `kernels.tier_find.ref.tier_find_ref`.
    `warm_layout="block"` walks the warm tier through the block-major
    B-skiplist planes (`core.layout.bskiplist_layout`) instead of the
    level-major stack — same found/vals, fewer walk steps."""
    t = queries.shape[0]
    pad = (-t) % tile
    qp = jnp.pad(queries, (0, pad), constant_values=KEY_INF)
    qh, ql = split_u64(qp)
    slots = hash_slot(qp, hot.num_slots)
    blay = bucket_layout(hot.keys)
    if warm_layout == "block":
        wlay = bskiplist_layout(cold)
        warm_planes = (wlay.blk_hi, wlay.blk_lo, None,
                       wlay.term_hi, wlay.term_lo, wlay.term_mark)
    else:
        slay = skiplist_layout(cold)
        warm_planes = (slay.lvl_hi, slay.lvl_lo, slay.lvl_child,
                       slay.term_hi, slay.term_lo, slay.term_mark)
    args = (qh, ql, slots, blay.key_hi, blay.key_lo) + warm_planes
    if spill is not None:
        sp = spill_layout(spill.keys, spill.dead, spill.run_start, spill.n)
        args += (sp.key_hi, sp.key_lo, sp.dead, sp.run_off)
    # named scope: visible as obs.kernel.tier_find in jax.profiler
    # timelines / lowered HLO (span taxonomy in store/obs.py)
    with jax.named_scope("obs.kernel.tier_find"):
        out = tier_find_tiles(*args, tile=tile, interpret=interpret)

    valid = queries != KEY_INF
    f_hot = out[0][:t].astype(bool) & valid
    c_hot = out[1][:t]
    v_hot = jnp.where(f_hot, hot.vals[slots[:t], c_hot], jnp.uint64(0))
    f_warm = out[2][:t].astype(bool) & valid
    i_warm = jnp.clip(out[3][:t], 0, cold.capacity - 1)
    v_warm = jnp.where(f_warm, cold.term_vals[i_warm], jnp.uint64(0))
    if spill is not None:
        f_sp = out[4][:t].astype(bool) & valid
        i_sp = jnp.clip(out[5][:t], 0, spill.keys.shape[0] - 1)
        v_sp = jnp.where(f_sp, spill.vals[i_sp], jnp.uint64(0))
    else:
        f_sp = jnp.zeros((t,), bool)
        v_sp = jnp.zeros((t,), jnp.uint64)
    return (f_hot, v_hot, c_hot), (f_warm, v_warm), (f_sp, v_sp)
