"""Pure-jnp oracle for the fused tier find: the SAME per-tier probes the
unfused chain uses (`core.hashtable.fixed_find_cols`,
`core.det_skiplist.find_batch`) plus the per-run spill searchsorted —
which is also the jnp implementation behind `store.exec.spill_find`, so
all three exec modes share the O(runs * log run-len) cold-tier algorithm
instead of the old O(S) masked flat compare.

Returns RAW per-tier results (no fall-through masking): the dispatch layer
(`store.exec.tier_find`) applies the miss fall-through identically to the
kernel path and to this reference.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.bits import KEY_INF
from repro.core.layout import MAX_SPILL_RUNS, run_offsets


def spill_run_cells(keys, dead, run_start, n, queries,
                    max_runs: int = MAX_SPILL_RUNS):
    """Per-run binary-searched LIVE-cell lookup over the spill planes:
    (found[Q] bool, cell[Q] i32). Each sorted run [off[r], off[r+1]) is
    searched with `searchsorted`-left semantics; the first live match wins
    (at most one exists under single-tier residency — the tie-break keeps
    pathological states deterministic). O(runs * log run-len) per query
    against the old flat compare's O(S); bit-identical to it by
    construction. Shared by the membership probe (`spill_find_runs`) and
    the tombstone path (`store.tiers.spill_discard`), so the cold tier has
    ONE search algorithm. Cell of a miss is unspecified."""
    q = queries.shape[0]
    s = keys.shape[0]
    off = run_offsets(run_start, n, max_runs)
    lo = jnp.broadcast_to(off[:-1][None, :], (q, max_runs)).astype(jnp.int32)
    end = jnp.broadcast_to(off[1:][None, :], (q, max_runs)).astype(jnp.int32)
    hi = end
    for _ in range(max(s.bit_length(), 1)):
        cont = lo < hi
        mid = jnp.clip((lo + hi) // 2, 0, s - 1)
        less = keys[mid] < queries[:, None]
        lo = jnp.where(cont & less, mid + 1, lo)
        hi = jnp.where(cont & ~less, mid, hi)
    pos = jnp.clip(lo, 0, s - 1)
    live = (lo < end) & (keys[pos] == queries[:, None]) & ~dead[pos]
    found = jnp.any(live, axis=1) & (queries != KEY_INF)
    cell = pos[jnp.arange(q), jnp.argmax(live, axis=1)]   # first live run
    return found, cell


def spill_find_runs(keys, vals, dead, run_start, n, queries,
                    max_runs: int = MAX_SPILL_RUNS):
    """Membership form of `spill_run_cells`: (found[Q] bool, vals[Q] u64)."""
    found, cell = spill_run_cells(keys, dead, run_start, n, queries,
                                  max_runs)
    return found, jnp.where(found, vals[cell], jnp.uint64(0))


def tier_find_ref(hot, cold, spill, queries, warm_layout: str = "level"):
    """Raw per-tier probes with the reference implementations:
    ((hot found, vals, col), (warm found, vals), (spill found, vals));
    spill=None (2-tier stacks) yields all-miss spill results. The warm
    probe walks the layout the stack selected: level-major fan-out-4
    (`find_batch`) or the block-major B-skiplist (`find_batch_blocked`) —
    bit-identical found/vals either way."""
    from repro.core import det_skiplist as dsl
    from repro.core import hashtable as ht
    f_hot, v_hot, c_hot = ht.fixed_find_cols(hot, queries)
    warm_find = (dsl.find_batch_blocked if warm_layout == "block"
                 else dsl.find_batch)
    f_warm, v_warm, _ = warm_find(cold, queries)
    if spill is None:
        f_sp = jnp.zeros(queries.shape, bool)
        v_sp = jnp.zeros(queries.shape, jnp.uint64)
    else:
        f_sp, v_sp = spill_find_runs(spill.keys, spill.vals, spill.dead,
                                     spill.run_start, spill.n, queries)
    return (f_hot, v_hot, c_hot), (f_warm, v_warm), (f_sp, v_sp)
