"""Fused tier-find — one Pallas dispatch across all three §IX tiers.

The unfused FIND path of the tier stack issues one dispatch per tier per
plan: hot bucket probe, warm skiplist walk, cold spill membership. That is
three memory-system round trips for what is logically ONE lookup whose
later stages only matter on a miss — exactly the repeated-dispatch overhead
the paper's hierarchy exists to avoid (hot keys answered in the fast tier,
cold accesses batched). This kernel fuses the chain: per query tile, ONE
`pallas_call` probes the hot fixed-hash buckets, falls misses through a
level-major walk of the warm skiplist, and finishes with a per-run binary
search over the cold spill tier's `run_offsets` boundaries. The dispatch
count of a FIND plan becomes independent of tier depth.

TPU mapping (all three tier layouts are VMEM-resident via whole-array
BlockSpecs; the per-tile VMEM budget is the sum of the three planes — see
docs/tiers.md for the worked budget):
  * hot: `core.layout.bucket_layout` [M, B] u32 planes; the probe body is
    `kernels.hash_probe.kernel.bucket_probe` — shared, not copied.
  * warm: `core.layout.skiplist_layout` [L, C1] u32/i32 level stack + flat
    [C] terminal planes; the walk body is
    `kernels.skiplist_search.kernel.level_walk` — shared, not copied.
    Stacks built with `warm_layout="block"` pass the block-major
    `core.layout.bskiplist_layout` planes instead ([L, W] fat-node rows, no
    child plane — the child id is `node*128 + position`) and the walk body
    is `kernels.bskiplist_walk.kernel.block_walk`: one whole-block compare
    per step instead of a fan-out-4 gather, same found/idx contract.
  * cold: `core.layout.spill_layout` [S] u32 key planes + i8 tombstones +
    the [MAX_SPILL_RUNS + 1] i32 `run_offsets` plane. Each run is binary
    searched with `key_lt` (searchsorted "left" semantics), a static
    runs x log2(S) loop — the run cap is what makes this static-shape.
  * 64-bit keys travel as (hi, lo) u32 planes; all value gathers happen
    outside the kernel where u64 lanes exist (ops.py), and the tier
    fall-through masking (warm only counts on hot miss, spill only on
    hot+warm miss) also lives in the dispatch layer so the jnp reference
    shares it verbatim.

Outputs are per-tier raw probe results: (hot hit i8, hot col i32,
warm found i8, warm terminal idx i32[, spill found i8, spill cell i32]).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.layout import BSKIP_BLOCK, key_lt as _lt
from repro.kernels.bskiplist_walk.kernel import block_walk
from repro.kernels.hash_probe.kernel import bucket_probe
from repro.kernels.skiplist_search.kernel import level_walk


def spill_run_probe(qh, ql, sp_hi, sp_lo, sp_dead, run_off, *,
                    max_runs: int, steps: int):
    """The in-kernel cold-tier probe body: binary search each sorted run
    [off[r], off[r+1]) for the query, first live match wins (at most one
    exists under the single-tier-residency invariant; the tie-break keeps
    pathological states deterministic). All runs are searched in PARALLEL
    as one [T, R] tile — the loop is `steps` (= ceil(log2(S))) wide-gather
    iterations, not runs x steps sequential ones, mirroring the jnp
    reference's vectorization (`kernels.tier_find.ref.spill_find_runs`).
    Returns (found bool[T], cell i32[T])."""
    t = qh.shape[0]
    s = sp_hi.shape[0]
    r = max_runs
    lo = jnp.broadcast_to(run_off[:r][None, :], (t, r)).astype(jnp.int32)
    end = jnp.broadcast_to(run_off[1:r + 1][None, :], (t, r)).astype(jnp.int32)
    hi = end
    qh2, ql2 = qh[:, None], ql[:, None]
    for _ in range(steps):
        cont = lo < hi
        mid = jnp.clip((lo + hi) // 2, 0, s - 1)
        mh = jnp.take(sp_hi, mid.reshape(-1), axis=0).reshape(t, r)
        ml = jnp.take(sp_lo, mid.reshape(-1), axis=0).reshape(t, r)
        less = _lt(mh, ml, qh2, ql2)            # sp[mid] < q
        lo = jnp.where(cont & less, mid + 1, lo)
        hi = jnp.where(cont & ~less, mid, hi)
    pos = jnp.clip(lo, 0, s - 1)
    p_hi = jnp.take(sp_hi, pos.reshape(-1), axis=0).reshape(t, r)
    p_lo = jnp.take(sp_lo, pos.reshape(-1), axis=0).reshape(t, r)
    p_dead = jnp.take(sp_dead, pos.reshape(-1), axis=0).reshape(t, r)
    live = (lo < end) & (p_hi == qh2) & (p_lo == ql2) & (p_dead == 0)
    found = jnp.any(live, axis=1)
    first = jnp.argmax(live, axis=1).astype(jnp.int32)   # first live run
    flat = jax.lax.broadcasted_iota(jnp.int32, (t,), 0) * r + first
    cell = jnp.take(pos.reshape(-1), flat, axis=0)
    return found, cell


def _tf_kernel(*refs, levels: int, fanout: int, warm_blocked: bool,
               block: int, has_spill: bool, max_runs: int,
               spill_steps: int):
    if warm_blocked:     # block-major warm planes carry no child plane
        (qh_ref, ql_ref, slot_ref, kh_ref, kl_ref,
         lh_ref, ll_ref, th_ref, tl_ref, tm_ref) = refs[:10]
        rest = refs[10:]
    else:
        (qh_ref, ql_ref, slot_ref, kh_ref, kl_ref,
         lh_ref, ll_ref, lc_ref, th_ref, tl_ref, tm_ref) = refs[:11]
        rest = refs[11:]
    if has_spill:
        sph_ref, spl_ref, spd_ref, off_ref = rest[:4]
        outs = rest[4:]
    else:
        outs = rest
    qh = qh_ref[...]
    ql = ql_ref[...]

    hot_hit, hot_col = bucket_probe(qh, ql, slot_ref[...], kh_ref[...],
                                    kl_ref[...])
    outs[0][...] = hot_hit.astype(jnp.int8)
    outs[1][...] = hot_col

    if warm_blocked:
        warm_found, warm_idx = block_walk(qh, ql, lh_ref[...], ll_ref[...],
                                          th_ref[...], tl_ref[...],
                                          tm_ref[...], levels=levels,
                                          block=block)
    else:
        warm_found, warm_idx = level_walk(qh, ql, lh_ref[...], ll_ref[...],
                                          lc_ref[...], th_ref[...],
                                          tl_ref[...], tm_ref[...],
                                          levels=levels, fanout=fanout)
    outs[2][...] = warm_found.astype(jnp.int8)
    outs[3][...] = warm_idx

    if has_spill:
        sp_found, sp_cell = spill_run_probe(
            qh, ql, sph_ref[...], spl_ref[...], spd_ref[...], off_ref[...],
            max_runs=max_runs, steps=spill_steps)
        outs[4][...] = sp_found.astype(jnp.int8)
        outs[5][...] = sp_cell


def tier_find_tiles(q_hi, q_lo, slots, key_hi, key_lo, lvl_hi, lvl_lo,
                    lvl_child, term_hi, term_lo, term_mark, sp_hi=None,
                    sp_lo=None, sp_dead=None, run_off=None, *,
                    block: int = BSKIP_BLOCK, tile: int = 256,
                    interpret: bool = True):
    """q_*: [T] u32; slots: [T] i32; key_*: [M, B]; lvl_*: [L, C1];
    term_*: [C]; sp_*: [S] (+ run_off [R+1] i32) or None for a 2-tier
    stack. Returns (hot i8[T], col i32[T], warm i8[T], idx i32[T]) plus
    (spill i8[T], cell i32[T]) when the spill planes are given.
    `lvl_child=None` selects the BLOCKED warm walk: lvl_* then carry the
    `bskiplist_layout` [L, W] fat-node rows and term_* its [NB*block]
    padded terminal planes (warm idx is into that padded plane)."""
    t = q_hi.shape[0]
    L = lvl_hi.shape[0]
    warm_blocked = lvl_child is None
    has_spill = sp_hi is not None
    n_out = 6 if has_spill else 4
    if t == 0:   # empty batch: same contract as the jnp references
        z8 = jnp.zeros((0,), jnp.int8)
        z32 = jnp.zeros((0,), jnp.int32)
        return (z8, z32, z8, z32, z8, z32)[:n_out]
    tile = min(tile, t)
    assert t % tile == 0
    grid = (t // tile,)
    whole = lambda a: pl.BlockSpec(a.shape, lambda g: (0,) * a.ndim)
    qspec = pl.BlockSpec((tile,), lambda g: (g,))

    ins = [q_hi, q_lo, slots, key_hi, key_lo, lvl_hi, lvl_lo]
    if not warm_blocked:
        ins.append(lvl_child)
    ins += [term_hi, term_lo, term_mark]
    in_specs = [qspec, qspec, qspec] + [whole(a) for a in ins[3:]]
    max_runs = spill_steps = 0
    if has_spill:
        ins += [sp_hi, sp_lo, sp_dead, run_off]
        in_specs += [whole(sp_hi), whole(sp_lo), whole(sp_dead),
                     whole(run_off)]
        max_runs = run_off.shape[0] - 1
        spill_steps = max(sp_hi.shape[0].bit_length(), 1)

    out_dtypes = ([jnp.int8, jnp.int32] * 3)[:n_out]
    kernel = functools.partial(_tf_kernel, levels=L, fanout=4,
                               warm_blocked=warm_blocked, block=block,
                               has_spill=has_spill, max_runs=max_runs,
                               spill_steps=spill_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((tile,), lambda g: (g,))] * n_out,
        out_shape=[jax.ShapeDtypeStruct((t,), d) for d in out_dtypes],
        interpret=interpret,
    )(*ins)
