"""Architecture registry: one module per assigned arch (+ the paper's own).

get_config(name)          -> full assigned ModelConfig
get_reduced(name)         -> same-family tiny config for CPU smoke tests
ARCHS                     -> all assigned arch names
"""
from importlib import import_module

ARCHS = [
    "llava-next-mistral-7b",
    "qwen3-moe-235b-a22b",
    "llama4-scout-17b-a16e",
    "qwen3-1.7b",
    "llama3-405b",
    "minicpm3-4b",
    "qwen1.5-110b",
    "xlstm-1.3b",
    "hymba-1.5b",
    "musicgen-medium",
]
ALL = ARCHS + ["paper-kvstore"]

_MOD = {n: n.replace("-", "_").replace(".", "_") for n in ALL}


def _module(name: str):
    return import_module(f"repro.configs.{_MOD[name]}")


def get_config(name: str):
    return _module(name).CONFIG


def get_reduced(name: str):
    return _module(name).reduced()
