"""musicgen-medium [audio]: decoder-only over EnCodec tokens, 4 codebooks
with delay pattern (frontend STUB: token grids arrive pre-delayed).
[arXiv:2306.05284; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab_size=2048, head_dim=64, n_codebooks=4, vocab_pad_to=256,
)

def reduced():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab_size=256, head_dim=16,
                          n_codebooks=2, vocab_pad_to=64)
