"""qwen1.5-110b [dense]: QKV bias, GQA kv=8. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=49152,
    vocab_size=152064, head_dim=128, qkv_bias=True, rope_theta=1e6,
)

def reduced():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=512, head_dim=16, vocab_pad_to=64)
