"""llama4-scout-17b-a16e [moe]: 16 experts top-1 + shared expert, early
fusion (multimodal frontend STUB). [hf:meta-llama/Llama-4-Scout-17B-16E;
unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202048, head_dim=128, rope_theta=5e5,
    n_experts=16, n_experts_active=1, d_expert=8192, n_shared_experts=1,
    norm_topk_prob=False, moe_impl="routed_a2a",
)

def reduced():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, d_expert=128, n_experts=4,
                          n_experts_active=1, n_shared_experts=1,
                          vocab_size=512, head_dim=16, vocab_pad_to=64,
                          moe_impl="dense")
