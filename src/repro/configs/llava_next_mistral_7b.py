"""llava-next-mistral-7b [vlm]: Mistral-7B backbone + anyres image-patch
prefix (frontend STUB: input_specs provides precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, head_dim=128, rope_theta=1e6,
    frontend_tokens=1152,   # anyres stub: base 576 + one 576 tile
)

def reduced():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=512, head_dim=16,
                          frontend_tokens=8, vocab_pad_to=64)
