"""The paper's own architecture: the sharded ordered-set (skiplist) service
(§VI) as a dry-run config — store_step lowers on the production meshes."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-kvstore", family="kvstore",
    store_capacity=65536, store_lanes=4096,
)

def reduced():
    return CONFIG.replace(store_capacity=512, store_lanes=32)
