"""The paper's own architecture: the sharded ordered-set service (§VI) as a
dry-run config — the store step lowers on the production meshes.

`store_backend` selects the engine through the `repro.store` registry:
"det_skiplist" is the paper's flagship; "hash+skiplist" is its §IX
hierarchical proposal (hot hash tier over the ordered skiplist);
"tiered3[/lru|/size]" deepens it to three tiers with hot-tier eviction
policies (docs/tiers.md); any other registered backend (twolevel_hash,
splitorder, ...) drops in unchanged."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-kvstore", family="kvstore",
    store_capacity=65536, store_lanes=4096,
    store_backend="det_skiplist",
)

def reduced():
    return CONFIG.replace(store_capacity=512, store_lanes=32)

def tiered():
    """The §IX hierarchical composition on the same shapes."""
    return CONFIG.replace(store_backend="hash+skiplist")

def tiered3(policy: str = "lru"):
    """The three-deep §IX stack (hash -> skiplist -> host spill) with a
    hot-tier eviction policy ("lru" | "size"; "none" = spill-only). Results
    stay bit-identical to every other backend; residency is what changes.
    The registered tier stacks probe through the FUSED tier-find path (one
    exec dispatch per plan across all tiers — docs/tiers.md); construct
    `TieredBackend(fused=False)` directly for the unfused chain."""
    name = "tiered3" if policy == "none" else f"tiered3/{policy}"
    return CONFIG.replace(store_backend=name)


def kernelized(mode: str = "pallas"):
    """Probe phases through the Pallas execution layer ("interpret" on CPU);
    results are bit-identical to the jnp default — a pure perf knob."""
    return CONFIG.replace(store_backend="hash+skiplist", store_exec=mode)
