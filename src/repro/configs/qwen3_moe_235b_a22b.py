"""qwen3-moe-235b-a22b [moe]: 128 experts top-8, GQA kv=4, qk_norm.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
    vocab_size=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
    n_experts=128, n_experts_active=8, d_expert=1536, norm_topk_prob=True,
    moe_impl="routed_a2a",
)

def reduced():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=96, d_expert=96, n_experts=8,
                          n_experts_active=2, vocab_size=512, head_dim=16,
                          vocab_pad_to=64, moe_impl="dense")
