"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks, 7:1 ratio (xLSTM[7:1]).
d_ff=0 per assignment: mixing blocks carry their own up/down projections.
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, block_pattern="xlstm", slstm_every=8,
    ssm_expand=2, ssm_conv=4,
)


def reduced():
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
                          vocab_size=512, vocab_pad_to=64, slstm_every=4)
