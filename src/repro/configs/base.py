"""Config schema: one frozen dataclass covers all 10 assigned architectures
plus the paper's own KV-store service config.

Every assigned arch file defines `CONFIG` (exact assignment numbers) and
`reduced()` (same family, tiny dims) for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|vlm|audio|kvstore
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention ---
    attn_type: str = "gqa"           # gqa|mla
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen1.5
    rope_theta: float = 1e4
    sliding_window: int = 0          # 0 = full attention (hymba: >0)
    global_attn_every: int = 0       # hymba: every k-th layer full attn

    # --- MLA (minicpm3) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    n_experts: int = 0
    n_experts_active: int = 0        # top-k
    d_expert: int = 0                # expert FFN width
    n_shared_experts: int = 0        # llama4 shared expert
    norm_topk_prob: bool = True
    moe_impl: str = "replicated_psum"   # or "routed_a2a" (the paper's routing)
    moe_capacity_factor: float = 2.0    # dispatch-buffer budget (§Perf lever)

    # --- SSM / xLSTM / hybrid ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0             # xlstm: every k-th block is sLSTM
    block_pattern: str = "transformer"  # transformer|xlstm|hybrid

    # --- modality frontends (stubs per assignment) ---
    n_codebooks: int = 0             # musicgen EnCodec codebooks
    frontend_tokens: int = 0         # vlm/audio: precomputed prefix embeddings

    # --- numerics / structure ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    vocab_pad_to: int = 256
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_impl: str = "xla"           # xla | pallas (TPU) | pallas_interpret
    attn_block_q: int = 512          # q-chunking for the XLA attention path
    scan_chunk: int = 256            # mLSTM/mamba chunk length
    kv_cache_dtype: str = "bfloat16"  # or "float8_e4m3fn": §Perf decode lever
    ssm_scan_dtype: str = "float32"   # or "bfloat16": SSM hidden-state traffic
    decode_shard: str = "batch"       # or "seq2d": replicate batch, shard the
                                      # cache seq dim over BOTH axes (weights
                                      # stay stationary — decode comm lever)
    pod_compress: bool = False        # int8 error-feedback gradient exchange
                                      # on the pod (DCI) axis — multi-pod lever
    # (roofline probes unroll by setting these >= seq_len + scan_layers=False)

    # --- kvstore (the paper's own architecture) ---
    store_capacity: int = 0
    store_lanes: int = 0
    store_backend: str = "det_skiplist"  # any repro.store registry name:
                                         # flat structures (twolevel_hash,
                                         # splitorder, ...) or a tier stack —
                                         # "hash+skiplist" (2-tier) or
                                         # "tiered3[/lru|/size]" (3-tier with
                                         # an eviction policy; docs/tiers.md)
    store_exec: str = "jnp"              # probe execution mode (store.exec):
                                         # jnp | interpret | pallas —
                                         # bit-identical results, perf knob

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return -(-self.vocab_size // p) * p

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic/recurrent decode state);
# pure full-attention archs skip it (DESIGN.md §5)
LONG_CONTEXT_OK = {"xlstm-1.3b", "hymba-1.5b"}


def cells_for(arch_name: str) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_name in LONG_CONTEXT_OK or arch_name == "paper-kvstore":
        out.append("long_500k")
    return out
