"""llama3-405b [dense]: GQA kv=8, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248,
    vocab_size=128256, head_dim=128, rope_theta=5e5,
)

def reduced():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=256, vocab_size=512, head_dim=16, vocab_pad_to=64)
