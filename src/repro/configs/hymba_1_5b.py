"""hymba-1.5b [hybrid]: parallel attention + mamba heads per block, sliding
window except 3 global layers, ssm_state=16. [arXiv:2411.13676; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, head_dim=64, block_pattern="hybrid",
    ssm_state=16, ssm_expand=2, ssm_conv=4, sliding_window=2048,
)

def reduced():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=512, head_dim=16,
                          sliding_window=16, vocab_pad_to=64)
