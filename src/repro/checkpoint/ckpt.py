"""Checkpointing: atomic, elastic, dependency-free.

Layout: <dir>/step_<N>/ with one .npy per leaf (path-keyed) + manifest.json
(step, tree paths, shapes, dtypes, user metadata). Writes go to a tmp dir
and commit with os.replace — a crash mid-save never corrupts the latest
checkpoint (restart-safe).

Elastic remap: restore() takes target shardings and device_puts each leaf —
a checkpoint written on one mesh restores onto any other mesh/size (the
resharding is the load-time device_put). An async variant overlaps the host
write with the next step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import numpy as np
import jax


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, tree, meta: dict | None = None):
    """Atomic synchronous save. Returns the committed directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "meta": meta or {}}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        fn = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][key] = {"file": fn, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # the atomic commit point
    return final


class AsyncSaver:
    """Overlap the host-side write with compute (one in flight)."""

    def __init__(self):
        self._t = None

    def save(self, ckpt_dir, step, tree, meta=None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot before mutation
        self._t = threading.Thread(target=save,
                                   args=(ckpt_dir, step, host_tree, meta))
        self._t.start()

    def wait(self):
        if self._t is not None:
            self._t.join()
            self._t = None


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of `like_tree`; `shardings` (same pytree
    structure or None) performs the elastic remap via device_put."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like_tree)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for key in flat_like:
        info = manifest["leaves"][key]
        arr = np.load(os.path.join(d, info["file"]))
        if shardings is not None and key in flat_sh:
            loaded[key] = jax.device_put(arr, flat_sh[key])
        else:
            loaded[key] = jax.numpy.asarray(arr)
    # rebuild tree in like_tree's structure
    paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for path, _ in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(loaded[key])
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["meta"]
