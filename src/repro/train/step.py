"""Train / serve step builders — the functions the dry-run lowers and the
train loop jits.

train_step features:
  * microbatch gradient accumulation (lax.scan — bounds activation memory)
  * remat per layer group (model-level flag)
  * optional int8 error-feedback gradient compression on the pod (DCI) axis
    via a shard_map over ("pod",) with intra-pod axes on GSPMD auto
  * AdamW with ZeRO-sharded moments (sharding inherited from params)

serve_prefill / serve_decode lower the inference cells; decode carries the
contiguous KV caches (seq dim shardable over the model axis).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim.adamw import adamw_update
from repro.optim.compress import pod_allreduce_compressed
from repro.optim.schedule import cosine_with_warmup

AUX_WEIGHT = 0.01


def loss_fn(params, cfg, batch):
    logits, aux = M.forward(params, cfg, batch["tokens"],
                            prefix_embeds=batch.get("prefix_embeds"))
    loss = M.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return loss + AUX_WEIGHT * aux, (loss, aux)


def _split_micro(batch, n):
    def f(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return jax.tree.map(f, batch)


def grads_of(params, cfg, batch, microbatches: int = 1):
    """Accumulated grads + metrics over microbatches (sequential scan)."""
    if microbatches == 1:
        (_, (loss, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch)
        return grads, loss, aux
    mb = _split_micro(batch, microbatches)

    def body(carry, mbatch):
        acc, loss_acc, aux_acc = carry
        (_, (loss, aux)), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, mbatch)
        acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
        return (acc, loss_acc + loss, aux_acc + aux), None

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss, aux), _ = jax.lax.scan(body, (zero, 0.0, jnp.float32(0)), mb)
    inv = 1.0 / microbatches
    return (jax.tree.map(lambda g: g * inv, grads), loss * inv, aux * inv)


def make_train_step(cfg, *, lr_peak=3e-4, warmup=100, total_steps=10000,
                    microbatches: int = 1, pod_compress: bool = False,
                    mesh=None, pod_axis: str = "pod"):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).

    With pod_compress, batches stay pod-local (the batch dim's pod shard) and
    gradients cross the DCI as int8 + error feedback; opt_state carries the
    residuals.
    """

    def apply_update(params, opt_state, grads, loss, aux):
        lr = cosine_with_warmup(opt_state["adam"]["step"] + 1, peak_lr=lr_peak,
                                warmup_steps=warmup, total_steps=total_steps)
        new_p, new_adam, gn = adamw_update(grads, opt_state["adam"], params, lr)
        metrics = {"loss": loss, "aux": aux, "grad_norm": gn, "lr": lr}
        return new_p, {**opt_state, "adam": new_adam}, metrics

    if not pod_compress:
        def train_step(params, opt_state, batch):
            grads, loss, aux = grads_of(params, cfg, batch, microbatches)
            return apply_update(params, opt_state, grads, loss, aux)
        return train_step

    assert mesh is not None and pod_axis in mesh.axis_names
    from repro.core.routing import mesh_shard_map
    from jax.sharding import PartitionSpec as P

    try:                       # partial-manual (intra-pod axes on GSPMD auto)
        from jax import shard_map as _new_sm  # noqa: F401
        partial_manual = True
    except ImportError:
        # jax 0.4.x: all_gather inside a partial-manual region aborts XLA's
        # SPMD partitioner, so go FULLY manual: intra-pod axes exchange
        # gradients with an explicit uncompressed pmean (the fast ICI hop),
        # then the pod (DCI) hop runs the int8 exchange as before
        partial_manual = False
    intra_axes = tuple(a for a in mesh.axis_names if a != pod_axis)

    def train_step(params, opt_state, batch):
        def per_pod(params, residuals, batch):
            grads, loss, aux = grads_of(params, cfg, batch, microbatches)
            if not partial_manual:
                for ax in intra_axes:
                    grads = jax.tree.map(lambda g: jax.lax.pmean(g, ax), grads)
                    loss = jax.lax.pmean(loss, ax)
                    aux = jax.lax.pmean(aux, ax)
            grads, residuals = pod_allreduce_compressed(grads, residuals,
                                                        pod_axis)
            loss = jax.lax.pmean(loss, pod_axis)
            aux = jax.lax.pmean(aux, pod_axis)
            return grads, residuals, loss, aux

        specs_p = jax.tree.map(lambda _: P(), params)
        if partial_manual:
            batch_specs = jax.tree.map(lambda _: P(pod_axis), batch)
            manual_kw = dict(axis_names={pod_axis}, check_vma=False)
        else:
            batch_specs = jax.tree.map(lambda _: P(tuple(mesh.axis_names)),
                                       batch)
            manual_kw = dict(check_vma=False)
        grads, residuals, loss, aux = mesh_shard_map(
            per_pod, mesh=mesh,
            in_specs=(specs_p, specs_p, batch_specs),
            out_specs=(specs_p, specs_p, P(), P()),
            **manual_kw,
        )(params, opt_state["residuals"], batch)
        params, opt_state, metrics = apply_update(params, opt_state, grads,
                                                  loss, aux)
        return params, {**opt_state, "residuals": residuals}, metrics

    return train_step


def make_serve_prefill(cfg, cache_len: int):
    def serve_prefill(params, batch):
        logits, caches, _ = M.prefill(params, cfg, batch["tokens"], cache_len,
                                      prefix_embeds=batch.get("prefix_embeds"))
        return logits[:, -1], caches
    return serve_prefill


def make_serve_decode(cfg):
    def serve_decode(params, token, pos, caches):
        logits, caches = M.decode_step(params, cfg, token, pos, caches)
        return logits[:, 0] if logits.ndim == 3 else logits[:, 0], caches
    return serve_decode
