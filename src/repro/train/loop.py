"""Fault-tolerant training loop.

* checkpoint/restart: atomic saves every `ckpt_every` steps (async), resume
  from the latest on start — deterministic data replay makes the restarted
  run bitwise-continue (tested in tests/test_fault_tolerance.py).
* straggler mitigation: prefetch-depth redundancy + deadline fallback in the
  data pipeline (never blocks the mesh on one slow producer).
* elastic: restore() remaps to whatever mesh/sharding the new run uses.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.checkpoint.ckpt import AsyncSaver, latest_step, restore, save
from repro.data.pipeline import PrefetchPipeline, synth_batch
from repro.models import model as M
from repro.optim.adamw import adamw_init
from repro.train.step import make_train_step


def train(cfg, shape, *, steps: int, seed: int = 0, ckpt_dir: str | None = None,
          ckpt_every: int = 0, microbatches: int = 1, shardings=None,
          delay_injector=None, log_every: int = 10, async_save: bool = True,
          lr_peak: float = 3e-4):
    """Returns (params, opt_state, history). Resumes from ckpt_dir if it has
    a checkpoint."""
    key = jax.random.PRNGKey(seed)
    params = M.init_params(key, cfg)
    opt_state = {"adam": adamw_init(params)}
    start = 0
    if ckpt_dir is not None:
        last = latest_step(ckpt_dir)
        if last is not None:
            (params, opt_state), meta = restore(ckpt_dir, last,
                                                (params, opt_state), shardings)
            start = int(meta["next_step"])

    step_fn = jax.jit(make_train_step(cfg, microbatches=microbatches,
                                      lr_peak=lr_peak))
    pipe = PrefetchPipeline(lambda s: synth_batch(cfg, shape, seed, s),
                            depth=4, deadline=5.0,
                            delay_injector=delay_injector)
    # fast-forward the producer past already-trained steps
    pipe._next_consume = start

    saver = AsyncSaver()
    history = []
    try:
        for step in range(start, steps):
            t0 = time.monotonic()
            batch = pipe.get(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            history.append({"step": step, "loss": loss,
                            "grad_norm": float(metrics["grad_norm"]),
                            "time": time.monotonic() - t0})
            if log_every and step % log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"dt {history[-1]['time']*1e3:.0f}ms", flush=True)
            if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
                meta = {"next_step": step + 1}
                if async_save:
                    saver.save(ckpt_dir, step + 1, (params, opt_state), meta)
                else:
                    save(ckpt_dir, step + 1, (params, opt_state), meta)
    finally:
        saver.wait()
        pipe.stop()
    return params, opt_state, {"history": history,
                               "straggler_skips": pipe.straggler_skips}
