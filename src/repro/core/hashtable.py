"""MWMR hash tables (paper §VII): fixed-slot and two-level implementations.

Paper version 1: fixed number of slots, a binary tree per slot for collisions.
Paper version 2: two-level tables — RW locks shared at L1, a second-level
table per slot expanded past a collision threshold, a memory manager per
first-level slot.

TPU adaptation: a per-slot search tree makes no sense at bucket sizes that fit
one vector register row — a bucket is a contiguous [B]-wide row compared in a
single vector op (the "constant cost per key" the paper wants, with perfect
spatial locality: one bucket = one VMEM tile row). The RW-lock concurrency
becomes batched updates with deterministic linearization: lanes sort
lexicographically by (slot, key) (two stable argsorts), in-batch duplicates
resolve to the lowest lane, and within-slot ranks come from a segmented
cumsum — the fetch-add analogue, assigning distinct bucket columns.

Two-level: every L1 slot has an inline bucket; overflow expands into an L2
table block allocated from a BlockPool (the paper's per-slot memory manager),
hashed by the *next* log2(M2) bits — exactly the paper's bit-slicing.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bits import EMPTY, dup_in_run, hash64
from repro.core.blockpool import BlockPool, blockpool_init, pool_alloc
from repro.core.layout import block_arrays, hash_slot, is_pow2, kv_arrays


def _lex_sort_slots_keys(slots: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """Stable lexicographic argsort by (slot, key): sort by key, then stable
    sort by slot."""
    o1 = jnp.argsort(keys, stable=True)
    o2 = jnp.argsort(slots[o1], stable=True)
    return o1[o2]


def _batch_plan(slots: jnp.ndarray, keys: jnp.ndarray, mask: jnp.ndarray):
    """Shared linearization plan: returns (order, sorted slots/keys/mask,
    in-batch-dup mask, within-slot insert rank, inverse permutation)."""
    K = keys.shape[0]
    order = _lex_sort_slots_keys(slots, keys)
    ss, sk, sm = slots[order], keys[order], mask[order]
    same = jnp.concatenate([jnp.zeros((1,), bool),
                            (sk[1:] == sk[:-1]) & (ss[1:] == ss[:-1])])
    dup = dup_in_run(same, sm)
    # segmented rank among insert-candidate lanes of the same slot
    run_start = jnp.searchsorted(ss, ss, side="left").astype(jnp.int32)
    inv = jnp.zeros((K,), jnp.int32).at[order].set(jnp.arange(K, dtype=jnp.int32))
    return order, ss, sk, sm, dup, run_start, inv


def _seg_rank(cand: jnp.ndarray, run_start: jnp.ndarray) -> jnp.ndarray:
    c = jnp.cumsum(cand.astype(jnp.int32))
    before = jnp.where(run_start > 0, c[jnp.maximum(run_start - 1, 0)], 0)
    before = jnp.where(run_start > 0, before, 0)
    return c - before - cand.astype(jnp.int32)   # 0-based rank within slot run


def _nth_empty(rows_keys: jnp.ndarray, rank: jnp.ndarray):
    """Column of the (rank+1)-th EMPTY cell in each [B] row; B on overflow."""
    B = rows_keys.shape[1]
    empty = rows_keys == EMPTY
    cum = jnp.cumsum(empty.astype(jnp.int32), axis=1)
    want = rank[:, None] + 1
    hit = empty & (cum == want)
    col = jnp.argmax(hit, axis=1).astype(jnp.int32)
    ok = jnp.any(hit, axis=1)
    return jnp.where(ok, col, B), ok


# ---------------------------------------------------------------------------
# Version 1: fixed slots, vector-row buckets
# ---------------------------------------------------------------------------

class FixedHash(NamedTuple):
    keys: jnp.ndarray   # [M, B] uint64, EMPTY pad
    vals: jnp.ndarray   # [M, B] uint64
    count: jnp.ndarray  # scalar int64 live entries

    @property
    def num_slots(self) -> int:
        return self.keys.shape[0]

    @property
    def bucket(self) -> int:
        return self.keys.shape[1]


def fixed_init(num_slots: int, bucket: int) -> FixedHash:
    assert is_pow2(num_slots), "power-of-two slots (paper §VIII)"
    keys, vals = kv_arrays((num_slots, bucket))
    return FixedHash(keys=keys, vals=vals, count=jnp.int64(0))


def _slot_of(h: FixedHash, keys: jnp.ndarray) -> jnp.ndarray:
    # s = H(k) mod M; M power of two -> low log(M) bits of the scrambled hash
    return hash_slot(keys, h.num_slots)


class BucketInsertPlan(NamedTuple):
    """The shared insert-linearization prologue of a fixed-slot table, in
    sorted (slot, key) lane order: who exists, who is an in-batch
    duplicate, which candidates fit an empty bucket column. `fixed_insert`
    consumes it directly; the tier stack's policy-driven insert
    (`store/tiers.py`) extends it with eviction, so the two insert paths
    share ONE linearization (dup/exists/rank rules) by construction."""
    order: jnp.ndarray   # [K] sorted-lane permutation
    inv: jnp.ndarray     # [K] inverse permutation (back to caller order)
    ss: jnp.ndarray      # [K] slots, sorted order
    sk: jnp.ndarray      # [K] keys, sorted order
    sv: jnp.ndarray      # [K] vals, sorted order
    sm: jnp.ndarray      # [K] mask, sorted order
    rows: jnp.ndarray    # [K, B] pre-batch bucket rows
    dup: jnp.ndarray     # [K] in-batch duplicate (not the first masked lane)
    exists: jnp.ndarray  # [K] key already stored (pre-batch)
    cand: jnp.ndarray    # [K] insert candidate (masked, no dup, absent)
    rank: jnp.ndarray    # [K] within-slot rank among candidates
    col_e: jnp.ndarray   # [K] empty-column placement for `rank`
    fit_e: jnp.ndarray   # [K] candidate fits an empty column


def bucket_insert_plan(h: FixedHash, keys, vals, mask) -> BucketInsertPlan:
    """Build the `BucketInsertPlan` for one batched insert (pre-batch
    state; callers perform the scatters)."""
    mask = mask & (keys != EMPTY)
    slots = _slot_of(h, keys)
    order, ss, sk, sm, dup, run_start, inv = _batch_plan(slots, keys, mask)
    rows = h.keys[ss]
    exists = sm & jnp.any(rows == sk[:, None], axis=1) & ~dup
    cand = sm & ~dup & ~exists
    rank = _seg_rank(cand, run_start)
    col_e, fit_e = _nth_empty(rows, rank)
    return BucketInsertPlan(order=order, inv=inv, ss=ss, sk=sk,
                            sv=vals[order], sm=sm, rows=rows, dup=dup,
                            exists=exists, cand=cand, rank=rank, col_e=col_e,
                            fit_e=fit_e)


def fixed_insert(h: FixedHash, keys: jnp.ndarray, vals: jnp.ndarray,
                 mask: jnp.ndarray | None = None):
    """Returns (h', inserted[K], existed[K]). Bucket-full lanes fail (the
    bounded-collision threshold; the two-level table and the tier stacks'
    eviction policies are the remedies)."""
    K = keys.shape[0]
    M, B = h.num_slots, h.bucket
    if mask is None:
        mask = jnp.ones((K,), bool)
    p = bucket_insert_plan(h, keys, vals, mask)
    ins = p.cand & p.fit_e

    flat = jnp.where(ins, p.ss * B + p.col_e, M * B)
    nk = h.keys.reshape(-1).at[flat].set(p.sk, mode="drop").reshape(M, B)
    nv = h.vals.reshape(-1).at[flat].set(p.sv, mode="drop").reshape(M, B)
    h2 = FixedHash(keys=nk, vals=nv,
                   count=h.count + jnp.sum(ins).astype(jnp.int64))
    return h2, ins[p.inv], (p.exists | p.dup)[p.inv]


def fixed_find_cols(h: FixedHash, keys: jnp.ndarray):
    """`fixed_find` plus the hit column: (found[K], vals[K], col[K] int32).
    `col` is the first matching bucket column (unique per key — the table is
    insert-if-absent) and feeds the tier stack's eviction-policy metadata
    refresh (`store/tiers.py`); col of a miss is unspecified."""
    slots = _slot_of(h, keys)
    rows = h.keys[slots]
    hit = rows == keys[:, None]
    found = jnp.any(hit, axis=1) & (keys != EMPTY)
    col = jnp.argmax(hit, axis=1).astype(jnp.int32)
    vals = jnp.where(found, h.vals[slots, col], jnp.uint64(0))
    return found, vals, col


def fixed_find(h: FixedHash, keys: jnp.ndarray):
    return fixed_find_cols(h, keys)[:2]


def fixed_delete(h: FixedHash, keys: jnp.ndarray, mask: jnp.ndarray | None = None):
    K = keys.shape[0]
    M, B = h.num_slots, h.bucket
    if mask is None:
        mask = jnp.ones((K,), bool)
    slots = _slot_of(h, keys)
    rows = h.keys[slots]
    hit = rows == keys[:, None]
    found = jnp.any(hit, axis=1) & mask & (keys != EMPTY)
    col = jnp.argmax(hit, axis=1).astype(jnp.int32)
    # in-batch duplicate deletes target the same cell: scatter of EMPTY is
    # idempotent, count via unique cells -> dedupe by (slot,col); non-found
    # lanes park at the sentinel cell so a miss with col==0 can never alias a
    # genuine hit at column 0 into a false duplicate
    cell = jnp.where(found, slots * B + col, M * B)
    o = jnp.argsort(cell, stable=True)
    cs = cell[o]
    fdup = jnp.concatenate([jnp.zeros((1,), bool), cs[1:] == cs[:-1]]) & found[o]
    inv = jnp.zeros((K,), jnp.int32).at[o].set(jnp.arange(K, dtype=jnp.int32))
    eff = found & ~fdup[inv]
    flat = jnp.where(eff, cell, M * B)
    nk = h.keys.reshape(-1).at[flat].set(EMPTY, mode="drop").reshape(M, B)
    h2 = FixedHash(keys=nk, vals=h.vals, count=h.count - jnp.sum(eff).astype(jnp.int64))
    return h2, eff


# ---------------------------------------------------------------------------
# Version 2: two-level (inline L1 bucket + pooled L2 tables)
# ---------------------------------------------------------------------------

class TwoLevelHash(NamedTuple):
    l1_keys: jnp.ndarray   # [M1, B1]
    l1_vals: jnp.ndarray   # [M1, B1]
    l2_block: jnp.ndarray  # [M1] int32 block id, -1 = not expanded
    l2_keys: jnp.ndarray   # [P, M2, B2] pooled second-level tables
    l2_vals: jnp.ndarray   # [P, M2, B2]
    pool: BlockPool        # allocator over P blocks (memory manager per slot)
    count: jnp.ndarray

    @property
    def m1(self) -> int:
        return self.l1_keys.shape[0]

    @property
    def m2(self) -> int:
        return self.l2_keys.shape[1]


def twolevel_init(m1: int, b1: int, m2: int, b2: int, pool_blocks: int) -> TwoLevelHash:
    assert is_pow2(m1) and is_pow2(m2)
    l1_keys, l1_vals = kv_arrays((m1, b1))
    l2_keys, l2_vals = block_arrays(pool_blocks, (m2, b2))
    return TwoLevelHash(
        l1_keys=l1_keys,
        l1_vals=l1_vals,
        l2_block=jnp.full((m1,), -1, jnp.int32),
        l2_keys=l2_keys,
        l2_vals=l2_vals,
        pool=blockpool_init(pool_blocks),
        count=jnp.int64(0),
    )


def _slots12(h: TwoLevelHash, keys: jnp.ndarray):
    # lower log(M1) bits for L1, the NEXT log(M2) bits for L2 (paper §VIII)
    hv = hash64(keys)
    s1 = hash_slot(hv, h.m1, prehashed=True)
    s2 = ((hv >> jnp.uint64(h.m1.bit_length() - 1))
          & jnp.uint64(h.m2 - 1)).astype(jnp.int32)
    return s1, s2


def twolevel_find(h: TwoLevelHash, keys: jnp.ndarray):
    s1, s2 = _slots12(h, keys)
    rows1 = h.l1_keys[s1]
    hit1 = rows1 == keys[:, None]
    f1 = jnp.any(hit1, axis=1)
    v1 = h.l1_vals[s1, jnp.argmax(hit1, axis=1)]
    blk = h.l2_block[s1]
    safe = jnp.maximum(blk, 0)
    rows2 = h.l2_keys[safe, s2]
    hit2 = (rows2 == keys[:, None]) & (blk >= 0)[:, None]
    f2 = jnp.any(hit2, axis=1)
    v2 = h.l2_vals[safe, s2, jnp.argmax(hit2, axis=1)]
    found = (f1 | f2) & (keys != EMPTY)
    return found, jnp.where(f1, v1, jnp.where(f2, v2, jnp.uint64(0)))


def twolevel_insert(h: TwoLevelHash, keys: jnp.ndarray, vals: jnp.ndarray,
                    mask: jnp.ndarray | None = None):
    """L1 inline bucket first; on overflow expand the slot with a pooled L2
    table (the paper's threshold-triggered expansion) and place there."""
    K = keys.shape[0]
    M1, B1 = h.l1_keys.shape
    P, M2, B2 = h.l2_keys.shape
    if mask is None:
        mask = jnp.ones((K,), bool)
    mask = mask & (keys != EMPTY)
    s1, s2 = _slots12(h, keys)
    order, ss, sk, sm, dup, run_start, inv = _batch_plan(s1, keys, mask)
    sv = vals[order]
    ss2 = s2[order]

    # existence check across both levels (pre-batch state)
    rows1 = h.l1_keys[ss]
    blk0 = h.l2_block[ss]
    rows2 = h.l2_keys[jnp.maximum(blk0, 0), ss2]
    exists = sm & ~dup & (jnp.any(rows1 == sk[:, None], axis=1)
                          | (jnp.any(rows2 == sk[:, None], axis=1) & (blk0 >= 0)))
    cand = sm & ~dup & ~exists

    # L1 placement by within-slot rank over remaining empties
    rank1 = _seg_rank(cand, run_start)
    col1, fit1 = _nth_empty(rows1, rank1)
    put1 = cand & fit1

    # overflow lanes go to L2; slots without an L2 table get one (first
    # overflow lane of each slot run performs the allocation)
    over = cand & ~fit1
    need_alloc = over & (blk0 < 0)
    first_of_run = jnp.arange(K, dtype=jnp.int32) == run_start
    # the first *needing* lane in the run allocates: rank among needing == 0
    alloc_rank = _seg_rank(need_alloc, run_start)
    do_alloc = need_alloc & (alloc_rank == 0)
    pool2, ids, _handles, got = pool_alloc(h.pool, do_alloc)
    l2_block = h.l2_block.at[jnp.where(do_alloc & got, ss, M1)].set(ids, mode="drop")

    blk = l2_block[ss]                                  # post-allocation view
    has_l2 = over & (blk >= 0)
    # within (slot) rank among L2-bound lanes, placed at s2 buckets; lanes in
    # the same (s1, s2) pair need distinct columns -> rank over that pair
    pair = ss.astype(jnp.int64) * M2 + ss2.astype(jnp.int64)
    po = jnp.argsort(pair, stable=True)
    ppair = pair[po]
    prun = jnp.searchsorted(ppair, ppair, side="left").astype(jnp.int32)
    pcand = has_l2[po]
    prank = _seg_rank(pcand, prun)
    rank2 = jnp.zeros((K,), jnp.int32).at[po].set(prank)
    rows2b = h.l2_keys[jnp.maximum(blk, 0), ss2]
    col2, fit2 = _nth_empty(rows2b, rank2)
    put2 = has_l2 & fit2

    # scatters
    flat1 = jnp.where(put1, ss * B1 + col1, M1 * B1)
    nk1 = h.l1_keys.reshape(-1).at[flat1].set(sk, mode="drop").reshape(M1, B1)
    nv1 = h.l1_vals.reshape(-1).at[flat1].set(sv, mode="drop").reshape(M1, B1)
    flat2 = jnp.where(put2, (blk * M2 + ss2) * B2 + col2, P * M2 * B2)
    nk2 = h.l2_keys.reshape(-1).at[flat2].set(sk, mode="drop").reshape(P, M2, B2)
    nv2 = h.l2_vals.reshape(-1).at[flat2].set(sv, mode="drop").reshape(P, M2, B2)

    ins = put1 | put2
    h2 = TwoLevelHash(l1_keys=nk1, l1_vals=nv1, l2_block=l2_block,
                      l2_keys=nk2, l2_vals=nv2, pool=pool2,
                      count=h.count + jnp.sum(ins).astype(jnp.int64))
    return h2, ins[inv], (exists | dup)[inv]


def twolevel_delete(h: TwoLevelHash, keys: jnp.ndarray,
                    mask: jnp.ndarray | None = None):
    """Delete from either level: scatter EMPTY into the matched cell.

    In-batch duplicate deletes of one key target the same cell and are deduped
    by a global cell id (L1 cells first, then L2 cells) so the count stays
    exact — the same first-lane-wins linearization as fixed_delete. Expanded
    L2 tables stay allocated even when emptied (the paper never shrinks a
    slot's second level). Returns (h', deleted[K])."""
    K = keys.shape[0]
    M1, B1 = h.l1_keys.shape
    P, M2, B2 = h.l2_keys.shape
    if mask is None:
        mask = jnp.ones((K,), bool)
    mask = mask & (keys != EMPTY)
    s1, s2 = _slots12(h, keys)

    rows1 = h.l1_keys[s1]
    hit1 = rows1 == keys[:, None]
    f1 = jnp.any(hit1, axis=1) & mask
    col1 = jnp.argmax(hit1, axis=1).astype(jnp.int32)
    blk = h.l2_block[s1]
    safe = jnp.maximum(blk, 0)
    rows2 = h.l2_keys[safe, s2]
    hit2 = (rows2 == keys[:, None]) & (blk >= 0)[:, None]
    f2 = jnp.any(hit2, axis=1) & mask & ~f1
    col2 = jnp.argmax(hit2, axis=1).astype(jnp.int32)

    found = f1 | f2
    cell1 = s1 * B1 + col1
    cell2 = M1 * B1 + (safe * M2 + s2) * B2 + col2
    cell = jnp.where(f1, cell1, jnp.where(f2, cell2, M1 * B1 + P * M2 * B2))
    o = jnp.argsort(cell, stable=True)
    cs = cell[o]
    fdup = jnp.concatenate([jnp.zeros((1,), bool), cs[1:] == cs[:-1]]) & found[o]
    inv = jnp.zeros((K,), jnp.int32).at[o].set(jnp.arange(K, dtype=jnp.int32))
    eff = found & ~fdup[inv]

    flat1 = jnp.where(eff & f1, cell1, M1 * B1)
    nk1 = h.l1_keys.reshape(-1).at[flat1].set(EMPTY, mode="drop").reshape(M1, B1)
    flat2 = jnp.where(eff & f2, (safe * M2 + s2) * B2 + col2, P * M2 * B2)
    nk2 = h.l2_keys.reshape(-1).at[flat2].set(EMPTY, mode="drop").reshape(P, M2, B2)
    h2 = h._replace(l1_keys=nk1, l2_keys=nk2,
                    count=h.count - jnp.sum(eff).astype(jnp.int64))
    return h2, eff
