"""Split-order hash tables (paper §VII-VIII, after Shalev & Shavit).

The split-order idea: keep entries ordered by the BIT-REVERSED hash; a table
of M = 2^m slots partitions that order into M contiguous segments (the low m
hash bits, reversed, are the top m bits of the sort key). Doubling M splits
every segment in half — rehash with ZERO data movement ("splitting performed
the required rehashing without data migration").

TPU adaptation: the paper's linked list + dummy nodes become one sorted array
(dummy nodes = implicit segment boundaries found by searchsorted); the paper's
recursive parent-slot initialization disappears entirely (anchors are
computed, not stored) — which is the same cache-miss pathology the paper
measured in its one-level variant (table VI), here showing up as scattered
binary-search gathers over a large array. The two-level variant routes by the
TOP hash bits to one of T small tables first (the paper's NUMA partitioning),
so the binary search touches one small contiguous region — the VMEM-tile
analogue of the paper's locality win.

Resizing is a scalar bump of `n_slots` under the occupancy rule
n > n_slots * max_load — observable, costless, and exactly the paper's rule.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bits import KEY_INF, bitrev64, dup_in_run, hash64
from repro.core.layout import is_pow2, kv_arrays

_WINDOW = 4  # rk-collision scan width (64-bit hash collisions are ~0)


class SplitOrderHash(NamedTuple):
    rk: jnp.ndarray       # [C] bit-reversed hash, sorted, KEY_INF pad
    keys: jnp.ndarray     # [C] original keys
    vals: jnp.ndarray     # [C]
    n: jnp.ndarray        # scalar int32
    n_slots: jnp.ndarray  # scalar int32 (power of two, grows by doubling)
    max_load: int

    @property
    def capacity(self) -> int:
        return self.rk.shape[0]


def splitorder_init(capacity: int, seed_slots: int, max_load: int = 16) -> SplitOrderHash:
    assert is_pow2(seed_slots)
    keys, vals = kv_arrays(capacity)
    return SplitOrderHash(
        rk=jnp.full((capacity,), KEY_INF),
        keys=keys,
        vals=vals,
        n=jnp.int32(0),
        n_slots=jnp.int32(seed_slots),
        max_load=max_load,
    )


def _rk_of(keys: jnp.ndarray) -> jnp.ndarray:
    return bitrev64(hash64(keys))


def _window_match(rk_arr, key_arr, pos, rk_q, key_q):
    """Scan _WINDOW entries from pos for (rk, key) equality (collision runs)."""
    C = rk_arr.shape[0]
    idx = jnp.clip(pos[:, None] + jnp.arange(_WINDOW, dtype=jnp.int32)[None, :], 0, C - 1)
    hit = (rk_arr[idx] == rk_q[:, None]) & (key_arr[idx] == key_q[:, None])
    found = jnp.any(hit, axis=1)
    at = pos + jnp.argmax(hit, axis=1).astype(jnp.int32)
    return found, jnp.clip(at, 0, C - 1)


def splitorder_find(h: SplitOrderHash, keys: jnp.ndarray):
    rkq = _rk_of(keys)
    pos = jnp.searchsorted(h.rk, rkq, side="left").astype(jnp.int32)
    found, at = _window_match(h.rk, h.keys, pos, rkq, keys)
    found = found & (keys != KEY_INF)
    return found, jnp.where(found, h.vals[at], jnp.uint64(0))


def splitorder_insert(h: SplitOrderHash, keys: jnp.ndarray, vals: jnp.ndarray,
                      mask: jnp.ndarray | None = None):
    """Bulk sorted merge by reversed hash + occupancy-triggered slot doubling.
    Returns (h', inserted[K], existed[K])."""
    K = keys.shape[0]
    C = h.capacity
    if mask is None:
        mask = jnp.ones((K,), bool)
    mask = mask & (keys != KEY_INF)
    rkq = _rk_of(keys)

    order = jnp.argsort(rkq, stable=True)
    srk, sk, sv, sm = rkq[order], keys[order], vals[order], mask[order]
    same = jnp.concatenate([jnp.zeros((1,), bool),
                            (srk[1:] == srk[:-1]) & (sk[1:] == sk[:-1])])
    dup = dup_in_run(same, sm)

    pos = jnp.searchsorted(h.rk, srk, side="left").astype(jnp.int32)
    exists, _ = _window_match(h.rk, h.keys, pos, srk, sk)
    exists = exists & sm & ~dup

    new = sm & ~dup & ~exists
    rank = jnp.cumsum(new.astype(jnp.int32)) - 1
    new = new & (h.n + rank < C)
    n_new = jnp.sum(new).astype(jnp.int32)

    crank = jnp.where(new, rank, K)
    nrk = jnp.full((K,), KEY_INF).at[crank].set(srk, mode="drop")
    nk = jnp.full((K,), KEY_INF).at[crank].set(sk, mode="drop")
    nv = jnp.zeros((K,), jnp.uint64).at[crank].set(sv, mode="drop")

    old_idx = jnp.arange(C, dtype=jnp.int32)
    dest_old = old_idx + jnp.searchsorted(nrk, h.rk, side="left").astype(jnp.int32)
    dest_old = jnp.where(old_idx < h.n, dest_old, C)
    dest_new = (jnp.searchsorted(h.rk, nrk, side="right").astype(jnp.int32)
                + jnp.arange(K, dtype=jnp.int32))
    dest_new = jnp.where(jnp.arange(K) < n_new, dest_new, C)

    rk2 = jnp.full((C,), KEY_INF).at[dest_old].set(h.rk, mode="drop")
    rk2 = rk2.at[dest_new].set(nrk, mode="drop")
    k2 = jnp.full((C,), KEY_INF).at[dest_old].set(h.keys, mode="drop")
    k2 = k2.at[dest_new].set(nk, mode="drop")
    v2 = jnp.zeros((C,), jnp.uint64).at[dest_old].set(h.vals, mode="drop")
    v2 = v2.at[dest_new].set(nv, mode="drop")

    n2 = h.n + n_new
    # occupancy > n_slots * max_load -> double the slots (zero movement)
    grow = n2 > h.n_slots * h.max_load
    n_slots = jnp.where(grow, h.n_slots * 2, h.n_slots).astype(jnp.int32)

    h2 = h._replace(rk=rk2, keys=k2, vals=v2, n=n2, n_slots=n_slots)
    inv = jnp.zeros((K,), jnp.int32).at[order].set(jnp.arange(K, dtype=jnp.int32))
    return h2, new[inv], (exists | dup)[inv]


def splitorder_delete(h: SplitOrderHash, keys: jnp.ndarray,
                      mask: jnp.ndarray | None = None):
    """Batched delete: locate by (rk, key), then physically compact survivors.

    The sorted-array analogue of unlinking a node: split-order segment anchors
    are computed (not stored), so compaction needs no rehash and `n_slots` is
    untouched (the paper never shrinks the table). In-batch duplicate deletes
    of one key resolve to the first lane (they match the same cell).
    Returns (h', deleted[K])."""
    K = keys.shape[0]
    C = h.capacity
    if mask is None:
        mask = jnp.ones((K,), bool)
    mask = mask & (keys != KEY_INF)
    rkq = _rk_of(keys)
    pos = jnp.searchsorted(h.rk, rkq, side="left").astype(jnp.int32)
    found, at = _window_match(h.rk, h.keys, pos, rkq, keys)
    found = found & mask

    # dedupe in-batch duplicates by target cell (first lane wins)
    cell = jnp.where(found, at, C)
    o = jnp.argsort(cell, stable=True)
    cs = cell[o]
    fdup = jnp.concatenate([jnp.zeros((1,), bool), cs[1:] == cs[:-1]]) & found[o]
    inv = jnp.zeros((K,), jnp.int32).at[o].set(jnp.arange(K, dtype=jnp.int32))
    eff = found & ~fdup[inv]

    dead = jnp.zeros((C,), bool).at[jnp.where(eff, at, C)].set(True, mode="drop")
    keep = ~dead & (jnp.arange(C) < h.n)
    dest = jnp.where(keep, jnp.cumsum(keep.astype(jnp.int32)) - 1, C)
    rk2 = jnp.full((C,), KEY_INF).at[dest].set(h.rk, mode="drop")
    k2 = jnp.full((C,), KEY_INF).at[dest].set(h.keys, mode="drop")
    v2 = jnp.zeros((C,), jnp.uint64).at[dest].set(h.vals, mode="drop")
    n2 = jnp.sum(keep).astype(jnp.int32)
    return h._replace(rk=rk2, keys=k2, vals=v2, n=n2), eff


def splitorder_slot_bounds(h: SplitOrderHash, keys: jnp.ndarray):
    """Segment [lo, hi) of each key's slot under the CURRENT n_slots — the
    implicit dummy-node anchors; used by the locality bench (table VI)."""
    m = jnp.log2(h.n_slots.astype(jnp.float64)).astype(jnp.int32)
    slot = (hash64(keys) & (h.n_slots - 1).astype(jnp.uint64))
    lo_rk = bitrev64(slot)                      # slot bits land at the top
    step = (KEY_INF >> m.astype(jnp.uint64))    # segment width in rk space
    hi_rk = lo_rk + step
    wrap = hi_rk < lo_rk                        # last slot: saturate to array end
    lo = jnp.searchsorted(h.rk, lo_rk, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(h.rk, hi_rk, side="left").astype(jnp.int32)
    hi = jnp.where(wrap, h.n, hi)
    return lo, hi


# ---------------------------------------------------------------------------
# Two-level split-order: route by top hash bits to T small tables
# ---------------------------------------------------------------------------

class TwoLevelSplitOrder(NamedTuple):
    rk: jnp.ndarray       # [T, C2]
    keys: jnp.ndarray     # [T, C2]
    vals: jnp.ndarray     # [T, C2]
    n: jnp.ndarray        # [T] int32
    n_slots: jnp.ndarray  # [T] int32 — per-table resizing (paper: "resizing
                          # operations performed per table")
    max_load: int

    @property
    def num_tables(self) -> int:
        return self.rk.shape[0]

    @property
    def table_capacity(self) -> int:
        return self.rk.shape[1]


def twolevel_splitorder_init(num_tables: int, capacity: int, seed_slots: int,
                             max_load: int = 16) -> TwoLevelSplitOrder:
    assert is_pow2(num_tables)
    keys, vals = kv_arrays((num_tables, capacity))
    return TwoLevelSplitOrder(
        rk=jnp.full((num_tables, capacity), KEY_INF),
        keys=keys,
        vals=vals,
        n=jnp.zeros((num_tables,), jnp.int32),
        n_slots=jnp.full((num_tables,), seed_slots, jnp.int32),
        max_load=max_load,
    )


def _table_of(h: TwoLevelSplitOrder, keys: jnp.ndarray) -> jnp.ndarray:
    t_bits = h.num_tables.bit_length() - 1
    return (hash64(keys) >> jnp.uint64(64 - t_bits)).astype(jnp.int32) if t_bits \
        else jnp.zeros(keys.shape, jnp.int32)


def twolevel_splitorder_find(h: TwoLevelSplitOrder, keys: jnp.ndarray):
    t = _table_of(h, keys)
    rkq = _rk_of(keys)
    # vectorized per-lane binary search within the owning table row
    rows_rk = h.rk[t]                       # [K, C2] gather of table rows
    pos = jax.vmap(lambda row, q: jnp.searchsorted(row, q, side="left"))(rows_rk, rkq)
    pos = pos.astype(jnp.int32)
    C2 = h.table_capacity
    idx = jnp.clip(pos[:, None] + jnp.arange(_WINDOW, dtype=jnp.int32)[None, :], 0, C2 - 1)
    hit = (rows_rk[jnp.arange(keys.shape[0])[:, None], idx] == rkq[:, None]) \
        & (h.keys[t[:, None], idx] == keys[:, None])
    found = jnp.any(hit, axis=1) & (keys != KEY_INF)
    at = jnp.clip(pos + jnp.argmax(hit, axis=1).astype(jnp.int32), 0, C2 - 1)
    return found, jnp.where(found, h.vals[t, at], jnp.uint64(0))


def twolevel_splitorder_insert(h: TwoLevelSplitOrder, keys: jnp.ndarray,
                               vals: jnp.ndarray, mask: jnp.ndarray | None = None):
    """Route lanes to owner tables, then a vmapped per-table sorted merge —
    the same two-phase motion as the paper's queue-to-NUMA-node pipeline."""
    K = keys.shape[0]
    T, C2 = h.rk.shape
    if mask is None:
        mask = jnp.ones((K,), bool)
    mask = mask & (keys != KEY_INF)
    t = _table_of(h, keys)
    rkq = _rk_of(keys)

    def one_table(rk_row, key_row, val_row, n_row, slots_row, tbl_id):
        sub = SplitOrderHash(rk=rk_row, keys=key_row, vals=val_row, n=n_row,
                             n_slots=slots_row, max_load=h.max_load)
        m = mask & (t == tbl_id)
        sub2, ins, ex = splitorder_insert(sub, keys, vals, m)
        return sub2.rk, sub2.keys, sub2.vals, sub2.n, sub2.n_slots, ins, ex

    rk2, k2, v2, n2, s2, ins, ex = jax.vmap(one_table)(
        h.rk, h.keys, h.vals, h.n, h.n_slots, jnp.arange(T, dtype=jnp.int32))
    h2 = h._replace(rk=rk2, keys=k2, vals=v2, n=n2, n_slots=s2)
    return h2, jnp.any(ins, axis=0), jnp.any(ex, axis=0)


def twolevel_splitorder_delete(h: TwoLevelSplitOrder, keys: jnp.ndarray,
                               mask: jnp.ndarray | None = None):
    """Route lanes to owner tables, vmapped per-table compacting delete.
    Returns (h', deleted[K])."""
    K = keys.shape[0]
    T, C2 = h.rk.shape
    if mask is None:
        mask = jnp.ones((K,), bool)
    mask = mask & (keys != KEY_INF)
    t = _table_of(h, keys)

    def one_table(rk_row, key_row, val_row, n_row, slots_row, tbl_id):
        sub = SplitOrderHash(rk=rk_row, keys=key_row, vals=val_row, n=n_row,
                             n_slots=slots_row, max_load=h.max_load)
        sub2, eff = splitorder_delete(sub, keys, mask & (t == tbl_id))
        return sub2.rk, sub2.keys, sub2.vals, sub2.n, eff

    rk2, k2, v2, n2, eff = jax.vmap(one_table)(
        h.rk, h.keys, h.vals, h.n, h.n_slots, jnp.arange(T, dtype=jnp.int32))
    return h._replace(rk=rk2, keys=k2, vals=v2, n=n2), jnp.any(eff, axis=0)
