"""Unbounded lock-free queue, LCRQ-adapted (paper §III, algorithms 7-10).

Faithful structure: a chain of fixed-size array blocks (`list` in the paper)
with per-block `front`/`rear` monotone counters, full/empty flag arrays `fe`
(0 empty, 1 full, 2 consumed), `wclosed`/`rclosed` completion flags, a `use[]`
bitmap over a preallocated pool of blocks, `next` links, and block recycling
with a per-block recycle counter (the ABA refcount).

TPU adaptation (DESIGN.md §2): threads -> batch lanes. The paper's fetch-add
(`atomicAdd(rear, 1)` per thread) becomes a cumsum over the lane mask — each
lane receives a distinct slot, which is exactly the linearization the paper
proves: front/rear updates are the linearization points; here the single
functional state update is that point. The `fe` flags lose their signalling
role (no racing readers) and become checked invariants: a pop only consumes
fe==1 slots and a push only fills fe==0 slots; property tests assert the
discipline, catching the same bugs the flags guard against on a CPU.

A batched push of K lanes spans at most ceil(K/B)+1 blocks, so block discovery
is a static unrolled walk — no data-dependent loops (TPU-friendly).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

FE_EMPTY, FE_FULL, FE_CONSUMED = 0, 1, 2
NO_BLK = jnp.int32(-1)


class RingQueue(NamedTuple):
    data: jnp.ndarray      # [M, B] payload
    fe: jnp.ndarray        # [M, B] int8
    front: jnp.ndarray     # [M] int32
    rear: jnp.ndarray      # [M] int32
    wclosed: jnp.ndarray   # [M] bool
    rclosed: jnp.ndarray   # [M] bool
    nxt: jnp.ndarray       # [M] int32, -1 = none
    use: jnp.ndarray       # [M] bool
    recycles: jnp.ndarray  # [M] uint32 — paper's per-node recycle refcount
    head_blk: jnp.ndarray  # scalar int32 (listhead)
    tail_blk: jnp.ndarray  # scalar int32 (cn)
    pushed: jnp.ndarray    # scalar int64 monotone
    popped: jnp.ndarray    # scalar int64 monotone

    @property
    def max_blocks(self) -> int:
        return self.data.shape[0]

    @property
    def block_size(self) -> int:
        return self.data.shape[1]


def queue_init(max_blocks: int, block_size: int, dtype=jnp.uint64) -> RingQueue:
    use = jnp.zeros((max_blocks,), bool).at[0].set(True)
    return RingQueue(
        data=jnp.zeros((max_blocks, block_size), dtype),
        fe=jnp.zeros((max_blocks, block_size), jnp.int8),
        front=jnp.zeros((max_blocks,), jnp.int32),
        rear=jnp.zeros((max_blocks,), jnp.int32),
        wclosed=jnp.zeros((max_blocks,), bool),
        rclosed=jnp.zeros((max_blocks,), bool),
        nxt=jnp.full((max_blocks,), NO_BLK),
        use=use,
        recycles=jnp.zeros((max_blocks,), jnp.uint32),
        head_blk=jnp.int32(0),
        tail_blk=jnp.int32(0),
        pushed=jnp.int64(0),
        popped=jnp.int64(0),
    )


def queue_size(q: RingQueue) -> jnp.ndarray:
    return q.pushed - q.popped


def _chain(q: RingQueue, start: jnp.ndarray, span: int):
    """Unrolled walk of `span` chain blocks from `start`; -1 past the end.
    Returns ([span] ids, the continuation id after the last one)."""
    ids = []
    cur = start
    for _ in range(span):
        ids.append(cur)
        safe = jnp.maximum(cur, 0)
        cur = jnp.where(cur >= 0, q.nxt[safe], NO_BLK)
    return jnp.stack(ids), cur  # [span] int32, scalar int32


def push_batch(q: RingQueue, vals: jnp.ndarray, mask: jnp.ndarray):
    """Batched push (paper algs. 7+8). Returns (q', pushed_mask).

    Lanes fail only if the block pool is exhausted (addNode's `return false`).
    """
    K_lanes = vals.shape[0]
    B, M = q.block_size, q.max_blocks
    span = math.ceil(K_lanes / B) + 1

    mask = mask.astype(bool)
    offs = jnp.cumsum(mask.astype(jnp.int32)) - 1          # fetch-add analogue
    K = jnp.sum(mask.astype(jnp.int32))

    room0 = B - q.rear[q.tail_blk]
    n_new = jnp.maximum(0, -(-(K - room0) // B)).astype(jnp.int32)  # ceil div, >=0

    # --- allocate up to span new blocks from the use[] bitmap (alg. 8 scans
    # use[] for a free block; we do the scan as one vector ranking) ---
    free = ~q.use
    frank = jnp.cumsum(free.astype(jnp.int32)) - 1         # rank among free blocks
    slot_of = jnp.where(free & (frank < span), frank, span)
    new_ids = jnp.full((span,), NO_BLK).at[slot_of].set(
        jnp.arange(M, dtype=jnp.int32), mode="drop")
    j_idx = jnp.arange(span, dtype=jnp.int32)
    alloc = (j_idx < n_new) & (new_ids >= 0)               # blocks we truly take
    got_all = jnp.sum(alloc.astype(jnp.int32)) == n_new

    # --- lane -> (block, slot) ---
    in_tail = offs < room0
    j_lane = jnp.where(in_tail, 0, (offs - room0) // B)    # new-block index
    blk = jnp.where(
        in_tail,
        q.tail_blk,
        jnp.where(j_lane < span, new_ids[jnp.clip(j_lane, 0, span - 1)], NO_BLK),
    )
    slot = jnp.where(in_tail, q.rear[q.tail_blk] + offs, (offs - room0) % B)
    # allocation shortfalls only ever cut a *suffix* of the needed blocks
    # (free-rank assignment is in order), so failed lanes are a FIFO-safe tail
    del got_all
    ok = mask & (blk >= 0) & (slot < B)

    flat = jnp.where(ok, blk * B + slot, M * B)            # OOB -> dropped
    data = q.data.reshape(-1).at[flat].set(vals.astype(q.data.dtype), mode="drop").reshape(M, B)
    fe = q.fe.reshape(-1).at[flat].set(jnp.int8(FE_FULL), mode="drop").reshape(M, B)

    # --- counters & links ---
    k_ok = jnp.sum(ok, dtype=jnp.int32)
    take_tail = jnp.minimum(k_ok, jnp.maximum(room0, 0)).astype(jnp.int32)
    rear = q.rear.at[q.tail_blk].add(take_tail)
    new_counts = jnp.clip(k_ok - take_tail - j_idx * B, 0, B).astype(jnp.int32)
    rear = rear.at[jnp.where(alloc, new_ids, M)].set(new_counts, mode="drop")
    front = q.front.at[jnp.where(alloc, new_ids, M)].set(0, mode="drop")
    fe_rows = jnp.where(alloc, new_ids, M)
    use = q.use.at[fe_rows].set(True, mode="drop")
    wclosed = q.wclosed
    # wclose every block that is now full (rear == B): tail + interior new blocks
    wclosed = wclosed.at[q.tail_blk].set(jnp.where(rear[q.tail_blk] >= B, True, wclosed[q.tail_blk]))
    full_new = alloc & (new_counts >= B)
    wclosed = wclosed.at[jnp.where(full_new, new_ids, M)].set(True, mode="drop")

    # chain links: tail -> new0 -> new1 -> ...
    prev = jnp.concatenate([q.tail_blk[None], new_ids[:-1]])
    link_ok = alloc
    nxt = q.nxt.at[jnp.where(link_ok, prev, M)].set(new_ids, mode="drop")
    n_alloc = jnp.sum(alloc, dtype=jnp.int32)
    tail_blk = jnp.where(n_alloc > 0, new_ids[jnp.maximum(n_alloc - 1, 0)], q.tail_blk)

    q2 = q._replace(data=data, fe=fe, front=front, rear=rear, wclosed=wclosed,
                    nxt=nxt, use=use, tail_blk=tail_blk,
                    pushed=q.pushed + k_ok.astype(jnp.int64))
    return q2, ok


def pop_batch(q: RingQueue, n_lanes: int, want: jnp.ndarray | None = None):
    """Batched pop (paper algs. 9+10). Returns (q', vals, got_mask).

    Exhausted wclosed blocks are rclosed, unlinked, reset and recycled
    (recycle counter bump — the ABA guard); the tail block is never recycled
    (alg. 10's `n != cn` check).
    """
    B, M = q.block_size, q.max_blocks
    span = math.ceil(n_lanes / B) + 1
    if want is None:
        want = jnp.ones((n_lanes,), bool)
    want = want.astype(bool)
    rank = jnp.cumsum(want.astype(jnp.int32)) - 1

    ids, follow = _chain(q, q.head_blk, span)              # [span], cont.
    safe = jnp.maximum(ids, 0)
    valid_blk = ids >= 0
    fronts = jnp.where(valid_blk, q.front[safe], 0)
    rears = jnp.where(valid_blk, q.rear[safe], 0)
    avail = jnp.maximum(rears - fronts, 0)
    cum = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(avail)])
    total = cum[-1]

    got = want & (rank < total)
    j = jnp.searchsorted(cum[1:], rank, side="right").astype(jnp.int32)
    j = jnp.clip(j, 0, span - 1)
    blk = safe[j]
    slot = fronts[j] + rank - cum[j]
    flat = jnp.where(got, blk * B + slot, M * B)

    vals = q.data.reshape(-1)[jnp.minimum(flat, M * B - 1)]
    fe_at = q.fe.reshape(-1)[jnp.minimum(flat, M * B - 1)]
    got = got & (fe_at == FE_FULL)                          # invariant guard (retry semantics)
    vals = jnp.where(got, vals, jnp.zeros((), q.data.dtype))

    fe = q.fe.reshape(-1).at[jnp.where(got, flat, M * B)].set(
        jnp.int8(FE_CONSUMED), mode="drop").reshape(M, B)
    k = jnp.sum(got, dtype=jnp.int32)
    taken_j = jnp.clip(k - cum[:-1], 0, avail).astype(jnp.int32)
    front = q.front.at[jnp.where(valid_blk, ids, M)].add(taken_j, mode="drop")

    # --- recycle exhausted blocks (deleteNode) ---
    new_fronts = fronts + taken_j
    dead = valid_blk & q.wclosed[safe] & (new_fronts >= B) & (ids != q.tail_blk)
    dead_rows = jnp.where(dead, ids, M)
    fe = fe.at[dead_rows].set(jnp.int8(FE_EMPTY), mode="drop")
    front = front.at[dead_rows].set(0, mode="drop")
    rear = q.rear.at[dead_rows].set(0, mode="drop")
    wclosed = q.wclosed.at[dead_rows].set(False, mode="drop")
    rclosed = q.rclosed.at[dead_rows].set(False, mode="drop")
    nxt = q.nxt.at[dead_rows].set(NO_BLK, mode="drop")
    use = q.use.at[dead_rows].set(False, mode="drop")
    recycles = q.recycles.at[dead_rows].add(jnp.uint32(1), mode="drop")

    # head advances past the dead prefix: to the first alive chain block,
    # else to the chain CONTINUATION (the block after the last spanned one —
    # jumping straight to tail would orphan any unconsumed blocks between)
    alive = valid_blk & ~dead
    first_alive = jnp.argmax(alive)
    any_alive = jnp.any(alive)
    cont = jnp.where(follow >= 0, follow, q.tail_blk)
    head_blk = jnp.where(any_alive, safe[first_alive], cont)

    q2 = q._replace(fe=fe, front=front, rear=rear, wclosed=wclosed,
                    rclosed=rclosed, nxt=nxt, use=use, recycles=recycles,
                    head_blk=head_blk, popped=q.popped + k.astype(jnp.int64))
    return q2, vals, got


def push_one(q: RingQueue, val) -> tuple[RingQueue, jnp.ndarray]:
    q2, ok = push_batch(q, jnp.asarray([val], q.data.dtype), jnp.ones((1,), bool))
    return q2, ok[0]


def pop_one(q: RingQueue):
    q2, vals, got = pop_batch(q, 1)
    return q2, vals[0], got[0]
