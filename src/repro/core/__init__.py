"""Core data structures — the paper's contribution, TPU-native.

bits          key packing, splitmix64, bit reversal, geometric heights
blockpool     §V memory manager: id pool + free ring + ABA generations
ringqueue     §III LCRQ-adapted block queue with recycling
det_skiplist  §II deterministic 1-2-3-4 skiplist (the primary contribution)
rand_skiplist §VI randomized comparator (table IV)
hashtable     §VII fixed-slot + two-level MWMR tables
splitorder    §VII split-order + two-level split-order tables
routing       §I/§VI hierarchical NUMA->mesh key routing (all-to-all)
ordered_sharded  sharded ordered-set service (routing + skiplist)
"""
