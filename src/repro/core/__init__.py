"""Core data structures — the paper's contribution, TPU-native.

bits          key packing, splitmix64, bit reversal, geometric heights
layout        shared flat-memory layout layer: (hi, lo) u32 key planes,
              kv/block array allocation, level-major skiplist + bucket-major
              hash layouts — the shapes `repro.kernels.*` consume
blockpool     §V memory manager: id pool + free ring + ABA generations
ringqueue     §III LCRQ-adapted block queue with recycling
det_skiplist  §II deterministic 1-2-3-4 skiplist (the primary contribution)
rand_skiplist §VI randomized comparator (table IV)
hashtable     §VII fixed-slot + two-level MWMR tables (insert/find/delete)
splitorder    §VII split-order + two-level split-order tables
routing       §I/§VI hierarchical NUMA->mesh key routing (all-to-all)
ordered_sharded  compatibility veneer: the original skiplist-backed sharded
                 service API, now thin wrappers over `repro.store.engine`

The uniform access layer lives one package up in `repro.store`: every
structure here is also registered as a `Store` backend (api/backends), the
§IX hierarchical composition is `repro.store.tiers` (hot hash tier over the
ordered skiplist), and the mesh-sharded engine over any backend is
`repro.store.engine`. New code should go through that protocol; this package
stays the home of the raw batched primitives.
"""
