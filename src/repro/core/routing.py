"""Hierarchical NUMA->mesh key routing (paper §I, §VI, §VII).

The paper's pattern: partition the key space by top key bits, one structure
instance per NUMA node; per-thread lock-free queues carry each key to a
thread pinned on the owner node; all structure memory accesses stay local.
"Hierarchical usage of concurrent data structures ... reduces memory accesses
from remote NUMA nodes."

Mesh adaptation: NUMA node -> mesh shard; the queue hop -> `all_to_all`
inside `shard_map`; the hierarchy (socket -> node) -> routing one mesh axis
at a time, coarsest (slowest link) first: on the multi-pod mesh that is the
"pod" axis (DCI) then the "data" axis (ICI) — two-stage all-to-all, exactly
the paper's proposal of hierarchical structure usage. MoE expert dispatch
reuses this module with expert-id in place of key bits.

Everything here runs INSIDE a shard_map body. Buckets are capacity-bounded
(static shapes); overflow lanes are dropped and *counted* — the bounded
analogue of the paper's unbounded queues, with the drop count surfaced so
capacity factors can be tuned (and asserted zero in tests).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.bits import KEY_INF


class RouteResult(NamedTuple):
    keys: jnp.ndarray      # [P] routed keys (KEY_INF padding)
    vals: jnp.ndarray      # [P] routed payloads
    aux: jnp.ndarray       # [P] routed aux (e.g. op codes), int32
    origin: jnp.ndarray    # [P] uint64 packed (src_shard << 32 | src_lane)
    valid: jnp.ndarray     # [P] bool
    dropped: jnp.ndarray   # scalar int32 — capacity overflow count (telemetry)


def owner_of(keys: jnp.ndarray, n_shards: int) -> jnp.ndarray:
    """Owner shard from the top key bits (paper: 3 MSBs -> 8 skiplists)."""
    b = int(math.log2(n_shards))
    if b == 0:
        return jnp.zeros(keys.shape, jnp.int32)
    return (keys >> jnp.uint64(64 - b)).astype(jnp.int32)


def bucketize(dest: jnp.ndarray, valid: jnp.ndarray, payloads: Sequence[jnp.ndarray],
              n_dest: int, capacity: int):
    """Group lanes by destination with per-destination capacity.

    Returns ([n_dest, capacity] buffers..., valid[n_dest, capacity], dropped).
    Deterministic: lanes sort stably by dest, overflow drops highest ranks.
    """
    sort_key = jnp.where(valid, dest, n_dest)   # invalid lanes park at n_dest
    order = jnp.argsort(sort_key, stable=True)
    sd = sort_key[order]                        # sorted — safe for searchsorted
    sv = valid[order]
    run_start = jnp.searchsorted(sd, sd, side="left").astype(jnp.int32)
    rank = jnp.arange(dest.shape[0], dtype=jnp.int32) - run_start
    keep = sv & (rank < capacity) & (sd < n_dest)
    dropped = jnp.sum(sv & ~keep, dtype=jnp.int32)
    slot = jnp.where(keep, sd * capacity + rank, n_dest * capacity)
    out = []
    for p in payloads:
        buf = jnp.zeros((n_dest * capacity,) + p.shape[1:], p.dtype)
        buf = buf.at[slot].set(p[order], mode="drop")
        out.append(buf.reshape((n_dest, capacity) + p.shape[1:]))
    vbuf = jnp.zeros((n_dest * capacity,), bool).at[slot].set(keep, mode="drop")
    return out, vbuf.reshape(n_dest, capacity), dropped


def _a2a(x: jnp.ndarray, name: str) -> jnp.ndarray:
    """all_to_all with bool transport (collectives move numeric payloads)."""
    if x.dtype == jnp.bool_:
        return jax.lax.all_to_all(x.astype(jnp.uint8), name, 0, 0,
                                  tiled=False).astype(bool)
    return jax.lax.all_to_all(x, name, 0, 0, tiled=False)


def mesh_shard_map(f, mesh, in_specs, out_specs, axis_names=None,
                   check_vma=None):
    """`jax.shard_map` compat across the 0.4.x -> 0.5+ API change.

    New jax: top-level `jax.shard_map(..., axis_names=..., check_vma=...)`.
    Old jax: `jax.experimental.shard_map.shard_map(..., auto=..., check_rep=...)`
    where `auto` is the COMPLEMENT of axis_names and check_rep ~ check_vma.
    """
    try:
        from jax import shard_map as _sm
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm
        kw = {}
        if check_vma is not None:
            kw["check_rep"] = check_vma
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - set(axis_names)
            if auto:
                kw["auto"] = auto
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def axis_size(name: str) -> int:
    """Static mesh-axis size inside shard_map — `jax.lax.axis_size` compat
    (jax <= 0.4.x has no lax.axis_size; there, core.axis_frame(name) IS the
    size)."""
    try:
        return jax.lax.axis_size(name)
    except AttributeError:
        return jax.core.axis_frame(name)


def shard_linear_id(axis_names: Sequence[str]) -> jnp.ndarray:
    """Flat shard id over the routing axes (row-major, coarsest first)."""
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * axis_size(name) + jax.lax.axis_index(name).astype(jnp.int32)
    return idx


def route_to_owners(keys: jnp.ndarray, vals: jnp.ndarray, aux: jnp.ndarray,
                    valid: jnp.ndarray, axis_names: Sequence[str],
                    axis_sizes: Sequence[int], pool: int) -> RouteResult:
    """Route (key, val, aux) to owner shards, one mesh axis per stage,
    coarsest first (pod -> data): the hierarchical NUMA route.

    Must run inside shard_map over (at least) `axis_names`. `pool` is the
    per-shard item budget after every stage (static).
    """
    n_shards = int(math.prod(axis_sizes))
    me = shard_linear_id(axis_names)
    lane = jnp.arange(keys.shape[0], dtype=jnp.uint32)
    origin = (me.astype(jnp.uint64) << jnp.uint64(32)) | lane.astype(jnp.uint64)

    dropped = jnp.int32(0)
    # digit weights, coarsest first: owner = d0 * (s1*s2..) + d1 * (s2..) + ...
    weights = []
    rem = n_shards
    for s in axis_sizes:
        rem //= s
        weights.append(rem)

    for name, size, w in zip(axis_names, axis_sizes, weights):
        owner = owner_of(keys, n_shards)
        digit = (owner // w) % size
        cap = max(1, -(-pool // size))
        (k_b, v_b, a_b, o_b), val_b, drop = bucketize(
            digit, valid, [keys, vals, aux, origin], size, cap)
        dropped = dropped + drop
        # the queue hop: chunk i -> shard with digit i on this axis
        k_b, v_b, a_b, o_b, val_b = (_a2a(k_b, name), _a2a(v_b, name),
                                     _a2a(a_b, name), _a2a(o_b, name),
                                     _a2a(val_b, name))
        flat = lambda x: x.reshape((size * cap,) + x.shape[2:])
        keys, vals, aux, origin, valid = map(flat, (k_b, v_b, a_b, o_b, val_b))
        # re-pack to the pool budget (compact valid lanes first)
        keys, vals, aux, origin, valid, drop2 = _compact(
            [keys, vals, aux, origin], valid, pool)
        dropped = dropped + drop2
    keys = jnp.where(valid, keys, KEY_INF)
    return RouteResult(keys=keys, vals=vals, aux=aux, origin=origin,
                       valid=valid, dropped=dropped)


def _compact(payloads: Sequence[jnp.ndarray], valid: jnp.ndarray, out_size: int):
    """Compact valid lanes to a prefix of a fixed-size pool. Returns
    (*payloads, valid, dropped) — overflow is counted, never silent."""
    rank = jnp.cumsum(valid.astype(jnp.int32)) - 1
    keep = valid & (rank < out_size)
    dropped = jnp.sum(valid & ~keep, dtype=jnp.int32)
    slot = jnp.where(keep, rank, out_size)
    outs = []
    for p in payloads:
        buf = jnp.zeros((out_size,) + p.shape[1:], p.dtype)
        outs.append(buf.at[slot].set(p, mode="drop"))
    vout = jnp.zeros((out_size,), bool).at[slot].set(keep, mode="drop")
    return (*outs, vout, dropped)


def route_back(results: jnp.ndarray, found: jnp.ndarray, origin: jnp.ndarray,
               valid: jnp.ndarray, axis_names: Sequence[str],
               axis_sizes: Sequence[int], lanes_out: int):
    """Send per-op results back to their source shard + lane.

    Returns (results[lanes_out], found[lanes_out]) scattered into the original
    lane positions. Reverse hop order (finest axis first) — the return queue.
    """
    src = (origin >> jnp.uint64(32)).astype(jnp.int32)
    lane = (origin & jnp.uint64(0xFFFFFFFF)).astype(jnp.int32)
    pool = results.shape[0]

    weights = []
    rem = int(math.prod(axis_sizes))
    for s in axis_sizes:
        rem //= s
        weights.append(rem)

    for name, size, w in zip(reversed(axis_names), reversed(axis_sizes),
                             reversed(weights)):
        digit = (src // w) % size
        cap = max(1, -(-pool // size))
        (r_b, f_b, s_b, l_b), val_b, _ = bucketize(
            digit, valid, [results, found.astype(jnp.int32), src, lane], size, cap)
        r_b, f_b, s_b, l_b, val_b = (_a2a(r_b, name), _a2a(f_b, name),
                                     _a2a(s_b, name), _a2a(l_b, name),
                                     _a2a(val_b, name))
        flat = lambda x: x.reshape((size * cap,) + x.shape[2:])
        results, found_i, src, lane, valid = (flat(r_b), flat(f_b), flat(s_b),
                                              flat(l_b), flat(val_b))
        found = found_i.astype(bool)
        results, found_i2, src, lane, valid, _ = _compact(
            [results, found.astype(jnp.int32), src, lane], valid, pool)
        found = found_i2.astype(bool)

    # scatter into original lanes
    slot = jnp.where(valid, lane, lanes_out)
    out_r = jnp.zeros((lanes_out,) + results.shape[1:], results.dtype
                      ).at[slot].set(results, mode="drop")
    out_f = jnp.zeros((lanes_out,), bool).at[slot].set(found & valid, mode="drop")
    return out_r, out_f
