"""Shared flat-memory layout layer (the paper's §V discipline, one module).

The paper's performance numbers come from memory-layout discipline: nodes are
pool-allocated in blocks, placed for cache/NUMA locality, and probed with
constant-cost loops. Before this module each core structure carried its own
ad-hoc arrays; now the conventions live in one place and the Pallas kernels
(`repro.kernels.skiplist_search`, `repro.kernels.hash_probe`) consume exactly
these layouts — the layout and the probe loop are co-designed, which is the
whole trick (cf. "Skiplists with Foresight", locality-optimized B-skiplists).

Conventions:

* **Key/value/tombstone arrays** — keys are uint64 with `KEY_INF` padding
  (`EMPTY` for hash slots is the same sentinel), values are uint64 zeros.
  `kv_arrays` allocates the pair; every structure's init goes through it so
  the padding contract has one source of truth.
* **(hi, lo) u32 pairs** — TPU has no native u64 lanes, so kernels receive
  keys as two u32 planes compared lexicographically (`key_leq`). This is the
  hardware adaptation of the paper's 128-bit key|next atomic words.
* **Level-major skiplist layout** — every index level is one contiguous row
  of a `[L, C1]` stack (u32 hi/lo planes + int32 child starts), terminal
  level as flat `[C]` planes + int8 marks. Whole-array BlockSpecs make the
  entire index VMEM-resident: the CPU path through HBM pointer-land becomes
  L on-chip hops.
* **Block-major B-skiplist layout** — the SAME deterministic skiplist,
  re-viewed as lane-width fat nodes: the sorted terminal level is cut into
  blocks of `BSKIP_BLOCK` = 128 keys (one VPU register tile) and every
  index level holds nodes of 128 child maxima, so a walk compares a WHOLE
  block per step (one `key_lt` vector compare + sum-reduction = the
  searchsorted-left position) instead of touching one key per step. Probe
  cost drops from `num_levels + 1` fan-out-4 steps to
  `ceil(log_128(C/128)) + 1` block steps. Derived at probe time by
  `bskiplist_layout` from the same state `skiplist_layout` reads — the
  layout is an execution knob, not a second structure, which is what keeps
  results/residency bit-identical across layouts.
* **Bucket-major hash layout** — a bucket is one contiguous `[B]`-wide row
  (`[M, B]` planes); one bucket = one VMEM tile row, compared in a single
  vector op. `hash_slot` is the shared slot function (splitmix64, low bits).
* **Pooled blocks** — `block_arrays` allocates `[P, ...]` pooled payload
  arrays (two-level hash L2 tables, ring-queue blocks) matching the
  `core.blockpool` id/generation allocator.
* **Eviction-policy metadata** — `policy_arrays` allocates the per-entry
  int32 metadata plane a tiered hot table carries NEXT TO its key plane
  (same `[M, B]` shape, so one bucket row of keys and one row of metadata
  are adjacent tiles). LRU-by-batch stores the last-touch batch clock;
  size-aware stores `val_weight` (payload byte count). The probe kernels
  read keys only; the policy planes are updated on the u64 host path.
* **Metrics plane** — `metrics_plane` allocates the observability layer's
  jit-carried int64 counters (one scalar per `repro.store.obs` metric name)
  as a dict pytree that rides inside the store state: counters shard and
  checkpoint exactly like the key planes they measure, and are held to the
  same cross-exec-mode bit-identity contract as results.
* **Spill runs** — `spill_arrays` allocates the cold host-spill tier: flat
  append-only key/value planes (`kv_arrays` conventions) plus tombstone and
  run-boundary marks. Each batch that spills appends one SORTED run;
  membership is a per-run binary search over the `run_offsets` boundary
  plane (O(runs * log run-len); scans merge the runs — store/tiers.py).
  The live run count is capped at `MAX_SPILL_RUNS` (the tier compacts
  before the cap can be exceeded), which is what gives every probe path —
  jnp reference, Pallas interpret, compiled — a STATIC run-boundary plane
  to search over.

Pure layout, no execution: the probe loops over these shapes live in
`repro.kernels.*` and are dispatched by `repro.store.exec`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.bits import EMPTY, KEY_INF, hash64


# ---------------------------------------------------------------------------
# sizing helpers
# ---------------------------------------------------------------------------

def pow2_floor(n: int) -> int:
    """Largest power of two <= max(n, 1)."""
    return 1 << max(int(n).bit_length() - 1, 0)


def is_pow2(n: int) -> bool:
    return n > 0 and n & (n - 1) == 0


# ---------------------------------------------------------------------------
# flat key/value storage
# ---------------------------------------------------------------------------

def kv_arrays(shape, key_fill=KEY_INF):
    """The shared (keys, vals) allocation: u64 keys filled with the sentinel
    (KEY_INF == EMPTY), u64 zero values. Used by every structure's init."""
    if isinstance(shape, int):
        shape = (shape,)
    return jnp.full(shape, key_fill), jnp.zeros(shape, jnp.uint64)


def block_arrays(num_blocks: int, block_shape, key_fill=KEY_INF):
    """Pooled `[P, ...]` key/value payload arrays for a `core.blockpool`
    allocator of `num_blocks` ids (two-level hash L2 tables, queue blocks)."""
    if isinstance(block_shape, int):
        block_shape = (block_shape,)
    return kv_arrays((num_blocks,) + tuple(block_shape), key_fill)


# ---------------------------------------------------------------------------
# in-array metrics plane (the observability layer's jit-carried counters)
# ---------------------------------------------------------------------------

def metrics_plane(names) -> dict:
    """The observability layer's counter allocation: one int64 zero scalar
    per metric name, as a dict pytree that rides inside a store state (so
    the counters are jit-carried, shard with the state on dim 0 like any
    other leaf, and survive checkpointing for free). int64 matches the
    stats counters; the schema itself (which names) is owned by
    `repro.store.obs.METRICS_SCHEMA` — this module only owns the
    allocation convention, like every other plane here."""
    return {n: jnp.zeros((), jnp.int64) for n in names}


# ---------------------------------------------------------------------------
# eviction-policy metadata + spill-run planes (the §IX tier stack)
# ---------------------------------------------------------------------------

def policy_arrays(shape) -> jnp.ndarray:
    """Per-entry eviction-policy metadata, one int32 per stored key (same
    shape as the key plane it annotates — for a bucket table, `[M, B]`).
    The meaning is the policy's: LRU-by-batch stamps the batch clock at
    insert/touch; size-aware stamps `val_weight`. Zeros = empty cells."""
    if isinstance(shape, int):
        shape = (shape,)
    return jnp.zeros(shape, jnp.int32)


def val_weight(vals: jnp.ndarray) -> jnp.ndarray:
    """The size-aware policy's deterministic payload weight: bytes needed to
    encode the u64 value (1..8). A pure function of the stored value, so
    every exec mode and every shard computes the same weight."""
    v = vals.astype(jnp.uint64)
    bits = jnp.zeros(v.shape, jnp.int32)
    for shift in (32, 16, 8, 4, 2, 1):
        big = v >= (jnp.uint64(1) << jnp.uint64(shift))
        bits = bits + jnp.where(big, shift, 0)
        v = jnp.where(big, v >> jnp.uint64(shift), v)
    bits = bits + v.astype(jnp.int32)        # +1 when any bit remains
    return jnp.maximum((bits + 7) // 8, 1)   # bytes, floor 1


def spill_arrays(capacity: int):
    """The cold spill tier's planes: append-only u64 (keys, vals) with the
    shared KEY_INF padding, bool tombstones (`dead`), and bool run-boundary
    marks (`run_start[i]` = entry i opens a sorted run). Append-only: cells
    `< n` are immutable except for tombstoning, so the whole region can live
    in host/pinned memory and be DMA'd in bulk."""
    keys, vals = kv_arrays(capacity)
    return keys, vals, jnp.zeros((capacity,), bool), jnp.zeros((capacity,), bool)


# MAX_SPILL_RUNS: the static cap on live sorted runs in a spill tier. The
# probe paths (jnp reference AND the fused tier-find kernel) binary-search
# each run through a fixed-size `run_offsets` boundary plane, so the cap is
# what makes the probe a static-shape program; the tier stack enforces it by
# compacting (merging all runs into one) before an `apply`/`flush` could
# push the count past the cap (store/tiers.py appends at most 3 runs per
# apply, 1 per flush).
MAX_SPILL_RUNS = 16

# Spill maintenance thresholds — ONE source of truth for the tier stack's
# compaction policy (`store.tiers.spill_maintain`), the kernels' static
# sizing assumptions, and the docs:
#   SPILL_COMPACT_DEAD_FRAC   compact when tombstones exceed 1/FRAC of the
#                             appended total (the churn rule — the same 25%
#                             discipline as the skiplist compaction)
#   SPILL_RUNS_PER_APPLY      worst-case sorted runs ONE apply can append
#                             (eviction demotes, insert overflow, promotion
#                             demotes); compacting when `runs +
#                             RUNS_PER_APPLY > MAX_SPILL_RUNS` is what makes
#                             the run cap an invariant rather than a hope
SPILL_COMPACT_DEAD_FRAC = 4
SPILL_RUNS_PER_APPLY = 3


def run_offsets(run_start: jnp.ndarray, n: jnp.ndarray,
                max_runs: int = MAX_SPILL_RUNS) -> jnp.ndarray:
    """The run-boundary plane: int32 [max_runs + 1] where entry r is the
    start cell of sorted run r (runs in append order) and every entry past
    the live run count — including the final sentinel — is the append
    cursor `n`. Run r therefore spans cells [off[r], off[r + 1]), empty for
    padded runs, which is exactly the loop bound the per-run binary search
    wants. Precondition (maintained by the tier stack): at most `max_runs`
    live runs, and `run_start[0]` is set whenever n > 0."""
    S = run_start.shape[0]
    idx = jnp.arange(S, dtype=jnp.int32)
    rid = jnp.cumsum(run_start.astype(jnp.int32)) - 1
    ok = run_start & (idx < n) & (rid < max_runs)
    tgt = jnp.where(ok, rid, max_runs + 1)       # out of bounds -> dropped
    return jnp.full((max_runs + 1,), n, jnp.int32).at[tgt].min(
        idx, mode="drop")


class SpillLayout(NamedTuple):
    """A spill tier's probe view in kernel conventions: (hi, lo) u32 key
    planes, int8 tombstones, and the `run_offsets` boundary plane. Values
    stay outside (u64 gathers happen on the host path, like every other
    kernel wrapper)."""
    key_hi: jnp.ndarray    # [S] uint32
    key_lo: jnp.ndarray    # [S] uint32
    dead: jnp.ndarray      # [S] int8 tombstones
    run_off: jnp.ndarray   # [MAX_SPILL_RUNS + 1] int32 run boundaries

    # maintenance thresholds (class constants, not tuple fields) — the
    # names the tier stack and the docs read; values owned by the module
    # constants above so layout sizing and compaction policy stay in sync
    MAX_RUNS = MAX_SPILL_RUNS
    COMPACT_DEAD_FRAC = SPILL_COMPACT_DEAD_FRAC
    RUNS_PER_APPLY = SPILL_RUNS_PER_APPLY


def spill_layout(keys: jnp.ndarray, dead: jnp.ndarray,
                 run_start: jnp.ndarray, n: jnp.ndarray,
                 max_runs: int = MAX_SPILL_RUNS) -> SpillLayout:
    """SpillTier planes -> kernel layout (see `run_offsets`)."""
    kh, kl = split_u64(keys)
    return SpillLayout(key_hi=kh, key_lo=kl, dead=dead.astype(jnp.int8),
                       run_off=run_offsets(run_start, n, max_runs))


# ---------------------------------------------------------------------------
# the (hi, lo) u32 key convention
# ---------------------------------------------------------------------------

def split_u64(x: jnp.ndarray):
    """u64 -> (hi u32, lo u32) planes — the kernel-side key representation."""
    return ((x >> jnp.uint64(32)).astype(jnp.uint32),
            (x & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32))


def key_leq(qh, ql, kh, kl):
    """Lexicographic (hi, lo) <= — bitwise-equal to u64 compare. The ONE
    comparison every kernel uses, so parity with the u64 reference paths is
    by construction."""
    return (qh < kh) | ((qh == kh) & (ql <= kl))


def key_lt(ah, al, bh, bl):
    """Lexicographic (hi, lo) strict < — bitwise-equal to u64 compare. The
    binary-search step of the searchsorted-style kernels (spill runs,
    split-order tables): `side="left"` semantics need strict less-than."""
    return (ah < bh) | ((ah == bh) & (al < bl))


# ---------------------------------------------------------------------------
# level-major skiplist layout (det_skiplist -> skiplist_search kernel)
# ---------------------------------------------------------------------------

class SkiplistLayout(NamedTuple):
    """The deterministic skiplist as VMEM-tileable flat planes.

    Levels are stacked bottom-up into one [L, C1] rectangle (C1 = widest
    level's capacity, KEY_INF padding): row r holds level r's max-of-group
    keys and child start indices. The terminal level stays flat [C]."""
    lvl_hi: jnp.ndarray     # [L, C1] uint32
    lvl_lo: jnp.ndarray     # [L, C1] uint32
    lvl_child: jnp.ndarray  # [L, C1] int32 (group start in the level below)
    term_hi: jnp.ndarray    # [C] uint32
    term_lo: jnp.ndarray    # [C] uint32
    term_mark: jnp.ndarray  # [C] int8 tombstones


def skiplist_layout(s) -> SkiplistLayout:
    """DetSkiplist (or any state with the same level_keys/level_child/
    term_keys/term_mark fields) -> level-major kernel layout."""
    c1 = s.level_keys[0].shape[0]
    his, los, chs = [], [], []
    for lk, lc in zip(s.level_keys, s.level_child):
        pad = c1 - lk.shape[0]
        lk = jnp.pad(lk, (0, pad), constant_values=KEY_INF)
        lc = jnp.pad(lc, (0, pad))
        h, l = split_u64(lk)
        his.append(h)
        los.append(l)
        chs.append(lc.astype(jnp.int32))
    th, tl = split_u64(s.term_keys)
    return SkiplistLayout(lvl_hi=jnp.stack(his), lvl_lo=jnp.stack(los),
                          lvl_child=jnp.stack(chs), term_hi=th, term_lo=tl,
                          term_mark=s.term_mark.astype(jnp.int8))


# ---------------------------------------------------------------------------
# block-major B-skiplist layout (det_skiplist -> bskiplist_walk kernel)
# ---------------------------------------------------------------------------

# Lane-width block: one B-skiplist node holds this many sorted keys, matched
# to the 128-lane VPU register tile so a node compare is ONE vector op.
BSKIP_BLOCK = 128


class BSkiplistLayout(NamedTuple):
    """The deterministic skiplist re-blocked into lane-width fat nodes
    (the B-skiplist view; cf. 2507.21492 / "Skiplists with Foresight").

    Derived at probe time from the SAME DetSkiplist state as
    `skiplist_layout` — state never changes shape, so switching layouts
    cannot perturb residency or results. The sorted terminal level (KEY_INF
    padding) is reshaped into NB = ceil(C/B) blocks of B keys; index level
    0 nodes hold the B maxima of B consecutive terminal blocks (block max =
    LAST entry, because blocks are sorted with KEY_INF padding at the end),
    level l+1 summarizes level l the same way, until one node remains.
    Index levels are stacked bottom-up into a [L, W] rectangle (W = widest
    level's node count * B; node j of a row spans cells [j*B, (j+1)*B),
    KEY_INF padding). A walk step loads one node row and computes
    `sum(key_lt(entry, q))` — the searchsorted-left position of q — so the
    descent is L + 1 whole-block compares total."""
    blk_hi: jnp.ndarray     # [L, W] uint32 index-node entries (hi)
    blk_lo: jnp.ndarray     # [L, W] uint32 index-node entries (lo)
    term_hi: jnp.ndarray    # [NB * B] uint32 terminal keys (hi)
    term_lo: jnp.ndarray    # [NB * B] uint32 terminal keys (lo)
    term_mark: jnp.ndarray  # [NB * B] int8 tombstones

    @property
    def num_levels(self) -> int:
        return self.blk_hi.shape[0]


def bskip_num_levels(capacity: int, block: int = BSKIP_BLOCK) -> int:
    """Index levels a `bskiplist_layout` over `capacity` terminals has —
    the blocked walk runs this + 1 (terminal) block compares. Static, so
    benches and tests can report steps/plan without building a layout."""
    nb = -(-capacity // block)
    levels = 1                                 # always >= 1 (root node)
    while -(-nb // block) > 1:
        nb = -(-nb // block)
        levels += 1
    return levels


def bskiplist_layout(s, block: int = BSKIP_BLOCK) -> BSkiplistLayout:
    """DetSkiplist (or any state with sorted KEY_INF-padded term_keys +
    term_mark) -> block-major kernel layout. Pure reshape/reduce over the
    terminal planes: index levels are DERIVED, mirroring how
    `_rebuild_levels` derives the level-major index — deterministic block
    splits/merges fall out of the batched sorted-merge for free (every
    non-tail block holds exactly B live keys)."""
    B = block
    C = s.term_keys.shape[0]
    nb = -(-C // B)
    tk = jnp.pad(s.term_keys, (0, nb * B - C), constant_values=KEY_INF)
    tm = jnp.pad(s.term_mark.astype(jnp.int8), (0, nb * B - C))
    th, tl = split_u64(tk)

    # bottom-up node planes: entries of level 0 = terminal block maxima
    rows, counts = [], []
    cur = tk.reshape(nb, B)[:, -1]             # [nb] block maxima (sorted)
    while True:
        n = cur.shape[0]
        nn = -(-n // B)
        row = jnp.pad(cur, (0, nn * B - n), constant_values=KEY_INF)
        rows.append(row)
        counts.append(nn)
        cur = row.reshape(nn, B)[:, -1]        # node maxima for level above
        if nn == 1:
            break
    W = counts[0] * B
    his, los = [], []
    for row in rows:
        row = jnp.pad(row, (0, W - row.shape[0]), constant_values=KEY_INF)
        h, l = split_u64(row)
        his.append(h)
        los.append(l)
    return BSkiplistLayout(blk_hi=jnp.stack(his), blk_lo=jnp.stack(los),
                           term_hi=th, term_lo=tl, term_mark=tm)


# ---------------------------------------------------------------------------
# bucket-major hash layout (FixedHash -> hash_probe kernel)
# ---------------------------------------------------------------------------

class BucketLayout(NamedTuple):
    """A fixed-slot table's keys as u32 planes: one bucket = one [B] row =
    one VMEM tile row, probed in a single vector compare."""
    key_hi: jnp.ndarray  # [M, B] uint32
    key_lo: jnp.ndarray  # [M, B] uint32

    @property
    def num_slots(self) -> int:
        return self.key_hi.shape[0]

    @property
    def bucket(self) -> int:
        return self.key_hi.shape[1]


def bucket_layout(keys2d: jnp.ndarray) -> BucketLayout:
    """[M, B] u64 bucket keys (FixedHash.keys, TwoLevelHash.l1_keys) ->
    kernel layout."""
    kh, kl = split_u64(keys2d)
    return BucketLayout(key_hi=kh, key_lo=kl)


def hash_slot(keys: jnp.ndarray, num_slots: int, *,
              prehashed: bool = False) -> jnp.ndarray:
    """The shared slot function: s = splitmix64(k) mod M, M a power of two.
    Computed on the u64 host path and handed to kernels as int32 (TPU lanes
    have no u64, so the scramble stays outside the kernel). Pass
    `prehashed=True` when `keys` is already the scrambled hash (callers that
    slice several bit fields out of one hash)."""
    hv = keys.astype(jnp.uint64) if prehashed else hash64(keys)
    return (hv & jnp.uint64(num_slots - 1)).astype(jnp.int32)
