"""Key/bit utilities shared by every core data structure.

The paper stores a 64-bit key and a 64-bit next-pointer in one 128-bit atomic
word and extracts the halves with bit masks. In a functional setting we keep
keys as plain uint64 and, where the paper packs (key, pointer), we pack
(key_hi32 | payload_lo32) or keep parallel arrays updated in a single scatter
(the linearization point).

splitmix64 is the hash used everywhere (the paper scrambles 64-bit integers
with Boost hash functions); bit-reversal implements split-ordering (§VII).
"""
from __future__ import annotations

import jax.numpy as jnp

# Sentinels: the paper's head key is 2**64 - 1 and sentinel tail nodes point to
# themselves. We reserve the max key as +inf padding ("tail") and max-1 as the
# largest storable key.
KEY_INF = jnp.uint64(0xFFFFFFFFFFFFFFFF)
KEY_MAX = jnp.uint64(0xFFFFFFFFFFFFFFFE)
EMPTY = KEY_INF  # empty hash-table slot marker

_U = jnp.uint64


def splitmix64(x: jnp.ndarray) -> jnp.ndarray:
    """splitmix64 finalizer — a high-quality 64-bit scrambler."""
    x = x.astype(jnp.uint64)
    x = x + _U(0x9E3779B97F4A7C15)
    x = (x ^ (x >> _U(30))) * _U(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> _U(27))) * _U(0x94D049BB133111EB)
    x = x ^ (x >> _U(31))
    return x


def hash64(x: jnp.ndarray) -> jnp.ndarray:
    return splitmix64(x)


def bitrev64(x: jnp.ndarray) -> jnp.ndarray:
    """Reverse the bits of a uint64 (split-ordering: sort keys by reversed hash).

    log-step swap network — 6 vector ops, no loops.
    """
    x = x.astype(jnp.uint64)
    x = ((x & _U(0x5555555555555555)) << _U(1)) | ((x & _U(0xAAAAAAAAAAAAAAAA)) >> _U(1))
    x = ((x & _U(0x3333333333333333)) << _U(2)) | ((x & _U(0xCCCCCCCCCCCCCCCC)) >> _U(2))
    x = ((x & _U(0x0F0F0F0F0F0F0F0F)) << _U(4)) | ((x & _U(0xF0F0F0F0F0F0F0F0)) >> _U(4))
    x = ((x & _U(0x00FF00FF00FF00FF)) << _U(8)) | ((x & _U(0xFF00FF00FF00FF00)) >> _U(8))
    x = ((x & _U(0x0000FFFF0000FFFF)) << _U(16)) | ((x & _U(0xFFFF0000FFFF0000)) >> _U(16))
    x = (x << _U(32)) | (x >> _U(32))
    return x


def geometric_height(key: jnp.ndarray, max_height: int, p_shift: int = 2) -> jnp.ndarray:
    """Random-skiplist node height from the key's hash: P(h >= j) = (1/4)^j.

    Counts consecutive zero 2-bit groups from the LSB of splitmix64(key) —
    the deterministic-by-hash analogue of the paper's RNG-driven node heights
    (level j+1 with probability (1/t)^j, t = 4).
    """
    h = splitmix64(key)
    height = jnp.zeros(key.shape, dtype=jnp.int32)
    alive = jnp.ones(key.shape, dtype=bool)
    for j in range(max_height):
        bits = (h >> _U(p_shift * j)) & _U((1 << p_shift) - 1)
        alive = alive & (bits == _U(0))
        height = height + alive.astype(jnp.int32)
    return height  # 0-based extra height above the terminal level


def pack_key_payload(key_hi32: jnp.ndarray, payload: jnp.ndarray) -> jnp.ndarray:
    """Pack a 32-bit key tag and 32-bit payload into one uint64 (analogue of the
    paper's 128-bit key|next word, halved for TPU-friendly widths)."""
    return (key_hi32.astype(jnp.uint64) << _U(32)) | (payload.astype(jnp.uint64) & _U(0xFFFFFFFF))


def unpack_key_payload(word: jnp.ndarray):
    return (word >> _U(32)).astype(jnp.uint32), (word & _U(0xFFFFFFFF)).astype(jnp.uint32)


def dup_in_run(same_as_prev: jnp.ndarray, masked: jnp.ndarray) -> jnp.ndarray:
    """In-batch duplicate mask over a SORTED batch: True for every masked lane
    that is not the FIRST MASKED lane of its equal-key run.

    `same_as_prev[i]` says lane i has the same key(s) as lane i-1 (with
    same_as_prev[0] == False). Counting only masked lanes matters: a run can
    interleave masked and unmasked lanes (e.g. a FIND lane between two
    DELETE lanes for the same key) and the first *masked* lane must win —
    this is the deterministic linearization tie-break.
    """
    import jax

    idx = jnp.arange(same_as_prev.shape[0], dtype=jnp.int32)
    run_first = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(~same_as_prev, idx, -1))
    c = jnp.cumsum(masked.astype(jnp.int32))
    m_i = masked.astype(jnp.int32)
    before = c[run_first] - m_i[run_first]
    rank = c - m_i - before
    return masked & (rank > 0)


def make_priority_key(priority: jnp.ndarray, ticket: jnp.ndarray) -> jnp.ndarray:
    """(priority, ticket) -> orderable u64: priority in high 32, ticket low 32.

    Used by the serving scheduler's skiplist index; ticket breaks ties
    deterministically (the linearization order)."""
    return (priority.astype(jnp.uint64) << _U(32)) | (ticket.astype(jnp.uint64) & _U(0xFFFFFFFF))
