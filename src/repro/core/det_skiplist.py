"""Concurrent deterministic 1-2-3-4 skiplist (paper §II) — TPU-native encoding.

The paper's structure: a terminal sorted linked list of (key, data) nodes plus
log n index levels; every non-terminal node covers 2..4 children ("1-2-3-4"
criterion), node key = max of its children's keys, sentinel tail/bottom nodes,
mark bits for lazy deletion, lock-free Find, and proactive top-down
rebalancing whose total work is linear in the number of operations (the
(a,b)-tree analysis, eqs. 2-4: rebalancing work at height h decays
geometrically).

TPU adaptation (DESIGN.md §4): pointers -> level-major sorted arrays.

  level 0 (terminal):  keys[C], vals[C], mark[C]  — sorted, KEY_INF padding
  level l>=1:          keys_l[C_l] (max-of-group), child_l[C_l] (group start)

* Lock-free Find -> a pure fixed-trip-count walk: exactly L levels, one
  4-wide gather per level (guaranteed arity <= 4 — THIS is why the
  deterministic variant is SIMD-friendly; the randomized skiplist needs
  worst-case probe padding, see rand_skiplist.py).
* Threads -> batch lanes. A batch of K ops linearizes by (key, lane) sort with
  first-lane-wins tie-break: a deterministic linearization, strictly stronger
  than the paper's "some linearization exists".
* Top-down rebalancing -> deterministic level rebuild, grouping threes
  (boundaries b_j = min(3j, n-2)) so every group has arity in {2,3} — always
  1-2-3-4-legal. Rebuild cost at level l is n/3^l: the same geometric decay
  the paper proves for per-op rebalancing, amortized over the batch.
* Lazy deletion -> tombstone marks; non-terminal nodes keep routing through
  marked keys (the paper's lazy non-terminal removal + CheckNodeKey) until a
  compaction at 25% tombstones rebuilds all levels.
* Sentinels -> KEY_INF padding rows with clamped gathers (self-pointing
  sentinels = never out of bounds).

All ops are jit-able; state is a pytree (checkpointable for free).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.bits import KEY_INF, dup_in_run
from repro.core.layout import kv_arrays

FANOUT = 4  # 1-2-3-4: arity in [2, 4]


class DetSkiplist(NamedTuple):
    term_keys: jnp.ndarray            # [C] uint64 sorted (marked entries stay)
    term_vals: jnp.ndarray            # [C] uint64
    term_mark: jnp.ndarray            # [C] bool tombstones
    term_stamp: jnp.ndarray           # [C] int32 batch clock at insert/revive
    n_term: jnp.ndarray               # scalar int32 — physical entries
    n_marked: jnp.ndarray             # scalar int32
    clock: jnp.ndarray                # scalar int32 — ticked once per apply
    level_keys: tuple                 # L arrays [C_l] uint64 (max of group)
    level_child: tuple                # L arrays [C_l] int32  (group start)
    level_count: jnp.ndarray          # [L] int32

    @property
    def capacity(self) -> int:
        return self.term_keys.shape[0]

    @property
    def num_levels(self) -> int:
        return len(self.level_keys)

    def size(self) -> jnp.ndarray:
        return self.n_term - self.n_marked


def _level_caps(capacity: int) -> list[int]:
    """Index-level capacities: groups are >=2 wide so counts at least halve."""
    caps, c = [], capacity
    while c > FANOUT:
        c = (c + 1) // 2
        caps.append(max(c, FANOUT))
    return caps or [FANOUT]


def skiplist_init(capacity: int) -> DetSkiplist:
    caps = _level_caps(capacity)
    term_keys, term_vals = kv_arrays(capacity)
    return DetSkiplist(
        term_keys=term_keys,
        term_vals=term_vals,
        term_mark=jnp.zeros((capacity,), bool),
        term_stamp=jnp.zeros((capacity,), jnp.int32),
        n_term=jnp.int32(0),
        n_marked=jnp.int32(0),
        clock=jnp.int32(0),
        level_keys=tuple(jnp.full((c,), KEY_INF) for c in caps),
        level_child=tuple(jnp.zeros((c,), jnp.int32) for c in caps),
        level_count=jnp.zeros((len(caps),), jnp.int32),
    )


# ---------------------------------------------------------------------------
# rebuild (the batched top-down rebalance)
# ---------------------------------------------------------------------------

def _group(n_prev: jnp.ndarray, cap_l: int, prev_keys: jnp.ndarray):
    """Deterministic 1-2-3-4 grouping of a sorted level of n_prev keys.

    boundaries b_j = min(3j, max(n_prev-2, 0)), b_g = n_prev with
    g = (n_prev+2)//3 -> every group arity in {2,3} (single group of 1 only
    when n_prev == 1 — the root edge case, same as the paper's head node).
    """
    j = jnp.arange(cap_l, dtype=jnp.int32)
    g = jnp.where(n_prev > 0, (n_prev + 2) // 3, 0)
    lo = jnp.minimum(3 * j, jnp.maximum(n_prev - 2, 0))
    hi = jnp.where(j + 1 < g, jnp.minimum(3 * (j + 1), jnp.maximum(n_prev - 2, 0)), n_prev)
    live = j < g
    kidx = jnp.clip(hi - 1, 0, prev_keys.shape[0] - 1)
    keys = jnp.where(live, prev_keys[kidx], KEY_INF)   # node key = max of group
    child = jnp.where(live, lo, 0)
    return keys, child, g


def _rebuild_levels(s: DetSkiplist) -> DetSkiplist:
    """Rebuild every index level from the terminal array (work n/3^l at level
    l — the geometric decay of eqs. 2-4, amortized over the batch)."""
    lkeys, lchild, counts = [], [], []
    prev_keys, n_prev = s.term_keys, s.n_term
    for l in range(s.num_levels):
        cap_l = s.level_keys[l].shape[0]
        keys, child, g = _group(n_prev, cap_l, prev_keys)
        lkeys.append(keys)
        lchild.append(child)
        counts.append(g)
        prev_keys, n_prev = keys, g
    return s._replace(level_keys=tuple(lkeys), level_child=tuple(lchild),
                      level_count=jnp.stack(counts).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Find (lock-free walk -> pure fixed-trip-count walk)
# ---------------------------------------------------------------------------

def find_batch(s: DetSkiplist, queries: jnp.ndarray):
    """Batched Find. Returns (found[Q] bool, vals[Q], term_idx[Q] int32).

    Exactly L descent steps; each step gathers <= FANOUT child keys (guaranteed
    by the 1-2-3-4 criterion) and picks the first child with q <= child_key —
    which exists inside the group because node key = max of group, and
    first-true never escapes the group because the next group's keys are
    larger (sorted order = the self-pointing sentinel).
    """
    Q = queries.shape[0]
    top = s.num_levels - 1
    # top level holds <= FANOUT live nodes: one static probe
    topk = s.level_keys[top][:FANOUT]
    ge = queries[:, None] <= topk[None, :]
    i = jnp.argmax(ge, axis=1).astype(jnp.int32)          # first j with q <= key
    for l in range(top, -1, -1):
        child = s.level_child[l]
        start = child[jnp.clip(i, 0, child.shape[0] - 1)]
        below = s.term_keys if l == 0 else s.level_keys[l - 1]
        idx = jnp.clip(start[:, None] + jnp.arange(FANOUT, dtype=jnp.int32)[None, :],
                       0, below.shape[0] - 1)
        ck = below[idx]                                    # [Q, FANOUT]
        sel = jnp.argmax(queries[:, None] <= ck, axis=1).astype(jnp.int32)
        i = start + sel
    i = jnp.clip(i, 0, s.capacity - 1)
    found = (s.term_keys[i] == queries) & ~s.term_mark[i] & (queries != KEY_INF)
    return found, jnp.where(found, s.term_vals[i], jnp.uint64(0)), i


def find_batch_blocked(s: DetSkiplist, queries: jnp.ndarray,
                       block: int | None = None):
    """Batched Find through the block-major B-skiplist view — same contract
    (and bit-identical found/vals) as `find_batch`, with the descent
    restructured into lane-width fat nodes: each step compares a WHOLE
    block of `block` sorted keys (one vector compare + sum-reduction = the
    searchsorted-left position) instead of a fan-out-4 gather, so the walk
    is `ceil(log_block(C/block)) + 1` steps instead of `num_levels + 1`.
    The blocked index is derived from the terminal level at probe time
    (`core.layout.bskiplist_layout`) exactly like `_rebuild_levels` derives
    the level-major index — the layout is a probe-execution knob, state
    never changes shape. Kernel twin: `repro.kernels.bskiplist_walk`.
    """
    from repro.core.layout import BSKIP_BLOCK, bskiplist_layout, key_lt, split_u64

    B = BSKIP_BLOCK if block is None else block
    lay = bskiplist_layout(s, B)
    qh, ql = split_u64(queries)
    L, W = lay.blk_hi.shape
    nb = lay.term_hi.shape[0] // B
    lanes = jnp.arange(B, dtype=jnp.int32)[None, :]
    i = jnp.zeros(queries.shape, jnp.int32)          # root: node 0, row L-1
    for r in range(L - 1, -1, -1):
        base = jnp.clip(i, 0, W // B - 1) * B
        idx = base[:, None] + lanes
        lt = key_lt(lay.blk_hi[r][idx], lay.blk_lo[r][idx],
                    qh[:, None], ql[:, None])
        sel = jnp.sum(lt, axis=1).astype(jnp.int32)  # searchsorted-left
        i = base + sel                               # child node / block id
    blk = jnp.clip(i, 0, nb - 1)
    idx = blk[:, None] * B + lanes
    lt = key_lt(lay.term_hi[idx], lay.term_lo[idx], qh[:, None], ql[:, None])
    sel = jnp.sum(lt, axis=1).astype(jnp.int32)
    i = jnp.clip(blk * B + sel, 0, s.capacity - 1)
    found = (s.term_keys[i] == queries) & ~s.term_mark[i] & (queries != KEY_INF)
    return found, jnp.where(found, s.term_vals[i], jnp.uint64(0)), i


def contains(s: DetSkiplist, key) -> jnp.ndarray:
    return find_batch(s, jnp.asarray([key], jnp.uint64))[0][0]


# ---------------------------------------------------------------------------
# Addition (bulk, deterministic linearization)
# ---------------------------------------------------------------------------

def insert_batch(s: DetSkiplist, keys: jnp.ndarray, vals: jnp.ndarray,
                 mask: jnp.ndarray | None = None):
    """Batched Addition. Returns (s', inserted[K] bool, existed[K] bool).

    Linearization: lanes sort by (key, lane) — stable argsort — duplicates
    within the batch resolve to the lowest lane (first-writer-wins, a fixed
    rule). Duplicate-vs-stored keys return existed (the paper's duplicate
    check); keys matching a *marked* entry revive it in place (lazy-deletion
    composition). Capacity overflow drops the highest-ranked lanes and
    reports inserted=False (the paper's allocation-failure path).
    """
    K = keys.shape[0]
    C = s.capacity
    if mask is None:
        mask = jnp.ones((K,), bool)
    mask = mask & (keys != KEY_INF)

    order = jnp.argsort(keys, stable=True)
    sk = keys[order]
    sv = vals[order]
    sm = mask[order]
    same = jnp.concatenate([jnp.zeros((1,), bool), sk[1:] == sk[:-1]])
    dup = dup_in_run(same, sm)

    pos = jnp.searchsorted(s.term_keys, sk).astype(jnp.int32)
    posc = jnp.clip(pos, 0, C - 1)
    match = sm & (pos < C) & (s.term_keys[posc] == sk)
    revive = match & s.term_mark[posc] & ~dup
    exists = match & ~s.term_mark[posc]

    # revive in place (first lane among in-batch dups wins — dup already
    # false); a revival is a re-insertion, so its snapshot stamp refreshes
    # to the current batch clock (upserts on LIVE entries do not re-stamp)
    rpos = jnp.where(revive, posc, C)
    term_mark = s.term_mark.at[rpos].set(False, mode="drop")
    term_vals = s.term_vals.at[rpos].set(sv, mode="drop")
    term_stamp = s.term_stamp.at[rpos].set(s.clock, mode="drop")
    n_marked = s.n_marked - jnp.sum(revive).astype(jnp.int32)

    new = sm & ~match & ~dup
    rank = jnp.cumsum(new.astype(jnp.int32)) - 1
    new = new & (s.n_term + rank < C)                      # overflow -> fail lanes
    n_new = jnp.sum(new).astype(jnp.int32)

    # compact the new keys into a sorted [K] buffer (pad KEY_INF)
    crank = jnp.where(new, rank, K)
    newk = jnp.full((K,), KEY_INF).at[crank].set(sk, mode="drop")
    newv = jnp.zeros((K,), jnp.uint64).at[crank].set(sv, mode="drop")

    # two-way sorted merge by destination scatter
    old_idx = jnp.arange(C, dtype=jnp.int32)
    dest_old = old_idx + jnp.searchsorted(newk, s.term_keys, side="left").astype(jnp.int32)
    dest_old = jnp.where(old_idx < s.n_term, dest_old, C)
    dest_new = (jnp.searchsorted(s.term_keys, newk, side="left").astype(jnp.int32)
                + jnp.arange(K, dtype=jnp.int32))
    dest_new = jnp.where(jnp.arange(K) < n_new, dest_new, C)

    tk = jnp.full((C,), KEY_INF).at[dest_old].set(s.term_keys, mode="drop")
    tk = tk.at[dest_new].set(newk, mode="drop")
    tv = jnp.zeros((C,), jnp.uint64).at[dest_old].set(term_vals, mode="drop")
    tv = tv.at[dest_new].set(newv, mode="drop")
    tm = jnp.zeros((C,), bool).at[dest_old].set(term_mark, mode="drop")
    # new entries unmarked (already False); their stamp = this batch's clock
    ts = jnp.zeros((C,), jnp.int32).at[dest_old].set(term_stamp, mode="drop")
    ts = ts.at[dest_new].set(s.clock, mode="drop")

    s2 = s._replace(term_keys=tk, term_vals=tv, term_mark=tm, term_stamp=ts,
                    n_term=s.n_term + n_new, n_marked=n_marked)
    s2 = _rebuild_levels(s2)

    inv = jnp.zeros((K,), jnp.int32).at[order].set(jnp.arange(K, dtype=jnp.int32))
    inserted = (new | revive)[inv]
    existed = (exists | dup)[inv]
    return s2, inserted, existed


# ---------------------------------------------------------------------------
# Deletion (lazy marks + threshold compaction)
# ---------------------------------------------------------------------------

def delete_batch(s: DetSkiplist, keys: jnp.ndarray,
                 mask: jnp.ndarray | None = None, compact_num: int = 1,
                 compact_den: int = 4):
    """Batched Deletion: tombstone the terminal nodes (DropKey), leave the
    index levels stale (the paper's lazy non-terminal removal). Compaction
    (merge/borrow analogue, performed wholesale) triggers when tombstones
    exceed compact_num/compact_den of entries. Returns (s', deleted[K])."""
    K = keys.shape[0]
    C = s.capacity
    if mask is None:
        mask = jnp.ones((K,), bool)

    order = jnp.argsort(keys, stable=True)
    sk = keys[order]
    sm = mask[order] & (sk != KEY_INF)
    same = jnp.concatenate([jnp.zeros((1,), bool), sk[1:] == sk[:-1]])
    dup = dup_in_run(same, sm)

    pos = jnp.searchsorted(s.term_keys, sk).astype(jnp.int32)
    posc = jnp.clip(pos, 0, C - 1)
    hit = sm & ~dup & (pos < C) & (s.term_keys[posc] == sk) & ~s.term_mark[posc]

    mark = s.term_mark.at[jnp.where(hit, posc, C)].set(True, mode="drop")
    n_marked = s.n_marked + jnp.sum(hit).astype(jnp.int32)
    s2 = s._replace(term_mark=mark, n_marked=n_marked)

    s2 = jax.lax.cond(n_marked * compact_den > s2.n_term * compact_num,
                      compact, lambda t: t, s2)

    inv = jnp.zeros((K,), jnp.int32).at[order].set(jnp.arange(K, dtype=jnp.int32))
    return s2, hit[inv]


def compact(s: DetSkiplist) -> DetSkiplist:
    """Physically remove tombstones and rebuild all levels (the wholesale
    merge/borrow + DecreaseDepth: stale index nodes vanish here)."""
    C = s.capacity
    keep = (~s.term_mark) & (jnp.arange(C) < s.n_term)
    dest = jnp.where(keep, jnp.cumsum(keep.astype(jnp.int32)) - 1, C)
    tk = jnp.full((C,), KEY_INF).at[dest].set(s.term_keys, mode="drop")
    tv = jnp.zeros((C,), jnp.uint64).at[dest].set(s.term_vals, mode="drop")
    ts = jnp.zeros((C,), jnp.int32).at[dest].set(s.term_stamp, mode="drop")
    n = jnp.sum(keep).astype(jnp.int32)
    # derive cleared fields from inputs (keeps shard_map varying-axis types
    # identical across lax.cond branches)
    s2 = s._replace(term_keys=tk, term_vals=tv, term_stamp=ts,
                    term_mark=s.term_mark & False, n_term=n,
                    n_marked=s.n_marked * 0)
    return _rebuild_levels(s2)


# ---------------------------------------------------------------------------
# Range search (the skiplist's raison d'être vs hash tables)
# ---------------------------------------------------------------------------

def range_query(s: DetSkiplist, lo: jnp.ndarray, hi: jnp.ndarray, max_out: int,
                as_of_batch=None):
    """Keys in [lo, hi), batched over Q query rows.

    Returns (count[Q], keys[Q, max_out], vals[Q, max_out], valid[Q, max_out]).
    Terminal contiguity makes this a gather — the paper's argument for
    skiplists over BSTs (follow the linked list vs depth-first traversal).

    `as_of_batch`: snapshot scan — additionally exclude entries whose
    insert/revive stamp is LATER than the given batch clock (entries of
    batch b carry stamp b, so `as_of_batch=b` sees batches 0..b). Tombstones
    still hide deleted entries: this is a filter, not time travel — a key
    deleted since its insertion does not reappear. None (the default) skips
    the stamp plane entirely, which keeps this routine shared with states
    that don't carry one (the randomized skiplist).
    """
    i_lo = jnp.searchsorted(s.term_keys, lo, side="left").astype(jnp.int32)
    i_hi = jnp.searchsorted(s.term_keys, hi, side="left").astype(jnp.int32)
    idx = jnp.clip(i_lo[:, None] + jnp.arange(max_out, dtype=jnp.int32)[None, :],
                   0, s.capacity - 1)
    in_range = (i_lo[:, None] + jnp.arange(max_out)[None, :]) < i_hi[:, None]
    valid = in_range & ~s.term_mark[idx]
    # exact count (including beyond max_out): prefix-sum of live entries
    live = (~s.term_mark) & (s.term_keys != KEY_INF)
    if as_of_batch is not None:
        vis = s.term_stamp <= jnp.asarray(as_of_batch, jnp.int32)
        valid = valid & vis[idx]
        live = live & vis
    cs = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(live.astype(jnp.int32))])
    count = cs[i_hi] - cs[i_lo]
    return count, s.term_keys[idx], s.term_vals[idx], valid


# ---------------------------------------------------------------------------
# Priority-queue extraction (pop-min as rank-select over the live prefix)
# ---------------------------------------------------------------------------

def pop_rank_select(s: DetSkiplist, ranks: jnp.ndarray, mask: jnp.ndarray):
    """Locate the rank-th smallest live key per lane (rank 0 = minimum).

    Returns (found[K] bool, keys[K] uint64, idx[K] int32). Pure read — the
    caller commits the extraction with `pop_mark`. Built on the same
    live-prefix cumsum as `range_query` (live = unmarked, non-padding), so
    every execution path that reproduces that formula agrees bit-for-bit.
    Lanes whose rank exceeds the live population (or with mask False)
    return found=False, keys=KEY_INF, idx=0.
    """
    live = (~s.term_mark) & (s.term_keys != KEY_INF)
    prefix = jnp.cumsum(live.astype(jnp.int32))            # [C] inclusive
    total = s.n_term - s.n_marked
    want = ranks.astype(jnp.int32) + 1
    found = mask & (want >= 1) & (want <= total)
    idx = jnp.searchsorted(prefix, want, side="left").astype(jnp.int32)
    idx = jnp.where(found, jnp.clip(idx, 0, s.capacity - 1), 0)
    keys = jnp.where(found, s.term_keys[idx], KEY_INF)
    return found, keys, idx


def pop_mark(s: DetSkiplist, idx: jnp.ndarray, hit: jnp.ndarray,
             compact_num: int = 1, compact_den: int = 4) -> DetSkiplist:
    """Commit a batch of pops: tombstone the selected terminal slots (the
    same lazy DropKey path as `delete_batch` — index levels stay stale) and
    run the threshold compaction. `idx` rows with hit=False are ignored.
    Lanes must target distinct slots (guaranteed by distinct ranks)."""
    mark = s.term_mark.at[jnp.where(hit, idx, s.capacity)].set(True, mode="drop")
    n_marked = s.n_marked + jnp.sum(hit).astype(jnp.int32)
    s2 = s._replace(term_mark=mark, n_marked=n_marked)
    return jax.lax.cond(n_marked * compact_den > s2.n_term * compact_num,
                        compact, lambda t: t, s2)


# ---------------------------------------------------------------------------
# Range deletion (bulk DropKey over [lo, hi) intervals)
# ---------------------------------------------------------------------------

def range_delete_batch(s: DetSkiplist, lo: jnp.ndarray, hi: jnp.ndarray,
                       mask: jnp.ndarray | None = None, compact_num: int = 1,
                       compact_den: int = 4):
    """Tombstone every live key in [lo, hi) per lane, batched over K lanes.

    Returns (s', counts[K] int32). When lanes overlap, each deleted entry
    is attributed to the FIRST (lowest-index) covering lane — a fixed rule,
    like first-lane-wins everywhere else — so sum(counts) is exactly the
    number of entries removed. Same threshold compaction as `delete_batch`.
    """
    K = lo.shape[0]
    if mask is None:
        mask = jnp.ones((K,), bool)
    live = (~s.term_mark) & (s.term_keys != KEY_INF)
    cover = (mask[:, None]
             & (s.term_keys[None, :] >= lo[:, None])
             & (s.term_keys[None, :] < hi[:, None])
             & live[None, :])                               # [K, C]
    hitany = jnp.any(cover, axis=0)                         # [C]
    first = jnp.argmax(cover, axis=0).astype(jnp.int32)     # [C] first lane
    counts = jnp.zeros((K,), jnp.int32).at[
        jnp.where(hitany, first, K)].add(1, mode="drop")
    n_marked = s.n_marked + jnp.sum(hitany).astype(jnp.int32)
    s2 = s._replace(term_mark=s.term_mark | hitany, n_marked=n_marked)
    s2 = jax.lax.cond(n_marked * compact_den > s2.n_term * compact_num,
                      compact, lambda t: t, s2)
    return s2, counts


# ---------------------------------------------------------------------------
# invariant checker (tests + the paper's 1-2-3-4 criterion)
# ---------------------------------------------------------------------------

def check_invariants(s: DetSkiplist) -> dict:
    """Host-side structural validation. Returns dict of violation counts."""
    import numpy as np

    out = {}
    tk = np.asarray(s.term_keys)
    n = int(s.n_term)
    out["terminal_sorted"] = int(np.sum(np.diff(tk[:n].astype(np.float64)) < 0)) if n > 1 else 0
    out["padding_inf"] = int(np.sum(tk[n:] != np.uint64(0xFFFFFFFFFFFFFFFF)))
    prev_keys, n_prev = tk, n
    bad_arity = bad_maxkey = bad_subset = 0
    counts = np.asarray(s.level_count)
    for l in range(s.num_levels):
        lk = np.asarray(s.level_keys[l])
        lc = np.asarray(s.level_child[l])
        g = int(counts[l])
        for j in range(g):
            lo = int(lc[j])
            hi = int(lc[j + 1]) if j + 1 < g else n_prev
            arity = hi - lo
            if not (1 <= arity <= FANOUT) or (arity == 1 and n_prev != 1):
                bad_arity += 1
            if hi >= 1 and lk[j] != prev_keys[hi - 1]:
                bad_maxkey += 1
            if lk[j] not in prev_keys[:n_prev]:
                bad_subset += 1
        prev_keys, n_prev = lk, g
    out["bad_arity"] = bad_arity
    out["bad_maxkey"] = bad_maxkey
    out["bad_subset"] = bad_subset
    return out
