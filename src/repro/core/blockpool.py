"""Block memory manager (paper §V) — preallocate, recycle, never malloc in the loop.

The paper's memory manager: allocate memory in blocks, recycle deleted nodes
through a lock-free queue, guard against ABA with per-node reference counters
bumped on every recycle. JAX's static-shape discipline makes this design
mandatory rather than optional: the pool is a fixed set of block ids, the free
list is an array ring with monotone head/tail counters (fetch-add -> prefix-sum
slot assignment), and generation counters replace refcounts as the ABA guard.

`BlockPool` manages ids and generations only; the data arrays live with the
user (paged KV cache, two-level hash L2 tables, queue blocks) so one allocator
serves heterogeneous block payloads — "data structures manage their own
memory" per the paper, with the id pool shared.

Batched alloc/free are the thread-level ops: a batch of K requests is K
threads; cumsum assigns distinct ring slots exactly as fetch-add assigns
distinct indices; the functional state update is the linearization point.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class FreeRing(NamedTuple):
    """MPMC ring of int32 ids with monotone 64-bit head/tail counters.

    Paper: front/rear "are incremented monotonically during push and pop";
    slot = counter mod capacity. head == tail means empty.
    """

    buf: jnp.ndarray   # [cap] int32
    head: jnp.ndarray  # scalar int64 — pop side
    tail: jnp.ndarray  # scalar int64 — push side

    @property
    def capacity(self) -> int:
        return self.buf.shape[0]

    def size(self) -> jnp.ndarray:
        return self.tail - self.head


def freering_init(capacity: int, fill_ids: int | None = None) -> FreeRing:
    """A ring, optionally pre-filled with ids 0..fill_ids-1 (a fresh pool)."""
    buf = jnp.zeros((capacity,), jnp.int32)
    n = 0
    if fill_ids:
        assert fill_ids <= capacity
        buf = buf.at[:fill_ids].set(jnp.arange(fill_ids, dtype=jnp.int32))
        n = fill_ids
    return FreeRing(buf=buf, head=jnp.int64(0), tail=jnp.int64(n))


def freering_push(ring: FreeRing, ids: jnp.ndarray, mask: jnp.ndarray) -> FreeRing:
    """Batched push of ids where mask. Never overflows if capacity >= live ids
    (true by construction for a pool's free list)."""
    mask = mask & (ids >= 0)
    offs = jnp.cumsum(mask.astype(jnp.int64)) - 1          # fetch-add analogue
    pos = ((ring.tail + offs) % ring.capacity).astype(jnp.int32)
    # masked scatter: invalid lanes write out-of-range -> drop_indices
    pos = jnp.where(mask, pos, ring.capacity)
    buf = ring.buf.at[pos].set(ids.astype(jnp.int32), mode="drop")
    k = jnp.sum(mask.astype(jnp.int64))
    return FreeRing(buf=buf, head=ring.head, tail=ring.tail + k)


def freering_pop(ring: FreeRing, want: jnp.ndarray):
    """Batched pop: lane i (with want[i]) receives an id iff its rank among
    wanting lanes < available. Returns (ring, ids [-1 on failure], got_mask)."""
    rank = jnp.cumsum(want.astype(jnp.int64)) - 1
    avail = ring.tail - ring.head
    got = want & (rank < avail)
    pos = ((ring.head + rank) % ring.capacity).astype(jnp.int32)
    ids = jnp.where(got, ring.buf[pos], -1).astype(jnp.int32)
    k = jnp.sum(got.astype(jnp.int64))
    return FreeRing(buf=ring.buf, head=ring.head + k, tail=ring.tail), ids, got


class BlockPool(NamedTuple):
    """Id/generation pool. gen bump on free = the paper's recycle refcount."""

    free: FreeRing
    gen: jnp.ndarray        # [num_blocks] uint32 — ABA guard
    in_use: jnp.ndarray     # [num_blocks] bool   — the paper's use[] bitmap

    @property
    def num_blocks(self) -> int:
        return self.gen.shape[0]

    def num_free(self) -> jnp.ndarray:
        return self.free.size()


def blockpool_init(num_blocks: int) -> BlockPool:
    return BlockPool(
        free=freering_init(num_blocks, fill_ids=num_blocks),
        gen=jnp.zeros((num_blocks,), jnp.uint32),
        in_use=jnp.zeros((num_blocks,), bool),
    )


def pool_alloc(pool: BlockPool, want: jnp.ndarray):
    """Batched alloc. Returns (pool, ids[-1 fail], handles, got_mask).

    handle = (gen << 32) | id — the ABA-safe reference the user stores (e.g.
    in a block table); stale handles are detectable after the block recycles.
    """
    free, ids, got = freering_pop(pool.free, want)
    safe = jnp.where(got, ids, 0)
    handles = (pool.gen[safe].astype(jnp.uint64) << jnp.uint64(32)) | safe.astype(jnp.uint64)
    handles = jnp.where(got, handles, jnp.uint64(0xFFFFFFFFFFFFFFFF))
    in_use = pool.in_use.at[jnp.where(got, ids, pool.num_blocks)].set(True, mode="drop")
    return BlockPool(free=free, gen=pool.gen, in_use=in_use), ids, handles, got


def pool_free(pool: BlockPool, ids: jnp.ndarray, mask: jnp.ndarray) -> BlockPool:
    """Batched free: gen bump (recycle counter) + push back on the free ring."""
    mask = mask & (ids >= 0)
    safe = jnp.where(mask, ids, pool.num_blocks)
    gen = pool.gen.at[safe].add(jnp.uint32(1), mode="drop")
    in_use = pool.in_use.at[safe].set(False, mode="drop")
    free = freering_push(pool.free, ids, mask)
    return BlockPool(free=free, gen=gen, in_use=in_use)


def handle_valid(pool: BlockPool, handles: jnp.ndarray) -> jnp.ndarray:
    """ABA check: a handle is valid iff its generation matches the pool's."""
    ids = (handles & jnp.uint64(0xFFFFFFFF)).astype(jnp.int64)
    gens = (handles >> jnp.uint64(32)).astype(jnp.uint32)
    ok_id = (ids >= 0) & (ids < pool.num_blocks)
    safe = jnp.clip(ids, 0, pool.num_blocks - 1)
    return ok_id & (pool.gen[safe] == gens) & pool.in_use[safe]


def expected_blocks_in_use(n_ops: int, block_size: int) -> float:
    """Paper eq. (5): average blocks in use over all valid new/delete prefixes.

    avg = sum_{k=1..N} sum_{i=0..k} ceil((k-i)/C) / sum_{i=1..N} i
    (k news, i deletes, C block size). Used by a property test to validate the
    pool's live-block accounting against the paper's analysis.
    """
    import numpy as np

    num = 0
    for k in range(1, n_ops + 1):
        i = np.arange(0, k + 1)
        num += int(np.ceil((k - i) / block_size).sum())
    den = n_ops * (n_ops + 1) // 2
    return num / den
