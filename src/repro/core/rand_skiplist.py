"""Lock-free randomized skiplist (paper §VI / Pugh) — array-encoded comparator.

The paper implements Pugh's randomized skiplist (lock-free, with the same
memory manager) and finds it BEATS the deterministic 1-2-3-4 tree on CPU
(tables IV / fig 6): no rebalancing work, no L-shaped lock contention.

On a SIMD machine the trade inverts, and this module exists to measure that
(benchmarks/table4_det_vs_rand.py): node heights are geometric(1/4), so level
intervals have *random* width — a batched descent must pad every lane's probe
to the worst-case gap, wasting lanes, while the deterministic skiplist probes
exactly 4 wide. Heights come from splitmix64(key) (deterministic-by-hash:
the functional analogue of the paper's RNG, and reproducible).

TPU adaptation: unbounded w.h.p. gaps are incompatible with static shapes, so
the builder force-promotes a key wherever a level gap would exceed MAX_GAP
(probability ~ (3/4)^MAX_GAP per position — measured and reported by the
bench). This cap is itself a mini-determinization and is called out in
DESIGN.md §6.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bits import KEY_INF, dup_in_run, geometric_height

MAX_GAP = 16   # static probe width per level
PROBE = 8      # gather chunk


class RandSkiplist(NamedTuple):
    term_keys: jnp.ndarray   # [C] sorted uint64, KEY_INF pad
    term_vals: jnp.ndarray   # [C] uint64
    term_mark: jnp.ndarray   # [C] bool
    n_term: jnp.ndarray      # scalar int32
    n_marked: jnp.ndarray
    level_keys: tuple        # L x [C_l]
    level_child: tuple       # L x [C_l] int32 — position in level below
    level_count: jnp.ndarray # [L] int32
    forced: jnp.ndarray      # scalar int32 — gap-cap promotions (telemetry)

    @property
    def capacity(self) -> int:
        return self.term_keys.shape[0]

    @property
    def num_levels(self) -> int:
        return len(self.level_keys)

    def size(self):
        return self.n_term - self.n_marked


def _level_caps(capacity: int) -> list[int]:
    caps, c = [], capacity
    while c > MAX_GAP:
        c = (c + 1) // 2   # gap >= 2 enforced below, so counts at least halve
        caps.append(max(c, MAX_GAP))
    return caps or [MAX_GAP]


def rand_skiplist_init(capacity: int) -> RandSkiplist:
    caps = _level_caps(capacity)
    return RandSkiplist(
        term_keys=jnp.full((capacity,), KEY_INF),
        term_vals=jnp.zeros((capacity,), jnp.uint64),
        term_mark=jnp.zeros((capacity,), bool),
        n_term=jnp.int32(0),
        n_marked=jnp.int32(0),
        level_keys=tuple(jnp.full((c,), KEY_INF) for c in caps),
        level_child=tuple(jnp.zeros((c,), jnp.int32) for c in caps),
        level_count=jnp.zeros((len(caps),), jnp.int32),
        forced=jnp.int32(0),
    )


def _promote(keys: jnp.ndarray, n: jnp.ndarray, want_level: int):
    """Membership mask for the next level: hash-height >= level, with gaps
    capped at MAX_GAP by forced promotion (see module docstring)."""
    C = keys.shape[0]
    idx = jnp.arange(C, dtype=jnp.int32)
    live = idx < n
    want = live & (geometric_height(keys, want_level) >= want_level)
    # cap gaps: promote idx where distance to previous promoted >= MAX_GAP
    last = jax.lax.associative_scan(jnp.maximum, jnp.where(want, idx, -1))
    force = live & ~want & ((idx - last) % MAX_GAP == 0) & (last < idx)
    forced_n = jnp.sum(force).astype(jnp.int32)
    # always promote position 0 of a non-empty level so the top has an anchor
    head = live & (idx == 0)
    return want | force | head, forced_n


def _rebuild(s: RandSkiplist) -> RandSkiplist:
    lkeys, lchild, counts = [], [], []
    prev_keys, n_prev = s.term_keys, s.n_term
    forced_total = jnp.int32(0)
    for l in range(s.num_levels):
        cap_l = s.level_keys[l].shape[0]
        memb, fn = _promote(prev_keys, n_prev, l + 1)
        forced_total = forced_total + fn
        rank = jnp.cumsum(memb.astype(jnp.int32)) - 1
        g = jnp.sum(memb).astype(jnp.int32)
        dest = jnp.where(memb, jnp.minimum(rank, cap_l - 1), cap_l)
        keys = jnp.full((cap_l,), KEY_INF).at[dest].set(prev_keys, mode="drop")
        src = jnp.arange(prev_keys.shape[0], dtype=jnp.int32)
        child = jnp.zeros((cap_l,), jnp.int32).at[dest].set(src, mode="drop")
        g = jnp.minimum(g, cap_l)
        lkeys.append(keys)
        lchild.append(child)
        counts.append(g)
        prev_keys, n_prev = keys, g
    return s._replace(level_keys=tuple(lkeys), level_child=tuple(lchild),
                      level_count=jnp.stack(counts).astype(jnp.int32),
                      forced=forced_total)


def find_batch(s: RandSkiplist, queries: jnp.ndarray):
    """Batched lock-free Find: descend levels, scanning right in PROBE-wide
    chunks up to MAX_GAP (random interval widths — the padded cost)."""
    top = s.num_levels - 1
    i = jnp.zeros(queries.shape, jnp.int32)   # anchor at leftmost top node
    for l in range(top, -1, -1):
        keys_l = s.level_keys[l]
        cap = keys_l.shape[0]
        # walk right within this level: first j >= i with q <= keys_l[j]
        best = jnp.full(queries.shape, -1, jnp.int32)
        for c in range(MAX_GAP // PROBE):
            idx = jnp.clip(i[:, None] + c * PROBE
                           + jnp.arange(PROBE, dtype=jnp.int32)[None, :], 0, cap - 1)
            ck = keys_l[idx]
            hit = queries[:, None] <= ck
            off = jnp.argmax(hit, axis=1).astype(jnp.int32)
            found_here = jnp.any(hit, axis=1)
            cand = i + c * PROBE + off
            best = jnp.where((best < 0) & found_here, cand, best)
        j = jnp.where(best >= 0, best, jnp.minimum(i + MAX_GAP - 1, cap - 1))
        below_start = s.level_child[l][jnp.clip(j, 0, cap - 1)]
        prev_j = jnp.maximum(j - 1, 0)
        # descend from the *previous* node's child (strictly-less anchor) so we
        # do not skip keys between prev and j at the level below
        anchor = jnp.where(j > 0, s.level_child[l][prev_j], 0)
        i = anchor
    # terminal scan
    tk = s.term_keys
    best = jnp.full(queries.shape, -1, jnp.int32)
    for c in range(MAX_GAP // PROBE * 2):
        idx = jnp.clip(i[:, None] + c * PROBE
                       + jnp.arange(PROBE, dtype=jnp.int32)[None, :], 0, s.capacity - 1)
        ck = tk[idx]
        hit = queries[:, None] <= ck
        off = jnp.argmax(hit, axis=1).astype(jnp.int32)
        cand = i + c * PROBE + off
        best = jnp.where((best < 0) & jnp.any(hit, axis=1), cand, best)
    ti = jnp.clip(jnp.where(best >= 0, best, 0), 0, s.capacity - 1)
    found = (tk[ti] == queries) & ~s.term_mark[ti] & (queries != KEY_INF)
    return found, jnp.where(found, s.term_vals[ti], jnp.uint64(0)), ti


def insert_batch(s: RandSkiplist, keys: jnp.ndarray, vals: jnp.ndarray,
                 mask: jnp.ndarray | None = None):
    """Same bulk merge as the deterministic version; levels rebuilt from
    hash-heights (no grouping work — the paper's 'no rebalancing' advantage,
    which the batched build mostly erases: measured in table4 bench)."""
    K = keys.shape[0]
    C = s.capacity
    if mask is None:
        mask = jnp.ones((K,), bool)
    mask = mask & (keys != KEY_INF)

    order = jnp.argsort(keys, stable=True)
    sk, sv, sm = keys[order], vals[order], mask[order]
    same = jnp.concatenate([jnp.zeros((1,), bool), sk[1:] == sk[:-1]])
    dup = dup_in_run(same, sm)

    pos = jnp.searchsorted(s.term_keys, sk).astype(jnp.int32)
    posc = jnp.clip(pos, 0, C - 1)
    match = sm & (pos < C) & (s.term_keys[posc] == sk)
    revive = match & s.term_mark[posc] & ~dup
    exists = match & ~s.term_mark[posc]

    rpos = jnp.where(revive, posc, C)
    term_mark = s.term_mark.at[rpos].set(False, mode="drop")
    term_vals = s.term_vals.at[rpos].set(sv, mode="drop")
    n_marked = s.n_marked - jnp.sum(revive).astype(jnp.int32)

    new = sm & ~match & ~dup
    rank = jnp.cumsum(new.astype(jnp.int32)) - 1
    new = new & (s.n_term + rank < C)
    n_new = jnp.sum(new).astype(jnp.int32)

    crank = jnp.where(new, rank, K)
    newk = jnp.full((K,), KEY_INF).at[crank].set(sk, mode="drop")
    newv = jnp.zeros((K,), jnp.uint64).at[crank].set(sv, mode="drop")

    old_idx = jnp.arange(C, dtype=jnp.int32)
    dest_old = old_idx + jnp.searchsorted(newk, s.term_keys, side="left").astype(jnp.int32)
    dest_old = jnp.where(old_idx < s.n_term, dest_old, C)
    dest_new = (jnp.searchsorted(s.term_keys, newk, side="left").astype(jnp.int32)
                + jnp.arange(K, dtype=jnp.int32))
    dest_new = jnp.where(jnp.arange(K) < n_new, dest_new, C)

    tk = jnp.full((C,), KEY_INF).at[dest_old].set(s.term_keys, mode="drop")
    tk = tk.at[dest_new].set(newk, mode="drop")
    tv = jnp.zeros((C,), jnp.uint64).at[dest_old].set(term_vals, mode="drop")
    tv = tv.at[dest_new].set(newv, mode="drop")
    tm = jnp.zeros((C,), bool).at[dest_old].set(term_mark, mode="drop")

    s2 = s._replace(term_keys=tk, term_vals=tv, term_mark=tm,
                    n_term=s.n_term + n_new, n_marked=n_marked)
    s2 = _rebuild(s2)

    inv = jnp.zeros((K,), jnp.int32).at[order].set(jnp.arange(K, dtype=jnp.int32))
    return s2, (new | revive)[inv], (exists | dup)[inv]


def delete_batch(s: RandSkiplist, keys: jnp.ndarray,
                 mask: jnp.ndarray | None = None):
    K = keys.shape[0]
    C = s.capacity
    if mask is None:
        mask = jnp.ones((K,), bool)
    order = jnp.argsort(keys, stable=True)
    sk = keys[order]
    sm = mask[order] & (sk != KEY_INF)
    same = jnp.concatenate([jnp.zeros((1,), bool), sk[1:] == sk[:-1]])
    dup = dup_in_run(same, sm)
    pos = jnp.searchsorted(s.term_keys, sk).astype(jnp.int32)
    posc = jnp.clip(pos, 0, C - 1)
    hit = sm & ~dup & (pos < C) & (s.term_keys[posc] == sk) & ~s.term_mark[posc]
    mark = s.term_mark.at[jnp.where(hit, posc, C)].set(True, mode="drop")
    s2 = s._replace(term_mark=mark,
                    n_marked=s.n_marked + jnp.sum(hit).astype(jnp.int32))
    inv = jnp.zeros((K,), jnp.int32).at[order].set(jnp.arange(K, dtype=jnp.int32))
    return s2, hit[inv]
