"""Sharded ordered-set service: routing + deterministic skiplist (paper §VI).

The paper's flagship experiment: 8 skiplists, one per NUMA node, key space
partitioned by the top 3 key bits, per-thread lock-free queues routing each
key to a thread on the owner node. Here: one skiplist per mesh shard, key
space partitioned by the top log2(n_shards) key bits, hierarchical all_to_all
routing (pod axis first — the DCI hop — then intra-pod), batched ops applied
locally, results routed back to the requesting shard/lane.

Batch linearization order (deterministic): INSERTS, then DELETES, then FINDS.
A find in batch t observes every insert/delete of batches <= t.

This module is now a compatibility veneer: the machinery lives in
`repro.store.engine`, which generalizes the same routing + local-apply step
to ANY registered backend (hash tables, split-order, the tiered
hash+skiplist stack, ...). These wrappers pin the backend the paper used —
the deterministic skiplist — so existing callers and the dry-run config
(`configs/paper_kvstore.py`) keep working unchanged.
"""
from __future__ import annotations

from typing import Sequence

from jax.sharding import Mesh

from repro.store import engine as store_engine
# op codes are canonical in repro.store.api; re-exported here for callers
from repro.store.api import (OP_DELETE, OP_FIND, OP_INSERT, OP_NONE,  # noqa: F401
                             OP_RANGE)

store_sharding = store_engine.store_sharding


def sharded_store_init(n_shards: int, capacity_per_shard: int):
    """Skiplist pytree with a leading shard dim (to be sharded over the mesh)."""
    return store_engine.sharded_init("det_skiplist", n_shards,
                                     capacity_per_shard)


def make_store_step(mesh: Mesh, axis_names: Sequence[str], lanes: int,
                    pool_factor: int = 2):
    """The original skiplist-backed batched-op step (see engine.make_store_step)."""
    return store_engine.make_store_step(mesh, axis_names, lanes,
                                        backend="det_skiplist",
                                        pool_factor=pool_factor)


def make_range_step(mesh: Mesh, axis_names: Sequence[str], lanes: int,
                    max_out: int, pool_factor: int = 2):
    """The original skiplist-backed range step (see engine.make_range_step)."""
    return store_engine.make_range_step(mesh, axis_names, lanes, max_out,
                                        backend="det_skiplist",
                                        pool_factor=pool_factor)
