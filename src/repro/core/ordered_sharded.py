"""Sharded ordered-set service: routing + deterministic skiplist (paper §VI).

The paper's flagship experiment: 8 skiplists, one per NUMA node, key space
partitioned by the top 3 key bits, per-thread lock-free queues routing each
key to a thread on the owner node. Here: one skiplist per mesh shard, key
space partitioned by the top log2(n_shards) key bits, hierarchical all_to_all
routing (pod axis first — the DCI hop — then intra-pod), batched ops applied
locally, results routed back to the requesting shard/lane.

Batch linearization order (deterministic): INSERTS, then DELETES, then FINDS.
A find in batch t observes every insert/delete of batches <= t.

This module is also the paper's-own-architecture config for the dry-run
(`configs/paper_kvstore.py`): `store_step` lowers and compiles on the
production meshes like any LM train_step.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax import shard_map

from repro.core import det_skiplist as dsl
from repro.core.bits import KEY_INF
from repro.core.routing import route_back, route_to_owners

OP_NONE, OP_FIND, OP_INSERT, OP_DELETE, OP_RANGE = -1, 0, 1, 2, 3


def sharded_store_init(n_shards: int, capacity_per_shard: int):
    """Skiplist pytree with a leading shard dim (to be sharded over the mesh)."""
    one = dsl.skiplist_init(capacity_per_shard)
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_shards,) + x.shape), one)


def store_sharding(mesh: Mesh, axis_names: Sequence[str]):
    """NamedShardings: state sharded on dim 0 over all routing axes; op
    streams likewise (each shard issues its own lanes)."""
    spec_state = P(tuple(axis_names))
    return NamedSharding(mesh, spec_state)


def make_store_step(mesh: Mesh, axis_names: Sequence[str], lanes: int,
                    pool_factor: int = 2):
    """Build the jit-able batched-op step.

    Global inputs: ops[int32 S*lanes], keys[u64 S*lanes], vals[u64 S*lanes]
    sharded over the routing axes (S = total shards; each shard contributes
    `lanes` requests — "threads fill queues, then operate", §IX).
    Returns (state', results[u64], ok[bool]).
    """
    axis_sizes = [mesh.shape[a] for a in axis_names]
    n_shards = int(math.prod(axis_sizes))
    pool = lanes * pool_factor

    def body(state, ops, keys, vals):
        sl = jax.tree.map(lambda x: x[0], state)      # this shard's skiplist
        valid = ops >= 0
        rr = route_to_owners(keys, vals, ops, valid, axis_names, axis_sizes, pool)

        ins_m = rr.valid & (rr.aux == OP_INSERT)
        del_m = rr.valid & (rr.aux == OP_DELETE)
        sl, inserted, existed = dsl.insert_batch(sl, rr.keys, rr.vals, ins_m)
        sl, deleted = dsl.delete_batch(sl, rr.keys, del_m)
        found, fvals, _ = dsl.find_batch(sl, jnp.where(rr.valid, rr.keys, KEY_INF))

        ok = jnp.where(rr.aux == OP_FIND, found,
                       jnp.where(rr.aux == OP_INSERT, inserted | existed, deleted))
        res = jnp.where(rr.aux == OP_FIND, fvals,
                        jnp.where(rr.aux == OP_INSERT,
                                  existed.astype(jnp.uint64), jnp.uint64(0)))
        res, okb = route_back(res, ok, rr.origin, rr.valid & (rr.aux >= 0),
                              axis_names, axis_sizes, lanes)
        state2 = jax.tree.map(lambda a, b: b[None], state, sl)
        return state2, res, okb, rr.dropped[None]   # [1] per shard -> [S] global

    spec1 = P(tuple(axis_names))
    step = shard_map(body, mesh=mesh,
                     in_specs=(spec1, spec1, spec1, spec1),
                     out_specs=(spec1, spec1, spec1, P(tuple(axis_names))))

    def wrapped(state, ops, keys, vals):
        st, res, ok, dropped = step(state, ops, keys, vals)
        return st, res, ok, jnp.sum(dropped)

    return wrapped


def make_range_step(mesh: Mesh, axis_names: Sequence[str], lanes: int,
                    max_out: int, pool_factor: int = 2):
    """Range counting: [lo, hi) per lane. Ranges crossing shard boundaries are
    answered by every touched shard and summed on the way back (the skiplist's
    contiguous terminal level makes the local part a gather — §II's argument
    for skiplists over BSTs)."""
    axis_sizes = [mesh.shape[a] for a in axis_names]
    n_shards = int(math.prod(axis_sizes))
    pool = lanes * pool_factor
    bits_shards = int(math.log2(n_shards)) if n_shards > 1 else 0

    def body(state, los, his, valid):
        valid = valid.astype(jnp.int32)
        sl = jax.tree.map(lambda x: x[0], state)
        # broadcast every range to all shards whose key interval intersects:
        # here, simple + correct — replicate ranges via all_gather along the
        # routing axes, count locally, then psum (a 2-collective pattern
        # instead of the paper's per-key queues: ranges are rare + wide)
        ls, hs, vs = los, his, valid
        for a in axis_names:
            ls = jax.lax.all_gather(ls, a, axis=0, tiled=True)
            hs = jax.lax.all_gather(hs, a, axis=0, tiled=True)
            vs = jax.lax.all_gather(vs, a, axis=0, tiled=True)
        cnt, _, _, _ = dsl.range_query(sl, ls, hs, max_out)
        cnt = jnp.where(vs > 0, cnt, 0)
        for a in axis_names:
            cnt = jax.lax.psum(cnt, a)
        # return this shard's slice of the global answer
        me = jnp.int32(0)
        for a in axis_names:
            me = me * jax.lax.axis_size(a) + jax.lax.axis_index(a).astype(jnp.int32)
        return jax.lax.dynamic_slice_in_dim(cnt, me * lanes, lanes)

    spec1 = P(tuple(axis_names))
    return shard_map(body, mesh=mesh, in_specs=(spec1, spec1, spec1, spec1),
                     out_specs=spec1)
