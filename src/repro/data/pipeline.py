"""Data pipeline: deterministic synthetic token stream + prefetch through the
paper's structures.

Staging buffers come from a §V block pool; the producer thread allocates a
buffer, fills it, and pushes its id onto a §III ring queue; the consumer pops
ids and recycles buffers — the paper's "queues for load balancing workloads"
applied to input pipelining.

Determinism & fault tolerance: batch(step, shard) is a pure function of
(seed, step, shard) — restart from any checkpoint step replays the exact
stream; no pipeline state needs checkpointing beyond the step counter.

Straggler mitigation: the consumer takes whichever prefetched batch is ready
(depth-R redundancy); a producer stall beyond `deadline` is counted and the
consumer synthesizes the batch inline (deterministic — same function) instead
of blocking the whole step: slow data hosts never stall the mesh.
"""
from __future__ import annotations

import threading
import time

import numpy as np
import jax.numpy as jnp

from repro.core.blockpool import blockpool_init, pool_alloc, pool_free
from repro.core.ringqueue import pop_one, push_one, queue_init


def synth_batch(cfg, shape, seed: int, step: int, shard: int = 0,
                n_shards: int = 1):
    """Pure function of (seed, step, shard): the replayable batch."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard, n_shards]))
    b = shape.global_batch // n_shards
    s = shape.seq_len
    if cfg.n_codebooks:
        toks = rng.integers(0, cfg.vocab_size, (b, cfg.n_codebooks, s + 1))
        return {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
                "labels": jnp.asarray(toks[..., 1:], jnp.int32),
                "loss_mask": jnp.ones((b, s), jnp.float32)}
    ft = cfg.frontend_tokens
    toks = rng.integers(0, cfg.vocab_size, (b, s - ft + 1))
    batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(
                 np.pad(toks[:, 1:], ((0, 0), (ft, 0))), jnp.int32),
             "loss_mask": jnp.concatenate(
                 [jnp.zeros((b, ft), jnp.float32),
                  jnp.ones((b, s - ft), jnp.float32)], axis=1)}
    if ft:
        emb = rng.standard_normal((b, ft, cfg.d_model)).astype(np.float32) * 0.02
        batch["prefix_embeds"] = jnp.asarray(emb)
    return batch


class PrefetchPipeline:
    """Producer thread + block-pool staging + ring-queue handoff."""

    def __init__(self, make_batch, depth: int = 4, deadline: float = 30.0,
                 delay_injector=None):
        self.make_batch = make_batch
        self.depth = depth
        self.deadline = deadline
        self.delay_injector = delay_injector          # test hook (straggler)
        self.pool = blockpool_init(depth)
        self.queue = queue_init(max_blocks=4, block_size=max(depth, 4),
                                dtype=jnp.uint64)
        self.buffers = [None] * depth
        self.straggler_skips = 0
        self._next_produce = 0
        self._next_consume = 0
        self._lock = threading.Lock()
        self._stop = False
        self._t = threading.Thread(target=self._producer, daemon=True)
        self._t.start()

    def _producer(self):
        while not self._stop:
            with self._lock:
                step = self._next_produce
            if step - self._next_consume >= self.depth:
                time.sleep(0.001)
                continue
            if self.delay_injector:
                self.delay_injector(step)
            batch = self.make_batch(step)
            with self._lock:   # guards the (queue, pool, buffers) triple —
                # the device-side ops are linearizable; swapping the PYTHON
                # references between threads is not, hence the mutex
                self.pool, ids, _, got = pool_alloc(self.pool,
                                                    jnp.ones((1,), bool))
                if not bool(got[0]):
                    pass
                else:
                    bid = int(ids[0])
                    self.buffers[bid] = (step, batch)
                    self.queue, ok = push_one(self.queue, np.uint64(bid))
                    self._next_produce = step + 1
                    continue
            time.sleep(0.001)

    def get(self, step: int):
        """Batch for `step` — from prefetch if ready, else synthesized inline
        (counted as a straggler skip)."""
        t0 = time.monotonic()
        while True:
            with self._lock:
                self.queue, val, got = pop_one(self.queue)
                if bool(got):
                    bid = int(val)
                    got_step, batch = self.buffers[bid]
                    self.buffers[bid] = None
                    self.pool = pool_free(self.pool,
                                          jnp.asarray([bid], jnp.int32),
                                          jnp.ones((1,), bool))
                    self._next_consume = max(self._next_consume, got_step + 1)
                else:
                    batch = None
            if batch is not None:
                if got_step == step:
                    return batch
                continue  # stale prefetch (post-restart) — drop & keep looking
            if time.monotonic() - t0 > self.deadline:
                self.straggler_skips += 1
                self._next_consume = max(self._next_consume, step + 1)
                return self.make_batch(step)
            time.sleep(0.0005)

    def stop(self):
        self._stop = True
        self._t.join(timeout=5)
