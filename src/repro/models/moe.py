"""Mixture-of-Experts FFN with the paper's routing as the dispatch layer.

Token -> expert dispatch IS the paper's key -> NUMA-owner routing (§I/§VI):
the expert id plays the role of the key's top bits, experts are the "NUMA
domains" sharded over the model axis, and the two dispatch implementations
mirror the paper's two memory regimes:

  * "replicated_psum"  — activations replicated over the model axis; every
    expert shard computes its experts for all tokens it can see, partial
    outputs are psum-combined. No all_to_all; collective = one psum of the
    output. The remote-access-heavy baseline.
  * "routed_a2a"       — tokens bucketized by owner shard (capacity-bounded,
    deterministic linearization — core.routing.bucketize) and moved with
    all_to_all over the model axis, computed NUMA-locally, moved back.
    The paper's design; collective = 2 x all_to_all of only the routed
    tokens (top-k/E of the psum bytes). See EXPERIMENTS.md §Perf.

Router: softmax top-k, optional probability renormalization (qwen3), plus a
load-balancing auxiliary loss (Switch-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.routing import axis_size, bucketize
from repro.models.common import cast, dense_init


def init_moe(key, cfg):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, e, cfg.param_dtype, scale=0.02),
        "wi": (jax.random.normal(ks[1], (e, d, f), jnp.float32) / jnp.sqrt(d)
               ).astype(cfg.param_dtype),
        "wu": (jax.random.normal(ks[2], (e, d, f), jnp.float32) / jnp.sqrt(d)
               ).astype(cfg.param_dtype),
        "wd": (jax.random.normal(ks[3], (e, f, d), jnp.float32) / jnp.sqrt(f)
               ).astype(cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        from repro.models.mlp import init_mlp
        p["shared"] = init_mlp(ks[4], d, cfg.d_expert * cfg.n_shared_experts,
                               cfg.param_dtype)
    return p


def router_probs(p, cfg, x):
    """x: [T, D] -> (weights [T, k], experts [T, k], aux_loss scalar)."""
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    k = cfg.n_experts_active
    w, idx = jax.lax.top_k(probs, k)
    if cfg.norm_topk_prob:
        w = w / jnp.sum(w, axis=-1, keepdims=True)
    # Switch aux loss: E * sum_e f_e * P_e
    e = cfg.n_experts
    dispatch = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    f_e = jnp.mean(dispatch, axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return w, idx.astype(jnp.int32), aux


def _expert_ffn(wi, wu, wd, xe, compute_dtype):
    """xe: [E_local, C, D] bucketed tokens -> [E_local, C, D]."""
    g = jnp.einsum("ecd,edf->ecf", xe, cast(wi, compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, cast(wu, compute_dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, cast(wd, compute_dtype))


def moe_dense_ffn(p, cfg, x2d):
    """Reference dispatch (tiny/smoke scale): bucketize into [E, C, D] on one
    shard, no collectives. Returns (y2d, aux)."""
    t, d = x2d.shape
    k = cfg.n_experts_active
    e = cfg.n_experts
    w, idx, aux = router_probs(p, cfg, x2d)
    # flatten (token, choice) pairs -> bucketize by expert
    flat_dest = idx.reshape(-1)
    flat_w = w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    cap = max(1, int(2 * t * k / e) + 8)
    (tok_b, w_b), valid, dropped = bucketize(
        flat_dest, jnp.ones_like(flat_dest, bool),
        [flat_tok, flat_w.astype(jnp.float32)], e, cap)
    xe = jnp.where(valid[..., None], x2d[tok_b], 0)          # [E, C, D]
    ye = _expert_ffn(p["wi"], p["wu"], p["wd"], xe, cfg.compute_dtype)
    ye = ye * w_b[..., None].astype(ye.dtype)
    y = jnp.zeros_like(x2d).at[jnp.where(valid, tok_b, t).reshape(-1)].add(
        ye.reshape(e * cap, d), mode="drop")
    if cfg.n_shared_experts:
        from repro.models.mlp import mlp
        y = y + mlp(p["shared"], x2d, cfg.compute_dtype)
    return y, aux


def moe_replicated_psum(p, cfg, x2d, axis: str):
    """EP over `axis` (model): experts sharded, tokens replicated, psum
    combine. Runs inside shard_map: p['wi'] etc. arrive [E_local, D, F]."""
    t, d = x2d.shape
    e_local = p["wi"].shape[0]
    size = axis_size(axis)
    me = jax.lax.axis_index(axis).astype(jnp.int32)
    w, idx, aux = router_probs(p, cfg, x2d)      # router replicated
    k = cfg.n_experts_active
    flat_dest = idx.reshape(-1)
    flat_w = w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    mine = (flat_dest // e_local) == me
    local_e = flat_dest % e_local
    cap = max(1, int(2 * t * k / cfg.n_experts) + 8)
    (tok_b, w_b), valid, dropped = bucketize(
        local_e, mine, [flat_tok, flat_w.astype(jnp.float32)], e_local, cap)
    xe = jnp.where(valid[..., None], x2d[tok_b], 0)
    ye = _expert_ffn(p["wi"], p["wu"], p["wd"], xe, cfg.compute_dtype)
    ye = ye * w_b[..., None].astype(ye.dtype)
    y = jnp.zeros_like(x2d).at[jnp.where(valid, tok_b, t).reshape(-1)].add(
        ye.reshape(-1, d), mode="drop")
    # f32 reduction (bf16 all-reduce promotion crashes XLA:CPU; f32 accumulate
    # is also the numerically-right choice for a 16-way combine)
    y = jax.lax.psum(y.astype(jnp.float32), axis).astype(y.dtype)
    # (shared expert is applied OUTSIDE the manual region — blocks._ffn_apply)
    return y, jnp.float32(aux)


def moe_routed_a2a(p, cfg, x2d, axis: str, capacity_factor: float | None = None):
    """The paper's routing: tokens sharded over `axis` (sequence-split),
    bucketized by owner shard, all_to_all out, expert FFN NUMA-locally,
    all_to_all back. Collective bytes ~ top-k routed tokens only."""
    t, d = x2d.shape                              # t = local tokens
    if capacity_factor is None:
        capacity_factor = getattr(cfg, "moe_capacity_factor", 2.0)
    e_local = p["wi"].shape[0]
    size = axis_size(axis)
    me = jax.lax.axis_index(axis).astype(jnp.int32)
    w, idx, aux = router_probs(p, cfg, x2d)
    k = cfg.n_experts_active
    flat_dest = idx.reshape(-1)                   # global expert id
    flat_w = w.reshape(-1).astype(jnp.float32)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    owner = flat_dest // e_local                  # owner shard on `axis`

    cap = max(1, int(capacity_factor * t * k / size) + 8)
    (x_b, w_b, tok_b, e_b), valid, dropped = bucketize(
        owner, jnp.ones_like(owner, bool),
        [x2d[flat_tok].astype(cfg.compute_dtype), flat_w, flat_tok, flat_dest],
        size, cap)
    # out: [size, cap, ...] -> exchange (the queue hop to the owner NUMA node)
    a2a = lambda v: jax.lax.all_to_all(v, axis, 0, 0, tiled=False)
    x_r = a2a(x_b)
    w_r = a2a(w_b)
    e_r = a2a(e_b)
    val_r = a2a(valid.astype(jnp.uint8)).astype(bool)

    # local expert compute: bucketize arrivals by local expert
    xf = x_r.reshape(size * cap, d)
    ef = (e_r % e_local).reshape(-1)
    vf = val_r.reshape(-1)
    cap2 = max(1, int(capacity_factor * size * cap / max(e_local, 1)) + 8)
    (pos_b,), valid2, dropped2 = bucketize(
        ef, vf, [jnp.arange(size * cap, dtype=jnp.int32)], e_local, cap2)
    xe = jnp.where(valid2[..., None], xf[pos_b], 0)
    ye = _expert_ffn(p["wi"], p["wu"], p["wd"], xe, cfg.compute_dtype)
    yf = jnp.zeros_like(xf).at[
        jnp.where(valid2, pos_b, size * cap).reshape(-1)].set(
        ye.reshape(-1, d), mode="drop")

    # route back (reverse hop) and weighted-combine at the source
    y_r = a2a(yf.reshape(size, cap, d))
    w_back = w_b                                  # weights never left home order
    tok_back = tok_b
    val_back = valid
    y = jnp.zeros((t, d), y_r.dtype).at[
        jnp.where(val_back, tok_back, t).reshape(-1)].add(
        (y_r * w_back[..., None].astype(y_r.dtype)).reshape(-1, d), mode="drop")
    # (shared expert is applied OUTSIDE the manual region — blocks._ffn_apply)
    return y.astype(jnp.dtype(cfg.compute_dtype)), jnp.float32(aux)
