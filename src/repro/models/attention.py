"""Attention variants: GQA (with qk-norm / bias / sliding window) and MLA.

Three entry points per variant:
  *_forward  — full-sequence causal (train / prefill)
  *_prefill  — forward + cache write
  *_decode   — one token against a contiguous KV cache

The serving engine's paged (block-pool) attention lives in serving/ and
kernels/paged_attention; these contiguous paths are what the dry-run lowers
(sequence dim shardable over the model axis — GSPMD inserts the partial-
softmax collectives; see EXPERIMENTS.md §Perf for the measured choice).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (apply_rope, attend_causal, causal_mask, cast,
                                 dense_init, rms_norm, softmax_attend)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg):
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * dh, cfg.param_dtype),
        "wk": dense_init(ks[1], d, hkv * dh, cfg.param_dtype),
        "wv": dense_init(ks[2], d, hkv * dh, cfg.param_dtype),
        "wo": dense_init(ks[3], h * dh, d, cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), cfg.param_dtype)
        p["bk"] = jnp.zeros((hkv * dh,), cfg.param_dtype)
        p["bv"] = jnp.zeros((hkv * dh,), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((dh,), cfg.param_dtype)
    return p


def _qkv(p, cfg, x):
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ct = cfg.compute_dtype
    q = x @ cast(p["wq"], ct)
    k = x @ cast(p["wk"], ct)
    v = x @ cast(p["wv"], ct)
    if cfg.qkv_bias:
        q = q + cast(p["bq"], ct)
        k = k + cast(p["bk"], ct)
        v = v + cast(p["bv"], ct)
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def gqa_forward(p, cfg, x, positions, window: int = 0):
    """x: [B, S, D], positions: [B, S] int32. Returns [B, S, D]."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attend_causal(q, k, v, window, cfg.compute_dtype,
                      block_q=cfg.attn_block_q, impl=cfg.attn_impl)
    return o.reshape(b, s, -1) @ cast(p["wo"], cfg.compute_dtype)


def gqa_prefill(p, cfg, x, positions, cache_len: int, window: int = 0,
                past=None):
    """Forward + return the KV cache (padded to cache_len).

    `past`: {"k","v"} [B, S_past, Hkv, Dh] already-roped prefix KV (prefix
    cache reuse): the suffix attends over past+new with the right offset."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    off = 0
    if past is not None:
        off = past["k"].shape[1]
        k = jnp.concatenate([past["k"].astype(k.dtype), k], axis=1)
        v = jnp.concatenate([past["v"].astype(v.dtype), v], axis=1)
    o = attend_causal(q, k, v, window, cfg.compute_dtype,
                      block_q=cfg.attn_block_q, impl=cfg.attn_impl,
                      q_offset=off)
    y = o.reshape(b, s, -1) @ cast(p["wo"], cfg.compute_dtype)
    pad = cache_len - s - off
    kt = jnp.dtype(cfg.kv_cache_dtype)
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(kt)
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(kt)
    return y, {"k": kc, "v": vc}


def gqa_decode(p, cfg, x, pos, cache, window: int = 0):
    """x: [B, 1, D]; pos: [B] int32 (write position); contiguous cache.

    The cache seq dim may be sharded over the model axis — the score
    contraction and softmax then run as GSPMD partial-softmax collectives.
    """
    b, _, d = x.shape
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _qkv(p, cfg, x)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    s_max = cache["k"].shape[1]
    kt = cache["k"].dtype
    oh = jax.nn.one_hot(pos, s_max, dtype=jnp.bfloat16)[:, :, None, None]
    kc = (cache["k"].astype(jnp.bfloat16)
          + oh * k.astype(jnp.bfloat16)).astype(kt)
    vc = (cache["v"].astype(jnp.bfloat16)
          + oh * v.astype(jnp.bfloat16)).astype(kt)

    ki = jnp.arange(s_max, dtype=jnp.int32)[None, :]
    ok = (ki <= pos[:, None]) & ((jnp.asarray(window) <= 0)
                                 | (ki > pos[:, None] - window))
    mask = jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)      # [B, S]

    h = cfg.n_heads
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.bfloat16),
                        kc.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(dh).astype(jnp.float32) + mask[:, None, None, :]
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w.astype(jnp.bfloat16),
                   vc.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, h * dh).astype(x.dtype)
    y = o @ cast(p["wo"], cfg.compute_dtype)
    return y, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# MLA (minicpm3): latent-compressed KV, decoupled rope head
# ---------------------------------------------------------------------------

def init_mla(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    qk_nope, qk_rope, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    qd = qk_nope + qk_rope
    ks = jax.random.split(key, 8)
    p = {
        "wdq": dense_init(ks[0], d, cfg.q_lora_rank, cfg.param_dtype),
        "q_ln": jnp.ones((cfg.q_lora_rank,), cfg.param_dtype),
        "wuq": dense_init(ks[1], cfg.q_lora_rank, h * qd, cfg.param_dtype),
        "wdkv": dense_init(ks[2], d, cfg.kv_lora_rank, cfg.param_dtype),
        "kv_ln": jnp.ones((cfg.kv_lora_rank,), cfg.param_dtype),
        "wuk": dense_init(ks[3], cfg.kv_lora_rank, h * qk_nope, cfg.param_dtype),
        "wuv": dense_init(ks[4], cfg.kv_lora_rank, h * dv, cfg.param_dtype),
        "wkr": dense_init(ks[5], d, qk_rope, cfg.param_dtype),
        "wo": dense_init(ks[6], h * dv, d, cfg.param_dtype),
    }
    return p


def _mla_qckv(p, cfg, x):
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_nope, qk_rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    ct = cfg.compute_dtype
    cq = rms_norm(x @ cast(p["wdq"], ct), p["q_ln"], cfg.norm_eps)
    q = (cq @ cast(p["wuq"], ct)).reshape(b, s, h, qk_nope + qk_rope)
    q_nope, q_pe = q[..., :qk_nope], q[..., qk_nope:]
    ckv = rms_norm(x @ cast(p["wdkv"], ct), p["kv_ln"], cfg.norm_eps)  # [B,S,r]
    kpe = x @ cast(p["wkr"], ct)                                       # [B,S,rope]
    return q_nope, q_pe, ckv, kpe


def _mla_attend(p, cfg, q_nope, q_pe, ckv, kpe, mask):
    """q_*: [B,Sq,H,*]; ckv: [B,Sk,r]; kpe: [B,Sk,rope] (rope pre-applied)."""
    b, sq, h, _ = q_nope.shape
    qk_nope, dv = cfg.qk_nope_dim, cfg.v_head_dim
    ct = cfg.compute_dtype
    ckv = ckv.astype(jnp.dtype(ct))
    k_nope = (ckv @ cast(p["wuk"], ct)).reshape(b, -1, h, qk_nope)
    v = (ckv @ cast(p["wuv"], ct)).reshape(b, -1, h, dv)
    scale = 1.0 / jnp.sqrt(qk_nope + cfg.qk_rope_dim).astype(jnp.float32)
    s_n = jnp.einsum("bqhd,bshd->bqhs", q_nope.astype(jnp.bfloat16),
                     k_nope.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
    s_p = jnp.einsum("bqhd,bsd->bqhs", q_pe.astype(jnp.bfloat16),
                     kpe.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
    scores = (s_n + s_p) * scale + mask
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bqhs,bshd->bqhd", w.astype(jnp.bfloat16),
                   v.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
    y = o.reshape(b, sq, h * dv).astype(jnp.dtype(ct))
    return y @ cast(p["wo"], ct)


_MLA_BLOCK_Q = 512


def _mla_attend_causal(p, cfg, q_nope, q_pe, ckv, kpe, window):
    """Chunked-over-q causal MLA (scores never exceed [B, bq, H, Sk])."""
    b, s, h, _ = q_nope.shape
    if s <= min(_MLA_BLOCK_Q, cfg.attn_block_q) or s <= cfg.attn_block_q:
        mask = causal_mask(s, s, window=window)[None, :, None, :]
        return _mla_attend(p, cfg, q_nope, q_pe, ckv, kpe, mask)
    bq = min(_MLA_BLOCK_Q, cfg.attn_block_q)
    assert s % bq == 0
    nb = s // bq
    qn = q_nope.reshape(b, nb, bq, h, -1).transpose(1, 0, 2, 3, 4)
    qp = q_pe.reshape(b, nb, bq, h, -1).transpose(1, 0, 2, 3, 4)

    def one(carry, inp):
        i, qni, qpi = inp
        mask = causal_mask(bq, s, q_offset=i * bq, window=window
                           )[None, :, None, :]
        y = _mla_attend(p, cfg, qni, qpi, ckv, kpe, mask)
        return carry, y

    _, yb = jax.lax.scan(one, 0, (jnp.arange(nb), qn, qp))
    return yb.transpose(1, 0, 2, 3).reshape(b, s, -1)


def mla_forward(p, cfg, x, positions, window: int = 0):
    b, s, _ = x.shape
    q_nope, q_pe, ckv, kpe = _mla_qckv(p, cfg, x)
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    kpe = apply_rope(kpe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return _mla_attend_causal(p, cfg, q_nope, q_pe, ckv, kpe, window)


def mla_prefill(p, cfg, x, positions, cache_len: int, window: int = 0):
    b, s, _ = x.shape
    q_nope, q_pe, ckv, kpe = _mla_qckv(p, cfg, x)
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    kpe = apply_rope(kpe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    y = _mla_attend_causal(p, cfg, q_nope, q_pe, ckv, kpe, window)
    pad = cache_len - s
    kt = jnp.dtype(cfg.kv_cache_dtype)
    return y, {"ckv": jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))).astype(kt),
               "kpe": jnp.pad(kpe, ((0, 0), (0, pad), (0, 0))).astype(kt)}


def mla_decode(p, cfg, x, pos, cache, window: int = 0):
    """The MLA decode win: the cache is the latent (r + rope) per token —
    5-10x smaller than GQA's — re-expanded per step."""
    b, _, _ = x.shape
    q_nope, q_pe, ckv_new, kpe_new = _mla_qckv(p, cfg, x)
    q_pe = apply_rope(q_pe, pos[:, None], cfg.rope_theta)
    kpe_new = apply_rope(kpe_new[:, :, None, :], pos[:, None], cfg.rope_theta)[:, :, 0, :]
    s_max = cache["ckv"].shape[1]
    kt = cache["ckv"].dtype
    oh = jax.nn.one_hot(pos, s_max, dtype=jnp.bfloat16)[:, :, None]
    ckv = (cache["ckv"].astype(jnp.bfloat16)
           + oh * ckv_new.astype(jnp.bfloat16)).astype(kt)
    kpe = (cache["kpe"].astype(jnp.bfloat16)
           + oh * kpe_new.astype(jnp.bfloat16)).astype(kt)
    ki = jnp.arange(s_max, dtype=jnp.int32)[None, :]
    ok = (ki <= pos[:, None]) & ((jnp.asarray(window) <= 0)
                                 | (ki > pos[:, None] - window))
    mask = jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)[:, None, None, :]
    y = _mla_attend(p, cfg, q_nope, q_pe, ckv, kpe, mask)
    return y, {"ckv": ckv, "kpe": kpe}
