"""Recurrent sequence mixers: selective SSM (mamba-style, for hymba) and
xLSTM cells (mLSTM matrix memory + sLSTM scalar memory).

Training uses parallel forms (associative scan / quadratic-with-decay), decode
uses O(1) recurrent state updates — the reason these archs run the long_500k
cell that full-attention archs must skip (DESIGN.md §5).

State conventions (per layer):
  mamba: {"conv": [B, K-1, d_inner], "ssm": [B, d_inner, d_state]}
  mlstm: {"c": [B, H, dk, dv], "n": [B, H, dk], "m": [B, H]}
  slstm: {"c": [B, d], "n": [B, d], "h": [B, d], "m": [B, d]}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import cast, dense_init


# ---------------------------------------------------------------------------
# selective SSM (mamba-style)
# ---------------------------------------------------------------------------

def init_mamba(key, cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, cfg.param_dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                   * 0.1).astype(cfg.param_dtype),
        "x_proj": dense_init(ks[2], di, 1 + 2 * n, cfg.param_dtype),  # dt, B, C
        "a_log": jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)
                         )[None, :].repeat(di, 0).astype(cfg.param_dtype),
        "d_skip": jnp.ones((di,), cfg.param_dtype),
        "out_proj": dense_init(ks[3], di, d, cfg.param_dtype),
    }


def _mamba_core(p, cfg, xz, conv_state):
    """xz: [B, S, 2*di] post in_proj; returns (x_conv, z, new_conv_state)."""
    di = xz.shape[-1] // 2
    x, z = xz[..., :di], xz[..., di:]
    k = cfg.ssm_conv
    xp = jnp.concatenate([conv_state, x], axis=1)        # [B, K-1+S, di]
    # causal depthwise conv, kernel K
    w = cast(p["conv_w"], cfg.compute_dtype)
    xc = sum(xp[:, i: xp.shape[1] - (k - 1 - i), :] * w[i] for i in range(k))
    xc = jax.nn.silu(xc)
    new_conv = xp[:, -(k - 1):, :]
    return xc, z, new_conv


MAMBA_CHUNK = 256


def mamba_forward(p, cfg, x, state=None, chunk: int | None = None):
    """x: [B, S, D] -> (y, new_state). Chunked parallel scan: the [B,L,di,n]
    hidden tensor exists for one chunk at a time (L = chunk) — the memory
    shape real selective-scan kernels use."""
    b, s, d = x.shape
    if chunk is None:
        chunk = getattr(cfg, "scan_chunk", MAMBA_CHUNK)
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    ct = cfg.compute_dtype
    if state is None:
        state = {"conv": jnp.zeros((b, cfg.ssm_conv - 1, di), jnp.dtype(ct)),
                 "ssm": jnp.zeros((b, di, n), jnp.float32)}
    xz = x @ cast(p["in_proj"], ct)
    xc, z, new_conv = _mamba_core(p, cfg, xz, state["conv"])
    dbc = xc @ cast(p["x_proj"], ct)                      # [B,S,1+2n]
    dt = jax.nn.softplus(dbc[..., :1].astype(jnp.float32))       # [B,S,1]
    bmat = dbc[..., 1:1 + n].astype(jnp.float32)                 # [B,S,n]
    cmat = dbc[..., 1 + n:].astype(jnp.float32)                  # [B,S,n]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                 # [di,n]

    L = min(chunk, s)
    pad = (-s) % L
    nc = (s + pad) // L

    def chunks(arr, fill=0.0):
        arr = jnp.pad(arr, [(0, 0), (0, pad)] + [(0, 0)] * (arr.ndim - 2),
                      constant_values=fill)
        return arr.reshape((b, nc, L) + arr.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, arr.ndim + 1)))

    dt_c = chunks(dt)
    b_c = chunks(bmat)
    xc_c = chunks(xc.astype(jnp.float32))
    c_c = chunks(cmat)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    sdt = jnp.dtype(getattr(cfg, "ssm_scan_dtype", "float32"))

    def one(h0, inp):
        dtj, bj, xj, cj = inp
        da = jnp.exp(dtj[..., None] * a[None, None])             # [B,L,di,n]
        dbx = dtj[..., None] * bj[:, :, None, :] * xj[..., None]
        aa = jnp.concatenate([jnp.ones((b, 1, di, n), sdt),
                              da.astype(sdt)], 1)
        bb = jnp.concatenate([h0[:, None].astype(sdt), dbx.astype(sdt)], 1)
        _, hs = jax.lax.associative_scan(combine, (aa, bb), axis=1)
        yj = jnp.einsum("bldn,bln->bld", hs[:, 1:].astype(jnp.float32), cj)
        return hs[:, -1].astype(jnp.float32), yj

    h_last, y_c = jax.lax.scan(one, state["ssm"], (dt_c, b_c, xc_c, c_c))
    y = y_c.transpose(1, 0, 2, 3).reshape(b, nc * L, di)[:, :s]
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(jnp.dtype(ct))
    new_state = {"conv": new_conv, "ssm": h_last}
    return y @ cast(p["out_proj"], ct), new_state


def mamba_decode(p, cfg, x, state):
    """Single-token step, O(1) in context length."""
    y, new_state = mamba_forward(p, cfg, x, state)
    return y, new_state


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory)
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    di = cfg.ssm_expand * d            # up-projected width
    dh = di // h
    ks = jax.random.split(key, 8)
    return {
        "up": dense_init(ks[0], d, 2 * di, cfg.param_dtype),      # x, gate
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                   * 0.1).astype(cfg.param_dtype),
        "wq": dense_init(ks[2], di, di, cfg.param_dtype),
        "wk": dense_init(ks[3], di, di, cfg.param_dtype),
        "wv": dense_init(ks[4], di, di, cfg.param_dtype),
        "wif": dense_init(ks[5], di, 2 * h, cfg.param_dtype),     # i, f gates
        "ln": jnp.ones((di,), cfg.param_dtype),
        "down": dense_init(ks[6], di, d, cfg.param_dtype),
    }


MLSTM_CHUNK = 256


def mlstm_forward(p, cfg, x, state=None, chunk: int | None = None):
    """Chunkwise-parallel mLSTM: O(S·L) memory (L = chunk), quadratic only
    inside a chunk; the inter-chunk recurrence carries the stabilized matrix
    memory (C, n, m) — the same state decode uses. Returns (y, new_state)."""
    b, s, d = x.shape
    if chunk is None:
        chunk = getattr(cfg, "scan_chunk", MLSTM_CHUNK)
    h = cfg.n_heads
    di = cfg.ssm_expand * d
    dh = di // h
    ct = cfg.compute_dtype
    if state is None:
        state = mlstm_init_state(cfg, b)
    xu = x @ cast(p["up"], ct)
    xm, z = xu[..., :di], xu[..., di:]
    k_ = cfg.ssm_conv
    xp = jnp.concatenate([state["conv"].astype(xm.dtype), xm], axis=1)
    w = cast(p["conv_w"], ct)
    xc = sum(xp[:, i: xp.shape[1] - (k_ - 1 - i), :] * w[i] for i in range(k_))
    xc = jax.nn.silu(xc)
    new_conv = xp[:, -(k_ - 1):, :]

    q = (xc @ cast(p["wq"], ct)).reshape(b, s, h, dh)
    kk = (xc @ cast(p["wk"], ct)).reshape(b, s, h, dh) / jnp.sqrt(dh)
    v = (xm @ cast(p["wv"], ct)).reshape(b, s, h, dh)
    gif = (xc @ cast(p["wif"], ct)).astype(jnp.float32)
    log_i = gif[..., :h]                                   # [B,S,H]
    log_f = jax.nn.log_sigmoid(gif[..., h:])               # [B,S,H]

    L = min(chunk, s)
    pad = (-s) % L
    nc = (s + pad) // L

    def pad_chunks(a, fill=0.0):
        a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                    constant_values=fill)
        return a.reshape((b, nc, L) + a.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, a.ndim + 1)))       # [NC, B, L, ...]

    qc = pad_chunks(q.astype(jnp.float32))
    kc = pad_chunks(kk.astype(jnp.float32))
    vc = pad_chunks(v.astype(jnp.float32))
    lic = pad_chunks(log_i, fill=-1e30)                    # pad never writes
    lfc = pad_chunks(log_f, fill=0.0)                      # pad never decays

    def step(carry, inp):
        C, n, m_c = carry                                  # [B,H,dk,dv],[B,H,dk],[B,H]
        qj, kj, vj, lij, lfj = inp                         # [B,L,...]
        cf = jnp.cumsum(lfj, axis=1)                       # [B,L,H]
        # intra-chunk decay D_ts = cf_t - cf_s + li_s (causal)
        Dm = cf[:, :, None, :] - cf[:, None, :, :] + lij[:, None, :, :]
        ti = jnp.arange(L)
        Dm = jnp.where((ti[None, :, None] >= ti[None, None, :])[..., None],
                       Dm, -jnp.inf)
        m_intra = jnp.max(Dm, axis=2)                      # [B,L,H]
        b_t = cf + m_c[:, None, :]                         # carry path decay
        m_t = jnp.maximum(m_intra, b_t)                    # [B,L,H]
        dexp = jnp.exp(Dm - m_t[:, :, None, :])
        scores = jnp.einsum("blhd,bshd->blsh", qj, kj) * dexp
        num = jnp.einsum("blsh,bshd->blhd", scores, vj)
        den = jnp.sum(scores, axis=2)                      # [B,L,H]
        cfac = jnp.exp(b_t - m_t)                          # [B,L,H]
        num = num + jnp.einsum("blhd,bhde->blhe", qj, C) * cfac[..., None]
        den = den + jnp.einsum("blhd,bhd->blh", qj, n) * cfac
        yj = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # fold chunk into state
        cfL = cf[:, -1]                                    # [B,H]
        dk_s = cfL[:, None, :] - cf + lij                  # [B,L,H]
        m_next = jnp.maximum(cfL + m_c, jnp.max(dk_s, axis=1))
        sfac = jnp.exp(dk_s - m_next[:, None, :])
        C2 = (C * jnp.exp(cfL + m_c - m_next)[..., None, None]
              + jnp.einsum("blh,blhd,blhe->bhde", sfac, kj, vj))
        n2 = (n * jnp.exp(cfL + m_c - m_next)[..., None]
              + jnp.einsum("blh,blhd->bhd", sfac, kj))
        return (C2, n2, m_next), yj

    carry0 = (state["c"], state["n"], state["m"])
    (C, n, m_c), ys = jax.lax.scan(step, carry0, (qc, kc, vc, lic, lfc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * L, di)[:, :s]
    y = y.astype(jnp.dtype(ct)) * jax.nn.silu(z) * cast(p["ln"], ct)
    new_state = {"conv": new_conv, "c": C, "n": n, "m": m_c}
    return y @ cast(p["down"], ct), new_state


def mlstm_init_state(cfg, b):
    h = cfg.n_heads
    di = cfg.ssm_expand * cfg.d_model
    dh = di // h
    return {"conv": jnp.zeros((b, cfg.ssm_conv - 1, di), jnp.dtype(cfg.compute_dtype)),
            "c": jnp.zeros((b, h, dh, dh), jnp.float32),
            "n": jnp.zeros((b, h, dh), jnp.float32),
            "m": jnp.zeros((b, h), jnp.float32)}


def mlstm_decode(p, cfg, x, state):
    """O(1) recurrent step: C_t = f C_{t-1} + i v k^T (stabilized)."""
    b, _, d = x.shape
    h = cfg.n_heads
    di = cfg.ssm_expand * d
    dh = di // h
    ct = cfg.compute_dtype
    xu = x @ cast(p["up"], ct)
    xm, z = xu[..., :di], xu[..., di:]
    k_ = cfg.ssm_conv
    xp = jnp.concatenate([state["conv"], xm], axis=1)       # [B, K, di]
    w = cast(p["conv_w"], ct)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", xp, w))[:, None, :]
    q = (xc @ cast(p["wq"], ct)).reshape(b, h, dh)
    kk = (xc @ cast(p["wk"], ct)).reshape(b, h, dh) / jnp.sqrt(dh)
    v = (xm @ cast(p["wv"], ct)).reshape(b, h, dh)
    gif = (xc @ cast(p["wif"], ct)).astype(jnp.float32).reshape(b, 2 * h)
    log_i, log_f = gif[:, :h], jax.nn.log_sigmoid(gif[:, h:])
    m_new = jnp.maximum(state["m"] + log_f, log_i)
    fdec = jnp.exp(state["m"] + log_f - m_new)[..., None]
    iexp = jnp.exp(log_i - m_new)[..., None]
    c = state["c"] * fdec[..., None] + iexp[..., None] * jnp.einsum(
        "bhd,bhe->bhde", kk.astype(jnp.float32), v.astype(jnp.float32))
    n = state["n"] * fdec + iexp * kk.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n)),
                      jnp.exp(-m_new))[..., None]
    y = (num / den).reshape(b, 1, di).astype(jnp.dtype(ct))
    y = y * jax.nn.silu(z) * cast(p["ln"], ct)
    new_state = {"conv": xp[:, 1:], "c": c, "n": n, "m": m_new}
    return y @ cast(p["down"], ct), new_state


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, true recurrence -> lax.scan)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "wx": dense_init(ks[0], d, 4 * d, cfg.param_dtype),    # z i f o
        "wh": dense_init(ks[1], d, 4 * d, cfg.param_dtype,
                         scale=0.5 / jnp.sqrt(d)),
        "b": jnp.zeros((4 * d,), cfg.param_dtype),
        "out": dense_init(ks[2], d, d, cfg.param_dtype),
    }


def slstm_init_state(cfg, b):
    d = cfg.d_model
    z = jnp.zeros((b, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def _slstm_step(p, cfg, carry, xt):
    c, n, hprev, m = carry
    d = cfg.d_model
    pre = (xt.astype(jnp.float32) @ p["wx"].astype(jnp.float32)
           + hprev @ p["wh"].astype(jnp.float32) + p["b"].astype(jnp.float32))
    z, i, f, o = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    log_f = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(log_f + m, i)
    ie = jnp.exp(i - m_new)
    fe = jnp.exp(log_f + m - m_new)
    c2 = fe * c + ie * z
    n2 = fe * n + ie
    h2 = o * c2 / jnp.maximum(n2, 1.0)
    return (c2, n2, h2, m_new), h2


def slstm_forward(p, cfg, x, state=None):
    b, s, d = x.shape
    if state is None:
        state = slstm_init_state(cfg, b)
    carry = (state["c"], state["n"], state["h"], state["m"])
    carry, ys = jax.lax.scan(lambda c, xt: _slstm_step(p, cfg, c, xt),
                             carry, x.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    new_state = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    return y @ cast(p["out"], cfg.compute_dtype), new_state


def slstm_decode(p, cfg, x, state):
    carry = (state["c"], state["n"], state["h"], state["m"])
    carry, y = _slstm_step(p, cfg, carry, x[:, 0, :])
    new_state = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    return (y[:, None, :].astype(x.dtype)) @ cast(p["out"], cfg.compute_dtype), new_state
