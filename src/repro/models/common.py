"""Shared model components: norms, rotary embeddings, init, dtype policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cast(x, dtype_str: str):
    return x.astype(jnp.dtype(dtype_str))


def dense_init(key, d_in: int, d_out: int, dtype="float32", scale: float | None = None):
    s = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def embed_init(key, n: int, d: int, dtype="float32"):
    return (jax.random.normal(key, (n, d), jnp.float32) * 0.02).astype(dtype)


def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, Dh] (or [..., H, Dh] with scalar pos), half-dim rotation."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos = jnp.cos(ang)[..., None, :]                    # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def causal_mask(s_q: int, s_k: int, q_offset=0, window=0):
    """[s_q, s_k] additive mask; window > 0 = sliding-window attention.
    `window` may be a traced scalar (hymba mixes global/window per layer)."""
    qi = jnp.arange(s_q)[:, None] + q_offset
    ki = jnp.arange(s_k)[None, :]
    ok = (ki <= qi) & ((jnp.asarray(window) <= 0) | (ki > qi - window))
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


ATTEND_BLOCK_Q = 512


def attend_causal(q, k, v, window=0, compute_dtype="bfloat16",
                  block_q: int = ATTEND_BLOCK_Q, impl: str = "xla",
                  q_offset: int = 0):
    """Causal (optionally windowed) attention.

    impl="xla":       chunked over query blocks — scores materialize as
                      [B, block_q, H, Sk] per chunk (baseline dry-run path).
    impl="xla_flash": the full flash algorithm in XLA — a kv-block inner
                      scan with online-softmax carry; no [.., Sk]-wide score
                      tensor ever reaches HBM (the §Perf memory-term lever;
                      kernels/flash_attention is the true TPU kernel).

    q: [B,Sq,H,Dh]; k/v: [B,Sk,Hkv,Dh] -> [B,Sq,H,Dh].
    """
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    if impl == "xla_flash":
        return _attend_flash_xla(q, k, v, window, compute_dtype, block_q,
                                 q_offset)
    if sq <= block_q:
        return softmax_attend(q, k, v,
                              causal_mask(sq, sk, q_offset=q_offset,
                                          window=window), compute_dtype)
    assert sq % block_q == 0
    nb = sq // block_q
    qb = q.reshape(b, nb, block_q, h, dh).transpose(1, 0, 2, 3, 4)

    def one(carry, inp):
        i, qi = inp
        qg = qi.reshape(b, block_q, hkv, g, dh)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qg.astype(jnp.bfloat16),
                       k.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
        s = s / jnp.sqrt(dh).astype(jnp.float32)
        qi_idx = q_offset + i * block_q + jnp.arange(block_q)[:, None]
        ki_idx = jnp.arange(sk)[None, :]
        ok = (ki_idx <= qi_idx) & ((jnp.asarray(window) <= 0)
                                   | (ki_idx > qi_idx - window))
        s = jnp.where(ok[None, :, None, None, :], s, -jnp.inf)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bqkgs,bskd->bqkgd", w.astype(jnp.bfloat16),
                       v.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
        return carry, o.reshape(b, block_q, h, dh).astype(jnp.dtype(compute_dtype))

    _, ob = jax.lax.scan(one, 0, (jnp.arange(nb), qb))
    return ob.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


def _attend_flash_xla(q, k, v, window, compute_dtype, block: int,
                      q_offset: int = 0):
    """Online-softmax double loop in pure XLA (scan over kv blocks inside a
    scan over q blocks). Causal block skip via where on the carry."""
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    bq = min(block, sq)
    bk = min(block, sk)
    assert sq % bq == 0 and sk % bk == 0
    nq, nk = sq // bq, sk // bk
    qb = q.reshape(b, nq, bq, h, dh).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(b, nk, bk, hkv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, bk, hkv, dh).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    def q_step(_, qin):
        i, qi = qin
        qg = qi.reshape(b, bq, hkv, g, dh).astype(jnp.bfloat16)

        def kv_step(carry, kin):
            j, kj, vj = kin
            m, l, acc = carry
            live = j * bk <= q_offset + i * bq + bq - 1  # causal relevance
            s = jnp.einsum("bqkgd,bskd->bqkgs", qg, kj.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32) * scale
            qi_idx = q_offset + i * bq + jnp.arange(bq)[:, None]
            ki_idx = j * bk + jnp.arange(bk)[None, :]
            ok = (ki_idx <= qi_idx) & ((jnp.asarray(window) <= 0)
                                       | (ki_idx > qi_idx - window))
            s = jnp.where(ok[None, :, None, None, :], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = (acc * alpha[..., None]
                       + jnp.einsum("bqkgs,bskd->bqkgd", p.astype(jnp.bfloat16),
                                    vj.astype(jnp.bfloat16),
                                    preferred_element_type=jnp.float32))
            keep = live
            m = jnp.where(keep, m_new, m)
            l = jnp.where(keep, l_new, l)
            acc = jnp.where(keep, acc_new, acc)
            return (m, l, acc), None

        m0 = jnp.full((b, bq, hkv, g), -1e30, jnp.float32)
        l0 = jnp.zeros((b, bq, hkv, g), jnp.float32)
        a0 = jnp.zeros((b, bq, hkv, g, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nk), kb, vb))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, o.reshape(b, bq, h, dh).astype(jnp.dtype(compute_dtype))

    _, ob = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    return ob.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


def softmax_attend(q, k, v, mask, compute_dtype="bfloat16"):
    """q:[B,Sq,H,Dh] k/v:[B,Sk,Hkv,Dh] -> [B,Sq,H,Dh]; GQA broadcast of kv.

    Scores accumulate in f32 (MXU-friendly bf16 inputs, f32 accumulation).
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bqkgs", qg.astype(jnp.bfloat16),
                        k.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    scores = scores + mask[None, :, None, None, :]
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", w.astype(jnp.bfloat16),
                     v.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, dh).astype(jnp.dtype(compute_dtype))
