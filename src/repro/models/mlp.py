"""SwiGLU MLP (all assigned dense archs use gated MLPs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import cast, dense_init


def init_mlp(key, d_model: int, d_ff: int, dtype="float32"):
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], d_model, d_ff, dtype),   # gate
        "wu": dense_init(ks[1], d_model, d_ff, dtype),   # up
        "wd": dense_init(ks[2], d_ff, d_model, dtype),   # down
    }


def mlp(p, x, compute_dtype="bfloat16"):
    g = x @ cast(p["wi"], compute_dtype)
    u = x @ cast(p["wu"], compute_dtype)
    return (jax.nn.silu(g) * u) @ cast(p["wd"], compute_dtype)
