"""LM assembly: embeddings -> scanned block groups -> head.

Layers scan over homogeneous groups (period = block pattern length) with
stacked params — compact HLO at 126 layers, remat per group. Three lowered
entry points match the assigned shape kinds:

  forward  (train_4k)       [B,S] tokens -> [B,S,V] logits
  prefill  (prefill_32k)    + contiguous KV caches
  decode   (decode_32k/long_500k)  one token vs caches

Modality frontends are stubs per the assignment: `prefix_embeds` carries
precomputed patch/frame embeddings (vlm/audio); musicgen inputs are
[B, n_codebooks, S] EnCodec token grids with summed codebook embeddings and
factored heads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.blocks import (block_decode, block_forward, block_init_cache,
                                 block_kinds, block_prefill, layer_windows)
from repro.models.blocks import init_block
from repro.models.common import cast, embed_init, rms_norm


def init_params(key, cfg):
    kinds = block_kinds(cfg)
    period = len(kinds)
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    ng = cfg.n_layers // period
    keys = jax.random.split(key, period + 3)
    vp = cfg.padded_vocab

    blocks = []
    for i, kind in enumerate(kinds):
        gkeys = jax.random.split(keys[i], ng)
        blocks.append(jax.vmap(lambda k, i=i, kind=kind: init_block(k, cfg, kind)
                               )(gkeys))
    p = {
        "blocks": tuple(blocks),
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if cfg.n_codebooks:
        p["embed"] = jax.vmap(lambda k: embed_init(k, vp, cfg.d_model,
                                                   cfg.param_dtype))(
            jax.random.split(keys[period], cfg.n_codebooks))
        p["lm_head"] = embed_init(keys[period + 1],
                                  cfg.n_codebooks * vp, cfg.d_model,
                                  cfg.param_dtype).T
    else:
        p["embed"] = embed_init(keys[period], vp, cfg.d_model, cfg.param_dtype)
        if not cfg.tie_embeddings:
            p["lm_head"] = embed_init(keys[period + 1], vp, cfg.d_model,
                                      cfg.param_dtype).T
    return p


def _embed(p, cfg, tokens):
    ct = jnp.dtype(cfg.compute_dtype)
    if cfg.n_codebooks:
        # tokens: [B, n_cb, S] -> sum of codebook embeddings
        def one(cb, tok):
            return p["embed"][cb][tok]
        embs = [p["embed"][c][tokens[:, c, :]] for c in range(cfg.n_codebooks)]
        return sum(embs).astype(ct)
    return p["embed"][tokens].astype(ct)


def _head(p, cfg, x):
    ct = jnp.dtype(cfg.compute_dtype)
    w = p.get("lm_head")
    if w is None:
        w = p["embed"].T
    logits = (x @ cast(w, ct)).astype(jnp.float32)
    vp, v = cfg.padded_vocab, cfg.vocab_size
    if cfg.n_codebooks:
        b, s, _ = logits.shape
        logits = logits.reshape(b, s, cfg.n_codebooks, vp)
    if vp != v:
        pad_mask = jnp.arange(logits.shape[-1]) >= v
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def _windows_grouped(cfg):
    kinds = block_kinds(cfg)
    period = len(kinds)
    ng = cfg.n_layers // period
    return layer_windows(cfg).reshape(ng, period)


def forward(p, cfg, tokens, positions=None, prefix_embeds=None):
    """Returns (logits, aux). aux = summed MoE load-balance loss."""
    x = _embed(p, cfg, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    kinds = block_kinds(cfg)
    wins = _windows_grouped(cfg)

    def group(x, xs):
        bparams, wrow = xs
        aux = jnp.float32(0.0)
        for i, kind in enumerate(kinds):
            x, a = block_forward(bparams[i], cfg, kind, x, positions, wrow[i])
            aux = aux + a
        return x, aux

    g = jax.checkpoint(group) if cfg.remat else group
    if cfg.scan_layers:
        x, auxs = jax.lax.scan(g, x, (p["blocks"], wins))
        aux = jnp.sum(auxs)
    else:
        ng = wins.shape[0]
        aux = jnp.float32(0.0)
        for j in range(ng):
            bp = jax.tree.map(lambda a: a[j], p["blocks"])
            x, a = g(x, (bp, wins[j]))
            aux = aux + a
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    return _head(p, cfg, x), aux


def init_caches(p, cfg, batch: int, cache_len: int):
    kinds = block_kinds(cfg)
    ng = cfg.n_layers // len(kinds)
    caches = []
    for kind in kinds:
        one = block_init_cache(cfg, kind, batch, cache_len)
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (ng,) + a.shape), one))
    return tuple(caches)


def prefill(p, cfg, tokens, cache_len: int, positions=None, prefix_embeds=None):
    """Returns (logits, caches, aux)."""
    x = _embed(p, cfg, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    kinds = block_kinds(cfg)
    wins = _windows_grouped(cfg)

    def group(x, xs):
        bparams, wrow = xs
        caches = []
        for i, kind in enumerate(kinds):
            x, c, _ = block_prefill(bparams[i], cfg, kind, x, positions,
                                    cache_len, wrow[i])
            caches.append(c)
        return x, tuple(caches)

    g = jax.checkpoint(group) if cfg.remat else group
    if cfg.scan_layers:
        x, caches = jax.lax.scan(g, x, (p["blocks"], wins))
    else:
        ng = wins.shape[0]
        outs = []
        for j in range(ng):
            bp = jax.tree.map(lambda a: a[j], p["blocks"])
            x, c = g(x, (bp, wins[j]))
            outs.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    return _head(p, cfg, x), caches, jnp.float32(0.0)


def prefill_with_past(p, cfg, tokens, past_k, past_v, cache_len: int):
    """Suffix prefill against cached prefix KV (prefix-cache reuse; GQA
    transformer families). past_k/v: [ng, B, S_past, Hkv, Dh] roped.
    Returns (logits, caches, aux) with caches covering past+suffix."""
    x = _embed(p, cfg, tokens)
    b, s, _ = x.shape
    s_past = past_k.shape[2]
    positions = jnp.broadcast_to(
        (s_past + jnp.arange(s, dtype=jnp.int32))[None], (b, s))
    kinds = block_kinds(cfg)
    assert kinds == ["dense"], "prefix reuse: GQA transformer families"
    wins = _windows_grouped(cfg)

    def group(x, xs):
        bparams, pk, pv, wrow = xs
        x, c, _ = block_prefill(bparams[0], cfg, "dense", x, positions,
                                cache_len, wrow[0], past={"k": pk, "v": pv})
        return x, (c,)

    x, caches = jax.lax.scan(group, x, (p["blocks"], past_k, past_v, wins))
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    return _head(p, cfg, x), caches, jnp.float32(0.0)


def decode_step(p, cfg, token, pos, caches):
    """token: [B,1] (or [B,n_cb,1]); pos: [B] int32; returns (logits, caches)."""
    x = _embed(p, cfg, token)
    kinds = block_kinds(cfg)
    wins = _windows_grouped(cfg)

    def group(x, xs):
        bparams, cach, wrow = xs
        new = []
        for i, kind in enumerate(kinds):
            x, c = block_decode(bparams[i], cfg, kind, x, pos, cach[i], wrow[i])
            new.append(c)
        return x, tuple(new)

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(group, x, (p["blocks"], caches, wins))
    else:
        ng = wins.shape[0]
        outs = []
        for j in range(ng):
            bp = jax.tree.map(lambda a: a[j], p["blocks"])
            cj = jax.tree.map(lambda a: a[j], caches)
            x, c = group(x, (bp, cj, wins[j]))
            outs.append(c)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    return _head(p, cfg, x), new_caches


def cross_entropy(logits, labels, mask=None):
    """f32 CE with optional [B,S] mask; handles musicgen's codebook dim."""
    if logits.ndim == 4:  # [B,S,n_cb,V] with labels [B,n_cb,S]
        labels = labels.transpose(0, 2, 1)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if logits.ndim == 4:
        nll = jnp.mean(nll, axis=-1)
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)
