"""Decoder blocks, one per architecture family, with a uniform scan-friendly
signature: every layer of an arch shares one block structure (heterogeneous
patterns — xlstm's 7:1 mLSTM:sLSTM, hymba's global-attention layers — are
expressed as a fixed period of positions, scanned over groups).

Block kinds: dense | moe | mlstm | slstm | hymba
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as att
from repro.models import ssm
from repro.models.common import cast, rms_norm
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe


def block_kinds(cfg) -> list[str]:
    """The per-period list of block kinds for this config."""
    if cfg.block_pattern == "xlstm":
        p = cfg.slstm_every or 8
        return ["mlstm"] * (p - 1) + ["slstm"]
    if cfg.block_pattern == "hybrid":
        return ["hymba"]
    if cfg.is_moe:
        return ["moe"]
    return ["dense"]


def layer_windows(cfg) -> jnp.ndarray:
    """Per-layer sliding-window sizes (0 = global), [n_layers] int32.

    hymba: full attention on layer 0, the middle layer and the last layer;
    sliding window elsewhere (arXiv:2411.13676)."""
    n = cfg.n_layers
    if cfg.sliding_window <= 0:
        return jnp.zeros((n,), jnp.int32)
    w = jnp.full((n,), cfg.sliding_window, jnp.int32)
    for g in (0, n // 2, n - 1):
        w = w.at[g].set(0)
    return w


def init_block(key, cfg, kind: str):
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.ones((cfg.d_model,), cfg.param_dtype)}
    if kind in ("dense", "moe", "hymba"):
        p["attn"] = (att.init_mla(ks[0], cfg) if cfg.attn_type == "mla"
                     else att.init_gqa(ks[0], cfg))
        p["ln2"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
        if kind == "moe":
            p["ffn"] = init_moe(ks[1], cfg)
        else:
            p["ffn"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.param_dtype)
        if kind == "hymba":
            p["mamba"] = ssm.init_mamba(ks[2], cfg)
            p["attn_ln"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
            p["mamba_ln"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
    elif kind == "mlstm":
        p["cell"] = ssm.init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["cell"] = ssm.init_slstm(ks[0], cfg)
        p["ln2"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
        p["ffn"] = init_mlp(ks[1], cfg.d_model,
                            max(cfg.d_ff, 4 * cfg.d_model // 3), cfg.param_dtype)
    return p


def _ffn_apply(p, cfg, x):
    """MLP or MoE on [B, S, D]; returns (y, aux)."""
    if cfg.is_moe and "router" in p:
        from repro.models import moe as moe_mod
        b, s, d = x.shape
        x2 = x.reshape(b * s, d)
        impl = cfg.moe_impl
        if impl == "dense":
            y2, aux = moe_mod.moe_dense_ffn(p, cfg, x2)
        else:
            y2, aux = _moe_sharded(p, cfg, x2, impl)
            if cfg.n_shared_experts:
                # shared expert runs under GSPMD auto-sharding (its weights
                # are TP-sharded like a dense MLP; no manual collectives)
                y2 = y2 + mlp(p["shared"], x2, cfg.compute_dtype)
        return y2.reshape(b, s, d).astype(x.dtype), aux
    return mlp(p, x, cfg.compute_dtype), jnp.float32(0.0)


def _moe_sharded(p, cfg, x2d, impl: str):
    """Nested shard_map over the model axis (GSPMD auto elsewhere)."""
    from repro.core.routing import mesh_shard_map
    from jax.sharding import PartitionSpec as P
    from repro.models import moe as moe_mod
    from repro.parallel.sharding import current_mesh, mesh_cfg

    mesh = current_mesh()
    if mesh is None:  # single-device smoke: fall back to reference dispatch
        return moe_mod.moe_dense_ffn(p, cfg, x2d)
    mc = mesh_cfg()
    tp = mc["tp_axis"]
    dp = tuple(mc["dp_axes"])
    # fully-manual region over (dp..., tp): GSPMD makes zero resharding
    # decisions inside the dispatch (its gather-resharding fallback emits
    # invalid programs on some (arch x mesh) combos — observed llama4@16x16)
    manual = set(dp) | {tp}

    def _mean_aux(aux):
        for a in manual:
            aux = jax.lax.pmean(aux, a)
        return aux

    tok_spec = P((*dp, tp), None)           # tokens split over all axes
    if impl == "routed_a2a":
        def fn(pp, xx):
            y, aux = moe_mod.moe_routed_a2a(pp, cfg, xx, tp)
            return y, _mean_aux(aux)
        in_specs = (_expert_specs(p, tp), tok_spec)
        out_specs = (tok_spec, P())
    else:
        def fn(pp, xx):
            y, aux = moe_mod.moe_replicated_psum(pp, cfg, xx, tp)
            return y, _mean_aux(aux)
        in_specs = (_expert_specs(p, tp), P(tuple(dp), None))
        out_specs = (P(tuple(dp), None), P())
    y2, aux = mesh_shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                        axis_names=manual, check_vma=False)(p, x2d)
    return y2, aux


def _expert_specs(p, tp):
    from jax.sharding import PartitionSpec as P

    def spec(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        if name in ("wi", "wu", "wd") and leaf.ndim == 3:
            return P(tp, None, None)       # experts over the model axis
        return P(*([None] * leaf.ndim))
    return jax.tree_util.tree_map_with_path(spec, p)


# ---------------------------------------------------------------------------
# forward / prefill / decode per block
# ---------------------------------------------------------------------------

def block_forward(p, cfg, kind, x, positions, window):
    """x: [B,S,D] -> (x', aux)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("dense", "moe"):
        a = (att.mla_forward(p["attn"], cfg, h, positions, window)
             if cfg.attn_type == "mla"
             else att.gqa_forward(p["attn"], cfg, h, positions, window))
        x = x + a
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        f, aux = _ffn_apply(p["ffn"], cfg, h2)
        return x + f, aux
    if kind == "hymba":
        a = att.gqa_forward(p["attn"], cfg, h, positions, window)
        m, _ = ssm.mamba_forward(p["mamba"], cfg, h)
        a = rms_norm(a, p["attn_ln"], cfg.norm_eps)
        m = rms_norm(m, p["mamba_ln"], cfg.norm_eps)
        x = x + 0.5 * (a + m)
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        f, aux = _ffn_apply(p["ffn"], cfg, h2)
        return x + f, aux
    if kind == "mlstm":
        y, _ = ssm.mlstm_forward(p["cell"], cfg, h)
        return x + y, jnp.float32(0.0)
    if kind == "slstm":
        y, _ = ssm.slstm_forward(p["cell"], cfg, h)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp(p["ffn"], h2, cfg.compute_dtype), jnp.float32(0.0)
    raise ValueError(kind)


def block_init_cache(cfg, kind, batch: int, cache_len: int):
    ct = jnp.dtype(cfg.compute_dtype)
    kt = jnp.dtype(cfg.kv_cache_dtype)
    if kind in ("dense", "moe"):
        if cfg.attn_type == "mla":
            return {"ckv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), kt),
                    "kpe": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), kt)}
        dh = cfg.resolved_head_dim
        return {"k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, dh), kt),
                "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, dh), kt)}
    if kind == "hymba":
        dh = cfg.resolved_head_dim
        di = cfg.ssm_expand * cfg.d_model
        return {"k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, dh), kt),
                "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, dh), kt),
                "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), ct),
                "ssm": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32)}
    if kind == "mlstm":
        st = ssm.mlstm_init_state(cfg, batch)
        return st
    if kind == "slstm":
        return ssm.slstm_init_state(cfg, batch)
    raise ValueError(kind)


def block_prefill(p, cfg, kind, x, positions, cache_len, window, past=None):
    """Returns (x', cache, aux). `past`: roped prefix KV (dense/GQA only) —
    prefix-cache reuse skips recomputing the shared pages."""
    b = x.shape[0]
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("dense", "moe"):
        if cfg.attn_type == "mla":
            a, cache = att.mla_prefill(p["attn"], cfg, h, positions, cache_len, window)
        else:
            a, cache = att.gqa_prefill(p["attn"], cfg, h, positions, cache_len,
                                       window, past=past)
        x = x + a
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        f, aux = _ffn_apply(p["ffn"], cfg, h2)
        return x + f, cache, aux
    if kind == "hymba":
        a, kv = att.gqa_prefill(p["attn"], cfg, h, positions, cache_len, window)
        st0 = {"conv": jnp.zeros((b, cfg.ssm_conv - 1,
                                  cfg.ssm_expand * cfg.d_model), h.dtype),
               "ssm": jnp.zeros((b, cfg.ssm_expand * cfg.d_model,
                                 cfg.ssm_state), jnp.float32)}
        m, st = ssm.mamba_forward(p["mamba"], cfg, h, st0)
        a = rms_norm(a, p["attn_ln"], cfg.norm_eps)
        m = rms_norm(m, p["mamba_ln"], cfg.norm_eps)
        x = x + 0.5 * (a + m)
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        f, aux = _ffn_apply(p["ffn"], cfg, h2)
        return x + f, {**kv, **st}, aux
    if kind == "mlstm":
        y, st = ssm.mlstm_forward(p["cell"], cfg, h,
                                  ssm.mlstm_init_state(cfg, b))
        return x + y, st, jnp.float32(0.0)
    if kind == "slstm":
        y, st = ssm.slstm_forward(p["cell"], cfg, h, ssm.slstm_init_state(cfg, b))
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp(p["ffn"], h2, cfg.compute_dtype), st, jnp.float32(0.0)
    raise ValueError(kind)


def block_decode(p, cfg, kind, x, pos, cache, window):
    """x: [B,1,D]; returns (x', cache')."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("dense", "moe"):
        if cfg.attn_type == "mla":
            a, cache = att.mla_decode(p["attn"], cfg, h, pos, cache, window)
        else:
            a, cache = att.gqa_decode(p["attn"], cfg, h, pos, cache, window)
        x = x + a
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.is_moe and "router" in p["ffn"]:
            from repro.models.moe import moe_dense_ffn
            b, s, d = h2.shape
            f, _ = moe_dense_ffn(p["ffn"], cfg, h2.reshape(b, d))
            f = f.reshape(b, 1, d).astype(x.dtype)
        else:
            f = mlp(p["ffn"], h2, cfg.compute_dtype)
        return x + f, cache
    if kind == "hymba":
        kv = {"k": cache["k"], "v": cache["v"]}
        a, kv = att.gqa_decode(p["attn"], cfg, h, pos, kv, window)
        st = {"conv": cache["conv"], "ssm": cache["ssm"]}
        m, st = ssm.mamba_decode(p["mamba"], cfg, h, st)
        a = rms_norm(a, p["attn_ln"], cfg.norm_eps)
        m = rms_norm(m, p["mamba_ln"], cfg.norm_eps)
        x = x + 0.5 * (a + m)
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp(p["ffn"], h2, cfg.compute_dtype), {**kv, **st}
    if kind == "mlstm":
        y, st = ssm.mlstm_decode(p["cell"], cfg, h, cache)
        return x + y, st
    if kind == "slstm":
        y, st = ssm.slstm_decode(p["cell"], cfg, h, cache)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp(p["ffn"], h2, cfg.compute_dtype), st
    raise ValueError(kind)
