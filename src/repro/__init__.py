"""repro — JAX/TPU framework built around the concurrent-data-structures paper.

64-bit keys are first-class citizens (the paper packs 64-bit keys + 64-bit
pointers into 128-bit atomic words); we enable x64 globally and keep all model
code on explicit int32/bf16/f32 dtypes.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "0.1.0"
