"""AdamW with global-norm clipping. Optimizer state inherits the parameter
sharding (2D fsdp x tp), so moments are ZeRO-sharded by construction —
no separate partitioner needed."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.int32(0),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, clip_norm=1.0):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-6))
    step = state["step"] + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / c1
        vh = v2 / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gn
