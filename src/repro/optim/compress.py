"""Gradient compression for the slow (inter-pod DCI) axis.

int8 quantization with error feedback: each pod keeps the quantization
residual and adds it to the next step's gradient — unbiased in the long run
(1-bit-Adam-style). The exchange is an all_gather of int8 shards + local
dequant-sum, which moves half the bytes of a bf16 psum on a 2-pod mesh (and
the HLO collective-bytes parser in launch/roofline.py sees exactly that —
this is a measured §Perf lever, not a claim).

Used inside a shard_map over ("pod",) with the intra-pod axes on GSPMD auto.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.routing import axis_size


def compress_state_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def pod_allreduce_compressed(grads, residuals, axis: str):
    """Per-leaf: g' = mean_pods(dequant(quant(g + residual))); residual
    updated with the local quantization error. Returns (grads', residuals')."""
    npods = axis_size(axis)

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = _quant(g32)
        err = g32 - q.astype(jnp.float32) * scale
        # exchange int8 payloads + f32 scales (scales are scalar per leaf)
        qg = jax.lax.all_gather(q, axis)                  # [P, ...] int8
        sg = jax.lax.all_gather(scale, axis)              # [P]
        summed = jnp.tensordot(sg, qg.astype(jnp.float32), axes=(0, 0))
        return (summed / npods).astype(g.dtype), err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))


def pod_allreduce_plain(grads, axis: str):
    npods = axis_size(axis)
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
