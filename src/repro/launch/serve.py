"""Serving launcher: reduced-config continuous batching on CPU, or --dryrun
to lower the full decode/prefill cells on the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --requests 12
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-405b --dryrun
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dryrun:
        import os
        import subprocess
        import sys
        rc = 0
        for shape in ("prefill_32k", "decode_32k"):
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
                   args.arch, "--shape", shape]
            if args.multi_pod:
                cmd.append("--multi-pod")
            rc |= subprocess.run(cmd, env=os.environ).returncode
        raise SystemExit(rc)

    import numpy as np
    import jax
    import repro  # noqa: F401
    from repro.configs import get_reduced
    from repro.models import model as M
    from repro.serving.engine import Engine, Request

    cfg = get_reduced(args.arch)
    if cfg.attn_type != "gqa" or cfg.block_pattern != "transformer":
        raise SystemExit(f"{args.arch}: paged engine serves GQA transformer "
                         f"families; recurrent archs decode via model state "
                         f"(see launch/dryrun decode cells)")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_reqs=4, num_pages=64, page_size=8,
                 max_pages_per_req=8)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(req_id=i,
                           prompt=rng.integers(1, cfg.vocab_size, 8),
                           max_new=args.max_new, priority=i % 3))
    outs = eng.run(max_steps=512)
    toks = sum(len(v) for v in outs.values())
    print(f"served {len(outs)} requests / {toks} tokens; "
          f"pool free={int(eng.kv.pool.num_free())}")


if __name__ == "__main__":
    main()
