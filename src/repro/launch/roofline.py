import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# (must run in the dry-run's 512-device environment)

"""Roofline analysis from the compiled dry-run artifacts.

Terms per (arch x shape x mesh), all in seconds-per-step per device:
  compute term    = HLO_FLOPs_per_dev / peak_FLOPs      (197 TF/s bf16, v5e)
  memory term     = HLO_bytes_per_dev / HBM_bw          (819 GB/s)
  collective term = collective_bytes_per_dev / link_bw  (~50 GB/s/link ICI)

Scan-body correction (measured: XLA cost_analysis counts a scan body ONCE,
not x trip count): we lower each cell twice more with n_layers = period and
2 x period; the difference isolates the per-layer-group cost and the affine
extrapolation  total = base + n_groups * group  recovers true per-step
totals (collective bytes parsed from HLO text get the same treatment; the
optimizer/head live in `base`). Microbatch probes run mb=1 with the full
batch in one body, so totals need no mb factor.

MODEL_FLOPS = 6*N*D (train, D = tokens incl. frontend) or 2*N_active*B
(decode) — the ratio MODEL_FLOPS / HLO_FLOPs_total flags remat/redundancy
waste (>1/3 of compute non-useful is a §Perf target).
"""
import argparse
import json
import math

import jax

import repro  # noqa: F401
from repro.configs import ALL, get_config
from repro.configs.base import SHAPES, cells_for
from repro.launch.dryrun import MICROBATCHES, lower_cell
from repro.models.blocks import block_kinds

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9}


def count_params(cfg, active_only: bool = False) -> float:
    """Analytic parameter count (embedding + blocks + head)."""
    d = cfg.d_model
    if cfg.family == "kvstore":
        return 0.0
    vp = cfg.padded_vocab
    emb = vp * d * (cfg.n_codebooks or 1)
    head = 0 if cfg.tie_embeddings else vp * d * (cfg.n_codebooks or 1)
    kinds = block_kinds(cfg)
    per_period = 0.0
    dh = cfg.resolved_head_dim
    for kind in kinds:
        p = 0.0
        if kind in ("dense", "moe", "hymba"):
            if cfg.attn_type == "mla":
                qd = cfg.qk_nope_dim + cfg.qk_rope_dim
                p += (d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * qd
                      + d * cfg.kv_lora_rank
                      + cfg.kv_lora_rank * cfg.n_heads
                      * (cfg.qk_nope_dim + cfg.v_head_dim)
                      + d * cfg.qk_rope_dim + cfg.n_heads * cfg.v_head_dim * d)
            else:
                p += d * (cfg.n_heads + 2 * cfg.n_kv_heads) * dh
                p += cfg.n_heads * dh * d
            if kind == "moe":
                e = cfg.n_experts_active if active_only else cfg.n_experts
                p += e * 3 * d * cfg.d_expert + d * cfg.n_experts
                p += cfg.n_shared_experts * 3 * d * cfg.d_expert
            else:
                p += 3 * d * cfg.d_ff
            if kind == "hymba":
                di = cfg.ssm_expand * d
                p += d * 2 * di + di * (1 + 2 * cfg.ssm_state) + di * d
        elif kind == "mlstm":
            di = cfg.ssm_expand * d
            p += d * 2 * di + 3 * di * di + di * 2 * cfg.n_heads + di * d
        elif kind == "slstm":
            p += d * 4 * d + d * 4 * d + d * d
            p += 3 * d * max(cfg.d_ff, 4 * d // 3)
        per_period += p
    n_groups = cfg.n_layers // len(kinds)
    return emb + head + per_period * n_groups


def model_flops(cfg, shape) -> float:
    n_act = count_params(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch        # decode: one token/req


def _extract(rep):
    return (rep["flops"], rep["bytes_accessed"],
            rep["collective_bytes_total"])


def analyze_cell(arch: str, shape_name: str, multi_pod: bool,
                 full_report: dict | None = None,
                 overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    if full_report is None:
        full_report = lower_cell(arch, shape_name, multi_pod,
                                 overrides=overrides)
    out = dict(full_report)

    if cfg.family == "kvstore":
        flops_t, bytes_t, coll_t = _extract(full_report)
    else:
        period = len(block_kinds(cfg))
        # UNROLLED probes: scan_layers off + inner chunk scans disabled so
        # every op is counted x its true trip count (see §Method notes)
        unroll = {"scan_layers": False, "attn_block_q": 1 << 30,
                  "scan_chunk": 1 << 30, "remat": False}
        ov = dict(overrides or {})
        f1 = lower_cell(arch, shape_name, multi_pod, microbatches=1,
                        donate=False,
                        overrides={**ov, **unroll, "n_layers": period})
        f2 = lower_cell(arch, shape_name, multi_pod, microbatches=1,
                        donate=False,
                        overrides={**ov, **unroll, "n_layers": 2 * period})
        g = [b - a for a, b in zip(_extract(f1), _extract(f2))]
        base = [a - d for a, d in zip(_extract(f1), g)]
        ng = cfg.n_layers // period
        flops_t, bytes_t, coll_t = [max(b, 0) + ng * max(dd, 0)
                                    for b, dd in zip(base, g)]
        # remat recompute: the production step rematerializes each layer
        # group in the backward -> +1 forward pass of the group compute
        if shape.kind == "train" and cfg.remat:
            # fwd ~= 1/3 of fwd+bwd group flops
            flops_t = flops_t + ng * max(g[0], 0) / 3.0
        # sLSTM's time recurrence is a lax.scan the probes cannot unroll
        # (sequential): add its per-token flops analytically
        if cfg.block_pattern == "xlstm" and shape.kind != "decode":
            d = cfg.d_model
            tokens = shape.global_batch * shape.seq_len
            n_slstm = cfg.n_layers // (cfg.slstm_every or 8)
            mult = 3.0 if shape.kind == "train" else 1.0
            missing = (tokens - shape.global_batch) * 18 * d * d * mult
            flops_t += n_slstm * missing / full_report["devices"]

    terms = {
        "compute_s": flops_t / HW["peak_flops"],
        "memory_s": bytes_t / HW["hbm_bw"],
        "collective_s": coll_t / HW["link_bw"],
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = flops_t * full_report["devices"]
    out.update({
        "flops_per_dev_corrected": flops_t,
        "bytes_per_dev_corrected": bytes_t,
        "collective_bytes_per_dev_corrected": coll_t,
        "terms": terms,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": (mf / hlo_total) if hlo_total else 0.0,
        "step_time_lb_s": max(terms.values()),
        "roofline_fraction": (mf / HW["peak_flops"] / full_report["devices"]
                              / max(terms.values())) if max(terms.values()) else 0.0,
    })
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="reports/roofline")
    ap.add_argument("--dryrun-dir", default="reports/dryrun")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cells = []
    if args.all:
        for arch in ALL:
            names = (["train_4k"] if arch == "paper-kvstore" else cells_for(arch))
            for sh in names:
                cells.append((arch, sh))
    else:
        cells.append((args.arch, args.shape))

    for arch, sh in cells:
        tag = f"{arch}__{sh}__{'2x16x16' if args.multi_pod else '16x16'}"
        full = None
        fp = os.path.join(args.dryrun_dir, tag + ".json")
        if os.path.exists(fp):
            with open(fp) as f:
                full = json.load(f)
        try:
            rep = analyze_cell(arch, sh, args.multi_pod, full_report=full)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rep, f, indent=1)
            t = rep["terms"]
            print(f"{tag:58s} comp={t['compute_s']*1e3:8.2f}ms "
                  f"mem={t['memory_s']*1e3:8.2f}ms coll={t['collective_s']*1e3:8.2f}ms "
                  f"dom={rep['dominant'][:-2]:10s} useful={rep['useful_ratio']:.2f} "
                  f"roofline={rep['roofline_fraction']:.3f}", flush=True)
        except Exception as e:
            print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)


if __name__ == "__main__":
    main()
