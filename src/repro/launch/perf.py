import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: evaluate config variants against a cell's baseline
roofline terms (hypothesis -> change -> re-lower -> before/after).

  PYTHONPATH=src python -m repro.launch.perf --arch llama3-405b \
      --shape train_4k --variants flash remat_off mb32

Known variants (composable, comma-free names):
  flash      attn_impl=xla_flash     (online-softmax double loop: removes
                                      [.., Sk]-wide score traffic)
  kvfp8      kv_cache_dtype=float8   (halves decode cache bytes vs bf16)
  moe_psum   moe_impl=replicated_psum (the remote-heavy MoE baseline)
  moe_a2a    moe_impl=routed_a2a      (the paper's routing)
  remat_off  remat=False
  mb<k>      microbatches=k
  bq<k>      attn_block_q=k
  chunk<k>   scan_chunk=k
"""
import argparse
import json
import re

import repro  # noqa: F401
from repro.launch.roofline import analyze_cell


def parse_variant(v: str):
    if v == "flash":
        return {"attn_impl": "xla_flash"}, None
    if v == "kvfp8":
        return {"kv_cache_dtype": "float8_e4m3fn"}, None
    if v == "moe_psum":
        return {"moe_impl": "replicated_psum"}, None
    if v == "moe_a2a":
        return {"moe_impl": "routed_a2a"}, None
    if v == "remat_off":
        return {"remat": False}, None
    m = re.fullmatch(r"mb(\d+)", v)
    if m:
        return {}, int(m.group(1))
    m = re.fullmatch(r"bq(\d+)", v)
    if m:
        return {"attn_block_q": int(m.group(1))}, None
    m = re.fullmatch(r"chunk(\d+)", v)
    if m:
        return {"scan_chunk": int(m.group(1))}, None
    if v == "ssmbf16":
        return {"ssm_scan_dtype": "bfloat16"}, None
    m = re.fullmatch(r"cf(\d+)", v)   # cf125 -> capacity factor 1.25
    if m:
        return {"moe_capacity_factor": int(m.group(1)) / 100.0}, None
    if v == "seq2d":
        return {"decode_shard": "seq2d"}, None
    if v == "podcomp":
        return {"pod_compress": True}, None
    raise SystemExit(f"unknown variant {v}")


def run_variant(arch, shape, multi_pod, overrides, microbatches):
    from repro.launch.dryrun import lower_cell
    full = lower_cell(arch, shape, multi_pod, microbatches=microbatches,
                      overrides=overrides or None)
    return analyze_cell(arch, shape, multi_pod, full_report=full,
                        overrides=overrides or None)


def fmt(rep):
    t = rep["terms"]
    mem = rep.get("memory", {})
    return (f"comp={t['compute_s']*1e3:9.2f}ms mem={t['memory_s']*1e3:9.2f}ms "
            f"coll={t['collective_s']*1e3:9.2f}ms dom={rep['dominant'][:-2]:10s} "
            f"useful={rep['useful_ratio']:.2f} roofline={rep['roofline_fraction']:.3f} "
            f"tempGiB={mem.get('temp_bytes', 0)/2**30:.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variants", nargs="+", default=[])
    ap.add_argument("--combine", nargs="*", default=None,
                    help="additionally evaluate all listed variants together")
    ap.add_argument("--out", default="reports/perf")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    tag = f"{args.arch}__{args.shape}__{'2x16x16' if args.multi_pod else '16x16'}"

    base = run_variant(args.arch, args.shape, args.multi_pod, {}, None)
    print(f"BASE      {tag}\n          {fmt(base)}", flush=True)
    results = {"baseline": base}
    for v in args.variants:
        ov, mb = parse_variant(v)
        rep = run_variant(args.arch, args.shape, args.multi_pod, ov, mb)
        results[v] = rep
        dom = base["dominant"]
        delta = (1 - rep["terms"][dom] / base["terms"][dom]) * 100
        print(f"VAR {v:10s} {fmt(rep)}\n          baseline-dominant({dom[:-2]}) "
              f"delta: {delta:+.1f}%", flush=True)
    if args.combine:
        ov_all, mb_all = {}, None
        for v in args.combine:
            ov, mb = parse_variant(v)
            ov_all.update(ov)
            mb_all = mb or mb_all
        rep = run_variant(args.arch, args.shape, args.multi_pod, ov_all, mb_all)
        results["+".join(args.combine)] = rep
        print(f"COMBINED  {fmt(rep)}", flush=True)
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
