"""Training launcher.

Single-host CPU (default): runs the real loop on a reduced/100M config.
--dryrun: lowers the FULL assigned config on the production mesh instead
(no allocation; see launch/dryrun.py for the sweep).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --steps 100
  PYTHONPATH=src python -m repro.launch.train --arch llama3-405b --dryrun
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dryrun:
        # re-exec through the dryrun module so XLA_FLAGS lands first
        import os
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
               args.arch, "--shape", "train_4k"]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.run(cmd, env=os.environ).returncode)

    import repro  # noqa: F401
    from repro.configs import get_reduced
    from repro.configs.base import ShapeConfig
    from repro.train.loop import train

    cfg = get_reduced(args.arch)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    _, _, out = train(cfg, shape, steps=args.steps, ckpt_dir=args.ckpt,
                      ckpt_every=50 if args.ckpt else 0,
                      microbatches=args.microbatches)
    h = out["history"]
    print(f"final loss {h[-1]['loss']:.4f} over {len(h)} steps")


if __name__ == "__main__":
    main()
