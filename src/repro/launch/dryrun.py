import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import — jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective data for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out reports/]

No arrays are ever materialized: params/optimizer/caches enter as
ShapeDtypeStructs with NamedShardings (jax.eval_shape over the init fns) and
jit(...).lower(...).compile() proves the distribution is coherent.
"""
import argparse
import json
import math
import re
import sys
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro  # noqa: F401  (x64)
from repro.configs import ALL, ARCHS, get_config
from repro.configs.base import SHAPES, cells_for
from repro.launch.mesh import dp_axes_of, make_production_mesh, n_devices
from repro.models import model as M
from repro.models.blocks import block_kinds
from repro.optim.adamw import adamw_init
from repro.parallel.sharding import param_spec, use_mesh
from repro.train.step import make_serve_decode, make_serve_prefill, make_train_step

# per-cell tuning (microbatches bound activation memory; these are the
# baseline settings — §Perf iterates them)
MICROBATCHES = {
    "llama3-405b": 16, "qwen1.5-110b": 8, "qwen3-moe-235b-a22b": 8,
    "llama4-scout-17b-a16e": 4, "llava-next-mistral-7b": 2,
}

COLLECTIVE_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(")
SHAPE_RE = re.compile(r"([a-z]+[0-9]+)\[([0-9,]*)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16}


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-buffer bytes of every collective op (per-device program)."""
    out = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "= " not in line:
            continue
        kind = m.group(1)
        lhs = line.split("= ", 1)[1] if " = " in line else line
        sm = SHAPE_RE.search(lhs)
        if not sm:
            continue
        dt, dims = sm.group(1), sm.group(2)
        size = DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                size *= int(d)
        e = out.setdefault(kind, {"count": 0, "bytes": 0})
        e["count"] += 1
        e["bytes"] += size
    return out


def shaped(tree, spec_fn, mesh):
    """eval_shape pytree -> ShapeDtypeStructs with NamedShardings."""
    def attach(path, leaf):
        sp = spec_fn(path, leaf)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, sp))
    return jax.tree_util.tree_map_with_path(attach, tree)


def _pathstr(path):
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _maybe_axes(dim, axes, mesh):
    if not axes:
        return None
    n = math.prod(mesh.shape[a] for a in axes)
    return axes if (n and dim % n == 0) else None


def param_specs_fn(mesh, dp_axes):
    def fn(path, leaf):
        ps = _pathstr(path)
        shape = leaf.shape
        if "blocks" in ps and len(shape) >= 1:
            inner = shape[1:]
            sp = param_spec(ps, inner, mesh, dp_axes, "model")
            return P(None, *sp)
        return param_spec(ps, shape, mesh, dp_axes, "model")
    return fn


def opt_specs_fn(mesh, dp_axes):
    pfn = param_specs_fn(mesh, dp_axes)

    def fn(path, leaf):
        ps = _pathstr(path)
        if ps.endswith("step") or leaf.ndim == 0:
            return P()
        # moments under "adam/m" / "adam/v" mirror the param tree paths
        return pfn(path[2:] if len(path) > 2 else path, leaf)
    return fn


def batch_specs_fn(mesh, dp_axes):
    def fn(path, leaf):
        dp = _maybe_axes(leaf.shape[0], dp_axes, mesh)
        return P(dp, *([None] * (leaf.ndim - 1)))
    return fn


def cache_specs_fn(cfg, mesh, dp_axes, batch):
    """Contiguous decode caches: batch over dp when divisible, the big
    context dim (seq / di / dk) over the model axis when divisible.

    decode_shard="seq2d" (§Perf lever): batch replicated, the cache seq dim
    sharded over (dp..., model) jointly — weights stay stationary and the
    per-step collectives shrink to partial-softmax stats."""
    seq2d = getattr(cfg, "decode_shard", "batch") == "seq2d"
    seq_axes = (*dp_axes, "model") if seq2d else ("model",)

    def fn(path, leaf):
        ps = _pathstr(path)
        name = ps.split("/")[-1]
        shape = leaf.shape  # leading ng stack dim
        dpax = None if seq2d else _maybe_axes(batch, dp_axes, mesh)
        rest = [None] * (leaf.ndim - 2)
        if name in ("k", "v") and leaf.ndim == 5:        # [ng,B,S,Hkv,Dh]
            mod = _maybe_axes(shape[2], seq_axes, mesh)
            return P(None, dpax, mod, None, None)
        if name in ("ckv", "kpe") and leaf.ndim == 4:    # [ng,B,S,r]
            mod = _maybe_axes(shape[2], seq_axes, mesh)
            return P(None, dpax, mod, None)
        if name == "c" and leaf.ndim == 5:               # mlstm [ng,B,H,dk,dv]
            mod = _maybe_axes(shape[4], ("model",), mesh)
            return P(None, dpax, None, None, mod)
        if name in ("ssm",) and leaf.ndim == 4:          # [ng,B,di,n]
            mod = _maybe_axes(shape[2], ("model",), mesh)
            return P(None, dpax, mod, None)
        if name in ("conv",) and leaf.ndim == 4:         # [ng,B,K-1,di]
            mod = _maybe_axes(shape[3], ("model",), mesh)
            return P(None, dpax, None, mod)
        if leaf.ndim >= 3:                               # slstm [ng,B,d] etc.
            mod = _maybe_axes(shape[-1], ("model",), mesh)
            return P(None, dpax, *([None] * (leaf.ndim - 3)), mod)
        return P(*([None] * leaf.ndim))
    return fn


def make_inputs_train(cfg, shape, mesh, dp_axes):
    b, s = shape.global_batch, shape.seq_len
    bs = batch_specs_fn(mesh, dp_axes)
    dp = _maybe_axes(b, dp_axes, mesh)
    f32 = jnp.float32
    if cfg.n_codebooks:
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, cfg.n_codebooks, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, cfg.n_codebooks, s), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((b, s), f32),
        }
    else:
        ft = cfg.frontend_tokens
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s - ft), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((b, s), f32),
        }
        if ft:
            batch["prefix_embeds"] = jax.ShapeDtypeStruct((b, ft, cfg.d_model), f32)
    return {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                    sharding=NamedSharding(mesh, bs((), v)))
            for k, v in batch.items()}


def input_specs(arch: str, shape_name: str, mesh):
    """Public entry: ShapeDtypeStruct stand-ins for every model input of the
    given cell (weak-type-correct, shardable, no device allocation)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    dp_axes = dp_axes_of(mesh)
    return make_inputs_train(cfg, shape, mesh, dp_axes)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               microbatches: int | None = None, donate: bool = True,
               overrides: dict | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp_axes = dp_axes_of(mesh)
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    report = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "devices": n_devices(mesh)}

    with use_mesh(mesh, dp_axes=dp_axes, tp_axis="model"):
        if cfg.family == "kvstore":
            from repro.store.engine import make_store_step, sharded_init
            lanes = cfg.store_lanes
            nsh = n_devices(mesh)
            report["store_backend"] = cfg.store_backend
            report["store_exec"] = cfg.store_exec
            state = jax.eval_shape(partial(sharded_init, cfg.store_backend,
                                           nsh, cfg.store_capacity))
            sp = P(tuple(mesh.axis_names))
            state = jax.tree.map(lambda l: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=NamedSharding(
                    mesh, P(tuple(mesh.axis_names), *([None] * (l.ndim - 1))))), state)
            stream = lambda dt: jax.ShapeDtypeStruct(
                (nsh * lanes,), dt, sharding=NamedSharding(mesh, sp))
            step = make_store_step(mesh, tuple(mesh.axis_names), lanes,
                                   backend=cfg.store_backend,
                                   exec_mode=cfg.store_exec)
            lowered = jax.jit(step).lower(state, stream(jnp.int32),
                                          stream(jnp.uint64), stream(jnp.uint64))
        elif shape.kind == "train":
            mb = microbatches or MICROBATCHES.get(arch, 1)
            report["microbatches"] = mb
            pfn = param_specs_fn(mesh, dp_axes)
            params = shaped(jax.eval_shape(
                partial(M.init_params, jax.random.PRNGKey(0), cfg)), pfn, mesh)
            opt = shaped(jax.eval_shape(lambda p: {"adam": adamw_init(p)},
                                        params), opt_specs_fn(mesh, dp_axes), mesh)
            batch = make_inputs_train(cfg, shape, mesh, dp_axes)
            use_comp = getattr(cfg, "pod_compress", False) and "pod" in mesh.axis_names
            if use_comp:
                from repro.optim.compress import compress_state_init
                res = shaped(jax.eval_shape(compress_state_init, params),
                             param_specs_fn(mesh, dp_axes), mesh)
                opt = {**opt, "residuals": res}
                step = make_train_step(cfg, microbatches=mb, pod_compress=True,
                                       mesh=mesh)
            else:
                step = make_train_step(cfg, microbatches=mb)
            lowered = jax.jit(
                step, donate_argnums=(0, 1) if donate else ()).lower(
                params, opt, batch)
        elif shape.kind == "prefill":
            pfn = param_specs_fn(mesh, dp_axes)
            params = shaped(jax.eval_shape(
                partial(M.init_params, jax.random.PRNGKey(0), cfg)), pfn, mesh)
            batch = make_inputs_train(cfg, shape, mesh, dp_axes)
            batch.pop("labels")
            batch.pop("loss_mask")
            step = make_serve_prefill(cfg, cache_len=shape.seq_len)
            lowered = jax.jit(step).lower(params, batch)
        else:  # decode
            b, s = shape.global_batch, shape.seq_len
            pfn = param_specs_fn(mesh, dp_axes)
            params = shaped(jax.eval_shape(
                partial(M.init_params, jax.random.PRNGKey(0), cfg)), pfn, mesh)
            caches = jax.eval_shape(partial(M.init_caches, None, cfg, b, s))
            caches = shaped(caches, cache_specs_fn(cfg, mesh, dp_axes, b), mesh)
            dp = (None if getattr(cfg, "decode_shard", "batch") == "seq2d"
                  else _maybe_axes(b, dp_axes, mesh))
            tok_shape = ((b, cfg.n_codebooks, 1) if cfg.n_codebooks else (b, 1))
            token = jax.ShapeDtypeStruct(tok_shape, jnp.int32,
                                         sharding=NamedSharding(
                                             mesh, P(dp, *([None] * (len(tok_shape) - 1)))))
            pos = jax.ShapeDtypeStruct((b,), jnp.int32,
                                       sharding=NamedSharding(mesh, P(dp)))
            step = make_serve_decode(cfg)
            lowered = jax.jit(
                step, donate_argnums=(3,) if donate else ()).lower(
                params, token, pos, caches)

        compiled = lowered.compile()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # jax 0.4.x: one dict per device
            ca = ca[0] if ca else {}
        ma = compiled.memory_analysis()
        report["flops"] = float(ca.get("flops", 0.0))
        report["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
        if ma is not None:
            report["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
            }
        report["collectives"] = parse_collective_bytes(compiled.as_text())
        report["collective_bytes_total"] = sum(
            v["bytes"] for v in report["collectives"].values())
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in ALL:
            names = (["train_4k"] if arch == "paper-kvstore"
                     else cells_for(arch))
            for sh in names:
                cells.append((arch, sh))
    else:
        cells.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, sh in cells:
        for mp in meshes:
            tag = f"{arch}__{sh}__{'2x16x16' if mp else '16x16'}"
            try:
                rep = lower_cell(arch, sh, mp, microbatches=args.microbatches)
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(rep, f, indent=1)
                mem = rep.get("memory", {})
                per_dev = (mem.get("argument_bytes", 0)
                           + mem.get("temp_bytes", 0)) / rep["devices"]
                print(f"OK   {tag:60s} flops={rep['flops']:.3e} "
                      f"coll={rep['collective_bytes_total']:.3e}B "
                      f"mem/dev~{per_dev/2**30:.2f}GiB", flush=True)
            except Exception as e:
                failures += 1
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc(limit=3)
    print(f"done: {len(cells) * len(meshes) - failures} ok, {failures} failed")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
