"""Markdown table generation from reports/dryrun + reports/roofline JSONs.

  PYTHONPATH=src python -m repro.launch.report --kind dryrun
  PYTHONPATH=src python -m repro.launch.report --kind roofline
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(d):
    out = {}
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            rep = json.load(f)
        out[(rep["arch"], rep["shape"], rep["mesh"])] = rep
    return out


def dryrun_table(d="reports/dryrun"):
    reps = load(d)
    print("| arch | shape | mesh | flops/dev (HLO) | bytes/dev | collective B/dev "
          "| arg GiB/dev | temp GiB/dev | collectives |")
    print("|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(reps.items()):
        mem = r.get("memory", {})
        dev = r["devices"]
        arg = mem.get("argument_bytes", 0) / 2**30
        tmp = mem.get("temp_bytes", 0) / 2**30
        colls = ",".join(f"{k.split('-')[-1][:4]}:{v['count']}"
                         for k, v in sorted(r.get("collectives", {}).items()))
        print(f"| {arch} | {shape} | {mesh} | {r['flops']:.2e} | "
              f"{r['bytes_accessed']:.2e} | {r['collective_bytes_total']:.2e} | "
              f"{arg:.2f} | {tmp:.2f} | {colls} |")


def roofline_table(d="reports/roofline"):
    reps = load(d)
    print("| arch | shape | mesh | compute ms | memory ms | collective ms | "
          "dominant | MODEL_FLOPS | useful | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(reps.items()):
        t = r["terms"]
        print(f"| {arch} | {shape} | {mesh} | {t['compute_s']*1e3:.2f} | "
              f"{t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} | "
              f"{r['dominant'][:-2]} | {r['model_flops']:.2e} | "
              f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", choices=["dryrun", "roofline"], default="dryrun")
    ap.add_argument("--dir", default=None)
    a = ap.parse_args()
    if a.kind == "dryrun":
        dryrun_table(a.dir or "reports/dryrun")
    else:
        roofline_table(a.dir or "reports/roofline")
