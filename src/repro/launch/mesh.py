"""Production mesh builders. Functions, not module constants — importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16, 16) ("data", "model").
    Multi-pod: 2 pods = 512 chips (2, 16, 16) ("pod", "data", "model") —
    the pod axis is the DCI (slow) hop; routing and gradient exchange treat
    it hierarchically (coarsest first), per the paper's NUMA hierarchy."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if hasattr(jax.sharding, "AxisType"):      # jax >= 0.5
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)          # 0.4.x: Auto is the default


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_devices(mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= mesh.shape[a]
    return n
