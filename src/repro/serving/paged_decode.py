"""Decode step reading/writing KV through the paged block pool.

Supports the GQA-attention families (dense/vlm/audio/moe backbones); the
recurrent families decode through their O(1) states (model.decode_step) and
use the pool for state blocks instead.

Per layer: project q/k/v for the new token, paged attention over the pool
pages (Pallas kernel in interpret mode, or the jnp oracle), collect the new
token's K/V per layer, and scatter all layers into the pool in one update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.models.common import apply_rope, cast, rms_norm
from repro.models.mlp import mlp
from repro.serving.kvcache import PagedKV, write_decode_token


def paged_decode_step(params, cfg, tokens, slots, kv: PagedKV,
                      mask=None, use_kernel: bool = False):
    """tokens: [B, 1] int32; slots: [B] request slots (rows in block_tables).
    Call AFTER grow_for_decode — lengths already count the new token.
    `mask`: [B] bool — padding lanes must not write pages (they alias slot 0).
    Returns (logits [B, V], kv')."""
    assert cfg.attn_type == "gqa" and cfg.block_pattern == "transformer"
    b = tokens.shape[0]
    if mask is None:
        mask = jnp.ones((b,), bool)
    ct = jnp.dtype(cfg.compute_dtype)
    dh = cfg.resolved_head_dim
    x = params["embed"][tokens].astype(ct)                 # [B, 1, D]
    pos = kv.lengths[slots] - 1                            # new token position
    tables = kv.block_tables[slots]
    lengths = kv.lengths[slots]

    attend = paged_attention if use_kernel else paged_attention_ref

    def layer(x, xs):
        bp, k_pool, v_pool = xs
        p = bp["attn"] if "attn" in bp else bp
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q = (h @ cast(p["wq"], ct)).reshape(b, cfg.n_heads, dh)
        kn = (h @ cast(p["wk"], ct)).reshape(b, 1, cfg.n_kv_heads, dh)
        vn = (h @ cast(p["wv"], ct)).reshape(b, 1, cfg.n_kv_heads, dh)
        if cfg.qkv_bias:
            q = q + cast(p["bq"], ct).reshape(cfg.n_heads, dh)
            kn = kn + cast(p["bk"], ct).reshape(1, cfg.n_kv_heads, dh)
            vn = vn + cast(p["bv"], ct).reshape(1, cfg.n_kv_heads, dh)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            kn = rms_norm(kn, p["k_norm"], cfg.norm_eps)
        q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        kn = apply_rope(kn, pos[:, None], cfg.rope_theta)
        # write this token's K/V into its page BEFORE attending (the token
        # attends to itself) — single-page scatter
        page = kv.page_size
        pid = tables[jnp.arange(b), jnp.maximum(pos, 0) // page]
        off = jnp.maximum(pos, 0) % page
        pidx = jnp.where(mask & (pid >= 0), pid, k_pool.shape[0])
        k_pool = k_pool.at[pidx, off].set(kn[:, 0].astype(k_pool.dtype),
                                          mode="drop")
        v_pool = v_pool.at[pidx, off].set(vn[:, 0].astype(v_pool.dtype),
                                          mode="drop")
        o = attend(q, k_pool, v_pool, tables, lengths)
        y = (o.reshape(b, 1, cfg.n_heads * dh).astype(ct)
             @ cast(p["wo"], ct))
        x = x + y
        h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
        if cfg.is_moe and "router" in bp["ffn"]:
            from repro.models.moe import moe_dense_ffn
            f, _ = moe_dense_ffn(bp["ffn"], cfg, h2.reshape(b, -1))
            f = f.reshape(b, 1, -1).astype(x.dtype)
        else:
            f = mlp(bp["ffn"], h2, cfg.compute_dtype)
        return x + f, (k_pool, v_pool)

    x, pools = jax.lax.scan(layer, x, (params["blocks"][0], kv.k, kv.v))
    kv = kv._replace(k=pools[0], v=pools[1])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params.get("lm_head")
    if w is None:
        w = params["embed"].T
    logits = (x[:, 0] @ cast(w, ct)).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(logits.shape[-1]) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits, kv
