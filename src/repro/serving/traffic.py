"""Seeded heavy-traffic generator: the "millions of users" workload shape
at test scale.

`make_trace(seed, ...)` builds a deterministic request trace with the four
properties production serving traffic is hard for (the ROADMAP's
heavy-traffic story, scaled down):

* **Zipf-skewed prompt keys** — prompts share page-aligned prefixes drawn
  from a small pool with Zipf(`zipf_a`) popularity, so a few hot prefixes
  dominate (what makes the prefix cache earn its keep).
* **Bursty Poisson arrivals** — requests arrive in bursts of
  1 + Poisson(`burst_mean`) separated by geometric gaps of mean
  `1/burst_rate` ticks, not a smooth trickle (what makes bulk-pop-k
  admission earn its keep).
* **Mixed prompt lengths** — per request, prefix pages from
  `prefix_pages` plus a fresh suffix from `suffix_lens` (uneven prefill
  cost, uneven page demand).
* **Priority inversion** — every `inversion_every`-th request is an
  urgent (priority 0) short request arriving in the SAME burst as
  long low-priority (priority 2) bulk work; correct schedulers admit it
  first anyway (priority before FIFO), and the trace makes regressions
  here visible.

Every number comes from one `numpy` generator seeded with `seed`: the same
seed is the same trace, bit for bit — the determinism contract the serve
benchmark and the e2e replay test build on. `replay(...)` drives a
`serving.engine.Engine` through a trace tick by tick.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One arrival of the trace (prompt tokens are a host numpy array)."""
    req_id: int
    arrival: int          # replay tick the request becomes visible
    prompt: np.ndarray    # int32 tokens; leading pages come from the pool
    max_new: int
    priority: int         # 0 = most urgent (scheduler key high bits)
    deadline: int = -1    # admission deadline in ticks from submit (<0: none)


def make_trace(seed: int = 0, n_requests: int = 24, *, page_size: int = 8,
               vocab: int = 256, n_prefixes: int = 4, zipf_a: float = 1.3,
               burst_rate: float = 0.6, burst_mean: float = 2.0,
               prefix_pages=(1, 2), suffix_lens=(3, 6, 11),
               max_new=(3, 5), inversion_every: int = 6,
               deadline_frac: float = 0.0, deadline_slack=(4, 8),
               overload_at: int | None = None,
               overload_n: int = 0) -> list[TraceRequest]:
    """Deterministic heavy-traffic trace: list of `TraceRequest`, sorted by
    (arrival, req_id). See the module docstring for what each knob shapes.

    Degradation scenario knobs (docs/resilience.md; off by default, and
    when off they draw NOTHING from the generator, so pre-existing traces
    stay bit-identical):

    * `deadline_frac` — that fraction of requests (seeded draw) carries an
      admission deadline of `rng.choice(deadline_slack)` ticks; the engine
      drops expired requests (`deadline_expired`).
    * `overload_at` / `overload_n` — a seeded burst of `overload_n`
      LOW-priority (band 2) requests all arriving at tick `overload_at`,
      sized to push the backlog past a shedding engine's threshold (the
      `shed` counter's workload).
    """
    rng = np.random.default_rng(seed)
    # page-aligned shared-prefix pool (token blocks the prefix cache keys)
    longest = max(prefix_pages)
    pool = rng.integers(1, vocab, (n_prefixes, longest * page_size),
                        dtype=np.int64).astype(np.int32)
    # bounded Zipf popularity over pool ranks
    p = 1.0 / np.arange(1, n_prefixes + 1, dtype=np.float64) ** zipf_a
    p /= p.sum()

    out: list[TraceRequest] = []
    tick = 0
    rid = 0
    while rid < n_requests:
        # burst of arrivals at this tick
        burst = 1 + int(rng.poisson(burst_mean))
        inversion = any((rid + j + 1) % inversion_every == 0
                        for j in range(min(burst, n_requests - rid)))
        for j in range(burst):
            if rid >= n_requests:
                break
            urgent = (rid + 1) % inversion_every == 0
            pref = int(rng.choice(n_prefixes, p=p))
            npages = int(rng.choice(prefix_pages))
            suffix = int(rng.choice(suffix_lens))
            if inversion and urgent:
                prio, npages, suffix = 0, min(prefix_pages), min(suffix_lens)
            elif inversion:
                prio, npages, suffix = 2, max(prefix_pages), max(suffix_lens)
            else:
                prio = int(rng.choice((1, 2)))
            prompt = np.concatenate([
                pool[pref, :npages * page_size],
                rng.integers(1, vocab, suffix, dtype=np.int64).astype(np.int32),
            ])
            dl = -1
            if deadline_frac > 0.0 and rng.random() < deadline_frac:
                dl = int(rng.choice(deadline_slack))
            out.append(TraceRequest(req_id=rid, arrival=tick, prompt=prompt,
                                    max_new=int(rng.choice(max_new)),
                                    priority=prio, deadline=dl))
            rid += 1
        tick += 1 + int(rng.geometric(burst_rate))
    if overload_n > 0:
        # seeded low-priority flood at one tick: enough simultaneous band-2
        # arrivals to trip a shedding engine's backlog threshold
        at = overload_at if overload_at is not None else 0
        for j in range(overload_n):
            suffix = int(rng.choice(suffix_lens))
            prompt = rng.integers(1, vocab, suffix,
                                  dtype=np.int64).astype(np.int32)
            out.append(TraceRequest(req_id=rid, arrival=at, prompt=prompt,
                                    max_new=int(rng.choice(max_new)),
                                    priority=2))
            rid += 1
    return sorted(out, key=lambda r: (r.arrival, r.req_id))


def replay(engine, trace: list[TraceRequest], max_steps: int = 256) -> dict:
    """Drive a `serving.engine.Engine` through a trace: each tick submits
    the arrivals due by that tick, then runs one engine step. Returns
    {req_id: output tokens} once every request finished (or `max_steps`
    ticks elapsed). Deterministic: the same (engine config, trace) pair
    produces the same outputs — the seeded-replay e2e contract."""
    from repro.serving.engine import Request

    i, t = 0, 0
    while t < max_steps:
        while i < len(trace) and trace[i].arrival <= t:
            r = trace[i]
            engine.submit(Request(req_id=r.req_id, prompt=r.prompt,
                                  max_new=r.max_new, priority=r.priority,
                                  deadline=r.deadline))
            i += 1
        engine.step()
        t += 1
        if i >= len(trace) and all(r.done for r in engine.requests.values()):
            break
    return {r.req_id: list(r.out) for r in engine.requests.values()}
