"""Request scheduler on the Store API: an `obs:pq` priority-queue store +
the §III ring queue as the arrival buffer.

Pending requests enter the LCRQ-style ring (arrival order = FIFO ticket);
the priority index is the `pq` Store backend — a deterministic 1-2-3-4
skiplist keyed by (priority << 32 | ticket) with POPMIN extraction — driven
through `make_store_step` on a 1-shard local mesh
(`store.engine.local_store_engine`), so submission is an OP_INSERT plan,
admission is a bulk-pop-k plan of OP_POPMIN lanes, and the whole scheduler
hot path is the SAME jit-traced, shardable store step the kvstore workload
uses (exec-mode parity and the pops/pop_empty metrics plane come for
free). No direct skiplist calls remain here — the Store contract is the
only dependency.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp

from repro.core.bits import make_priority_key
from repro.core.ringqueue import RingQueue, pop_batch, push_batch, queue_init
from repro.store import engine as engine_mod
from repro.store import exec as exec_
from repro.store.api import OP_INSERT, OP_NONE, OP_POPMIN

BACKEND = "obs:pq"


class Scheduler(NamedTuple):
    arrivals: RingQueue          # §III queue of packed (priority, req_id)
    store: Any                   # sharded `obs:pq` store state (1-shard)
    next_ticket: jnp.ndarray     # uint32 monotone


def _engine(lanes: int) -> engine_mod.StoreEngine:
    # mode resolved at call time and baked into the cached engine's traced
    # step, so `with exec.exec_mode("interpret"):` replays the scheduler
    # through the interpreter without retracing the default-mode engine
    return engine_mod.local_store_engine(BACKEND, lanes, exec_.get_mode())


def scheduler_init(max_pending: int, queue_blocks: int = 16,
                   block_size: int = 64) -> Scheduler:
    return Scheduler(
        arrivals=queue_init(queue_blocks, block_size, jnp.uint64),
        store=engine_mod.sharded_init(BACKEND, 1, max_pending),
        next_ticket=jnp.uint32(0),
    )


def submit(s: Scheduler, priorities: jnp.ndarray, req_ids: jnp.ndarray,
           mask: jnp.ndarray):
    """Enqueue arrivals (producer side — any shard can push): one ring push
    + one OP_INSERT plan against the pq store (key = priority/ticket word,
    value = req_id). Returns (s', ok)."""
    tickets = s.next_ticket + jnp.cumsum(mask.astype(jnp.uint32)) - 1
    keys = make_priority_key(priorities.astype(jnp.uint32), tickets)
    q, ok = push_batch(s.arrivals, keys, mask)
    ops = jnp.where(mask & ok, OP_INSERT, OP_NONE).astype(jnp.int32)
    store, _, ins, _ = _engine(keys.shape[0]).step(
        s.store, ops, keys, req_ids.astype(jnp.uint64))
    nt = s.next_ticket + jnp.sum(mask, dtype=jnp.uint32)
    return Scheduler(arrivals=q, store=store, next_ticket=nt), ins


def pop_min(s: Scheduler, k: int):
    """Admit the k highest-priority (lowest-key) requests: ONE bulk-pop-k
    plan of k OP_POPMIN lanes (the j-th lane extracts the j-th smallest
    pending key; result vals = the popped req_id). Returns
    (s', req_ids[k], valid[k])."""
    ops = jnp.full((k,), OP_POPMIN, jnp.int32)
    zeros = jnp.zeros((k,), jnp.uint64)    # keys = shard hint; 1 shard here
    store, vals, popped, _ = _engine(k).step(s.store, ops, zeros, zeros)
    # drain matching arrivals (keeps queue and index in sync)
    q, _, _ = pop_batch(s.arrivals, k, popped)
    return Scheduler(arrivals=q, store=store, next_ticket=s.next_ticket), \
        vals.astype(jnp.int32), popped


def pending(s: Scheduler) -> jnp.ndarray:
    return jnp.asarray(engine_mod.sharded_stats(BACKEND, s.store)["size"][0])


def metrics(s: Scheduler) -> dict:
    """The scheduler store's metrics plane (shard 0 of the `obs:pq`
    counters — pops, pop_empty, inserts_new, ... over
    `obs.METRICS_SCHEMA`)."""
    per = engine_mod.sharded_metrics(BACKEND, s.store)
    return {k: v[0] for k, v in per.items()}
