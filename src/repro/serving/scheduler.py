"""Request scheduler on the Store API: an `obs:pq` priority-queue store +
the §III ring queue as the arrival buffer.

Pending requests enter the LCRQ-style ring (arrival order = FIFO ticket);
the priority index is the `pq` Store backend — a deterministic 1-2-3-4
skiplist keyed by (priority << 32 | ticket) with POPMIN extraction — driven
through `make_store_step` on a 1-shard local mesh
(`store.engine.local_store_engine`), so submission is an OP_INSERT plan,
admission is a bulk-pop-k plan of OP_POPMIN lanes, and the whole scheduler
hot path is the SAME jit-traced, shardable store step the kvstore workload
uses (exec-mode parity and the pops/pop_empty metrics plane come for
free). No direct skiplist calls remain here — the Store contract is the
only dependency.

Fault tolerance (docs/resilience.md): `scheduler_init(resilient=True)`
attaches a `SchedResilience` record — a write-ahead `resilience.Journal` of
every store plan plus a snapshot of the empty store — so a dropped
scheduler store (injected by the serving engine's fault plan, detected by
the `state_alive` probe before the next plan touches it) is rebuilt to the
exact pre-fault state by `recover()`. Cancellation rides the Store API
too: `cancel_class` drops an entire priority band's pending entries with
ONE `OP_RANGE_DELETE` lane over the band's contiguous key range
[priority << 32, (priority+1) << 32) — the load-shedding primitive the
serving engine uses under overload. The arrival ring is deliberately NOT
drained on cancellation (it is FIFO; the store is the authoritative
pending set), so `ring_depth` can overcount after a shed — documented in
docs/serving.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import numpy as np
import jax.numpy as jnp

from repro.core.bits import make_priority_key
from repro.core.ringqueue import RingQueue, pop_batch, push_batch, queue_init
from repro.store import engine as engine_mod
from repro.store import exec as exec_
from repro.store import obs
from repro.store import resilience as res_mod
from repro.store.api import OP_INSERT, OP_NONE, OP_POPMIN, OP_RANGE_DELETE

BACKEND = "obs:pq"

# lane width of a cancel_class plan (one active RANGE_DELETE lane, padded
# so the cached local engine for this width is shared across calls)
CANCEL_LANES = 4


@dataclasses.dataclass
class SchedResilience:
    """Host-side mutable resilience record riding inside the Scheduler
    NamedTuple (never traced): write-ahead journal of every store plan, the
    snapshot it replays from, the host-side resilience tally
    (`obs.RESILIENCE_SCHEMA`), and the plan seq counter."""
    journal: res_mod.Journal
    snapshot: res_mod.Snapshot
    tally: dict
    seq: int = 0


class Scheduler(NamedTuple):
    arrivals: RingQueue          # §III queue of packed (priority, req_id)
    store: Any                   # sharded `obs:pq` store state (1-shard)
    next_ticket: jnp.ndarray     # uint32 monotone
    res: Optional[SchedResilience] = None   # journaled mode (host-side)


def _engine(lanes: int) -> engine_mod.StoreEngine:
    # mode resolved at call time and baked into the cached engine's traced
    # step, so `with exec.exec_mode("interpret"):` replays the scheduler
    # through the interpreter without retracing the default-mode engine
    return engine_mod.local_store_engine(BACKEND, lanes, exec_.get_mode())


def scheduler_init(max_pending: int, queue_blocks: int = 16,
                   block_size: int = 64,
                   resilient: bool = False) -> Scheduler:
    store = engine_mod.sharded_init(BACKEND, 1, max_pending)
    res = None
    if resilient:
        res = SchedResilience(journal=res_mod.Journal(base_seq=0),
                              snapshot=res_mod.take_snapshot(store, 0),
                              tally=obs.resilience_zero())
    return Scheduler(
        arrivals=queue_init(queue_blocks, block_size, jnp.uint64),
        store=store,
        next_ticket=jnp.uint32(0),
        res=res,
    )


def health(s: Scheduler) -> bool:
    """Liveness probe of the (1-shard) scheduler store state."""
    return bool(res_mod.state_alive(s.store, 1)[0])


def recover(s: Scheduler):
    """Rebuild the scheduler store from snapshot + journal: replay every
    journaled plan through the SAME cached local engine steps the live
    calls used (entry lane width selects the engine). Returns the rebuilt
    store state; the ring and ticket counter are untouched — the fault
    model targets the store. Bit-identical to the pre-fault store by the
    journal contract; asserted by tests/test_serving.py."""
    r = s.res
    if r is None:
        raise ValueError("scheduler_init(resilient=True) required to recover")
    state = res_mod.snapshot_state(r.snapshot)
    replayed = 0
    with obs.span("recover", mode="scheduler", replay=len(r.journal)):
        for e in r.journal.entries:
            state, _, _, _ = _engine(e.ops.shape[0]).step(
                state, jnp.asarray(e.ops), jnp.asarray(e.keys),
                jnp.asarray(e.vals))
            replayed += e.n_ops
    r.tally["recoveries"] += 1
    r.tally["replayed_ops"] += replayed
    return state


def inject_fault(s: Scheduler) -> Scheduler:
    """Drop the scheduler store (zero its 1-shard state slice) — the
    serving engine's chaos hook. Counted in `faults_injected`."""
    if s.res is not None:
        s.res.tally["faults_injected"] += 1
    return s._replace(store=res_mod.inject_shard_drop(s.store, 0))


def _store_step(s: Scheduler, ops, keys, vals):
    """Every scheduler plan funnels through here: in journaled mode, check
    health (recovering a dropped store BEFORE the plan touches it), then
    write-ahead journal the plan, then step the cached local engine."""
    store = s.store
    if s.res is not None:
        if not health(s):
            store = recover(s)
        s.res.journal.append(s.res.seq, ops, keys, vals)
        s.res.seq += 1
    return _engine(ops.shape[0]).step(store, ops, keys, vals)


def submit(s: Scheduler, priorities: jnp.ndarray, req_ids: jnp.ndarray,
           mask: jnp.ndarray):
    """Enqueue arrivals (producer side — any shard can push): one ring push
    + one OP_INSERT plan against the pq store (key = priority/ticket word,
    value = req_id). Returns (s', ok)."""
    tickets = s.next_ticket + jnp.cumsum(mask.astype(jnp.uint32)) - 1
    keys = make_priority_key(priorities.astype(jnp.uint32), tickets)
    q, ok = push_batch(s.arrivals, keys, mask)
    ops = jnp.where(mask & ok, OP_INSERT, OP_NONE).astype(jnp.int32)
    store, _, ins, _ = _store_step(s, ops, keys,
                                   req_ids.astype(jnp.uint64))
    nt = s.next_ticket + jnp.sum(mask, dtype=jnp.uint32)
    return Scheduler(arrivals=q, store=store, next_ticket=nt, res=s.res), ins


def pop_min(s: Scheduler, k: int):
    """Admit the k highest-priority (lowest-key) requests: ONE bulk-pop-k
    plan of k OP_POPMIN lanes (the j-th lane extracts the j-th smallest
    pending key; result vals = the popped req_id). Returns
    (s', req_ids[k], valid[k])."""
    ops = jnp.full((k,), OP_POPMIN, jnp.int32)
    zeros = jnp.zeros((k,), jnp.uint64)    # keys = shard hint; 1 shard here
    store, vals, popped, _ = _store_step(s, ops, zeros, zeros)
    # drain matching arrivals (keeps queue and index in sync)
    q, _, _ = pop_batch(s.arrivals, k, popped)
    return Scheduler(arrivals=q, store=store, next_ticket=s.next_ticket,
                     res=s.res), vals.astype(jnp.int32), popped


def cancel_class(s: Scheduler, priority: int):
    """Cancel EVERY pending request of one priority band in one plan: a
    single OP_RANGE_DELETE lane over the band's contiguous key range
    [priority << 32, (priority+1) << 32) — priority keys are
    (priority, ticket) words, so a band is exactly one key interval. The
    load-shedding / deadline-cancellation primitive (the serving engine
    sheds the LOWEST band first under overload). Returns (s', cancelled
    count). The arrival ring is not drained (see module docstring)."""
    ops = jnp.asarray([OP_RANGE_DELETE] + [OP_NONE] * (CANCEL_LANES - 1),
                      jnp.int32)
    lo = make_priority_key(jnp.uint32(priority), jnp.uint32(0))
    hi = make_priority_key(jnp.uint32(priority + 1), jnp.uint32(0))
    keys = jnp.where(jnp.arange(CANCEL_LANES) == 0, lo, 0).astype(jnp.uint64)
    vals = jnp.where(jnp.arange(CANCEL_LANES) == 0, hi, 0).astype(jnp.uint64)
    store, out, ok, _ = _store_step(s, ops, keys, vals)
    cancelled = int(np.asarray(out)[0]) if bool(np.asarray(ok)[0]) else 0
    return Scheduler(arrivals=s.arrivals, store=store,
                     next_ticket=s.next_ticket, res=s.res), cancelled


def pending(s: Scheduler) -> jnp.ndarray:
    return jnp.asarray(engine_mod.sharded_stats(BACKEND, s.store)["size"][0])


def metrics(s: Scheduler) -> dict:
    """The scheduler store's metrics plane (shard 0 of the `obs:pq`
    counters — pops, pop_empty, inserts_new, ... over
    `obs.METRICS_SCHEMA`). The resilience counters in the schema are zeros
    here; `serving.engine.Engine.resilience_metrics` merges the host-side
    tallies in."""
    per = engine_mod.sharded_metrics(BACKEND, s.store)
    return {k: v[0] for k, v in per.items()}
