"""Request scheduler: a deterministic-skiplist priority index + the §III
ring queue as the arrival buffer.

Pending requests enter the LCRQ-style ring (arrival order = FIFO ticket);
the scheduler maintains a deterministic 1-2-3-4 skiplist keyed by
(priority << 32 | ticket) — guaranteed O(log n) admit/pop-min, and the
terminal level's contiguity gives "pop k smallest" as one range read (the
paper's range-search argument vs BSTs, §II). All state is a pytree: the
whole scheduler jit-compiles and checkpoints with the engine.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import det_skiplist as dsl
from repro.core.bits import KEY_INF, make_priority_key
from repro.core.ringqueue import RingQueue, pop_batch, push_batch, queue_init


class Scheduler(NamedTuple):
    arrivals: RingQueue          # §III queue of packed (priority, req_id)
    index: dsl.DetSkiplist       # §II ordered index
    next_ticket: jnp.ndarray     # uint32 monotone


def scheduler_init(max_pending: int, queue_blocks: int = 16,
                   block_size: int = 64) -> Scheduler:
    return Scheduler(
        arrivals=queue_init(queue_blocks, block_size, jnp.uint64),
        index=dsl.skiplist_init(max_pending),
        next_ticket=jnp.uint32(0),
    )


def submit(s: Scheduler, priorities: jnp.ndarray, req_ids: jnp.ndarray,
           mask: jnp.ndarray):
    """Enqueue arrivals (producer side — any shard can push)."""
    k = priorities.shape[0]
    tickets = s.next_ticket + jnp.cumsum(mask.astype(jnp.uint32)) - 1
    keys = make_priority_key(priorities.astype(jnp.uint32), tickets)
    packed = (keys << jnp.uint64(0)) | 0  # key doubles as payload
    vals = req_ids.astype(jnp.uint64)
    # pack (key, req_id) into the queue as two pushes? -> single u64:
    # priority key goes in the queue; req_id rides in the skiplist value.
    q, ok = push_batch(s.arrivals, keys, mask)
    # stash req ids keyed by ticket in the index immediately (queue carries
    # ordering; index carries the sorted view)
    idx, ins, _ = dsl.insert_batch(s.index, keys, vals, mask & ok)
    nt = s.next_ticket + jnp.sum(mask, dtype=jnp.uint32)
    return Scheduler(arrivals=q, index=idx, next_ticket=nt), ok & ins


def pop_min(s: Scheduler, k: int):
    """Admit the k highest-priority (lowest-key) requests: one terminal-level
    range read + batched delete. Returns (s', req_ids[k], valid[k])."""
    lo = jnp.zeros((1,), jnp.uint64)
    hi = jnp.full((1,), KEY_INF)
    _, keys, vals, valid = dsl.range_query(s.index, lo, hi, k)
    keys, vals, valid = keys[0], vals[0], valid[0]
    idx, _ = dsl.delete_batch(s.index, jnp.where(valid, keys, KEY_INF), valid)
    # drain matching arrivals (keeps queue and index in sync)
    q, _, _ = pop_batch(s.arrivals, k, valid)
    return Scheduler(arrivals=q, index=idx, next_ticket=s.next_ticket), \
        vals.astype(jnp.int32), valid


def pending(s: Scheduler) -> jnp.ndarray:
    return s.index.size()
