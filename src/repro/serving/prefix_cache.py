"""Prefix cache on the Store API: a `tiered3/lru` store (§IX hot hash ->
warm skiplist -> spill runs) mapping hash(token-block) -> KV page handle.

The tier stack fits a serving cache exactly: the hottest page hashes live
in the fixed-hash tier (one-probe lookups), the LRU-by-batch policy demotes
cooling prefixes to the warm skiplist, and overflow spills to the cold
runs instead of evicting — admission latency never spikes and the cache
scales to millions of prefix pages. Lookups and publishes are OP_FIND /
OP_INSERT plans through `make_store_step` on a 1-shard local mesh
(`store.engine.local_store_engine`), so the cache shares the kvstore
path's exec-mode parity and `obs` metrics plane (hot/warm/spill hits per
tier); no direct hash-table calls remain here.

Values are (gen << 32 | page_id) pool handles; a hit is only usable if the
generation still matches (ABA check) — a recycled page invalidates its
cache entries for free, no eviction sweep needed (the lazy deletion idea,
transplanted).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp

from repro.core.bits import hash64
from repro.core.blockpool import BlockPool, handle_valid
from repro.store import engine as engine_mod
from repro.store import exec as exec_
from repro.store.api import OP_FIND, OP_INSERT, OP_NONE

BACKEND = "obs:tiered3/lru"


class PrefixCache(NamedTuple):
    store: Any               # sharded `obs:tiered3/lru` state (1-shard)
    hits: jnp.ndarray
    misses: jnp.ndarray


def _engine(lanes: int) -> engine_mod.StoreEngine:
    return engine_mod.local_store_engine(BACKEND, lanes, exec_.get_mode())


def prefix_cache_init(capacity: int = 1024, **kw) -> PrefixCache:
    return PrefixCache(
        store=engine_mod.sharded_init(BACKEND, 1, capacity, **kw),
        hits=jnp.int64(0), misses=jnp.int64(0))


def block_key(tokens_block: jnp.ndarray, prev_key: jnp.ndarray) -> jnp.ndarray:
    """Rolling hash of a token block chained on the previous block's key
    (prefix identity = chain of block hashes)."""
    h = prev_key
    for i in range(tokens_block.shape[-1]):
        h = hash64(h ^ tokens_block[..., i].astype(jnp.uint64))
    return h


def lookup(pc: PrefixCache, pool: BlockPool, keys: jnp.ndarray):
    """Returns (pc', page_ids [-1 miss], hit_mask). One OP_FIND plan; stale
    (recycled-page) entries are misses via the generation check."""
    k = keys.shape[0]
    ops = jnp.full((k,), OP_FIND, jnp.int32)
    store, handles, found, _ = _engine(k).step(pc.store, ops, keys,
                                               jnp.zeros((k,), jnp.uint64))
    fresh = found & handle_valid(pool, handles)
    ids = jnp.where(fresh, (handles & jnp.uint64(0xFFFFFFFF)).astype(jnp.int32),
                    -1)
    return pc._replace(store=store,
                       hits=pc.hits + jnp.sum(fresh, dtype=jnp.int64),
                       misses=pc.misses + jnp.sum(k - jnp.sum(fresh),
                                                  dtype=jnp.int64)), ids, fresh


def insert(pc: PrefixCache, keys: jnp.ndarray, handles: jnp.ndarray,
           mask: jnp.ndarray):
    """Publish page handles under their prefix hashes (one OP_INSERT plan;
    insert-if-absent, like the split-order table it replaced)."""
    ops = jnp.where(mask, OP_INSERT, OP_NONE).astype(jnp.int32)
    store, _, _, _ = _engine(keys.shape[0]).step(pc.store, ops, keys, handles)
    return pc._replace(store=store)


def metrics(pc: PrefixCache) -> dict:
    """The cache store's metrics plane (shard 0 of the `obs:tiered3/lru`
    counters — find_hits/find_misses, hot/warm/spill hits, evictions,
    ... over `obs.METRICS_SCHEMA`)."""
    per = engine_mod.sharded_metrics(BACKEND, pc.store)
    return {k: v[0] for k, v in per.items()}
