"""Prefix cache: two-level split-order hash table (§VII's winner) mapping
hash(token-block) -> KV page handle.

Split-order growth fits a serving cache exactly: the table doubles its slot
count as the cache fills with ZERO rehash movement, so admission latency
never spikes. Values are (gen << 32 | page_id) pool handles; a hit is only
usable if the generation still matches (ABA check) — a recycled page
invalidates its cache entries for free, no eviction sweep needed (the lazy
deletion idea, transplanted).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bits import hash64
from repro.core.blockpool import BlockPool, handle_valid
from repro.core.splitorder import (TwoLevelSplitOrder, twolevel_splitorder_find,
                                   twolevel_splitorder_init,
                                   twolevel_splitorder_insert)


class PrefixCache(NamedTuple):
    table: TwoLevelSplitOrder
    hits: jnp.ndarray
    misses: jnp.ndarray


def prefix_cache_init(num_tables: int = 16, capacity: int = 1024,
                      seed_slots: int = 8) -> PrefixCache:
    return PrefixCache(
        table=twolevel_splitorder_init(num_tables, capacity, seed_slots),
        hits=jnp.int64(0), misses=jnp.int64(0))


def block_key(tokens_block: jnp.ndarray, prev_key: jnp.ndarray) -> jnp.ndarray:
    """Rolling hash of a token block chained on the previous block's key
    (prefix identity = chain of block hashes)."""
    h = prev_key
    for i in range(tokens_block.shape[-1]):
        h = hash64(h ^ tokens_block[..., i].astype(jnp.uint64))
    return h


def lookup(pc: PrefixCache, pool: BlockPool, keys: jnp.ndarray):
    """Returns (pc', page_ids [-1 miss], hit_mask). Stale (recycled-page)
    entries are misses via the generation check."""
    found, handles = twolevel_splitorder_find(pc.table, keys)
    fresh = found & handle_valid(pool, handles)
    ids = jnp.where(fresh, (handles & jnp.uint64(0xFFFFFFFF)).astype(jnp.int32), -1)
    return pc._replace(hits=pc.hits + jnp.sum(fresh, dtype=jnp.int64),
                       misses=pc.misses + jnp.sum(found.shape[0] - jnp.sum(fresh),
                                                  dtype=jnp.int64)), ids, fresh


def insert(pc: PrefixCache, keys: jnp.ndarray, handles: jnp.ndarray,
           mask: jnp.ndarray):
    table, _, _ = twolevel_splitorder_insert(pc.table, keys, handles, mask)
    return pc._replace(table=table)
