"""Continuous-batching serving engine — the paper's structures as substrate.

Host loop (like every production engine) around jitted device steps:

  arrivals -> §III ring queue -> §II skiplist priority index -> admit into
  free slots -> prefill writes §V pool pages (+ §VII prefix-cache sharing)
  -> decode batch via paged attention -> finished requests recycle pages.

Admission is capacity-aware: a request only admits if the pool can cover its
pages (allocation failure = retry with deterministic capped backoff: the
request parks for min(backoff_base * 2^(attempt-1), backoff_cap) engine
TICKS — never wall clock — then resubmits, counted in `retries`).

Graceful degradation (docs/resilience.md):

* **deadlines** — a request with `deadline >= 0` must be admitted within
  that many ticks of its first submit; expiry is checked lazily when the
  scheduler pops it (no extra scans) and an expired request is dropped
  with empty output (`deadline_expired`).
* **load shedding** — with `shed_threshold` set, a pending backlog above
  it sheds the LOWEST priority band (largest priority value) first via one
  `scheduler.cancel_class` RANGE_DELETE plan (`shed` counts dropped
  requests). Priority 0 work is shed last, matching the priority-inversion
  contract of the traffic generator.
* **faults** — a `fault_plan` (resilience.FaultPlan) injects scheduler
  store drops at step boundaries; the journaled scheduler detects and
  rebuilds before the next plan, so outputs stay bit-identical to the
  fault-free replay (asserted in tests/test_serving.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.serving import kvcache as KV
from repro.serving import prefix_cache as PC
from repro.serving import scheduler as SCH
from repro.serving.paged_decode import paged_decode_step
from repro.store import obs


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray
    max_new: int
    priority: int = 0
    deadline: int = -1      # max ticks from first submit to admission (<0: none)
    out: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False
    shed: bool = False      # dropped by overload shedding / deadline expiry
    attempts: int = 0       # admission attempts (drives the capped backoff)
    submit_step: int = -1   # engine step of first submit (admit latency t0)
    admit_step: int = -1    # engine step the request won a slot


class Engine:
    def __init__(self, cfg, params, *, max_reqs: int = 8, num_pages: int = 64,
                 page_size: int = 16, max_pages_per_req: int = 16,
                 use_kernel: bool = False, use_prefix_cache: bool = True,
                 shed_threshold: int | None = None, shed_band: int = 2,
                 backoff_base: int = 1, backoff_cap: int = 8,
                 fault_plan=None, resilient: bool = False):
        assert cfg.attn_type == "gqa" and cfg.block_pattern == "transformer"
        self.cfg = cfg
        self.params = params
        self.kv = KV.paged_kv_init(cfg, num_pages=num_pages, page_size=page_size,
                                   max_reqs=max_reqs,
                                   max_pages_per_req=max_pages_per_req)
        # a fault plan needs the journaled scheduler to recover from
        self.sched = SCH.scheduler_init(
            max_pending=1024, resilient=resilient or fault_plan is not None)
        self.pc = PC.prefix_cache_init() if use_prefix_cache else None
        self.shed_threshold = shed_threshold
        self.shed_band = shed_band
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.fault_plan = fault_plan
        self.res = obs.resilience_zero()   # engine-level host tally
        self._parked: list[tuple[int, int]] = []   # (retry_at_step, req_id)
        self.max_reqs = max_reqs
        self.requests: dict[int, Request] = {}
        self.slot_to_req = [-1] * max_reqs
        self._decode = jax.jit(
            lambda p, t, s, kv, m: paged_decode_step(p, cfg, t, s, kv, m,
                                                     use_kernel=use_kernel))
        self._prefill = {}
        self.steps = 0
        self.prefix_hits = 0       # full pages served from the prefix cache
        self.prefix_lookups = 0    # full pages probed against it
        self.decode_tokens = 0     # tokens emitted by decode steps
        self._batch_fill_sum = 0.0  # sum over steps of active/max_reqs

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.requests[req.req_id] = req
        if req.submit_step < 0:   # resubmits keep the original arrival step
            req.submit_step = self.steps
        self.sched, ok = SCH.submit(
            self.sched, jnp.asarray([req.priority], jnp.uint32),
            jnp.asarray([req.req_id], jnp.int32), jnp.ones((1,), bool))
        assert bool(ok[0])

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_to_req) if r < 0]

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill:
            cfg = self.cfg

            def fn(params, tokens):
                logits, caches, _ = M.prefill(params, cfg, tokens, cache_len=plen)
                kv_per_layer = caches[0]          # single-kind transformer
                return logits[:, -1], kv_per_layer["k"], kv_per_layer["v"]

            self._prefill[plen] = jax.jit(fn)
        return self._prefill[plen]

    def _prefill_past_fn(self, s_past: int, s_suf: int):
        key = ("past", s_past, s_suf)
        if key not in self._prefill:
            cfg = self.cfg

            def fn(params, tokens, past_k, past_v):
                logits, caches, _ = M.prefill_with_past(
                    params, cfg, tokens, past_k, past_v,
                    cache_len=s_past + s_suf)
                kvl = caches[0]
                return logits[:, -1], kvl["k"], kvl["v"]

            self._prefill[key] = jax.jit(fn)
        return self._prefill[key]

    def _page_keys(self, prompt):
        """Chained hashes of the prompt's FULL pages (prefix identity)."""
        page = self.kv.page_size
        n_full = len(prompt) // page
        keys = []
        prev = jnp.zeros((1,), jnp.uint64)
        for j in range(n_full):
            blk = jnp.asarray(prompt[j * page:(j + 1) * page], jnp.int32)[None]
            prev = PC.block_key(blk, prev)
            keys.append(int(prev[0]))
        return keys

    def _park(self, req: Request):
        """Deterministic capped exponential backoff, in engine ticks: the
        n-th failed admission parks the request for
        min(backoff_base * 2^(n-1), backoff_cap) steps before resubmission
        (attempt 1 with the defaults = next step, the original immediate
        retry). Resubmissions are counted in `retries`."""
        req.attempts += 1
        delay = min(self.backoff_base * (2 ** (req.attempts - 1)),
                    self.backoff_cap)
        self._parked.append((self.steps + delay, req.req_id))

    def _release_parked(self):
        due = [rid for t, rid in self._parked if t <= self.steps]
        self._parked = [(t, rid) for t, rid in self._parked
                        if t > self.steps]
        for rid in due:
            if self.requests[rid].done:           # shed/expired while parked
                continue
            self.res["retries"] += 1
            self.submit(self.requests[rid])

    def _shed_overload(self):
        """Above `shed_threshold` pending, drop the lowest priority band in
        ONE RANGE_DELETE plan (`scheduler.cancel_class`) and mark the shed
        requests done with empty output. Parked requests are not in the pq
        store, so they are shed from the park list directly."""
        if self.shed_threshold is None:
            return
        if int(SCH.pending(self.sched)) <= self.shed_threshold:
            return
        self.sched, n = SCH.cancel_class(self.sched, self.shed_band)
        parked_ids = {rid for _, rid in self._parked}
        for req in self.requests.values():
            if (not req.done and req.slot < 0
                    and req.priority == self.shed_band
                    and req.submit_step >= 0
                    and req.req_id not in parked_ids):
                req.done = True
                req.shed = True
        self.res["shed"] += n

    def _admit(self):
        free = self._free_slots()
        if not free:
            return
        k = min(len(free), 4)
        self.sched, rids, valid = SCH.pop_min(self.sched, k)
        rids = np.asarray(rids)
        valid = np.asarray(valid)
        for j in range(k):
            if not valid[j]:
                continue
            req = self.requests[int(rids[j])]
            if req.done:                          # shed while queued
                continue
            # lazy deadline expiry: checked when the scheduler pops it
            if (req.deadline >= 0
                    and self.steps > req.submit_step + req.deadline):
                req.done = True
                req.shed = True
                self.res["deadline_expired"] += 1
                continue
            slot = free.pop(0) if free else -1
            if slot < 0:
                self._park(req)                   # back off, then requeue
                continue
            plen = len(req.prompt)
            page = self.kv.page_size
            mp = self.kv.max_pages_per_req

            # --- prefix-cache probe: leading full pages already resident? ---
            pkeys = self._page_keys(req.prompt) if self.pc is not None else []
            n_hit = 0
            hit_ids = []
            if pkeys:
                self.prefix_lookups += len(pkeys)
                self.pc, pids, fresh = PC.lookup(
                    self.pc, self.kv.pool, jnp.asarray(pkeys, jnp.uint64))
                for pid, f in zip(np.asarray(pids), np.asarray(fresh)):
                    if not f:
                        break
                    n_hit += 1
                    hit_ids.append(int(pid))
                # always keep >= 1 suffix token to prefill (the model needs
                # a query to produce the next-token logits)
                while n_hit and n_hit * page >= plen:
                    n_hit -= 1
                    hit_ids.pop()

            shared = np.full((1, mp), -1, np.int32)
            shared[0, :n_hit] = hit_ids
            kv2, ok = KV.admit_requests(
                self.kv, jnp.asarray([slot], jnp.int32),
                jnp.asarray([plen], jnp.int32), jnp.ones((1,), bool),
                shared_pages=jnp.asarray(shared),
                n_shared=jnp.asarray([n_hit], jnp.int32))
            if not bool(ok[0]):                   # pool exhausted: back off
                self._park(req)
                continue
            self.kv = kv2
            if n_hit:
                # gather past KV from the shared pages; prefill the suffix
                ids = jnp.asarray(hit_ids, jnp.int32)
                past_k = self.kv.k[:, ids].reshape(
                    self.kv.k.shape[0], 1, n_hit * page, *self.kv.k.shape[3:])
                past_v = self.kv.v[:, ids].reshape(
                    self.kv.v.shape[0], 1, n_hit * page, *self.kv.v.shape[3:])
                suf = jnp.asarray(req.prompt[n_hit * page:], jnp.int32)[None]
                # model expects past as [ng, B, S, Hkv, Dh]
                pk = past_k.transpose(0, 1, 2, 3, 4)
                with obs.span("prefill", req_id=req.req_id, plen=plen,
                              shared_pages=n_hit):
                    logits, klay, vlay = self._prefill_past_fn(
                        n_hit * page, plen - n_hit * page)(
                        self.params, suf, past_k, past_v)
                # caches cover past+suffix; write only the suffix pages
                kl = klay[:, 0, n_hit * page:]
                vl = vlay[:, 0, n_hit * page:]
                self.kv = KV.write_prefill(self.kv, slot, kl, vl,
                                           start_page=n_hit)
                self.prefix_hits += n_hit
            else:
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                with obs.span("prefill", req_id=req.req_id, plen=plen,
                              shared_pages=0):
                    logits, klay, vlay = self._prefill_fn(plen)(self.params,
                                                                toks)
                # klay: [n_groups, B, S, Hkv, Dh] -> [L, S, Hkv, Dh]
                kl = klay[:, 0]
                vl = vlay[:, 0]
                self.kv = KV.write_prefill(self.kv, slot, kl, vl)
            # publish this prompt's full pages for future prefix reuse
            if self.pc is not None and pkeys:
                bt = np.asarray(self.kv.block_tables[slot])
                n_pub = min(len(pkeys), mp)
                ids = bt[:n_pub]
                gens = np.asarray(self.kv.pool.gen)[np.maximum(ids, 0)]
                handles = (gens.astype(np.uint64) << np.uint64(32)) \
                    | ids.astype(np.uint64)
                self.pc = PC.insert(self.pc, jnp.asarray(pkeys[:n_pub],
                                                         jnp.uint64),
                                    jnp.asarray(handles),
                                    jnp.asarray(ids >= 0))
            nxt = int(jnp.argmax(logits[0]))
            req.out.append(nxt)
            req.slot = slot
            req.admit_step = self.steps
            self.slot_to_req[slot] = req.req_id

    def _active_slots(self):
        return [i for i, r in enumerate(self.slot_to_req) if r >= 0]

    def step(self):
        """One engine iteration: inject any scheduled fault, release parked
        retries, shed under overload, admit, decode one token for every
        active request, retire finished ones."""
        if self.fault_plan is not None:
            for f in self.fault_plan.at(self.steps):
                if f.kind == "shard_drop":
                    self.sched = SCH.inject_fault(self.sched)
        self._release_parked()
        self._shed_overload()
        with obs.span("admit"):
            self._admit()
        active = self._active_slots()
        if not active:
            return 0
        slots = jnp.asarray(
            active + [0] * (self.max_reqs - len(active)), jnp.int32)
        mask = jnp.asarray([True] * len(active)
                           + [False] * (self.max_reqs - len(active)))
        with obs.span("decode", batch=len(active)):
            self.kv, ok = KV.grow_for_decode(self.kv, slots, mask)
            toks = [self.requests[self.slot_to_req[s]].out[-1]
                    for s in active]
            toks = jnp.asarray(toks + [0] * (self.max_reqs - len(active)),
                               jnp.int32)[:, None]
            logits, self.kv = self._decode(self.params, toks, slots, self.kv,
                                           mask)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self._batch_fill_sum += len(active) / self.max_reqs
        self.decode_tokens += len(active)
        done_slots = []
        for i, s in enumerate(active):
            req = self.requests[self.slot_to_req[s]]
            req.out.append(int(nxt[i]))
            if len(req.out) >= req.max_new:
                req.done = True
                done_slots.append(s)
        if done_slots:
            ds = jnp.asarray(done_slots, jnp.int32)
            self.kv = KV.release_requests(self.kv, ds,
                                          jnp.ones((len(done_slots),), bool))
            for s in done_slots:
                self.slot_to_req[s] = -1
        self.steps += 1
        return len(active)

    def run(self, max_steps: int = 256):
        while (any(not r.done for r in self.requests.values())
               and self.steps < max_steps):
            self.step()
        return {r.req_id: r.out for r in self.requests.values()}

    def metrics(self) -> dict:
        """Host-side engine counters over the closed `obs.SERVING_SCHEMA`
        (glossary in docs/observability.md): current ring-queue depth, the
        prefix cache's page hit rate, mean scheduler batch fill, and the
        decode totals. Same schema discipline as the store metrics plane —
        unknown keys are a ValueError, so docs stay exhaustive."""
        return obs.uniform_serving_metrics(
            ring_depth=int(SCH.pending(self.sched)),
            prefix_hits=self.prefix_hits,
            prefix_lookups=self.prefix_lookups,
            prefix_hit_rate=(self.prefix_hits / self.prefix_lookups
                             if self.prefix_lookups else 0.0),
            batch_fill=(self._batch_fill_sum / self.steps
                        if self.steps else 0.0),
            decode_steps=self.steps,
            decode_tokens=self.decode_tokens)

    def resilience_metrics(self) -> dict:
        """The full `obs.METRICS_SCHEMA` view of the scheduler store with
        every host-side resilience tally folded in: the engine's own
        (deadline_expired / shed / retries) plus the journaled scheduler's
        (faults_injected / recoveries / replayed_ops), via
        `obs.merge_resilience`. Deterministic — every count is a pure
        function of (config, trace, fault seed)."""
        tally = dict(self.res)
        if self.sched.res is not None:
            for k, v in self.sched.res.tally.items():
                tally[k] += v
        m = {k: int(v) for k, v in SCH.metrics(self.sched).items()}
        return obs.merge_resilience(m, tally)
