"""Paged KV cache on the paper's §V block pool.

KV pages ARE pool blocks: allocation = free-ring pop (prefix-sum slot
assignment), request completion = push-back (recycling), generation counters
catch stale block-table references (the ABA guard). Per-layer K/V page data
lives beside the id pool; block tables map (request, page_idx) -> page id.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.blockpool import (BlockPool, blockpool_init, pool_alloc,
                                  pool_free)


class PagedKV(NamedTuple):
    pool: BlockPool
    k: jnp.ndarray            # [layers, N_pages, page, Hkv, Dh]
    v: jnp.ndarray
    block_tables: jnp.ndarray  # [max_reqs, max_pages] int32, -1 empty
    lengths: jnp.ndarray       # [max_reqs] int32 tokens written
    active: jnp.ndarray        # [max_reqs] bool
    refcount: jnp.ndarray      # [N_pages] int32 — prefix-shared pages hold >1

    @property
    def page_size(self) -> int:
        return self.k.shape[2]

    @property
    def max_pages_per_req(self) -> int:
        return self.block_tables.shape[1]


def paged_kv_init(cfg, *, num_pages: int, page_size: int, max_reqs: int,
                  max_pages_per_req: int) -> PagedKV:
    ct = jnp.dtype(cfg.compute_dtype)
    dh = cfg.resolved_head_dim
    shape = (cfg.n_layers, num_pages, page_size, cfg.n_kv_heads, dh)
    return PagedKV(
        pool=blockpool_init(num_pages),
        k=jnp.zeros(shape, ct),
        v=jnp.zeros(shape, ct),
        block_tables=jnp.full((max_reqs, max_pages_per_req), -1, jnp.int32),
        lengths=jnp.zeros((max_reqs,), jnp.int32),
        active=jnp.zeros((max_reqs,), bool),
        refcount=jnp.zeros((num_pages,), jnp.int32),
    )


def admit_requests(kv: PagedKV, slots: jnp.ndarray, prompt_lens: jnp.ndarray,
                   mask: jnp.ndarray, shared_pages: jnp.ndarray | None = None,
                   n_shared: jnp.ndarray | None = None):
    """Allocate pages for admitted prompts. slots: [K] request slots;
    prompt_lens: [K]. Returns (kv', ok[K]) — ok=False when the pool is
    exhausted (the paper's allocation-failure path; scheduler retries).

    Prefix sharing: `shared_pages` [K, mp] (-1 pad) + `n_shared` [K] give
    already-resident pages covering each prompt's leading full pages; their
    refcount bumps (+1) and only the remainder is allocated."""
    page = kv.page_size
    mp = kv.max_pages_per_req
    k_lanes = slots.shape[0]
    if shared_pages is None:
        shared_pages = jnp.full((k_lanes, mp), -1, jnp.int32)
        n_shared = jnp.zeros((k_lanes,), jnp.int32)
    total_need = jnp.where(mask, -(-prompt_lens // page), 0)  # pages per req
    need = jnp.maximum(total_need - n_shared, 0)              # new pages
    # flatten (req, page_idx) wants: new pages occupy positions n_shared..
    pos = jnp.arange(mp)[None, :]
    want_new = (pos >= n_shared[:, None]) & (pos < total_need[:, None]) \
        & mask[:, None]
    pool, ids, _handles, got = pool_alloc(kv.pool, want_new.reshape(-1))
    ids = ids.reshape(k_lanes, mp)
    got = got.reshape(k_lanes, mp)
    ok = mask & (jnp.sum(got, axis=1) == need)
    # rollback lanes that got only part of their pages
    give_back = got & ~ok[:, None]
    pool = pool_free(pool, ids.reshape(-1), give_back.reshape(-1))
    # table rows: shared prefix then new pages
    is_shared = pos < n_shared[:, None]
    table_row = jnp.where(is_shared, shared_pages,
                          jnp.where(got, ids, -1))
    table_row = jnp.where((pos < total_need[:, None]) & ok[:, None],
                          table_row, -1)
    rows = jnp.where(ok, slots, kv.block_tables.shape[0])
    bt = kv.block_tables.at[rows].set(table_row, mode="drop")
    lengths = kv.lengths.at[rows].set(jnp.where(ok, prompt_lens, 0), mode="drop")
    active = kv.active.at[rows].set(ok, mode="drop")
    # refcounts: new pages -> 1; shared pages -> +1
    new_idx = jnp.where(got & ok[:, None], ids, kv.refcount.shape[0])
    refcount = kv.refcount.at[new_idx.reshape(-1)].set(1, mode="drop")
    sh_idx = jnp.where(is_shared & ok[:, None] & (shared_pages >= 0),
                       shared_pages, kv.refcount.shape[0])
    refcount = refcount.at[sh_idx.reshape(-1)].add(1, mode="drop")
    return kv._replace(pool=pool, block_tables=bt, lengths=lengths,
                       active=active, refcount=refcount), ok


def grow_for_decode(kv: PagedKV, slots: jnp.ndarray, mask: jnp.ndarray):
    """One more token per request: allocate a fresh page at page boundaries."""
    page = kv.page_size
    cur = kv.lengths[slots]
    needs_page = mask & (cur % page == 0) & (cur // page < kv.max_pages_per_req)
    pool, ids, _h, got = pool_alloc(kv.pool, needs_page)
    ok = mask & (~needs_page | got)
    rows = jnp.where(needs_page & got, slots, kv.block_tables.shape[0])
    bt = kv.block_tables.at[rows, jnp.where(needs_page & got, cur // page, 0)
                            ].set(ids, mode="drop")
    lengths = kv.lengths.at[jnp.where(ok, slots, kv.lengths.shape[0])
                            ].add(1, mode="drop")
    refcount = kv.refcount.at[jnp.where(needs_page & got, ids,
                                        kv.refcount.shape[0])
                              ].set(1, mode="drop")
    return kv._replace(pool=pool, block_tables=bt, lengths=lengths,
                       refcount=refcount), ok


def release_requests(kv: PagedKV, slots: jnp.ndarray, mask: jnp.ndarray):
    """Finish requests: decrement page refcounts; only pages reaching zero
    return to the free ring (recycling + generation bump — a recycled page
    auto-invalidates its prefix-cache entries via the ABA check)."""
    from repro.core.bits import dup_in_run

    mp = kv.max_pages_per_req
    npg = kv.refcount.shape[0]
    rows = kv.block_tables[slots]                             # [K, mp]
    held = mask[:, None] & (rows >= 0)
    dec_idx = jnp.where(held, rows, npg)
    refcount = kv.refcount.at[dec_idx.reshape(-1)].add(-1, mode="drop")
    # free each page ONCE even if several finishing requests shared it:
    # sort the flattened page list, keep the first held occurrence
    flat = jnp.where(held, rows, npg).reshape(-1)
    heldf = held.reshape(-1)
    order = jnp.argsort(flat, stable=True)
    sf = flat[order]
    same = jnp.concatenate([jnp.zeros((1,), bool), sf[1:] == sf[:-1]])
    dup = dup_in_run(same, heldf[order])
    first = heldf[order] & ~dup & (sf < npg)
    give = first & (refcount[jnp.clip(sf, 0, npg - 1)] <= 0)
    pool = pool_free(kv.pool, sf, give)
    r = jnp.where(mask, slots, kv.block_tables.shape[0])
    bt = kv.block_tables.at[r].set(-1, mode="drop")
    lengths = kv.lengths.at[r].set(0, mode="drop")
    active = kv.active.at[r].set(False, mode="drop")
    return kv._replace(pool=pool, block_tables=bt, lengths=lengths,
                       active=active, refcount=refcount)


def write_prefill(kv: PagedKV, slot, layer_k, layer_v, start_page: int = 0):
    """Write a prefilled request's KV ([L, S, Hkv, Dh]) into its pages from
    `start_page` on (prefix-shared pages before it are read-only)."""
    page = kv.page_size
    s = layer_k.shape[1]
    npages = -(-s // page)
    pad = npages * page - s
    kpad = jnp.pad(layer_k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vpad = jnp.pad(layer_v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kpag = kpad.reshape(kv.k.shape[0], npages, page, *layer_k.shape[2:])
    vpag = vpad.reshape(kv.v.shape[0], npages, page, *layer_v.shape[2:])
    ids = jax.lax.dynamic_slice_in_dim(kv.block_tables[slot], start_page,
                                       npages)
    k = kv.k.at[:, ids].set(kpag, mode="drop")
    v = kv.v.at[:, ids].set(vpag, mode="drop")
    return kv._replace(k=k, v=v)


def write_decode_token(kv: PagedKV, slots, layer_k, layer_v, mask):
    """Append one token's K/V per request. layer_k: [L, K, Hkv, Dh];
    call AFTER grow_for_decode (lengths already include the new token)."""
    page = kv.page_size
    pos = kv.lengths[slots] - 1                   # the new token's index
    pid = kv.block_tables[slots, jnp.maximum(pos, 0) // page]
    off = jnp.maximum(pos, 0) % page
    ok = mask & (pid >= 0)
    pidx = jnp.where(ok, pid, kv.k.shape[1])
    k = kv.k.at[:, pidx, off].set(layer_k, mode="drop")
    v = kv.v.at[:, pidx, off].set(layer_v, mode="drop")
    return kv._replace(k=k, v=v)


def live_pages(kv: PagedKV) -> jnp.ndarray:
    return jnp.sum(kv.block_tables >= 0)
