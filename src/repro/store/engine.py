"""Mesh-sharded storage engine over any registered `Store` backend.

Generalizes the paper's flagship experiment (§VI: 8 skiplists, one per NUMA
node, keys partitioned by top bits, lock-free queues routing each key to the
owner node) from "skiplist only" to ANY backend or tier stack: one backend
instance per mesh shard, hierarchical all_to_all routing (coarsest axis — the
DCI hop — first), the backend's `apply` executed locally, results routed back
to the requesting shard/lane.

Selection is by config string (`get_backend`): swapping `det_skiplist` for
`twolevel_hash`, `splitorder`, or a tier stack (`hash+skiplist`,
`tiered3/lru`, ...) changes one argument, nothing else — the routing,
sharding, and result plumbing are backend-agnostic, and each shard runs its
own full tier stack (hot table, warm skiplist, spill runs, and policy
state all shard on dim 0 like any other state leaf). The registered tier
stacks probe through the FUSED `exec.tier_find` path, so each shard's
local FIND chain is one kernel dispatch per plan regardless of tier depth
(docs/tiers.md); an unfused `TieredBackend(fused=False)` instance drops in
with bit-identical results and residency (the FUSED-OK multidev check).
Because the policies are deterministic and the linearization is
order-independent for distinct keys, per-shard tier residency is EXACTLY
what a single-device instance produces for that shard's sub-stream —
asserted by `tests/multidev/store_prog.py`. `core/ordered_sharded.py`
keeps its original API as thin wrappers over this module.
"""
from __future__ import annotations

import functools
import math
from contextlib import nullcontext
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.routing import (axis_size, mesh_shard_map, route_back,
                                route_to_owners)
from repro.store import exec as exec_
from repro.store import obs
from repro.store.api import OpPlan, Store, get_backend


def resolve(backend) -> Store:
    """Accept a backend instance or a registry name."""
    return get_backend(backend) if isinstance(backend, str) else backend


def sharded_init(backend, n_shards: int, capacity_per_shard: int, **kw):
    """Backend state pytree with a leading shard dim (to be device_put with
    `store_sharding`). Python-int leaves (static knobs) are promoted to
    arrays so every leaf broadcasts."""
    be = resolve(backend)
    one = be.init(capacity_per_shard, **kw)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(jnp.asarray(x)[None],
                                   (n_shards,) + jnp.asarray(x).shape), one)


def store_sharding(mesh: Mesh, axis_names: Sequence[str]) -> NamedSharding:
    """State sharded on dim 0 over all routing axes; op streams likewise."""
    return NamedSharding(mesh, P(tuple(axis_names)))


def make_store_step(mesh: Mesh, axis_names: Sequence[str], lanes: int,
                    backend="det_skiplist", pool_factor: int = 2,
                    exec_mode: str | None = None):
    """Build the jit-able batched-op step for `backend`.

    Global inputs: ops[int32 S*lanes], keys[u64 S*lanes], vals[u64 S*lanes]
    sharded over the routing axes (S = total shards; each shard contributes
    `lanes` requests — "threads fill queues, then operate", §IX).
    Returns (state', results[u64 S*lanes], ok[bool S*lanes], dropped).

    `exec_mode` selects the probe execution layer (`repro.store.exec`:
    jnp | interpret | pallas; None = the module default) for the local
    `apply` only — routing, sharding, and result plumbing are identical in
    every mode, and so are the results (bit-identical by contract).
    """
    be = resolve(backend)
    mode = exec_.get_mode() if exec_mode is None else exec_mode
    axis_sizes = [mesh.shape[a] for a in axis_names]
    pool = lanes * pool_factor

    # per-shard routing counters land in the engine's OWN frame (opened
    # only around the route phase — the backend's apply opens its own
    # nested frame, so the two never double count) and are folded into the
    # observed state explicitly; with an un-observed backend the frame is
    # never opened and the records are no-ops
    observed = isinstance(be, obs.ObservedStore)

    def body(state, ops, keys, vals):
        sl = jax.tree.map(lambda x: x[0], state)   # this shard's instance
        valid = ops >= 0
        with (obs.collect() if observed else nullcontext(None)) as frame:
            with obs.span("route", backend=be.name):
                rr = route_to_owners(keys, vals, ops, valid, axis_names,
                                     axis_sizes, pool)
            if observed:
                # ops this shard RECEIVED for local execution (valid routed
                # lanes in its pool) and the bytes they carried through the
                # all_to_all queues — per shard, like every other counter
                routed = jnp.sum(rr.valid & (rr.aux >= 0)).astype(jnp.int64)
                obs.record("routed_ops", routed)
                obs.record("routed_bytes", routed * obs.ROUTED_OP_BYTES)
        plan = OpPlan(ops=rr.aux, keys=rr.keys, vals=rr.vals, mask=rr.valid)
        with exec_.exec_mode(mode):   # baked in at trace time
            sl, res = be.apply(sl, plan)
        sl = obs.absorb_frame(sl, frame)
        resv, okb = route_back(res.vals, res.ok, rr.origin,
                               rr.valid & (rr.aux >= 0), axis_names,
                               axis_sizes, lanes)
        state2 = jax.tree.map(lambda a, b: b[None], state, sl)
        return state2, resv, okb, rr.dropped[None]   # [1]/shard -> [S] global

    spec1 = P(tuple(axis_names))
    # pallas_call has no shard_map replication rule: disable the check ONLY
    # when this backend actually traces one (results unchanged — parity is
    # tested); jnp-fallback backends keep the check in every mode
    check = False if (mode != "jnp"
                      and getattr(be, "kernelized", False)) else None
    step = mesh_shard_map(body, mesh=mesh,
                          in_specs=(spec1, spec1, spec1, spec1),
                          out_specs=(spec1, spec1, spec1, spec1),
                          check_vma=check)

    def wrapped(state, ops, keys, vals):
        st, res, ok, dropped = step(state, ops, keys, vals)
        return st, res, ok, jnp.sum(dropped)

    return wrapped


def make_range_step(mesh: Mesh, axis_names: Sequence[str], lanes: int,
                    max_out: int, backend="det_skiplist",
                    pool_factor: int = 2):
    """Range counting over an ORDERED backend: [lo, hi) per lane. Ranges
    crossing shard boundaries are answered by every touched shard and summed
    on the way back (all_gather + psum: ranges are rare + wide, so two
    collectives beat per-key queues)."""
    be = resolve(backend)
    if not be.ordered:
        raise ValueError(f"backend {be.name!r} is unordered: range steps "
                         f"need an ordered backend or tier stack")
    axis_sizes = [mesh.shape[a] for a in axis_names]

    def body(state, los, his, valid):
        valid = valid.astype(jnp.int32)
        sl = jax.tree.map(lambda x: x[0], state)
        ls, hs, vs = los, his, valid
        for a in axis_names:
            ls = jax.lax.all_gather(ls, a, axis=0, tiled=True)
            hs = jax.lax.all_gather(hs, a, axis=0, tiled=True)
            vs = jax.lax.all_gather(vs, a, axis=0, tiled=True)
        cnt, _, _, _ = be.scan(sl, ls, hs, max_out)
        cnt = jnp.where(vs > 0, cnt, 0)
        for a in axis_names:
            cnt = jax.lax.psum(cnt, a)
        me = jnp.int32(0)
        for a in axis_names:
            me = me * axis_size(a) + jax.lax.axis_index(a).astype(jnp.int32)
        return jax.lax.dynamic_slice_in_dim(cnt, me * lanes, lanes)

    spec1 = P(tuple(axis_names))
    return mesh_shard_map(body, mesh=mesh,
                          in_specs=(spec1, spec1, spec1, spec1),
                          out_specs=spec1)


def sharded_stats(backend, state) -> dict:
    """Host-side per-shard `Store.stats`: dict of [S] numpy arrays."""
    be = resolve(backend)
    n_shards = jax.tree.leaves(state)[0].shape[0]
    per = [be.stats(jax.tree.map(lambda x: x[i], state))
           for i in range(n_shards)]
    return {k: np.asarray([np.asarray(jax.device_get(p[k])) for p in per])
            for k in per[0]}


def sharded_metrics(backend, state) -> dict:
    """Host-side per-shard metrics plane: dict of [S] numpy int64 arrays
    over `obs.METRICS_SCHEMA`. Requires an `obs:`-wrapped backend (whose
    sharded state carries the counters on dim 0 like every other leaf);
    per-shard values are bit-identical to a single-device observed instance
    replaying that shard's sub-stream — the METRICS-OK multidev contract."""
    be = resolve(backend)
    if not isinstance(be, obs.ObservedStore):
        raise ValueError(f"backend {be.name!r} carries no metrics plane; "
                         f"construct the engine with an 'obs:'-prefixed "
                         f"backend string (e.g. 'obs:tiered3/lru')")
    n_shards = jax.tree.leaves(state)[0].shape[0]
    per = [be.metrics(jax.tree.map(lambda x: x[i], state))
           for i in range(n_shards)]
    return {k: np.asarray([np.asarray(jax.device_get(p[k])) for p in per])
            for k in per[0]}


class StoreEngine:
    """Convenience bundle: backend + mesh + jitted step, one object.

    `backend` is a registry string (`api.available_backends()`: flat
    structures, or the `hash+skiplist` / `tiered3[/lru|/size]` tier
    stacks) or a `Store` instance; `exec_mode` bakes a probe execution
    mode (jnp | interpret | pallas, `repro.store.exec`) into the jitted
    step — None uses the process default (`REPRO_STORE_EXEC`).

    >>> eng = StoreEngine(mesh, ("pod", "data"), lanes=32,
    ...                   backend="hash+skiplist")
    >>> state = jax.device_put(eng.init(4096), eng.sharding)
    >>> state, res, ok, dropped = eng.step(state, ops, keys, vals)
    >>> eng.stats(state)["size"]        # per-shard live sizes
    """

    def __init__(self, mesh: Mesh, axis_names: Sequence[str], lanes: int,
                 backend="det_skiplist", pool_factor: int = 2,
                 exec_mode: str | None = None):
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self.lanes = lanes
        self.backend = resolve(backend)
        self.exec_mode = exec_mode
        self.pool_factor = pool_factor
        self.n_shards = int(math.prod(mesh.shape[a] for a in self.axis_names))
        self.sharding = store_sharding(mesh, self.axis_names)
        self._jit_step = jax.jit(make_store_step(mesh, self.axis_names, lanes,
                                                 backend=self.backend,
                                                 pool_factor=pool_factor,
                                                 exec_mode=exec_mode))
        # host-side step sequence number: incremented once per `step()` call,
        # surfaced in `stats()` and the "step" span. The resilience journal
        # keys its entries off this counter (`journal.py` restores it on
        # `restore`), and traces gain numbered steps. Deliberately NOT a
        # state leaf: engine state must stay leaf-for-leaf identical to a
        # broadcast backend state (the RESIDENCY-OK contract).
        self.seq = 0

    def step(self, state, ops, keys, vals):
        """One batched-op step, wrapped in the `"step"` trace span (real
        per-batch wall time when a `obs.tracing()` block is active — the
        timeline row `tools/trace_export.py` exports). Each call advances
        the host-side `seq` counter; the span carries the seq of the step
        it timed."""
        seq = self.seq
        self.seq += 1
        with obs.span("step", backend=self.backend.name, lanes=self.lanes,
                      shards=self.n_shards, seq=seq):
            return self._jit_step(state, ops, keys, vals)

    def init(self, capacity_per_shard: int, **kw):
        return sharded_init(self.backend, self.n_shards, capacity_per_shard,
                            **kw)

    def range_step(self, max_out: int, pool_factor: int = 2):
        return jax.jit(make_range_step(self.mesh, self.axis_names, self.lanes,
                                       max_out, backend=self.backend,
                                       pool_factor=pool_factor))

    def stats(self, state) -> dict:
        """Per-shard `STATS_SCHEMA` arrays plus the engine-level `"seq"`
        (host step counter — how many steps this engine has applied; the
        journal's next entry number). `"seq"` is engine metadata, not part
        of `api.STATS_SCHEMA`: backend stats stay schema-exact."""
        out = sharded_stats(self.backend, state)
        out["seq"] = self.seq
        return out

    def metrics(self, state) -> dict:
        """Per-shard metrics plane (`sharded_metrics`); raises unless the
        engine was built over an `obs:` backend."""
        return sharded_metrics(self.backend, state)


@functools.lru_cache(maxsize=None)
def local_store_engine(backend: str, lanes: int,
                       exec_mode: str | None = None) -> StoreEngine:
    """A cached 1-shard StoreEngine over the first local device — the
    serving layer's route into the Store API. Single-shard routing is the
    identity partition (`owner_of` -> shard 0 for every key), so a plan's
    lanes execute in their original order and pop lanes see the EXACT
    global pop-min; pool_factor=1 because the pooled plan is exactly the
    lane set. Cached by (backend string, lanes, exec_mode) so every
    scheduler/prefix-cache call reuses one traced step per configuration
    (flip modes at trace time by passing `exec_mode`, e.g. from
    `exec.get_mode()` inside an `exec.exec_mode(...)` block)."""
    mesh = jax.make_mesh((1,), ("local",),
                         devices=np.array(jax.devices()[:1]))
    return StoreEngine(mesh, ("local",), lanes, backend=backend,
                       pool_factor=1, exec_mode=exec_mode)
