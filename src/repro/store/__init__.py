"""repro.store — unified storage-engine API over the concurrent structures.

The paper's closing proposal (§IX) is hierarchical composition of its
structures; this package is that composition layer. Module map:

api       the `Store` protocol (`init/apply/scan/stats`), the `OpPlan` /
          `OpResults` batch types, op codes, the uniform `STATS_SCHEMA`,
          and the string-keyed backend registry (`register`, `get_backend`,
          `available_backends`)
backends  adapters wrapping det_skiplist, rand_skiplist, fixed hash,
          two-level hash, split-order, and two-level split-order behind the
          protocol — all jit/shard_map-safe pytrees, all agreeing lane-for-
          lane on the INSERTS -> DELETES -> FINDS linearization
exec      the execution layer: FIND/probe phases dispatch through here to
          the pure-jnp references or the Pallas kernels
          (kernels/skiplist_search, kernels/hash_probe) — three modes
          (jnp | interpret | pallas), bit-identical results
pq        the priority-queue backend (`pq`): the deterministic skiplist as
          a mergeable heap — OP_POPMIN/OP_POPK bulk extraction (one rank
          pool per plan, kernelized rank-select + level walk), plus
          OP_RANGE_DELETE; the admission path of `repro.serving.scheduler`
tiers     the hierarchical tier stacks: `hash+skiplist` (hot fixed-hash
          over the ordered skiplist) and `tiered3[/lru|/size]` (a third
          append-only host-spill tier of sorted runs, plus pluggable
          deterministic hot-tier eviction policies — LRU-by-batch and
          size-aware), with batched spill/eviction/promotion/flush; the
          hot-tier probe is the kernelized fast path (docs/tiers.md)
engine    the mesh-sharded engine (hierarchical all_to_all routing + local
          apply) generalizing core/ordered_sharded.py to any backend;
          `StoreEngine` is the one-object convenience wrapper
obs       the observability layer: `obs:`-prefixed backends carry a
          deterministic jit-carried metrics plane (`METRICS_SCHEMA`,
          bit-identical across exec modes and shardings, like results),
          and `span`/`tracing` record host trace spans exportable as
          Chrome-trace/Perfetto JSON (tools/trace_export.py,
          docs/observability.md)

The stack is three explicit layers: `core.layout` owns the flat-memory
shapes, `store.exec` owns probe execution over them, and this package's
backends/tiers/engine own semantics, composition, and sharding. Pick a
backend by config string (`configs/*.py: store_backend`) and an execution
mode by `store_exec`; adding a backend is a one-file drop-in that calls
`register`.
"""
from repro.store.api import (OP_DELETE, OP_FIND, OP_INSERT, OP_NONE, OP_POPK,
                             OP_POPMIN, OP_RANGE, OP_RANGE_DELETE,
                             STATS_SCHEMA, OpPlan, OpResults, Store,
                             available_backends, get_backend, make_plan,
                             register, uniform_stats)
from repro.store.obs import (METRICS_SCHEMA, SERVING_SCHEMA, SPAN_TAXONOMY,
                             ObservedStore, Tracer, current_tracer, span,
                             tracing, uniform_serving_metrics)

__all__ = [
    "OP_DELETE", "OP_FIND", "OP_INSERT", "OP_NONE", "OP_POPK", "OP_POPMIN",
    "OP_RANGE", "OP_RANGE_DELETE",
    "STATS_SCHEMA", "OpPlan", "OpResults", "Store", "available_backends",
    "get_backend", "make_plan", "register", "uniform_stats",
    "METRICS_SCHEMA", "SERVING_SCHEMA", "SPAN_TAXONOMY", "ObservedStore",
    "Tracer", "current_tracer", "span", "tracing",
    "uniform_serving_metrics",
]
