"""Built-in `Store` adapters over the paper's concurrent structures.

Each adapter wraps one core module behind the uniform protocol of
`store.api`. All share one linearization helper so every backend agrees,
lane for lane, on mixed insert/find/delete plans: INSERTS apply first
(insert-if-absent, first lane wins on in-batch duplicates), then DELETES
(first lane wins), then FINDS observe the post-update state. This is what
makes backends interchangeable — `examples/kvstore_service.py` asserts
bit-identical results across all of them on the 8-device mesh.

Registered names:
  det_skiplist         §II deterministic 1-2-3-4 skiplist (ordered)
  rand_skiplist        §VI randomized comparator (ordered)
  fixed_hash           §VII fixed-slot MWMR table
  twolevel_hash        §VII two-level table with pooled L2 expansion
  splitorder           §VII/VIII split-order table
  twolevel_splitorder  §VIII two-level split-order (NUMA-partition analogue)
(`tiers.py` adds the hierarchical stacks: `hash+skiplist` and
`tiered3[/lru|/size]`.)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import det_skiplist as dsl
from repro.core import hashtable as ht
from repro.core import rand_skiplist as rsl
from repro.core import splitorder as so
from repro.core.bits import EMPTY, KEY_INF
from repro.core.layout import pow2_floor as _pow2
from repro.store import exec as exec_
from repro.store import obs
from repro.store.api import (OP_DELETE, OP_FIND, OP_INSERT, OP_RANGE_DELETE,
                             OpPlan, OpResults, register, uniform_stats)


def finalize_results(ops, valid, found, fvals, inserted, existed,
                     deleted) -> OpResults:
    """The per-lane (ok, res) encoding every backend must share — FIND ->
    (hit, value), INSERT -> (applied, existed flag), DELETE -> (removed, 0).
    One implementation so the bit-identical cross-backend contract has a
    single source of truth (tiers.py uses it too)."""
    ok = jnp.where(ops == OP_FIND, found,
                   jnp.where(ops == OP_INSERT, inserted | existed,
                             deleted)) & valid
    res = jnp.where(valid & (ops == OP_FIND), fvals,
                    jnp.where(valid & (ops == OP_INSERT),
                              existed.astype(jnp.uint64), jnp.uint64(0)))
    return OpResults(ok=ok, vals=res)


def apply_linearized(state, plan: OpPlan, insert_fn, delete_fn, find_fn,
                     absent_key, range_delete_fn=None):
    """The shared INSERTS -> DELETES -> [RANGE_DELETES ->] FINDS execution
    over masked batch primitives. `find_fn(state, keys) -> (found, vals)`;
    `absent_key` is the backend's sentinel for lanes that must not match
    anything. Ordered backends pass `range_delete_fn(state, lo, hi, mask)
    -> (state, counts)` to execute `OP_RANGE_DELETE` lanes (lane keys = lo,
    vals = hi, result = (any deleted, count)); backends without one leave
    those lanes at the ok=False/vals=0 fall-through of
    `finalize_results`."""
    valid = plan.mask & (plan.ops >= 0)
    ins_m = valid & (plan.ops == OP_INSERT)
    del_m = valid & (plan.ops == OP_DELETE)
    state, inserted, existed = insert_fn(state, plan.keys, plan.vals, ins_m)
    state, deleted = delete_fn(state, plan.keys, del_m)
    rd_counts = None
    if range_delete_fn is not None:
        rd_m = valid & (plan.ops == OP_RANGE_DELETE)
        state, rd_counts = range_delete_fn(state, plan.keys, plan.vals, rd_m)
    found, fvals = find_fn(state, jnp.where(valid, plan.keys, absent_key))
    res = finalize_results(plan.ops, valid, found, fvals, inserted,
                           existed, deleted)
    if rd_counts is not None:
        is_rd = valid & (plan.ops == OP_RANGE_DELETE)
        res = OpResults(ok=jnp.where(is_rd, rd_counts > 0, res.ok),
                        vals=jnp.where(is_rd, rd_counts.astype(jnp.uint64),
                                       res.vals))
    return state, res


class DetSkiplistBackend:
    name = "det_skiplist"
    ordered = True
    kernelized = True      # FIND dispatches to kernels/skiplist_search

    def init(self, capacity: int, **kw):
        return dsl.skiplist_init(capacity)

    def apply(self, state, plan: OpPlan):
        state, res = apply_linearized(
            state, plan, dsl.insert_batch, dsl.delete_batch,
            lambda s, q: exec_.skiplist_find(s, q)[:2], KEY_INF,
            range_delete_fn=dsl.range_delete_batch)
        # batch clock: entries inserted by apply #b carry stamp b, which is
        # what scan(as_of_batch=b) snapshots against
        return state._replace(clock=state.clock + 1), res

    def scan(self, state, lo, hi, max_out: int, as_of_batch=None):
        return dsl.range_query(state, lo, hi, max_out,
                               as_of_batch=as_of_batch)

    def stats(self, state):
        return uniform_stats(
            size=state.n_term - state.n_marked,
            tombstones=state.n_marked,
            capacity=state.term_keys.shape[0])


class RandSkiplistBackend:
    name = "rand_skiplist"
    ordered = True
    kernelized = False     # MAX_GAP walk stays jnp in every mode

    def init(self, capacity: int, **kw):
        return rsl.rand_skiplist_init(capacity)

    def apply(self, state, plan: OpPlan):
        return apply_linearized(
            state, plan, rsl.insert_batch, rsl.delete_batch,
            lambda s, q: exec_.rand_skiplist_find(s, q)[:2], KEY_INF)

    def scan(self, state, lo, hi, max_out: int):
        # the randomized variant keeps the same contiguous sorted terminal
        # level, so the deterministic range gather applies verbatim
        return dsl.range_query(state, lo, hi, max_out)

    def stats(self, state):
        return uniform_stats(
            size=state.n_term - state.n_marked,
            tombstones=state.n_marked,
            capacity=state.term_keys.shape[0])


class _Unordered:
    ordered = False
    kernelized = False

    def scan(self, state, lo, hi, max_out: int):
        raise NotImplementedError(
            f"{self.name} is unordered: no range scan (pick an ordered "
            f"backend or the tiered hash+skiplist stack)")


class FixedHashBackend(_Unordered):
    name = "fixed_hash"
    kernelized = True      # probe dispatches to kernels/hash_probe

    def init(self, capacity: int, bucket: int = 16, **kw):
        return ht.fixed_init(_pow2(max(capacity // bucket, 1)), bucket)

    def apply(self, state, plan: OpPlan):
        def find(h, queries):
            # bucket_collisions: live non-matching entries in each probed
            # row — computed from the probe INPUTS on the host path, so the
            # count is bit-identical across exec modes by construction
            obs.record("bucket_collisions",
                       lambda: obs.bucket_collision_count(h, queries))
            return exec_.hash_find(h, queries)
        return apply_linearized(state, plan, ht.fixed_insert, ht.fixed_delete,
                                find, EMPTY)

    def stats(self, state):
        return uniform_stats(size=state.count, capacity=state.keys.size)


class TwoLevelHashBackend(_Unordered):
    name = "twolevel_hash"

    def init(self, capacity: int, b1: int = 8, m2: int = 16, b2: int = 8,
             pool_blocks: int | None = None, **kw):
        m1 = _pow2(max(capacity // (2 * b1), 1))
        if pool_blocks is None:
            # default: every L1 slot can expand once (threshold expansion
            # must be able to absorb overflow on ALL slots — paper table V)
            pool_blocks = max(m1, 8)
        return ht.twolevel_init(m1, b1, m2, b2, pool_blocks)

    def apply(self, state, plan: OpPlan):
        return apply_linearized(state, plan, ht.twolevel_insert,
                                ht.twolevel_delete, exec_.twolevel_hash_find,
                                EMPTY)

    def stats(self, state):
        return uniform_stats(
            size=state.count,
            capacity=state.l1_keys.size + state.l2_keys.size,
            l2_tables=jnp.sum(state.l2_block >= 0))


class SplitOrderBackend(_Unordered):
    name = "splitorder"

    def init(self, capacity: int, seed_slots: int = 4, max_load: int = 16, **kw):
        return so.splitorder_init(capacity, seed_slots, max_load)

    def apply(self, state, plan: OpPlan):
        return apply_linearized(state, plan, so.splitorder_insert,
                                so.splitorder_delete, exec_.splitorder_find,
                                KEY_INF)

    def stats(self, state):
        return uniform_stats(size=state.n, capacity=state.rk.shape[0],
                             slots=state.n_slots)


class TwoLevelSplitOrderBackend(_Unordered):
    name = "twolevel_splitorder"
    kernelized = True      # probe dispatches to kernels/splitorder_probe

    def init(self, capacity: int, num_tables: int = 8, seed_slots: int = 2,
             max_load: int = 16, **kw):
        per_table = max(capacity // num_tables, 16)
        return so.twolevel_splitorder_init(num_tables, per_table, seed_slots,
                                           max_load)

    def apply(self, state, plan: OpPlan):
        return apply_linearized(state, plan, so.twolevel_splitorder_insert,
                                so.twolevel_splitorder_delete,
                                exec_.twolevel_splitorder_find, KEY_INF)

    def stats(self, state):
        return uniform_stats(size=jnp.sum(state.n), capacity=state.rk.size,
                             slots=jnp.sum(state.n_slots))


DET_SKIPLIST = register(DetSkiplistBackend())
RAND_SKIPLIST = register(RandSkiplistBackend())
FIXED_HASH = register(FixedHashBackend())
TWOLEVEL_HASH = register(TwoLevelHashBackend())
SPLITORDER = register(SplitOrderBackend())
TWOLEVEL_SPLITORDER = register(TwoLevelSplitOrderBackend())
