"""Priority-queue `Store` backend over the deterministic skiplist.

"Practical Concurrent Priority Queues" (arXiv:1509.07053) builds pq
semantics on exactly the structure the paper gives us: a skiplist whose
minimum is the leftmost live terminal entry. This backend exposes that
through the Store contract as two lane ops:

  OP_POPMIN  extract-min; result vals = the popped entry's VALUE
  OP_POPK    extract-min; result vals = the popped entry's KEY

Both pop identically — all pop lanes of a plan share ONE rank pool in lane
order, so the j-th pop lane (counting POPMIN and POPK together) extracts
the j-th smallest live key and k pop lanes ARE a deterministic bulk-pop-k.
A pop lane's `keys` field is ignored here; under the sharded engine it is
the routing hint that selects WHICH shard's queue the lane pops — the
per-shard relaxed-pq design of 1509.07053 (a 1-shard mesh degenerates to
the exact global pop-min, which is how the serving scheduler runs it).

Pops execute as rank-select + lazy tombstones: `exec.pq_pop` (jnp |
Pallas interpret | pallas, bit-identical) locates the rank-th smallest
live key, `det_skiplist.pop_mark` commits the extraction through the same
DropKey/compaction path as deletes. FIND/INSERT/DELETE/RANGE_DELETE lanes
behave exactly as on `det_skiplist` (same primitives, same order), so the
cross-backend parity sweep covers `pq` unchanged; the full linearization
is INSERTS -> DELETES -> RANGE_DELETES -> POPS -> FINDS.

Registered as `pq` (and `obs:pq` via the observability prefix, which adds
the `pops` / `pop_empty` counters to the metrics plane; the same two ride
in `stats()` for un-observed states).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import det_skiplist as dsl
from repro.core.bits import KEY_INF
from repro.store import exec as exec_
from repro.store import obs
from repro.store.api import (OP_POPK, OP_POPMIN, OP_RANGE_DELETE, OpPlan,
                             OpResults, register, uniform_stats)
from repro.store.backends import apply_linearized


class PQState(NamedTuple):
    """The pq backend's pytree: the skiplist heap + cumulative pop stats."""
    heap: dsl.DetSkiplist
    n_pops: jnp.ndarray       # scalar int64 — successful pop lanes
    n_pop_empty: jnp.ndarray  # scalar int64 — pop lanes that found it empty


class PQSkiplistBackend:
    name = "pq"
    ordered = True
    kernelized = True      # FIND -> kernels/skiplist_search, POP -> kernels/pq_pop

    def init(self, capacity: int, **kw) -> PQState:
        return PQState(heap=dsl.skiplist_init(capacity),
                       n_pops=jnp.zeros((), jnp.int64),
                       n_pop_empty=jnp.zeros((), jnp.int64))

    def apply(self, state: PQState, plan: OpPlan):
        valid = plan.mask & (plan.ops >= 0)
        is_pop = (plan.ops == OP_POPMIN) | (plan.ops == OP_POPK)
        pop_m = valid & is_pop
        rd_m = valid & (plan.ops == OP_RANGE_DELETE)

        def popping_find(heap, queries):
            # spliced between range-deletes and finds: `apply_linearized`
            # calls its find closure exactly once, after every update
            # phase, so committing the pops here keeps the linearization
            # INSERTS -> DELETES -> RANGE_DELETES -> POPS -> FINDS with
            # the insert/delete/range-delete half shared with det_skiplist
            ranks = jnp.cumsum(pop_m.astype(jnp.int32)) - 1
            with obs.span("pop", backend=self.name):
                popped, pkeys, pidx = exec_.pq_pop(heap, ranks, pop_m)
                pvals = jnp.where(popped, heap.term_vals[pidx], jnp.uint64(0))
                heap = dsl.pop_mark(heap, pidx, popped)
            obs.record("pops", lambda: jnp.sum(popped))
            obs.record("pop_empty", lambda: jnp.sum(pop_m & ~popped))
            pop_state["heap"] = heap
            pop_state["res"] = (popped, pkeys, pvals)
            found, fvals, _ = exec_.skiplist_find(heap, queries)
            return found, fvals

        pop_state: dict = {}
        _, res = apply_linearized(
            state.heap, plan, dsl.insert_batch, dsl.delete_batch,
            popping_find, KEY_INF, range_delete_fn=dsl.range_delete_batch)
        heap = pop_state["heap"]
        popped, pkeys, pvals = pop_state["res"]

        # overlay the pop lanes onto the shared result encoding: ok = a
        # live entry was extracted; vals = its VALUE (POPMIN) or KEY (POPK)
        pres = jnp.where(popped,
                         jnp.where(plan.ops == OP_POPMIN, pvals, pkeys),
                         jnp.uint64(0))
        res = OpResults(ok=jnp.where(is_pop, popped, res.ok),
                        vals=jnp.where(is_pop & valid, pres, res.vals))
        n_pops = state.n_pops + jnp.sum(popped).astype(jnp.int64)
        n_empty = state.n_pop_empty + jnp.sum(pop_m & ~popped).astype(jnp.int64)
        heap = heap._replace(clock=heap.clock + 1)   # same batch clock as
        return PQState(heap=heap, n_pops=n_pops,     # det_skiplist.scan
                       n_pop_empty=n_empty), res

    def scan(self, state: PQState, lo, hi, max_out: int, as_of_batch=None):
        return dsl.range_query(state.heap, lo, hi, max_out,
                               as_of_batch=as_of_batch)

    def stats(self, state: PQState):
        return uniform_stats(
            size=state.heap.n_term - state.heap.n_marked,
            tombstones=state.heap.n_marked,
            capacity=state.heap.term_keys.shape[0],
            pops=state.n_pops,
            pop_empty=state.n_pop_empty)


PQ = register(PQSkiplistBackend())
