"""Storage-engine protocol: one API over every concurrent structure.

The paper's closing proposal is *hierarchical usage of concurrent data
structures in programs* — composing skiplists, hash tables, and queues into
one system to cut remote-node memory accesses. That composition needs a
common contract first: this module defines it.

* `OpPlan` — a batch of K operations as parallel arrays (ops/keys/vals/mask).
  A lane is one "thread"; the whole plan is one linearization unit with the
  deterministic order INSERTS -> DELETES -> RANGE_DELETES -> POPS -> FINDS,
  first-lane-wins on in-batch duplicates (strictly stronger than the
  paper's "some linearization exists").
* `OpResults` — per-lane (ok, vals): FIND -> (hit, stored value);
  INSERT -> (applied, already-existed flag); DELETE -> (removed, 0).
  Priority-queue lanes (ordered backends that support them — `pq`):
  POPMIN -> (popped, popped entry's VALUE) and POPK -> (popped, popped
  entry's KEY). All pop lanes in a plan share one rank pool in lane
  order — the j-th pop lane (counting POPMIN and POPK together) extracts
  the j-th smallest live key, so k pop lanes ARE a bulk-pop-k. A pop
  lane's `keys` field is ignored by the backend itself; under the sharded
  engine it is the routing hint that selects WHICH shard's queue to pop
  (per-shard relaxed pq semantics, arXiv:1509.07053). RANGE_DELETE ->
  (any deleted, deleted count as u64): lane `keys` = lo, `vals` = hi,
  removes [lo, hi); overlapping lanes attribute each deleted entry to the
  first covering lane.
* `Store` — the backend protocol: `init(capacity, **kw)` builds a
  jit/shard_map-safe pytree state, `apply(state, plan)` executes a plan,
  `scan(state, lo, hi, max_out)` is the ordered range query (unordered
  backends raise NotImplementedError and advertise `ordered = False`),
  `stats(state)` returns uniform occupancy scalars (at least `size` and
  `capacity`).
* registry — backends register under a string key so callers select one by
  config (`configs/*.py: ModelConfig.store_backend`) and every future
  backend is a one-file drop-in. Built-in registry strings:

    det_skiplist         §II deterministic 1-2-3-4 skiplist (ordered)
    rand_skiplist        §VI randomized comparator (ordered)
    fixed_hash           §VII fixed-slot MWMR table
    twolevel_hash        §VII two-level table with pooled L2 expansion
    splitorder           §VII/VIII split-order table
    twolevel_splitorder  §VIII two-level split-order (NUMA analogue)
    hash+skiplist        §IX two-tier stack: hot fixed-hash over skiplist
    tiered3              §IX three-tier stack (hash -> skiplist -> spill)
    tiered3/lru          tiered3 with LRU-by-batch hot-tier eviction
    tiered3/size         tiered3 with size-aware hot-tier eviction
    tiered3/b128         tiered3 probing the warm tier through the
                         block-major B-skiplist layout (128-key lane-width
                         nodes) — bit-identical results and residency
    pq                   priority queue over the det skiplist: POPMIN /
                         POPK bulk extraction (arXiv:1509.07053 design)

  The first six live in `store/backends.py`, the tier stacks in
  `store/tiers.py` (policy semantics in docs/tiers.md), the priority
  queue in `store/pq.py` (serving usage in docs/serving.md). Prefixing any
  registry string with `obs:` (e.g. `obs:tiered3/lru`) wraps the backend
  in the observability layer (`store/obs.py`): same results, plus a
  deterministic jit-carried metrics plane and host trace spans. Execution mode is
  orthogonal: `store/exec.py` (`store_exec` config / `REPRO_STORE_EXEC`
  env var) picks jnp | interpret | pallas probes for ANY backend, with
  bit-identical results. Fault tolerance is orthogonal too:
  `store/resilience/` journals applied plans (seq-numbered, digest-chained)
  against periodic state snapshots, so ANY backend or engine state is
  bit-identically reconstructible by replaying the journal tail through
  this same `apply` path (docs/resilience.md).

Op codes are shared with the router (`core/ordered_sharded.py` re-exports
them for compatibility): lane op `OP_NONE` means an idle lane.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Protocol, runtime_checkable

import jax.numpy as jnp

OP_NONE, OP_FIND, OP_INSERT, OP_DELETE, OP_RANGE = -1, 0, 1, 2, 3
# Priority-queue + ordered-maintenance lane ops (PR 7). POPMIN and POPK
# pop identically (shared lane-order rank pool: j-th pop lane gets the
# j-th smallest live key) and differ only in the result: POPMIN returns
# the popped value, POPK the popped key. RANGE_DELETE reads the lane as
# [keys, vals) = [lo, hi) and returns the deleted count.
OP_POPMIN, OP_POPK, OP_RANGE_DELETE = 4, 5, 6

# The closed set of executable lane op codes (OP_NONE is the idle lane, not
# an op). The resilience layer (`store/resilience/`, docs/resilience.md)
# treats any other value as a poisoned lane: `faults.sanitize_plan` masks it
# to OP_NONE before the plan reaches a backend, journals the sanitized plan,
# and re-submits the original lane intent on the next step.
VALID_OPS = frozenset((OP_FIND, OP_INSERT, OP_DELETE, OP_RANGE,
                       OP_POPMIN, OP_POPK, OP_RANGE_DELETE))


class OpPlan(NamedTuple):
    """A batch of K ops as parallel arrays — the unit of linearization."""
    ops: jnp.ndarray    # [K] int32 op codes (OP_NONE lanes are idle)
    keys: jnp.ndarray   # [K] uint64
    vals: jnp.ndarray   # [K] uint64 (insert payloads; ignored otherwise)
    mask: jnp.ndarray   # [K] bool — False lanes are no-ops with ok=False

    @property
    def width(self) -> int:
        return self.ops.shape[0]


class OpResults(NamedTuple):
    ok: jnp.ndarray     # [K] bool — FIND hit / INSERT applied / DELETE removed
    vals: jnp.ndarray   # [K] uint64 — FIND value; INSERT existed flag; else 0


def make_plan(ops, keys, vals=None, mask=None) -> OpPlan:
    """Convenience constructor with dtype coercion and default vals/mask."""
    ops = jnp.asarray(ops, jnp.int32)
    keys = jnp.asarray(keys, jnp.uint64)
    vals = jnp.zeros_like(keys) if vals is None else jnp.asarray(vals, jnp.uint64)
    mask = jnp.ones(ops.shape, bool) if mask is None else jnp.asarray(mask, bool)
    return OpPlan(ops=ops, keys=keys, vals=vals, mask=mask)


@runtime_checkable
class Store(Protocol):
    """Backend protocol. State is an opaque jit-able pytree; every method is
    pure (state in, state out) so backends compose with jit/shard_map/vmap
    and checkpoint for free."""

    name: str
    ordered: bool
    # kernelized (optional, default False): True iff the backend's probe
    # phases dispatch to Pallas kernels under non-jnp exec modes — the
    # engine uses it to scope shard_map's replication-check workaround

    def init(self, capacity: int, **kw) -> Any:
        """Empty state holding up to ~capacity entries."""
        ...

    def apply(self, state: Any, plan: OpPlan) -> tuple[Any, OpResults]:
        """Execute a plan under the deterministic linearization."""
        ...

    def scan(self, state: Any, lo: jnp.ndarray, hi: jnp.ndarray, max_out: int):
        """Batched range query over [lo, hi) rows. Returns
        (count[Q], keys[Q, max_out], vals[Q, max_out], valid[Q, max_out]).
        Unordered backends raise NotImplementedError. Backends may accept
        extra keyword options (the skiplist-terminal backends take
        `as_of_batch=b` for a snapshot scan that excludes entries inserted
        after batch clock b)."""
        ...

    def stats(self, state: Any) -> Dict[str, jnp.ndarray]:
        """Uniform occupancy scalars: EXACTLY the `STATS_SCHEMA` key set
        (backends pad untracked counters with zeros via `uniform_stats`).
        No caller should reach into backend internals."""
        ...


# Every backend's `stats()` returns EXACTLY these keys (counters a backend
# does not track are zero), so engine-level aggregation, dashboards, and the
# uniform-schema test never special-case a backend.
#   size        live entries across every tier/level
#   capacity    total allocated entry slots
#   tombstones  lazily-deleted entries awaiting compaction
#   hot_size / cold_size / spill_size   per-tier live entries of the tiered
#               stacks (hot fixed-hash / warm skiplist / cold spill runs)
#   l2_tables   expanded second-level tables (twolevel_hash)
#   slots       live split-order slot count
#   evictions / promotions   cumulative tier-policy movement counters
#               (tiered stacks; preserved across `flush`)
#   pops / pop_empty   cumulative successful pop lanes / pop lanes that
#               found the queue empty (priority-queue backends)
STATS_SCHEMA = ("size", "capacity", "tombstones", "hot_size", "cold_size",
                "spill_size", "l2_tables", "slots", "evictions", "promotions",
                "pops", "pop_empty")


def uniform_stats(**counters) -> Dict[str, jnp.ndarray]:
    """Pad a backend's native counters to the shared `STATS_SCHEMA` key set
    (missing keys become int64 zeros; unknown keys are an error so the
    schema stays closed)."""
    unknown = set(counters) - set(STATS_SCHEMA)
    if unknown:
        raise ValueError(f"stats keys {sorted(unknown)} not in STATS_SCHEMA; "
                         f"extend api.STATS_SCHEMA to add a counter")
    return {k: jnp.asarray(counters.get(k, 0)).astype(jnp.int64)
            for k in STATS_SCHEMA}


_REGISTRY: Dict[str, Store] = {}


def register(backend: Store) -> Store:
    """Register a backend instance under its `name` (decorator-friendly)."""
    if backend.name in _REGISTRY:
        raise ValueError(f"store backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def _ensure_builtin() -> None:
    # importing these modules registers the built-in backends; deferred so
    # api.py itself stays dependency-free (no import cycles)
    from repro.store import backends, pq, tiers  # noqa: F401


def get_backend(name: str) -> Store:
    """Look up a registered backend by its registry string (the module
    docstring lists the built-ins; `available_backends()` lists everything
    currently registered, including third-party drop-ins).

    The `obs:` prefix composes observability onto ANY registered backend:
    `get_backend("obs:tiered3/lru")` returns the `tiered3/lru` backend
    wrapped in `repro.store.obs.ObservedStore`, whose state carries the
    jit-compatible metrics plane and whose apply/scan record trace spans.
    """
    if name.startswith("obs:"):
        from repro.store.obs import ObservedStore
        return ObservedStore(get_backend(name[len("obs:"):]))
    _ensure_builtin()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown store backend {name!r}; "
                       f"available: {sorted(_REGISTRY)}") from None


def available_backends() -> list[str]:
    """Sorted registry strings of every registered backend."""
    _ensure_builtin()
    return sorted(_REGISTRY)
