"""Hierarchical tier stack: hot fixed-slot hash in front of an ordered
skiplist (the paper's closing proposal, §IX: "hierarchical usage of
concurrent data structures ... reduces memory accesses from remote NUMA
nodes").

Layout invariant: every live key resides in EXACTLY ONE tier. The hot tier
is a small fixed-slot table (one VMEM-tile row per bucket — the constant-cost
fast path); the cold tier is the deterministic skiplist (ordered, large).

Batched movement between tiers, all inside one `apply` (jit-able, no host
round trips):
  * spill     — insert lanes whose hot bucket is full fall through to cold
  * promotion — FIND lanes served by the cold tier are re-inserted into the
                hot tier (when bucket space allows) and deleted from cold,
                so repeated hot-set accesses migrate up, batch by batch
  * flush     — explicit bulk demotion of the whole hot tier into cold
                (used before ordered bulk work, checkpoint compaction, ...)

Linearization matches every flat backend: INSERTS -> DELETES -> FINDS, first
lane wins on duplicates. Promotion runs after FINDS and is membership-neutral,
so results are bit-identical to the flat `det_skiplist` backend — asserted by
`examples/kvstore_service.py` and `tests/test_store_api.py`.

`scan` stays exact: counts merge the cold range count with a hot-tier
in-range reduction, and materialized rows are the sorted union of both tiers
(truncated at max_out, same contract as the flat ordered backends).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import det_skiplist as dsl
from repro.core import hashtable as ht
from repro.core.bits import EMPTY, KEY_INF
from repro.store import exec as exec_
from repro.store.api import (OP_DELETE, OP_FIND, OP_INSERT, OpPlan, register,
                             uniform_stats)
from repro.store.backends import _pow2, finalize_results


class TierState(NamedTuple):
    hot: ht.FixedHash     # small fixed-slot table (the near/fast tier)
    cold: dsl.DetSkiplist  # ordered backing store (the far/large tier)


class TieredBackend:
    """`hash+skiplist`: hot fixed-hash tier over a det-skiplist cold tier."""

    name = "hash+skiplist"
    ordered = True
    kernelized = True      # hot probe + cold find dispatch to kernels

    def __init__(self, promote: bool = True):
        self.promote = promote

    def init(self, capacity: int, hot_bucket: int = 8, hot_frac: int = 8,
             **kw) -> TierState:
        """Cold tier sized at `capacity`; hot tier at ~capacity/hot_frac."""
        hot_slots = _pow2(max(capacity // (hot_frac * hot_bucket), 1))
        return TierState(hot=ht.fixed_init(hot_slots, hot_bucket),
                         cold=dsl.skiplist_init(capacity))

    # -- apply ---------------------------------------------------------------

    def apply(self, state: TierState, plan: OpPlan):
        hot, cold = state.hot, state.cold
        ops, keys, vals = plan.ops, plan.keys, plan.vals
        valid = plan.mask & (ops >= 0)
        ins_m = valid & (ops == OP_INSERT)
        del_m = valid & (ops == OP_DELETE)
        qk = jnp.where(valid, keys, KEY_INF)

        # INSERTS: insert-if-absent across BOTH tiers; try hot first, spill
        # bucket-full lanes down to cold (the batched spill path)
        in_cold, _, _ = exec_.skiplist_find(cold,
                                            jnp.where(ins_m, keys, KEY_INF))
        hot, ins_hot, ex_hot = ht.fixed_insert(hot, keys, vals,
                                               ins_m & ~in_cold)
        spill = ins_m & ~in_cold & ~ins_hot & ~ex_hot
        cold, ins_cold, ex_cold = dsl.insert_batch(cold, keys, vals, spill)
        inserted = ins_hot | ins_cold
        existed = ex_hot | in_cold | ex_cold

        # DELETES: the single-tier invariant means exactly one tier can hit
        hot, del_hot = ht.fixed_delete(hot, keys, del_m)
        cold, del_cold = dsl.delete_batch(cold, keys, del_m & ~del_hot)
        deleted = del_hot | del_cold

        # FINDS observe the post-update state of both tiers; the hot probe is
        # the kernelized fast path (kernels/hash_probe under exec dispatch)
        f_hot, v_hot = exec_.hash_find(hot, qk)
        f_cold, v_cold, _ = exec_.skiplist_find(cold, qk)
        found = f_hot | f_cold
        fvals = jnp.where(f_hot, v_hot, v_cold)

        # PROMOTION (after the linearization point; membership-neutral):
        # cold-served FIND lanes migrate to the hot tier when space allows
        if self.promote:
            prom = valid & (ops == OP_FIND) & f_cold & ~f_hot
            hot, prom_ok, _ = ht.fixed_insert(hot, keys, v_cold, prom)
            cold, _ = dsl.delete_batch(cold, keys, prom & prom_ok)

        return TierState(hot=hot, cold=cold), finalize_results(
            ops, valid, found, fvals, inserted, existed, deleted)

    # -- ordered scan over both tiers ----------------------------------------

    def scan(self, state: TierState, lo, hi, max_out: int):
        cnt_c, k_c, v_c, val_c = dsl.range_query(state.cold, lo, hi, max_out)
        hk = state.hot.keys.reshape(-1)
        hv = state.hot.vals.reshape(-1)
        in_range = (hk[None, :] >= lo[:, None]) & (hk[None, :] < hi[:, None]) \
            & (hk[None, :] != EMPTY)
        count = cnt_c + jnp.sum(in_range, axis=1).astype(cnt_c.dtype)

        # materialize the sorted union, truncated at max_out: sort the hot
        # in-range entries per query, then merge with the cold slice
        sk = jnp.where(in_range, hk[None, :], KEY_INF)        # [Q, H]
        oh = jnp.argsort(sk, axis=1)[:, :max_out]
        hkeys = jnp.take_along_axis(sk, oh, axis=1)
        hvals = jnp.take_along_axis(
            jnp.broadcast_to(hv[None, :], sk.shape), oh, axis=1)
        ck = jnp.where(val_c, k_c, KEY_INF)
        allk = jnp.concatenate([ck, hkeys], axis=1)           # [Q, 2*max_out]
        allv = jnp.concatenate([jnp.where(val_c, v_c, jnp.uint64(0)), hvals],
                               axis=1)
        om = jnp.argsort(allk, axis=1)[:, :max_out]
        keys = jnp.take_along_axis(allk, om, axis=1)
        vals = jnp.take_along_axis(allv, om, axis=1)
        return count, keys, vals, keys != KEY_INF

    # -- movement / stats ----------------------------------------------------

    def flush(self, state: TierState) -> TierState:
        """Bulk demotion: move every hot entry into the cold tier."""
        hk = state.hot.keys.reshape(-1)
        hv = state.hot.vals.reshape(-1)
        cold, _, _ = dsl.insert_batch(state.cold, hk, hv, hk != EMPTY)
        hot = state.hot._replace(keys=jnp.full_like(state.hot.keys, EMPTY),
                                 vals=jnp.zeros_like(state.hot.vals),
                                 count=state.hot.count * 0)
        return TierState(hot=hot, cold=cold)

    def stats(self, state: TierState):
        hot_size = state.hot.count.astype(jnp.int64)
        cold_size = (state.cold.n_term - state.cold.n_marked).astype(jnp.int64)
        return uniform_stats(
            size=hot_size + cold_size,
            hot_size=hot_size,
            cold_size=cold_size,
            tombstones=state.cold.n_marked,
            capacity=state.hot.keys.size + state.cold.term_keys.shape[0])


HASH_SKIPLIST = register(TieredBackend())
