"""Hierarchical tier stack (paper §IX): hot fixed-hash tier with pluggable
eviction policies, warm ordered skiplist tier, and an optional cold
"host spill" tier of append-only sorted runs.

The paper's closing proposal is *hierarchical usage of concurrent data
structures* so hot data stays in the fastest tier and remote/cold accesses
are batched. Related work ("Skiplists with Foresight", NUMA-local skip
graphs) shows the latency win comes from locality-aware PLACEMENT and
EVICTION, not just capacity spill — hence the policy layer here.

Tier layout (every live key resides in EXACTLY ONE tier):

  hot    fixed-slot hash (`core.hashtable.FixedHash`): one VMEM-tile row
         per bucket, the kernelized constant-cost fast path, annotated with
         a per-entry policy-metadata plane (`core.layout.policy_arrays`)
  warm   the deterministic skiplist (ordered, large — the `cold` field,
         named for continuity with the two-tier stack)
  cold   `SpillTier` (depth-3 only): append-only sorted runs outside the
         hot/warm device-resident structures (`core.layout.spill_arrays`;
         on TPU the planes are placed in pinned host memory — see
         `_pin_spill_host`). Cells below the cursor are immutable except
         for tombstones, so the region can live in host/pinned memory and
         be DMA'd in bulk; runs are merged on scan, probed by a per-run
         binary search over the `core.layout.run_offsets` boundary plane,
         and `spill_compact` rewrites them (dropping tombstones) when dead
         entries pass 1/4 of the appended total OR the live run count
         nears `core.layout.MAX_SPILL_RUNS` (the static cap that keeps the
         probe's boundary plane fixed-size).

Probe execution (the `fused` knob, default True): the FIND phases issue
ONE `store.exec.tier_find` dispatch per plan — the fused
`kernels/tier_find` pallas_call probes hot buckets, walks the warm
skiplist, and binary-searches the spill runs in a single launch, so the
hot path's dispatch count is independent of tier depth (one for the
insert-phase membership probe + one for the FIND phase = 2 per apply,
down from 5). `fused=False` keeps the original three-dispatch chain —
bit-identical results AND residency by contract (the parity suite
`tests/test_tier_find.py` asserts it across exec modes and shardings).

Eviction policies (the `policy` knob; state carried in `TierState.hot_meta`
plus the `clock` batch counter — all deterministic, jit-able, and
bit-identical across exec modes):

  none   no eviction: bucket-full inserts fall through (spill-only, the
         original two-tier behavior)
  lru    LRU-by-batch: `hot_meta[slot, col]` holds the batch clock of the
         entry's last touch — placement, FIND hit, or an INSERT that found
         the key already resident; a full bucket evicts the oldest stamp
         (ties: lowest column) down to the warm tier and installs the
         incoming key hot — repeated access keeps an entry resident
  size   size-aware: `hot_meta` holds `core.layout.val_weight` (payload
         bytes); a full bucket evicts the LARGEST payload first (ties:
         lowest column), biasing the fast tier toward many small entries

Batched movement between tiers, all inside one `apply` (no host round
trips):
  * spill     — insert lanes the hot tier cannot place (bucket full under
                `none`, or more lanes than bucket width under any policy)
                fall to warm; warm capacity overflow appends to the cold
                spill runs
  * eviction  — policy victims demote hot -> warm (-> spill runs on warm
                overflow), batched with the inserts that displaced them.
                Evictions are capped at the lower tiers' free headroom, so
                a displaced resident ALWAYS lands somewhere: when the
                whole stack is full, the NEW lane fails (the flat
                backend's allocation-failure analogue), never a resident
  * promotion — FIND lanes served by warm or spill are re-installed hot
                (evicting victims under `lru`/`size`; only into free space
                under `none`) and removed from their source tier
  * flush     — explicit bulk demotion of the whole hot tier into warm
                (-> spill on overflow); entries the lower tiers cannot
                absorb stay hot (demotion is lossless here too). Flushed
                cells' policy metadata is cleared WITH the keys, but the
                batch clock and the cumulative eviction/promotion counters
                are preserved — a flush is an event in the policy's
                history, not a history reset.

Linearization matches every flat backend: INSERTS -> DELETES -> FINDS,
first lane wins on duplicates. Eviction and promotion are
membership-neutral (they move keys between tiers, never add or drop one),
so EVERY tier configuration is bit-identical to the flat `det_skiplist`
backend for the same `OpPlan` stream — asserted across all registered tier
configs by `tests/test_store_api.py`, across exec modes by
`tests/test_exec_modes.py`, and for residency itself (the full state, not
just results) by `tests/test_tiers3.py`.

`scan` stays exact: the warm range count/slice merges with in-range
reductions over the hot table and the live spill-run entries; materialized
rows are the sorted union of all tiers, truncated at `max_out`.

Warm probe layout (the `warm_layout` knob, default "level"): "block"
walks the warm tier through the block-major B-skiplist planes
(`core.layout.bskiplist_layout` — lane-width 128-key fat nodes, one
whole-block compare per descent step) instead of the level-major
fan-out-4 stack, on the fused AND unfused paths. Like `fused`, it is an
execution knob: results, the full residency pytree, and the metrics
plane are bit-identical across layouts (`tests/test_bskiplist.py`).

Registered configurations (see `store.api`): `hash+skiplist` (2-tier,
policy `none` — unchanged semantics), `tiered3`, `tiered3/lru`,
`tiered3/size` (3-tier), `tiered3/b128` (3-tier, block-major warm walks).
Any depth/policy combination can be constructed
directly: `TieredBackend(depth=2, policy="lru")`. Capacity sizing: the warm
tier holds `capacity` entries and (depth 3) the spill runs another
`spill_cap` (default `capacity`), so policy-driven demotion always has
somewhere to put a victim until the whole stack is genuinely full.
See docs/tiers.md for the architecture walkthrough and a worked example.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import det_skiplist as dsl
from repro.core import hashtable as ht
from repro.core.bits import EMPTY, KEY_INF, dup_in_run
from repro.core.layout import (SpillLayout, hash_slot, policy_arrays,
                               spill_arrays)
from repro.kernels.tier_apply.ref import hot_insert_evict
from repro.kernels.tier_find.ref import spill_find_runs, spill_run_cells
from repro.store import exec as exec_
from repro.store import obs
from repro.store.api import (OP_DELETE, OP_FIND, OP_INSERT, OpPlan,
                             get_backend, register, uniform_stats)
from repro.store.backends import _pow2, finalize_results

POLICIES = ("none", "lru", "size")


class SpillTier(NamedTuple):
    """Cold host-spill tier: append-only sorted runs (`core.layout.
    spill_arrays`). Each batch that demotes past the warm tier appends ONE
    sorted run; `run_start[i]` marks run boundaries, `dead` tombstones
    entries deleted or promoted away, `n` is the append cursor. Cells below
    `n` are never rewritten — the append-only contract that lets the region
    live off-device."""
    keys: jnp.ndarray       # [S] uint64, KEY_INF pad
    vals: jnp.ndarray       # [S] uint64
    dead: jnp.ndarray       # [S] bool tombstones
    run_start: jnp.ndarray  # [S] bool — True at the first entry of each run
    n: jnp.ndarray          # scalar int32 append cursor
    n_dead: jnp.ndarray     # scalar int32


def spill_init(capacity: int) -> SpillTier:
    keys, vals, dead, run_start = spill_arrays(capacity)
    return SpillTier(keys=keys, vals=vals, dead=dead, run_start=run_start,
                     n=jnp.int32(0), n_dead=jnp.int32(0))


def spill_append(sp: SpillTier, keys, vals, mask):
    """Append the masked lanes as ONE sorted run (in-batch duplicates keep
    the first lane). Lanes past capacity are dropped (appended=False) — the
    whole stack is full at that point, the flat backend's
    allocation-failure analogue. Returns (sp', appended[K])."""
    K = keys.shape[0]
    S = sp.keys.shape[0]
    mask = mask & (keys != KEY_INF)
    order = jnp.argsort(keys, stable=True)
    sk, sv, sm = keys[order], vals[order], mask[order]
    same = jnp.concatenate([jnp.zeros((1,), bool), sk[1:] == sk[:-1]])
    put = sm & ~dup_in_run(same, sm)
    rank = jnp.cumsum(put.astype(jnp.int32)) - 1
    ok = put & (sp.n + rank < S)
    dest = jnp.where(ok, sp.n + rank, S)
    nk = sp.keys.at[dest].set(sk, mode="drop")
    nv = sp.vals.at[dest].set(sv, mode="drop")
    cnt = jnp.sum(ok).astype(jnp.int32)
    rs = sp.run_start.at[jnp.where(cnt > 0, sp.n, S)].set(True, mode="drop")
    inv = jnp.zeros((K,), jnp.int32).at[order].set(
        jnp.arange(K, dtype=jnp.int32))
    obs.record("spill_appends", lambda: jnp.sum(ok))
    return sp._replace(keys=nk, vals=nv, run_start=rs, n=sp.n + cnt), ok[inv]


def spill_find_ref(sp: SpillTier, queries):
    """Membership probe over the live run entries: (found[Q], vals[Q]).
    The jnp reference behind `store.exec.spill_find` — a per-run binary
    search over the `run_offsets` boundaries
    (`kernels.tier_find.ref.spill_find_runs`), O(runs * log run-len)
    instead of the old O(S) masked flat compare, so every exec mode AND
    the fused tier-find kernel share one cold-tier algorithm."""
    return spill_find_runs(sp.keys, sp.vals, sp.dead, sp.run_start, sp.n,
                           queries)


def spill_compact(sp: SpillTier) -> SpillTier:
    """Merge the runs: drop tombstones and rewrite the live entries as ONE
    sorted run (the batched analogue of an LSM run merge). Triggered by
    `apply` when tombstones exceed 1/4 of the appended entries — the same
    threshold discipline as the skiplist's compaction — so churn cannot
    exhaust the spill capacity while live occupancy is low. Between
    compactions the append-only contract holds unchanged."""
    live = ~sp.dead & (sp.keys != KEY_INF)
    skey = jnp.where(live, sp.keys, KEY_INF)
    o = jnp.argsort(skey)
    n_live = jnp.sum(live).astype(jnp.int32)
    return SpillTier(
        keys=skey[o],
        vals=jnp.where(live, sp.vals, jnp.uint64(0))[o],
        dead=jnp.zeros_like(sp.dead),
        run_start=jnp.zeros_like(sp.run_start).at[0].set(n_live > 0),
        n=n_live, n_dead=jnp.int32(0))


def spill_discard(sp: SpillTier, keys, mask):
    """Tombstone live matches (used by DELETE and by promotion). The cell
    lookup is the same per-run binary search as the membership probe
    (`spill_run_cells` — the update path shares the find path's O(runs *
    log run-len) algorithm, not the old flat compare). In-batch duplicate
    lanes for one key dedupe by cell so `n_dead` stays exact.
    Returns (sp', hit[K])."""
    K = keys.shape[0]
    S = sp.keys.shape[0]
    hit, at = spill_run_cells(sp.keys, sp.dead, sp.run_start, sp.n, keys)
    found = hit & mask & (keys != KEY_INF)
    cell = jnp.where(found, at.astype(jnp.int32), S)
    o = jnp.argsort(cell, stable=True)
    cs = cell[o]
    fdup = jnp.concatenate([jnp.zeros((1,), bool),
                            cs[1:] == cs[:-1]]) & found[o]
    inv = jnp.zeros((K,), jnp.int32).at[o].set(jnp.arange(K, dtype=jnp.int32))
    eff = found & ~fdup[inv]
    nd = sp.dead.at[jnp.where(eff, cell, S)].set(True, mode="drop")
    return sp._replace(dead=nd,
                       n_dead=sp.n_dead + jnp.sum(eff).astype(jnp.int32)), eff


def spill_maintain(sp: SpillTier) -> SpillTier:
    """Run-merging maintenance, applied at the end of every `apply`/`flush`
    that carries a spill tier. Compacts when tombstones pass
    1/`SpillLayout.COMPACT_DEAD_FRAC` of the appended total (the churn
    rule) OR when the live run count could exceed `SpillLayout.MAX_RUNS`
    next batch (one apply appends at most `SpillLayout.RUNS_PER_APPLY`
    runs: eviction demotes, insert overflow, promotion demotes). The
    thresholds live on `core.layout.SpillLayout` — the SAME class the
    probe kernels size their boundary plane from — so the compaction
    policy and the layout's static-shape assumptions cannot drift apart.
    The second trigger is what makes the run cap an INVARIANT — and the
    cap is what gives the per-run probe (jnp and the fused kernel alike)
    a static run-boundary plane to binary-search."""
    churn = sp.n_dead * SpillLayout.COMPACT_DEAD_FRAC > sp.n
    runs = jnp.sum(sp.run_start.astype(jnp.int32))
    return jax.lax.cond(
        churn | (runs + SpillLayout.RUNS_PER_APPLY > SpillLayout.MAX_RUNS),
        spill_compact, lambda s: s, sp)


def _pin_spill_host(sp: SpillTier) -> SpillTier:
    """Best-effort placement of the spill planes in pinned host memory —
    the append-only layout was built for exactly this (cells below the
    cursor move only in bulk). Only attempted on TPU backends that expose
    a `pinned_host` memory space; anywhere else (CPU CI, older runtimes)
    it is a guarded no-op. Engines that re-device_put the whole state with
    their own sharding override the placement — this covers the direct
    single-device path."""
    try:
        dev = jax.devices()[0]
        if dev.platform != "tpu":
            return sp
        sharding = jax.sharding.SingleDeviceSharding(
            dev, memory_kind="pinned_host")
        return jax.tree.map(lambda x: jax.device_put(x, sharding), sp)
    except Exception:
        return sp


class TierState(NamedTuple):
    hot: ht.FixedHash          # fixed-slot table (the near/fast tier)
    hot_meta: jnp.ndarray      # [M, B] int32 policy metadata (stamp/weight)
    clock: jnp.ndarray         # scalar int32 — the LRU batch clock
    n_evict: jnp.ndarray       # scalar int64 — cumulative policy evictions
    n_promote: jnp.ndarray     # scalar int64 — cumulative promotions
    cold: dsl.DetSkiplist      # warm ordered tier (historic field name)
    spill: Optional[SpillTier]  # cold spill runs; None on 2-tier stacks


# The policy-driven hot insert (`hot_insert_evict`, formerly defined here)
# moved to `kernels.tier_apply.ref` so the fused apply kernel, the unfused
# `store.exec.hot_update` dispatch, and the promotion path below all share
# ONE implementation of the victim-selection lane math.


class TieredBackend:
    """The configurable tier stack behind the registry strings
    `hash+skiplist` (depth 2) and `tiered3[/lru|/size]` (depth 3)."""

    ordered = True
    kernelized = True      # fused tier find / per-tier probes -> kernels

    def __init__(self, promote: bool = True, depth: int = 2,
                 policy: str = "none", fused: bool = True,
                 warm_layout: str = "level"):
        assert depth in (2, 3), "2 (hash->skiplist) or 3 (+ host spill)"
        assert policy in POLICIES, f"policy must be one of {POLICIES}"
        assert warm_layout in ("level", "block")
        self.promote = promote
        self.depth = depth
        self.policy = policy
        self.fused = fused     # one tier_find dispatch per probe phase
        # warm probe layout: level-major fan-out-4 walk, or the block-major
        # B-skiplist (lane-width fat nodes, one whole-block compare per
        # step). An execution knob like `fused` — results, residency, and
        # the metrics plane are bit-identical either way.
        self.warm_layout = warm_layout
        base = "hash+skiplist" if depth == 2 else "tiered3"
        name = base if policy == "none" else f"{base}/{policy}"
        self.name = name + ("/b128" if warm_layout == "block" else "")

    def init(self, capacity: int, hot_bucket: int = 8, hot_frac: int = 8,
             spill_cap: int | None = None, **kw) -> TierState:
        """Warm tier sized at `capacity`; hot tier at ~capacity/hot_frac;
        depth-3 spill runs at `spill_cap` (default `capacity`)."""
        hot_slots = _pow2(max(capacity // (hot_frac * hot_bucket), 1))
        return TierState(
            hot=ht.fixed_init(hot_slots, hot_bucket),
            hot_meta=policy_arrays((hot_slots, hot_bucket)),
            clock=jnp.int32(0),
            n_evict=jnp.int64(0),
            n_promote=jnp.int64(0),
            cold=dsl.skiplist_init(capacity),
            spill=(_pin_spill_host(
                spill_init(capacity if spill_cap is None else spill_cap))
                   if self.depth == 3 else None))

    # -- tier movement helpers ----------------------------------------------

    def _demote(self, cold, spill, keys, vals, mask):
        """Push lanes down: warm skiplist first; lanes the skiplist cannot
        take (capacity) append to the spill runs (depth 3) or drop (depth 2
        — the flat backend's allocation-failure analogue)."""
        with obs.span("demote", backend=self.name):
            cold, ok_c, ex_c = dsl.insert_batch(cold, keys, vals, mask)
            ok = ok_c | ex_c
            if spill is not None:
                spill, ok_s = spill_append(spill, keys, vals, mask & ~ok)
                ok = ok | ok_s
            obs.record("demotions", lambda: jnp.sum(ok & mask))
        return cold, spill, ok

    def _record_probe_cost(self, cold, spill, queries):
        """`warm_probe_steps` / `spill_runs_searched` for ONE lower-tier
        probe phase, derived from the probe INPUTS (pre-probe tier state +
        query mask). The deterministic skiplist walk descends every level
        exactly once and the spill probe binary-searches every live run, so
        the counts are exact per probed lane — and identical on the fused
        and unfused paths by construction, since both consume the same
        inputs. The counters use the level-major walk formula for BOTH
        warm layouts: `warm_layout` is an execution knob like `fused`, and
        the metrics plane must stay bit-identical across execution knobs
        (the blocked walk's smaller step count is reported in the bench
        rows, not here)."""
        if not obs.collecting():
            return
        lanes = jnp.sum(queries != KEY_INF).astype(jnp.int64)
        obs.record("warm_probe_steps", lanes * (cold.num_levels + 1))
        if spill is not None:
            runs = jnp.sum(
                spill.run_start
                & (jnp.arange(spill.run_start.shape[0]) < spill.n)
            ).astype(jnp.int64)
            obs.record("spill_runs_searched", lanes * runs)

    def _headroom(self, cold, spill):
        """Free lower-tier slots = the eviction budget: how many hot
        victims the warm tier + spill runs can absorb RIGHT NOW. Capping
        evictions at this keeps demotion lossless — when the stack is
        genuinely full, the NEW key's lane fails (like the flat backend's
        allocation failure), never a resident's."""
        free = (jnp.int32(cold.term_keys.shape[0]) - cold.n_term)
        if spill is not None:
            free = free + (jnp.int32(spill.keys.shape[0]) - spill.n)
        return free

    # -- apply ---------------------------------------------------------------

    def apply(self, state: TierState, plan: OpPlan):
        hot, meta, clock = state.hot, state.hot_meta, state.clock
        cold, spill = state.cold, state.spill
        n_evict, n_promote = state.n_evict, state.n_promote
        ops, keys, vals = plan.ops, plan.keys, plan.vals
        K = keys.shape[0]
        valid = plan.mask & (ops >= 0)
        ins_m = valid & (ops == OP_INSERT)
        del_m = valid & (ops == OP_DELETE)
        qk = jnp.where(valid, keys, KEY_INF)

        # INSERTS: insert-if-absent across ALL tiers; lanes absent
        # everywhere try hot first (under the policy), the rest fall down.
        # Fused: membership + the whole hot-insert prologue (bucket plan,
        # victim selection) is ONE tier_apply dispatch per plan; unfused:
        # one probe dispatch per lower tier, then one hot_update dispatch.
        with obs.span("insert", backend=self.name):
            ins_k = jnp.where(ins_m, keys, KEY_INF)
            self._record_probe_cost(cold, spill, ins_k)
            if self.fused:
                (hot, meta, in_cold, in_spill, ins_hot, ex_hot,
                 ev_k, ev_v, ev_m) = exec_.tier_apply(
                    hot, meta, clock, cold, spill, keys, vals, ins_m,
                    self.policy, self._headroom(cold, spill),
                    warm_layout=self.warm_layout)
                try_hot = ins_m & ~in_cold & ~in_spill
            else:
                warm_find = (exec_.bskiplist_find
                             if self.warm_layout == "block"
                             else exec_.skiplist_find)
                in_cold, _, _ = warm_find(cold, ins_k)
                if spill is not None:
                    in_spill, _ = exec_.spill_find(spill, ins_k)
                else:
                    in_spill = jnp.zeros((K,), bool)
                try_hot = ins_m & ~in_cold & ~in_spill
                (hot, meta, ins_hot, ex_hot,
                 ev_k, ev_v, ev_m) = exec_.hot_update(
                    hot, meta, clock, keys, vals, try_hot, self.policy,
                    self._headroom(cold, spill))
            if self.policy != "none":
                n_evict = n_evict + jnp.sum(ev_m).astype(jnp.int64)
                obs.record("evictions", lambda: jnp.sum(ev_m))
                # victims demote first — the eviction cap guarantees they
                # fit, so a displaced resident is never the lane that fails
                cold, spill, _ = self._demote(cold, spill, ev_k, ev_v, ev_m)
            down = try_hot & ~ins_hot & ~ex_hot
            cold, spill, down_ok = self._demote(
                cold, spill, jnp.where(down, keys, KEY_INF), vals, down)
            inserted = ins_hot | down_ok
            existed = ex_hot | in_cold | in_spill

        # DELETES: the single-tier invariant means exactly one tier can hit
        with obs.span("delete", backend=self.name):
            hot, del_hot = ht.fixed_delete(hot, keys, del_m)
            cold, del_cold = dsl.delete_batch(cold, keys, del_m & ~del_hot)
            if spill is not None:
                spill, del_spill = spill_discard(
                    spill, keys, del_m & ~del_hot & ~del_cold)
            else:
                del_spill = jnp.zeros((K,), bool)
            deleted = del_hot | del_cold | del_spill

        # FINDS observe the post-update state of every tier. Fused: the
        # whole hot -> warm -> spill chain is ONE tier_find dispatch per
        # plan (dispatch count independent of tier depth); unfused: one
        # dispatch per tier. Either way the hot probe reports the hit
        # column so the LRU policy can refresh its stamps.
        with obs.span("find", backend=self.name):
            self._record_probe_cost(cold, spill, qk)
            if self.fused:
                ((f_hot, v_hot, c_hot), (f_cold, v_cold),
                 (f_spill, v_spill)) = exec_.tier_find(
                    hot, cold, spill, qk, warm_layout=self.warm_layout)
            else:
                warm_find = (exec_.bskiplist_find
                             if self.warm_layout == "block"
                             else exec_.skiplist_find)
                f_hot, v_hot, c_hot = exec_.hash_find_cols(hot, qk)
                f_cold, v_cold, _ = warm_find(cold, qk)
                if spill is not None:
                    f_spill, v_spill = exec_.spill_find(spill, qk)
                else:
                    f_spill = jnp.zeros((K,), bool)
                    v_spill = jnp.zeros((K,), jnp.uint64)
            # per-tier FIND attribution + hot probe collisions — all
            # derived from post-branch probe outputs and the post-update
            # hot table, so the fused and unfused paths record identical
            # counters (single-tier residency makes f_* disjoint)
            fnd_m = valid & (ops == OP_FIND)
            obs.record("hot_hits", lambda: jnp.sum(fnd_m & f_hot))
            obs.record("warm_hits", lambda: jnp.sum(fnd_m & f_cold))
            obs.record("spill_hits", lambda: jnp.sum(fnd_m & f_spill))
            obs.record("bucket_collisions",
                       lambda: obs.bucket_collision_count(hot, qk))
            found = f_hot | f_cold | f_spill
            fvals = jnp.where(f_hot, v_hot,
                              jnp.where(f_cold, v_cold, v_spill))
            if self.policy == "lru":
                touch = fnd_m & f_hot
                tslots = hash_slot(qk, hot.num_slots)
                cell = jnp.where(touch, tslots * hot.bucket + c_hot,
                                 hot.keys.size)
                meta = meta.reshape(-1).at[cell].set(
                    jnp.broadcast_to(clock, (K,)).astype(jnp.int32),
                    mode="drop").reshape(meta.shape)

        # PROMOTION (after the linearization point; membership-neutral):
        # warm/spill-served FIND lanes migrate up, displacing policy victims
        if self.promote:
            with obs.span("promote", backend=self.name):
                prom = valid & (ops == OP_FIND) & found & ~f_hot
                pv = jnp.where(f_cold, v_cold, v_spill)
                if self.policy == "none":
                    hot, prom_ok, _ = ht.fixed_insert(hot, keys, pv, prom)
                else:
                    (hot, meta, prom_ok, _,
                     ev_k, ev_v, ev_m) = hot_insert_evict(
                        hot, meta, clock, keys, pv, prom, self.policy,
                        self._headroom(cold, spill))
                    n_evict = n_evict + jnp.sum(ev_m).astype(jnp.int64)
                    obs.record("evictions", lambda: jnp.sum(ev_m))
                    cold, spill, _ = self._demote(cold, spill,
                                                  ev_k, ev_v, ev_m)
                n_promote = n_promote + jnp.sum(prom_ok).astype(jnp.int64)
                obs.record("promotions", lambda: jnp.sum(prom_ok))
                cold, _ = dsl.delete_batch(cold, keys,
                                           prom & prom_ok & f_cold)
                if spill is not None:
                    spill, _ = spill_discard(spill, keys,
                                             prom & prom_ok & f_spill)

        # spill-run maintenance: merge runs + drop tombstones at the same
        # 25% threshold discipline as the skiplist compaction (so churn
        # cannot exhaust the append cursor while live occupancy stays low)
        # and keep the live run count under the static MAX_SPILL_RUNS cap
        # the per-run probe's boundary plane is sized for
        if spill is not None:
            with obs.span("compact", backend=self.name):
                pre_dead = spill.n_dead
                spill = spill_maintain(spill)
                obs.record("tombstones_reclaimed",
                           lambda: pre_dead - spill.n_dead)

        state2 = TierState(hot=hot, hot_meta=meta, clock=clock + 1,
                           n_evict=n_evict, n_promote=n_promote,
                           cold=cold, spill=spill)
        return state2, finalize_results(ops, valid, found, fvals, inserted,
                                        existed, deleted)

    # -- ordered scan over all tiers -----------------------------------------

    def scan(self, state: TierState, lo, hi, max_out: int):
        cnt_c, k_c, v_c, val_c = dsl.range_query(state.cold, lo, hi, max_out)

        def tier_rows(tk, tv, live):
            """In-range count + per-query sorted top-max_out of a flat
            (keys, vals, live) tier view."""
            in_r = (tk[None, :] >= lo[:, None]) & (tk[None, :] < hi[:, None]) \
                & live[None, :]
            cnt = jnp.sum(in_r, axis=1).astype(cnt_c.dtype)
            sk = jnp.where(in_r, tk[None, :], KEY_INF)
            o = jnp.argsort(sk, axis=1)[:, :max_out]
            return (cnt, jnp.take_along_axis(sk, o, axis=1),
                    jnp.take_along_axis(
                        jnp.broadcast_to(tv[None, :], sk.shape), o, axis=1))

        hk = state.hot.keys.reshape(-1)
        cnt_h, hkeys, hvals = tier_rows(hk, state.hot.vals.reshape(-1),
                                        hk != EMPTY)
        count = cnt_c + cnt_h
        parts_k = [jnp.where(val_c, k_c, KEY_INF), hkeys]
        parts_v = [jnp.where(val_c, v_c, jnp.uint64(0)), hvals]
        if state.spill is not None:
            sp = state.spill
            cnt_s, skeys, svals = tier_rows(sp.keys, sp.vals,
                                            ~sp.dead & (sp.keys != KEY_INF))
            count = count + cnt_s
            parts_k.append(skeys)
            parts_v.append(svals)

        # materialize the sorted union, truncated at max_out (single-tier
        # residency means the union has no cross-tier duplicates)
        allk = jnp.concatenate(parts_k, axis=1)
        allv = jnp.concatenate(parts_v, axis=1)
        om = jnp.argsort(allk, axis=1)[:, :max_out]
        keys = jnp.take_along_axis(allk, om, axis=1)
        vals = jnp.take_along_axis(allv, om, axis=1)
        return count, keys, vals, keys != KEY_INF

    # -- movement / stats ----------------------------------------------------

    def flush(self, state: TierState) -> TierState:
        """Bulk demotion: move every hot entry into the warm tier (spill
        runs absorb warm overflow on depth 3). Entries the lower tiers
        cannot absorb (stack genuinely full) STAY hot with their metadata —
        demotion is lossless, same invariant as eviction. Flushed cells'
        policy metadata is cleared with the keys; the batch clock and the
        cumulative eviction / promotion counters are PRESERVED — flushing
        the tier must not erase the policy's history (the
        hot-tier-exactly-full audit)."""
        with obs.span("flush", backend=self.name):
            shape = state.hot.keys.shape
            hk = state.hot.keys.reshape(-1)
            hv = state.hot.vals.reshape(-1)
            cold, spill, ok = self._demote(state.cold, state.spill, hk, hv,
                                           hk != EMPTY)
            if spill is not None:   # keep the run count under the static cap
                with obs.span("compact", backend=self.name):
                    pre_dead = spill.n_dead
                    spill = spill_maintain(spill)
                    obs.record("tombstones_reclaimed",
                               lambda: pre_dead - spill.n_dead)
            keep = (hk != EMPTY) & ~ok
            hot = state.hot._replace(
                keys=jnp.where(keep, hk, EMPTY).reshape(shape),
                vals=jnp.where(keep, hv, jnp.uint64(0)).reshape(shape),
                count=jnp.sum(keep).astype(jnp.int64))
            meta = jnp.where(keep.reshape(shape), state.hot_meta, 0)
        return state._replace(hot=hot, hot_meta=meta, cold=cold, spill=spill)

    def stats(self, state: TierState):
        hot_size = state.hot.count.astype(jnp.int64)
        cold_size = (state.cold.n_term - state.cold.n_marked).astype(jnp.int64)
        spill_size = jnp.int64(0)
        spill_dead = jnp.int64(0)
        capacity = state.hot.keys.size + state.cold.term_keys.shape[0]
        if state.spill is not None:
            spill_size = (state.spill.n - state.spill.n_dead).astype(jnp.int64)
            spill_dead = state.spill.n_dead.astype(jnp.int64)
            capacity += state.spill.keys.shape[0]
        return uniform_stats(
            size=hot_size + cold_size + spill_size,
            hot_size=hot_size,
            cold_size=cold_size,
            spill_size=spill_size,
            tombstones=state.cold.n_marked + spill_dead,
            evictions=state.n_evict,
            promotions=state.n_promote,
            capacity=capacity)


def unfused_twin(name: str) -> TieredBackend:
    """A `fused=False` twin of a registered tier config — same depth,
    policy, and promotion, probing through the original dispatch-per-tier
    chain. The single source for what the parity suites and the
    fused-vs-unfused bench rows compare the fused path against."""
    be = get_backend(name)
    assert isinstance(be, TieredBackend), f"{name!r} is not a tier stack"
    return TieredBackend(promote=be.promote, depth=be.depth,
                         policy=be.policy, fused=False,
                         warm_layout=be.warm_layout)


HASH_SKIPLIST = register(TieredBackend())
TIERED3 = register(TieredBackend(depth=3))
TIERED3_LRU = register(TieredBackend(depth=3, policy="lru"))
TIERED3_SIZE = register(TieredBackend(depth=3, policy="size"))
TIERED3_B128 = register(TieredBackend(depth=3, warm_layout="block"))
