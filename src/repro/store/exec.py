"""Execution-mode dispatch: the layer between layouts and the Store API.

Every backend's FIND/probe phase calls through here, so the probe
implementation is swappable without touching backend or tier logic. Three
interchangeable modes, selected by config string
(`configs/*.py: store_exec`, or the `REPRO_STORE_EXEC` env default):

  jnp        pure-jnp reference probes (`core.det_skiplist.find_batch`,
             `core.hashtable.fixed_find`, ...) — the portable baseline
  interpret  Pallas kernels in interpreter mode — the kernel bodies execute
             on CPU; what CI runs
  pallas     Pallas kernels compiled (TPU) — the production hot path

The correctness contract is BIT-IDENTICAL results across all three modes
for every backend (asserted by tests/test_exec_modes.py): the kernels
consume the same `core.layout` shapes the references do and use the same
comparisons, so parity is by construction, and mode choice is purely a
performance knob.

Kernelized probes: the deterministic skiplist search
(`kernels.skiplist_search`; its block-major B-skiplist twin
`kernels.bskiplist_walk`, dispatched by `bskiplist_find` — lane-width fat
nodes, one whole-block compare per step), the fixed-hash bucket probe
(`kernels.hash_probe` — also the §IX hot-tier fast path), the FUSED
tier-stack find (`kernels.tier_find` — hot probe + warm walk + per-run
spill search in ONE pallas_call, dispatched by `tier_find`), the
two-level split-order per-table searchsorted (`kernels.splitorder_probe`),
and the priority-queue pop rank-select (`kernels.pq_pop`, dispatched by
`pq_pop` — live-prefix cumsum + the shared `level_walk` descent).
Probes whose access pattern defeats the static-shape or VMEM premise (the
randomized skiplist's MAX_GAP-padded walk, ONE-level split-order's
searchsorted over the full array — the global array does not fit VMEM,
which is why only the two-level variant kernelizes — and the two-level
hash table's pooled L2 indirection) fall back to their jnp reference in
every mode — still routed through this module so a future kernel is a
one-function change.

The mode is read at TRACE time: `StoreEngine`/`make_store_step` bake it
into the jitted step via `exec_mode(...)`, so two engines with different
modes coexist; flipping the module default after a step is traced does not
retrace it.

Every entry here counts as ONE dispatch (`dispatch_count()` /
`measure_dispatches()`), split by kind: probes ("probe" — the read-only
FIND/membership launches) and updates ("update" — the write-path launches:
`hot_update`, the fused `tier_apply`). The counter ticks when the entry is
TRACED, which is exactly once per launch in the compiled step — the unit
the fused tier find/apply kernels exist to minimize. Benchmarks and the
fused-path tests read it to report dispatches per plan, probe and update
halves separately. Meters are CONTEXT-LOCAL and NESTABLE (see
`measure_dispatches`); each dispatch also opens an `obs.span("find", ...)`
or `obs.span("update", ...)` so trace exports attribute lowering cost per
entry.
"""
from __future__ import annotations

import functools
import os
from contextlib import contextmanager
from contextvars import ContextVar

MODES = ("jnp", "interpret", "pallas")


def _check(mode: str) -> str:
    if mode not in MODES:
        raise ValueError(f"unknown store exec mode {mode!r}; one of {MODES}")
    return mode


# REPRO_STORE_EXEC: the process-wide default execution mode, read ONCE at
# import ("jnp" | "interpret" | "pallas"; default "jnp"). CI re-runs the
# kernel suites with REPRO_STORE_EXEC=interpret; `set_mode`/`exec_mode()`
# override it per call site, and `StoreEngine(exec_mode=...)` bakes an
# explicit mode into its jitted step regardless of this default.
_mode = _check(os.environ.get("REPRO_STORE_EXEC", "jnp"))


def get_mode() -> str:
    return _mode


def set_mode(mode: str) -> None:
    global _mode
    _mode = _check(mode)


@contextmanager
def exec_mode(mode: str | None):
    """Scoped mode override (None = keep the current mode). Wrap the TRACE
    of a jitted step to bake the mode in."""
    global _mode
    prev = _mode
    if mode is not None:
        _mode = _check(mode)
    try:
        yield
    finally:
        _mode = prev


def _resolve(mode: str | None) -> str:
    return _check(mode) if mode is not None else _mode


_PALLAS_OK: bool | None = None


def pallas_available() -> bool:
    """True iff COMPILED Pallas kernels run on the current jax backend
    (TPU; CPU/GPU get interpret mode only). Probed once with a tiny kernel
    launch; tests and benchmarks use this to scope the `pallas` mode."""
    global _PALLAS_OK
    if _PALLAS_OK is None:
        try:
            import jax
            import jax.numpy as jnp
            from repro.kernels.hash_probe.kernel import hash_probe_tiles
            z32 = jnp.zeros((8,), jnp.uint32)
            out = hash_probe_tiles(z32, z32, z32.astype(jnp.int32),
                                   jnp.zeros((4, 8), jnp.uint32),
                                   jnp.zeros((4, 8), jnp.uint32),
                                   tile=8, interpret=False)
            jax.block_until_ready(out)
            _PALLAS_OK = True
        except Exception:
            _PALLAS_OK = False
    return _PALLAS_OK


def runnable_modes() -> tuple:
    """The execution modes that can actually run here (drops `pallas` off
    TPU) — what parity tests and benchmarks iterate over."""
    return MODES if pallas_available() else tuple(m for m in MODES
                                                  if m != "pallas")


# ---------------------------------------------------------------------------
# dispatch accounting (context-local, nestable)
# ---------------------------------------------------------------------------

# dispatches split by KIND: "probe" (read-only FIND/membership launches)
# and "update" (write-path launches: the hot-tier insert prologue, the
# fused tier-apply). The split is what lets the fused-vs-unfused bench
# rows report dispatches_per_apply for each half of an apply.
DISPATCH_KINDS = ("probe", "update")

_n_dispatch = 0
_n_by_kind = {"probe": 0, "update": 0}

# the active meter stack lives in a ContextVar, so meters are CONTEXT-LOCAL:
# concurrent traces (threads, async tasks) each see only their own probes,
# and nested `measure_dispatches()` blocks compose instead of sharing one
# global start offset
_METERS: ContextVar[tuple] = ContextVar("repro_exec_meters", default=())


def _bump(kind: str = "probe") -> None:
    global _n_dispatch
    _n_dispatch += 1
    _n_by_kind[kind] += 1
    for meter in _METERS.get():
        meter._n += 1
        if kind == "probe":
            meter._probe += 1
        else:
            meter._update += 1


def dispatch_count(kind: str | None = None) -> int:
    """Cumulative dispatches issued through this module in this process
    (counted at trace time — one tick = one launch in the traced step).
    `kind=None` returns the total; `"probe"` / `"update"` return one half
    of the split (probe = FIND/membership launches, update = write-path
    launches). Monotone; see `reset_dispatch_count` for the reset
    semantics. For scoped counts prefer `measure_dispatches`."""
    if kind is None:
        return _n_dispatch
    if kind not in DISPATCH_KINDS:
        raise ValueError(f"unknown dispatch kind {kind!r}; "
                         f"one of {DISPATCH_KINDS}")
    return _n_by_kind[kind]


def reset_dispatch_count() -> None:
    """Zero the process-cumulative `dispatch_count()` (total and both
    kinds). Reset semantics: only the global totals are affected — active
    `measure_dispatches` meters count INCREMENTS (not offsets against the
    global), so a reset inside a measured block neither corrupts nor
    rewinds any meter."""
    global _n_dispatch
    _n_dispatch = 0
    for k in DISPATCH_KINDS:
        _n_by_kind[k] = 0


class DispatchMeter:
    """Live dispatch counter for one `measure_dispatches` block. `n` is
    valid DURING the block (live count so far) and after it (final count),
    with the probe/update split exposed as `.probe` / `.update`
    (`n == probe + update`); every dispatch traced in the block ticks this
    meter AND any enclosing ones, so nested blocks see their own totals
    and outer blocks include inner activity."""

    __slots__ = ("_n", "_probe", "_update")

    def __init__(self):
        self._n = 0
        self._probe = 0
        self._update = 0

    @property
    def n(self) -> int:
        return self._n

    @property
    def probe(self) -> int:
        return self._probe

    @property
    def update(self) -> int:
        return self._update


@contextmanager
def measure_dispatches():
    """Count the probe dispatches traced inside the block:

    >>> with measure_dispatches() as m:
    ...     backend.apply(state, plan)        # or jax.make_jaxpr(...)
    >>> m.n                                   # dispatches per plan

    Context-local and nestable: an inner `with measure_dispatches()` block
    keeps its own total while still contributing to the outer meter, and
    meters in other threads/contexts never observe this block's probes.
    """
    meter = DispatchMeter()
    token = _METERS.set(_METERS.get() + (meter,))
    try:
        yield meter
    finally:
        _METERS.reset(token)


def _probe(fn):
    """Shared probe-entry decorator: one dispatch tick + one
    `obs.span("find", probe=<name>)` per entry (the span records lowering
    wall time when a tracer is installed and names the scope for
    `jax.profiler` either way)."""
    from repro.store import obs

    @functools.wraps(fn)
    def wrapped(*args, **kw):
        _bump("probe")
        with obs.span("find", cat="dispatch", probe=fn.__name__):
            return fn(*args, **kw)
    return wrapped


def _update(fn):
    """Write-path twin of `_probe`: one "update"-kind dispatch tick + one
    `obs.span("update", probe=<name>)` per entry. Update dispatches are the
    half of an apply the fused tier-apply kernel collapses; the split
    counters are what the fused-vs-unfused bench rows report."""
    from repro.store import obs

    @functools.wraps(fn)
    def wrapped(*args, **kw):
        _bump("update")
        with obs.span("update", cat="dispatch", probe=fn.__name__):
            return fn(*args, **kw)
    return wrapped


# ---------------------------------------------------------------------------
# kernelized probes
# ---------------------------------------------------------------------------

@_probe
def skiplist_find(s, queries, mode: str | None = None):
    """Deterministic-skiplist FIND: (found[Q], vals[Q], term_idx[Q])."""
    m = _resolve(mode)
    if m == "jnp":
        from repro.core import det_skiplist as dsl
        return dsl.find_batch(s, queries)
    from repro.kernels.skiplist_search.ops import skiplist_find as sk_find
    return sk_find(s, queries, interpret=(m == "interpret"))


@_probe
def bskiplist_find(s, queries, mode: str | None = None):
    """Deterministic-skiplist FIND through the block-major B-skiplist view
    (`core.layout.bskiplist_layout`): same (found[Q], vals[Q], term_idx[Q])
    contract and bit-identical found/vals as `skiplist_find`, but the walk
    compares one lane-width fat node (128 sorted keys) per step instead of
    a fan-out-4 gather — `tiered3/b128`'s warm probe. The layout, like the
    mode, is a probe-execution knob over unchanged state."""
    m = _resolve(mode)
    if m == "jnp":
        from repro.core import det_skiplist as dsl
        return dsl.find_batch_blocked(s, queries)
    from repro.kernels.bskiplist_walk.ops import bskiplist_find as bsk_find
    return bsk_find(s, queries, interpret=(m == "interpret"))


@_probe
def pq_pop(s, ranks, mask, mode: str | None = None):
    """Priority-queue rank-select on a DetSkiplist: the rank-th smallest
    live key per lane. Returns (found[K], keys[K] u64, idx[K] i32) — a pure
    read; the pq backend commits the extraction with `pop_mark`. Both paths
    apply identical not-found masking (keys=KEY_INF, idx=0), so results are
    bit-identical across modes including the miss lanes."""
    m = _resolve(mode)
    if m == "jnp":
        from repro.core import det_skiplist as dsl
        return dsl.pop_rank_select(s, ranks, mask)
    from repro.kernels.pq_pop.ops import pq_pop_ranks
    return pq_pop_ranks(s, ranks, mask, interpret=(m == "interpret"))


@_probe
def hash_find(h, queries, mode: str | None = None):
    """Fixed-slot hash probe: (found[Q], vals[Q]). The §IX hot-tier path."""
    m = _resolve(mode)
    if m == "jnp":
        from repro.core import hashtable as ht
        return ht.fixed_find(h, queries)
    from repro.kernels.hash_probe.ops import fixed_hash_find
    return fixed_hash_find(h, queries, interpret=(m == "interpret"))


@_probe
def hash_find_cols(h, queries, mode: str | None = None):
    """Fixed-slot hash probe that also reports the hit column:
    (found[Q], vals[Q], col[Q] i32). This is the policy-aware form of the
    hot-tier probe: the column is what lets an eviction policy refresh its
    per-entry metadata plane (`core.layout.policy_arrays`) after a hit —
    LRU-by-batch stamps the batch clock at [slot, col]. Both the jnp
    reference and the Pallas kernel derive the column with the same
    first-match argmax over the bucket row, so metadata stays bit-identical
    across modes (col of a miss is unspecified; callers mask by `found`)."""
    m = _resolve(mode)
    if m == "jnp":
        from repro.core import hashtable as ht
        return ht.fixed_find_cols(h, queries)
    from repro.kernels.hash_probe.ops import fixed_hash_find_cols
    return fixed_hash_find_cols(h, queries, interpret=(m == "interpret"))


# ---------------------------------------------------------------------------
# reference-only probes (routed here so kernelizing one is a local change)
# ---------------------------------------------------------------------------

@_probe
def rand_skiplist_find(s, queries, mode: str | None = None):
    """Randomized-skiplist FIND — jnp in every mode (the MAX_GAP-padded walk
    has no static-shape kernel win; see docs/store_layers.md)."""
    _resolve(mode)
    from repro.core import rand_skiplist as rsl
    return rsl.find_batch(s, queries)


@_probe
def twolevel_hash_find(h, queries, mode: str | None = None):
    """Two-level hash FIND — jnp in every mode (pooled L2 indirection)."""
    _resolve(mode)
    from repro.core import hashtable as ht
    return ht.twolevel_find(h, queries)


@_probe
def splitorder_find(h, queries, mode: str | None = None):
    """ONE-level split-order FIND — jnp in every mode: its searchsorted
    runs over the single global [C] array, which does not fit VMEM at
    production capacity (the two-level variant is the kernelized one)."""
    _resolve(mode)
    from repro.core import splitorder as so
    return so.splitorder_find(h, queries)


@_probe
def twolevel_splitorder_find(h, queries, mode: str | None = None):
    """Two-level split-order FIND: per-table searchsorted over the
    [T, C2] two-level layout (`kernels.splitorder_probe` under
    interpret/pallas — each probe touches one small table row, so the
    whole plane stack is VMEM-resident, unlike the one-level variant)."""
    m = _resolve(mode)
    if m == "jnp":
        from repro.core import splitorder as so
        return so.twolevel_splitorder_find(h, queries)
    from repro.kernels.splitorder_probe.ops import twolevel_splitorder_probe
    return twolevel_splitorder_probe(h, queries, interpret=(m == "interpret"))


@_probe
def spill_find(sp, queries, mode: str | None = None):
    """Cold spill-tier membership probe: (found[Q], vals[Q]). jnp in every
    mode — since the fused tier find, a per-run binary search over the
    `run_offsets` boundaries (`kernels.tier_find.ref.spill_find_runs`,
    O(runs * log run-len); the old flat masked compare is gone from every
    path). Standalone spill probes only run on the UNFUSED chain — the
    fused path folds this search into the single `tier_find` dispatch —
    so the cold tier keeps no dedicated kernel of its own."""
    _resolve(mode)
    from repro.store.tiers import spill_find_ref
    return spill_find_ref(sp, queries)


@_probe
def tier_find(hot, cold, spill, queries, mode: str | None = None,
              warm_layout: str = "level"):
    """FUSED tier-stack FIND — the whole hot -> warm -> cold chain as ONE
    dispatch per plan (`kernels.tier_find`): VMEM bucket probe, warm
    skiplist walk (level-major fan-out-4, or the block-major B-skiplist
    walk when `warm_layout="block"` — same results, fewer steps), per-run
    searchsorted over the spill boundaries. Returns
    ((hot found, vals, col), (warm found, vals), (spill found, vals)) with
    miss FALL-THROUGH applied: a warm hit only counts on a hot miss, a
    spill hit only on a hot+warm miss (under single-tier residency the
    masking never changes a result — it encodes the fall-through contract).
    `spill=None` (2-tier stacks) yields all-miss spill results. The hot
    `col` feeds the LRU policy's stamp refresh, same as `hash_find_cols`.
    Bit-identical to the unfused three-dispatch chain in every mode."""
    m = _resolve(mode)
    if m == "jnp":
        from repro.kernels.tier_find.ref import tier_find_ref
        hot_r, warm_r, sp_r = tier_find_ref(hot, cold, spill, queries,
                                            warm_layout=warm_layout)
    else:
        from repro.kernels.tier_find.ops import tier_find_fused
        hot_r, warm_r, sp_r = tier_find_fused(hot, cold, spill, queries,
                                              warm_layout=warm_layout,
                                              interpret=(m == "interpret"))
    import jax.numpy as jnp
    f_hot, v_hot, c_hot = hot_r
    f_warm, v_warm = warm_r
    f_sp, v_sp = sp_r
    f_warm = f_warm & ~f_hot
    f_sp = f_sp & ~f_hot & ~f_warm
    # a masked-off lane's value stays zero (the shared miss convention)
    return ((f_hot, v_hot, c_hot),
            (f_warm, jnp.where(f_warm, v_warm, jnp.uint64(0))),
            (f_sp, jnp.where(f_sp, v_sp, jnp.uint64(0))))


# ---------------------------------------------------------------------------
# update dispatches (the write half of an apply)
# ---------------------------------------------------------------------------

@_update
def hot_update(hot, meta, clock, keys, vals, mask, policy, max_evict,
               mode: str | None = None):
    """Hot-tier insert prologue as ONE counted update dispatch — the
    UNFUSED write path (membership probes already ran). jnp in every mode:
    the sort/scatter prologue (`bucket_insert_plan` + victim selection) is
    gather/scatter-bound with no kernel win of its own; the fused
    `tier_apply` is the kernelized form. Returns
    (hot', meta', ins[K], exists[K], ev_key[K], ev_val[K], ev_mask[K]);
    for `policy == "none"` the eviction lanes are all-miss zeros and meta
    passes through unchanged."""
    _resolve(mode)
    import jax.numpy as jnp
    from repro.kernels.tier_apply.ref import hot_insert_evict
    if policy == "none":
        from repro.core import hashtable as ht
        hot2, ins, exists = ht.fixed_insert(hot, keys, vals, mask)
        k = keys.shape[0]
        return (hot2, meta, ins, exists,
                jnp.zeros((k,), jnp.uint64), jnp.zeros((k,), jnp.uint64),
                jnp.zeros((k,), bool))
    return hot_insert_evict(hot, meta, clock, keys, vals, mask,
                            policy, max_evict)


@_update
def tier_apply(hot, meta, clock, cold, spill, keys, vals, mask, policy,
               max_evict, mode: str | None = None,
               warm_layout: str = "level"):
    """FUSED tier-stack APPLY prologue — membership probes + the hot-tier
    insert plan + victim selection as ONE dispatch per plan
    (`kernels.tier_apply`): the `tier_find` probe chain (bucket probe,
    warm walk in the selected `warm_layout` — level-major or the blocked
    B-skiplist — per-run spill search with the `run_offsets` plane
    scalar-prefetched so spill chunks stream through VMEM), then the
    sorted insert prologue (dup/exists/candidate lanes, nth-empty column,
    eviction-rank victim selection off the policy metadata plane) inside
    the same launch; the u64 scatters commit in the glue. Returns
    (hot', meta', in_warm[K], in_spill[K], ins[K], exists[K],
    ev_key[K], ev_val[K], ev_mask[K]) — `in_warm`/`in_spill` carry the
    same fall-through masking as `tier_find`, so the caller's demote
    routing sees identical lanes fused and unfused. `spill=None` (2-tier
    stacks) yields all-miss spill lanes. Bit-identical to the unfused
    probes + `hot_update` chain in every mode."""
    m = _resolve(mode)
    if m == "jnp":
        from repro.kernels.tier_apply.ref import tier_apply_ref
        return tier_apply_ref(hot, meta, clock, cold, spill, keys, vals,
                              mask, policy, max_evict,
                              warm_layout=warm_layout)
    from repro.kernels.tier_apply.ops import tier_apply_fused
    return tier_apply_fused(hot, meta, clock, cold, spill, keys, vals,
                            mask, policy, max_evict,
                            warm_layout=warm_layout,
                            interpret=(m == "interpret"))
