"""Deterministic store observability: in-array metrics + host trace spans.

The source paper's whole argument rests on *measured* memory behavior —
page faults, cache misses, remote-node accesses per NUMA hop. This module
is the reproduction's measurement substrate, in two planes:

**Plane 1 — in-array metrics.** `METRICS_SCHEMA` names deterministic int64
counters that are accumulated INSIDE `apply` (per-tier hit/miss, bucket
collisions, probe steps, eviction/demotion/promotion movement, spill-run
activity, per-shard routed ops/bytes) and carried in the state pytree
(`core.layout.metrics_plane`), so they jit, shard on dim 0, and checkpoint
like any other plane. Metrics are part of the determinism story, not a side
channel: every counter is computed on the u64 host path from probe INPUTS
and OUTPUTS (never inside a kernel body), so the `metrics()` pytree is held
to the SAME cross-exec-mode and cross-sharding bit-identity contract as
results — asserted by tests/test_obs.py and the METRICS-OK lane of
tests/multidev/store_prog.py.

Enable by wrapping any registered backend: ``get_backend("obs:tiered3/lru")``
returns an `ObservedStore` whose state is ``ObservedState(inner, metrics)``
and whose `metrics(state)` accessor returns the counter dict. Instrumented
modules (`store/backends.py`, `store/tiers.py`, `store/engine.py`) call
`record(name, thunk)` at the accumulation points; with NO collection frame
active (i.e. every un-wrapped backend) `record` returns before evaluating
the thunk, so observability off costs nothing — the acceptance bar is < 5%
apply wall time vs the uninstrumented baseline (`BENCH_tiers.json` carries
an ``/obs`` row documenting the enabled cost too).

**Plane 2 — host trace spans.** `span(name, **args)` records wall-clock
spans into a context-local `Tracer` (installed with `tracing()`), and
always enters `jax.named_scope` so the same names annotate the lowered
HLO/Pallas kernels for `jax.profiler` timelines. The span taxonomy
(`SPAN_TAXONOMY`) covers the engine step ("route"/"step"), the exec
dispatch layer ("find" for probe dispatches, "update" for state-writing
dispatches, with the entry name as an arg), the tier stack's
apply phases ("insert"/"delete"/"demote"/"promote"/"compact"/"flush"), and
the serving engine's host loop ("admit"/"prefill"/"decode"). Spans around
TRACED code measure trace/lowering time (they fire once per compilation);
spans around host loops (engine step, serving admit/decode) measure real
per-batch wall time. `tools/trace_export.py` exports a Tracer as
Chrome-trace JSON that loads in Perfetto (see docs/observability.md).

The serving engine's host-side counters (`SERVING_SCHEMA`) share the same
closed-schema discipline via `uniform_serving_metrics`, so the serving
workload reports through one glossary.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from contextvars import ContextVar
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bits import EMPTY, KEY_INF
from repro.core.layout import hash_slot, metrics_plane

# ---------------------------------------------------------------------------
# metric schema (the glossary lives in docs/observability.md; names are
# gated there by tools/check_docs.py, like backend registry strings)
# ---------------------------------------------------------------------------

METRICS_SCHEMA = (
    # plan-level (recorded by the ObservedStore wrapper for EVERY backend,
    # derived from the plan and the results contract)
    "ops_find", "ops_insert", "ops_delete",
    "find_hits", "find_misses",
    "inserts_new", "inserts_existing", "deletes_hit",
    # probe cost (store/backends.py, store/tiers.py)
    "bucket_collisions", "warm_probe_steps", "spill_runs_searched",
    # tier residency + movement (store/tiers.py)
    "hot_hits", "warm_hits", "spill_hits",
    "evictions", "demotions", "promotions",
    "spill_appends", "tombstones_reclaimed",
    # priority-queue extraction (store/pq.py): successful pop lanes and
    # pop lanes that found the queue empty
    "pops", "pop_empty",
    # engine routing, per shard (store/engine.py)
    "routed_ops", "routed_bytes",
    # fault tolerance (store/resilience/, serving deadlines/overload).
    # These six are DELIBERATELY never accumulated inside a store state:
    # the journal contract says snapshot+replay reproduces the fault-free
    # state bit for bit, and counters *about* faults cannot live inside
    # the very plane a recovery must reproduce. They are tallied by the
    # resilience layer (ResilientEngine / the scheduler's resilience
    # record) and merged into the `metrics()` READ view by
    # `merge_resilience` — still deterministic (pure functions of the
    # seeded fault plan + trace), still schema-closed, still docs-gated.
    "faults_injected", "recoveries", "replayed_ops",
    "deadline_expired", "shed", "retries",
)

# the resilience-layer subset of METRICS_SCHEMA (glossary in
# docs/resilience.md): host-tallied, merged into metrics views
RESILIENCE_SCHEMA = ("faults_injected", "recoveries", "replayed_ops",
                     "deadline_expired", "shed", "retries")


def resilience_zero() -> Dict[str, int]:
    """A zeroed host-side resilience tally (plain ints — these counters
    never enter a jitted state; see the METRICS_SCHEMA note above)."""
    return {k: 0 for k in RESILIENCE_SCHEMA}


def merge_resilience(metrics: Dict[str, Any],
                     tally: Dict[str, int]) -> Dict[str, Any]:
    """A metrics view with a host-side resilience tally folded in (values
    added to the store plane's zeros; non-resilience keys pass through)."""
    unknown = set(tally) - set(RESILIENCE_SCHEMA)
    if unknown:
        raise ValueError(f"resilience tally keys {sorted(unknown)} not in "
                         f"obs.RESILIENCE_SCHEMA")
    return {k: (v + tally[k] if k in tally else v)
            for k, v in metrics.items()}

# host-side serving-engine counters (serving/engine.py `Engine.metrics()`)
SERVING_SCHEMA = ("ring_depth", "prefix_hits", "prefix_lookups",
                  "prefix_hit_rate", "batch_fill", "decode_steps",
                  "decode_tokens")

# span names (docs/observability.md lists what each phase wraps); `span`
# accepts any name, but the instrumented modules stick to this taxonomy so
# traces from different runs line up in Perfetto
SPAN_TAXONOMY = ("route", "step", "find", "update", "insert", "delete",
                 "pop", "demote", "promote", "compact", "flush", "scan",
                 "admit", "prefill", "decode",
                 # fault tolerance (store/resilience/restore.py): wraps a
                 # quarantined shard's snapshot+journal rebuild — args carry
                 # the shard id, journal replay length, and recovery mode
                 "recover")

# bytes one routed op carries through the engine's all_to_all queues:
# key u64 + val u64 + op i32 + origin i32 (core/routing.py lane payload)
ROUTED_OP_BYTES = 24


def metrics_zero() -> Dict[str, jnp.ndarray]:
    """A zeroed metrics plane (`core.layout.metrics_plane` over the schema)."""
    return metrics_plane(METRICS_SCHEMA)


def uniform_serving_metrics(**counters) -> Dict[str, Any]:
    """Pad host-side serving counters to the closed `SERVING_SCHEMA` key set
    (the serving analogue of `api.uniform_stats`; unknown keys error so the
    schema stays closed and the docs glossary stays exhaustive)."""
    unknown = set(counters) - set(SERVING_SCHEMA)
    if unknown:
        raise ValueError(f"serving metric keys {sorted(unknown)} not in "
                         f"SERVING_SCHEMA; extend obs.SERVING_SCHEMA")
    return {k: counters.get(k, 0) for k in SERVING_SCHEMA}


# ---------------------------------------------------------------------------
# collection frames (context-local, nestable: records go to the INNERMOST
# frame, so an engine-level frame and a backend-level frame never double
# count — the engine absorbs its own frame explicitly)
# ---------------------------------------------------------------------------

class MetricsFrame:
    """Trace-time accumulator: metric name -> traced int64 scalar."""

    __slots__ = ("acc",)

    def __init__(self):
        self.acc: Dict[str, jnp.ndarray] = {}

    def add(self, name: str, value) -> None:
        if name not in METRICS_SCHEMA:
            raise ValueError(f"unknown metric {name!r}; extend "
                             f"obs.METRICS_SCHEMA (and its docs glossary)")
        v = jnp.asarray(value).astype(jnp.int64)
        self.acc[name] = self.acc[name] + v if name in self.acc else v


_FRAMES: ContextVar[tuple] = ContextVar("repro_obs_frames", default=())


@contextmanager
def collect():
    """Open a metrics frame: `record` calls inside the block accumulate into
    it. Frames nest; each `record` lands in the innermost frame only."""
    frame = MetricsFrame()
    token = _FRAMES.set(_FRAMES.get() + (frame,))
    try:
        yield frame
    finally:
        _FRAMES.reset(token)


def collecting() -> bool:
    """True iff a metrics frame is active (instrumentation sites can use it
    to skip expensive derivations, though `record` thunks already do)."""
    return bool(_FRAMES.get())


def record(name: str, value) -> None:
    """Accumulate `value` into the innermost active frame. `value` may be a
    zero-arg thunk, evaluated ONLY when a frame is active — the disabled
    path is a dict lookup and a return, so un-observed stores pay nothing
    (neither trace-time compute nor extra ops in the jaxpr)."""
    frames = _FRAMES.get()
    if not frames:
        return
    frames[-1].add(name, value() if callable(value) else value)


def merge_metrics(metrics: Dict[str, jnp.ndarray],
                  frame: MetricsFrame) -> Dict[str, jnp.ndarray]:
    """metrics plane + one frame's accumulations (schema-complete result)."""
    return {k: (metrics[k] + frame.acc[k]) if k in frame.acc else metrics[k]
            for k in METRICS_SCHEMA}


# ---------------------------------------------------------------------------
# shared metric derivations (used by backends.py and tiers.py so the flat
# fixed-hash backend and the tier stack's hot tier agree on definitions)
# ---------------------------------------------------------------------------

def bucket_collision_count(table, queries) -> jnp.ndarray:
    """Bucket collisions of one probe phase: over every probed lane (query
    != EMPTY/KEY_INF sentinel), the non-empty cells of the lane's bucket
    row that are NOT the queried key — the constant-cost analogue of the
    paper's probe-chain length. Pure function of (table state, queries), so
    identical in every exec mode by construction."""
    rows = table.keys[hash_slot(queries, table.num_slots)]      # [K, B]
    live = (queries != EMPTY) & (queries != KEY_INF)
    coll = (rows != EMPTY) & (rows != queries[:, None])
    return jnp.sum(coll & live[:, None]).astype(jnp.int64)


# ---------------------------------------------------------------------------
# the ObservedStore wrapper: any registered backend + a metrics plane
# ---------------------------------------------------------------------------

class ObservedState(NamedTuple):
    """An observed backend's state: the wrapped backend's pytree plus the
    jit-carried metrics plane."""
    inner: Any
    metrics: Dict[str, jnp.ndarray]


class ObservedStore:
    """`Store` adapter adding the in-array metrics plane to any backend.

    Constructed via the registry prefix — ``get_backend("obs:<name>")`` —
    or directly over a backend instance (e.g. an unfused
    `TieredBackend(fused=False)` twin, which is how the fused-vs-unfused
    metric parity is asserted). `apply` opens a collection frame around the
    inner apply, records the plan-level counters itself, and folds
    everything into `state.metrics`; `scan`/`stats` proxy through
    (`scan` is read-only — a pure function cannot return new counters, so
    it contributes spans only)."""

    def __init__(self, inner):
        self.inner = inner
        self.name = f"obs:{inner.name}"
        self.ordered = inner.ordered
        self.kernelized = getattr(inner, "kernelized", False)

    def init(self, capacity: int, **kw) -> ObservedState:
        return ObservedState(inner=self.inner.init(capacity, **kw),
                             metrics=metrics_zero())

    def apply(self, state: ObservedState, plan):
        with collect() as frame:
            inner2, res = self.inner.apply(state.inner, plan)
            _record_plan_metrics(plan, res)
        return (ObservedState(inner=inner2,
                              metrics=merge_metrics(state.metrics, frame)),
                res)

    def scan(self, state: ObservedState, lo, hi, max_out: int, **kw):
        # **kw forwards backend-specific scan options (e.g. the ordered
        # skiplist backends' snapshot `as_of_batch=`) untouched
        with span("scan", backend=self.inner.name):
            return self.inner.scan(state.inner, lo, hi, max_out, **kw)

    def stats(self, state: ObservedState):
        return self.inner.stats(state.inner)

    def metrics(self, state: ObservedState) -> Dict[str, jnp.ndarray]:
        """The accumulated metrics plane (schema-complete dict of int64
        scalars; untouched counters are zero, like `uniform_stats`)."""
        return dict(state.metrics)

    def flush(self, state: ObservedState) -> ObservedState:
        """Proxy of a tier stack's bulk demotion — instrumented too, so
        flush-driven demotions/spill appends land in the same counters."""
        with collect() as frame:
            inner2 = self.inner.flush(state.inner)
        return ObservedState(inner=inner2,
                             metrics=merge_metrics(state.metrics, frame))


def _record_plan_metrics(plan, res) -> None:
    """The backend-generic counters, derived from the plan and the shared
    `OpResults` encoding (FIND -> (hit, val); INSERT -> (applied, existed);
    DELETE -> (removed, 0)) — bit-identical across modes because results
    are."""
    from repro.store.api import OP_DELETE, OP_FIND, OP_INSERT
    valid = plan.mask & (plan.ops >= 0)
    find_m = valid & (plan.ops == OP_FIND)
    ins_m = valid & (plan.ops == OP_INSERT)
    del_m = valid & (plan.ops == OP_DELETE)
    record("ops_find", lambda: jnp.sum(find_m))
    record("ops_insert", lambda: jnp.sum(ins_m))
    record("ops_delete", lambda: jnp.sum(del_m))
    record("find_hits", lambda: jnp.sum(find_m & res.ok))
    record("find_misses", lambda: jnp.sum(find_m & ~res.ok))
    existed = res.vals != 0
    record("inserts_new", lambda: jnp.sum(ins_m & res.ok & ~existed))
    record("inserts_existing", lambda: jnp.sum(ins_m & res.ok & existed))
    record("deletes_hit", lambda: jnp.sum(del_m & res.ok))


def absorb_frame(state, frame) -> Any:
    """Fold an EXTERNAL frame's counters (e.g. the engine's routing frame)
    into an observed state; a no-op for un-observed states, so callers can
    stay backend-agnostic."""
    if isinstance(state, ObservedState) and frame is not None and frame.acc:
        return ObservedState(inner=state.inner,
                             metrics=merge_metrics(state.metrics, frame))
    return state


# ---------------------------------------------------------------------------
# plane 2: host trace spans
# ---------------------------------------------------------------------------

class Span(NamedTuple):
    name: str
    cat: str
    ts_ns: int          # absolute perf_counter_ns at entry
    dur_ns: int
    args: Dict[str, Any]


class Tracer:
    """Span sink: append-only list of `Span`s plus the recording epoch
    (t0_ns), which `tools/trace_export.py` subtracts so trace timestamps
    start near zero."""

    def __init__(self):
        self.t0_ns = time.perf_counter_ns()
        self.spans: list[Span] = []

    def add(self, name: str, cat: str, ts_ns: int, dur_ns: int,
            args: Dict[str, Any]) -> None:
        self.spans.append(Span(name=name, cat=cat, ts_ns=ts_ns,
                               dur_ns=dur_ns, args=args))


_TRACER: ContextVar[Tracer | None] = ContextVar("repro_obs_tracer",
                                                default=None)


@contextmanager
def tracing(tracer: Tracer | None = None):
    """Install a Tracer for the block (context-local; nested `tracing`
    blocks shadow the outer tracer). Yields the tracer."""
    tr = tracer if tracer is not None else Tracer()
    token = _TRACER.set(tr)
    try:
        yield tr
    finally:
        _TRACER.reset(token)


def current_tracer() -> Tracer | None:
    return _TRACER.get()


@contextmanager
def span(name: str, cat: str = "host", **args):
    """One trace span. Always enters `jax.named_scope("obs.<name>")` so the
    phase name reaches the lowered HLO / Pallas kernels (visible in
    `jax.profiler` timelines); when a Tracer is installed (`tracing()`),
    also records wall time + args for the Chrome-trace export. Without a
    tracer the cost is one contextvar read."""
    tr = _TRACER.get()
    t0 = time.perf_counter_ns() if tr is not None else 0
    with jax.named_scope(f"obs.{name}"):
        try:
            yield
        finally:
            if tr is not None:
                tr.add(name, cat, t0, time.perf_counter_ns() - t0, args)
