"""Seeded deterministic fault injection for the store engine.

Chaos testing with the determinism turned UP instead of off: a `FaultPlan`
is a pure function of its seed, so "the run where shard 3 dies at step 5"
is a reproducible artifact, not a flake. Faults are injected at the engine
step boundary by `store.resilience.restore.ResilientEngine` (never inside a
kernel — the corruption models infrastructure failure, not miscompiled
math), and every injection is tallied in the `faults_injected` counter of
the resilience tally (`obs.RESILIENCE_SCHEMA`).

Three fault kinds (schema table in docs/resilience.md):

* ``shard_drop`` — shard `shard`'s state slice is zeroed at step `step`,
  modeling a lost NUMA node / device. Detected by the per-step health
  epoch (`state_alive`: a live store state always has nonzero leaves —
  key planes are KEY_INF-filled from init — so an all-zero slice is
  unambiguous death), then recovered from snapshot + journal.
* ``poison`` — lane `lane`'s op code is overwritten with `POISON_OP`
  (outside `api.VALID_OPS`) on the wire copy of the plan, modeling
  in-flight corruption. Detected by `sanitize_ops`; repaired by re-reading
  the write-ahead journaled intent (counted in `retries`).
* ``stall`` — a maintenance stall (e.g. spill compaction) charging `ticks`
  virtual ticks to the engine's stall clock. Determinism makes a stall
  pure latency — it cannot corrupt state — so recovery is accounting:
  the serving layer's deadline clock absorbs the ticks.

`REPRO_FAULTS=<seed>` (read by `default_seed`) re-seeds the suite-level
fault plans — the CI chaos lane runs the resilience + serving suites under
a non-default seed in interpret mode.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, List, NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.store.api import OP_NONE, VALID_OPS

# the poisoned-lane op code: far outside VALID_OPS, recognizable in dumps
POISON_OP = 113

FAULT_KINDS = ("shard_drop", "poison", "stall")


class Fault(NamedTuple):
    """One scheduled fault. `shard` is used by shard_drop, `lane` by
    poison, `ticks` by stall; the unused fields are -1/0."""
    kind: str
    step: int
    shard: int = -1
    lane: int = -1
    ticks: int = 0


class FaultPlan:
    """The deterministic fault schedule: seed in, same faults out, always.
    `at(step)` returns the faults due at an engine step (possibly empty)."""

    def __init__(self, seed: int, faults: Sequence[Fault]):
        self.seed = int(seed)
        self.faults = sorted(faults, key=lambda f: (f.step, f.kind, f.shard,
                                                    f.lane))
        self._by_step: Dict[int, List[Fault]] = {}
        for f in self.faults:
            self._by_step.setdefault(f.step, []).append(f)

    def at(self, step: int) -> List[Fault]:
        return self._by_step.get(int(step), [])

    def __len__(self) -> int:
        return len(self.faults)

    def __repr__(self) -> str:
        return f"FaultPlan(seed={self.seed}, faults={self.faults!r})"


def default_seed(fallback: int = 0) -> int:
    """The suite-level fault seed: `REPRO_FAULTS` env var when set (the CI
    chaos lane's knob), else `fallback`."""
    v = os.environ.get("REPRO_FAULTS", "").strip()
    return int(v) if v else int(fallback)


def make_fault_plan(seed: int, n_steps: int, n_shards: int, lanes: int, *,
                    n_faults: int = 3,
                    kinds: Sequence[str] = FAULT_KINDS) -> FaultPlan:
    """Draw `n_faults` faults over steps [1, n_steps) from one seeded
    generator. Step 0 is excluded so there is always a pre-fault snapshot
    to recover from; at most one shard_drop is scheduled per step (two
    simultaneous drops of the same journal epoch are recovered one at a
    time anyway, but keeping steps distinct keeps test expectations
    legible)."""
    if n_steps < 2:
        raise ValueError("need n_steps >= 2 (step 0 is fault-free)")
    bad = set(kinds) - set(FAULT_KINDS)
    if bad:
        raise ValueError(f"unknown fault kinds {sorted(bad)}; "
                         f"valid: {FAULT_KINDS}")
    rng = np.random.default_rng(seed)
    out: List[Fault] = []
    drop_steps: set[int] = set()
    for _ in range(n_faults):
        kind = str(rng.choice(list(kinds)))
        step = int(rng.integers(1, n_steps))
        if kind == "shard_drop":
            while step in drop_steps:
                step = int(rng.integers(1, n_steps))
            drop_steps.add(step)
            out.append(Fault(kind=kind, step=step,
                             shard=int(rng.integers(0, n_shards))))
        elif kind == "poison":
            out.append(Fault(kind=kind, step=step,
                             lane=int(rng.integers(0, lanes))))
        else:
            out.append(Fault(kind=kind, step=step,
                             ticks=int(rng.integers(1, 5))))
    return FaultPlan(seed, out)


# ---------------------------------------------------------------------------
# injection primitives
# ---------------------------------------------------------------------------

def inject_shard_drop(state, shard: int):
    """Zero shard `shard`'s slice of every state leaf (leading dim = shard
    dim, the engine's layout). The zeroed slice is dead by the
    `state_alive` criterion — live stores carry KEY_INF-filled key planes
    from `init` on."""
    return jax.tree.map(
        lambda x: x.at[shard].set(jnp.zeros_like(x[shard])), state)


def poison_ops(ops, lane: int):
    """The wire-corruption model: lane `lane`'s op code becomes POISON_OP."""
    return jnp.asarray(ops).at[lane].set(jnp.int32(POISON_OP))


def sanitize_ops(ops):
    """Split a wire plan's op codes into (clean, poisoned_mask): codes
    outside `api.VALID_OPS` (and not the idle OP_NONE) are masked to
    OP_NONE. Host-side numpy — the sanitizer runs before the plan enters
    the jitted step."""
    ops = np.asarray(jax.device_get(ops), np.int32)
    ok = np.isin(ops, np.asarray(sorted(VALID_OPS), np.int32)) \
        | (ops == OP_NONE)
    clean = np.where(ok, ops, OP_NONE).astype(np.int32)
    return clean, ~ok


@functools.partial(jax.jit, static_argnums=1)
def _alive_leaf(x, n_shards: int):
    return jnp.any((x != 0).reshape(n_shards, -1), axis=1)


def state_alive(state, n_shards: int) -> np.ndarray:
    """Per-shard liveness probe: shard s is alive iff ANY leaf has a
    nonzero element in its slice. One fused any-reduce per leaf; the
    result is the health epoch's heartbeat (ResilientEngine marks shards
    whose heartbeat lags the epoch as failed)."""
    leaves = jax.tree.leaves(state)
    per = [np.asarray(_alive_leaf(x, n_shards)) for x in leaves]
    return np.any(np.stack(per, 0), axis=0)
