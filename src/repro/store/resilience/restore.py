"""ResilientEngine: fault detection + deterministic recovery over a
`store.engine.StoreEngine`.

Wraps an engine's `step` with the full fault-tolerance loop:

  1. **snapshot cadence** — every `snapshot_every` steps (of a healthy
     state), `journal.take_snapshot` flattens the state pytree to host.
  2. **write-ahead journal** — the caller's plan is journaled BEFORE the
     wire, so in-flight corruption can always be repaired from intent.
  3. **inject** — faults scheduled by the seeded `FaultPlan` for this seq
     are applied (shard slice zeroed / wire op poisoned / stall ticks).
  4. **detect** — poisoned lanes via `sanitize_ops` (op code outside
     `api.VALID_OPS` = checksum failure; repaired from the journaled
     intent, counted in `retries`); dead shards via the health epoch
     (`state_alive` heartbeat lagging the epoch).
  5. **recover** — the quarantined shard is rebuilt from the latest
     snapshot plus the journal tail, under the `"recover"` trace span.

Two recovery modes:

* ``sync`` (default) — the rebuild completes inside the detecting step.
  The rebuilt shard slice is BIT-IDENTICAL to the fault-free shard (state
  AND metrics plane): per-shard replay mirrors the engine's routing
  exactly (owner selection in global lane order, pooled plan padding,
  manual routed-op accounting — the RESIDENCY-OK/METRICS-OK equivalence),
  so after recovery the whole run digests equal the uninterrupted run's.
* ``degraded`` — healthy shards keep serving while the dead shard replays
  `replay_per_tick` journal entries per step. Lanes owned by the dead
  shard are DEFERRED (masked to OP_NONE on the wire, so callers see
  ok=False at the original seq) and applied as journaled catch-up steps
  once the rebuild completes; their true results land in
  `self.completions[(seq, lane)]`. Per-shard linearization makes the
  deferred answers equal the fault-free answers — the dead shard's keys
  are only ever touched by its own (deferred, order-preserved) lanes —
  but batch clocks shift, so degraded mode promises RESULT equality, not
  state-digest equality (docs/resilience.md spells out the split).

The resilience tally (`obs.RESILIENCE_SCHEMA`: faults_injected,
recoveries, replayed_ops, retries, ...) is host-side by design — counters
*about* faults must not live inside the state plane a recovery has to
reproduce — and is merged into the read-side `metrics()` view by
`obs.merge_resilience`.
"""
from __future__ import annotations

from contextlib import nullcontext as _null
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.routing import owner_of
from repro.store import exec as exec_
from repro.store import obs
from repro.store.api import OP_NONE, OpPlan
from repro.store.resilience import faults as F
from repro.store.resilience import journal as J


def _np_owner(keys: np.ndarray, n_shards: int) -> np.ndarray:
    """Host-side `routing.owner_of` (top log2(S) key bits)."""
    b = int(np.log2(n_shards)) if n_shards > 1 else 0
    if b == 0:
        return np.zeros(keys.shape, np.int32)
    return (keys >> np.uint64(64 - b)).astype(np.int32)


def _make_replayer(be, mode):
    def run(state, plan):
        with exec_.exec_mode(mode):
            return be.apply(state, plan)
    return jax.jit(run)


def rebuild_shard(be, snap: J.Snapshot, entries, shard: int, n_shards: int,
                  pool: int, exec_mode: str, replayer=None, start: int = 0,
                  stop: Optional[int] = None, slice_state=None):
    """Replay shard `shard`'s sub-stream of `entries[start:stop]` onto its
    snapshot slice, reproducing EXACTLY what the engine computed for that
    shard: lanes selected by owner in global lane order (stable routing
    order), padded to the engine's per-shard pool, applied DIRECTLY under
    the engine's exec mode, with the engine's routed-op counters recorded
    manually (the METRICS-OK equivalence pattern). Lanes beyond the pool
    are truncated, matching the router's deterministic overflow drop.

    Returns (shard slice state, replayed op count). Pass `slice_state` to
    continue an incremental (degraded-mode) rebuild.
    """
    if slice_state is None:
        slice_state = jax.tree.map(lambda x: jnp.asarray(x[shard]),
                                   jax.tree.unflatten(snap.treedef,
                                                      snap.leaves))
    if replayer is None:
        replayer = _make_replayer(be, exec_mode)
    observed = isinstance(be, obs.ObservedStore)
    replayed = 0
    for e in entries[start:stop]:
        owner = _np_owner(e.keys, n_shards)
        sel = np.nonzero((owner == shard) & (e.ops >= 0))[0][:pool]
        n = len(sel)
        replayed += n
        p_ops = np.full(pool, OP_NONE, np.int32)
        p_keys = np.zeros(pool, np.uint64)
        p_vals = np.zeros(pool, np.uint64)
        p_ops[:n], p_keys[:n], p_vals[:n] = (e.ops[sel], e.keys[sel],
                                             e.vals[sel])
        plan = OpPlan(ops=jnp.asarray(p_ops), keys=jnp.asarray(p_keys),
                      vals=jnp.asarray(p_vals),
                      mask=jnp.asarray(np.arange(pool) < n))
        with obs.collect() if observed else _null() as frame:
            if observed:
                obs.record("routed_ops", np.int64(n))
                obs.record("routed_bytes",
                           np.int64(n) * obs.ROUTED_OP_BYTES)
        slice_state, _ = replayer(slice_state, plan)
        slice_state = obs.absorb_frame(slice_state, frame)
    return slice_state, replayed


def splice_shard(state, slice_state, shard: int, sharding=None):
    """Write a rebuilt shard slice back into the global sharded state."""
    out = jax.tree.map(lambda g, l: g.at[shard].set(l), state, slice_state)
    if sharding is not None:
        out = jax.device_put(out, sharding)
    return out


class _Quarantine:
    """Degraded-mode rebuild in progress for one shard."""

    __slots__ = ("shard", "snap", "entries", "pos", "slice", "replayed",
                 "deferred")

    def __init__(self, shard: int, snap: J.Snapshot, entries):
        self.shard = shard
        self.snap = snap
        self.entries = entries          # journal tail to replay
        self.pos = 0                    # next entry index
        self.slice = None               # rebuilt per-shard state
        self.replayed = 0
        self.deferred: List[tuple] = []  # (seq, ops, keys, vals) per step


class ResilientEngine:
    """The fault-tolerance wrapper. Drop-in for `StoreEngine.step` (same
    signature and return), plus the journal/snapshot/fault machinery.

    >>> reng = ResilientEngine(eng, snapshot_every=4,
    ...                        fault_plan=make_fault_plan(seed, ...))
    >>> state, res, ok, dropped = reng.step(state, ops, keys, vals)
    >>> reng.tally["recoveries"], reng.completions   # degraded catch-ups
    """

    def __init__(self, eng, *, snapshot_every: int = 4,
                 fault_plan: Optional[F.FaultPlan] = None,
                 mode: str = "sync", replay_per_tick: int = 2):
        if mode not in ("sync", "degraded"):
            raise ValueError(f"recovery mode {mode!r}: sync | degraded")
        self.eng = eng
        self.snapshot_every = int(snapshot_every)
        self.fault_plan = fault_plan
        self.mode = mode
        self.replay_per_tick = int(replay_per_tick)
        self.journal = J.Journal(base_seq=eng.seq)
        self.snapshots: List[J.Snapshot] = []
        self.tally = obs.resilience_zero()
        self.completions = {}            # (seq, lane) -> (ok, val)
        self.stall_ticks = 0
        self.epoch = 0
        self.last_seen = np.zeros(eng.n_shards, np.int64)
        self.quarantine: Optional[_Quarantine] = None
        self._pool = eng.lanes * eng.pool_factor
        self._replayer = _make_replayer(
            eng.backend, eng.exec_mode or exec_.get_mode())

    # -- health ---------------------------------------------------------
    @property
    def virtual_ticks(self) -> int:
        """The deadline clock: engine steps plus injected stall ticks."""
        return self.eng.seq + self.stall_ticks

    def _detect_dead(self, state) -> List[int]:
        """Advance the health epoch; shards whose liveness heartbeat lags
        the epoch are failed."""
        self.epoch += 1
        alive = F.state_alive(state, self.eng.n_shards)
        self.last_seen[alive] = self.epoch
        return [int(s) for s in
                np.nonzero(self.last_seen < self.epoch)[0]]

    # -- recovery -------------------------------------------------------
    def _latest_snapshot(self) -> J.Snapshot:
        if not self.snapshots:
            raise RuntimeError("shard failed before the first snapshot; "
                               "snapshot_every must cover step 0")
        return self.snapshots[-1]

    def _recover_sync(self, state, shard: int):
        snap = self._latest_snapshot()
        entries = self.journal.tail(snap.seq)
        with obs.span("recover", shard=shard, mode="sync",
                      replay=len(entries)):
            sl, n = rebuild_shard(self.eng.backend, snap, entries, shard,
                                  self.eng.n_shards, self._pool,
                                  self.eng.exec_mode or exec_.get_mode(),
                                  replayer=self._replayer)
            state = splice_shard(state, sl, shard, self.eng.sharding)
        self.tally["recoveries"] += 1
        self.tally["replayed_ops"] += n
        return state

    def _advance_degraded(self, state):
        q = self.quarantine
        with obs.span("recover", shard=q.shard, mode="degraded",
                      replay=min(self.replay_per_tick,
                                 len(q.entries) - q.pos)):
            stop = min(q.pos + self.replay_per_tick, len(q.entries))
            q.slice, n = rebuild_shard(
                self.eng.backend, q.snap, q.entries, q.shard,
                self.eng.n_shards, self._pool,
                self.eng.exec_mode or exec_.get_mode(),
                replayer=self._replayer, start=q.pos, stop=stop,
                slice_state=q.slice)
            q.pos = stop
            q.replayed += n
        if q.pos < len(q.entries):
            return state
        # rebuild complete: splice, then apply the deferred lanes as
        # journaled catch-up steps (their results land in `completions`)
        state = splice_shard(state, q.slice, q.shard, self.eng.sharding)
        self.tally["recoveries"] += 1
        self.tally["replayed_ops"] += q.replayed
        deferred, self.quarantine = q.deferred, None
        for dseq, dops, dkeys, dvals in deferred:
            cseq = self.eng.seq
            self.journal.append(cseq, dops, dkeys, dvals)
            state, res, ok, _ = self.eng.step(state, jnp.asarray(dops),
                                              jnp.asarray(dkeys),
                                              jnp.asarray(dvals))
            okh, vh = np.asarray(ok), np.asarray(res)
            for lane in np.nonzero(dops >= 0)[0]:
                self.completions[(dseq, int(lane))] = (bool(okh[lane]),
                                                       int(vh[lane]))
        return state

    # -- the step -------------------------------------------------------
    def step(self, state, ops, keys, vals):
        seq = self.eng.seq
        ops_h = np.asarray(jax.device_get(ops), np.int32)
        keys_h = np.asarray(jax.device_get(keys), np.uint64)
        vals_h = np.asarray(jax.device_get(vals), np.uint64)

        # 1) snapshot cadence (healthy states only — a quarantined state
        # carries a garbage slice that must never become a restore point)
        if self.quarantine is None and seq % self.snapshot_every == 0:
            self.snapshots.append(J.take_snapshot(state, seq))

        # 2) write-ahead intent (the poison repair source); the wire copy
        # is what faults corrupt
        wire_ops = jnp.asarray(ops_h)

        # 3) inject this step's scheduled faults
        for f in (self.fault_plan.at(seq) if self.fault_plan else []):
            self.tally["faults_injected"] += 1
            if f.kind == "poison":
                wire_ops = F.poison_ops(wire_ops, f.lane)
            elif f.kind == "shard_drop":
                state = F.inject_shard_drop(state, f.shard)
            elif f.kind == "stall":
                self.stall_ticks += f.ticks

        # 4a) detect + repair wire corruption: any op code outside
        # VALID_OPS fails the sanitizer; the journaled intent is
        # authoritative, so the repair is a re-read (one retry per lane)
        clean, poisoned = F.sanitize_ops(wire_ops)
        n_poisoned = int(np.sum(poisoned))
        if n_poisoned:
            self.tally["retries"] += n_poisoned
            wire_ops = jnp.asarray(ops_h)        # re-fetch intent
        else:
            wire_ops = jnp.asarray(clean)

        # 4b) detect dead shards via the health epoch
        dead = self._detect_dead(state)
        if dead and self.quarantine is None:
            if self.mode == "sync":
                for s in dead:
                    state = self._recover_sync(state, s)
            else:
                snap = self._latest_snapshot()
                self.quarantine = _Quarantine(dead[0], snap,
                                              self.journal.tail(snap.seq))

        # 5) degraded mode: defer the dead shard's lanes (healthy shards
        # keep serving), journal + apply the masked plan, advance the
        # background rebuild
        applied_ops = np.asarray(jax.device_get(wire_ops), np.int32)
        if self.quarantine is not None:
            q = self.quarantine
            sel = ((_np_owner(keys_h, self.eng.n_shards) == q.shard)
                   & (applied_ops >= 0))
            if sel.any():
                q.deferred.append((seq,
                                   np.where(sel, applied_ops,
                                            OP_NONE).astype(np.int32),
                                   keys_h.copy(), vals_h.copy()))
                applied_ops = np.where(sel, OP_NONE,
                                       applied_ops).astype(np.int32)

        self.journal.append(seq, applied_ops, keys_h, vals_h)
        state, res, ok, dropped = self.eng.step(state,
                                                jnp.asarray(applied_ops),
                                                jnp.asarray(keys_h),
                                                jnp.asarray(vals_h))
        if self.quarantine is not None:
            state = self._advance_degraded(state)
        return state, res, ok, dropped

    # -- read side ------------------------------------------------------
    def stats(self, state) -> dict:
        out = self.eng.stats(state)
        out["seq"] = self.eng.seq
        return out

    def metrics(self, state) -> dict:
        """Global (summed-over-shards) metrics view with the host-side
        resilience tally folded in (`obs.merge_resilience`). Per-shard
        planes stay available via `self.eng.metrics`."""
        per = self.eng.metrics(state)
        summed = {k: int(np.sum(v)) for k, v in per.items()}
        return obs.merge_resilience(summed, self.tally)
