"""Deterministic fault tolerance over the Store API (docs/resilience.md).

Three layers, importable a la carte:

* `journal` — write-ahead op-plan journal (seq-numbered, digest-chained)
  plus state snapshots; `restore()` replays the tail through the normal
  `apply` path to a bit-identical state/metrics digest.
* `faults` — seeded deterministic fault plans (shard drop, poisoned op
  lane, maintenance stall) injected at the engine step boundary;
  `REPRO_FAULTS=<seed>` re-seeds the suites (the CI chaos lane).
* `restore` — `ResilientEngine`: per-step health epoch, quarantine, and
  snapshot+journal rebuild in sync or degraded mode.
"""
from repro.store.resilience.faults import (FAULT_KINDS, Fault, FaultPlan,
                                           POISON_OP, default_seed,
                                           inject_shard_drop,
                                           make_fault_plan, poison_ops,
                                           sanitize_ops, state_alive)
# the restore MODULE import must precede the journal's `restore` FUNCTION
# import: a submodule import binds the package attribute to the module, and
# the later from-import rebinds it to the function (the public name)
from repro.store.resilience.restore import (ResilientEngine, rebuild_shard,
                                            splice_shard)
from repro.store.resilience.journal import (GENESIS, Journal, JournalEntry,
                                            Snapshot, replay_plans, restore,
                                            snapshot_state, state_digest,
                                            take_snapshot)

__all__ = [
    "FAULT_KINDS", "Fault", "FaultPlan", "POISON_OP", "default_seed",
    "inject_shard_drop", "make_fault_plan", "poison_ops", "sanitize_ops",
    "state_alive", "GENESIS", "Journal", "JournalEntry", "Snapshot",
    "replay_plans", "restore", "snapshot_state", "state_digest",
    "take_snapshot", "ResilientEngine", "rebuild_shard", "splice_shard",
]
