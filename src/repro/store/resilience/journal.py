"""Write-ahead op-plan journal + state snapshots: determinism as recovery.

The whole reproduction's contract — one op stream, one bit-identical state,
in every exec mode and sharding — makes fault tolerance almost free: if we
record (a) a periodic SNAPSHOT of the state pytree and (b) every `OpPlan`
batch applied after it, then any later state is reconstructible by replaying
the journal tail through the SAME `apply` path the live run used. No fuzzy
"close enough" recovery: `restore()` reproduces the state digest and the
metrics-plane digest bit for bit (tests/test_resilience.py kills the run
after every batch and proves it; the RECOVER-OK lane of
tests/multidev/store_prog.py proves it on an 8-device mesh).

Three pieces (formats documented in docs/resilience.md):

* `JournalEntry` — one applied batch: `seq` (the engine's host step
  counter, `StoreEngine.seq`), the plan arrays as host numpy copies, and a
  chained blake2b digest over (previous digest, seq, arrays). The chain
  makes truncation/reordering/corruption of the journal detectable
  (`Journal.verify()`), the same way the digest chain in a replicated log
  does.
* `Snapshot` — `(seq, leaves, treedef, digest)`: the state pytree flattened
  to host numpy leaves. `state_digest()` is the canonical digest used
  everywhere a test says "bit-identical state".
* `restore(eng, snapshot, entries)` — device_put the snapshot back
  (re-sharded), reset `eng.seq`, and replay the tail through `eng.step`.
  Because replay IS the normal path, anything the engine guarantees
  (routing determinism, metrics bit-identity, exec-mode parity) transfers
  to the restored state for free.

The journal is WRITE-AHEAD relative to the wire: `ResilientEngine.step`
journals the caller's intent before transmitting the plan, so a poisoned
op lane (corruption in flight, detected as an op code outside
`api.VALID_OPS`) is repaired by re-reading the journaled intent — see
store/resilience/restore.py and faults.py.
"""
from __future__ import annotations

import hashlib
from typing import Any, List, NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp

# chain seed: a fixed tag, not empty, so an empty journal still has a
# well-defined head digest distinct from "no journal"
GENESIS = hashlib.blake2b(b"repro.store.resilience/journal",
                          digest_size=16).hexdigest()


def _chain(prev_hex: str, seq: int, arrays: Sequence[np.ndarray]) -> str:
    """blake2b over (previous digest, seq, each array's dtype+shape+bytes) —
    the per-entry link of the journal's digest chain."""
    h = hashlib.blake2b(digest_size=16)
    h.update(bytes.fromhex(prev_hex))
    h.update(int(seq).to_bytes(8, "little", signed=True))
    for a in arrays:
        a = np.asarray(a)
        h.update(str(a.dtype).encode())
        h.update(np.asarray(a.shape, np.int64).tobytes())
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def state_digest(state) -> str:
    """Canonical digest of a state pytree (leaves pulled to host). Two
    states are "bit-identical" iff their digests match — the equality every
    resilience test asserts."""
    leaves = [np.asarray(x) for x in jax.device_get(jax.tree.leaves(state))]
    return _chain(GENESIS, len(leaves), leaves)


class JournalEntry(NamedTuple):
    """One applied batch: plan arrays as host copies + the chain digest."""
    seq: int
    ops: np.ndarray      # [K] int32 (OP_NONE lanes idle; masked lanes too)
    keys: np.ndarray     # [K] uint64
    vals: np.ndarray     # [K] uint64
    digest: str

    @property
    def n_ops(self) -> int:
        """Valid (executable) lanes this entry carries."""
        return int(np.sum(self.ops >= 0))


class Snapshot(NamedTuple):
    """A state pytree flattened to host numpy leaves at step `seq`."""
    seq: int
    leaves: tuple
    treedef: Any
    digest: str


def take_snapshot(state, seq: int) -> Snapshot:
    """Flatten + device_get a state pytree (any backend, any sharding —
    leaves keep their leading shard dim if present)."""
    leaves, treedef = jax.tree.flatten(state)
    host = tuple(np.asarray(x) for x in jax.device_get(leaves))
    return Snapshot(seq=int(seq), leaves=host, treedef=treedef,
                    digest=_chain(GENESIS, len(host), host))


def snapshot_state(snap: Snapshot, sharding=None):
    """Rebuild the device state pytree from a snapshot (optionally re-laid
    onto a NamedSharding — restoring onto a fresh mesh is the point)."""
    state = jax.tree.unflatten(snap.treedef,
                               [jnp.asarray(x) for x in snap.leaves])
    if sharding is not None:
        state = jax.device_put(state, sharding)
    return state


class Journal:
    """Append-only, digest-chained record of applied `OpPlan` batches.

    Entries are seq-contiguous from `base_seq`; `append` enforces it, so a
    journal can only describe one gap-free suffix of the engine's step
    sequence — exactly what replay needs. The chain head is `head_digest`;
    `verify()` recomputes every link and raises on any tampering.
    """

    def __init__(self, base_seq: int = 0):
        self.base_seq = int(base_seq)
        self.entries: List[JournalEntry] = []
        self.head_digest = GENESIS

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def next_seq(self) -> int:
        return self.base_seq + len(self.entries)

    def append(self, seq: int, ops, keys, vals) -> JournalEntry:
        if int(seq) != self.next_seq:
            raise ValueError(f"journal expects seq {self.next_seq}, "
                             f"got {seq} (entries must be gap-free)")
        ops = np.asarray(jax.device_get(ops), np.int32).copy()
        keys = np.asarray(jax.device_get(keys), np.uint64).copy()
        vals = np.asarray(jax.device_get(vals), np.uint64).copy()
        self.head_digest = _chain(self.head_digest, int(seq),
                                  (ops, keys, vals))
        e = JournalEntry(seq=int(seq), ops=ops, keys=keys, vals=vals,
                         digest=self.head_digest)
        self.entries.append(e)
        return e

    def tail(self, from_seq: int) -> List[JournalEntry]:
        """Entries with seq >= from_seq (what a restore from a snapshot
        taken at `from_seq` replays)."""
        return [e for e in self.entries if e.seq >= from_seq]

    def verify(self) -> bool:
        """Recompute the whole chain; raises ValueError at the first entry
        whose digest does not match (truncation at the end is legal — a
        shorter journal is just an earlier prefix)."""
        prev = GENESIS
        for i, e in enumerate(self.entries):
            want = _chain(prev, e.seq, (e.ops, e.keys, e.vals))
            if e.digest != want:
                raise ValueError(f"journal digest chain broken at entry {i} "
                                 f"(seq {e.seq})")
            if e.seq != self.base_seq + i:
                raise ValueError(f"journal seq gap at entry {i}: "
                                 f"{e.seq} != {self.base_seq + i}")
            prev = e.digest
        return True


def restore(eng, snap: Snapshot, entries: Sequence[JournalEntry]):
    """Snapshot + journal tail -> (state, replayed_ops), through the normal
    `eng.step` path.

    `eng` is a `store.engine.StoreEngine` (or anything with `.step`,
    `.sharding`, `.seq`). The engine's host seq counter is reset to the
    snapshot's, each entry is replayed in order (entry seq must line up),
    and the returned state is bit-identical to the state the live run had
    after the last replayed entry — digest-checked by the callers in
    tests/test_resilience.py and the RECOVER-OK multidev lane.
    """
    state = snapshot_state(snap, getattr(eng, "sharding", None))
    eng.seq = snap.seq
    replayed = 0
    for e in entries:
        if e.seq != eng.seq:
            raise ValueError(f"replay expects seq {eng.seq}, entry has "
                             f"{e.seq} (snapshot/journal mismatch)")
        state, _, _, _ = eng.step(state, jnp.asarray(e.ops),
                                  jnp.asarray(e.keys), jnp.asarray(e.vals))
        replayed += e.n_ops
    return state, replayed


def replay_plans(apply_fn, state, entries: Sequence[JournalEntry],
                 mask_from_ops: bool = True):
    """Generic single-instance replay for DIRECT backends (no engine): fold
    `apply_fn(state, plan)` over the journal tail. Used by the differential
    fault-interleave test and the scheduler recovery path, where the journal
    was recorded at plan level rather than engine level."""
    from repro.store.api import make_plan
    replayed = 0
    for e in entries:
        mask = (e.ops >= 0) if mask_from_ops else np.ones(e.ops.shape, bool)
        state, _ = apply_fn(state, make_plan(e.ops, e.keys, e.vals,
                                             mask=mask))
        replayed += e.n_ops
    return state, replayed
