"""End-to-end training driver: ~100M-param qwen3-style model for a few
hundred steps with checkpoints, prefetch pipeline and straggler fallback.

Run: PYTHONPATH=src python examples/train_smoke.py [--steps 200] [--small]
"""
import argparse

import repro  # noqa: F401
from repro.configs import get_config, get_reduced
from repro.configs.base import ShapeConfig
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true",
                    help="tiny config for a fast smoke run")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    if args.small:
        cfg = get_reduced("qwen3-1.7b")
        shape = ShapeConfig("smoke", seq_len=64, global_batch=8, kind="train")
    else:
        # ~100M params: qwen3 family scaled
        cfg = get_config("qwen3-1.7b").replace(
            n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
            head_dim=64, vocab_size=32000)
        shape = ShapeConfig("100m", seq_len=256, global_batch=8, kind="train")

    params, opt, out = train(cfg, shape, steps=args.steps, seed=0,
                             ckpt_dir=args.ckpt, ckpt_every=50,
                             microbatches=2, log_every=10, lr_peak=1e-3)
    h = out["history"]
    print(f"\nloss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over "
          f"{len(h)} steps; straggler skips: {out['straggler_skips']}")
    assert h[-1]["loss"] < h[0]["loss"], "loss should decrease"


if __name__ == "__main__":
    main()
