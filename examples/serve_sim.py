"""End-to-end serving driver: continuous batching with paged KV cache,
skiplist scheduler and ring-queue arrivals on a reduced qwen3 model.

Run: PYTHONPATH=src python examples/serve_sim.py
"""
import time

import numpy as np
import jax

import repro  # noqa: F401
from repro.configs import get_reduced
from repro.models import model as M
from repro.serving.engine import Engine, Request


def main():
    cfg = get_reduced("qwen3-1.7b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_reqs=4, num_pages=64, page_size=8,
                 max_pages_per_req=8)
    rng = np.random.default_rng(0)
    for i in range(10):
        eng.submit(Request(req_id=i,
                           prompt=rng.integers(1, cfg.vocab_size, 8),
                           max_new=12, priority=i % 3))
    t0 = time.perf_counter()
    outs = eng.run(max_steps=128)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in outs.values())
    print(f"served {len(outs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on 1 CPU core)")
    print(f"pool fully recycled: {int(eng.kv.pool.num_free())} pages free")
    print("sample output:", outs[0])


if __name__ == "__main__":
    main()
