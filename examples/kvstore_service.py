"""The paper's own system, cluster-shaped: a sharded ordered KV store.

One deterministic skiplist per mesh shard (= NUMA node), key space split by
top key bits, ops routed hierarchically with all_to_all (= the paper's
lock-free queues), results routed back. Runs on 8 fake devices.

Run: PYTHONPATH=src python examples/kvstore_service.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

import repro  # noqa: F401,E402
from repro.core.ordered_sharded import (OP_DELETE, OP_FIND, OP_INSERT,  # noqa: E402
                                        make_store_step, sharded_store_init)

AXES = ("pod", "data")
LANES = 32


def main():
    mesh = jax.make_mesh((2, 4), AXES)
    sharding = NamedSharding(mesh, P(AXES))
    state = jax.device_put(sharded_store_init(8, 4096), sharding)
    step = jax.jit(make_store_step(mesh, AXES, LANES, pool_factor=4))

    rng = np.random.default_rng(0)
    total = 8 * LANES
    put = lambda x: jax.device_put(jnp.asarray(x), sharding)

    # round 1: inserts from every shard
    keys = rng.integers(1, 2**63, total, dtype=np.uint64)
    state, res, ok, dropped = step(state, put(np.full(total, OP_INSERT, np.int32)),
                                   put(keys), put(keys + 1))
    print(f"inserted {int(np.asarray(ok).sum())}/{total} "
          f"(dropped={int(dropped)})")

    # round 2: 50% finds / 25% deletes / 25% new inserts
    ops = rng.choice([OP_FIND, OP_DELETE, OP_INSERT], total,
                     p=[0.5, 0.25, 0.25]).astype(np.int32)
    k2 = keys.copy()
    k2[ops == OP_INSERT] = rng.integers(1, 2**63, int((ops == OP_INSERT).sum()),
                                        dtype=np.uint64)
    state, res, ok, dropped = step(state, put(ops), put(k2), put(k2 + 1))
    finds = ops == OP_FIND
    print(f"finds hit {int(np.asarray(ok)[finds].sum())}/{int(finds.sum())}, "
          f"deletes ok {int(np.asarray(ok)[ops == OP_DELETE].sum())}, "
          f"dropped={int(dropped)}")
    sizes = np.asarray(jax.device_get(state.n_term)) - np.asarray(
        jax.device_get(state.n_marked))
    print("per-shard live sizes (key-space partition by top 3 bits):", sizes)


if __name__ == "__main__":
    main()
