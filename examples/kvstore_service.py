"""The paper's own system, cluster-shaped: a sharded KV store over any backend.

One structure instance per mesh shard (= NUMA node), key space split by top
key bits, ops routed hierarchically with all_to_all (= the paper's lock-free
queues), results routed back. Runs on 8 fake devices.

The store is built through `repro.store.engine`, so the backend is a config
string: the deterministic skiplist, the two-level hash, the split-order
table, and the hierarchical hash+skiplist tier stack all serve the exact
same workload here — and the deterministic linearization makes their
find/insert/delete results bit-identical, which this example asserts.
The probe execution layer is a second config knob: the tiered backend is
re-run with its FIND phases on the Pallas kernels (interpret mode on CPU)
and must reproduce the jnp results bit-for-bit.

Run: PYTHONPATH=src python examples/kvstore_service.py [backend ...]
     (no args: run all of BACKENDS, cross-check, then cross-check exec modes)
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro  # noqa: F401,E402
from repro.store import OP_DELETE, OP_FIND, OP_INSERT, OP_POPK  # noqa: E402
from repro.store.engine import StoreEngine  # noqa: E402

AXES = ("pod", "data")
LANES = 32
BACKENDS = ("det_skiplist", "twolevel_hash", "splitorder", "hash+skiplist",
            "tiered3/lru", "pq")


def workload(n_rounds: int, total: int, seed: int = 0):
    """Deterministic op stream shared by every backend (vals = key + 1, so
    in-batch duplicate resolution cannot disagree between backends)."""
    rng = np.random.default_rng(seed)
    rounds = []
    keys = rng.integers(1, 2**63, total, dtype=np.uint64)
    rounds.append((np.full(total, OP_INSERT, np.int32), keys))
    for _ in range(n_rounds - 1):
        ops = rng.choice([OP_FIND, OP_DELETE, OP_INSERT], total,
                         p=[0.5, 0.25, 0.25]).astype(np.int32)
        k = keys.copy()
        fresh = ops == OP_INSERT
        k[fresh] = rng.integers(1, 2**63, int(fresh.sum()), dtype=np.uint64)
        rounds.append((ops, k))
        keys = k
    return rounds


def run_backend(name: str, rounds, exec_mode: str | None = None) -> list:
    mesh = jax.make_mesh((2, 4), AXES)
    eng = StoreEngine(mesh, AXES, LANES, backend=name, pool_factor=4,
                      exec_mode=exec_mode)
    state = jax.device_put(eng.init(4096), eng.sharding)
    put = lambda x: jax.device_put(jnp.asarray(x), eng.sharding)

    outs = []
    for rnd, (ops, keys) in enumerate(rounds):
        state, res, ok, dropped = eng.step(state, put(ops), put(keys),
                                           put(keys + 1))
        assert int(dropped) == 0, f"{name}: routing drops"
        outs.append((np.asarray(ok), np.asarray(res)))
        finds = ops == OP_FIND
        if finds.any():
            hits = int(outs[-1][0][finds].sum())
            print(f"  [{name}] round {rnd}: finds hit {hits}/{int(finds.sum())}")

    stats = eng.stats(state)   # the Store.stats() accessor — no internals
    print(f"  [{name}] per-shard live sizes (top-3-bit key partition): "
          f"{stats['size']}")
    # "seq" is the engine's host step counter — a plain int, not a
    # per-shard plane, and it counts batches rather than structure totals
    extra = {k: np.sum(v) for k, v in stats.items()
             if k not in ("size", "capacity", "seq") and int(np.sum(v))}
    if extra:
        print(f"  [{name}] totals: " + ", ".join(
            f"{k}={int(v)}" for k, v in sorted(extra.items())))
    return outs


def main():
    backends = tuple(sys.argv[1:]) or BACKENDS
    rounds = workload(n_rounds=3, total=8 * LANES)
    results = {}
    for name in backends:
        print(f"backend: {name}")
        results[name] = run_backend(name, rounds)

    if len(results) > 1:
        ref_name, *others = list(results)
        ref = results[ref_name]
        for name in others:
            for r, ((ok_a, res_a), (ok_b, res_b)) in enumerate(
                    zip(ref, results[name])):
                assert (ok_a == ok_b).all(), (ref_name, name, r, "ok")
                assert (res_a == res_b).all(), (ref_name, name, r, "vals")
        print(f"all {len(results)} backends produced identical results "
              f"({len(rounds)} rounds x {8 * LANES} lanes)")

    # execution-layer parity: the tiered stack with its probes on the Pallas
    # kernels (interpret on CPU) must reproduce the jnp results bit-for-bit
    if "hash+skiplist" in results:
        kernelized = run_backend("hash+skiplist", rounds,
                                 exec_mode="interpret")
        for r, ((ok_a, res_a), (ok_b, res_b)) in enumerate(
                zip(results["hash+skiplist"], kernelized)):
            assert (ok_a == ok_b).all(), ("exec-mode", r, "ok")
            assert (res_a == res_b).all(), ("exec-mode", r, "vals")
        print("exec modes jnp and interpret produced identical results "
              "(hash+skiplist, kernelized hot-tier probe)")

    demo_pq_drain()


def demo_pq_drain():
    """Bulk-pop-k drain on the sharded `pq` backend: every shard is a
    per-NUMA priority queue (the relaxed-pq design — pop lanes carry a
    shard HINT in their key field), and one plan of OP_POPK lanes extracts
    each shard's k smallest keys in one dispatch. Drains the store to
    empty and checks each shard's pop stream comes out sorted."""
    print("backend: pq (sharded bulk-pop-k drain)")
    mesh = jax.make_mesh((2, 4), AXES)
    eng = StoreEngine(mesh, AXES, LANES, backend="pq", pool_factor=4)
    state = jax.device_put(eng.init(4096), eng.sharding)
    put = lambda x: jax.device_put(jnp.asarray(x), eng.sharding)

    rng = np.random.default_rng(7)
    total = 8 * LANES
    keys = np.unique(rng.integers(1, 2**64, 2 * total,
                                  dtype=np.uint64))[:total]
    state, _, ok, dropped = eng.step(
        state, put(np.full(total, OP_INSERT, np.int32)), put(keys),
        put(keys + 1))
    assert int(dropped) == 0 and int(np.asarray(ok).sum()) == total

    # drain: every lane is OP_POPK; lane i hints shard i % 8, so each round
    # asks every shard for its LANES smallest live keys at once
    hints = (np.arange(total, dtype=np.uint64) % 8) << np.uint64(61)
    pops = np.full(total, OP_POPK, np.int32)
    drained = []                           # per round: 8 per-shard pop sets
    while True:
        state, res, ok, _ = eng.step(state, put(pops), put(hints),
                                     put(np.zeros(total, np.uint64)))
        ok, res = np.asarray(ok), np.asarray(res)
        if not ok.any():
            break
        drained.append([res[(np.arange(total) % 8 == s) & ok]
                        for s in range(8)])
    per_shard = [sum(len(r[s]) for r in drained) for s in range(8)]
    print(f"  [pq] drained {sum(per_shard)} keys in {len(drained)} bulk-pop "
          f"rounds; per-shard {per_shard}")
    # each round extracts a shard's smallest LIVE keys, so successive
    # rounds are strictly increasing blocks per shard — and the union is
    # exactly the inserted key set
    for s in range(8):
        for a, b in zip(drained, drained[1:]):
            assert not len(a[s]) or not len(b[s]) \
                or a[s].max() < b[s].min(), f"shard {s} pop order broken"
    got = sorted(k for r in drained for s in range(8) for k in r[s].tolist())
    assert got == sorted(keys.tolist())
    stats = eng.stats(state)
    print(f"  [pq] empty again (sizes {stats['size']}); pops="
          f"{int(stats['pops'].sum())} pop_empty="
          f"{int(stats['pop_empty'].sum())} — per-shard pop rounds strictly "
          f"increasing")


if __name__ == "__main__":
    main()
