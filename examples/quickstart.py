"""Quickstart: the paper's data structures in five minutes.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core.det_skiplist import (delete_batch, find_batch, insert_batch,
                                     range_query, skiplist_init)
from repro.core.ringqueue import pop_batch, push_batch, queue_init
from repro.core.splitorder import (splitorder_find, splitorder_init,
                                   splitorder_insert)


def main():
    print("== deterministic 1-2-3-4 skiplist (paper §II) ==")
    s = skiplist_init(capacity=1024)
    keys = jnp.asarray(np.random.default_rng(0).integers(1, 10_000, 200,
                                                         dtype=np.uint64))
    s, inserted, existed = insert_batch(s, keys, keys * jnp.uint64(10))
    print(f"inserted {int(inserted.sum())} keys "
          f"({int(existed.sum())} in-batch duplicates)")
    found, vals, _ = find_batch(s, keys[:8])
    print("find:", np.asarray(found), "->", np.asarray(vals))
    cnt, rk, _, valid = range_query(s, jnp.asarray([100], jnp.uint64),
                                    jnp.asarray([1000], jnp.uint64), 16)
    print(f"range [100,1000): {int(cnt[0])} keys, first few:",
          np.asarray(rk[0])[np.asarray(valid[0])][:5])
    s, deleted = delete_batch(s, keys[:50])
    print(f"deleted {int(deleted.sum())} (lazy tombstones; compaction at 25%)")

    print("\n== lock-free block queue (paper §III) ==")
    q = queue_init(max_blocks=8, block_size=16)
    q, ok = push_batch(q, jnp.arange(40, dtype=jnp.uint64),
                       jnp.ones((40,), bool))
    q, out, got = pop_batch(q, 10)
    print("FIFO pop:", np.asarray(out))
    print("block recycles so far:", int(np.asarray(q.recycles).sum()))

    print("\n== split-order hash (paper §VII): resize w/o movement ==")
    h = splitorder_init(512, seed_slots=4, max_load=4)
    h, _, _ = splitorder_insert(h, keys, keys)
    print(f"slots grew 4 -> {int(h.n_slots)} with zero data migration")
    f, v = splitorder_find(h, keys[:5])
    print("find:", np.asarray(f))


if __name__ == "__main__":
    main()
