"""Tests for the unified repro.store storage-engine API.

The load-bearing property is backend interchangeability: every registered
backend must produce IDENTICAL per-lane results for the same `OpPlan` under
the deterministic linearization (INSERTS -> DELETES -> FINDS, first lane
wins on duplicates). Plus tier-stack correctness: spill, promotion, flush,
and the exact two-tier ordered scan.
"""
import numpy as np
import pytest

import jax.numpy as jnp

import repro  # noqa: F401  (enables x64)
from repro.core.layout import hash_slot
from repro.store import (OP_DELETE, OP_FIND, OP_INSERT, OP_NONE, STATS_SCHEMA,
                         available_backends, get_backend, make_plan)

ALL_BACKENDS = available_backends()
ORDERED = [n for n in ALL_BACKENDS if get_backend(n).ordered]


def u64(xs):
    return jnp.asarray(np.array(xs, dtype=np.uint64))


def _mixed_plans(seed=0, n_rounds=6, width=48, pool_size=64):
    """Overlapping insert/find/delete workload: keys drawn from a small pool
    so finds and deletes hit, duplicates occur in-batch, and deletes collide
    with same-batch inserts."""
    rng = np.random.default_rng(seed)
    pool = rng.integers(1, 2**62, pool_size, dtype=np.uint64)
    plans = []
    for _ in range(n_rounds):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], width,
                         p=[0.5, 0.35, 0.15]).astype(np.int32)
        keys = rng.choice(pool, width)
        mask = rng.random(width) > 0.05          # a few masked-off lanes
        plans.append(make_plan(ops, keys, keys + 1, mask))
    return plans


# ---------------------------------------------------------------------------
# per-backend semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_BACKENDS)
class TestBackendSemantics:
    def test_roundtrip(self, name):
        be = get_backend(name)
        st = be.init(1024)
        ks = u64([10, 20, 30, 40, 50])
        st, res = be.apply(st, make_plan(np.full(5, OP_INSERT, np.int32),
                                         ks, ks * jnp.uint64(2)))
        assert res.ok.all() and not res.vals.any()   # inserted, none existed
        st, res = be.apply(st, make_plan(np.full(5, OP_FIND, np.int32), ks))
        assert res.ok.all()
        assert (res.vals == ks * jnp.uint64(2)).all()
        st, res = be.apply(st, make_plan(
            np.array([OP_DELETE, OP_FIND], np.int32), u64([20, 20])))
        assert bool(res.ok[0]) and not bool(res.ok[1])  # find after delete
        assert int(be.stats(st)["size"]) == 4

    def test_masked_lanes_are_noops(self, name):
        be = get_backend(name)
        st = be.init(256)
        ks = u64([1, 2, 3, 4])
        mask = jnp.asarray([True, False, True, False])
        st, res = be.apply(st, make_plan(np.full(4, OP_INSERT, np.int32),
                                         ks, ks, mask))
        assert (np.asarray(res.ok) == np.asarray(mask)).all()
        st, res = be.apply(st, make_plan(np.full(4, OP_FIND, np.int32), ks))
        assert (np.asarray(res.ok) == np.asarray(mask)).all()
        assert int(be.stats(st)["size"]) == 2

    def test_idle_lanes(self, name):
        be = get_backend(name)
        st = be.init(256)
        st, res = be.apply(st, make_plan(
            np.array([OP_INSERT, OP_NONE, OP_NONE], np.int32), u64([7, 8, 9]),
            u64([70, 80, 90])))
        assert bool(res.ok[0]) and not res.ok[1:].any()
        assert int(be.stats(st)["size"]) == 1

    def test_stats_contract(self, name):
        """Every backend returns EXACTLY the shared STATS_SCHEMA key set
        (untracked counters are zero), in schema order, as int64."""
        be = get_backend(name)
        st = be.init(512)
        s = be.stats(st)
        assert tuple(s) == STATS_SCHEMA
        assert all(np.asarray(v).dtype == np.int64 for v in s.values())
        assert int(s["size"]) == 0 and int(s["capacity"]) >= 512
        assert all(int(v) >= 0 for v in s.values())
        # schema still uniform (and size live) after a few inserts
        ks = u64([3, 5, 7])
        st, _ = be.apply(st, make_plan(np.full(3, OP_INSERT, np.int32), ks, ks))
        s2 = be.stats(st)
        assert tuple(s2) == STATS_SCHEMA
        assert int(s2["size"]) == 3


# ---------------------------------------------------------------------------
# cross-backend parity (the API's core promise)
# ---------------------------------------------------------------------------

def test_all_backends_identical_results():
    plans = _mixed_plans()
    results = {}
    sizes = {}
    for name in ALL_BACKENDS:
        be = get_backend(name)
        st = be.init(4096)
        out = []
        for p in plans:
            st, res = be.apply(st, p)
            out.append((np.asarray(res.ok), np.asarray(res.vals)))
        results[name] = out
        sizes[name] = int(be.stats(st)["size"])

    ref = results["det_skiplist"]
    for name, out in results.items():
        for rnd, ((ok_r, v_r), (ok, v)) in enumerate(zip(ref, out)):
            assert (ok_r == ok).all(), (name, rnd, "ok")
            assert (v_r == v).all(), (name, rnd, "vals")
    assert len(set(sizes.values())) == 1, sizes


def test_parity_matches_dict_model():
    plans = _mixed_plans(seed=3)
    be = get_backend("det_skiplist")
    st = be.init(4096)
    model = {}
    for p in plans:
        ops = np.asarray(p.ops)
        keys = np.asarray(p.keys)
        vals = np.asarray(p.vals)
        mask = np.asarray(p.mask)
        st, res = be.apply(st, p)
        ok = np.asarray(res.ok)
        live = [i for i in range(p.width) if mask[i]]
        for i in live:
            if ops[i] == OP_INSERT and int(keys[i]) not in model:
                model[int(keys[i])] = int(vals[i])
        for i in live:
            if ops[i] == OP_DELETE:
                model.pop(int(keys[i]), None)
        for i in range(p.width):
            if mask[i] and ops[i] == OP_FIND:
                assert bool(ok[i]) == (int(keys[i]) in model)
                if ok[i]:
                    assert int(np.asarray(res.vals)[i]) == model[int(keys[i])]
    assert int(be.stats(st)["size"]) == len(model)


def test_ordered_backends_scan_parity():
    rng = np.random.default_rng(5)
    ks = np.unique(rng.integers(1, 2**40, 60, dtype=np.uint64))
    plan = make_plan(np.full(len(ks), OP_INSERT, np.int32), ks, ks + 9)
    lo = u64([0, int(ks[10])])
    hi = u64([2**41, int(ks[40])])
    ref = None
    for name in ORDERED:
        be = get_backend(name)
        st, _ = be.apply(be.init(1024), plan)
        cnt, keys, vals, valid = be.scan(st, lo, hi, 64)
        rows = []
        for q in range(2):
            rows.append([(int(k), int(v)) for k, v, m in
                         zip(np.asarray(keys[q]), np.asarray(vals[q]),
                             np.asarray(valid[q])) if m])
        got = (np.asarray(cnt).tolist(), rows)
        if ref is None:
            ref = (name, got)
        else:
            assert got == ref[1], (name, ref[0])
    assert ref[1][0] == [len(ks), 30]


@pytest.mark.parametrize("name", ["det_skiplist", "pq"])
def test_snapshot_scan_as_of_batch(name):
    """scan(as_of_batch=b) sees exactly the entries inserted by applies
    0..b: later batches are invisible, and the exact count plane agrees
    with the valid plane. Apply #i stamps its inserts with clock i."""
    be = get_backend(name)
    st = be.init(256)
    batches = [u64([10, 20]), u64([30, 40]), u64([50, 60])]
    for ks in batches:
        st, res = be.apply(st, make_plan(
            np.full(2, OP_INSERT, np.int32), ks, ks + 1))
        assert res.ok.all()
    lo, hi = u64([0]), u64([2**63])
    for b in range(3):
        cnt, keys, vals, valid = be.scan(st, lo, hi, 16, as_of_batch=b)
        seen = sorted(int(k) for k, m in
                      zip(np.asarray(keys[0]), np.asarray(valid[0])) if m)
        want = sorted(int(k) for ks in batches[:b + 1] for k in np.asarray(ks))
        assert seen == want, b
        assert int(cnt[0]) == 2 * (b + 1)
    # no as_of: the plain full scan, unchanged
    cnt, _, _, valid = be.scan(st, lo, hi, 16)
    assert int(cnt[0]) == 6 == int(np.asarray(valid[0]).sum())


def test_snapshot_scan_is_a_filter_not_time_travel():
    """Deleting an entry hides it from EVERY as_of (tombstones still
    apply), and re-inserting it re-stamps: the revived entry belongs to
    the reviving batch, not the original one."""
    be = get_backend("det_skiplist")
    st = be.init(256)
    ks = u64([10, 20, 30])
    st, _ = be.apply(st, make_plan(np.full(3, OP_INSERT, np.int32), ks, ks))
    st, res = be.apply(st, make_plan(
        np.array([OP_DELETE], np.int32), u64([20])))          # batch 1
    assert bool(res.ok[0])
    lo, hi = u64([0]), u64([2**63])
    for b in range(2):
        _, keys, _, valid = be.scan(st, lo, hi, 8, as_of_batch=b)
        seen = {int(k) for k, m in
                zip(np.asarray(keys[0]), np.asarray(valid[0])) if m}
        assert seen == {10, 30}, b                 # 20 gone at every as_of
    st, _ = be.apply(st, make_plan(
        np.array([OP_INSERT], np.int32), u64([20]), u64([99])))  # batch 2
    _, keys, _, valid = be.scan(st, lo, hi, 8, as_of_batch=1)
    seen = {int(k) for k, m in
            zip(np.asarray(keys[0]), np.asarray(valid[0])) if m}
    assert seen == {10, 30}                        # revival stamped batch 2
    cnt, keys, _, valid = be.scan(st, lo, hi, 8, as_of_batch=2)
    seen = {int(k) for k, m in
            zip(np.asarray(keys[0]), np.asarray(valid[0])) if m}
    assert seen == {10, 20, 30} and int(cnt[0]) == 3


def test_unordered_backends_refuse_scan():
    for name in ALL_BACKENDS:
        be = get_backend(name)
        if be.ordered:
            continue
        with pytest.raises(NotImplementedError):
            be.scan(be.init(64), u64([0]), u64([1]), 4)


def test_unknown_backend_error():
    with pytest.raises(KeyError, match="unknown store backend"):
        get_backend("btree9000")


# ---------------------------------------------------------------------------
# tier stack (store/tiers.py)
# ---------------------------------------------------------------------------

def _keys_filling_hot(num_slots: int, per_slot: int, seed=17) -> np.ndarray:
    """Distinct keys hashing `per_slot`-deep into every hot-tier slot — fills
    an [num_slots, per_slot] fixed-hash tier EXACTLY."""
    rng = np.random.default_rng(seed)
    buckets: dict[int, list] = {s: [] for s in range(num_slots)}
    while any(len(v) < per_slot for v in buckets.values()):
        cand = rng.integers(1, 2**62, 512, dtype=np.uint64)
        slots = np.asarray(hash_slot(jnp.asarray(cand), num_slots))
        for k, s in zip(cand.tolist(), slots.tolist()):
            if len(buckets[s]) < per_slot and k not in buckets[s]:
                buckets[s].append(k)
    return np.array([k for v in buckets.values() for k in v], dtype=np.uint64)


class TestTieredStore:
    def _setup_split(self):
        """Insert past the hot tier's capacity so spill is guaranteed."""
        be = get_backend("hash+skiplist")
        st = be.init(1024, hot_bucket=4, hot_frac=32)   # hot: 8 slots x 4
        rng = np.random.default_rng(11)
        ks = np.unique(rng.integers(1, 2**62, 64, dtype=np.uint64))
        st, res = be.apply(st, make_plan(
            np.full(len(ks), OP_INSERT, np.int32), ks, ks + 1))
        assert res.ok.all()
        return be, st, ks

    def test_spill_and_size_conservation(self):
        be, st, ks = self._setup_split()
        s = be.stats(st)
        assert int(s["size"]) == len(ks)
        assert int(s["hot_size"]) <= 32
        assert int(s["cold_size"]) > 0          # bucket overflow spilled down
        assert int(s["hot_size"]) + int(s["cold_size"]) == len(ks)

    def test_promotion_moves_cold_hits_up(self):
        be, st, ks = self._setup_split()
        hot_keys = set(np.asarray(st.hot.keys).reshape(-1).tolist())
        hot_resident = np.array([k for k in ks if int(k) in hot_keys],
                                dtype=np.uint64)
        cold_resident = np.array([k for k in ks if int(k) not in hot_keys],
                                 dtype=np.uint64)
        assert len(hot_resident) and len(cold_resident)

        # free the hot tier, then FIND the cold residents -> they promote
        st, res = be.apply(st, make_plan(
            np.full(len(hot_resident), OP_DELETE, np.int32), hot_resident))
        assert res.ok.all()
        st, res = be.apply(st, make_plan(
            np.full(len(cold_resident), OP_FIND, np.int32), cold_resident))
        assert res.ok.all()
        assert (np.asarray(res.vals) == cold_resident + 1).all()
        s = be.stats(st)
        assert int(s["size"]) == len(cold_resident)   # membership-neutral
        assert int(s["hot_size"]) > 0                 # promotion happened
        # promoted keys now serve from the hot tier
        hot_keys2 = set(np.asarray(st.hot.keys).reshape(-1).tolist())
        promoted = [k for k in cold_resident if int(k) in hot_keys2]
        assert len(promoted) == int(s["hot_size"])
        # and still findable with intact values
        st, res = be.apply(st, make_plan(
            np.full(len(cold_resident), OP_FIND, np.int32), cold_resident))
        assert res.ok.all()

    def test_flush_demotes_everything(self):
        be, st, ks = self._setup_split()
        st = be.flush(st)
        s = be.stats(st)
        assert int(s["hot_size"]) == 0
        assert int(s["size"]) == len(ks)
        st, res = be.apply(st, make_plan(
            np.full(len(ks), OP_FIND, np.int32), ks))
        assert res.ok.all()
        assert (np.asarray(res.vals) == ks + 1).all()

    def _setup_exactly_full(self):
        """Hot tier (8 slots x 4) filled to EXACTLY its capacity."""
        be = get_backend("hash+skiplist")
        st = be.init(1024, hot_bucket=4, hot_frac=32)
        fill = _keys_filling_hot(8, 4)
        st, res = be.apply(st, make_plan(
            np.full(len(fill), OP_INSERT, np.int32), fill, fill + 1))
        assert res.ok.all()
        s = be.stats(st)
        assert int(s["hot_size"]) == 32 and int(s["cold_size"]) == 0
        return be, st, fill

    def test_insert_spills_when_hot_exactly_full(self):
        be, st, fill = self._setup_exactly_full()
        extra = np.uint64(2**62 + 11)          # outside the fill key range
        st, res = be.apply(st, make_plan(
            np.array([OP_INSERT], np.int32), u64([extra]), u64([extra + 1])))
        assert bool(res.ok[0])
        s = be.stats(st)
        assert int(s["hot_size"]) == 32        # no hot cell was displaced
        assert int(s["cold_size"]) == 1        # the new key spilled down
        # every hot resident still served, values intact
        st, res = be.apply(st, make_plan(
            np.full(len(fill), OP_FIND, np.int32), fill))
        assert res.ok.all()
        assert (np.asarray(res.vals) == fill + 1).all()

    def test_promotion_noop_when_hot_exactly_full(self):
        be, st, fill = self._setup_exactly_full()
        extra = np.uint64(2**62 + 11)
        st, _ = be.apply(st, make_plan(
            np.array([OP_INSERT], np.int32), u64([extra]), u64([extra + 1])))
        # FIND the cold resident: promotion has no hot space -> key STAYS
        # cold, result still correct, membership conserved
        st, res = be.apply(st, make_plan(
            np.array([OP_FIND], np.int32), u64([extra])))
        assert bool(res.ok[0]) and int(res.vals[0]) == int(extra) + 1
        s = be.stats(st)
        assert int(s["hot_size"]) == 32 and int(s["cold_size"]) == 1
        assert int(s["size"]) == len(fill) + 1

    def test_flush_when_hot_exactly_full(self):
        be, st, fill = self._setup_exactly_full()
        st = be.flush(st)
        s = be.stats(st)
        assert int(s["hot_size"]) == 0
        assert int(s["cold_size"]) == len(fill) == int(s["size"])
        st, res = be.apply(st, make_plan(
            np.full(len(fill), OP_FIND, np.int32), fill))
        assert res.ok.all()
        assert (np.asarray(res.vals) == fill + 1).all()

    def test_scan_sees_both_tiers(self):
        be, st, ks = self._setup_split()
        det = get_backend("det_skiplist")
        st_d, _ = det.apply(det.init(1024), make_plan(
            np.full(len(ks), OP_INSERT, np.int32), ks, ks + 1))
        sk = np.sort(ks)
        lo = u64([0, int(sk[8])])
        hi = u64([2**63, int(sk[40])])
        cnt_t, k_t, v_t, m_t = be.scan(st, lo, hi, len(ks) + 8)
        cnt_d, k_d, v_d, m_d = det.scan(st_d, lo, hi, len(ks) + 8)
        assert (np.asarray(cnt_t) == np.asarray(cnt_d)).all()
        assert int(cnt_t[0]) == len(ks) and int(cnt_t[1]) == 32
        for q in range(2):
            a = [(int(k), int(v)) for k, v, m in zip(
                np.asarray(k_t[q]), np.asarray(v_t[q]), np.asarray(m_t[q])) if m]
            b = [(int(k), int(v)) for k, v, m in zip(
                np.asarray(k_d[q]), np.asarray(v_d[q]), np.asarray(m_d[q])) if m]
            assert a == b, q
            assert a == sorted(a)                    # ordered output
