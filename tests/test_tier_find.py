"""Fused tier-find parity: the one-dispatch FIND path contract.

The fused `store.exec.tier_find` (kernels/tier_find — hot bucket probe +
warm level walk + per-run spill search in ONE pallas_call) must be
BIT-IDENTICAL to the unfused dispatch-per-tier chain, for results AND for
the full residency pytree, in every runnable exec mode — fusion is a
dispatch-count optimization, never a semantics change. Also covered: the
per-run spill searchsorted (now the jnp reference path too), the
`run_offsets` boundary plane, the run-count cap that keeps it static, the
measured dispatch counts (FIND phase = exactly ONE dispatch fused), the
two-level split-order probe kernel, and the pinned-host spill placement
guard. (The 8-device engine analogue runs in
tests/multidev/store_prog.py: FUSED-OK.)
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401  (enables x64)
from repro.core.bits import KEY_INF
from repro.core.layout import MAX_SPILL_RUNS, run_offsets
from repro.store import (OP_DELETE, OP_FIND, OP_INSERT, get_backend,
                         make_plan)
from repro.store import exec as exec_
from repro.store.tiers import spill_find_ref, spill_init, unfused_twin

MODES = exec_.runnable_modes()
TIERED = ["hash+skiplist", "tiered3", "tiered3/lru", "tiered3/size",
          "tiered3/b128"]
WARM_LAYOUTS = ("level", "block")


def _mixed_plans(seed=21, n_rounds=5, width=48, pool_size=96):
    rng = np.random.default_rng(seed)
    pool = rng.integers(1, 2**62, pool_size, dtype=np.uint64)
    plans = []
    for _ in range(n_rounds):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], width,
                         p=[0.5, 0.35, 0.15]).astype(np.int32)
        keys = rng.choice(pool, width)
        mask = rng.random(width) > 0.05
        plans.append(make_plan(ops, keys, keys + 1, mask))
    return plans


def assert_states_equal(sa, sb, ctx):
    la, lb = jax.tree.leaves(sa), jax.tree.leaves(sb)
    assert len(la) == len(lb), ctx
    for i, (a, b) in enumerate(zip(la, lb)):
        assert (np.asarray(a) == np.asarray(b)).all(), (ctx, i)


# ---------------------------------------------------------------------------
# the run-boundary plane + per-run spill probe
# ---------------------------------------------------------------------------

def _spill_with_runs(seed=3, capacity=128, runs=5, run_len=9, kills=7):
    """A spill tier holding `runs` appended sorted runs with tombstones."""
    rng = np.random.default_rng(seed)
    sp = spill_init(capacity)
    from repro.store.tiers import spill_append, spill_discard
    all_keys = []
    for _ in range(runs):
        ks = np.unique(rng.integers(1, 2**62, run_len + 2,
                                    dtype=np.uint64))[:run_len]
        sp, ok = spill_append(sp, jnp.asarray(ks), jnp.asarray(ks + 1),
                              jnp.ones((len(ks),), bool))
        all_keys.extend(ks[np.asarray(ok)].tolist())
    doomed = rng.choice(np.array(all_keys, np.uint64), kills, replace=False)
    sp, eff = spill_discard(sp, jnp.asarray(doomed),
                            jnp.ones((kills,), bool))
    assert bool(np.asarray(eff).all())
    live = sorted(set(all_keys) - set(doomed.tolist()))
    return sp, np.array(live, np.uint64), doomed


def test_run_offsets_boundaries():
    sp, _, _ = _spill_with_runs()
    off = np.asarray(run_offsets(sp.run_start, sp.n))
    assert off.shape == (MAX_SPILL_RUNS + 1,)
    n = int(sp.n)
    starts = np.flatnonzero(np.asarray(sp.run_start)[:n])
    n_runs = len(starts)
    assert off[:n_runs].tolist() == starts.tolist()
    assert (off[n_runs:] == n).all()           # pads + sentinel = cursor
    assert (np.diff(off) >= 0).all()
    # every run slice is sorted (the property the binary search leans on)
    keys = np.asarray(sp.keys)
    for r in range(n_runs):
        run = keys[off[r]:off[r + 1]]
        assert (np.diff(run.astype(np.float64)) > 0).all()


def test_spill_per_run_probe_matches_flat_compare():
    sp, live, doomed = _spill_with_runs()
    queries = np.concatenate([live, doomed,
                              np.array([123456789, KEY_INF], np.uint64)])
    found, vals = spill_find_ref(sp, jnp.asarray(queries))
    # oracle: the pre-fusion masked flat compare
    alive = ~np.asarray(sp.dead) & (np.asarray(sp.keys) != KEY_INF)
    eq = (np.asarray(sp.keys)[None, :] == queries[:, None]) & alive[None, :]
    want = eq.any(axis=1) & (queries != KEY_INF)
    assert (np.asarray(found) == want).all()
    idx = np.argmax(eq, axis=1)
    wvals = np.where(want, np.asarray(sp.vals)[idx], 0)
    assert (np.asarray(vals) == wvals).all()


def test_spill_probe_handles_duplicate_dead_copies():
    """A key whose old copy is tombstoned in an earlier run and live in a
    later one must resolve to the live cell (promote-then-evict churn)."""
    from repro.store.tiers import spill_append, spill_discard
    sp = spill_init(64)
    ks = np.array([10, 20, 30], np.uint64)
    sp, _ = spill_append(sp, jnp.asarray(ks), jnp.asarray(ks + 1),
                         jnp.ones((3,), bool))
    sp, _ = spill_discard(sp, jnp.asarray(np.array([20], np.uint64)),
                          jnp.ones((1,), bool))
    sp, _ = spill_append(sp, jnp.asarray(np.array([20], np.uint64)),
                         jnp.asarray(np.array([99], np.uint64)),
                         jnp.ones((1,), bool))
    found, vals = spill_find_ref(sp, jnp.asarray(np.array([20], np.uint64)))
    assert bool(found[0]) and int(vals[0]) == 99


def test_run_count_stays_under_cap():
    """Appending more batches than MAX_SPILL_RUNS must trigger the
    run-merging maintenance, never exceed the boundary plane."""
    be = get_backend("tiered3")
    st = be.init(8, hot_bucket=2, hot_frac=4, spill_cap=4096)
    rng = np.random.default_rng(11)
    step = jax.jit(be.apply)
    for i in range(MAX_SPILL_RUNS + 8):
        ks = np.unique(rng.integers(1, 2**62, 24, dtype=np.uint64))[:20]
        st, res = step(st, make_plan(
            np.full(len(ks), OP_INSERT, np.int32), ks, ks + 1))
        assert bool(np.asarray(res.ok).all())
        runs = int(np.asarray(st.spill.run_start).sum())
        assert runs <= MAX_SPILL_RUNS, (i, runs)
    # everything is still findable after the forced merges
    assert int(be.stats(st)["spill_size"]) > 0


def test_pinned_host_guard_is_noop_off_tpu():
    from repro.store.tiers import _pin_spill_host
    sp = spill_init(32)
    sp2 = _pin_spill_host(sp)
    if jax.default_backend() != "tpu":
        assert sp2 is sp                      # guarded no-op on CPU CI
    assert_states_equal(sp, sp2, "pin")


# ---------------------------------------------------------------------------
# fused probe vs unfused chain, probe-level and apply-level
# ---------------------------------------------------------------------------

def _loaded_state(name, seed=7):
    """A tier state with all tiers populated (warm overflowed on depth 3)."""
    be = get_backend(name)
    st = be.init(32, hot_bucket=4, hot_frac=8)
    rng = np.random.default_rng(seed)
    ks = np.unique(rng.integers(1, 2**62, 80, dtype=np.uint64))[:60]
    st, _ = be.apply(st, make_plan(np.full(len(ks), OP_INSERT, np.int32),
                                   ks, ks + 1))
    return be, st, ks


@pytest.mark.parametrize("warm_layout", WARM_LAYOUTS)
@pytest.mark.parametrize("name", ["tiered3", "hash+skiplist"])
def test_tier_find_matches_unfused_probes(name, warm_layout):
    """Probe-level parity: one tier_find call vs the three (or two)
    separate exec probes, same state, every runnable mode — under BOTH
    warm layouts (the unfused warm probe is the matching layout's walk:
    `skiplist_find` or `bskiplist_find`)."""
    _, st, ks = _loaded_state(name)
    rng = np.random.default_rng(5)
    queries = jnp.asarray(np.concatenate(
        [ks[:20], rng.integers(1, 2**62, 12, dtype=np.uint64)]))
    warm_find = (exec_.bskiplist_find if warm_layout == "block"
                 else exec_.skiplist_find)
    for mode in MODES:
        (fh, vh, ch), (fc, vc), (fs, vs) = exec_.tier_find(
            st.hot, st.cold, st.spill, queries, mode,
            warm_layout=warm_layout)
        rh, rvh, rch = exec_.hash_find_cols(st.hot, queries, mode)
        rc, rvc, _ = warm_find(st.cold, queries, mode)
        if st.spill is not None:
            rs, rvs = exec_.spill_find(st.spill, queries, mode)
        else:
            rs = jnp.zeros(queries.shape, bool)
            rvs = jnp.zeros(queries.shape, jnp.uint64)
        # raw parity on the hot tier (col included, it feeds LRU stamps)
        assert (np.asarray(fh) == np.asarray(rh)).all(), mode
        assert (np.asarray(vh) == np.asarray(rvh)).all(), mode
        hot_hit = np.asarray(rh)
        assert (np.asarray(ch)[hot_hit] == np.asarray(rch)[hot_hit]).all()
        # fall-through masking: lower tiers only count on upper-tier miss
        assert (np.asarray(fc) == (np.asarray(rc) & ~hot_hit)).all(), mode
        miss2 = ~hot_hit & ~np.asarray(rc)
        assert (np.asarray(fs) == (np.asarray(rs) & miss2)).all(), mode
        cold_hit = np.asarray(fc)
        assert (np.asarray(vc)[cold_hit]
                == np.asarray(rvc)[cold_hit]).all(), mode
        sp_hit = np.asarray(fs)
        assert (np.asarray(vs)[sp_hit] == np.asarray(rvs)[sp_hit]).all()
        # every preloaded key is found in exactly one tier
        total = (np.asarray(fh) | np.asarray(fc) | np.asarray(fs))
        assert total[:20].all(), mode


@pytest.mark.parametrize("name", TIERED)
def test_fused_apply_bit_identical_to_unfused(name):
    """Apply-level parity: the registered (fused) backend and an unfused
    twin produce identical results AND identical residency (full state
    pytree) for the same plan stream, in every runnable mode."""
    plans = _mixed_plans()
    for mode in MODES:
        fused = get_backend(name)
        unf = unfused_twin(name)
        with exec_.exec_mode(mode):
            sf = fused.init(64, hot_bucket=4, hot_frac=8)
            su = unf.init(64, hot_bucket=4, hot_frac=8)
            step_f = jax.jit(fused.apply)
            step_u = jax.jit(unf.apply)
            for rnd, p in enumerate(plans):
                sf, rf = step_f(sf, p)
                su, ru = step_u(su, p)
                assert (np.asarray(rf.ok) == np.asarray(ru.ok)).all(), \
                    (name, mode, rnd)
                assert (np.asarray(rf.vals) == np.asarray(ru.vals)).all(), \
                    (name, mode, rnd)
                assert_states_equal(sf, su, (name, mode, rnd))


@pytest.mark.parametrize("name", ["tiered3/lru"])
def test_fused_residency_bit_identical_across_modes(name):
    """The fused path keeps the residency-determinism contract across exec
    modes (the unfused analogue lives in test_tiers3)."""
    be = get_backend(name)
    states = {}
    for mode in MODES:
        with exec_.exec_mode(mode):
            st = be.init(64, hot_bucket=4, hot_frac=8)
            step = jax.jit(be.apply)
            for p in _mixed_plans(seed=33):
                st, _ = step(st, p)
        states[mode] = st
    ref = states[MODES[0]]
    for mode, st in states.items():
        assert_states_equal(ref, st, (name, mode))


@pytest.mark.parametrize("name", ["tiered3", "tiered3/b128"])
def test_fused_find_is_one_dispatch(name):
    """The acceptance criterion, measured: in fused mode the FIND chain is
    ONE exec dispatch per plan regardless of tier depth (the unfused chain
    pays one per tier), and a whole fused apply traces 2 dispatches total
    (ONE tier_apply update + ONE FIND-phase probe) against the unfused 6
    (2 insert probes + 1 hot_update + 3 FIND probes). The warm layout is
    an execution knob: `tiered3/b128` has the SAME budgets — the blocked
    walk changes steps per dispatch, never dispatches per plan."""
    be = get_backend(name)
    wl = be.warm_layout
    _, st, _ = _loaded_state(name)
    q = jnp.asarray(np.arange(1, 33, dtype=np.uint64))
    with exec_.measure_dispatches() as m_f:
        exec_.tier_find(st.hot, st.cold, st.spill, q, warm_layout=wl)
    assert (m_f.n, m_f.probe, m_f.update) == (1, 1, 0)
    warm_find = (exec_.bskiplist_find if wl == "block"
                 else exec_.skiplist_find)
    with exec_.measure_dispatches() as m_u:
        exec_.hash_find_cols(st.hot, q)
        warm_find(st.cold, q)
        exec_.spill_find(st.spill, q)
    assert (m_u.n, m_u.probe, m_u.update) == (3, 3, 0)

    plan = make_plan(np.full(32, OP_FIND, np.int32), np.asarray(q))
    fused, unf = get_backend(name), unfused_twin(name)
    with exec_.measure_dispatches() as m_f:
        jax.make_jaxpr(fused.apply)(st, plan)
    assert (m_f.n, m_f.probe, m_f.update) == (2, 1, 1), \
        "fused apply: ONE tier_apply update + ONE FIND-phase probe"
    with exec_.measure_dispatches() as m_u:
        jax.make_jaxpr(unf.apply)(st, plan)
    assert (m_u.n, m_u.probe, m_u.update) == (6, 5, 1), \
        "unfused apply: 2 insert probes + hot_update + 3 FIND probes"


def test_tier_find_empty_batch_all_modes():
    _, st, _ = _loaded_state("tiered3")
    none = jnp.zeros((0,), jnp.uint64)
    for mode in MODES:
        (fh, vh, ch), (fc, vc), (fs, vs) = exec_.tier_find(
            st.hot, st.cold, st.spill, none, mode)
        for a in (fh, vh, ch, fc, vc, fs, vs):
            assert a.shape == (0,), mode


# ---------------------------------------------------------------------------
# the two-level split-order probe kernel
# ---------------------------------------------------------------------------

def test_twolevel_splitorder_probe_matches_reference():
    from repro.core import splitorder as so
    from repro.kernels.splitorder_probe.ops import twolevel_splitorder_probe
    rng = np.random.default_rng(17)
    h = so.twolevel_splitorder_init(8, 64, 2)
    ks = np.unique(rng.integers(1, 2**62, 200, dtype=np.uint64))[:150]
    h, ins, _ = so.twolevel_splitorder_insert(h, jnp.asarray(ks),
                                              jnp.asarray(ks + 1))
    assert bool(np.asarray(ins).all())
    queries = np.concatenate([ks[:64], rng.integers(1, 2**62, 64,
                                                    dtype=np.uint64),
                              np.array([KEY_INF], np.uint64)])
    want_f, want_v = so.twolevel_splitorder_find(h, jnp.asarray(queries))
    got_f, got_v = twolevel_splitorder_probe(h, jnp.asarray(queries),
                                             interpret=True)
    assert (np.asarray(got_f) == np.asarray(want_f)).all()
    assert (np.asarray(got_v) == np.asarray(want_v)).all()


def test_twolevel_splitorder_backend_parity_modes():
    """Backend-level: interpret mode (kernel) == jnp mode (reference) for a
    mixed plan stream, including the post-resize layout."""
    name = "twolevel_splitorder"
    plans = _mixed_plans(seed=9, n_rounds=3)
    outs = {}
    for mode in MODES:
        be = get_backend(name)
        with exec_.exec_mode(mode):
            st = be.init(2048)
            step = jax.jit(be.apply)
            rows = []
            for p in plans:
                st, res = step(st, p)
                rows.append((np.asarray(res.ok), np.asarray(res.vals)))
        outs[mode] = rows
    ref = outs[MODES[0]]
    for mode in MODES[1:]:
        for (ok_r, v_r), (ok, v) in zip(ref, outs[mode]):
            assert (ok_r == ok).all(), mode
            assert (v_r == v).all(), mode
