"""Unit + property tests for the paper's core data structures.

Every structure is validated against a pure-Python reference model over
random operation sequences (hypothesis), plus the structural invariants the
paper states (1-2-3-4 criterion, FIFO order, recycling accounting, ABA
detection, split-order zero-movement growth).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # deterministic fallback (seeded examples)
    from _hypothesis_fallback import given, settings, strategies as st

import jax.numpy as jnp

import repro  # noqa: F401  (enables x64)
from repro.core import bits
from repro.core.blockpool import (blockpool_init, expected_blocks_in_use,
                                  handle_valid, pool_alloc, pool_free)
from repro.core.det_skiplist import (check_invariants, compact, delete_batch,
                                     find_batch, insert_batch, range_query,
                                     skiplist_init)
from repro.core.hashtable import (fixed_delete, fixed_find, fixed_init,
                                  fixed_insert, twolevel_find, twolevel_init,
                                  twolevel_insert)
from repro.core.ringqueue import (pop_batch, push_batch, queue_init,
                                  queue_size)
from repro.core import rand_skiplist as rsl
from repro.core.splitorder import (splitorder_find, splitorder_init,
                                   splitorder_insert, splitorder_slot_bounds,
                                   twolevel_splitorder_find,
                                   twolevel_splitorder_init,
                                   twolevel_splitorder_insert)

U64 = st.integers(min_value=1, max_value=2**62)


def u64(xs):
    return jnp.asarray(np.array(xs, dtype=np.uint64))


# ---------------------------------------------------------------------------
# bits
# ---------------------------------------------------------------------------

class TestBits:
    def test_bitrev_involution(self):
        xs = u64([0, 1, 2, 3, 0xDEADBEEF, 2**63, 2**64 - 1])
        assert (bits.bitrev64(bits.bitrev64(xs)) == xs).all()

    def test_bitrev_low_bits_to_top(self):
        # split-ordering: low m bits become the top m bits (segment prefix)
        x = u64([0b101])
        r = int(bits.bitrev64(x)[0])
        assert r >> 61 == 0b101

    def test_splitmix_scrambles(self):
        xs = u64(np.arange(1024))
        hs = np.asarray(bits.hash64(xs))
        assert len(np.unique(hs)) == 1024
        # low bits should be balanced (used as slot index)
        assert 400 < int(np.sum(hs & 1)) < 624

    def test_geometric_height_distribution(self):
        xs = u64(np.arange(1, 40001))
        h = np.asarray(bits.geometric_height(xs, 8))
        frac1 = np.mean(h >= 1)
        assert 0.2 < frac1 < 0.3          # P(h>=1) = 1/4
        frac2 = np.mean(h >= 2)
        assert 0.04 < frac2 < 0.09        # 1/16

    def test_pack_unpack(self):
        k = jnp.asarray(np.array([1, 7, 2**31], dtype=np.uint32))
        p = jnp.asarray(np.array([9, 0, 2**32 - 1], dtype=np.uint32))
        w = bits.pack_key_payload(k, p)
        k2, p2 = bits.unpack_key_payload(w)
        assert (k2 == k).all() and (p2 == p).all()

    def test_priority_key_orders(self):
        a = bits.make_priority_key(jnp.uint32(1), jnp.uint32(999))
        b = bits.make_priority_key(jnp.uint32(2), jnp.uint32(0))
        assert int(a) < int(b)


# ---------------------------------------------------------------------------
# deterministic skiplist (paper §II)
# ---------------------------------------------------------------------------

class TestDetSkiplist:
    def _fresh(self, cap=256):
        return skiplist_init(cap)

    def test_insert_find_roundtrip(self):
        s = self._fresh()
        ks = u64([10, 20, 30, 40, 50])
        s, ins, ex = insert_batch(s, ks, ks * jnp.uint64(2))
        assert ins.all() and not ex.any()
        f, v, _ = find_batch(s, ks)
        assert f.all()
        assert (v == ks * jnp.uint64(2)).all()

    def test_duplicate_returns_existed(self):
        s = self._fresh()
        s, _, _ = insert_batch(s, u64([7]), u64([1]))
        s, ins, ex = insert_batch(s, u64([7]), u64([2]))
        assert not ins.any() and ex.all()
        _, v, _ = find_batch(s, u64([7]))
        assert int(v[0]) == 1  # insert-if-absent keeps the original

    def test_in_batch_duplicates_first_lane_wins(self):
        s = self._fresh()
        s, ins, ex = insert_batch(s, u64([5, 5, 5]), u64([1, 2, 3]))
        assert int(ins.sum()) == 1 and int(ex.sum()) == 2
        _, v, _ = find_batch(s, u64([5]))
        assert int(v[0]) == 1  # deterministic linearization: lowest lane

    def test_delete_then_absent_and_revive(self):
        s = self._fresh()
        s, _, _ = insert_batch(s, u64([3, 4]), u64([30, 40]))
        s, d = delete_batch(s, u64([3]))
        assert d.all()
        f, _, _ = find_batch(s, u64([3, 4]))
        assert not bool(f[0]) and bool(f[1])
        # revive: re-inserting a tombstoned key works
        s, ins, _ = insert_batch(s, u64([3]), u64([99]))
        assert ins.all()
        f, v, _ = find_batch(s, u64([3]))
        assert bool(f[0]) and int(v[0]) == 99

    def test_compaction_preserves_membership(self):
        s = self._fresh(128)
        ks = u64(np.arange(1, 65))
        s, _, _ = insert_batch(s, ks, ks)
        s, _ = delete_batch(s, u64(np.arange(1, 33)))  # 50% marked -> compact
        assert int(s.n_marked) == 0  # compaction ran
        f, _, _ = find_batch(s, ks)
        assert int(f.sum()) == 32
        assert not f[:32].any() and f[32:].all()
        inv = check_invariants(s)
        assert all(v == 0 for v in inv.values()), inv

    def test_capacity_overflow_fails_cleanly(self):
        s = self._fresh(8)
        ks = u64(np.arange(1, 13))
        s, ins, _ = insert_batch(s, ks, ks)
        assert int(ins.sum()) == 8
        assert int(s.n_term) == 8
        assert all(v == 0 for v in check_invariants(s).values())

    def test_range_query(self):
        s = self._fresh(128)
        ks = u64(np.arange(10, 100, 10))
        s, _, _ = insert_batch(s, ks, ks)
        s, _ = delete_batch(s, u64([30]))
        cnt, keys, _, valid = range_query(s, u64([15]), u64([65]), 8)
        got = sorted(int(k) for k, m in zip(np.asarray(keys[0]), np.asarray(valid[0])) if m)
        assert got == [20, 40, 50, 60]
        assert int(cnt[0]) == 4

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["ins", "del", "find"]),
                              st.lists(U64, min_size=1, max_size=12)),
                    min_size=1, max_size=8))
    def test_model_based(self, ops):
        """Random op sequences match a dict reference model; invariants hold."""
        s = self._fresh(512)
        model = {}
        for kind, keys in ops:
            ks = u64(keys)
            if kind == "ins":
                vs = ks + jnp.uint64(1)
                s, ins, ex = insert_batch(s, ks, vs)
                for i, k in enumerate(keys):
                    if k not in model and keys.index(k) == i:
                        model[k] = k + 1
            elif kind == "del":
                s, _ = delete_batch(s, ks)
                for k in keys:
                    model.pop(k, None)
            else:
                f, v, _ = find_batch(s, ks)
                for i, k in enumerate(keys):
                    assert bool(f[i]) == (k in model), (k, kind)
                    if k in model:
                        assert int(v[i]) == model[k]
        assert int(s.size()) == len(model)
        probe = u64(list(model.keys())[:64]) if model else None
        if probe is not None:
            f, _, _ = find_batch(s, probe)
            assert f.all()
        inv = check_invariants(s)
        assert all(v == 0 for v in inv.values()), inv

    def test_search_cost_is_guaranteed_log(self):
        # structural: number of levels is static, independent of data
        s = self._fresh(4096)
        assert s.num_levels == len(s.level_keys)
        ks = u64(np.random.default_rng(1).integers(1, 2**60, 2000, dtype=np.uint64))
        s, _, _ = insert_batch(s, ks, ks)
        inv = check_invariants(s)
        assert all(v == 0 for v in inv.values()), inv
        # every level at most half the previous (arity >= 2)
        counts = np.asarray(s.level_count)
        prev = int(s.n_term)
        for c in counts:
            assert c <= (prev + 2) // 2 + 1
            prev = int(c)


# ---------------------------------------------------------------------------
# randomized skiplist (paper §VI, table IV comparator)
# ---------------------------------------------------------------------------

class TestRandSkiplist:
    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["ins", "del", "find"]),
                              st.lists(U64, min_size=1, max_size=10)),
                    min_size=1, max_size=6))
    def test_model_based(self, ops):
        s = rsl.rand_skiplist_init(512)
        model = {}
        for kind, keys in ops:
            ks = u64(keys)
            if kind == "ins":
                s, _, _ = rsl.insert_batch(s, ks, ks + jnp.uint64(1))
                for i, k in enumerate(keys):
                    if k not in model and keys.index(k) == i:
                        model[k] = k + 1
            elif kind == "del":
                s, _ = rsl.delete_batch(s, ks)
                for k in keys:
                    model.pop(k, None)
            else:
                f, v, _ = rsl.find_batch(s, ks)
                for i, k in enumerate(keys):
                    assert bool(f[i]) == (k in model)
                    if k in model:
                        assert int(v[i]) == model[k]
        assert int(s.size()) == len(model)

    def test_bulk_and_absent(self):
        rng = np.random.default_rng(7)
        ks = u64(rng.integers(1, 2**60, 300, dtype=np.uint64))
        s = rsl.rand_skiplist_init(1024)
        s, ins, _ = rsl.insert_batch(s, ks, ks)
        f, _, _ = rsl.find_batch(s, ks)
        assert f.all()
        absent = u64(rng.integers(1, 2**60, 100, dtype=np.uint64))
        fa, _, _ = rsl.find_batch(s, absent)
        present = set(np.asarray(ks).tolist())
        expect = np.array([int(a) in present for a in np.asarray(absent)])
        assert (np.asarray(fa) == expect).all()


# ---------------------------------------------------------------------------
# lock-free queue (paper §III)
# ---------------------------------------------------------------------------

class TestRingQueue:
    def test_fifo_order_across_blocks(self):
        q = queue_init(max_blocks=6, block_size=4)
        vals = jnp.arange(100, 118, dtype=jnp.uint64)
        q, ok = push_batch(q, vals, jnp.ones(18, bool))
        assert ok.all()
        q, out, got = pop_batch(q, 18)
        assert got.all()
        assert (out == vals).all()

    def test_pop_empty(self):
        q = queue_init(4, 4)
        q, _, got = pop_batch(q, 3)
        assert not got.any()

    def test_block_exhaustion_fails_tail_lanes(self):
        q = queue_init(max_blocks=2, block_size=4)  # capacity 8 max
        vals = jnp.arange(12, dtype=jnp.uint64)
        q, ok = push_batch(q, vals, jnp.ones(12, bool))
        n_ok = int(ok.sum())
        assert n_ok < 12 and ok[:n_ok].all() and not ok[n_ok:].any()  # FIFO-safe suffix failure
        q, out, got = pop_batch(q, 12)
        assert int(got.sum()) == n_ok
        assert (np.asarray(out[:n_ok]) == np.arange(n_ok)).all()

    def test_recycling_bumps_counter(self):
        q = queue_init(4, 4)
        for round_ in range(5):
            q, ok = push_batch(q, jnp.arange(8, dtype=jnp.uint64), jnp.ones(8, bool))
            assert ok.all()
            q, _, got = pop_batch(q, 8)
            assert got.all()
        assert int(np.asarray(q.recycles).sum()) >= 4  # blocks were recycled
        assert int(queue_size(q)) == 0

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.tuples(st.booleans(), st.integers(1, 9)), min_size=1, max_size=14))
    def test_model_based_fifo(self, ops):
        from collections import deque
        q = queue_init(max_blocks=16, block_size=4)
        model = deque()
        counter = 0
        for is_push, n in ops:
            if is_push:
                vs = np.arange(counter, counter + n, dtype=np.uint64)
                counter += n
                q, ok = push_batch(q, jnp.asarray(vs), jnp.ones(n, bool))
                for v, o in zip(vs, np.asarray(ok)):
                    if o:
                        model.append(int(v))
            else:
                q, out, got = pop_batch(q, n)
                for v, g in zip(np.asarray(out), np.asarray(got)):
                    if g:
                        assert model and int(v) == model.popleft()
            assert int(queue_size(q)) == len(model)
        # fe discipline: every FULL cell lies in [front, rear) of its block
        fe = np.asarray(q.fe)
        fr, re = np.asarray(q.front), np.asarray(q.rear)
        for b in range(q.max_blocks):
            full_cols = np.where(fe[b] == 1)[0]
            for c in full_cols:
                assert fr[b] <= c < re[b], (b, c, fr[b], re[b])


# ---------------------------------------------------------------------------
# block pool (paper §V)
# ---------------------------------------------------------------------------

class TestBlockPool:
    def test_alloc_unique_and_exhaustion(self):
        p = blockpool_init(8)
        p, ids, _, got = pool_alloc(p, jnp.ones(12, bool))
        taken = np.asarray(ids)[np.asarray(got)]
        assert len(np.unique(taken)) == 8 and int(got.sum()) == 8

    def test_aba_detection(self):
        p = blockpool_init(4)
        p, ids, h1, _ = pool_alloc(p, jnp.ones(2, bool))
        p = pool_free(p, ids, jnp.ones(2, bool))
        p, ids2, h2, _ = pool_alloc(p, jnp.ones(2, bool))
        assert not handle_valid(p, h1).any()   # stale generation
        assert handle_valid(p, h2).all()

    def test_live_blocks_bounded_by_paper_analysis(self):
        # paper: blocks in use <= ceil(news_outstanding / C) with C = 1 block
        # per request here; exercise interleavings and check live count
        rng = np.random.default_rng(3)
        p = blockpool_init(32)
        live = 0
        held = []
        for _ in range(30):
            if rng.random() < 0.6 or not held:
                p, ids, _, got = pool_alloc(p, jnp.ones(3, bool))
                new = [int(i) for i, g in zip(np.asarray(ids), np.asarray(got)) if g]
                held.extend(new)
                live += len(new)
            else:
                k = min(len(held), 2)
                give = [held.pop() for _ in range(k)]
                p = pool_free(p, jnp.asarray(give, jnp.int32), jnp.ones(k, bool))
                live -= k
            assert int(np.asarray(p.in_use).sum()) == live == len(held)

    def test_expected_blocks_formula(self):
        # eq. (5) sanity: alternating new/delete ~1 block; all-news-first ~N/C
        assert expected_blocks_in_use(8, 8) < expected_blocks_in_use(8, 1)
        assert expected_blocks_in_use(4, 100) <= 1.0


# ---------------------------------------------------------------------------
# hash tables (paper §VII/VIII)
# ---------------------------------------------------------------------------

class TestHashTables:
    @settings(max_examples=15, deadline=None)
    @given(st.lists(U64, min_size=1, max_size=60, unique=True))
    def test_fixed_model(self, keys):
        h = fixed_init(16, 16)
        ks = u64(keys)
        h, ins, ex = fixed_insert(h, ks, ks + jnp.uint64(5))
        assert not ex.any()
        f, v = fixed_find(h, ks)
        ok = np.asarray(ins)
        assert (np.asarray(f) == ok).all()  # failed lanes (bucket full) absent
        assert (np.asarray(v)[ok] == (np.asarray(ks) + 5)[ok]).all()

    def test_fixed_delete_and_reinsert(self):
        h = fixed_init(8, 8)
        ks = u64([1, 2, 3, 4, 5])
        h, _, _ = fixed_insert(h, ks, ks)
        h, d = fixed_delete(h, u64([2, 4]))
        assert d.all()
        f, _ = fixed_find(h, ks)
        assert int(f.sum()) == 3
        h, ins, _ = fixed_insert(h, u64([2]), u64([22]))
        assert ins.all()
        f, v = fixed_find(h, u64([2]))
        assert bool(f[0]) and int(v[0]) == 22

    @settings(max_examples=15, deadline=None)
    @given(st.lists(U64, min_size=1, max_size=80, unique=True))
    def test_twolevel_model(self, keys):
        h = twolevel_init(8, 4, 8, 4, pool_blocks=32)
        ks = u64(keys)
        h, ins, ex = twolevel_insert(h, ks, ks + jnp.uint64(9))
        assert not ex.any()
        f, v = twolevel_find(h, ks)
        ok = np.asarray(ins)
        assert (np.asarray(f) == ok).all()
        assert (np.asarray(v)[ok] == (np.asarray(ks) + 9)[ok]).all()

    def test_twolevel_expands_past_threshold(self):
        h = twolevel_init(2, 2, 8, 8, pool_blocks=8)  # tiny L1 forces overflow
        ks = u64(np.arange(1, 41))
        h, ins, _ = twolevel_insert(h, ks, ks)
        assert int((np.asarray(h.l2_block) >= 0).sum()) >= 1
        assert int(ins.sum()) > 4  # more than L1 alone could hold

    def test_insert_existing_reports_existed(self):
        h = twolevel_init(8, 4, 8, 4, pool_blocks=8)
        h, _, _ = twolevel_insert(h, u64([42]), u64([1]))
        h, ins, ex = twolevel_insert(h, u64([42]), u64([2]))
        assert not ins.any() and ex.all()


# ---------------------------------------------------------------------------
# split-order tables (paper §VII/VIII)
# ---------------------------------------------------------------------------

class TestSplitOrder:
    @settings(max_examples=15, deadline=None)
    @given(st.lists(U64, min_size=1, max_size=60, unique=True))
    def test_model(self, keys):
        h = splitorder_init(256, 4, max_load=4)
        ks = u64(keys)
        h, ins, ex = splitorder_insert(h, ks, ks + jnp.uint64(3))
        assert ins.all() and not ex.any()
        f, v = splitorder_find(h, ks)
        assert f.all()
        assert (v == ks + jnp.uint64(3)).all()

    def test_growth_without_movement(self):
        h = splitorder_init(512, 2, max_load=2)
        ks = u64(np.arange(1, 32))
        for chunk in np.array_split(np.asarray(ks), 4):
            before = np.asarray(h.rk[: int(h.n)]).copy()
            h, _, _ = splitorder_insert(h, jnp.asarray(chunk), jnp.asarray(chunk))
            after = np.asarray(h.rk[: int(h.n)])
            # every old entry survives growth, and since both snapshots are
            # sorted by reversed hash, relative order is preserved for free:
            # zero-migration resizing, the paper's split-order claim
            assert np.isin(before, after).all()
        assert int(h.n_slots) > 2  # grew
        f, _ = splitorder_find(h, ks)
        assert f.all()

    def test_slot_bounds_cover_keys(self):
        h = splitorder_init(256, 4, max_load=4)
        ks = u64(np.arange(1, 65))
        h, _, _ = splitorder_insert(h, ks, ks)
        lo, hi = splitorder_slot_bounds(h, ks)
        rkq = np.asarray(bits.bitrev64(bits.hash64(ks)))
        rk = np.asarray(h.rk)
        for i in range(len(ks)):
            seg = rk[int(lo[i]): int(hi[i])]
            assert rkq[i] in seg

    @settings(max_examples=10, deadline=None)
    @given(st.lists(U64, min_size=1, max_size=40, unique=True))
    def test_twolevel_model(self, keys):
        h = twolevel_splitorder_init(4, 128, 2, max_load=4)
        ks = u64(keys)
        h, ins, ex = twolevel_splitorder_insert(h, ks, ks + jnp.uint64(7))
        assert ins.all() and not ex.any()
        f, v = twolevel_splitorder_find(h, ks)
        assert f.all()
        assert (v == ks + jnp.uint64(7)).all()
