"""Launches the 8-device sharded-store validation as a subprocess (device
count must be fixed before JAX initializes, so it cannot share this process).
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_sharded_store_multidevice():
    prog = os.path.join(ROOT, "tests", "multidev", "store_prog.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, prog], env=env, capture_output=True,
                         text=True, timeout=1500)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "STORE-OK" in out.stdout
    assert "RANGE-OK" in out.stdout
    assert "UNEVEN-OK" in out.stdout
    assert "RESIDENCY-OK" in out.stdout
    assert "FUSED-OK" in out.stdout
    assert "BSKIP-OK" in out.stdout
    assert "PQ-OK" in out.stdout
