"""Execution-layer parity: the layered-store correctness contract.

For EVERY registered backend (including the §IX tiered `hash+skiplist`
config), `apply` and `scan` results must be BIT-IDENTICAL across all
runnable `repro.store.exec` modes — pure-jnp reference, Pallas interpret,
and (on TPU) Pallas compiled. Mode choice is a performance knob only; this
suite is what makes that a contract instead of a hope.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401  (enables x64)
from repro.store import (OP_DELETE, OP_FIND, OP_INSERT, available_backends,
                         get_backend, make_plan)
from repro.store import exec as exec_

ALL_BACKENDS = available_backends()
MODES = exec_.runnable_modes()
KERNELIZED = ("det_skiplist", "fixed_hash", "hash+skiplist", "tiered3/lru",
              "twolevel_splitorder")


def _mixed_plans(seed=2, n_rounds=4, width=48, pool_size=64):
    """Overlapping insert/find/delete workload (same shape as
    test_store_api): duplicates in-batch, deletes colliding with inserts,
    a few masked lanes."""
    rng = np.random.default_rng(seed)
    pool = rng.integers(1, 2**62, pool_size, dtype=np.uint64)
    plans = []
    for _ in range(n_rounds):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], width,
                         p=[0.5, 0.35, 0.15]).astype(np.int32)
        keys = rng.choice(pool, width)
        mask = rng.random(width) > 0.05
        plans.append(make_plan(ops, keys, keys + 1, mask))
    return plans


def _run_apply(name, mode, plans, capacity=2048, **init_kw):
    be = get_backend(name)
    with exec_.exec_mode(mode):
        st = be.init(capacity, **init_kw)
        outs = []
        for p in plans:
            st, res = be.apply(st, p)
            outs.append((np.asarray(res.ok), np.asarray(res.vals)))
        stats = {k: int(v) for k, v in be.stats(st).items()}
    return st, outs, stats


def test_modes_cover_platform():
    assert "jnp" in MODES and "interpret" in MODES
    # `pallas` (compiled) participates exactly when the platform runs it
    assert ("pallas" in MODES) == exec_.pallas_available()


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_apply_bit_identical_across_modes(name):
    plans = _mixed_plans()
    _, ref_outs, ref_stats = _run_apply(name, MODES[0], plans)
    for mode in MODES[1:]:
        _, outs, stats = _run_apply(name, mode, plans)
        assert stats == ref_stats, (name, mode)
        for rnd, ((ok_r, v_r), (ok, v)) in enumerate(zip(ref_outs, outs)):
            assert (ok_r == ok).all(), (name, mode, rnd, "ok")
            assert (v_r == v).all(), (name, mode, rnd, "vals")


@pytest.mark.parametrize("name", [n for n in ALL_BACKENDS
                                  if get_backend(n).ordered])
def test_scan_bit_identical_across_modes(name):
    rng = np.random.default_rng(4)
    ks = np.unique(rng.integers(1, 2**40, 50, dtype=np.uint64))
    plan = make_plan(np.full(len(ks), OP_INSERT, np.int32), ks, ks + 3)
    lo = jnp.asarray(np.array([0, int(ks[5])], np.uint64))
    hi = jnp.asarray(np.array([2**41, int(ks[30])], np.uint64))
    ref = None
    for mode in MODES:
        be = get_backend(name)
        with exec_.exec_mode(mode):
            st, _ = be.apply(be.init(512), plan)
            out = [np.asarray(a) for a in be.scan(st, lo, hi, 64)]
        if ref is None:
            ref = out
        else:
            for a, b in zip(ref, out):
                assert (a == b).all(), (name, mode)


@pytest.mark.parametrize("name", KERNELIZED)
def test_tiered_and_kernelized_via_jitted_apply(name):
    """The dispatch survives jit: one jitted apply per mode, same bits
    (the engine path exercises the same trace-time mode capture)."""
    plans = _mixed_plans(seed=6, n_rounds=2)
    be = get_backend(name)
    ref = None
    for mode in MODES:
        with exec_.exec_mode(mode):
            st = be.init(1024)
            step = jax.jit(be.apply)
            outs = []
            for p in plans:
                st, res = step(st, p)
                outs.append((np.asarray(res.ok), np.asarray(res.vals)))
        if ref is None:
            ref = outs
        else:
            for (ok_r, v_r), (ok, v) in zip(ref, outs):
                assert (ok_r == ok).all(), (name, mode)
                assert (v_r == v).all(), (name, mode)


def test_empty_query_batch_all_modes():
    """Zero-width query batches work in every mode: the kernel wrappers
    must match the jnp references' empty-batch contract instead of crashing
    on tile=0 (batch UPDATE primitives require width > 0 in every mode —
    that pre-dates the exec layer and is mode-independent)."""
    from repro.core.det_skiplist import skiplist_init
    from repro.core.hashtable import fixed_init
    none = jnp.zeros((0,), jnp.uint64)
    s = skiplist_init(128)
    h = fixed_init(16, 4)
    for mode in MODES:
        f, v, i = exec_.skiplist_find(s, none, mode)
        assert f.shape == v.shape == i.shape == (0,), mode
        f, v = exec_.hash_find(h, none, mode)
        assert f.shape == v.shape == (0,), mode


def test_mode_plumbing():
    assert exec_.get_mode() in exec_.MODES
    before = exec_.get_mode()
    with exec_.exec_mode("interpret"):
        assert exec_.get_mode() == "interpret"
        with exec_.exec_mode(None):          # None = keep current
            assert exec_.get_mode() == "interpret"
    assert exec_.get_mode() == before
    with pytest.raises(ValueError, match="unknown store exec mode"):
        exec_.set_mode("cuda")
    with pytest.raises(ValueError):
        with exec_.exec_mode("nope"):
            pass


def test_engine_exec_mode_single_device():
    """StoreEngine bakes the mode into its jitted step; results match the
    jnp engine bit-for-bit on a 1-device mesh (8-device parity runs in
    tests/multidev/store_prog.py)."""
    from repro.store.engine import StoreEngine
    mesh = jax.make_mesh((1,), ("data",),
                         devices=np.array(jax.devices()[:1]))
    rng = np.random.default_rng(3)
    keys = rng.integers(1, 2**63, 32, dtype=np.uint64)
    ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], 32,
                     p=[0.4, 0.5, 0.1]).astype(np.int32)
    outs = {}
    for mode in MODES:
        eng = StoreEngine(mesh, ("data",), 32, backend="hash+skiplist",
                          exec_mode=mode)
        assert eng.exec_mode == mode
        state = jax.device_put(eng.init(256), eng.sharding)
        put = lambda x: jax.device_put(jnp.asarray(x), eng.sharding)
        state, res, ok, dropped = eng.step(state, put(ops), put(keys),
                                           put(keys + 1))
        assert int(dropped) == 0
        outs[mode] = (np.asarray(ok), np.asarray(res))
    ref = outs[MODES[0]]
    for mode in MODES[1:]:
        assert (outs[mode][0] == ref[0]).all(), mode
        assert (outs[mode][1] == ref[1]).all(), mode
