"""Fused tier-apply parity: the ≤2-dispatch apply-path contract.

The fused `store.exec.tier_apply` (kernels/tier_apply — the tier_find
membership probes + the hot-insert linearization + the eviction policy's
victim selection in ONE pallas_call, spill planes streamed through VMEM
chunks under a scalar-prefetched `run_offsets` plane) must be
BIT-IDENTICAL to the jnp reference and to the unfused dispatch-per-phase
chain, for results AND the full residency pytree, in every runnable exec
mode. Covered here: direct exec-entry parity across modes for every
policy, the measured dispatch budget (a whole fused apply = exactly TWO
dispatches: one tier_apply update + one FIND-phase tier_find probe), the
spill-chunk streaming path against oversized spill tiers, run-cap
compaction tripping INSIDE a fused apply, and the empty batch. (The
8-device engine analogue runs in tests/multidev/store_prog.py: APPLY-OK.)
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401  (enables x64)
from repro.core.layout import SpillLayout
from repro.store import (OP_DELETE, OP_FIND, OP_INSERT, get_backend,
                         make_plan)
from repro.store import exec as exec_
from repro.store.tiers import unfused_twin

MODES = exec_.runnable_modes()
TIERED = ["hash+skiplist", "tiered3", "tiered3/lru", "tiered3/size",
          "tiered3/b128"]
POLICY_OF = {"tiered3": "none", "tiered3/lru": "lru",
             "tiered3/size": "size", "tiered3/b128": "none"}


def _warm_layout_of(name):
    return "block" if name.endswith("/b128") else "level"


def assert_states_equal(sa, sb, ctx):
    la, lb = jax.tree.leaves(sa), jax.tree.leaves(sb)
    assert len(la) == len(lb), ctx
    for i, (a, b) in enumerate(zip(la, lb)):
        assert (np.asarray(a) == np.asarray(b)).all(), (ctx, i)


def _loaded_state(name, seed=7, capacity=32):
    """A tier state with all three tiers populated (warm overflowed)."""
    be = get_backend(name)
    st = be.init(capacity, hot_bucket=4, hot_frac=8)
    rng = np.random.default_rng(seed)
    ks = np.unique(rng.integers(1, 2**62, 80, dtype=np.uint64))[:60]
    st, _ = be.apply(st, make_plan(np.full(len(ks), OP_INSERT, np.int32),
                                   ks, ks + 1))
    return be, st, ks


def _apply_batch(ks, seed=9, width=48):
    """Insert lanes mixing resident keys (hot/warm/spill), fresh keys,
    in-batch duplicates, and masked-off lanes — every branch of the apply
    prologue in one batch."""
    rng = np.random.default_rng(seed)
    fresh = rng.integers(2**62, 2**63, width, dtype=np.uint64)
    keys = np.where(rng.random(width) < 0.5, rng.choice(ks, width), fresh)
    keys[width - 3] = keys[0]                       # guaranteed in-batch dup
    mask = rng.random(width) > 0.1
    vals = rng.integers(1, 2**62, width, dtype=np.uint64)
    return jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(mask)


# ---------------------------------------------------------------------------
# exec-entry parity across modes (the kernel vs its jnp oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(POLICY_OF))
def test_tier_apply_exec_matches_ref_across_modes(name):
    """One exec.tier_apply call per mode on the same loaded state: all nine
    outputs (hot', meta', the membership/insert flags, and the victim
    lanes) bit-identical between the jnp reference and the fused kernel."""
    _, st, ks = _loaded_state(name)
    keys, vals, mask = _apply_batch(ks)
    outs = {}
    for mode in MODES:
        outs[mode] = exec_.tier_apply(st.hot, st.hot_meta, st.clock,
                                      st.cold, st.spill, keys, vals, mask,
                                      POLICY_OF[name], 8, mode,
                                      warm_layout=_warm_layout_of(name))
    ref_mode, ref = next(iter(outs.items()))
    for mode, got in outs.items():
        assert_states_equal(ref, got, (name, ref_mode, mode))


def test_tier_apply_two_tier_stack_no_spill():
    """spill=None (hash+skiplist depth): the kernel builds without the
    scalar-prefetched chunk grid and spill lanes are all-miss."""
    _, st, ks = _loaded_state("hash+skiplist")
    assert st.spill is None
    keys, vals, mask = _apply_batch(ks, seed=11)
    outs = {}
    for mode in MODES:
        outs[mode] = exec_.tier_apply(st.hot, st.hot_meta, st.clock,
                                      st.cold, None, keys, vals, mask,
                                      "none", 8, mode)
    ref_mode, ref = next(iter(outs.items()))
    for mode, got in outs.items():
        assert_states_equal(ref, got, (ref_mode, mode))
    assert not np.asarray(ref[3]).any()             # in_spill all-miss


def test_tier_apply_streams_spill_in_chunks():
    """A spill tier larger than one chunk exercises the scalar-prefetched
    grid: per-chunk window clipping + the VMEM OR-accumulator must
    reproduce the global searchsorted bit exactly."""
    from repro.kernels.tier_apply.ops import tier_apply_fused
    from repro.kernels.tier_apply.ref import tier_apply_ref
    be = get_backend("tiered3/lru")
    st = be.init(64, hot_bucket=4, hot_frac=8, spill_cap=4096)
    rng = np.random.default_rng(19)
    ks = np.unique(rng.integers(1, 2**62, 900, dtype=np.uint64))[:800]
    for chunk in np.array_split(ks, 4):
        st, _ = be.apply(st, make_plan(
            np.full(len(chunk), OP_INSERT, np.int32), chunk, chunk + 1))
    assert int(st.spill.n) > 256                  # multiple 128-wide chunks
    keys, vals, mask = _apply_batch(ks, seed=23)
    ref = tier_apply_ref(st.hot, st.hot_meta, st.clock, st.cold, st.spill,
                         keys, vals, mask, "lru", 8)
    got = tier_apply_fused(st.hot, st.hot_meta, st.clock, st.cold, st.spill,
                           keys, vals, mask, "lru", 8, spill_chunk=128,
                           interpret=True)
    assert_states_equal(ref, got, "chunked-spill")
    assert np.asarray(ref[3]).any()               # spill residents probed


def test_tier_apply_empty_batch_all_modes():
    _, st, _ = _loaded_state("tiered3")
    none = jnp.zeros((0,), jnp.uint64)
    zb = jnp.zeros((0,), bool)
    for mode in MODES:
        out = exec_.tier_apply(st.hot, st.hot_meta, st.clock, st.cold,
                               st.spill, none, none, zb, "none", 8, mode)
        for a in out[2:]:
            assert a.shape == (0,), mode
        assert_states_equal((out[0], out[1]), (st.hot, st.hot_meta), mode)


# ---------------------------------------------------------------------------
# the dispatch budget (the acceptance criterion, measured)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", TIERED)
def test_fused_apply_is_two_dispatches(name):
    """A whole fused apply traces exactly TWO exec dispatches per plan —
    one tier_apply update (insert prologue) + one tier_find probe (FIND
    phase) — regardless of tier depth or policy; the unfused twin pays one
    probe per tier per phase plus the hot_update."""
    be = get_backend(name)
    st = be.init(32, hot_bucket=4, hot_frac=8)
    plan = make_plan(np.array([OP_INSERT, OP_FIND, OP_DELETE], np.int32),
                     np.array([5, 6, 7], np.uint64))
    with exec_.measure_dispatches() as m_f:
        jax.make_jaxpr(be.apply)(st, plan)
    assert (m_f.n, m_f.probe, m_f.update) == (2, 1, 1), name
    n_tiers = 2 if name == "hash+skiplist" else 3
    with exec_.measure_dispatches() as m_u:
        jax.make_jaxpr(unfused_twin(name).apply)(st, plan)
    # insert phase: probes the LOWER tiers only; FIND phase: every tier
    assert (m_u.n, m_u.probe, m_u.update) == \
        (2 * n_tiers, 2 * n_tiers - 1, 1), name


# ---------------------------------------------------------------------------
# run-cap compaction inside a fused apply (the maintenance interaction)
# ---------------------------------------------------------------------------

def test_run_cap_compaction_inside_fused_apply():
    """Demote-per-apply churn accretes one spill run per batch until the
    static run cap trips `spill_maintain` INSIDE an apply. The fused path
    must ride through the merge bit-identically to the unfused twin in
    every runnable mode, and the residency audit must hold throughout."""
    rng = np.random.default_rng(29)
    preload = np.unique(rng.integers(1, 2**61, 32, dtype=np.uint64))[:20]
    rounds = [np.unique(rng.integers(2**61, 2**62, 4, dtype=np.uint64))[:2]
              for _ in range(SpillLayout.MAX_RUNS - 1)]
    total = len(preload) + sum(len(r) for r in rounds)

    states, runs_seen = {}, []
    for tag, be in (("fused", get_backend("tiered3/lru")),
                    ("unfused", unfused_twin("tiered3/lru"))):
        for mode in MODES:
            with exec_.exec_mode(mode):
                # hot 2x2, warm 16: every post-preload insert demotes
                st = be.init(16, hot_bucket=2, hot_frac=8, spill_cap=64)
                step = jax.jit(be.apply)
                st, res = step(st, make_plan(
                    np.full(len(preload), OP_INSERT, np.int32), preload,
                    preload + 1))
                assert bool(np.asarray(res.ok).all())
                for ks in rounds:
                    st, res = step(st, make_plan(
                        np.full(len(ks), OP_INSERT, np.int32), ks, ks + 1))
                    assert bool(np.asarray(res.ok).all())
                    runs = int(np.asarray(st.spill.run_start)
                               [:int(st.spill.n)].sum())
                    assert runs <= SpillLayout.MAX_RUNS
                    if tag == "fused" and mode == MODES[0]:
                        runs_seen.append(runs)
            states[(tag, mode)] = st

    # the cap genuinely tripped: the run count grew, then a merge shrank it
    assert max(runs_seen) >= SpillLayout.MAX_RUNS - SpillLayout.RUNS_PER_APPLY
    assert any(b < a for a, b in zip(runs_seen, runs_seen[1:])), runs_seen

    ref_key, ref = next(iter(states.items()))
    for key, st in states.items():
        assert_states_equal(ref, st, (ref_key, key))

    # residency audit on the final state: conservation + findability
    be = get_backend("tiered3/lru")
    s = {k: int(v) for k, v in be.stats(ref).items()}
    assert s["size"] == total
    assert s["hot_size"] + s["cold_size"] + s["spill_size"] == total
    every = np.concatenate([preload] + rounds)
    st, res = be.apply(ref, make_plan(
        np.full(len(every), OP_FIND, np.int32), every))
    assert bool(np.asarray(res.ok).all())
    assert (np.asarray(res.vals) == every + 1).all()
