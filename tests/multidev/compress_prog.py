"""8-device validation of int8 error-feedback pod-axis gradient compression:
(a) compressed training tracks uncompressed losses, (b) residuals carry the
quantization error, (c) the lowered HLO actually moves int8 over the pod axis.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import repro  # noqa: F401,E402
from repro.configs import get_reduced  # noqa: E402
from repro.configs.base import ShapeConfig  # noqa: E402
from repro.data.pipeline import synth_batch  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.optim.adamw import adamw_init  # noqa: E402
from repro.optim.compress import compress_state_init  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402


def main() -> int:
    cfg = get_reduced("qwen3-1.7b")
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    plain = jax.jit(make_train_step(cfg))
    comp = jax.jit(make_train_step(cfg, pod_compress=True, mesh=mesh))

    opt_a = {"adam": adamw_init(params)}
    opt_b = {"adam": adamw_init(params),
             "residuals": compress_state_init(params)}
    pa, pb = params, params
    losses_a, losses_b = [], []
    for step in range(4):
        batch = synth_batch(cfg, shape, 11, step)
        pa, opt_a, ma = plain(pa, opt_a, batch)
        pb, opt_b, mb = comp(pb, opt_b, batch)
        losses_a.append(float(ma["loss"]))
        losses_b.append(float(mb["loss"]))
    print("plain:", [f"{x:.4f}" for x in losses_a])
    print("comp: ", [f"{x:.4f}" for x in losses_b])
    # int8 quantization error must stay small at loss level
    for a, b in zip(losses_a, losses_b):
        assert abs(a - b) < 0.05 * max(abs(a), 1), (a, b)
    rn = sum(float(jnp.sum(jnp.abs(r)))
             for r in jax.tree.leaves(opt_b["residuals"]))
    assert rn > 0, "error feedback residuals never populated"
    # the pod exchange must be int8 on the wire
    batch = synth_batch(cfg, shape, 11, 0)
    txt = jax.jit(make_train_step(cfg, pod_compress=True, mesh=mesh)
                  ).lower(pb, opt_b, batch).compile().as_text()
    assert any("s8[" in l and "all-gather" in l for l in txt.splitlines()), \
        "no int8 all-gather found in HLO"
    print("COMPRESS-OK residual_norm=%.3f" % rn)
    return 0


if __name__ == "__main__":
    sys.exit(main())
