"""8-device MoE dispatch equivalence: the three dispatch implementations
(reference dense, replicated+psum, the paper's routed all_to_all) must agree
on the same inputs/weights. Also exercises forward+backward under jit."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import repro  # noqa: F401,E402
from repro.configs import get_reduced  # noqa: E402
from repro.models import moe as moe_mod  # noqa: E402
from repro.models.blocks import _moe_sharded  # noqa: E402
from repro.parallel.sharding import use_mesh  # noqa: E402


def main() -> int:
    cfg = get_reduced("qwen3-moe-235b-a22b")  # 8 experts top-2
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, cfg)
    t, d = 64, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d), jnp.float32) * 0.5
    x = x.astype(jnp.dtype(cfg.compute_dtype))

    y_ref, aux_ref = moe_mod.moe_dense_ffn(p, cfg, x)

    with use_mesh(mesh, dp_axes=("data",), tp_axis="model"):
        for impl in ("replicated_psum", "routed_a2a"):
            y, aux = jax.jit(lambda p, x, impl=impl:
                             _moe_sharded(p, cfg, x, impl))(p, x)
            err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                        - y_ref.astype(jnp.float32))))
            print(f"{impl}: max|dy|={err:.5f} aux_err="
                  f"{abs(float(aux) - float(aux_ref)):.6f}")
            assert err < 0.05, (impl, err)

        # backward through the routed path
        def loss(p, x):
            y, aux = _moe_sharded(p, cfg, x, "routed_a2a")
            return jnp.sum(y.astype(jnp.float32) ** 2) + aux

        g = jax.jit(jax.grad(loss))(p, x)
        gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0
        print(f"routed_a2a grad |sum|={gn:.3f}")
    print("MOE-OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
