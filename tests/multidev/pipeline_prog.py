"""4-stage pipeline parallelism vs sequential reference (8 devices: the
mesh keeps a spare axis on auto to prove PP composes with DP)."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import repro  # noqa: F401,E402
from repro.parallel.pipeline import pipeline_apply  # noqa: E402


def main() -> int:
    mesh = jax.make_mesh((4,), ("stage",),
                         devices=np.asarray(jax.devices()[:4]))
    S, M, B, D = 4, 6, 2, 16
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((S, D, D)) / np.sqrt(D), jnp.float32)
    xs = jnp.asarray(rng.standard_normal((M, B, D)), jnp.float32)

    def stage_fn(wi, x):
        return jnp.tanh(x @ wi)

    piped = jax.jit(pipeline_apply(stage_fn, mesh, "stage"))
    ys = piped(w, xs)

    # sequential reference
    ref = xs
    for i in range(S):
        ref = jnp.tanh(ref @ w[i])
    err = float(jnp.max(jnp.abs(ys - ref)))
    print("pipeline err:", err)
    assert err < 1e-5
    print("PIPELINE-OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
