"""Multi-device validation program for the sharded ordered store.

Run under XLA_FLAGS=--xla_force_host_platform_device_count=8 by
tests/test_routing_store.py. Builds a (2, 4) ("pod", "data") mesh — a
miniature of the production (2, 16, 16) — applies random batched ops through
the hierarchical router and checks every result against a global dict model.
Exits 0 on success.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import repro  # noqa: F401,E402
from repro.core.ordered_sharded import (OP_DELETE, OP_FIND, OP_INSERT,  # noqa: E402
                                        make_store_step, sharded_store_init)

AXES = ("pod", "data")
LANES = 16
N_SHARDS = 8
ROUNDS = 6


def main() -> int:
    mesh = jax.make_mesh((2, 4), AXES)
    state = sharded_store_init(N_SHARDS, capacity_per_shard=512)
    sharding = NamedSharding(mesh, P(AXES))
    state = jax.device_put(state, NamedSharding(mesh, P(AXES)))
    step = jax.jit(make_store_step(mesh, AXES, LANES, pool_factor=4))

    rng = np.random.default_rng(42)
    model: dict[int, int] = {}
    total = N_SHARDS * LANES
    for rnd in range(ROUNDS):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], size=total,
                         p=[0.5, 0.4, 0.1]).astype(np.int32)
        keys = rng.integers(1, 2**63, size=total, dtype=np.uint64)
        # force key reuse so finds/deletes hit
        if model:
            reuse = rng.choice(np.fromiter(model.keys(), dtype=np.uint64),
                               size=min(len(model), total // 2))
            keys[: len(reuse)] = reuse
        vals = keys + 1

        ops_d = jax.device_put(jnp.asarray(ops), sharding)
        keys_d = jax.device_put(jnp.asarray(keys), sharding)
        vals_d = jax.device_put(jnp.asarray(vals), sharding)
        state, res, ok, dropped = step(state, ops_d, keys_d, vals_d)
        res, ok = np.asarray(res), np.asarray(ok)
        assert int(dropped) == 0, f"capacity drops: {int(dropped)}"

        # model semantics: batch linearization = inserts, then deletes, then
        # finds; in-batch duplicate inserts: lowest lane wins (vals are a
        # pure function of keys here, so lane order cannot disagree)
        for i in range(total):
            if ops[i] == OP_INSERT and int(keys[i]) not in model:
                model[int(keys[i])] = int(vals[i])
        for i in range(total):
            if ops[i] == OP_DELETE:
                model.pop(int(keys[i]), None)

        for i in range(total):
            k = int(keys[i])
            if ops[i] == OP_FIND:
                want = k in model
                assert bool(ok[i]) == want, (rnd, i, k, "find flag")
                if want:
                    assert int(res[i]) == model[k], (rnd, i, k, "find val")
    print(f"STORE-OK rounds={ROUNDS} model_size={len(model)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
