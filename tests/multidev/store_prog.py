"""Multi-device validation program for the sharded store engine.

Run under XLA_FLAGS=--xla_force_host_platform_device_count=8 by
tests/test_routing_store.py. Builds a (2, 4) ("pod", "data") mesh — a
miniature of the production (2, 16, 16) — and, for EVERY backend listed in
BACKENDS (flat skiplist, hash tables, split-order, and the tiered
hash+skiplist stack), applies random batched ops through the hierarchical
router and checks every result against a global dict model. The uniform
`repro.store` protocol is what lets one program validate all of them.
Exits 0 on success.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import repro  # noqa: F401,E402
from repro.store import (OP_DELETE, OP_FIND, OP_INSERT, OP_POPK,  # noqa: E402
                         OP_POPMIN)
from repro.store.engine import StoreEngine  # noqa: E402

AXES = ("pod", "data")
LANES = 16
N_SHARDS = 8
ROUNDS = 4
BACKENDS = ("det_skiplist", "twolevel_hash", "splitorder", "hash+skiplist",
            "tiered3/lru", "pq")


def check_backend(mesh, backend: str) -> None:
    eng = StoreEngine(mesh, AXES, LANES, backend=backend, pool_factor=4)
    state = jax.device_put(eng.init(512), eng.sharding)

    rng = np.random.default_rng(42)
    model: dict[int, int] = {}
    total = N_SHARDS * LANES
    for rnd in range(ROUNDS):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], size=total,
                         p=[0.5, 0.4, 0.1]).astype(np.int32)
        keys = rng.integers(1, 2**63, size=total, dtype=np.uint64)
        # force key reuse so finds/deletes hit
        if model:
            reuse = rng.choice(np.fromiter(model.keys(), dtype=np.uint64),
                               size=min(len(model), total // 2))
            keys[: len(reuse)] = reuse
        vals = keys + 1

        ops_d = jax.device_put(jnp.asarray(ops), eng.sharding)
        keys_d = jax.device_put(jnp.asarray(keys), eng.sharding)
        vals_d = jax.device_put(jnp.asarray(vals), eng.sharding)
        state, res, ok, dropped = eng.step(state, ops_d, keys_d, vals_d)
        res, ok = np.asarray(res), np.asarray(ok)
        assert int(dropped) == 0, f"capacity drops: {int(dropped)}"

        # model semantics: batch linearization = inserts, then deletes, then
        # finds; in-batch duplicate inserts: lowest lane wins (vals are a
        # pure function of keys here, so lane order cannot disagree)
        for i in range(total):
            if ops[i] == OP_INSERT and int(keys[i]) not in model:
                model[int(keys[i])] = int(vals[i])
        for i in range(total):
            if ops[i] == OP_DELETE:
                model.pop(int(keys[i]), None)

        for i in range(total):
            k = int(keys[i])
            if ops[i] == OP_FIND:
                want = k in model
                assert bool(ok[i]) == want, (backend, rnd, i, k, "find flag")
                if want:
                    assert int(res[i]) == model[k], (backend, rnd, i, k,
                                                     "find val")

    # uniform stats accessor: global live size must match the model
    sizes = eng.stats(state)["size"]
    assert int(sizes.sum()) == len(model), (backend, sizes, len(model))
    print(f"STORE-OK backend={backend} rounds={ROUNDS} "
          f"model_size={len(model)}")


def check_range(mesh, backend: str) -> None:
    """Cross-shard range counting on an ordered backend (all_gather + psum)."""
    eng = StoreEngine(mesh, AXES, LANES, backend=backend, pool_factor=4)
    state = jax.device_put(eng.init(1024), eng.sharding)
    put = lambda x: jax.device_put(jnp.asarray(x), eng.sharding)
    rng = np.random.default_rng(9)
    keys = rng.integers(1, 2**63, N_SHARDS * LANES, dtype=np.uint64)
    state, _, ok, dropped = eng.step(
        state, put(np.full(keys.size, OP_INSERT, np.int32)), put(keys),
        put(keys + 1))
    assert np.asarray(ok).all() and int(dropped) == 0
    rstep = eng.range_step(max_out=keys.size)
    ks = np.sort(np.unique(keys))
    los = np.zeros(keys.size, np.uint64)
    his = np.zeros(keys.size, np.uint64)
    valid = np.zeros(keys.size, bool)
    los[0], his[0], valid[0] = 0, np.uint64(2**63), True      # everything
    los[1], his[1], valid[1] = ks[10], ks[50], True           # 40 keys
    cnt = np.asarray(rstep(state, put(los), put(his), put(valid)))
    assert int(cnt[0]) == len(ks), cnt[0]
    assert int(cnt[1]) == 40, cnt[1]
    print(f"RANGE-OK backend={backend} counts={cnt[:2]}")


def check_uneven_occupancy(mesh) -> None:
    """Engine scan/stats when per-shard occupancy is SKEWED (shard s holds
    2s+1 keys: shard 0 nearly empty, shard 7 ~full for its lane budget),
    and exec-layer parity on the same skewed state: the jnp and
    Pallas-interpret engines must agree bit-for-bit."""
    per_shard = [2 * s + 1 for s in range(N_SHARDS)]         # 1,3,...,15
    rng = np.random.default_rng(5)
    keys = []
    for s, n in enumerate(per_shard):
        low = rng.integers(1, 2**61, n, dtype=np.uint64)
        keys.extend((np.uint64(s) << np.uint64(61)) | low)   # owner = top 3b
    keys = np.array(keys, np.uint64)
    total = len(keys)
    assert len(np.unique(keys)) == total
    ops = np.full(N_SHARDS * LANES, -1, np.int32)
    ops[:total] = OP_INSERT
    ks = np.zeros(N_SHARDS * LANES, np.uint64)
    ks[:total] = keys

    outs = {}
    for mode in ("jnp", "interpret"):
        eng = StoreEngine(mesh, AXES, LANES, backend="hash+skiplist",
                          pool_factor=8, exec_mode=mode)
        state = jax.device_put(eng.init(512), eng.sharding)
        put = lambda x: jax.device_put(jnp.asarray(x), eng.sharding)
        state, res, ok, dropped = eng.step(state, put(ops), put(ks),
                                           put(ks + 1))
        assert int(dropped) == 0
        assert np.asarray(ok)[:total].all()
        outs[mode] = (np.asarray(ok), np.asarray(res))

        # per-shard stats see the skew exactly, under the uniform schema
        stats = eng.stats(state)
        assert stats["size"].tolist() == per_shard, (mode, stats["size"])
        assert (stats["hot_size"] + stats["cold_size"]
                == stats["size"]).all(), mode
        assert (stats["tombstones"] == 0).all()

        # cross-shard range counts on the skewed state
        rstep = eng.range_step(max_out=total)
        sk = np.sort(keys)
        los = np.zeros(N_SHARDS * LANES, np.uint64)
        his = np.zeros(N_SHARDS * LANES, np.uint64)
        valid = np.zeros(N_SHARDS * LANES, bool)
        los[0], his[0], valid[0] = 0, np.uint64(2**64 - 1), True   # all
        los[1], his[1], valid[1] = sk[10], sk[50], True            # 40 keys
        los[2], his[2], valid[2] = sk[0], sk[1], True              # 1 key
        cnt = np.asarray(rstep(state, put(los), put(his), put(valid)))
        assert int(cnt[0]) == total, cnt[0]
        assert int(cnt[1]) == 40, cnt[1]
        assert int(cnt[2]) == 1, cnt[2]
    assert (outs["jnp"][0] == outs["interpret"][0]).all()
    assert (outs["jnp"][1] == outs["interpret"][1]).all()
    print(f"UNEVEN-OK per_shard={per_shard} modes=jnp,interpret")


def check_tier_residency(mesh, backend: str = "tiered3/lru") -> None:
    """Eviction determinism under sharding: after the same global op
    stream, every shard's tier residency — the FULL tier-stack state,
    including hot keys, policy metadata, warm skiplist, and spill runs —
    is bit-identical to a direct (engine-less) backend instance applying
    that shard's per-round sub-plans. Sharding is pure partitioning; the
    mesh, routing, and pooling cannot change what the policies decide.
    Run for both exec modes (the 1-device analogue lives in
    tests/test_tiers3.py)."""
    from repro.store import get_backend, make_plan
    from repro.store import exec as exec_

    total = N_SHARDS * LANES
    rng = np.random.default_rng(77)
    # per-shard key pools, owner = top 3 bits (the router's partition)
    pools = [np.unique((np.uint64(s) << np.uint64(61))
                       | rng.integers(1, 2**61, 24, dtype=np.uint64))
             for s in range(N_SHARDS)]
    rounds = []
    for _ in range(ROUNDS):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], size=total,
                         p=[0.5, 0.4, 0.1]).astype(np.int32)
        keys = np.concatenate([
            rng.choice(pools[s], LANES, replace=False)
            for s in range(N_SHARDS)])
        rng.shuffle(keys)                    # lanes hit arbitrary owners
        rounds.append((ops, keys))

    init_kw = dict(hot_bucket=4, hot_frac=8)
    for mode in ("jnp", "interpret"):
        eng = StoreEngine(mesh, AXES, LANES, backend=backend, pool_factor=8,
                          exec_mode=mode)
        state = jax.device_put(eng.init(64, **init_kw), eng.sharding)
        put = lambda x: jax.device_put(jnp.asarray(x), eng.sharding)
        for ops, keys in rounds:
            state, _, _, dropped = eng.step(state, put(ops), put(keys),
                                            put(keys + 3))
            assert int(dropped) == 0, mode

        be = get_backend(backend)
        for s in range(N_SHARDS):
            with exec_.exec_mode(mode):
                direct = be.init(64, **init_kw)
                for ops, keys in rounds:
                    owner = (keys >> np.uint64(61)).astype(np.int32)
                    sel = owner == s
                    direct, _ = be.apply(direct, make_plan(
                        ops[sel], keys[sel], keys[sel] + 3))
            sharded = jax.tree.map(lambda x, s=s: x[s], state)
            la, lb = jax.tree.leaves(sharded), jax.tree.leaves(direct)
            assert len(la) == len(lb)
            for i, (a, b) in enumerate(zip(la, lb)):
                assert (np.asarray(a) == np.asarray(b)).all(), \
                    (backend, mode, s, i)
    print(f"RESIDENCY-OK backend={backend} shards={N_SHARDS} "
          f"modes=jnp,interpret")


def check_fused_vs_unfused(mesh, name: str = "tiered3/lru") -> None:
    """Fused-path determinism under sharding: an engine over the registered
    (fused — one `exec.tier_find` dispatch per probe phase) tier backend
    and an engine over an unfused `TieredBackend(fused=False)` twin must
    produce bit-identical results AND bit-identical per-shard residency
    (the full tier-stack state) for the same global op stream, in both
    exec modes. Fusing the FIND chain is a dispatch-count optimization;
    the 8-device mesh must not be able to tell the difference."""
    from repro.store.tiers import unfused_twin

    total = N_SHARDS * LANES
    rng = np.random.default_rng(99)
    pools = [np.unique((np.uint64(s) << np.uint64(61))
                       | rng.integers(1, 2**61, 24, dtype=np.uint64))
             for s in range(N_SHARDS)]
    rounds = []
    for _ in range(ROUNDS):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], size=total,
                         p=[0.5, 0.4, 0.1]).astype(np.int32)
        keys = np.concatenate([
            rng.choice(pools[s], LANES, replace=False)
            for s in range(N_SHARDS)])
        rng.shuffle(keys)
        rounds.append((ops, keys))

    init_kw = dict(hot_bucket=4, hot_frac=8)
    unfused = unfused_twin(name)
    for mode in ("jnp", "interpret"):
        states, results = [], []
        for backend in (name, unfused):
            eng = StoreEngine(mesh, AXES, LANES, backend=backend,
                              pool_factor=8, exec_mode=mode)
            state = jax.device_put(eng.init(64, **init_kw), eng.sharding)
            put = lambda x: jax.device_put(jnp.asarray(x), eng.sharding)
            outs = []
            for ops, keys in rounds:
                state, res, ok, dropped = eng.step(state, put(ops),
                                                   put(keys), put(keys + 3))
                assert int(dropped) == 0, mode
                outs.append((np.asarray(ok), np.asarray(res)))
            states.append(state)
            results.append(outs)
        for rnd, ((ok_f, v_f), (ok_u, v_u)) in enumerate(zip(*results)):
            assert (ok_f == ok_u).all(), (mode, rnd)
            assert (v_f == v_u).all(), (mode, rnd)
        la, lb = jax.tree.leaves(states[0]), jax.tree.leaves(states[1])
        assert len(la) == len(lb)
        for i, (a, b) in enumerate(zip(la, lb)):
            assert (np.asarray(a) == np.asarray(b)).all(), (mode, i)
    print(f"FUSED-OK backend={name} shards={N_SHARDS} modes=jnp,interpret")


def check_fused_apply(mesh, name: str = "tiered3/lru") -> None:
    """APPLY-OK: the fused-apply budget and its eviction math survive the
    mesh. (a) Tracing one 8-device engine step over the fused tier backend
    records exactly TWO exec dispatches — one `tier_apply` update plus one
    `tier_find` probe — while the unfused twin records the
    dispatch-per-tier chain (2*n_tiers total, 2*n_tiers-1 probes);
    shard_map traces the shard body once, so the per-shard budget is
    visible at trace time. (b) An INSERT-heavy stream over a deliberately
    tiny hot tier forces the policy's victim selection and demote scatter
    through the fused kernel on every shard; the fused engine and its
    `fused=False` twin must stay bit-identical in results AND full sharded
    residency, in both exec modes, with evictions actually recorded."""
    from repro.store import exec as exec_
    from repro.store.tiers import unfused_twin

    total = N_SHARDS * LANES
    init_kw = dict(hot_bucket=2, hot_frac=8)      # tiny hot tier: 8 slots
    unfused = unfused_twin(name)
    n_tiers = 3

    # (a) trace-time dispatch budget of the sharded step, per variant
    budgets = {name: (2, 1, 1),
               unfused: (2 * n_tiers, 2 * n_tiers - 1, 1)}
    for backend, (n, npr, nup) in budgets.items():
        eng = StoreEngine(mesh, AXES, LANES, backend=backend, pool_factor=8,
                          exec_mode="jnp")
        state = jax.device_put(eng.init(64, **init_kw), eng.sharding)
        put = lambda x: jax.device_put(jnp.asarray(x), eng.sharding)
        args = (state, put(np.full(total, OP_INSERT, np.int32)),
                put(np.arange(1, total + 1, dtype=np.uint64)),
                put(np.arange(2, total + 2, dtype=np.uint64)))
        with exec_.measure_dispatches() as m:
            jax.eval_shape(eng._jit_step, *args)
        assert (m.n, m.probe, m.update) == (n, npr, nup), \
            (backend, m.n, m.probe, m.update)

    # (b) eviction-heavy fused-vs-unfused bit-identity under sharding
    rng = np.random.default_rng(101)
    pools = [np.unique((np.uint64(s) << np.uint64(61))
                       | rng.integers(1, 2**61, 64, dtype=np.uint64))
             for s in range(N_SHARDS)]
    rounds = []
    for _ in range(ROUNDS):
        keys = np.concatenate([
            rng.choice(pools[s], LANES, replace=False)
            for s in range(N_SHARDS)])
        rng.shuffle(keys)
        rounds.append((np.full(total, OP_INSERT, np.int32), keys))

    for mode in ("jnp", "interpret"):
        states, results, evs = [], [], []
        for backend in (name, unfused):
            eng = StoreEngine(mesh, AXES, LANES, backend=backend,
                              pool_factor=8, exec_mode=mode)
            state = jax.device_put(eng.init(64, **init_kw), eng.sharding)
            put = lambda x: jax.device_put(jnp.asarray(x), eng.sharding)
            outs = []
            for ops, keys in rounds:
                state, res, ok, dropped = eng.step(state, put(ops),
                                                   put(keys), put(keys + 3))
                assert int(dropped) == 0, mode
                outs.append((np.asarray(ok), np.asarray(res)))
            states.append(state)
            results.append(outs)
            evs.append(int(eng.stats(state)["evictions"].sum()))
        assert evs[0] > 0 and evs[0] == evs[1], (mode, evs)
        for rnd, ((ok_f, v_f), (ok_u, v_u)) in enumerate(zip(*results)):
            assert (ok_f == ok_u).all(), (mode, rnd)
            assert (v_f == v_u).all(), (mode, rnd)
        la, lb = jax.tree.leaves(states[0]), jax.tree.leaves(states[1])
        assert len(la) == len(lb)
        for i, (a, b) in enumerate(zip(la, lb)):
            assert (np.asarray(a) == np.asarray(b)).all(), (mode, i)
    print(f"APPLY-OK backend={name} shards={N_SHARDS} "
          f"evictions={evs[0]} modes=jnp,interpret")


def check_bskip(mesh) -> None:
    """BSKIP-OK: the warm tier's block-major probe layout under sharding.
    An engine over `tiered3/b128` (B-skiplist warm walk, fused) and one
    over `tiered3` (level-major walk) must produce bit-identical results
    AND bit-identical per-shard residency for the same global op stream,
    in both exec modes — the layout knob, like fusion, is invisible to
    the 8-device mesh."""
    total = N_SHARDS * LANES
    rng = np.random.default_rng(117)
    pools = [np.unique((np.uint64(s) << np.uint64(61))
                       | rng.integers(1, 2**61, 24, dtype=np.uint64))
             for s in range(N_SHARDS)]
    rounds = []
    for _ in range(ROUNDS):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], size=total,
                         p=[0.5, 0.4, 0.1]).astype(np.int32)
        keys = np.concatenate([
            rng.choice(pools[s], LANES, replace=False)
            for s in range(N_SHARDS)])
        rng.shuffle(keys)
        rounds.append((ops, keys))

    for mode in ("jnp", "interpret"):
        states, results = [], []
        for backend in ("tiered3", "tiered3/b128"):
            eng = StoreEngine(mesh, AXES, LANES, backend=backend,
                              pool_factor=8, exec_mode=mode)
            state = jax.device_put(eng.init(64, hot_bucket=4, hot_frac=8),
                                   eng.sharding)
            put = lambda x: jax.device_put(jnp.asarray(x), eng.sharding)
            outs = []
            for ops, keys in rounds:
                state, res, ok, dropped = eng.step(state, put(ops),
                                                   put(keys), put(keys + 3))
                assert int(dropped) == 0, mode
                outs.append((np.asarray(ok), np.asarray(res)))
            states.append(state)
            results.append(outs)
        for rnd, ((ok_l, v_l), (ok_b, v_b)) in enumerate(zip(*results)):
            assert (ok_l == ok_b).all(), (mode, rnd)
            assert (v_l == v_b).all(), (mode, rnd)
        la, lb = jax.tree.leaves(states[0]), jax.tree.leaves(states[1])
        assert len(la) == len(lb)
        for i, (a, b) in enumerate(zip(la, lb)):
            assert (np.asarray(a) == np.asarray(b)).all(), (mode, i)
    print(f"BSKIP-OK backend=tiered3/b128 shards={N_SHARDS} "
          f"modes=jnp,interpret")


def check_metrics(mesh, backend: str = "obs:tiered3/lru") -> None:
    """METRICS-OK: the observability plane under sharding. Each shard of an
    `obs:`-wrapped engine carries its own metrics counters (on dim 0, like
    every state leaf); after the same global op stream, every shard's
    counters must be bit-identical to a direct observed instance replaying
    that shard's sub-stream — the same pure-partitioning contract as tier
    residency — and the engine-only routing counters must equal the
    explicitly computed expectation (`routed_ops` = valid lanes the shard
    owns, `routed_bytes` = 24x). Run for both exec modes, so cross-mode AND
    cross-sharding bit-identity is covered in one lane."""
    from repro.store import get_backend, make_plan
    from repro.store import exec as exec_
    from repro.store import obs

    total = N_SHARDS * LANES
    rng = np.random.default_rng(123)
    pools = [np.unique((np.uint64(s) << np.uint64(61))
                       | rng.integers(1, 2**61, 24, dtype=np.uint64))
             for s in range(N_SHARDS)]
    rounds = []
    for _ in range(ROUNDS):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], size=total,
                         p=[0.5, 0.4, 0.1]).astype(np.int32)
        keys = np.concatenate([
            rng.choice(pools[s], LANES, replace=False)
            for s in range(N_SHARDS)])
        rng.shuffle(keys)
        rounds.append((ops, keys))

    init_kw = dict(hot_bucket=4, hot_frac=8)
    pool = 8 * LANES
    ref = None
    for mode in ("jnp", "interpret"):
        eng = StoreEngine(mesh, AXES, LANES, backend=backend, pool_factor=8,
                          exec_mode=mode)
        state = jax.device_put(eng.init(64, **init_kw), eng.sharding)
        put = lambda x: jax.device_put(jnp.asarray(x), eng.sharding)
        for ops, keys in rounds:
            state, _, _, dropped = eng.step(state, put(ops), put(keys),
                                            put(keys + 3))
            assert int(dropped) == 0, mode
        per_shard = eng.metrics(state)
        assert set(per_shard) == set(obs.METRICS_SCHEMA)

        be = get_backend(backend)
        for s in range(N_SHARDS):
            with exec_.exec_mode(mode):
                direct = be.init(64, **init_kw)
                expect_routed = 0
                for ops, keys in rounds:
                    owner = (keys >> np.uint64(61)).astype(np.int32)
                    sel = (owner == s) & (ops >= 0)
                    expect_routed += int(np.sum(sel))
                    # the shard executes its sub-stream padded to the
                    # engine's routing pool; pad lanes are masked
                    n = int(np.sum(sel))
                    p_ops = np.full(pool, -1, np.int32)
                    p_keys = np.zeros(pool, np.uint64)
                    p_ops[:n] = ops[sel]
                    p_keys[:n] = keys[sel]
                    direct, _ = be.apply(direct, make_plan(
                        p_ops, p_keys, p_keys + 3,
                        mask=np.arange(pool) < n))
            m_dir = {k: int(v) for k, v in be.metrics(direct).items()}
            for k in obs.METRICS_SCHEMA:
                if k in ("routed_ops", "routed_bytes"):
                    continue
                assert int(per_shard[k][s]) == m_dir[k], (mode, s, k)
            assert int(per_shard["routed_ops"][s]) == expect_routed, (mode, s)
            assert (int(per_shard["routed_bytes"][s])
                    == obs.ROUTED_OP_BYTES * expect_routed), (mode, s)
        if ref is None:
            ref = {k: v.tolist() for k, v in per_shard.items()}
        else:       # cross-mode bit-identity of the whole sharded plane
            assert ref == {k: v.tolist() for k, v in per_shard.items()}, mode
    print(f"METRICS-OK backend={backend} shards={N_SHARDS} "
          f"modes=jnp,interpret")


def check_pq(mesh) -> None:
    """PQ-OK: sharded bulk-pop-k on the `pq` backend. Pop lanes carry a
    shard HINT in their key field (the per-shard relaxed-pq design), so
    each round every shard extracts its LANES smallest live keys in one
    routed plan. Per (shard, round) the popped multiset must equal the
    next block of a per-shard sorted model (POPK answers keys, POPMIN the
    stored values), the store must drain to empty with exact pops /
    pop_empty counters per shard, and the whole run must be bit-identical
    across exec modes."""
    total = N_SHARDS * LANES
    rng = np.random.default_rng(31)
    per_shard = [2 * s + 3 for s in range(N_SHARDS)]          # uneven: 3..17
    shard_keys = []
    for s, n in enumerate(per_shard):
        low = np.unique(rng.integers(1, 2**61, 2 * n, dtype=np.uint64))[:n]
        shard_keys.append(((np.uint64(s) << np.uint64(61)) | low))
    keys = np.zeros(total, np.uint64)
    flat = np.concatenate(shard_keys)
    keys[:len(flat)] = flat
    ins = np.full(total, -1, np.int32)
    ins[:len(flat)] = OP_INSERT
    hints = (np.arange(total, dtype=np.uint64) % N_SHARDS) << np.uint64(61)

    outs_by_mode = {}
    for mode in ("jnp", "interpret"):
        eng = StoreEngine(mesh, AXES, LANES, backend="pq", pool_factor=4,
                          exec_mode=mode)
        state = jax.device_put(eng.init(512), eng.sharding)
        put = lambda x: jax.device_put(jnp.asarray(x), eng.sharding)
        state, _, ok, dropped = eng.step(state, put(ins), put(keys),
                                         put(keys + 1))
        assert np.asarray(ok)[:len(flat)].all() and int(dropped) == 0, mode

        model = [sorted(int(k) for k in sk) for sk in shard_keys]
        expect_pops = np.zeros(N_SHARDS, np.int64)
        expect_empty = np.zeros(N_SHARDS, np.int64)
        rnd, outs = 0, []
        while True:
            op = OP_POPK if rnd % 2 == 0 else OP_POPMIN
            state, res, ok, _ = eng.step(
                state, put(np.full(total, op, np.int32)), put(hints),
                put(np.zeros(total, np.uint64)))
            ok, res = np.asarray(ok), np.asarray(res)
            outs.append((ok.copy(), res.copy()))
            for s in range(N_SHARDS):
                lanes = (np.arange(total) % N_SHARDS == s) & ok
                got = sorted(int(v) for v in res[lanes])
                if op == OP_POPMIN:                 # value = key + 1
                    got = [v - 1 for v in got]
                k = min(LANES, len(model[s]))
                assert got == model[s][:k], (mode, rnd, s)
                model[s] = model[s][k:]
                expect_pops[s] += k
                expect_empty[s] += LANES - k
            rnd += 1
            if not ok.any():
                break
        stats = eng.stats(state)
        assert int(stats["size"].sum()) == 0, mode   # drained dry
        assert stats["pops"].tolist() == expect_pops.tolist(), mode
        assert stats["pop_empty"].tolist() == expect_empty.tolist(), mode
        outs_by_mode[mode] = (outs, jax.tree.leaves(state))
    (oa, sa), (ob, sb) = outs_by_mode["jnp"], outs_by_mode["interpret"]
    for (ok_a, v_a), (ok_b, v_b) in zip(oa, ob):
        assert (ok_a == ok_b).all() and (v_a == v_b).all()
    for a, b in zip(sa, sb):
        assert (np.asarray(a) == np.asarray(b)).all()
    print(f"PQ-OK backend=pq shards={N_SHARDS} per_shard={per_shard} "
          f"modes=jnp,interpret")


def check_recover(mesh, backend: str = "obs:tiered3/lru") -> None:
    """RECOVER-OK: the resilience layer on the 8-device mesh.

    (a) snapshot + journal `restore` onto a FRESH engine reproduces the
    fault-free run's state digest and full per-shard metrics plane;
    (b) a mid-trace shard drop (shard 3, step 3) recovered in sync mode
    leaves every round's results AND the final state/metrics digests
    bit-identical to the fault-free run;
    (c) degraded mode: healthy shards keep serving (their lanes match the
    fault-free run at every step) while the dead shard rebuilds one journal
    entry per tick; the deferred lanes' true answers land in `completions`
    equal to the fault-free answers, and a post-run FIND sweep over every
    key agrees between the two runs."""
    from repro.store import resilience as R

    total = N_SHARDS * LANES
    n_rounds = 6
    rng = np.random.default_rng(211)
    pools = [np.unique((np.uint64(s) << np.uint64(61))
                       | rng.integers(1, 2**61, 24, dtype=np.uint64))
             for s in range(N_SHARDS)]
    rounds = []
    for _ in range(n_rounds):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], size=total,
                         p=[0.4, 0.5, 0.1]).astype(np.int32)
        keys = np.concatenate([
            rng.choice(pools[s], LANES, replace=False)
            for s in range(N_SHARDS)])
        rng.shuffle(keys)
        rounds.append((ops, keys))

    init_kw = dict(hot_bucket=4, hot_frac=8)

    def fresh():
        eng = StoreEngine(mesh, AXES, LANES, backend=backend, pool_factor=8)
        state = jax.device_put(eng.init(64, **init_kw), eng.sharding)
        return eng, state

    put = lambda eng, x: jax.device_put(jnp.asarray(x), eng.sharding)

    # fault-free reference run
    eng0, state0 = fresh()
    ff_outs = []
    for ops, keys in rounds:
        state0, res, ok, dropped = eng0.step(state0, put(eng0, ops),
                                             put(eng0, keys),
                                             put(eng0, keys + 3))
        assert int(dropped) == 0
        ff_outs.append((np.asarray(ok).copy(), np.asarray(res).copy()))
    ff_digest = R.state_digest(state0)
    ff_metrics = {k: v.tolist() for k, v in eng0.metrics(state0).items()}

    # (a) full restore onto a fresh engine: 8-device snapshot + journal
    eng1, state1 = fresh()
    snap = R.take_snapshot(state1, 0)
    j = R.Journal(base_seq=0)
    for r, (ops, keys) in enumerate(rounds):
        j.append(r, ops, keys, keys + 3)
    assert j.verify()
    eng2, _ = fresh()
    restored, replayed = R.restore(eng2, snap, j.entries)
    assert replayed == sum(e.n_ops for e in j.entries)
    assert R.state_digest(restored) == ff_digest
    assert {k: v.tolist()
            for k, v in eng2.metrics(restored).items()} == ff_metrics

    # (b) mid-trace shard drop, sync recovery: bit-identical throughout
    eng3, state3 = fresh()
    reng = R.ResilientEngine(
        eng3, snapshot_every=2,
        fault_plan=R.FaultPlan(0, [R.Fault("shard_drop", 3, shard=3)]))
    for r, (ops, keys) in enumerate(rounds):
        state3, res, ok, dropped = reng.step(state3, put(eng3, ops),
                                             put(eng3, keys),
                                             put(eng3, keys + 3))
        assert int(dropped) == 0
        ok_f, v_f = ff_outs[r]
        assert (np.asarray(ok) == ok_f).all(), ("sync", r)
        assert (np.asarray(res) == v_f).all(), ("sync", r)
    assert R.state_digest(state3) == ff_digest
    assert {k: v.tolist()
            for k, v in eng3.metrics(state3).items()} == ff_metrics
    assert reng.tally["faults_injected"] == 1
    assert reng.tally["recoveries"] == 1
    assert reng.tally["replayed_ops"] > 0
    assert reng.journal.verify()

    # (c) degraded mode: drop shard 3 at step 3 with the last snapshot at
    # seq 0 and a one-entry-per-tick replay budget -> the rebuild spans
    # steps 3..5 while the healthy shards keep serving
    eng4, state4 = fresh()
    reng4 = R.ResilientEngine(
        eng4, snapshot_every=4, mode="degraded", replay_per_tick=1,
        fault_plan=R.FaultPlan(0, [R.Fault("shard_drop", 3, shard=3)]))
    owner_all = []
    for r, (ops, keys) in enumerate(rounds):
        owner = (keys >> np.uint64(61)).astype(np.int32)
        owner_all.append(owner)
        state4, res, ok, _ = reng4.step(state4, put(eng4, ops),
                                        put(eng4, keys), put(eng4, keys + 3))
        ok_h, v_h = np.asarray(ok), np.asarray(res)
        ok_f, v_f = ff_outs[r]
        deferred = (owner == 3) & (ops >= 0) if r >= 3 else \
            np.zeros(total, bool)
        live = ~deferred
        assert (ok_h[live] == ok_f[live]).all(), ("degraded", r)
        assert (v_h[live] == v_f[live]).all(), ("degraded", r)
        assert not ok_h[deferred].any(), ("degraded", r)   # visibly deferred
    assert reng4.quarantine is None                        # rebuild done
    assert reng4.tally["recoveries"] == 1
    # every deferred lane completed with the fault-free answer
    n_def = 0
    for (seq, lane), (cok, cval) in reng4.completions.items():
        ok_f, v_f = ff_outs[seq]
        assert cok == bool(ok_f[lane]), ("completion", seq, lane)
        assert cval == int(v_f[lane]), ("completion", seq, lane)
        n_def += 1
    assert n_def == sum(int(((o == 3) & (rounds[r][0] >= 0)).sum())
                        for r, o in enumerate(owner_all) if r >= 3)
    # content sweep: FIND every pool key on both final states
    for s in range(N_SHARDS):
        for chunk in np.array_split(pools[s], max(1, len(pools[s]) // LANES)):
            probe = np.zeros(total, np.uint64)
            probe[:len(chunk)] = chunk
            fops = np.full(total, -1, np.int32)
            fops[:len(chunk)] = OP_FIND
            _, v_a, ok_a, _ = eng0.step(state0, put(eng0, fops),
                                        put(eng0, probe),
                                        put(eng0, np.zeros(total, np.uint64)))
            _, v_b, ok_b, _ = eng4.step(state4, put(eng4, fops),
                                        put(eng4, probe),
                                        put(eng4, np.zeros(total, np.uint64)))
            assert (np.asarray(ok_a) == np.asarray(ok_b)).all(), ("sweep", s)
            m = np.asarray(ok_a)
            assert (np.asarray(v_a)[m] == np.asarray(v_b)[m]).all(), \
                ("sweep", s)
    print(f"RECOVER-OK backend={backend} shards={N_SHARDS} "
          f"sync_digest=match degraded_completions={n_def}")


def main() -> int:
    mesh = jax.make_mesh((2, 4), AXES)
    for backend in BACKENDS:
        check_backend(mesh, backend)
    for backend in ("det_skiplist", "hash+skiplist"):
        check_range(mesh, backend)
    check_uneven_occupancy(mesh)
    check_tier_residency(mesh)
    check_fused_vs_unfused(mesh)
    check_fused_apply(mesh)
    check_bskip(mesh)
    check_metrics(mesh)
    check_pq(mesh)
    check_recover(mesh)
    return 0


if __name__ == "__main__":
    sys.exit(main())
