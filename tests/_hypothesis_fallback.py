"""Deterministic stand-in for `hypothesis` when it is not installed.

Implements the tiny subset this suite uses — `given`, `settings`, and the
strategies `integers`, `booleans`, `sampled_from`, `lists`, `tuples` — with
seeded-RNG example generation (seed = hash of the test's qualname), so the
property tests still execute real randomized examples, reproducibly, in
environments without hypothesis. Install `hypothesis` (requirements-dev.txt)
to get full shrinking/coverage; this fallback trades example count for a
dependency-free tier-1 run.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

# fallback example count: small (examples dominate tier-1 runtime: every new
# list length is a fresh jit specialization); hypothesis, when present, uses
# the test's own @settings instead
MAX_EXAMPLES = 5


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: np.random.Generator):
        return self._sample(rng)


def integers(min_value=0, max_value=1 << 30) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value,
                                                  endpoint=True)))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def lists(elements: _Strategy, min_size=0, max_size=10,
          unique=False) -> _Strategy:
    def sample(rng):
        n = int(rng.integers(min_size, max_size, endpoint=True))
        out, seen, tries = [], set(), 0
        while len(out) < n and tries < 50 * (n + 1):
            v = elements.example(rng)
            tries += 1
            if unique:
                if v in seen:
                    continue
                seen.add(v)
            out.append(v)
        return out
    return _Strategy(sample)


def tuples(*elems: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))


class strategies:
    """Namespace mirror so `from _hypothesis_fallback import strategies as st`
    matches `from hypothesis import strategies as st`."""
    integers = staticmethod(integers)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)
    tuples = staticmethod(tuples)


def settings(max_examples=None, deadline=None, **kw):
    """Records max_examples on the (already given-wrapped) test function."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy, **kwstrats: _Strategy):
    """Run the test body over deterministic seeded examples."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            declared = getattr(wrapper, "_fallback_max_examples", None)
            n = min(declared or MAX_EXAMPLES, MAX_EXAMPLES)
            rng = np.random.default_rng(
                zlib.adler32(fn.__qualname__.encode()))
            for _ in range(n):
                ex = [s.example(rng) for s in strats]
                kex = {k: s.example(rng) for k, s in kwstrats.items()}
                fn(*args, *ex, **kwargs, **kex)

        # hide strategy-supplied parameters from pytest's fixture resolution
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        keep = params[: len(params) - len(strats)] if strats else params
        keep = [p for p in keep if p.name not in kwstrats]
        wrapper.__signature__ = sig.replace(parameters=keep)
        return wrapper
    return deco
