"""Structural invariant checks for the block-major B-skiplist layout.

The warm tier's blocked layout (`core.layout.bskiplist_layout`) is DERIVED
at probe time from the deterministic skiplist's packed terminal plane, so
its invariants follow from the derivation — but "follows by construction"
is exactly the claim a refactor silently breaks. These checkers audit the
derived planes the same way `core.det_skiplist.check_invariants` audits
the level-major state: host-side numpy, a dict of violation counts, zero
everywhere on a healthy structure.

Checked invariants (docs/store_layers.md, "Block-major B-skiplist"):

  block_unsorted     every terminal block's keys are non-decreasing and
                     every index-level row is non-decreasing (sorted
                     blocks are what make the one-compare-per-block
                     `searchsorted` descent correct)
  bad_occupancy      deterministic split/merge occupancy: every block
                     holds between ceil(B/2) and B live keys EXCEPT the
                     tail block of each level (the derivation packs
                     blocks full, so interior blocks hold exactly B —
                     strictly inside the classical B-structure bound)
  bad_level_shape    level monotonicity: level r has ceil(n_{r-1} / B)
                     nodes, strictly decreasing up to a single root node
  bad_block_max      each index entry equals the LAST key of the block it
                     summarizes (block max; KEY_INF pads absorb partial
                     tails so routing of over-max queries stays correct)
  bad_padding        cells past a level's node count are KEY_INF
  bad_tombstones     tombstone accounting: layout `term_mark` matches the
                     skiplist's mark plane and `n_marked` equals the
                     marked-cell population inside the packed prefix

`check_bskiplist_invariants(s)` takes a DetSkiplist; `assert_bskiplist_ok`
raises with the violation dict. Wired into the tier/pq parity suites
(tests/test_tiers3.py, tests/test_pq.py) and the differential harness
(tests/test_differential.py) so every randomized stream audits the
blocked layout it probed.
"""
from __future__ import annotations

import numpy as np

from repro.core.layout import BSKIP_BLOCK, KEY_INF, bskiplist_layout


def check_bskiplist_invariants(s, block: int = BSKIP_BLOCK) -> dict:
    """Audit the blocked layout derived from DetSkiplist `s`. Returns a
    dict of violation counts — all zero on a healthy structure."""
    B = block
    lay = bskiplist_layout(s, block)
    out = {"block_unsorted": 0, "bad_occupancy": 0, "bad_level_shape": 0,
           "bad_block_max": 0, "bad_padding": 0, "bad_tombstones": 0}

    def u64(hi, lo):
        return (np.asarray(hi, np.uint64) << np.uint64(32)) \
            | np.asarray(lo, np.uint64)

    term = u64(lay.term_hi, lay.term_lo)
    nb = term.shape[0] // B
    blocks = term.reshape(nb, B)
    occ = np.sum(blocks != KEY_INF, axis=1)
    n_live = int(np.sum(occ))
    # live blocks form a packed prefix; interior ones must satisfy the
    # B-structure occupancy bound (the derivation packs them full)
    last_live = int(np.max(np.nonzero(occ)[0])) if n_live else 0
    for j in range(nb):
        row = blocks[j]
        if np.any(np.diff(row.astype(np.float64)) < 0):
            out["block_unsorted"] += 1
        if j < last_live and not ((B + 1) // 2 <= occ[j] <= B):
            out["bad_occupancy"] += 1

    # index levels: shape, sortedness, block-max linkage
    lvls = u64(lay.blk_hi, lay.blk_lo)          # [L, W]
    child = term.reshape(nb, B)
    n_prev = nb
    for r in range(lvls.shape[0]):
        n_r = -(-n_prev // B)
        row = lvls[r]
        if np.any(np.diff(row.astype(np.float64)) < 0):
            out["block_unsorted"] += 1
        maxima = child[:, -1]                    # last entry = block max
        if not np.array_equal(row[:n_prev], maxima):
            out["bad_block_max"] += 1
        if np.any(row[n_prev:] != KEY_INF):      # level + stack pads
            out["bad_padding"] += 1
        child = row[:n_r * B].reshape(n_r, B)
        n_prev = n_r
    if n_prev != 1:                              # must shrink to one root
        out["bad_level_shape"] += 1

    # tombstone accounting against the source-of-truth mark plane
    mark = np.asarray(lay.term_mark).astype(bool)
    src_mark = np.asarray(s.term_mark).astype(bool)
    n = int(s.n_term)
    if not np.array_equal(mark[:src_mark.shape[0]], src_mark):
        out["bad_tombstones"] += 1
    if int(np.sum(src_mark[:n])) != int(s.n_marked) or np.any(src_mark[n:]):
        out["bad_tombstones"] += 1
    return out


def assert_bskiplist_ok(s, ctx="", block: int = BSKIP_BLOCK):
    """Raise AssertionError with the violation dict on any failure."""
    out = check_bskiplist_invariants(s, block)
    bad = {k: v for k, v in out.items() if v}
    assert not bad, (ctx, bad)
