"""Three-deep tier stack + eviction-policy determinism.

Covers the depth-3 `tiered3[/lru|/size]` configurations of
`repro.store.tiers`: spill-run overflow into the cold tier, policy victim
selection (LRU-by-batch picks the oldest touch, size-aware picks the
largest payload), policy counters surviving `flush`, and the residency
determinism contract — the same `OpPlan` stream produces BIT-IDENTICAL
tier residency (the full state pytree, not just results) across exec modes
and between the sharded engine and a direct backend instance.
(8-device residency parity runs in tests/multidev/store_prog.py.)
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401  (enables x64)
from repro.core.layout import hash_slot, val_weight
from repro.store import (OP_DELETE, OP_FIND, OP_INSERT, get_backend,
                         make_plan)
from repro.store import exec as exec_

from invariants import assert_bskiplist_ok

TIERED = ["hash+skiplist", "tiered3", "tiered3/lru", "tiered3/size",
          "tiered3/b128"]
POLICIED = ["tiered3/lru", "tiered3/size"]


def u64(xs):
    return jnp.asarray(np.array(xs, dtype=np.uint64))


def keys_for_slot(num_slots: int, slot: int, n: int, seed=0) -> np.ndarray:
    """n distinct keys hashing into hot-tier slot `slot`."""
    rng = np.random.default_rng(seed)
    out: list[int] = []
    while len(out) < n:
        cand = rng.integers(1, 2**61, 256, dtype=np.uint64)
        sl = np.asarray(hash_slot(jnp.asarray(cand), num_slots))
        for k, s in zip(cand.tolist(), sl.tolist()):
            if s == slot and k not in out:
                out.append(k)
                if len(out) == n:
                    break
    return np.array(out, dtype=np.uint64)


def ins(be, st, keys, vals=None):
    keys = np.asarray(keys, np.uint64)
    vals = keys + 1 if vals is None else np.asarray(vals, np.uint64)
    return be.apply(st, make_plan(np.full(len(keys), OP_INSERT, np.int32),
                                  keys, vals))


def hot_set(st):
    return set(np.asarray(st.hot.keys).reshape(-1).tolist()) - {2**64 - 1}


def spill_live(st):
    ks = np.asarray(st.spill.keys)
    return set(ks[~np.asarray(st.spill.dead) & (ks != np.uint64(2**64 - 1))]
               .tolist())


def _stats(be, st):
    return {k: int(v) for k, v in be.stats(st).items()}


def test_val_weight():
    w = np.asarray(val_weight(u64([0, 1, 255, 256, 2**32, 2**63 - 1, 2**63])))
    assert w.tolist() == [1, 1, 1, 2, 5, 8, 8]


class TestThirdTier:
    def _overflow_setup(self):
        """Warm tier (32) overfilled so inserts land in all THREE tiers."""
        be = get_backend("tiered3")
        st = be.init(32, hot_bucket=4, hot_frac=32)      # hot 1x4, spill 32
        rng = np.random.default_rng(7)
        ks = np.unique(rng.integers(1, 2**62, 80, dtype=np.uint64))[:60]
        st, res = ins(be, st, ks)
        assert res.ok.all()
        return be, st, ks

    def test_overflow_reaches_spill_runs(self):
        be, st, ks = self._overflow_setup()
        s = _stats(be, st)
        assert s["hot_size"] <= 4
        assert s["cold_size"] == 32                       # warm at capacity
        assert s["spill_size"] == len(ks) - s["hot_size"] - 32
        assert s["spill_size"] > 0
        assert s["size"] == len(ks)
        # one batch spilled -> exactly one sorted run
        assert int(np.asarray(st.spill.run_start).sum()) == 1
        n = int(st.spill.n)
        run = np.asarray(st.spill.keys)[:n]
        assert (np.diff(run.astype(np.float64)) > 0).all()

    def test_spill_residents_found_with_values(self):
        be, st, ks = self._overflow_setup()
        st, res = be.apply(st, make_plan(np.full(len(ks), OP_FIND, np.int32),
                                         ks))
        assert res.ok.all()
        assert (np.asarray(res.vals) == ks + 1).all()

    def test_spill_delete_tombstones(self):
        be, st, ks = self._overflow_setup()
        victim = sorted(spill_live(st))[:5]
        st, res = be.apply(st, make_plan(
            np.full(5, OP_DELETE, np.int32), np.array(victim, np.uint64)))
        assert res.ok.all()
        s = _stats(be, st)
        assert s["size"] == len(ks) - 5
        assert s["tombstones"] >= 5                      # spill dead counted
        st, res = be.apply(st, make_plan(
            np.full(5, OP_FIND, np.int32), np.array(victim, np.uint64)))
        assert not res.ok.any()

    def test_scan_merges_all_three_tiers(self):
        be, st, ks = self._overflow_setup()
        flat = get_backend("det_skiplist")
        st_f, _ = ins(flat, flat.init(1024), ks)
        sk = np.sort(ks)
        lo = u64([0, int(sk[5])])
        hi = u64([2**63, int(sk[40])])
        out_t = [np.asarray(a) for a in be.scan(st, lo, hi, len(ks) + 8)]
        out_f = [np.asarray(a) for a in flat.scan(st_f, lo, hi, len(ks) + 8)]
        assert (out_t[0] == out_f[0]).all()              # exact counts
        for q in range(2):
            rows_t = [(int(k), int(v)) for k, v, m in
                      zip(out_t[1][q], out_t[2][q], out_t[3][q]) if m]
            rows_f = [(int(k), int(v)) for k, v, m in
                      zip(out_f[1][q], out_f[2][q], out_f[3][q]) if m]
            assert rows_t == rows_f == sorted(rows_t), q

    def test_promotion_from_spill_marks_dead(self):
        be, st, ks = self._overflow_setup()
        target = sorted(spill_live(st))[0]
        # free the single hot bucket so promotion has space (policy "none")
        hot_res = np.array(sorted(hot_set(st)), np.uint64)
        st, res = be.apply(st, make_plan(
            np.full(len(hot_res), OP_DELETE, np.int32), hot_res))
        assert res.ok.all()
        dead0 = int(st.spill.n_dead)
        st, res = be.apply(st, make_plan(
            np.array([OP_FIND], np.int32), u64([target])))
        assert bool(res.ok[0]) and int(res.vals[0]) == target + 1
        assert target in hot_set(st)                     # promoted up
        assert target not in spill_live(st)              # tombstoned below
        assert int(st.spill.n_dead) == dead0 + 1
        assert _stats(be, st)["size"] == len(ks) - len(hot_res)


class TestEvictionPolicies:
    def _fresh(self, name):
        be = get_backend(name)
        # hot: 8 slots x 2 -> tiny buckets so eviction triggers fast
        return be, be.init(1024, hot_bucket=2, hot_frac=64)

    def test_lru_evicts_oldest_touch(self):
        be, st = self._fresh("tiered3/lru")
        k1, k2, k3 = keys_for_slot(8, 3, 3).tolist()
        st, _ = ins(be, st, [k1])                        # stamp 0
        st, _ = ins(be, st, [k2])                        # stamp 1
        st, res = be.apply(st, make_plan(
            np.array([OP_FIND], np.int32), u64([k1])))   # k1 touched: stamp 2
        assert bool(res.ok[0])
        st, _ = ins(be, st, [k3])                        # bucket full: evict
        assert hot_set(st) == {k1, k3}                   # k2 was LRU
        assert _stats(be, st)["evictions"] == 1
        st, res = be.apply(st, make_plan(                # k2 demoted, intact
            np.array([OP_FIND], np.int32), u64([k2])))
        assert bool(res.ok[0]) and int(res.vals[0]) == k2 + 1

    def test_lru_without_touch_evicts_first_insert(self):
        be, st = self._fresh("tiered3/lru")
        k1, k2, k3 = keys_for_slot(8, 5, 3, seed=1).tolist()
        st, _ = ins(be, st, [k1])
        st, _ = ins(be, st, [k2])
        st, _ = ins(be, st, [k3])
        assert hot_set(st) == {k2, k3}                   # k1 oldest stamp

    def test_size_evicts_largest_payload(self):
        be, st = self._fresh("tiered3/size")
        k1, k2, k3 = keys_for_slot(8, 2, 3, seed=2).tolist()
        st, _ = ins(be, st, [k1, k2], vals=[3, 2**60])   # weights 1 vs 8
        st, _ = ins(be, st, [k3], vals=[17])
        assert hot_set(st) == {k1, k3}                   # big k2 demoted
        st, res = be.apply(st, make_plan(
            np.array([OP_FIND], np.int32), u64([k2])))
        assert bool(res.ok[0]) and int(res.vals[0]) == 2**60

    @pytest.mark.parametrize("name", POLICIED)
    def test_eviction_is_membership_neutral(self, name):
        be, st = self._fresh(name)
        rng = np.random.default_rng(13)
        ks = np.unique(rng.integers(1, 2**62, 200, dtype=np.uint64))
        st, res = ins(be, st, ks)
        assert res.ok.all()
        s = _stats(be, st)
        assert s["size"] == len(ks)
        assert s["hot_size"] + s["cold_size"] + s["spill_size"] == len(ks)
        st, res = be.apply(st, make_plan(
            np.full(len(ks), OP_FIND, np.int32), ks))
        assert res.ok.all()
        assert (np.asarray(res.vals) == ks + 1).all()

    @pytest.mark.parametrize("name", POLICIED)
    def test_full_stack_fails_new_lane_not_residents(self, name):
        """When every tier is full, eviction is suppressed (no headroom):
        the NEW insert reports ok=False — the flat backend's allocation
        failure — and every previously stored key stays findable."""
        be = get_backend(name)
        st = be.init(8, hot_bucket=2, hot_frac=4, spill_cap=8)  # hot 2, 8, 8
        rng = np.random.default_rng(41)
        ks = np.unique(rng.integers(1, 2**62, 64, dtype=np.uint64))
        st, res = ins(be, st, ks)
        stored = ks[np.asarray(res.ok)]
        assert len(stored) == int(be.stats(st)["capacity"])   # brim full
        extra = np.uint64(2**62 + 5)
        st, res = be.apply(st, make_plan(
            np.array([OP_INSERT], np.int32), u64([extra]), u64([extra + 1])))
        assert not bool(res.ok[0])                 # new lane fails honestly
        st, res = be.apply(st, make_plan(
            np.full(len(stored), OP_FIND, np.int32), stored))
        assert res.ok.all()                        # no resident was lost
        assert (np.asarray(res.vals) == stored + 1).all()

    @pytest.mark.parametrize("name", ["tiered3", "tiered3/lru"])
    def test_flush_on_full_stack_keeps_unabsorbed_hot(self, name):
        """flush() demotes what the lower tiers can absorb and KEEPS the
        rest hot — a full stack must not turn flush into key loss."""
        be = get_backend(name)
        st = be.init(8, hot_bucket=2, hot_frac=4, spill_cap=8)
        rng = np.random.default_rng(47)
        ks = np.unique(rng.integers(1, 2**62, 64, dtype=np.uint64))
        st, res = ins(be, st, ks)
        stored = ks[np.asarray(res.ok)]
        s0 = _stats(be, st)
        assert s0["size"] == s0["capacity"] and s0["hot_size"] > 0
        st = be.flush(st)
        s1 = _stats(be, st)
        assert s1["size"] == s0["size"]            # nothing lost
        assert s1["hot_size"] == s0["hot_size"]    # no headroom below
        st, res = be.apply(st, make_plan(
            np.full(len(stored), OP_FIND, np.int32), stored))
        assert res.ok.all()
        assert (np.asarray(res.vals) == stored + 1).all()

    def test_lru_reinsert_of_resident_refreshes_stamp(self):
        """An INSERT that finds its key hot-resident counts as a touch:
        upsert-style traffic must keep the entry warm."""
        be = get_backend("tiered3/lru")
        st = be.init(1024, hot_bucket=2, hot_frac=64)
        k1, k2, k3 = keys_for_slot(8, 4, 3, seed=5).tolist()
        st, _ = ins(be, st, [k1])                  # stamp 0
        st, _ = ins(be, st, [k2])                  # stamp 1
        st, res = ins(be, st, [k1])                # existed -> stamp 2
        assert not bool(res.ok[0]) or int(res.vals[0]) == 1  # existed flag
        st, _ = ins(be, st, [k3])                  # evicts k2, not k1
        assert hot_set(st) == {k1, k3}

    def test_spill_compaction_reclaims_tombstones(self):
        """Churn (deletes + promotions against spill residents) triggers
        `spill_compact` at the 25% threshold: the append cursor shrinks
        back to the live count and the runs merge into one sorted run."""
        be = get_backend("tiered3")
        st = be.init(16, hot_bucket=2, hot_frac=8, spill_cap=32)
        rng = np.random.default_rng(43)
        ks = np.unique(rng.integers(1, 2**62, 48, dtype=np.uint64))[:40]
        st, res = ins(be, st, ks)
        assert res.ok.all()
        assert int(st.spill.n) > 8
        doomed = np.array(sorted(spill_live(st)), np.uint64)
        st, res = be.apply(st, make_plan(
            np.full(len(doomed), OP_DELETE, np.int32), doomed))
        assert res.ok.all()
        assert int(st.spill.n_dead) == 0           # compaction fired
        assert int(st.spill.n) == 0                # cursor reclaimed
        live = np.array(sorted(set(ks.tolist()) - set(doomed.tolist())),
                        np.uint64)
        st, res = be.apply(st, make_plan(
            np.full(len(live), OP_FIND, np.int32), live))
        assert res.ok.all()
        assert _stats(be, st)["size"] == len(live)

    def test_policy_counters_survive_flush(self):
        be, st = self._fresh("tiered3/lru")
        ks = keys_for_slot(8, 6, 4, seed=3)
        for k in ks:                                     # 2 evictions
            st, _ = ins(be, st, [k])
        demoted = sorted(set(ks.tolist()) - hot_set(st))
        st, res = be.apply(st, make_plan(                # 2 promotions
            np.full(len(demoted), OP_FIND, np.int32),
            np.array(demoted, np.uint64)))
        assert res.ok.all()
        s0 = _stats(be, st)
        assert s0["evictions"] > 0 and s0["promotions"] > 0
        clock0 = int(st.clock)
        st = be.flush(st)
        s1 = _stats(be, st)
        assert s1["hot_size"] == 0 and s1["size"] == s0["size"]
        # the audit fix: flush clears metadata WITH the keys but must not
        # silently drop the policy's history
        assert s1["evictions"] == s0["evictions"]
        assert s1["promotions"] == s0["promotions"]
        assert int(st.clock) == clock0
        assert not np.asarray(st.hot_meta).any()
        st, res = be.apply(st, make_plan(
            np.full(len(ks), OP_FIND, np.int32), ks))
        assert res.ok.all()


# ---------------------------------------------------------------------------
# residency determinism (the eviction-determinism contract)
# ---------------------------------------------------------------------------

def _churn_plans(seed=21, n_rounds=6, width=48):
    """Mixed workload over a pool small enough to churn every tier."""
    rng = np.random.default_rng(seed)
    pool = rng.integers(1, 2**62, 96, dtype=np.uint64)
    plans = []
    for _ in range(n_rounds):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], width,
                         p=[0.5, 0.35, 0.15]).astype(np.int32)
        keys = rng.choice(pool, width)
        mask = rng.random(width) > 0.05
        plans.append(make_plan(ops, keys, keys + 1, mask))
    return plans


def assert_states_equal(sa, sb, ctx):
    la, lb = jax.tree.leaves(sa), jax.tree.leaves(sb)
    assert len(la) == len(lb), ctx
    for i, (a, b) in enumerate(zip(la, lb)):
        assert (np.asarray(a) == np.asarray(b)).all(), (ctx, i)


@pytest.mark.parametrize("name", TIERED)
def test_residency_bit_identical_across_modes(name):
    """Same plan stream => identical TIER RESIDENCY (full state pytree,
    including policy metadata and spill runs) in every exec mode."""
    be = get_backend(name)
    states = {}
    for mode in exec_.runnable_modes():
        with exec_.exec_mode(mode):
            st = be.init(64, hot_bucket=4, hot_frac=8)   # churn all tiers
            for p in _churn_plans():
                st, _ = be.apply(st, p)
        states[mode] = st
    ref_mode, ref = next(iter(states.items()))
    for mode, st in states.items():
        assert_states_equal(ref, st, (name, ref_mode, mode))
        # the warm tier's derived block layout stays sound in every mode
        assert_bskiplist_ok(st.cold, (name, mode))


@pytest.mark.parametrize("name", POLICIED)
def test_engine_residency_matches_direct_apply(name):
    """Sharding is pure partitioning: the 1-device engine's backend state
    is bit-identical to a direct (engine-less) instance applying the same
    per-round op multisets — placement depends on sorted key order, not
    lane order, so the engine's routing/pooling cannot change residency."""
    from repro.store.engine import StoreEngine
    lanes = 32
    mesh = jax.make_mesh((1,), ("data",), devices=np.array(jax.devices()[:1]))
    eng = StoreEngine(mesh, ("data",), lanes, backend=name)
    state = jax.device_put(eng.init(64, hot_bucket=4, hot_frac=8),
                           eng.sharding)
    be = get_backend(name)
    direct = be.init(64, hot_bucket=4, hot_frac=8)

    rng = np.random.default_rng(31)
    pool = rng.integers(1, 2**62, 64, dtype=np.uint64)
    put = lambda x: jax.device_put(jnp.asarray(x), eng.sharding)
    for _ in range(5):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], lanes,
                         p=[0.5, 0.35, 0.15]).astype(np.int32)
        keys = rng.choice(pool, lanes, replace=False)    # distinct per round
        state, _, _, dropped = eng.step(state, put(ops), put(keys),
                                        put(keys + 7))
        assert int(dropped) == 0
        direct, _ = be.apply(direct, make_plan(ops, keys, keys + 7))
    assert_states_equal(jax.tree.map(lambda x: x[0], state), direct, name)
    assert_bskiplist_ok(direct.cold, name)
