"""Tests for the `pq` priority-queue Store backend and the pop/range-delete
lane ops.

The load-bearing properties:

* **Bulk-pop determinism** — the j-th pop lane of a plan receives the j-th
  smallest live key (one shared rank pool in lane order), POPMIN answers
  the popped VALUE and POPK the popped KEY, and pops past empty are clean
  misses (ok=False, vals=0) that count `pop_empty`.
* **Linearization** — INSERTS -> DELETES -> RANGE_DELETES -> POPS -> FINDS
  within one plan, so same-plan inserts are poppable and finds observe the
  post-pop heap.
* **Exec-mode parity** — results AND post-apply state pytrees bit-identical
  between `jnp` and the kernelized modes (`kernels/pq_pop` rank-select +
  the shared level walk), the same contract every other probe obeys.
* **Model agreement** — a seeded mixed workload tracks a host sorted-dict
  model exactly.
* **Range delete** — OP_RANGE_DELETE removes [lo, hi) on both ordered
  backends (det_skiplist and pq), reports per-lane counts, attributes
  overlapping lanes deterministically, and scans never see deleted keys.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401  (enables x64)
from repro.store import (OP_DELETE, OP_FIND, OP_INSERT, OP_NONE, OP_POPK,
                         OP_POPMIN, OP_RANGE_DELETE, get_backend, make_plan)
from repro.store import exec as exec_

from invariants import assert_bskiplist_ok

MODES = exec_.runnable_modes()


def u64(xs):
    return jnp.asarray(np.array(xs, dtype=np.uint64))


def i32(xs):
    return np.asarray(xs, np.int32)


def seeded(be, keys):
    st = be.init(1024)
    ks = u64(keys)
    st, res = be.apply(st, make_plan(np.full(len(keys), OP_INSERT, np.int32),
                                     ks, ks * jnp.uint64(10)))
    assert res.ok.all()
    return st


# ---------------------------------------------------------------------------
# pop semantics
# ---------------------------------------------------------------------------

class TestPopSemantics:
    def test_popmin_vs_popk_result_forms(self):
        be = get_backend("pq")
        st = seeded(be, [30, 10, 20])
        st, res = be.apply(st, make_plan(i32([OP_POPMIN, OP_POPK]),
                                         u64([0, 0]), u64([0, 0])))
        assert res.ok.all()
        assert int(res.vals[0]) == 100     # POPMIN -> value of key 10
        assert int(res.vals[1]) == 20      # POPK   -> the key itself

    def test_bulk_pop_rank_pool_in_lane_order(self):
        be = get_backend("pq")
        st = seeded(be, [50, 10, 40, 20, 30])
        # mixed POPK/POPMIN lanes share ONE rank pool in lane order
        st, res = be.apply(st, make_plan(
            i32([OP_POPK, OP_POPMIN, OP_POPK, OP_POPMIN]),
            u64([0] * 4), u64([0] * 4)))
        assert res.ok.all()
        assert [int(v) for v in res.vals] == [10, 200, 30, 400]
        st, res = be.apply(st, make_plan(i32([OP_POPK]), u64([0]), u64([0])))
        assert res.ok.all() and int(res.vals[0]) == 50

    def test_pop_empty_is_clean_miss(self):
        be = get_backend("pq")
        st = seeded(be, [10])
        st, res = be.apply(st, make_plan(i32([OP_POPK, OP_POPK, OP_POPK]),
                                         u64([0] * 3), u64([0] * 3)))
        assert [bool(b) for b in res.ok] == [True, False, False]
        assert [int(v) for v in res.vals] == [10, 0, 0]
        stats = be.stats(st)
        assert int(stats["pops"]) == 1 and int(stats["pop_empty"]) == 2
        # masked-off pop lanes are not misses
        st, res = be.apply(st, make_plan(i32([OP_POPK]), u64([0]), u64([0]),
                                         np.array([False])))
        assert not bool(res.ok[0])
        assert int(be.stats(st)["pop_empty"]) == 2

    def test_same_plan_insert_then_pop_linearization(self):
        be = get_backend("pq")
        st = seeded(be, [20])
        # the insert of 5 commits BEFORE the pops; the find runs after them
        st, res = be.apply(st, make_plan(
            i32([OP_POPK, OP_INSERT, OP_POPK, OP_FIND]),
            u64([0, 5, 0, 20]), u64([0, 55, 0, 0])))
        assert [int(v) for v in res.vals[:3]] == [5, 0, 20]
        assert not bool(res.ok[3])           # 20 was popped by lane 2
        assert int(be.stats(st)["size"]) == 0

    def test_delete_then_pop_skips_tombstones(self):
        be = get_backend("pq")
        st = seeded(be, [10, 20, 30])
        st, res = be.apply(st, make_plan(i32([OP_DELETE, OP_POPK]),
                                         u64([10, 0]), u64([0, 0])))
        assert int(res.vals[1]) == 20        # 10 died first in the same plan
        # pop across a compaction boundary still deterministic
        st, res = be.apply(st, make_plan(i32([OP_POPK]), u64([0]), u64([0])))
        assert int(res.vals[0]) == 30

    def test_scan_and_find_after_pops(self):
        be = get_backend("pq")
        st = seeded(be, [10, 20, 30, 40])
        st, _ = be.apply(st, make_plan(i32([OP_POPK, OP_POPK]),
                                       u64([0, 0]), u64([0, 0])))
        cnt, ks, _, _ = be.scan(st, u64([0]), u64([2**63]), 8)
        assert int(cnt[0]) == 2
        assert [int(k) for k in ks[0, :2]] == [30, 40]
        _, res = be.apply(st, make_plan(i32([OP_FIND, OP_FIND]),
                                        u64([10, 30]), u64([0, 0])))
        assert [bool(b) for b in res.ok] == [False, True]


# ---------------------------------------------------------------------------
# model agreement + determinism
# ---------------------------------------------------------------------------

def _model_apply(model, ops, keys, vals, mask):
    """Host sorted-dict oracle for one plan under the pq linearization."""
    out_ok, out_vals = [], []
    results = {}
    for i, (o, k, v, m) in enumerate(zip(ops, keys, vals, mask)):
        if m and o == OP_INSERT:             # INSERT -> (applied, existed)
            results[i] = (True, 1 if k in model else 0)
            model.setdefault(k, v)
    for i, (o, k, m) in enumerate(zip(ops, keys, mask)):
        if m and o == OP_DELETE:             # DELETE -> (removed, 0)
            results[i] = (k in model, 0)
            model.pop(k, None)
    pop_lanes = [i for i, (o, m) in enumerate(zip(ops, mask))
                 if m and o in (OP_POPMIN, OP_POPK)]
    popped = sorted(model)[:len(pop_lanes)]
    for i, lane in enumerate(pop_lanes):
        if i < len(popped):
            k = popped[i]
            results[lane] = (True, model[k] if ops[lane] == OP_POPMIN else k)
            del model[k]
        else:
            results[lane] = (False, 0)
    for i, (o, k, m) in enumerate(zip(ops, keys, mask)):
        if m and o == OP_FIND:
            results[i] = (k in model, model.get(k, 0))
    for i in range(len(ops)):
        ok, v = results.get(i, (False, 0))
        out_ok.append(ok)
        out_vals.append(v)
    return model, out_ok, out_vals


def _pq_plans(seed, n_rounds=6, width=32):
    rng = np.random.default_rng(seed)
    pool = rng.integers(1, 2**62, 48, dtype=np.uint64)
    plans = []
    for _ in range(n_rounds):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE, OP_POPMIN, OP_POPK],
                         width, p=[0.3, 0.35, 0.1, 0.15, 0.1]).astype(np.int32)
        keys = rng.choice(pool, width)
        mask = rng.random(width) > 0.05
        plans.append(make_plan(ops, keys, keys + 1, mask))
    return plans


class TestModelAndDeterminism:
    @pytest.mark.parametrize("seed", [0, 7])
    def test_seeded_workload_matches_sorted_model(self, seed):
        be = get_backend("pq")
        st = be.init(1024)
        model = {}
        for plan in _pq_plans(seed):
            st, res = be.apply(st, plan)
            model, ok, vals = _model_apply(
                model, np.asarray(plan.ops), np.asarray(plan.keys),
                np.asarray(plan.vals), np.asarray(plan.mask))
            assert np.array_equal(np.asarray(res.ok), ok)
            assert np.array_equal(np.asarray(res.vals),
                                  np.asarray(vals, np.uint64))
        assert int(be.stats(st)["size"]) == len(model)
        # churned heap still yields a sound derived block layout
        assert_bskiplist_ok(st.heap, f"pq seed={seed}")

    def test_replay_bit_identical(self):
        be = get_backend("pq")
        outs = []
        for _ in range(2):
            st = be.init(512)
            acc = []
            for plan in _pq_plans(3):
                st, res = be.apply(st, plan)
                acc.append((np.asarray(res.ok), np.asarray(res.vals)))
            outs.append((acc, st))
        for (a_ok, a_v), (b_ok, b_v) in zip(*[o[0] for o in outs]):
            assert np.array_equal(a_ok, b_ok) and np.array_equal(a_v, b_v)
        for a, b in zip(jax.tree.leaves(outs[0][1]),
                        jax.tree.leaves(outs[1][1])):
            assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# exec-mode parity (jnp reference vs kernels/pq_pop)
# ---------------------------------------------------------------------------

class TestExecModeParity:
    def test_results_and_state_parity(self):
        be = get_backend("pq")
        ref_out = None
        for mode in MODES:
            with exec_.exec_mode(mode):
                st = be.init(512)
                acc = []
                for plan in _pq_plans(5):
                    st, res = be.apply(st, plan)
                    acc.append((np.asarray(res.ok), np.asarray(res.vals)))
            out = (acc, jax.tree.leaves(st))
            if ref_out is None:
                ref_out = out
                continue
            for (a_ok, a_v), (b_ok, b_v) in zip(ref_out[0], out[0]):
                assert np.array_equal(a_ok, b_ok), f"ok diverges in {mode}"
                assert np.array_equal(a_v, b_v), f"vals diverge in {mode}"
            for a, b in zip(ref_out[1], out[1]):
                assert np.array_equal(np.asarray(a), np.asarray(b)), \
                    f"state diverges in {mode}"
            assert_bskiplist_ok(st.heap, mode)

    def test_obs_pop_counters_mode_parity(self):
        be = get_backend("obs:pq")
        ref = None
        for mode in MODES:
            with exec_.exec_mode(mode):
                st = be.init(512)
                for plan in _pq_plans(9, n_rounds=3):
                    st, _ = be.apply(st, plan)
                # over-drain so the pop_empty counter fires too
                st, _ = be.apply(st, make_plan(
                    np.full(64, OP_POPK, np.int32), u64([0] * 64),
                    u64([0] * 64)))
                m = {k: int(v) for k, v in be.metrics(st).items()}
            assert m["pops"] > 0 and m["pop_empty"] > 0
            if ref is None:
                ref = m
            assert m == ref, f"metrics diverge in mode {mode}"

    def test_pop_under_jit(self):
        be = get_backend("pq")
        st = seeded(be, [30, 10, 20])
        plan = make_plan(i32([OP_POPK, OP_POPK]), u64([0, 0]), u64([0, 0]))
        st2, res = jax.jit(be.apply)(st, plan)
        assert [int(v) for v in res.vals] == [10, 20]
        assert int(be.stats(st2)["size"]) == 1


# ---------------------------------------------------------------------------
# range delete (both ordered backends)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["det_skiplist", "pq"])
class TestRangeDelete:
    def test_deletes_half_open_interval(self, name):
        be = get_backend(name)
        st = seeded(be, [10, 20, 30, 40, 50])
        st, res = be.apply(st, make_plan(i32([OP_RANGE_DELETE]),
                                         u64([20]), u64([41])))
        assert bool(res.ok[0]) and int(res.vals[0]) == 3    # 20, 30, 40
        cnt, ks, _, _ = be.scan(st, u64([0]), u64([2**63]), 8)
        assert int(cnt[0]) == 2
        assert [int(k) for k in ks[0, :2]] == [10, 50]
        # empty interval: ok=False, count 0
        st, res = be.apply(st, make_plan(i32([OP_RANGE_DELETE]),
                                         u64([20]), u64([41])))
        assert not bool(res.ok[0]) and int(res.vals[0]) == 0

    def test_overlapping_lanes_attribute_once(self, name):
        be = get_backend(name)
        st = seeded(be, [10, 20, 30, 40])
        # both lanes cover 20 and 30; the FIRST covering lane owns each key
        st, res = be.apply(st, make_plan(
            i32([OP_RANGE_DELETE, OP_RANGE_DELETE]),
            u64([15, 10]), u64([35, 45])))
        assert [int(v) for v in res.vals] == [2, 2]
        assert int(be.stats(st)["size"]) == 0

    def test_linearizes_before_pops_and_finds(self, name):
        be = get_backend(name)
        st = seeded(be, [10, 20, 30])
        ops = [OP_RANGE_DELETE, OP_FIND]
        keys, vals = [5, 10], [25, 0]
        if name == "pq":
            ops.append(OP_POPK)
            keys.append(0)
            vals.append(0)
        st, res = be.apply(st, make_plan(i32(ops), u64(keys), u64(vals)))
        assert int(res.vals[0]) == 2         # 10 and 20 deleted
        assert not bool(res.ok[1])           # FIND sees the post-delete heap
        if name == "pq":
            assert int(res.vals[2]) == 30    # pop skips the deleted range

    def test_mode_parity(self, name):
        be = get_backend(name)
        ref = None
        for mode in MODES:
            with exec_.exec_mode(mode):
                st = seeded(be, [10, 20, 30, 40, 50, 60])
                st, res = be.apply(st, make_plan(
                    i32([OP_RANGE_DELETE, OP_FIND, OP_RANGE_DELETE]),
                    u64([25, 60, 55]), u64([45, 0, 61])))
                out = (np.asarray(res.ok), np.asarray(res.vals),
                       [np.asarray(x) for x in jax.tree.leaves(st)])
            if ref is None:
                ref = out
                continue
            assert np.array_equal(ref[0], out[0])
            assert np.array_equal(ref[1], out[1])
            for a, b in zip(ref[2], out[2]):
                assert np.array_equal(a, b), f"state diverges in {mode}"

    def test_unordered_backends_report_miss(self, name):
        del name
        be = get_backend("twolevel_hash")
        st = be.init(256)
        ks = u64([10, 20])
        st, _ = be.apply(st, make_plan(np.full(2, OP_INSERT, np.int32),
                                       ks, ks))
        st, res = be.apply(st, make_plan(i32([OP_RANGE_DELETE]),
                                         u64([0]), u64([100])))
        # hash backends don't implement range delete: clean per-lane miss
        assert not bool(res.ok[0]) and int(res.vals[0]) == 0
        _, res = be.apply(st, make_plan(i32([OP_FIND, OP_FIND]), ks, ks))
        assert res.ok.all()
