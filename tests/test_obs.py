"""Observability-layer tests (`repro.store.obs` + friends).

The load-bearing contract: the `metrics()` pytree is DETERMINISTIC the same
way results are — bit-identical across every runnable exec mode
(jnp | interpret | pallas), across the fused and unfused tier probe paths,
and across shardings (the 1-device engine here; 8 shards in
tests/multidev/store_prog.py's METRICS-OK lane). Plus: the counters are
CORRECT on hand-built plans, the plane jits and carries across steps, the
exec dispatch meters are context-local and nestable, spans export as
Chrome-trace JSON, and `tools/bench_diff.py --assert-within` gates
regressions with the right exit codes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.store import METRICS_SCHEMA, get_backend, make_plan
from repro.store import exec as exec_
from repro.store import obs
from repro.store.api import OP_DELETE, OP_FIND, OP_INSERT
from repro.store.tiers import unfused_twin

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

OBS_BACKENDS = ("obs:fixed_hash", "obs:det_skiplist", "obs:hash+skiplist",
                "obs:tiered3", "obs:tiered3/lru", "obs:tiered3/size")


def churn_plans(n_plans=5, width=16, key_lo=1, key_hi=48, seed=0):
    rng = np.random.default_rng(seed)
    plans = []
    for _ in range(n_plans):
        ops = rng.integers(0, 3, width).astype(np.int32)
        keys = rng.integers(key_lo, key_hi, width).astype(np.uint64)
        vals = rng.integers(1, 1 << 20, width).astype(np.uint64)
        plans.append(make_plan(ops, keys, vals))
    return plans


def apply_stream(be, plans, mode, capacity=64, jit=False, **init_kw):
    st = be.init(capacity, **init_kw)
    with exec_.exec_mode(mode):
        step = jax.jit(be.apply) if jit else be.apply
        for p in plans:
            st, _ = step(st, p)
    return st


def as_ints(metrics):
    return {k: int(v) for k, v in metrics.items()}


# ---------------------------------------------------------------------------
# metrics plane: schema, correctness, jit carry
# ---------------------------------------------------------------------------

class TestMetricsPlane:
    def test_schema_complete_and_zeroed(self):
        be = get_backend("obs:fixed_hash")
        st = be.init(64)
        m = be.metrics(st)
        assert set(m) == set(METRICS_SCHEMA)
        assert all(int(v) == 0 for v in m.values())
        assert all(v.dtype == jnp.int64 for v in m.values())

    def test_unknown_metric_rejected(self):
        with obs.collect() as frame:
            with pytest.raises(ValueError, match="unknown metric"):
                frame.add("not_a_metric", 1)

    def test_record_noop_without_frame(self):
        # the thunk must NOT be evaluated when no frame is active — this is
        # the observability-off-costs-nothing contract
        evaluated = []
        obs.record("find_hits", lambda: evaluated.append(1) or 1)
        assert not evaluated
        with obs.collect() as frame:
            obs.record("find_hits", lambda: evaluated.append(1) or 1)
        assert evaluated and int(frame.acc["find_hits"]) == 1

    def test_innermost_frame_wins(self):
        with obs.collect() as outer:
            with obs.collect() as inner:
                obs.record("ops_find", 3)
            obs.record("ops_find", 2)
        assert int(inner.acc["ops_find"]) == 3
        assert int(outer.acc["ops_find"]) == 2

    def test_hand_built_plan_counters(self):
        be = get_backend("obs:tiered3/lru")
        st = be.init(64, hot_bucket=4, hot_frac=8)
        # insert 1, 2, 3 (new); re-insert 2 (existing); delete 3; find
        # 1 (hit), 2 (hit), 99 (miss)
        st, _ = be.apply(st, make_plan(
            [OP_INSERT] * 3, [1, 2, 3], [10, 20, 30]))
        st, _ = be.apply(st, make_plan(
            [OP_INSERT, OP_DELETE], [2, 3], [99, 0]))
        st, res = be.apply(st, make_plan(
            [OP_FIND] * 3, [1, 2, 99]))
        m = as_ints(be.metrics(st))
        assert m["ops_insert"] == 4 and m["ops_delete"] == 1
        assert m["ops_find"] == 3
        assert m["inserts_new"] == 3 and m["inserts_existing"] == 1
        assert m["deletes_hit"] == 1
        assert m["find_hits"] == 2 and m["find_misses"] == 1
        # all three finds answered hot (fresh small inserts stay hot)
        assert m["hot_hits"] + m["warm_hits"] + m["spill_hits"] == 2
        assert np.array_equal(np.asarray(res.ok), [True, True, False])

    def test_plan_counters_respect_mask_and_none(self):
        be = get_backend("obs:det_skiplist")
        st = be.init(64)
        plan = make_plan([OP_INSERT, OP_INSERT, -1, OP_FIND],
                         [5, 6, 7, 5], [1, 2, 3, 0],
                         mask=[True, False, True, True])
        st, _ = be.apply(st, plan)
        m = as_ints(be.metrics(st))
        assert m["ops_insert"] == 1          # masked + OP_NONE lanes ignored
        assert m["ops_find"] == 1 and m["find_hits"] == 1

    def test_metrics_jit_carry(self):
        be = get_backend("obs:tiered3/lru")
        plans = churn_plans()
        st_e = apply_stream(be, plans, "jnp", jit=False,
                            hot_bucket=4, hot_frac=8)
        st_j = apply_stream(be, plans, "jnp", jit=True,
                            hot_bucket=4, hot_frac=8)
        assert as_ints(be.metrics(st_e)) == as_ints(be.metrics(st_j))
        assert any(v for v in as_ints(be.metrics(st_j)).values())

    def test_movement_counters_match_stats(self):
        # the metrics plane's eviction/promotion counts must agree with the
        # tier state's own cumulative counters
        be = get_backend("obs:tiered3/lru")
        st = be.init(64, hot_bucket=4, hot_frac=8)
        for p in churn_plans(n_plans=8):
            st, _ = be.apply(st, p)
        m = as_ints(be.metrics(st))
        stats = {k: int(v) for k, v in be.stats(st).items()}
        assert m["evictions"] == stats["evictions"]
        assert m["promotions"] == stats["promotions"]

    def test_flush_records_demotions(self):
        be = get_backend("obs:tiered3/lru")
        st = be.init(64, hot_bucket=4, hot_frac=8)
        st, _ = be.apply(st, make_plan([OP_INSERT] * 4, [1, 2, 3, 4],
                                       [1, 2, 3, 4]))
        before = as_ints(be.metrics(st))["demotions"]
        st = be.flush(st)
        after = as_ints(be.metrics(st))["demotions"]
        assert after > before
        assert int(be.stats(st)["hot_size"]) == 0


# ---------------------------------------------------------------------------
# determinism: exec modes, fused vs unfused, engine vs direct replay
# ---------------------------------------------------------------------------

class TestMetricsParity:
    @pytest.mark.parametrize("name", OBS_BACKENDS)
    def test_bit_identical_across_exec_modes(self, name):
        be = get_backend(name)
        plans = churn_plans()
        kw = (dict(hot_bucket=4, hot_frac=8)
              if name.startswith("obs:tiered3")
              or name == "obs:hash+skiplist" else {})
        ref = None
        for mode in exec_.runnable_modes():
            st = apply_stream(be, plans, mode, **kw)
            m = as_ints(be.metrics(st))
            if ref is None:
                ref = m
            else:
                assert m == ref, f"{name} metrics diverge in mode {mode}"

    @pytest.mark.parametrize("name", ["tiered3", "tiered3/lru",
                                      "tiered3/size"])
    def test_fused_vs_unfused_identical(self, name):
        plans = churn_plans()
        kw = dict(hot_bucket=4, hot_frac=8)
        fused = get_backend(f"obs:{name}")
        unf = obs.ObservedStore(unfused_twin(name))
        mf = as_ints(fused.metrics(apply_stream(fused, plans, "jnp", **kw)))
        mu = as_ints(unf.metrics(apply_stream(unf, plans, "jnp", **kw)))
        assert mf == mu

    def test_engine_matches_direct_replay(self):
        # METRICS-OK, 1-device form (8-shard form in multidev/store_prog.py):
        # the engine-carried plane == a direct observed instance replaying
        # the same stream, plus exact routed_ops/routed_bytes
        from jax.sharding import Mesh
        from repro.store.engine import StoreEngine

        lanes, steps = 16, 5
        mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
        eng = StoreEngine(mesh, ("d",), lanes=lanes,
                          backend="obs:tiered3/lru")
        state = jax.device_put(eng.init(64, hot_bucket=4, hot_frac=8),
                               eng.sharding)
        be = get_backend("obs:tiered3/lru")
        st_direct = be.init(64, hot_bucket=4, hot_frac=8)

        rng = np.random.default_rng(3)
        total_valid = 0
        for _ in range(steps):
            ops = rng.integers(0, 3, lanes).astype(np.int32)
            keys = rng.integers(1, 48, lanes).astype(np.uint64)
            vals = rng.integers(1, 1 << 20, lanes).astype(np.uint64)
            state, _, _, dropped = eng.step(state, jnp.asarray(ops),
                                            jnp.asarray(keys),
                                            jnp.asarray(vals))
            assert int(dropped) == 0
            total_valid += int(np.sum(ops >= 0))
            # single shard: the routed plan is the original plan padded to
            # the engine pool; pad lanes are masked, so metrics match the
            # unpadded direct apply
            pool = 2 * lanes
            p_ops = np.full(pool, -1, np.int32)
            p_keys = np.zeros(pool, np.uint64)
            p_vals = np.zeros(pool, np.uint64)
            p_ops[:lanes], p_keys[:lanes], p_vals[:lanes] = ops, keys, vals
            st_direct, _ = be.apply(st_direct, make_plan(
                p_ops, p_keys, p_vals,
                mask=np.arange(pool) < lanes))

        m_eng = {k: int(v[0]) for k, v in eng.metrics(state).items()}
        m_dir = as_ints(be.metrics(st_direct))
        for k in METRICS_SCHEMA:
            if k in ("routed_ops", "routed_bytes"):
                continue
            assert m_eng[k] == m_dir[k], k
        assert m_eng["routed_ops"] == total_valid
        assert m_eng["routed_bytes"] == obs.ROUTED_OP_BYTES * total_valid

    def test_plain_backend_state_unchanged(self):
        # wrapping is opt-in: the un-prefixed backend's state pytree carries
        # no metrics and its apply records nothing
        be = get_backend("tiered3/lru")
        st = be.init(64, hot_bucket=4, hot_frac=8)
        assert not isinstance(st, obs.ObservedState)
        assert not hasattr(be, "metrics")


# ---------------------------------------------------------------------------
# exec dispatch meters: context-local + nestable (satellite fix)
# ---------------------------------------------------------------------------

class TestDispatchMeters:
    def test_nested_meters_compose(self):
        h = get_backend("fixed_hash").init(64)
        q = jnp.zeros((8,), jnp.uint64)
        with exec_.measure_dispatches() as outer:
            exec_.hash_find(h, q)
            with exec_.measure_dispatches() as inner:
                exec_.hash_find(h, q)
                exec_.hash_find(h, q)
            assert inner.n == 2
            exec_.hash_find(h, q)
        assert outer.n == 4      # inner activity counts toward the outer
        assert inner.n == 2      # ... without clobbering the inner total

    def test_meters_are_thread_local(self):
        h = get_backend("fixed_hash").init(64)
        q = jnp.zeros((8,), jnp.uint64)
        seen = {}

        def other():
            with exec_.measure_dispatches() as m:
                exec_.hash_find(h, q)
            seen["other"] = m.n

        with exec_.measure_dispatches() as mine:
            t = threading.Thread(target=other)
            t.start()
            t.join()
            exec_.hash_find(h, q)
        assert seen["other"] == 1
        assert mine.n == 1       # the other thread's probe never leaked in

    def test_reset_does_not_corrupt_meters(self):
        h = get_backend("fixed_hash").init(64)
        q = jnp.zeros((8,), jnp.uint64)
        with exec_.measure_dispatches() as m:
            exec_.hash_find(h, q)
            exec_.reset_dispatch_count()     # documented: meters unaffected
            exec_.hash_find(h, q)
        assert m.n == 2
        assert exec_.dispatch_count() == 1   # global restarted mid-block


# ---------------------------------------------------------------------------
# spans + chrome-trace export
# ---------------------------------------------------------------------------

class TestSpans:
    def test_span_noop_without_tracer(self):
        assert obs.current_tracer() is None
        with obs.span("find"):       # must not raise or record anywhere
            pass

    def test_tracer_records_nested_spans(self):
        with obs.tracing() as tr:
            with obs.span("step", backend="x"):
                with obs.span("find", cat="dispatch"):
                    pass
        names = [s.name for s in tr.spans]
        assert names == ["find", "step"]      # inner closes first
        step = tr.spans[1]
        assert step.args == {"backend": "x"}
        assert step.dur_ns >= tr.spans[0].dur_ns

    def test_chrome_trace_structure(self):
        sys.path.insert(0, os.path.join(ROOT, "tools"))
        import trace_export
        with obs.tracing() as tr:
            with obs.span("step", lanes=4):
                pass
        payload = trace_export.to_chrome_trace(tr, meta={"k": 1})
        evs = [e for e in payload["traceEvents"] if e["ph"] == "X"]
        assert len(evs) == 1 and evs[0]["name"] == "step"
        assert evs[0]["ts"] >= 0 and evs[0]["dur"] >= 0
        assert evs[0]["args"] == {"lanes": 4}
        assert payload["otherData"] == {"k": 1}
        json.dumps(payload)      # must be JSON-serializable as-is

    def test_apply_emits_taxonomy_spans(self):
        be = get_backend("obs:tiered3/lru")
        st = be.init(64, hot_bucket=4, hot_frac=8)
        with obs.tracing() as tr:
            st, _ = be.apply(st, make_plan([OP_INSERT, OP_FIND], [1, 1],
                                           [7, 0]))
        names = {s.name for s in tr.spans}
        assert {"insert", "delete", "find", "promote",
                "compact"} <= names
        assert names <= set(obs.SPAN_TAXONOMY) | {"demote"}
        assert all(s.name in obs.SPAN_TAXONOMY for s in tr.spans)


# ---------------------------------------------------------------------------
# bench_diff --assert-within (satellite gate)
# ---------------------------------------------------------------------------

class TestBenchDiffGate:
    def _artifact(self, tmp_path, name, us):
        payload = {"table": "t", "jax_backend": "cpu", "bench_iters": 5,
                   "warmup_discard": 2,
                   "rows": [{"name": r, "us_per_call": u}
                            for r, u in us.items()]}
        p = tmp_path / name
        p.write_text(json.dumps(payload))
        return str(p)

    def _run(self, *args):
        return subprocess.run(
            [sys.executable, os.path.join(ROOT, "tools", "bench_diff.py"),
             *args], capture_output=True, text=True)

    def test_within_threshold_passes(self, tmp_path):
        a = self._artifact(tmp_path, "a.json", {"r": 10.0, "s": 20.0})
        b = self._artifact(tmp_path, "b.json", {"r": 11.0, "s": 15.0})
        r = self._run("--assert-within", "25", a, b)
        assert r.returncode == 0, r.stderr
        assert "OK" in r.stdout

    def test_regression_fails(self, tmp_path):
        a = self._artifact(tmp_path, "a.json", {"r": 10.0, "s": 20.0})
        b = self._artifact(tmp_path, "b.json", {"r": 14.0, "s": 20.0})
        r = self._run("--assert-within", "25", a, b)
        assert r.returncode == 1
        assert "FAIL" in r.stderr and "r:" in r.stderr

    def test_improvement_and_missing_rows_pass(self, tmp_path):
        a = self._artifact(tmp_path, "a.json", {"r": 10.0, "gone": 5.0})
        b = self._artifact(tmp_path, "b.json", {"r": 2.0, "new": 9.0})
        assert self._run("--assert-within", "10", a, b).returncode == 0

    def test_metadata_mismatch_refuses_to_gate(self, tmp_path):
        a = self._artifact(tmp_path, "a.json", {"r": 10.0})
        payload = json.loads(open(a).read())
        payload["bench_iters"] = 3
        c = tmp_path / "c.json"
        c.write_text(json.dumps(payload))
        r = self._run("--assert-within", "10", a, str(c))
        assert r.returncode == 2
        # without the gate flag a metadata mismatch is only a warning
        assert self._run(a, str(c)).returncode == 0
