"""Fault-tolerance layer tests: journal digest chain, crash-point restore,
seeded fault plans, and ResilientEngine recovery equality.

The headline contracts (docs/resilience.md):

* snapshot + journal replay reproduces the EXACT state digest (and metrics
  plane) of the uninterrupted run — killed after every batch, in every exec
  mode;
* a mid-stream shard drop recovered in sync mode leaves results, state
  digest, and metrics digest bit-identical to a fault-free run;
* degraded mode keeps healthy lanes serving and lands the deferred lanes'
  true results in `completions`, equal to the fault-free answers.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.store import engine as engine_mod
from repro.store import obs
from repro.store import resilience as R
from repro.store.api import OP_DELETE, OP_FIND, OP_INSERT, OP_NONE

BACKEND = "obs:det_skiplist"
LANES = 8
CAP = 256


def _mk_engine(exec_mode=None, lanes=LANES):
    """A FRESH 1-shard engine (not the lru-cached local engine: these tests
    own the host `seq` counter)."""
    mesh = jax.make_mesh((1,), ("local",),
                         devices=np.array(jax.devices()[:1]))
    return engine_mod.StoreEngine(mesh, ("local",), lanes, backend=BACKEND,
                                  pool_factor=1, exec_mode=exec_mode)


def _stream(seed, n_steps, lanes=LANES):
    """Deterministic mixed op stream: inserts dominate early, finds and
    deletes of previously inserted keys later."""
    rng = np.random.default_rng(seed)
    inserted = []
    out = []
    for t in range(n_steps):
        ops = np.full(lanes, OP_NONE, np.int32)
        keys = np.zeros(lanes, np.uint64)
        vals = np.zeros(lanes, np.uint64)
        for i in range(lanes):
            r = rng.random()
            if r < 0.55 or not inserted:
                k = np.uint64(rng.integers(1, 1 << 32))
                ops[i], keys[i], vals[i] = OP_INSERT, k, np.uint64(t * 100 + i)
                inserted.append(k)
            elif r < 0.85:
                ops[i] = OP_FIND
                keys[i] = inserted[rng.integers(len(inserted))]
            else:
                ops[i] = OP_DELETE
                keys[i] = inserted[rng.integers(len(inserted))]
        out.append((ops, keys, vals))
    return out, inserted


def _run(eng, state, plans):
    """Apply plans, returning per-step (results, ok) host copies."""
    outs = []
    for ops, keys, vals in plans:
        state, res, ok, _ = eng.step(state, jnp.asarray(ops),
                                     jnp.asarray(keys), jnp.asarray(vals))
        outs.append((np.asarray(res).copy(), np.asarray(ok).copy()))
    return state, outs


class TestJournal:
    def test_chain_append_verify_tail(self):
        plans, _ = _stream(0, 4)
        j = R.Journal(base_seq=0)
        heads = [j.head_digest]
        for s, (ops, keys, vals) in enumerate(plans):
            j.append(s, ops, keys, vals)
            heads.append(j.head_digest)
        assert len(set(heads)) == 5          # every link moves the head
        assert heads[0] == R.GENESIS
        assert j.verify()
        assert len(j.tail(2)) == 2 and j.tail(2)[0].seq == 2
        assert j.next_seq == 4

    def test_seq_gap_rejected(self):
        plans, _ = _stream(1, 2)
        j = R.Journal(base_seq=0)
        j.append(0, *plans[0])
        with pytest.raises(ValueError, match="gap-free"):
            j.append(2, *plans[1])

    def test_tamper_detected(self):
        plans, _ = _stream(2, 3)
        j = R.Journal(base_seq=0)
        for s, p in enumerate(plans):
            j.append(s, *p)
        bad = j.entries[1].ops.copy()
        bad[0] = OP_NONE if bad[0] != OP_NONE else OP_FIND
        j.entries[1] = j.entries[1]._replace(ops=bad)
        with pytest.raises(ValueError, match="chain broken at entry 1"):
            j.verify()

    def test_snapshot_roundtrip_digest(self):
        eng = _mk_engine()
        state = jax.device_put(eng.init(CAP), eng.sharding)
        plans, _ = _stream(3, 2)
        state, _ = _run(eng, state, plans)
        snap = R.take_snapshot(state, eng.seq)
        back = R.snapshot_state(snap, eng.sharding)
        assert R.state_digest(back) == R.state_digest(state) == snap.digest

    def test_state_digest_moves_with_state(self):
        eng = _mk_engine()
        state = jax.device_put(eng.init(CAP), eng.sharding)
        d0 = R.state_digest(state)
        plans, _ = _stream(4, 1)
        state, _ = _run(eng, state, plans)
        assert R.state_digest(state) != d0


class TestRestoreCrashPoints:
    """Kill the run after every batch; snapshot + journal tail must rebuild
    the exact digest the uninterrupted run had at that point."""

    N = 6

    @pytest.fixture(scope="class")
    def baseline(self):
        plans, _ = _stream(10, self.N)
        eng = _mk_engine()
        state = jax.device_put(eng.init(CAP), eng.sharding)
        snap = R.take_snapshot(state, 0)
        j = R.Journal(base_seq=0)
        digests, metrics = [], []
        for s, (ops, keys, vals) in enumerate(plans):
            j.append(s, ops, keys, vals)
            state, _, _, _ = eng.step(state, jnp.asarray(ops),
                                      jnp.asarray(keys), jnp.asarray(vals))
            digests.append(R.state_digest(state))
            metrics.append({k: v.copy() for k, v in
                            eng.metrics(state).items()})
        assert j.verify()
        return snap, j, digests, metrics

    @pytest.mark.parametrize("crash_after", range(1, N + 1))
    def test_restore_at_every_crash_point(self, baseline, crash_after):
        snap, j, digests, metrics = baseline
        eng = _mk_engine()
        state, replayed = R.restore(eng, snap, j.entries[:crash_after])
        assert R.state_digest(state) == digests[crash_after - 1]
        assert eng.seq == crash_after
        assert replayed == sum(e.n_ops for e in j.entries[:crash_after])
        got = eng.metrics(state)
        want = metrics[crash_after - 1]
        assert set(got) == set(want)
        for k in want:
            assert (got[k] == want[k]).all(), k

    @pytest.mark.parametrize("mode", ["jnp", "interpret"])
    def test_restore_exec_mode_parity(self, baseline, mode):
        """Replaying the journal under a DIFFERENT exec mode lands on the
        same digest — recovery inherits the exec-mode parity contract."""
        snap, j, digests, _ = baseline
        eng = _mk_engine(exec_mode=mode)
        state, _ = R.restore(eng, snap, j.entries)
        assert R.state_digest(state) == digests[-1]

    def test_restore_rejects_misaligned_tail(self, baseline):
        snap, j, _, _ = baseline
        eng = _mk_engine()
        with pytest.raises(ValueError, match="replay expects seq"):
            R.restore(eng, snap, j.entries[1:])


class TestFaultPlan:
    def test_seed_determinism(self):
        a = R.make_fault_plan(7, 10, 4, LANES, n_faults=5)
        b = R.make_fault_plan(7, 10, 4, LANES, n_faults=5)
        assert a.faults == b.faults
        c = R.make_fault_plan(8, 10, 4, LANES, n_faults=5)
        assert a.faults != c.faults

    def test_step_zero_is_fault_free(self):
        p = R.make_fault_plan(0, 5, 2, LANES, n_faults=16)
        assert p.at(0) == []
        assert all(1 <= f.step < 5 for f in p.faults)

    def test_at_groups_by_step(self):
        p = R.FaultPlan(0, [R.Fault("stall", 2, ticks=1),
                            R.Fault("poison", 2, lane=0),
                            R.Fault("shard_drop", 3, shard=1)])
        assert len(p.at(2)) == 2 and len(p.at(3)) == 1 and p.at(1) == []

    def test_validation(self):
        with pytest.raises(ValueError, match="unknown fault kinds"):
            R.make_fault_plan(0, 5, 2, LANES, kinds=("meteor",))
        with pytest.raises(ValueError, match="n_steps"):
            R.make_fault_plan(0, 1, 2, LANES)

    def test_default_seed_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_FAULTS", "42")
        assert R.default_seed(7) == 42
        monkeypatch.delenv("REPRO_FAULTS")
        assert R.default_seed(7) == 7


class TestFaultPrimitives:
    def test_poison_and_sanitize(self):
        ops = np.asarray([OP_INSERT, OP_FIND, OP_NONE, OP_DELETE], np.int32)
        wired = R.poison_ops(jnp.asarray(ops), 1)
        clean, poisoned = R.sanitize_ops(wired)
        assert poisoned.tolist() == [False, True, False, False]
        assert clean.tolist() == [OP_INSERT, OP_NONE, OP_NONE, OP_DELETE]
        # a clean plan sanitizes to itself
        clean2, poisoned2 = R.sanitize_ops(jnp.asarray(ops))
        assert not poisoned2.any() and (clean2 == ops).all()

    def test_shard_drop_kills_liveness(self):
        state = engine_mod.sharded_init(BACKEND, 2, CAP)
        assert R.state_alive(state, 2).tolist() == [True, True]
        dropped = R.inject_shard_drop(state, 1)
        assert R.state_alive(dropped, 2).tolist() == [True, False]
        # the healthy slice is untouched, bit for bit
        a = jax.tree.leaves(state)
        b = jax.tree.leaves(dropped)
        assert all((np.asarray(x[0]) == np.asarray(y[0])).all()
                   for x, y in zip(a, b))


def _fault_free_twin(plans):
    eng = _mk_engine()
    state = jax.device_put(eng.init(CAP), eng.sharding)
    state, outs = _run(eng, state, plans)
    return eng, state, outs


class TestResilientEngineSync:
    def test_shard_drop_recovers_bit_identical(self):
        plans, _ = _stream(20, 6)
        ref_eng, ref_state, ref_outs = _fault_free_twin(plans)

        eng = _mk_engine()
        plan = R.FaultPlan(0, [R.Fault("shard_drop", 3, shard=0)])
        reng = R.ResilientEngine(eng, snapshot_every=2, fault_plan=plan)
        state = jax.device_put(eng.init(CAP), eng.sharding)
        outs = []
        for ops, keys, vals in plans:
            state, res, ok, _ = reng.step(state, jnp.asarray(ops),
                                          jnp.asarray(keys),
                                          jnp.asarray(vals))
            outs.append((np.asarray(res).copy(), np.asarray(ok).copy()))

        for t, ((rv, rok), (fv, fok)) in enumerate(zip(outs, ref_outs)):
            assert (rv == fv).all() and (rok == fok).all(), f"step {t}"
        assert R.state_digest(state) == R.state_digest(ref_state)
        assert reng.metrics(state) == obs.merge_resilience(
            {k: int(np.sum(v)) for k, v in ref_eng.metrics(ref_state).items()},
            reng.tally)
        assert reng.tally["faults_injected"] == 1
        assert reng.tally["recoveries"] == 1
        assert reng.tally["replayed_ops"] > 0
        assert reng.journal.verify()
        assert reng.stats(state)["seq"] == len(plans)

    def test_seeded_plan_all_kinds_still_equal(self):
        """A REPRO_FAULTS-style seeded plan with every fault kind: results
        and final digest still equal the fault-free run (the CI chaos
        lane's contract, at unit scale)."""
        plans, _ = _stream(21, 8)
        _, ref_state, ref_outs = _fault_free_twin(plans)

        eng = _mk_engine()
        fplan = R.make_fault_plan(R.default_seed(3), len(plans), 1, LANES,
                                  n_faults=4)
        reng = R.ResilientEngine(eng, snapshot_every=2, fault_plan=fplan)
        state = jax.device_put(eng.init(CAP), eng.sharding)
        outs = []
        for ops, keys, vals in plans:
            state, res, ok, _ = reng.step(state, jnp.asarray(ops),
                                          jnp.asarray(keys),
                                          jnp.asarray(vals))
            outs.append((np.asarray(res).copy(), np.asarray(ok).copy()))
        for t, ((rv, rok), (fv, fok)) in enumerate(zip(outs, ref_outs)):
            assert (rv == fv).all() and (rok == fok).all(), f"step {t}"
        assert R.state_digest(state) == R.state_digest(ref_state)
        assert reng.tally["faults_injected"] == 4

    def test_poison_repaired_from_journaled_intent(self):
        plans, _ = _stream(22, 4)
        _, ref_state, ref_outs = _fault_free_twin(plans)

        eng = _mk_engine()
        plan = R.FaultPlan(0, [R.Fault("poison", 2, lane=3)])
        reng = R.ResilientEngine(eng, snapshot_every=4, fault_plan=plan)
        state = jax.device_put(eng.init(CAP), eng.sharding)
        state, outs = _run(reng, state, plans)
        for (rv, rok), (fv, fok) in zip(outs, ref_outs):
            assert (rv == fv).all() and (rok == fok).all()
        assert R.state_digest(state) == R.state_digest(ref_state)
        assert reng.tally["retries"] == 1
        assert reng.tally["recoveries"] == 0

    def test_stall_is_pure_latency(self):
        plans, _ = _stream(23, 4)
        _, ref_state, _ = _fault_free_twin(plans)
        eng = _mk_engine()
        plan = R.FaultPlan(0, [R.Fault("stall", 1, ticks=3),
                               R.Fault("stall", 2, ticks=2)])
        reng = R.ResilientEngine(eng, snapshot_every=4, fault_plan=plan)
        state = jax.device_put(eng.init(CAP), eng.sharding)
        state, _ = _run(reng, state, plans)
        assert R.state_digest(state) == R.state_digest(ref_state)
        assert reng.stall_ticks == 5
        assert reng.virtual_ticks == len(plans) + 5

    def test_metrics_view_is_schema_exact(self):
        plans, _ = _stream(24, 2)
        eng = _mk_engine()
        reng = R.ResilientEngine(eng, snapshot_every=2)
        state = jax.device_put(eng.init(CAP), eng.sharding)
        state, _ = _run(reng, state, plans)
        m = reng.metrics(state)
        assert set(m) == set(obs.METRICS_SCHEMA)
        assert all(m[k] == 0 for k in obs.RESILIENCE_SCHEMA)


class TestResilientEngineDegraded:
    def test_deferred_lanes_complete_with_fault_free_results(self):
        plans, _ = _stream(30, 6)
        _, ref_state, ref_outs = _fault_free_twin(plans)

        eng = _mk_engine()
        drop_at = 3
        plan = R.FaultPlan(0, [R.Fault("shard_drop", drop_at, shard=0)])
        # replay budget covers the whole tail at once: the rebuild and the
        # deferred catch-up complete inside the detecting step
        reng = R.ResilientEngine(eng, snapshot_every=2, fault_plan=plan,
                                 mode="degraded", replay_per_tick=64)
        state = jax.device_put(eng.init(CAP), eng.sharding)
        outs = []
        for ops, keys, vals in plans:
            state, res, ok, _ = reng.step(state, jnp.asarray(ops),
                                          jnp.asarray(keys),
                                          jnp.asarray(vals))
            outs.append((np.asarray(res).copy(), np.asarray(ok).copy()))

        # the detecting step deferred its (1-shard: ALL) lanes — callers saw
        # ok=False there; the true answers landed in completions and equal
        # the fault-free run's
        ops3 = plans[drop_at][0]
        fv, fok = ref_outs[drop_at]
        deferred = [(s, l) for (s, l) in reng.completions if s == drop_at]
        assert len(deferred) == int(np.sum(ops3 >= 0))
        for (s, lane), (cok, cval) in reng.completions.items():
            assert cok == bool(fok[lane]) and cval == int(fv[lane]), (s, lane)
        # non-faulted steps never diverged
        for t in range(len(plans)):
            if t == drop_at:
                continue
            rv, rok = outs[t]
            fvt, fokt = ref_outs[t]
            assert (rv == fvt).all() and (rok == fokt).all(), f"step {t}"
        assert reng.tally["recoveries"] == 1
        assert reng.quarantine is None

        # content equality (NOT digest: batch clocks shifted): probe every
        # key both runs touched and compare answers
        _, allkeys = _stream(30, 6)
        probe = np.asarray(allkeys[:LANES * 4], np.uint64)
        probe = np.pad(probe, (0, (-len(probe)) % LANES))
        ref_probe_eng, ref_probe_state, _ = _fault_free_twin(plans)
        for chunk in probe.reshape(-1, LANES):
            ops = np.where(chunk > 0, OP_FIND, OP_NONE).astype(np.int32)
            z = np.zeros(LANES, np.uint64)
            _, rv, rok, _ = reng.eng.step(state, jnp.asarray(ops),
                                          jnp.asarray(chunk), jnp.asarray(z))
            _, fv2, fok2, _ = ref_probe_eng.step(
                ref_probe_state, jnp.asarray(ops), jnp.asarray(chunk),
                jnp.asarray(z))
            assert (np.asarray(rok) == np.asarray(fok2)).all()
            okm = np.asarray(rok)
            assert (np.asarray(rv)[okm] == np.asarray(fv2)[okm]).all()
