"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward + one train step on CPU, asserting shapes and
no NaNs; plus prefill->decode vs full-forward consistency (exercises every
cache type: GQA KV, MLA latent, mamba conv+ssm, mLSTM matrix, sLSTM scalar).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs import ARCHS, get_reduced
from repro.models import model as M
from repro.optim.adamw import adamw_init
from repro.train.step import make_train_step

B, S = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    if cfg.n_codebooks:
        toks = jax.random.randint(ks[0], (B, cfg.n_codebooks, S), 0, cfg.vocab_size)
        labels = jax.random.randint(ks[1], (B, cfg.n_codebooks, S), 0, cfg.vocab_size)
        mask = jnp.ones((B, S), jnp.float32)
    else:
        toks = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
        labels = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
        total = S + cfg.frontend_tokens
        labels = jnp.pad(labels, ((0, 0), (cfg.frontend_tokens, 0)))
        mask = jnp.zeros((B, total), jnp.float32).at[:, cfg.frontend_tokens:].set(1.0)
    batch = {"tokens": toks, "labels": labels, "loss_mask": mask}
    if cfg.frontend_tokens:
        batch["prefix_embeds"] = jax.random.normal(
            ks[2], (B, cfg.frontend_tokens, cfg.d_model), jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_no_nan(name):
    cfg = get_reduced(name)
    key = jax.random.PRNGKey(0)
    p = M.init_params(key, cfg)
    b = _batch(cfg, key)
    logits, aux = M.forward(p, cfg, b["tokens"],
                            prefix_embeds=b.get("prefix_embeds"))
    s_total = S + (cfg.frontend_tokens or 0)
    if cfg.n_codebooks:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (B, s_total, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step(name):
    cfg = get_reduced(name)
    key = jax.random.PRNGKey(1)
    p = M.init_params(key, cfg)
    opt = {"adam": adamw_init(p)}
    step = make_train_step(cfg, microbatches=2)
    b = _batch(cfg, key)
    p2, opt2, metrics = jax.jit(step)(p, opt, b)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b_.astype(jnp.float32))))
                for a, b_ in zip(jax.tree.leaves(p), jax.tree.leaves(p2)))
    assert delta > 0
    assert int(opt2["adam"]["step"]) == 1


@pytest.mark.parametrize("name", [n for n in ARCHS if n != "llava-next-mistral-7b"])
def test_prefill_decode_matches_forward(name):
    """Decode continuation from a prefilled cache must match the full
    forward pass — validates every cache/state type."""
    cfg = get_reduced(name)
    key = jax.random.PRNGKey(2)
    p = M.init_params(key, cfg)
    if cfg.n_codebooks:
        toks = jax.random.randint(key, (B, cfg.n_codebooks, S), 0, cfg.vocab_size)
        pre, rest = toks[..., :8], toks[..., 8:]
        tok_at = lambda t: rest[..., t - 8: t - 7]
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        pre, rest = toks[:, :8], toks[:, 8:]
        tok_at = lambda t: rest[:, t - 8: t - 7]
    full, _ = M.forward(p, cfg, toks)
    _, caches, _ = M.prefill(p, cfg, pre, cache_len=S)
    lg = None
    for t in range(8, S):
        lg, caches = M.decode_step(p, cfg, tok_at(t),
                                   jnp.full((B,), t, jnp.int32), caches)
    want = full[:, -1]
    got = lg[:, 0]
    err = float(jnp.max(jnp.abs(want - got)))
    assert err < 0.1, f"{name}: decode/forward mismatch {err}"
    assert not bool(jnp.isnan(got).any())
