"""Serving substrate tests: paged KV cache accounting, scheduler ordering,
prefix cache ABA semantics, engine-vs-reference generation equality."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs import get_reduced
from repro.models import model as M
from repro.serving import kvcache as KV
from repro.serving import prefix_cache as PC
from repro.serving import scheduler as SCH
from repro.serving.engine import Engine, Request
from repro.core.blockpool import handle_valid, pool_alloc


@pytest.fixture(scope="module")
def cfg():
    return get_reduced("qwen3-1.7b")


class TestPagedKV:
    def test_admit_grow_release_accounting(self, cfg):
        kv = KV.paged_kv_init(cfg, num_pages=16, page_size=4, max_reqs=4,
                              max_pages_per_req=4)
        kv, ok = KV.admit_requests(kv, jnp.asarray([0, 1], jnp.int32),
                                   jnp.asarray([7, 4], jnp.int32),
                                   jnp.ones((2,), bool))
        assert ok.all()
        assert int(kv.pool.num_free()) == 16 - 2 - 1  # ceil(7/4)+ceil(4/4)
        # grow at page boundary: req1 at len 4 -> new page
        kv, ok = KV.grow_for_decode(kv, jnp.asarray([1], jnp.int32),
                                    jnp.ones((1,), bool))
        assert ok.all() and int(kv.lengths[1]) == 5
        assert int(kv.pool.num_free()) == 12
        kv = KV.release_requests(kv, jnp.asarray([0, 1], jnp.int32),
                                 jnp.ones((2,), bool))
        assert int(kv.pool.num_free()) == 16
        assert not kv.active.any()

    def test_admit_fails_clean_when_pool_exhausted(self, cfg):
        kv = KV.paged_kv_init(cfg, num_pages=2, page_size=4, max_reqs=2,
                              max_pages_per_req=4)
        kv, ok = KV.admit_requests(kv, jnp.asarray([0], jnp.int32),
                                   jnp.asarray([12], jnp.int32),
                                   jnp.ones((1,), bool))
        assert not ok.any()
        assert int(kv.pool.num_free()) == 2  # rollback returned pages


class TestScheduler:
    def test_priority_then_fifo_order(self):
        s = SCH.scheduler_init(64)
        pr = jnp.asarray([2, 0, 1, 0], jnp.uint32)
        ids = jnp.asarray([10, 11, 12, 13], jnp.int32)
        s, ok = SCH.submit(s, pr, ids, jnp.ones((4,), bool))
        assert ok.all()
        s, got, valid = SCH.pop_min(s, 4)
        order = [int(g) for g, v in zip(got, valid) if v]
        assert order == [11, 13, 12, 10]  # priority asc, ticket FIFO ties
        assert int(SCH.pending(s)) == 0

    def test_pop_partial(self):
        s = SCH.scheduler_init(64)
        s, _ = SCH.submit(s, jnp.asarray([5, 1], jnp.uint32),
                          jnp.asarray([1, 2], jnp.int32), jnp.ones((2,), bool))
        s, got, valid = SCH.pop_min(s, 1)
        assert int(got[0]) == 2 and bool(valid[0])
        assert int(SCH.pending(s)) == 1


class TestPrefixCache:
    def test_hit_miss_and_aba_invalidation(self, cfg):
        from repro.core.blockpool import blockpool_init, pool_free
        pool = blockpool_init(8)
        pool, ids, handles, got = pool_alloc(pool, jnp.ones(2, bool))
        pc = PC.prefix_cache_init(num_tables=4, capacity=64, seed_slots=2)
        keys = jnp.asarray([111, 222], jnp.uint64)
        pc = PC.insert(pc, keys, handles, jnp.ones((2,), bool))
        pc, pids, hit = PC.lookup(pc, pool, keys)
        assert hit.all() and (np.asarray(pids) == np.asarray(ids)).all()
        # recycle page 0 -> its cache entry must turn stale (ABA generation)
        pool = pool_free(pool, ids[:1], jnp.ones((1,), bool))
        pc, pids, hit = PC.lookup(pc, pool, keys)
        assert not bool(hit[0]) and bool(hit[1])

    def test_block_key_chains(self):
        t1 = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        t2 = jnp.asarray([[1, 2, 3, 5]], jnp.int32)
        k0 = jnp.zeros((1,), jnp.uint64)
        a = PC.block_key(t1, k0)
        b = PC.block_key(t2, k0)
        assert int(a[0]) != int(b[0])
        # chaining: same block after different prefixes differs
        c1 = PC.block_key(t1, a)
        c2 = PC.block_key(t1, b)
        assert int(c1[0]) != int(c2[0])


class TestPrefixSharing:
    def test_shared_prefix_pages_and_exact_outputs(self, cfg):
        """Concurrent requests with shared prefixes must (a) reuse resident
        pages (refcount sharing, counted hits), (b) produce token-identical
        outputs, (c) leak no pages (refcounted release)."""
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(3)
        base = rng.integers(1, cfg.vocab_size, 24)
        pA = base.copy()
        pB = np.concatenate([base[:16], rng.integers(1, cfg.vocab_size, 8)])
        eng = Engine(cfg, params, max_reqs=3, num_pages=32, page_size=8,
                     max_pages_per_req=8)
        for i, pr in enumerate([pA, pA, pB]):
            eng.submit(Request(req_id=i, prompt=pr, max_new=5))
        while not all(r.done for r in eng.requests.values()):
            eng.step()
        outs = {r.req_id: r.out for r in eng.requests.values()}

        def ref(prompt, n):
            toks = jnp.asarray(prompt, jnp.int32)[None]
            lg, caches, _ = M.prefill(params, cfg, toks, cache_len=64)
            out = [int(jnp.argmax(lg[0, -1]))]
            for t in range(len(prompt), len(prompt) + n - 1):
                lg, caches = M.decode_step(
                    params, cfg, jnp.asarray([[out[-1]]], jnp.int32),
                    jnp.asarray([t], jnp.int32), caches)
                out.append(int(jnp.argmax(lg[0, 0])))
            return out

        assert eng.prefix_hits >= 4          # replay: 2 pages; pB prefix: 2
        assert outs[0] == ref(pA, 5)
        assert outs[1] == ref(pA, 5)
        assert outs[2] == ref(pB, 5)
        assert int(eng.kv.pool.num_free()) == 32

    def test_recycled_pages_invalidate_cache_entries(self, cfg):
        """Sequential (non-overlapping) identical prompts miss: the pages
        were recycled, the generation bumped, and the stale prefix-cache
        entries turned invisible — no wrong reuse, ever (ABA guard)."""
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(4)
        p = rng.integers(1, cfg.vocab_size, 16)
        eng = Engine(cfg, params, max_reqs=1, num_pages=16, page_size=8,
                     max_pages_per_req=8)
        eng.submit(Request(req_id=0, prompt=p, max_new=3))
        while not all(r.done for r in eng.requests.values()):
            eng.step()
        eng.submit(Request(req_id=1, prompt=p, max_new=3))
        while not all(r.done for r in eng.requests.values()):
            eng.step()
        outs = {r.req_id: r.out for r in eng.requests.values()}
        assert eng.prefix_hits == 0          # recycled -> stale -> safe miss
        assert outs[0] == outs[1]            # and identical results


class TestEngineE2E:
    def test_engine_matches_contiguous_reference(self, cfg):
        # f32 compute: greedy-argmax sequences are only comparable between
        # the paged and contiguous paths when top-2 logit margins exceed the
        # reduction-order noise — under bf16 that noise (~6e-3) occasionally
        # beats a near-tie margin and flips a token
        cfg = cfg.replace(compute_dtype="float32")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(1)
        prompts = [rng.integers(1, cfg.vocab_size, n) for n in (8, 12, 8, 16)]
        eng = Engine(cfg, params, max_reqs=3, num_pages=48, page_size=8,
                     max_pages_per_req=8)
        for i, pr in enumerate(prompts):
            eng.submit(Request(req_id=i, prompt=pr, max_new=5, priority=0))
        outs = eng.run(max_steps=64)

        def ref(prompt, n):
            toks = jnp.asarray(prompt, jnp.int32)[None]
            lg, caches, _ = M.prefill(params, cfg, toks, cache_len=64)
            out = [int(jnp.argmax(lg[0, -1]))]
            for t in range(len(prompt), len(prompt) + n - 1):
                lg, caches = M.decode_step(
                    params, cfg, jnp.asarray([[out[-1]]], jnp.int32),
                    jnp.asarray([t], jnp.int32), caches)
                out.append(int(jnp.argmax(lg[0, 0])))
            return out

        for i, pr in enumerate(prompts):
            assert outs[i] == ref(pr, 5), f"request {i} diverged"
        # all pages recycled (no leaks across admissions/evictions)
        assert int(eng.kv.pool.num_free()) == 48
        # host-side counters report through the closed SERVING_SCHEMA
        from repro.store import obs
        m = eng.metrics()
        assert set(m) == set(obs.SERVING_SCHEMA)
        assert m["ring_depth"] == 0          # everything drained
        assert m["decode_steps"] == eng.steps > 0
        # 4 requests x 5 tokens, one from prefill each -> 16 decode tokens
        assert m["decode_tokens"] == sum(len(o) for o in outs.values()) - 4
        assert 0.0 < m["batch_fill"] <= 1.0
        assert m["prefix_lookups"] >= m["prefix_hits"] >= 0
        assert (m["prefix_hit_rate"] == 0.0 if not m["prefix_lookups"]
                else abs(m["prefix_hit_rate"]
                         - m["prefix_hits"] / m["prefix_lookups"]) < 1e-12)


class TestResilientServing:
    """Deadlines, backoff, load shedding, and scheduler fault recovery —
    all deterministic (two replays of the same trace + fault seed are
    bit-identical), per docs/resilience.md."""

    def test_deadline_expiry_lazy(self, cfg):
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(11)
        eng = Engine(cfg, params, max_reqs=1, num_pages=16, page_size=8,
                     max_pages_per_req=4)
        # A occupies the only slot for ~6 steps; B's 1-tick deadline expires
        # while it waits and is dropped at pop time (lazy check), C has no
        # deadline and completes after A retires
        eng.submit(Request(req_id=0, prompt=rng.integers(1, cfg.vocab_size, 8),
                           max_new=6))
        eng.submit(Request(req_id=1, prompt=rng.integers(1, cfg.vocab_size, 8),
                           max_new=3, deadline=1))
        eng.submit(Request(req_id=2, prompt=rng.integers(1, cfg.vocab_size, 8),
                           max_new=3))
        eng.run(max_steps=64)
        a, b, c = (eng.requests[i] for i in range(3))
        assert a.done and len(a.out) == 6 and not a.shed
        assert b.done and b.shed and b.out == []
        assert c.done and len(c.out) == 3 and not c.shed
        assert eng.res["deadline_expired"] == 1
        m = eng.resilience_metrics()
        from repro.store import obs
        assert set(m) == set(obs.METRICS_SCHEMA)
        assert m["deadline_expired"] == 1

    def test_backoff_retries_on_pool_exhaustion(self, cfg):
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(12)
        # two slots but pages for ~one request at a time: the second
        # admission fails allocation and backs off (parked, then retried)
        eng = Engine(cfg, params, max_reqs=2, num_pages=3, page_size=8,
                     max_pages_per_req=3, backoff_base=1, backoff_cap=4)
        for i in range(2):
            eng.submit(Request(req_id=i,
                               prompt=rng.integers(1, cfg.vocab_size, 12),
                               max_new=4))
        eng.run(max_steps=64)
        assert all(r.done and len(r.out) == 4 and not r.shed
                   for r in eng.requests.values())
        assert eng.res["retries"] >= 1
        assert max(r.attempts for r in eng.requests.values()) >= 1
        assert int(eng.kv.pool.num_free()) == 3

    def test_overload_shedding_deterministic(self, cfg):
        from repro.serving import traffic
        from repro.store import obs

        cfg = cfg.replace(compute_dtype="float32")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        trace = traffic.make_trace(seed=6, n_requests=6, page_size=8,
                                   overload_at=0, overload_n=6)
        assert sum(1 for t in trace if t.arrival == 0 and t.priority == 2) >= 6
        outs, mets, engines = [], [], []
        for _ in range(2):
            eng = Engine(cfg, params, max_reqs=2, num_pages=32, page_size=8,
                         max_pages_per_req=4, shed_threshold=3, shed_band=2)
            outs.append(traffic.replay(eng, trace, max_steps=200))
            mets.append(eng.resilience_metrics())
            engines.append(eng)
        assert outs[0] == outs[1], "shedding replay diverged"
        assert mets[0] == mets[1]
        assert set(mets[0]) == set(obs.METRICS_SCHEMA)
        assert mets[0]["shed"] > 0
        eng = engines[0]
        shed = [r for r in eng.requests.values() if r.shed]
        assert shed and all(r.out == [] and r.priority == 2 for r in shed)
        # priority-0 (urgent) work is never shed and always completes
        for t in trace:
            if t.priority == 0:
                r = eng.requests[t.req_id]
                assert not r.shed and len(r.out) == t.max_new
        # everything is terminal: completed or shed, nothing stuck
        assert all(r.done for r in eng.requests.values())
        assert int(eng.kv.pool.num_free()) == 32

    def test_traffic_deadline_knob_e2e(self, cfg):
        from repro.serving import traffic

        cfg = cfg.replace(compute_dtype="float32")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        trace = traffic.make_trace(seed=7, n_requests=8, page_size=8,
                                   deadline_frac=0.6, deadline_slack=(1, 2))
        assert any(t.deadline >= 0 for t in trace)
        outs, mets = [], []
        for _ in range(2):
            eng = Engine(cfg, params, max_reqs=1, num_pages=32, page_size=8,
                         max_pages_per_req=4)
            outs.append(traffic.replay(eng, trace, max_steps=200))
            mets.append((dict(eng.res),
                         {r.req_id: r.shed for r in eng.requests.values()}))
        assert outs[0] == outs[1] and mets[0] == mets[1]
        eng_res, shed_map = mets[0]
        assert eng_res["deadline_expired"] > 0     # 1 slot: some must expire
        # expired requests produced nothing; everyone else finished in full
        by_id = {t.req_id: t for t in trace}
        for rid, shed in shed_map.items():
            assert (len(outs[0][rid]) == 0 if shed
                    else len(outs[0][rid]) == by_id[rid].max_new)

    def test_traffic_knobs_off_draw_nothing(self):
        from repro.serving import traffic
        base = traffic.make_trace(seed=5, n_requests=8, page_size=8)
        again = traffic.make_trace(seed=5, n_requests=8, page_size=8,
                                   deadline_frac=0.0, overload_n=0)
        assert len(base) == len(again)
        for a, b in zip(base, again):
            assert a.req_id == b.req_id and a.arrival == b.arrival
            assert (a.prompt == b.prompt).all()
            assert (a.max_new, a.priority, a.deadline) == \
                (b.max_new, b.priority, b.deadline)
            assert a.deadline == -1

    def test_scheduler_fault_recovery_bit_identical(self, cfg):
        from repro.serving import traffic
        from repro.store import resilience as R

        cfg = cfg.replace(compute_dtype="float32")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        trace = traffic.make_trace(seed=8, n_requests=6, page_size=8)

        def run(fault_plan):
            eng = Engine(cfg, params, max_reqs=2, num_pages=48, page_size=8,
                         max_pages_per_req=8, fault_plan=fault_plan,
                         resilient=True)
            out = traffic.replay(eng, trace, max_steps=200)
            return out, eng

        ref, _ = run(None)
        plan = R.FaultPlan(0, [R.Fault("shard_drop", 2, shard=0),
                               R.Fault("shard_drop", 5, shard=0)])
        got, eng = run(plan)
        assert got == ref, "fault-free and recovered replays diverged"
        m = eng.resilience_metrics()
        assert m["faults_injected"] == 2
        assert m["recoveries"] >= 1
        assert m["replayed_ops"] > 0
        assert eng.sched.res.journal.verify()
        # the journaled scheduler's recover() is also callable standalone
        assert SCH.health(eng.sched)

    def test_scheduler_cancel_class_range_delete(self):
        s = SCH.scheduler_init(64, resilient=True)
        pr = jnp.asarray([2, 0, 2, 1, 2], jnp.uint32)
        ids = jnp.asarray([10, 11, 12, 13, 14], jnp.int32)
        s, ok = SCH.submit(s, pr, ids, jnp.ones((5,), bool))
        assert ok.all()
        s, cancelled = SCH.cancel_class(s, 2)
        assert cancelled == 3
        assert int(SCH.pending(s)) == 2
        s, got, valid = SCH.pop_min(s, 4)
        order = [int(g) for g, v in zip(got, valid) if v]
        assert order == [11, 13]          # band 2 gone, order preserved
        # the cancel plan itself is journaled: a post-cancel fault replays
        # to the SAME post-cancel pending set
        store = SCH.recover(s)
        import repro.store.resilience as R
        assert R.state_digest(store) == R.state_digest(s.store)


class TestTrafficReplay:
    def test_seeded_heavy_traffic_replay_deterministic(self, cfg):
        """E2E smoke over the traffic generator: two engines replaying the
        same seeded trace (bursts, Zipf prefixes, priority inversion)
        produce identical outputs, drain completely, recycle every page,
        and admit urgent arrivals before same-tick low-priority bulk."""
        from repro.serving import traffic

        cfg = cfg.replace(compute_dtype="float32")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        trace = traffic.make_trace(seed=5, n_requests=8, page_size=8)
        assert {t.priority for t in trace} >= {0, 2}   # inversion present
        outs, engines = [], []
        for _ in range(2):
            eng = Engine(cfg, params, max_reqs=3, num_pages=64, page_size=8,
                         max_pages_per_req=8)
            outs.append(traffic.replay(eng, trace, max_steps=200))
            engines.append(eng)
        assert outs[0] == outs[1], "seeded replays diverged"
        assert all(r.done for r in engines[0].requests.values())
        for t in trace:
            assert len(outs[0][t.req_id]) == t.max_new
        assert int(engines[0].kv.pool.num_free()) == 64   # no page leaks

        # priority inversion resolved: an urgent (priority 0) request
        # arriving in the same burst as priority-2 bulk admits first
        reqs = engines[0].requests
        checked = 0
        for t in trace:
            if t.priority != 0:
                continue
            for b in trace:
                if b.arrival == t.arrival and b.priority == 2:
                    assert (reqs[t.req_id].admit_step
                            <= reqs[b.req_id].admit_step), \
                        (t.req_id, b.req_id)
                    checked += 1
        assert checked > 0
