"""Serving substrate tests: paged KV cache accounting, scheduler ordering,
prefix cache ABA semantics, engine-vs-reference generation equality."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs import get_reduced
from repro.models import model as M
from repro.serving import kvcache as KV
from repro.serving import prefix_cache as PC
from repro.serving import scheduler as SCH
from repro.serving.engine import Engine, Request
from repro.core.blockpool import handle_valid, pool_alloc


@pytest.fixture(scope="module")
def cfg():
    return get_reduced("qwen3-1.7b")


class TestPagedKV:
    def test_admit_grow_release_accounting(self, cfg):
        kv = KV.paged_kv_init(cfg, num_pages=16, page_size=4, max_reqs=4,
                              max_pages_per_req=4)
        kv, ok = KV.admit_requests(kv, jnp.asarray([0, 1], jnp.int32),
                                   jnp.asarray([7, 4], jnp.int32),
                                   jnp.ones((2,), bool))
        assert ok.all()
        assert int(kv.pool.num_free()) == 16 - 2 - 1  # ceil(7/4)+ceil(4/4)
        # grow at page boundary: req1 at len 4 -> new page
        kv, ok = KV.grow_for_decode(kv, jnp.asarray([1], jnp.int32),
                                    jnp.ones((1,), bool))
        assert ok.all() and int(kv.lengths[1]) == 5
        assert int(kv.pool.num_free()) == 12
        kv = KV.release_requests(kv, jnp.asarray([0, 1], jnp.int32),
                                 jnp.ones((2,), bool))
        assert int(kv.pool.num_free()) == 16
        assert not kv.active.any()

    def test_admit_fails_clean_when_pool_exhausted(self, cfg):
        kv = KV.paged_kv_init(cfg, num_pages=2, page_size=4, max_reqs=2,
                              max_pages_per_req=4)
        kv, ok = KV.admit_requests(kv, jnp.asarray([0], jnp.int32),
                                   jnp.asarray([12], jnp.int32),
                                   jnp.ones((1,), bool))
        assert not ok.any()
        assert int(kv.pool.num_free()) == 2  # rollback returned pages


class TestScheduler:
    def test_priority_then_fifo_order(self):
        s = SCH.scheduler_init(64)
        pr = jnp.asarray([2, 0, 1, 0], jnp.uint32)
        ids = jnp.asarray([10, 11, 12, 13], jnp.int32)
        s, ok = SCH.submit(s, pr, ids, jnp.ones((4,), bool))
        assert ok.all()
        s, got, valid = SCH.pop_min(s, 4)
        order = [int(g) for g, v in zip(got, valid) if v]
        assert order == [11, 13, 12, 10]  # priority asc, ticket FIFO ties
        assert int(SCH.pending(s)) == 0

    def test_pop_partial(self):
        s = SCH.scheduler_init(64)
        s, _ = SCH.submit(s, jnp.asarray([5, 1], jnp.uint32),
                          jnp.asarray([1, 2], jnp.int32), jnp.ones((2,), bool))
        s, got, valid = SCH.pop_min(s, 1)
        assert int(got[0]) == 2 and bool(valid[0])
        assert int(SCH.pending(s)) == 1


class TestPrefixCache:
    def test_hit_miss_and_aba_invalidation(self, cfg):
        from repro.core.blockpool import blockpool_init, pool_free
        pool = blockpool_init(8)
        pool, ids, handles, got = pool_alloc(pool, jnp.ones(2, bool))
        pc = PC.prefix_cache_init(num_tables=4, capacity=64, seed_slots=2)
        keys = jnp.asarray([111, 222], jnp.uint64)
        pc = PC.insert(pc, keys, handles, jnp.ones((2,), bool))
        pc, pids, hit = PC.lookup(pc, pool, keys)
        assert hit.all() and (np.asarray(pids) == np.asarray(ids)).all()
        # recycle page 0 -> its cache entry must turn stale (ABA generation)
        pool = pool_free(pool, ids[:1], jnp.ones((1,), bool))
        pc, pids, hit = PC.lookup(pc, pool, keys)
        assert not bool(hit[0]) and bool(hit[1])

    def test_block_key_chains(self):
        t1 = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        t2 = jnp.asarray([[1, 2, 3, 5]], jnp.int32)
        k0 = jnp.zeros((1,), jnp.uint64)
        a = PC.block_key(t1, k0)
        b = PC.block_key(t2, k0)
        assert int(a[0]) != int(b[0])
        # chaining: same block after different prefixes differs
        c1 = PC.block_key(t1, a)
        c2 = PC.block_key(t1, b)
        assert int(c1[0]) != int(c2[0])


class TestPrefixSharing:
    def test_shared_prefix_pages_and_exact_outputs(self, cfg):
        """Concurrent requests with shared prefixes must (a) reuse resident
        pages (refcount sharing, counted hits), (b) produce token-identical
        outputs, (c) leak no pages (refcounted release)."""
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(3)
        base = rng.integers(1, cfg.vocab_size, 24)
        pA = base.copy()
        pB = np.concatenate([base[:16], rng.integers(1, cfg.vocab_size, 8)])
        eng = Engine(cfg, params, max_reqs=3, num_pages=32, page_size=8,
                     max_pages_per_req=8)
        for i, pr in enumerate([pA, pA, pB]):
            eng.submit(Request(req_id=i, prompt=pr, max_new=5))
        while not all(r.done for r in eng.requests.values()):
            eng.step()
        outs = {r.req_id: r.out for r in eng.requests.values()}

        def ref(prompt, n):
            toks = jnp.asarray(prompt, jnp.int32)[None]
            lg, caches, _ = M.prefill(params, cfg, toks, cache_len=64)
            out = [int(jnp.argmax(lg[0, -1]))]
            for t in range(len(prompt), len(prompt) + n - 1):
                lg, caches = M.decode_step(
                    params, cfg, jnp.asarray([[out[-1]]], jnp.int32),
                    jnp.asarray([t], jnp.int32), caches)
                out.append(int(jnp.argmax(lg[0, 0])))
            return out

        assert eng.prefix_hits >= 4          # replay: 2 pages; pB prefix: 2
        assert outs[0] == ref(pA, 5)
        assert outs[1] == ref(pA, 5)
        assert outs[2] == ref(pB, 5)
        assert int(eng.kv.pool.num_free()) == 32

    def test_recycled_pages_invalidate_cache_entries(self, cfg):
        """Sequential (non-overlapping) identical prompts miss: the pages
        were recycled, the generation bumped, and the stale prefix-cache
        entries turned invisible — no wrong reuse, ever (ABA guard)."""
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(4)
        p = rng.integers(1, cfg.vocab_size, 16)
        eng = Engine(cfg, params, max_reqs=1, num_pages=16, page_size=8,
                     max_pages_per_req=8)
        eng.submit(Request(req_id=0, prompt=p, max_new=3))
        while not all(r.done for r in eng.requests.values()):
            eng.step()
        eng.submit(Request(req_id=1, prompt=p, max_new=3))
        while not all(r.done for r in eng.requests.values()):
            eng.step()
        outs = {r.req_id: r.out for r in eng.requests.values()}
        assert eng.prefix_hits == 0          # recycled -> stale -> safe miss
        assert outs[0] == outs[1]            # and identical results


class TestEngineE2E:
    def test_engine_matches_contiguous_reference(self, cfg):
        # f32 compute: greedy-argmax sequences are only comparable between
        # the paged and contiguous paths when top-2 logit margins exceed the
        # reduction-order noise — under bf16 that noise (~6e-3) occasionally
        # beats a near-tie margin and flips a token
        cfg = cfg.replace(compute_dtype="float32")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(1)
        prompts = [rng.integers(1, cfg.vocab_size, n) for n in (8, 12, 8, 16)]
        eng = Engine(cfg, params, max_reqs=3, num_pages=48, page_size=8,
                     max_pages_per_req=8)
        for i, pr in enumerate(prompts):
            eng.submit(Request(req_id=i, prompt=pr, max_new=5, priority=0))
        outs = eng.run(max_steps=64)

        def ref(prompt, n):
            toks = jnp.asarray(prompt, jnp.int32)[None]
            lg, caches, _ = M.prefill(params, cfg, toks, cache_len=64)
            out = [int(jnp.argmax(lg[0, -1]))]
            for t in range(len(prompt), len(prompt) + n - 1):
                lg, caches = M.decode_step(
                    params, cfg, jnp.asarray([[out[-1]]], jnp.int32),
                    jnp.asarray([t], jnp.int32), caches)
                out.append(int(jnp.argmax(lg[0, 0])))
            return out

        for i, pr in enumerate(prompts):
            assert outs[i] == ref(pr, 5), f"request {i} diverged"
        # all pages recycled (no leaks across admissions/evictions)
        assert int(eng.kv.pool.num_free()) == 48
        # host-side counters report through the closed SERVING_SCHEMA
        from repro.store import obs
        m = eng.metrics()
        assert set(m) == set(obs.SERVING_SCHEMA)
        assert m["ring_depth"] == 0          # everything drained
        assert m["decode_steps"] == eng.steps > 0
        # 4 requests x 5 tokens, one from prefill each -> 16 decode tokens
        assert m["decode_tokens"] == sum(len(o) for o in outs.values()) - 4
        assert 0.0 < m["batch_fill"] <= 1.0
        assert m["prefix_lookups"] >= m["prefix_hits"] >= 0
        assert (m["prefix_hit_rate"] == 0.0 if not m["prefix_lookups"]
                else abs(m["prefix_hit_rate"]
                         - m["prefix_hits"] / m["prefix_lookups"]) < 1e-12)


class TestTrafficReplay:
    def test_seeded_heavy_traffic_replay_deterministic(self, cfg):
        """E2E smoke over the traffic generator: two engines replaying the
        same seeded trace (bursts, Zipf prefixes, priority inversion)
        produce identical outputs, drain completely, recycle every page,
        and admit urgent arrivals before same-tick low-priority bulk."""
        from repro.serving import traffic

        cfg = cfg.replace(compute_dtype="float32")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        trace = traffic.make_trace(seed=5, n_requests=8, page_size=8)
        assert {t.priority for t in trace} >= {0, 2}   # inversion present
        outs, engines = [], []
        for _ in range(2):
            eng = Engine(cfg, params, max_reqs=3, num_pages=64, page_size=8,
                         max_pages_per_req=8)
            outs.append(traffic.replay(eng, trace, max_steps=200))
            engines.append(eng)
        assert outs[0] == outs[1], "seeded replays diverged"
        assert all(r.done for r in engines[0].requests.values())
        for t in trace:
            assert len(outs[0][t.req_id]) == t.max_new
        assert int(engines[0].kv.pool.num_free()) == 64   # no page leaks

        # priority inversion resolved: an urgent (priority 0) request
        # arriving in the same burst as priority-2 bulk admits first
        reqs = engines[0].requests
        checked = 0
        for t in trace:
            if t.priority != 0:
                continue
            for b in trace:
                if b.arrival == t.arrival and b.priority == 2:
                    assert (reqs[t.req_id].admit_step
                            <= reqs[b.req_id].admit_step), \
                        (t.req_id, b.req_id)
                    checked += 1
        assert checked > 0
