"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU; TPU is the target)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.hash_probe.ops import hash_probe
from repro.kernels.hash_probe.ref import hash_probe_ref
from repro.kernels.skiplist_search.ops import skiplist_search
from repro.kernels.skiplist_search.ref import skiplist_search_ref
from repro.kernels.skiplist_search.ops import split_u64, stack_levels
from repro.core.det_skiplist import (delete_batch, find_batch, insert_batch,
                                     skiplist_init)
from repro.core.layout import bucket_layout, hash_slot


class TestFlashAttention:
    @pytest.mark.parametrize("shape", [
        (1, 128, 4, 64), (2, 256, 8, 64), (1, 256, 4, 128), (2, 128, 2, 32),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_mha_sweep(self, shape, dtype):
        b, s, h, d = shape
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
        k = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
        v = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
        out = flash_attention(q, k, v, block_q=64, block_k=64)
        ref = flash_attention_ref(q, k, v)
        tol = 5e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol, rtol=tol)

    @pytest.mark.parametrize("hkv", [1, 2, 4])
    def test_gqa_groups(self, hkv):
        b, s, h, d = 2, 128, 8, 64
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
        out = flash_attention(q, k, v, block_q=64, block_k=64)
        ref = flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=5e-6, rtol=5e-6)

    def test_noncausal(self):
        b, s, h, d = 1, 128, 2, 64
        rng = np.random.default_rng(2)
        q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
        ref = flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=5e-6, rtol=5e-6)


class TestPagedAttention:
    @pytest.mark.parametrize("cfg", [
        dict(B=2, H=4, HKV=2, D=64, PAGE=16, NP=16, P=4),
        dict(B=4, H=8, HKV=8, D=64, PAGE=32, NP=32, P=4),
        dict(B=1, H=8, HKV=1, D=128, PAGE=16, NP=8, P=3),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, cfg, dtype):
        B, H, HKV, D = cfg["B"], cfg["H"], cfg["HKV"], cfg["D"]
        PAGE, NP, P = cfg["PAGE"], cfg["NP"], cfg["P"]
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.standard_normal((B, H, D)), dtype)
        kp = jnp.asarray(rng.standard_normal((NP, PAGE, HKV, D)), dtype)
        vp = jnp.asarray(rng.standard_normal((NP, PAGE, HKV, D)), dtype)
        lengths = jnp.asarray(rng.integers(1, PAGE * P, B), jnp.int32)
        tables = np.full((B, P), -1, np.int32)
        ids = rng.permutation(NP)
        c = 0
        for b in range(B):
            need = int(np.ceil(int(lengths[b]) / PAGE))
            tables[b, :need] = ids[c:c + need]
            c += need
        out = paged_attention(q, kp, vp, jnp.asarray(tables), lengths)
        ref = paged_attention_ref(q, kp, vp, jnp.asarray(tables), lengths)
        tol = 5e-6 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol, rtol=tol)


class TestSelectiveScan:
    @pytest.mark.parametrize("shape", [(1, 32, 64, 8), (2, 64, 128, 16),
                                       (2, 128, 64, 8)])
    def test_vs_ref(self, shape):
        from repro.kernels.selective_scan.ops import selective_scan
        from repro.kernels.selective_scan.ref import selective_scan_ref
        b, s, d, n = shape
        rng = np.random.default_rng(s)
        x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32) * 0.5
        dt = jnp.asarray(np.abs(rng.standard_normal((b, s))) * 0.1 + 0.01,
                         jnp.float32)
        bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32) * 0.5
        cm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32) * 0.5
        a = -jnp.asarray(np.abs(rng.standard_normal((d, n))) + 0.1, jnp.float32)
        y = selective_scan(x, dt, bm, cm, a, d_block=min(64, d), chunk=min(32, s))
        yr, _ = selective_scan_ref(x, dt, bm, cm, a)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   atol=2e-5, rtol=2e-5)

    def test_matches_production_mamba_math(self):
        """The kernel recurrence == the chunked-scan math in models/ssm.py."""
        from repro.kernels.selective_scan.ops import selective_scan
        from repro.kernels.selective_scan.ref import selective_scan_ref
        import jax
        rng = np.random.default_rng(7)
        b, s, d, n = 1, 48, 32, 4
        x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32) * 0.3
        dt = jnp.asarray(np.abs(rng.standard_normal((b, s))) * 0.1, jnp.float32)
        bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
        cm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
        a = -jnp.asarray(np.abs(rng.standard_normal((d, n))) + 0.2, jnp.float32)

        # associative-scan form (what mamba_forward lowers)
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br
        da = jnp.exp(dt[..., None, None] * a[None, None])
        dbx = (dt[..., None] * x)[..., None] * bm[:, :, None, :]
        _, hs = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        y_assoc = jnp.einsum("bsdn,bsn->bsd", hs, cm)

        y_kernel = selective_scan(x, dt, bm, cm, a, d_block=32, chunk=16)
        np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_assoc),
                                   atol=2e-4, rtol=2e-4)


class TestSkiplistSearchKernel:
    @pytest.mark.parametrize("cap,n,q", [(256, 100, 128), (1024, 700, 512),
                                         (2048, 1500, 256)])
    def test_vs_find_batch(self, cap, n, q):
        rng = np.random.default_rng(cap)
        s = skiplist_init(cap)
        ks = jnp.asarray(rng.integers(1, 2**62, n, dtype=np.uint64))
        s, _, _ = insert_batch(s, ks, ks + jnp.uint64(7))
        s, _ = delete_batch(s, ks[: n // 5])
        queries = jnp.concatenate([
            ks[: q // 2],
            jnp.asarray(rng.integers(1, 2**62, q - q // 2, dtype=np.uint64))])
        f_ref, v_ref, _ = find_batch(s, queries)
        f_k, v_k, _ = skiplist_search(s, queries, tile=min(128, q))
        assert (np.asarray(f_ref) == np.asarray(f_k)).all()
        assert (np.asarray(v_ref) == np.asarray(v_k)).all()

    def test_kernel_matches_standalone_ref(self):
        rng = np.random.default_rng(9)
        s = skiplist_init(512)
        ks = jnp.asarray(rng.integers(1, 2**62, 300, dtype=np.uint64))
        s, _, _ = insert_batch(s, ks, ks)
        queries = ks[:128]
        qh, ql = split_u64(queries)
        lh, ll, lc = stack_levels(s)
        th, tl = split_u64(s.term_keys)
        f, i = skiplist_search_ref(qh, ql, lh, ll, lc, s.level_count, th, tl,
                                   s.term_mark.astype(jnp.int8))
        f2, _, i2 = skiplist_search(s, queries, tile=128)
        assert (np.asarray(f) == np.asarray(f2)).all()
        assert (np.asarray(i) == np.asarray(i2)).all()


class TestHashProbeKernel:
    @pytest.mark.parametrize("slots,bucket,n,q", [
        (64, 8, 200, 128), (256, 16, 1500, 512), (512, 4, 900, 256),
    ])
    def test_vs_fixed_find(self, slots, bucket, n, q):
        from repro.core.hashtable import (fixed_delete, fixed_find,
                                          fixed_init, fixed_insert)
        rng = np.random.default_rng(slots + q)
        h = fixed_init(slots, bucket)
        ks = jnp.asarray(rng.integers(1, 2**62, n, dtype=np.uint64))
        h, _, _ = fixed_insert(h, ks, ks + jnp.uint64(3))
        h, _ = fixed_delete(h, ks[: n // 6])
        queries = jnp.concatenate([
            ks[: q // 2],
            jnp.asarray(rng.integers(1, 2**62, q - q // 2, dtype=np.uint64))])
        f_ref, v_ref = fixed_find(h, queries)
        f_k, v_k = hash_probe(h, queries, tile=min(128, q))
        assert (np.asarray(f_ref) == np.asarray(f_k)).all()
        assert (np.asarray(v_ref) == np.asarray(v_k)).all()

    def test_kernel_matches_standalone_ref(self):
        from repro.core.hashtable import fixed_init, fixed_insert
        rng = np.random.default_rng(21)
        h = fixed_init(128, 8)
        ks = jnp.asarray(rng.integers(1, 2**62, 400, dtype=np.uint64))
        h, _, _ = fixed_insert(h, ks, ks)
        queries = ks[:128]
        qh, ql = split_u64(queries)
        slots = hash_slot(queries, h.num_slots)
        lay = bucket_layout(h.keys)
        f, c = hash_probe_ref(qh, ql, slots, lay.key_hi, lay.key_lo)
        f2, v2 = hash_probe(h, queries, tile=128)
        assert (np.asarray(f) == np.asarray(f2)).all()
        vals = np.where(np.asarray(f), np.asarray(h.vals)[np.asarray(slots),
                                                          np.asarray(c)], 0)
        assert (vals == np.asarray(v2)).all()
