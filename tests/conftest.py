"""Shared pytest hygiene for the tier-1 suite.

The suite compiles hundreds of XLA CPU executables in one process (every
backend x exec-mode x plan shape). The CPU client's JIT code memory is
only reclaimed when the cached executables are dropped; past a few
hundred live executables the next large compile can crash the process.
Clearing jax's compilation caches at module boundaries bounds that
growth — later modules simply recompile what they actually use.
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_jit_cache_growth():
    yield
    jax.clear_caches()
