"""Pipeline-parallel primitive: 4-stage 1F1B-style fill-drain schedule vs
sequential reference (subprocess: fixed device count)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_pipeline_matches_sequential():
    prog = os.path.join(ROOT, "tests", "multidev", "pipeline_prog.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, prog], env=env, capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "PIPELINE-OK" in out.stdout
