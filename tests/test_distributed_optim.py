"""Distributed-optimization tricks: unit tests + 8-device compression run."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.optim.adamw import adamw_init, adamw_update, global_norm
from repro.optim.compress import _quant, compress_state_init
from repro.optim.schedule import cosine_with_warmup

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestAdamW:
    def test_descends_quadratic(self):
        p = {"w": jnp.asarray([3.0, -2.0])}
        opt = adamw_init(p)
        for _ in range(200):
            g = {"w": 2 * p["w"]}
            p, opt, gn = adamw_update(g, opt, p, lr=0.05, weight_decay=0.0)
        assert float(jnp.max(jnp.abs(p["w"]))) < 0.1

    def test_clip_bounds_update(self):
        p = {"w": jnp.zeros((4,))}
        opt = adamw_init(p)
        g = {"w": jnp.full((4,), 1e6)}
        p2, opt, gn = adamw_update(g, opt, p, lr=1.0, clip_norm=1.0,
                                   weight_decay=0.0)
        assert float(gn) > 1e5                     # raw norm reported
        assert float(jnp.max(jnp.abs(p2["w"]))) < 2.0  # update clipped


class TestSchedule:
    def test_warmup_then_decay(self):
        lr0 = cosine_with_warmup(jnp.int32(1), peak_lr=1.0, warmup_steps=10,
                                 total_steps=100)
        lr_peak = cosine_with_warmup(jnp.int32(10), peak_lr=1.0,
                                     warmup_steps=10, total_steps=100)
        lr_end = cosine_with_warmup(jnp.int32(100), peak_lr=1.0,
                                    warmup_steps=10, total_steps=100)
        assert float(lr0) < float(lr_peak)
        assert abs(float(lr_peak) - 1.0) < 1e-5
        assert float(lr_end) < 0.2


class TestQuant:
    def test_roundtrip_bounded_error(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
        q, s = _quant(g)
        err = jnp.abs(q.astype(jnp.float32) * s - g)
        assert float(jnp.max(err)) <= float(s) * 0.5 + 1e-7


@pytest.mark.slow
def test_pod_compression_multidevice():
    prog = os.path.join(ROOT, "tests", "multidev", "compress_prog.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, prog], env=env, capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "COMPRESS-OK" in out.stdout
