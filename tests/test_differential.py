"""Differential oracle: random op streams vs a sorted-dict model.

Property-based cross-validation of EVERY registered backend against a
plain python sorted-dict oracle implementing the store contract's
linearization (INSERTS -> DELETES -> RANGE_DELETES -> POPS -> FINDS,
first lane wins on in-batch duplicates, masked lanes are no-ops). The
parity suites compare backends to each OTHER; a shared bug survives that.
The oracle is implemented from the CONTRACT (store/api.py docstring), so
agreement here is evidence the contract itself holds, not just that the
implementations agree.

Streams are hypothesis-driven when hypothesis is installed and fall back
to `tests/_hypothesis_fallback.py`'s seeded deterministic examples when
it is not (same test code either way). Keys come from a small adversarial
pool — duplicates land in every batch, and the pool crosses the u32
hi/lo split boundaries the (hi, lo)-plane kernels compare on.

Asserted per stream: per-lane results (`ok`/`vals`), ordered `scan()`
rows + exact counts, `stats()` size accounting — and, for every backend
carrying a deterministic-skiplist plane (flat or warm tier), the blocked
B-skiplist layout invariants (tests/invariants.py), so each randomized
stream also audits the derived block layout it probed.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401  (enables x64)
from repro.core.bits import KEY_INF

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # tier-1 runs dependency-free
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from invariants import assert_bskiplist_ok
from repro.store import (OP_DELETE, OP_FIND, OP_INSERT, OP_NONE, OP_POPK,
                         OP_POPMIN, OP_RANGE_DELETE, available_backends,
                         get_backend, make_plan)

ALL_BACKENDS = available_backends()
ORDERED = [n for n in ALL_BACKENDS if get_backend(n).ordered]
RANGE_DEL = ["det_skiplist", "pq"]       # backends wiring range_delete_fn
POPS = ["pq"]                            # POPMIN/POPK bulk extraction

WIDTH = 8                                # lanes per plan (static jit shape)

# adversarial key pool: in-batch duplicates are near-certain at this size,
# and the values straddle the u32 hi/lo split ((hi, lo) plane compares),
# sit at power-of-two hash boundaries, and reach near the u62 key ceiling
POOL = np.array([1, 2, 3, (1 << 32) - 1, 1 << 32, (1 << 32) + 1,
                 (1 << 40) | 5, (1 << 62) - 1, (1 << 62) - 2, 7 << 58],
                dtype=np.uint64)

BASIC_OPS = (OP_INSERT, OP_FIND, OP_DELETE)
LANE = st.tuples(st.sampled_from(range(-1, 7)),       # op code (or idle)
                 st.integers(0, len(POOL) - 1),       # pool key index
                 st.booleans())                       # lane mask
STREAM = st.lists(LANE, min_size=4, max_size=4 * WIDTH)


class DictOracle:
    """The store contract over a python dict, lane by lane. Sequential
    per-phase processing IS the contract's first-lane-wins rule."""

    def __init__(self):
        self.d = {}
        self.pops = 0
        self.pop_empty = 0

    def apply(self, ops, keys, vals, mask):
        K = len(ops)
        ok = np.zeros(K, bool)
        out = np.zeros(K, np.uint64)
        live = [i for i in range(K) if mask[i] and ops[i] >= 0]
        for i in live:                               # INSERTS
            if ops[i] == OP_INSERT:
                k = int(keys[i])
                existed = k in self.d
                if not existed:
                    self.d[k] = int(vals[i])
                ok[i] = True
                out[i] = np.uint64(existed)
        for i in live:                               # DELETES
            if ops[i] == OP_DELETE:
                ok[i] = self.d.pop(int(keys[i]), None) is not None
        for i in live:                               # RANGE_DELETES
            if ops[i] == OP_RANGE_DELETE:
                lo, hi = int(keys[i]), int(vals[i])
                hit = [k for k in self.d if lo <= k < hi]
                for k in hit:
                    del self.d[k]
                ok[i] = bool(hit)
                out[i] = np.uint64(len(hit))
        for i in live:                               # POPS (one rank pool)
            if ops[i] in (OP_POPMIN, OP_POPK):
                if self.d:
                    k = min(self.d)
                    v = self.d.pop(k)
                    ok[i] = True
                    out[i] = np.uint64(v if ops[i] == OP_POPMIN else k)
                    self.pops += 1
                else:
                    self.pop_empty += 1
        for i in live:                               # FINDS (post-update)
            if ops[i] == OP_FIND:
                k = int(keys[i])
                if k in self.d:
                    ok[i] = True
                    out[i] = np.uint64(self.d[k])
        return ok, out


def _plans(stream, allowed, round_salt):
    """Pad the lane stream to whole WIDTH-lane plans; ops outside `allowed`
    become idle lanes so every backend in the comparison supports the
    whole stream. Values are key-and-round-derived (stable, nonzero)."""
    lanes = list(stream) + [(-1, 0, False)] * ((-len(stream)) % WIDTH)
    plans = []
    for r in range(0, len(lanes), WIDTH):
        chunk = lanes[r:r + WIDTH]
        ops = np.array([op if op in allowed else OP_NONE
                        for op, _, _ in chunk], np.int32)
        keys = POOL[[ki for _, ki, _ in chunk]]
        vals = keys * np.uint64(2) + np.uint64(round_salt + r + 1)
        # RANGE_DELETE lanes: keys = lo, vals = hi (a pool-spanning window)
        rd = ops == OP_RANGE_DELETE
        vals = np.where(rd, keys + np.uint64(1 << 33), vals)
        mask = np.array([m for _, _, m in chunk], bool)
        plans.append((ops, keys, vals, mask))
    return plans


def _dsl_states(name, state):
    """Every deterministic-skiplist plane a backend state carries (flat
    state, warm tier, or pq's underlying skiplist) — the structures the
    blocked-layout invariants audit."""
    if name in ("det_skiplist", "rand_skiplist"):
        return [state]
    if hasattr(state, "cold"):
        return [state.cold]
    if hasattr(state, "heap"):               # pq
        return [state.heap]
    return []


# one jitted step per backend for the whole module: plans share a static
# WIDTH-lane shape, so every hypothesis example reuses the same compile
_JIT_STEP = {}


def _step(name):
    if name not in _JIT_STEP:
        _JIT_STEP[name] = jax.jit(get_backend(name).apply)
    return _JIT_STEP[name]


def _run_differential(names, allowed, stream, salt=0):
    oracle = DictOracle()
    bes = {n: get_backend(n) for n in names}
    sts = {n: be.init(256) for n, be in bes.items()}
    for rnd, (ops, keys, vals, mask) in enumerate(_plans(stream, allowed,
                                                         salt)):
        want_ok, want_vals = oracle.apply(ops, keys, vals, mask)
        plan = make_plan(ops, keys, vals, mask)
        for n in names:
            sts[n], res = _step(n)(sts[n], plan)
            assert (np.asarray(res.ok) == want_ok).all(), (n, rnd)
            assert (np.asarray(res.vals) == want_vals).all(), (n, rnd)

    want_rows = sorted(oracle.d.items())
    lo, hi = jnp.asarray([0], jnp.uint64), jnp.asarray([KEY_INF], jnp.uint64)
    for n in names:
        s = {k: int(v) for k, v in bes[n].stats(sts[n]).items()}
        assert s["size"] == len(oracle.d), n
        if n in POPS:
            assert s["pops"] == oracle.pops, n
            assert s["pop_empty"] == oracle.pop_empty, n
        if bes[n].ordered:
            cnt, ks, vs, valid = bes[n].scan(sts[n], lo, hi, 64)
            rows = [(int(k), int(v)) for k, v, m in
                    zip(np.asarray(ks[0]), np.asarray(vs[0]),
                        np.asarray(valid[0])) if m]
            assert int(cnt[0]) == len(want_rows), n
            assert rows == want_rows, n
        for dsl_state in _dsl_states(n, sts[n]):
            assert_bskiplist_ok(dsl_state, n)


@settings(max_examples=20, deadline=None)
@given(STREAM)
def test_differential_all_backends(stream):
    """INSERT/FIND/DELETE streams with duplicate + adversarial keys:
    every registered backend == the dict oracle, results + scan + stats,
    and every skiplist plane passes the blocked-layout invariants."""
    _run_differential(ALL_BACKENDS, BASIC_OPS, stream)


@settings(max_examples=20, deadline=None)
@given(STREAM)
def test_differential_range_delete(stream):
    """Streams adding RANGE_DELETE windows (lane keys = lo, vals = hi)
    on the backends that wire `range_delete_fn`."""
    _run_differential(RANGE_DEL, BASIC_OPS + (OP_RANGE_DELETE,), stream,
                      salt=1)


@settings(max_examples=20, deadline=None)
@given(STREAM)
def test_differential_pq_pops(stream):
    """The full op surface (pops + range deletes + the basic trio) on the
    priority-queue backend: the shared rank pool must equal sequential
    pop-min on the oracle, including pops against an empty queue."""
    _run_differential(POPS, BASIC_OPS + (OP_RANGE_DELETE, OP_POPMIN,
                                         OP_POPK), stream, salt=2)


@settings(max_examples=10, deadline=None)
@given(STREAM)
def test_differential_fault_interleaved_restore(stream):
    """Fault-interleaved oracle run: every plan is write-ahead journaled
    (store.resilience.Journal); mid-stream the backend state is LOST and
    rebuilt by `replay_plans` over the journal — the rebuilt state must be
    bit-identical (state_digest) to the lost one, and the remainder of the
    stream must keep agreeing with the oracle as if nothing happened."""
    from repro.store import resilience as R

    name = "det_skiplist"
    be = get_backend(name)
    oracle = DictOracle()
    stt = be.init(256)
    journal = R.Journal(base_seq=0)
    plans = _plans(stream, BASIC_OPS + (OP_RANGE_DELETE,), 3)
    crash_at = max(1, len(plans) // 2)
    for rnd, (ops, keys, vals, mask) in enumerate(plans):
        if rnd == crash_at:
            # the crash: state gone; snapshotless rebuild from seq 0
            pre = R.state_digest(stt)
            stt = None
            rebuilt, replayed = R.replay_plans(_step(name), be.init(256),
                                               journal.entries)
            assert R.state_digest(rebuilt) == pre
            assert replayed == sum(e.n_ops for e in journal.entries)
            stt = rebuilt
        # journal the intent with the lane mask folded in (a masked lane
        # is contractually a no-op, so OP_NONE is the same plan)
        eff_ops = np.where(mask, ops, OP_NONE).astype(np.int32)
        journal.append(rnd, eff_ops, keys, vals)
        want_ok, want_vals = oracle.apply(ops, keys, vals, mask)
        stt, res = _step(name)(stt, make_plan(ops, keys, vals, mask))
        assert (np.asarray(res.ok) == want_ok).all(), rnd
        assert (np.asarray(res.vals) == want_vals).all(), rnd
    assert journal.verify()
    # the surviving state still matches the oracle's ordered content
    lo, hi = jnp.asarray([0], jnp.uint64), jnp.asarray([KEY_INF], jnp.uint64)
    cnt, ks, vs, valid = be.scan(stt, lo, hi, 64)
    rows = [(int(k), int(v)) for k, v, m in
            zip(np.asarray(ks[0]), np.asarray(vs[0]), np.asarray(valid[0]))
            if m]
    assert rows == sorted(oracle.d.items())


def test_oracle_is_not_vacuous():
    """The harness must FAIL on a wrong implementation: a backend that
    drops deletes diverges from the oracle on the very first find."""
    class DropDeletes:
        def __init__(self):
            self.inner = get_backend("det_skiplist")
            self.state = self.inner.init(64)

    be = get_backend("det_skiplist")
    stt = be.init(64)
    oracle = DictOracle()
    ops = np.array([OP_INSERT, OP_DELETE, OP_FIND], np.int32)
    keys = np.array([5, 5, 5], np.uint64)
    vals = np.array([7, 0, 0], np.uint64)
    mask = np.array([True, True, True])
    want_ok, _ = oracle.apply(ops, keys, vals, mask)
    # sabotage: drop the delete lane -> the find must disagree
    ops_bad = np.array([OP_INSERT, OP_NONE, OP_FIND], np.int32)
    stt, res = be.apply(stt, make_plan(ops_bad, keys, vals, mask))
    assert bool(np.asarray(res.ok)[2]) != bool(want_ok[2])
