"""B-skiplist warm tier: blocked-walk parity + the execution-knob contract.

The block-major layout (`core.layout.bskiplist_layout` — 128-key
lane-width fat nodes derived at probe time from the UNCHANGED skiplist
state) must be a pure execution knob: `find_batch_blocked`, the
`bskiplist_walk` kernel, and the `tiered3/b128` stack all return the
exact bits of their level-major counterparts. Covered here: walk-level
parity across capacities and tombstone churn (jnp / kernel interpret /
jitted), layout shape + step-count laws, backend-level bit-identity of
results AND the full residency pytree vs `tiered3` across exec modes and
fused/unfused, the 23-counter metrics-plane identity (layout must not
leak into observability), and snapshot scans. The structural invariants
live in tests/invariants.py; the randomized streams in
tests/test_differential.py audit both. (The 8-device engine analogue
runs in tests/multidev/store_prog.py: BSKIP-OK.)
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401  (enables x64)
from repro.core import det_skiplist as dsl
from repro.core.bits import KEY_INF
from repro.core.layout import BSKIP_BLOCK, bskip_num_levels, bskiplist_layout
from repro.kernels.bskiplist_walk.ops import bskiplist_find, bskiplist_search
from repro.store import (OP_DELETE, OP_FIND, OP_INSERT, get_backend,
                         make_plan)
from repro.store import exec as exec_
from repro.store.tiers import unfused_twin

from invariants import assert_bskiplist_ok

MODES = exec_.runnable_modes()


def _populated(cap, seed=0, delete_frac=5):
    """A skiplist with inserts + a tombstone fraction (marked cells stay
    in the terminal plane — the case the found-mask must get right)."""
    rng = np.random.default_rng(seed)
    s = dsl.skiplist_init(cap)
    n = max(cap - cap // 8, 1)
    ks = np.unique(rng.integers(1, 1 << 62, size=2 * cap,
                                dtype=np.uint64))[:n]
    s, _, _ = dsl.insert_batch(s, jnp.asarray(ks), jnp.asarray(ks + 3),
                               jnp.ones((len(ks),), bool))
    dele = rng.choice(ks, size=max(len(ks) // delete_frac, 1), replace=False)
    s, _ = dsl.delete_batch(s, jnp.asarray(dele),
                            jnp.ones((len(dele),), bool))
    return s, ks, dele


def _queries(ks, dele, seed=1, n_miss=64):
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.concatenate([
        ks[:: max(len(ks) // 64, 1)], dele[:16],
        rng.integers(1, 1 << 62, size=n_miss, dtype=np.uint64),
        np.array([KEY_INF], np.uint64)]))


# ---------------------------------------------------------------------------
# walk-level parity: jnp reference, kernel, jitted wrapper
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cap", [64, 128, 300, 1 << 13])
def test_blocked_find_matches_level_walk(cap):
    s, ks, dele = _populated(cap)
    q = _queries(ks, dele)
    f0, v0, _ = dsl.find_batch(s, q)
    f1, v1, _ = dsl.find_batch_blocked(s, q)
    f2, v2, _ = bskiplist_find(s, q, interpret=True)
    f3, v3, _ = bskiplist_search(s, q)
    for tag, (f, v) in {"jnp": (f1, v1), "kernel": (f2, v2),
                        "jit": (f3, v3)}.items():
        assert (np.asarray(f) == np.asarray(f0)).all(), (cap, tag)
        assert (np.asarray(v) == np.asarray(v0)).all(), (cap, tag)
    assert np.asarray(f0)[-1] == False            # noqa: E712 — KEY_INF lane
    assert_bskiplist_ok(s, f"cap={cap}")


def test_blocked_find_empty_and_full_miss():
    s = dsl.skiplist_init(128)
    q = jnp.asarray(np.array([1, 2, KEY_INF], np.uint64))
    for fn in (dsl.find_batch_blocked,
               lambda s, q: bskiplist_find(s, q, interpret=True)):
        f, v, _ = fn(s, q)
        assert not np.asarray(f).any()
        assert not np.asarray(v).any()


def test_blocked_layout_shape_laws():
    """Level monotonicity + the step-count law: the blocked walk descends
    ceil(log_B(blocks)) index levels + 1 terminal block — strictly fewer
    block compares than the fan-out-4 walk's levels at every capacity the
    warm tier actually uses."""
    B = BSKIP_BLOCK
    for cap in (64, 128, 1 << 9, 1 << 13, 1 << 17):
        s = dsl.skiplist_init(cap)
        lay = bskiplist_layout(s)
        L = lay.num_levels
        assert L == bskip_num_levels(cap)
        assert lay.term_hi.shape[0] == -(-cap // B) * B
        # blocked steps (L index rows + 1 terminal block) vs level-major
        # steps (num_levels + 1): the measured BENCH_probe_modes reduction
        if cap > B:
            assert L + 1 < s.num_levels + 1, cap
        # stacked index planes share one block-aligned padded width
        W = lay.blk_hi.shape[1]
        assert L >= 1 and lay.blk_lo.shape == (L, W) and W % B == 0


# ---------------------------------------------------------------------------
# backend-level: tiered3/b128 is an execution knob, not a semantics change
# ---------------------------------------------------------------------------

def _mixed_plans(seed=21, n_rounds=5, width=48, pool_size=96):
    rng = np.random.default_rng(seed)
    pool = rng.integers(1, 2**62, pool_size, dtype=np.uint64)
    plans = []
    for _ in range(n_rounds):
        ops = rng.choice([OP_FIND, OP_INSERT, OP_DELETE], width,
                         p=[0.5, 0.35, 0.15]).astype(np.int32)
        keys = rng.choice(pool, width)
        mask = rng.random(width) > 0.05
        plans.append(make_plan(ops, keys, keys + 1, mask))
    return plans


def assert_states_equal(sa, sb, ctx):
    la, lb = jax.tree.leaves(sa), jax.tree.leaves(sb)
    assert len(la) == len(lb), ctx
    for i, (a, b) in enumerate(zip(la, lb)):
        assert (np.asarray(a) == np.asarray(b)).all(), (ctx, i)


def test_b128_backend_bit_identical_to_level_major():
    """`tiered3/b128` == `tiered3` for results AND the full residency
    pytree, fused and unfused, in every runnable exec mode."""
    plans = _mixed_plans()
    for mode in MODES:
        with exec_.exec_mode(mode):
            bes = [get_backend("tiered3"), get_backend("tiered3/b128"),
                   unfused_twin("tiered3/b128")]
            sts = [b.init(64, hot_bucket=4, hot_frac=8) for b in bes]
            steps = [jax.jit(b.apply) for b in bes]
            for rnd, p in enumerate(plans):
                outs = []
                for j in range(len(bes)):
                    sts[j], r = steps[j](sts[j], p)
                    outs.append(r)
                for j in (1, 2):
                    assert (np.asarray(outs[0].ok)
                            == np.asarray(outs[j].ok)).all(), (mode, rnd, j)
                    assert (np.asarray(outs[0].vals)
                            == np.asarray(outs[j].vals)).all(), \
                        (mode, rnd, j)
                    assert_states_equal(sts[0], sts[j], (mode, rnd, j))
            assert_bskiplist_ok(sts[1].cold, mode)


def test_b128_scan_and_stats_identical():
    be_a, be_b = get_backend("tiered3"), get_backend("tiered3/b128")
    st_a = be_a.init(64, hot_bucket=4, hot_frac=8)
    st_b = be_b.init(64, hot_bucket=4, hot_frac=8)
    for p in _mixed_plans(seed=5, n_rounds=3):
        st_a, _ = be_a.apply(st_a, p)
        st_b, _ = be_b.apply(st_b, p)
    lo = jnp.asarray([0], jnp.uint64)
    hi = jnp.asarray([KEY_INF], jnp.uint64)
    sa, sb = be_a.scan(st_a, lo, hi, 64), be_b.scan(st_b, lo, hi, 64)
    for a, b in zip(sa, sb):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert {k: int(v) for k, v in be_a.stats(st_a).items()} \
        == {k: int(v) for k, v in be_b.stats(st_b).items()}


def test_b128_metrics_plane_identical():
    """The 23-counter metrics plane must not see the layout knob: an
    observed `tiered3/b128` run records the SAME counters as `tiered3`
    (warm_probe_steps stays the level-walk formula on both — the blocked
    walk's step saving is a bench-row fact, not a semantics change)."""
    rows = {}
    for name in ("obs:tiered3", "obs:tiered3/b128"):
        be = get_backend(name)
        st = be.init(64, hot_bucket=4, hot_frac=8)
        for p in _mixed_plans(seed=9, n_rounds=3):
            st, _ = be.apply(st, p)
        rows[name] = {k: int(v) for k, v in be.metrics(st).items()}
    assert rows["obs:tiered3"] == rows["obs:tiered3/b128"]
