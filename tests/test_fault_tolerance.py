"""Fault tolerance: checkpoint/restart bitwise continuation, elastic remap,
straggler mitigation, async-save atomicity."""
import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.checkpoint.ckpt import latest_step, restore, save
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.train.loop import train

SHAPE = ShapeConfig("tiny", seq_len=16, global_batch=4, kind="train")


def test_restart_continues_exactly(tmp_path):
    cfg = get_reduced("qwen3-1.7b")
    # uninterrupted run: 6 steps
    _, _, ref = train(cfg, SHAPE, steps=6, seed=3, log_every=0)
    # interrupted: 3 steps + checkpoint, then "crash" and resume
    d = str(tmp_path / "ckpt")
    train(cfg, SHAPE, steps=3, seed=3, ckpt_dir=d, ckpt_every=3,
          log_every=0, async_save=False)
    assert latest_step(d) == 3
    _, _, cont = train(cfg, SHAPE, steps=6, seed=3, ckpt_dir=d,
                       ckpt_every=100, log_every=0)
    ref_losses = [h["loss"] for h in ref["history"][3:]]
    cont_losses = [h["loss"] for h in cont["history"]]
    assert [h["step"] for h in cont["history"]] == [3, 4, 5]
    np.testing.assert_allclose(ref_losses, cont_losses, rtol=1e-6)


def test_checkpoint_atomic_and_elastic(tmp_path):
    cfg = get_reduced("minicpm3-4b")
    from repro.models import model as M
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    d = str(tmp_path / "c")
    save(d, 5, params, {"next_step": 5})
    # a stale tmp dir must not be visible as a checkpoint
    os.makedirs(os.path.join(d, "step_00000009.tmp"), exist_ok=True)
    assert latest_step(d) == 5
    restored, meta = restore(d, 5, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # elastic: restore with explicit (single-device) shardings
    sh = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), params)
    restored2, _ = restore(d, 5, params, sh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_mitigation_keeps_loss_stream():
    """A producer stalled past the deadline must not stall training: the
    consumer synthesizes the identical batch inline (determinism)."""
    cfg = get_reduced("qwen3-1.7b")

    stalls = {3}

    def delay(step):
        if step in stalls:
            time.sleep(8.0)

    _, _, ref = train(cfg, SHAPE, steps=4, seed=7, log_every=0)
    # depth-1 pipeline (no lookahead can hide the stall) + tight deadline
    from repro.data import pipeline as P
    orig = P.PrefetchPipeline.__init__

    def tight(self, make_batch, depth=4, deadline=30.0, delay_injector=None):
        orig(self, make_batch, depth=1, deadline=0.5,
             delay_injector=delay_injector)

    P.PrefetchPipeline.__init__ = tight
    try:
        _, _, out = train(cfg, SHAPE, steps=4, seed=7, log_every=0,
                          delay_injector=delay)
    finally:
        P.PrefetchPipeline.__init__ = orig
    assert out["straggler_skips"] >= 1
    np.testing.assert_allclose([h["loss"] for h in ref["history"]],
                               [h["loss"] for h in out["history"]], rtol=1e-6)
