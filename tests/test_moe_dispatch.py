"""MoE dispatch: single-device reference behaviour + 8-device equivalence
of the three dispatch implementations (subprocess, fixed device count)."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs import get_reduced
from repro.models import moe as moe_mod

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_dense_dispatch_routes_topk():
    cfg = get_reduced("qwen3-moe-235b-a22b")
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model), jnp.float32)
    y, aux = moe_mod.moe_dense_ffn(p, cfg, x.astype(jnp.bfloat16))
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())
    assert float(aux) > 0
    # router selects exactly top-k distinct experts per token
    w, idx, _ = moe_mod.router_probs(p, cfg, x)
    assert idx.shape == (32, cfg.n_experts_active)
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == cfg.n_experts_active
    np.testing.assert_allclose(np.asarray(jnp.sum(w, axis=-1)), 1.0, rtol=1e-5)


def test_shared_expert_added_once():
    cfg = get_reduced("llama4-scout-17b-a16e")
    from repro.models.blocks import _ffn_apply, init_block
    p = init_block(jax.random.PRNGKey(0), cfg, "moe")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.bfloat16)
    y, aux = _ffn_apply(p["ffn"], cfg, x)
    assert y.shape == x.shape and not bool(jnp.isnan(y).any())


@pytest.mark.slow
def test_moe_dispatch_equivalence_multidevice():
    prog = os.path.join(ROOT, "tests", "multidev", "moe_prog.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, prog], env=env, capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "MOE-OK" in out.stdout
