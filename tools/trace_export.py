"""Export `repro.store.obs` trace spans as Chrome-trace / Perfetto JSON.

`obs.tracing()` records host wall-clock spans (`obs.span`) into a Tracer;
`to_chrome_trace` converts one Tracer into the Chrome Trace Event format
(JSON object with a ``traceEvents`` list of complete "X" events), which
https://ui.perfetto.dev opens directly — see docs/observability.md for the
span taxonomy and a how-to.

Run as a CLI it produces a demo timeline from a single-device `StoreEngine`
over an observed tier stack (churn workload: inserts, deletes, finds), and
embeds the final metrics plane in the trace metadata so the counter totals
ride along with the timeline:

    python tools/trace_export.py --out trace.json
    python tools/trace_export.py --out trace.json \\
        --backend obs:tiered3/lru --steps 8 --lanes 64

CI runs exactly that and uploads ``trace.json`` as the ``perfetto-trace``
artifact, so every push has an openable timeline.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def to_chrome_trace(tracer, meta: dict | None = None) -> dict:
    """Chrome Trace Event JSON for one `obs.Tracer`.

    Every span becomes a complete event (``ph: "X"``) with microsecond
    ``ts``/``dur`` relative to the tracer's epoch, so timestamps start near
    zero and nested spans (engine step > route > find ...) stack in
    Perfetto's flame view. `meta` (e.g. the final metrics plane) lands in
    ``otherData``, the spec's free-form metadata slot."""
    events = [{
        "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
        "args": {"name": "repro.store"},
    }]
    for s in tracer.spans:
        events.append({
            "name": s.name,
            "cat": s.cat,
            "ph": "X",
            "ts": (s.ts_ns - tracer.t0_ns) / 1e3,
            "dur": s.dur_ns / 1e3,
            "pid": 0,
            "tid": 0,
            "args": {k: (v if isinstance(v, (int, float, str, bool))
                         else str(v)) for k, v in s.args.items()},
        })
    payload = {"traceEvents": events, "displayTimeUnit": "ms"}
    if meta:
        payload["otherData"] = meta
    return payload


def record_demo_trace(backend: str = "obs:tiered3/lru", steps: int = 8,
                      lanes: int = 64, fault_step: int | None = None):
    """Run a small churn workload on a 1-device engine under `tracing()`;
    returns (tracer, metrics dict of plain ints). The spans cover the whole
    taxonomy the engine path exercises: "step" per batch (real wall time),
    and the trace-time "route"/"insert"/"delete"/"find"/"demote"/
    "promote"/"compact" phases from the first step's trace. With
    `fault_step` set, the engine is wrapped in a `ResilientEngine` with a
    shard-drop at that step, so the timeline also shows a real "recover"
    span (snapshot + journal rebuild) mid-trace."""
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.store import obs
    from repro.store import resilience as R
    from repro.store.engine import StoreEngine

    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    eng = StoreEngine(mesh, ("d",), lanes=lanes, backend=backend)
    drive = eng
    if fault_step is not None:
        fplan = R.FaultPlan(0, [R.Fault("shard_drop", fault_step, shard=0)])
        drive = R.ResilientEngine(eng, snapshot_every=2, fault_plan=fplan)
    state = jax.device_put(eng.init(max(4 * lanes, 64), hot_bucket=4,
                                    hot_frac=8), eng.sharding)
    rng = np.random.default_rng(0)
    with obs.tracing() as tracer:
        for _ in range(steps):
            ops = jnp.asarray(rng.integers(0, 3, lanes).astype(np.int32))
            keys = jnp.asarray(
                rng.integers(1, 4 * lanes, lanes).astype(np.uint64))
            vals = jnp.asarray(
                rng.integers(1, 1 << 20, lanes).astype(np.uint64))
            state, _, _, _ = drive.step(state, ops, keys, vals)
    if fault_step is not None:
        metrics = {k: int(v) for k, v in drive.metrics(state).items()}
    else:
        metrics = {k: int(v[0]) for k, v in eng.metrics(state).items()}
    return tracer, metrics


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(
        description="export a demo store timeline as Perfetto JSON")
    ap.add_argument("--out", default="trace.json",
                    help="output path (default trace.json)")
    ap.add_argument("--backend", default="obs:tiered3/lru",
                    help="obs:-prefixed registry string (default "
                         "obs:tiered3/lru)")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=64)
    ap.add_argument("--fault-step", type=int, default=None,
                    help="inject a shard drop at this step (wraps the "
                         "engine in a ResilientEngine) so the timeline "
                         "includes a 'recover' span")
    args = ap.parse_args(argv[1:])
    if not args.backend.startswith("obs:"):
        ap.error("--backend must be obs:-prefixed (the demo embeds the "
                 "metrics plane in the trace metadata)")

    sys.path.insert(0, os.path.join(ROOT, "src"))
    tracer, metrics = record_demo_trace(backend=args.backend,
                                        steps=args.steps, lanes=args.lanes,
                                        fault_step=args.fault_step)
    payload = to_chrome_trace(tracer, meta={"backend": args.backend,
                                            "metrics": metrics})
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out} ({len(tracer.spans)} spans; open at "
          f"https://ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
