"""Docs-consistency gate (CI step): the docs must name every registered
store backend string and every benchmark JSON artifact.

Fails (exit 1) when:
  * a `repro.store` registry string has no mention in docs/*.md — so a new
    backend cannot ship without at least an index entry, or
  * a `benchmarks/*.py` Recorder table's ``BENCH_<table>.json`` name is
    missing from docs/*.md — so artifact names and their docs stay in sync,
  * an observability name — a `METRICS_SCHEMA` counter, a `SERVING_SCHEMA`
    counter, or a `SPAN_TAXONOMY` span — has no mention, so the
    docs/observability.md glossary stays exhaustive.

Run from anywhere: ``python tools/check_docs.py`` (adds src/ to the path
itself, like benchmarks/run.py).
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def docs_text() -> str:
    docs_dir = os.path.join(ROOT, "docs")
    parts = []
    for name in sorted(os.listdir(docs_dir)):
        if name.endswith(".md"):
            with open(os.path.join(docs_dir, name)) as f:
                parts.append(f.read())
    return "\n".join(parts)


def bench_artifacts() -> list[str]:
    """BENCH_<table>.json names derived from Recorder("<table>") calls."""
    bench_dir = os.path.join(ROOT, "benchmarks")
    tables = set()
    for name in sorted(os.listdir(bench_dir)):
        if not name.endswith(".py"):
            continue
        with open(os.path.join(bench_dir, name)) as f:
            tables.update(re.findall(r"Recorder\(\s*[\"']([^\"']+)[\"']",
                                     f.read()))
    return sorted(f"BENCH_{t}.json" for t in tables)


def main() -> int:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.store import available_backends
    from repro.store import obs

    text = docs_text()

    def mentioned(name: str) -> bool:
        # standalone mention only: 'tiered3' inside 'tiered3/lru' (or any
        # future superstring) must NOT count as documentation of 'tiered3'
        return re.search(rf"(?<![\w+/]){re.escape(name)}(?![\w+/])",
                         text) is not None

    missing = [f"store backend string {b!r}"
               for b in available_backends() if not mentioned(b)]
    missing += [f"benchmark artifact name {a!r}"
                for a in bench_artifacts() if not mentioned(a)]
    missing += [f"metrics counter {m!r}"
                for m in obs.METRICS_SCHEMA if not mentioned(m)]
    missing += [f"serving counter {m!r}"
                for m in obs.SERVING_SCHEMA if not mentioned(m)]
    missing += [f"trace span {s!r}"
                for s in obs.SPAN_TAXONOMY if not mentioned(s)]
    if missing:
        print("docs/*.md is missing:", file=sys.stderr)
        for m in missing:
            print(f"  - {m}", file=sys.stderr)
        print("document new backends/artifacts in docs/README.md "
              "(see its registry + artifact tables)", file=sys.stderr)
        return 1
    print(f"docs-consistency OK: {len(available_backends())} backend "
          f"strings, {len(bench_artifacts())} artifact names, "
          f"{len(obs.METRICS_SCHEMA) + len(obs.SERVING_SCHEMA)} counters, "
          f"{len(obs.SPAN_TAXONOMY)} spans")
    return 0


if __name__ == "__main__":
    sys.exit(main())
