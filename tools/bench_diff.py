"""Diff two benchmark JSON artifacts (``BENCH_<table>.json``, written by
``benchmarks.common.Recorder``): join rows by name and print per-row
deltas, so fused-vs-unfused (or before-vs-after-a-PR) comparisons are one
command instead of eyeballing two files.

    python tools/bench_diff.py bench_a/BENCH_tiers.json \\
                               bench_b/BENCH_tiers.json

For every row name present in both files it prints the old and new
``us_per_call`` and the relative delta (negative = B is faster); rows
present in only one file are listed separately. The artifacts'
measurement metadata (backend, exec modes, repeat count, warmup discard)
is printed first — numbers from different protocols are flagged, not
silently compared.
"""
from __future__ import annotations

import json
import sys

META_KEYS = ("jax_backend", "device_count", "exec_modes", "bench_iters",
             "warmup_discard")


def load(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if "rows" not in payload:
        raise SystemExit(f"{path}: not a Recorder artifact (no 'rows')")
    return payload


def main(argv: list[str]) -> int:
    if len(argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    a, b = load(argv[1]), load(argv[2])
    meta_mismatch = [k for k in META_KEYS
                     if a.get(k) != b.get(k) and (k in a or k in b)]
    for payload, path in ((a, argv[1]), (b, argv[2])):
        meta = {k: payload.get(k) for k in META_KEYS if k in payload}
        print(f"{path}: table={payload['table']} {meta}")
    if meta_mismatch:
        print(f"WARNING: measurement metadata differs on {meta_mismatch} — "
              f"deltas below compare different protocols/platforms")

    rows_a = {r["name"]: r for r in a["rows"]}
    rows_b = {r["name"]: r for r in b["rows"]}
    shared = [n for n in rows_a if n in rows_b]
    width = max((len(n) for n in shared), default=4)
    print(f"\n{'row':<{width}}  {'A us/call':>10}  {'B us/call':>10}  "
          f"{'delta':>8}")
    for n in shared:
        ua, ub = rows_a[n]["us_per_call"], rows_b[n]["us_per_call"]
        delta = (ub - ua) / ua * 100 if ua else float("inf")
        print(f"{n:<{width}}  {ua:>10.2f}  {ub:>10.2f}  {delta:>+7.1f}%")
    for only, rows, path in ((set(rows_a) - set(rows_b), rows_a, argv[1]),
                             (set(rows_b) - set(rows_a), rows_b, argv[2])):
        for n in sorted(only):
            print(f"only in {path}: {n} "
                  f"({rows[n]['us_per_call']:.2f} us/call)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
