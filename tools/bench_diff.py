"""Diff two benchmark JSON artifacts (``BENCH_<table>.json``, written by
``benchmarks.common.Recorder``): join rows by name and print per-row
deltas, so fused-vs-unfused (or before-vs-after-a-PR) comparisons are one
command instead of eyeballing two files.

    python tools/bench_diff.py bench_a/BENCH_tiers.json \\
                               bench_b/BENCH_tiers.json

For every row name present in both files it prints the old and new
``us_per_call`` and the relative delta (negative = B is faster); rows
present in only one file are listed separately. The artifacts'
measurement metadata (backend, exec modes, repeat count, warmup discard)
is printed first — numbers from different protocols are flagged, not
silently compared.

Regression-gate mode (the CI smoke gate over the tier-churn rows):

    python tools/bench_diff.py --assert-within 50 base.json new.json

exits nonzero when ANY shared row's ``us_per_call`` regresses (B slower
than A) by more than the threshold percentage. Rows that carry a measured
``dispatches_per_apply`` are additionally gated EXACTLY: dispatch counts
are a compile-time structural property, not a noisy timing, so any growth
at all fails (the fused tier apply's ≤2-dispatch contract rides on this).
Improvements and missing rows never fail the gate — it bounds
regressions, it does not require progress. The mode refuses to gate
across mismatched measurement metadata (exit 2), since cross-protocol
deltas are noise.
"""
from __future__ import annotations

import argparse
import json
import sys

META_KEYS = ("jax_backend", "device_count", "exec_modes", "bench_iters",
             "warmup_discard")


def load(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if "rows" not in payload:
        raise SystemExit(f"{path}: not a Recorder artifact (no 'rows')")
    return payload


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_<table>.json artifacts (A -> B)")
    ap.add_argument("a", help="baseline artifact (A)")
    ap.add_argument("b", help="candidate artifact (B)")
    ap.add_argument("--assert-within", type=float, default=None,
                    metavar="PCT",
                    help="exit 1 if any shared row's us_per_call regresses "
                         "more than PCT%% vs the baseline")
    args = ap.parse_args(argv[1:])

    a, b = load(args.a), load(args.b)
    meta_mismatch = [k for k in META_KEYS
                     if a.get(k) != b.get(k) and (k in a or k in b)]
    for payload, path in ((a, args.a), (b, args.b)):
        meta = {k: payload.get(k) for k in META_KEYS if k in payload}
        print(f"{path}: table={payload['table']} {meta}")
    if meta_mismatch:
        print(f"WARNING: measurement metadata differs on {meta_mismatch} — "
              f"deltas below compare different protocols/platforms")
        if args.assert_within is not None:
            print("refusing to gate across mismatched metadata",
                  file=sys.stderr)
            return 2

    rows_a = {r["name"]: r for r in a["rows"]}
    rows_b = {r["name"]: r for r in b["rows"]}
    shared = [n for n in rows_a if n in rows_b]
    width = max((len(n) for n in shared), default=4)
    print(f"\n{'row':<{width}}  {'A us/call':>10}  {'B us/call':>10}  "
          f"{'delta':>8}")
    regressions = []
    for n in shared:
        ua, ub = rows_a[n]["us_per_call"], rows_b[n]["us_per_call"]
        delta = (ub - ua) / ua * 100 if ua else float("inf")
        print(f"{n:<{width}}  {ua:>10.2f}  {ub:>10.2f}  {delta:>+7.1f}%")
        if args.assert_within is not None and delta > args.assert_within:
            regressions.append((n, f"{delta:+.1f}%"))
        da = rows_a[n].get("dispatches_per_apply")
        db = rows_b[n].get("dispatches_per_apply")
        if args.assert_within is not None and da is not None \
                and db is not None and db > da:
            regressions.append(
                (n, f"dispatches_per_apply {da} -> {db}"))
    for only, rows, path in ((set(rows_a) - set(rows_b), rows_a, args.a),
                             (set(rows_b) - set(rows_a), rows_b, args.b)):
        for n in sorted(only):
            print(f"only in {path}: {n} "
                  f"({rows[n]['us_per_call']:.2f} us/call)")

    if args.assert_within is not None:
        if regressions:
            print(f"\nFAIL: {len(regressions)} row(s) regressed beyond "
                  f"{args.assert_within:g}%:", file=sys.stderr)
            for n, what in regressions:
                print(f"  {n}: {what}", file=sys.stderr)
            return 1
        print(f"\nOK: no shared row regressed beyond "
              f"{args.assert_within:g}% ({len(shared)} rows gated)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
